"""Tick-domain Chrome-trace export for every transport.

Maps the deterministic tick-domain world the repo already computes —
``faults.Scenario.timeline`` events, transfer in-flight windows
(latency + jitter + retries), the streaming fragment schedule's
snapshot→gather→merge offsets — onto Chrome trace-event JSON:

  * one lane (pid/tid) per worker: inner-compute phases and
    worker→server transfers as spans, Arrival / Lost / Leave / Join as
    instants, preemption gaps as spans;
  * one lane per streaming fragment: the in-flight gather window from
    its snapshot offset to its α-merge, carrying the packed wire bytes
    the PR 5 accounting charges;
  * a rounds lane for barrier-paced transports, one span per outer
    round annotated with the round record (loss, ppl, active count).

The produced file loads in Perfetto (https://ui.perfetto.dev) or
chrome://tracing; 1 tick is rendered as 1 ms. ``validate_trace``
checks structural well-formedness, ``span_event_correspondence``
checks the exactly-once contract (every applied delta ↔ exactly one
delivered transfer span) — both are CI gates via ``benchmarks/obs.py``
and ``python -m repro.obs.trace`` (the CLI validator).
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.core import faults
from repro.obs.metrics import to_jsonable

TICK_US = 1000.0            # 1 tick -> 1 ms on the Perfetto timeline

PID_ROUNDS = 0              # barrier-paced round spans
PID_WORKERS = 1             # one tid per worker
PID_FRAGMENTS = 2           # one tid per streaming fragment

_VALID_PH = {"M", "X", "i", "I", "B", "E", "C"}


class TraceBuilder:
    """Accumulates Chrome trace events in tick units (converted to µs
    at append time). Lane naming goes through ``process``/``thread``
    metadata events so Perfetto shows readable groups."""

    def __init__(self):
        self.events: list = []
        self._named: set = set()

    def process(self, pid: int, name: str):
        if ("p", pid) not in self._named:
            self._named.add(("p", pid))
            self.events.append({"name": "process_name", "ph": "M",
                                "pid": pid, "tid": 0,
                                "args": {"name": name}})

    def thread(self, pid: int, tid: int, name: str):
        if ("t", pid, tid) not in self._named:
            self._named.add(("t", pid, tid))
            self.events.append({"name": "thread_name", "ph": "M",
                                "pid": pid, "tid": tid,
                                "args": {"name": name}})

    def span(self, name: str, *, pid: int, tid: int, start, dur,
             args: dict | None = None, cat: str = ""):
        ev = {"name": name, "ph": "X", "pid": pid, "tid": tid,
              "ts": float(start) * TICK_US,
              "dur": max(0.0, float(dur)) * TICK_US}
        if cat:
            ev["cat"] = cat
        if args:
            ev["args"] = args
        self.events.append(ev)

    def instant(self, name: str, *, pid: int, tid: int, tick,
                args: dict | None = None, cat: str = ""):
        ev = {"name": name, "ph": "i", "pid": pid, "tid": tid,
              "ts": float(tick) * TICK_US, "s": "t"}
        if cat:
            ev["cat"] = cat
        if args:
            ev["args"] = args
        self.events.append(ev)

    def to_json(self, other_data: dict | None = None) -> dict:
        return to_jsonable({"traceEvents": self.events,
                            "displayTimeUnit": "ms",
                            "otherData": other_data or {}})

    def write(self, path: str, other_data: dict | None = None) -> str:
        with open(path, "w") as f:
            json.dump(self.to_json(other_data), f, indent=1)
        return path


def _worker_lanes(tb: TraceBuilder, k: int):
    tb.process(PID_WORKERS, "workers")
    for w in range(k):
        tb.thread(PID_WORKERS, w, f"worker {w}")


# ---------------------------------------------------------------------------
# barrier-free (async) runs: the event timeline IS the trace
# ---------------------------------------------------------------------------

def async_trace(scenario: faults.Scenario, k: int, ticks: int, *,
                history=(), wire_bytes: float = 0.0) -> TraceBuilder:
    """Trace of a barrier-free run: replays ``scenario.timeline`` onto
    worker lanes. For each terminal event the compute span covers
    [dispatch, finish]; each send attempt departs ``retry_backoff``
    ticks after the previous drop, so the delivered transfer span is
    [finish + attempt·backoff, arrival] with one dropped-send instant
    per failed attempt, and a Lost payload's span runs to its give-up
    tick. ``history`` (engine event records) annotates spans with the
    applied staleness / weight / delta norm; the timeline alone (no
    engine run) still yields a complete, valid trace."""
    tb = TraceBuilder()
    _worker_lanes(tb, k)
    by_uid = {r["uid"]: r for r in history if "uid" in r}
    backoff = max(1, int(scenario.retry_backoff))
    n_attempts = 1 + max(0, int(scenario.max_retries))
    gone_since: dict[int, int] = {}
    events = scenario.timeline(k, ticks)
    for ev in events:
        if isinstance(ev, faults.Arrival):
            tb.span("inner phase", pid=PID_WORKERS, tid=ev.worker,
                    start=ev.dispatch_tick,
                    dur=ev.finish_tick - ev.dispatch_tick, cat="compute",
                    args={"uid": ev.uid, "worker": ev.worker})
            depart = ev.finish_tick + ev.attempt * backoff
            for a in range(ev.attempt):
                tb.instant("dropped send", pid=PID_WORKERS,
                           tid=ev.worker, tick=ev.finish_tick + a * backoff,
                           args={"uid": ev.uid, "attempt": a})
            rec = by_uid.get(ev.uid, {})
            args = {"uid": ev.uid, "worker": ev.worker,
                    "attempt": ev.attempt, "delivered": True,
                    "wire_bytes": float(rec.get("wire_bytes",
                                                wire_bytes))}
            for key in ("staleness", "weight", "delta_norm",
                        "inner_loss", "val_loss", "ppl"):
                if key in rec:
                    args[key] = rec[key]
            tb.span("transfer", pid=PID_WORKERS, tid=ev.worker,
                    start=depart, dur=ev.tick - depart, cat="wire",
                    args=args)
            tb.instant("apply", pid=PID_WORKERS, tid=ev.worker,
                       tick=ev.tick, args={"uid": ev.uid,
                                           "attempt": ev.attempt})
        elif isinstance(ev, faults.Lost):
            if ev.dispatch_tick >= 0:
                tb.span("inner phase", pid=PID_WORKERS, tid=ev.worker,
                        start=ev.dispatch_tick,
                        dur=ev.finish_tick - ev.dispatch_tick,
                        cat="compute",
                        args={"uid": ev.uid, "worker": ev.worker})
                for a in range(n_attempts):
                    tb.instant("dropped send", pid=PID_WORKERS,
                               tid=ev.worker,
                               tick=ev.finish_tick + a * backoff,
                               args={"uid": ev.uid, "attempt": a})
                tb.span("transfer (lost)", pid=PID_WORKERS,
                        tid=ev.worker, start=ev.finish_tick,
                        dur=ev.tick - ev.finish_tick, cat="wire",
                        args={"uid": ev.uid, "worker": ev.worker,
                              "delivered": False,
                              "attempts": n_attempts})
            tb.instant("lost", pid=PID_WORKERS, tid=ev.worker,
                       tick=ev.tick, args={"uid": ev.uid})
        elif isinstance(ev, faults.Leave):
            gone_since[ev.worker] = ev.tick
            tb.instant("leave", pid=PID_WORKERS, tid=ev.worker,
                       tick=ev.tick)
        elif isinstance(ev, faults.Join):
            since = gone_since.pop(ev.worker, ev.tick)
            tb.span("preempted", pid=PID_WORKERS, tid=ev.worker,
                    start=since, dur=ev.tick - since, cat="fault")
            tb.instant("join", pid=PID_WORKERS, tid=ev.worker,
                       tick=ev.tick)
    for w, since in gone_since.items():
        tb.span("preempted", pid=PID_WORKERS, tid=w, start=since,
                dur=ticks - since, cat="fault")
    return tb


# ---------------------------------------------------------------------------
# barrier-paced runs (sync / streaming / sharded / gossip)
# ---------------------------------------------------------------------------

def round_trace(*, transport: str, k: int, rounds: int, H: int,
                scenario: faults.Scenario | None = None, drops=None,
                acts=None, history=(), plan=(), wire_bytes=None,
                gossip_rounds=(), overlap=None) -> TraceBuilder:
    """Trace of a barrier-paced run. Round r spans the tick window
    [r·T, (r+1)·T) with T = ``sync_round_ticks`` (1 under no
    scenario); each active worker's inner compute covers its own speed
    and its outer send pays its link latency; the barrier absorbs the
    rest of the window. Streaming fragment lanes map the staggered
    schedule (``plan`` rows from ``streaming.sync_plan``) into each
    round's compute window — a fragment whose apply crosses the round
    boundary draws its in-flight gather through the barrier, the
    overlap the schedule exists to create. ``gossip_rounds``
    ({"round", "fragment", "edges"} rows) draws the realized pairwise
    exchanges. ``overlap`` (``hlo_analysis.stream_overlap`` output)
    overlays the MEASURED issue→consume separation from the lowered
    HLO onto each fragment lane — the scheduled gather span plus a
    "consume (measured)" marker at the HLO-observed offset."""
    scenario = scenario or faults.Scenario.uniform(k)
    speeds = scenario.resolved_speeds(k)
    lat = scenario.resolved_latency(k)
    T = scenario.sync_round_ticks(k)
    smax = max(speeds)
    tb = TraceBuilder()
    tb.process(PID_ROUNDS, "rounds")
    tb.thread(PID_ROUNDS, 0, "outer rounds")
    _worker_lanes(tb, k)
    by_round = {r["round"]: r for r in history if "round" in r}
    for r in range(rounds):
        lo = r * T
        rec = by_round.get(r + 1, {})
        args = {kk: rec[kk] for kk in ("inner_loss", "val_loss",
                                       "outer_gnorm", "active")
                if kk in rec}
        tb.span(f"round {r + 1}", pid=PID_ROUNDS, tid=0, start=lo,
                dur=T, args=args or None)
        for w in range(k):
            if acts is not None and not acts[r][w]:
                continue
            tb.span("inner phase", pid=PID_WORKERS, tid=w, start=lo,
                    dur=speeds[w], cat="compute",
                    args={"round": r + 1, "worker": w})
            finish = lo + speeds[w]
            if drops is not None and not drops[r][w]:
                tb.instant("dropped", pid=PID_WORKERS, tid=w,
                           tick=finish, args={"round": r + 1})
            elif transport != "gossip" and not plan \
                    and wire_bytes is not None:
                tb.span("outer send", pid=PID_WORKERS, tid=w,
                        start=finish, dur=lat[w], cat="wire",
                        args={"round": r + 1, "worker": w,
                              "delivered": True,
                              "wire_bytes": float(wire_bytes)})
    if acts is not None:
        _preempt_spans(tb, acts, k, rounds, T)
    if plan:
        _fragment_lanes(tb, plan, k=k, rounds=rounds, H=H, T=T,
                        smax=smax, overlap=overlap)
    for g in gossip_rounds:
        for i, j in g.get("edges", ()):
            lo = g["round"] * T
            for a, b in ((i, j), (j, i)):
                tb.instant("exchange", pid=PID_WORKERS, tid=a,
                           tick=lo + speeds[a],
                           args={"partner": b,
                                 "fragment": g.get("fragment"),
                                 "round": g["round"] + 1})
    return tb


def _preempt_spans(tb: TraceBuilder, acts, k: int, rounds: int, T: int):
    """Contiguous inactive-round stretches drawn as preemption spans."""
    for w in range(k):
        start = None
        for r in range(rounds + 1):
            gone = r < rounds and not acts[r][w]
            if gone and start is None:
                start = r
            elif not gone and start is not None:
                tb.span("preempted", pid=PID_WORKERS, tid=w,
                        start=start * T, dur=(r - start) * T,
                        cat="fault")
                start = None


def _fragment_lanes(tb: TraceBuilder, plan, *, k: int, rounds: int,
                    H: int, T: int, smax: int, overlap=None):
    tb.process(PID_FRAGMENTS, "fragments")
    for row in plan:
        tb.thread(PID_FRAGMENTS, row["fragment"],
                  f"fragment {row['fragment']}")
    # measured issue→consume rows from the lowered HLO, matched to
    # schedule rows by issue order: deferred wire collectives are
    # emitted in send_step order (the wrapped fragment sends at H,
    # last), so sorting both sides aligns fragment ↔ collective
    measured = {}
    if overlap:
        wire = sorted((m for m in overlap.get("rows", ())
                       if m.get("deferred")),
                      key=lambda m: m["issue_id"])
        frags = sorted(plan, key=lambda row: row["send_step"])
        measured = {row["fragment"]: m for row, m in zip(frags, wire)}
    for r in range(rounds):
        lo = r * T
        for row in plan:
            p = row["fragment"]
            send_t = lo + row["send_step"] / H * smax
            a = row["apply_step"]
            apply_t = (lo + a / H * smax if a <= H
                       else lo + T + (a - H) / H * smax)
            tb.instant("snapshot", pid=PID_FRAGMENTS, tid=p,
                       tick=send_t, args={"round": r + 1})
            args = {"round": r + 1, "fragment": p,
                    "delivered": True,
                    "wire_bytes": float(row["wire_bytes"]),
                    "elems": row.get("elems"),
                    "crosses_round": bool(a > H)}
            m = measured.get(p)
            if m is not None:
                args.update(
                    hlo_issue_id=m["issue_id"],
                    hlo_consume_id=m["consume_id"],
                    measured_steps_between=m["steps_between"],
                    measured_dots_between=m["dots_between"],
                    wrapped=bool(m["wrapped"]))
            tb.span("gather (in flight)", pid=PID_FRAGMENTS, tid=p,
                    start=send_t, dur=apply_t - send_t, cat="wire",
                    args=args)
            if m is not None:
                tb.instant(
                    "consume (measured)", pid=PID_FRAGMENTS, tid=p,
                    tick=send_t + m["steps_between"] / H * smax,
                    args={"round": r + 1, "fragment": p,
                          "steps_after_issue": m["steps_between"],
                          "dots_after_issue": m["dots_between"]})
            tb.instant("merge", pid=PID_FRAGMENTS, tid=p, tick=apply_t,
                       args={"round": r + 1, "fragment": p})


# ---------------------------------------------------------------------------
# structural gates
# ---------------------------------------------------------------------------

def validate_trace(trace) -> list:
    """Structural well-formedness of a Chrome trace-event bundle.
    Returns a list of error strings — [] means valid (the shape
    Perfetto's JSON importer accepts)."""
    errors = []
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        return ["trace must be a dict with a 'traceEvents' list"]
    evs = trace["traceEvents"]
    if not isinstance(evs, list):
        return ["'traceEvents' must be a list"]
    for n, e in enumerate(evs):
        where = f"event {n}"
        if not isinstance(e, dict):
            errors.append(f"{where}: not a dict")
            continue
        ph = e.get("ph")
        if ph not in _VALID_PH:
            errors.append(f"{where}: bad ph {ph!r}")
            continue
        if not isinstance(e.get("name"), str):
            errors.append(f"{where}: missing name")
        for key in ("pid", "tid"):
            if not isinstance(e.get(key), int):
                errors.append(f"{where}: missing int {key}")
        if ph != "M":
            ts = e.get("ts")
            if not isinstance(ts, (int, float)) or ts < 0:
                errors.append(f"{where}: bad ts {ts!r}")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"{where}: bad dur {dur!r}")
        if "args" in e and not isinstance(e["args"], dict):
            errors.append(f"{where}: args must be a dict")
    try:
        json.dumps(trace)
    except (TypeError, ValueError) as exc:
        errors.append(f"not JSON-serializable: {exc}")
    return errors


def transfer_spans(trace) -> list:
    """All wire spans (cat='wire', ph='X') in a trace bundle."""
    return [e for e in trace.get("traceEvents", ())
            if e.get("ph") == "X" and e.get("cat") == "wire"]


def span_event_correspondence(trace, records) -> list:
    """The exactly-once gate: every applied delta ("arrival" record)
    has exactly one delivered transfer span carrying its uid, every
    permanently-lost payload exactly one undelivered span, and no wire
    span exists without its record. Returns error strings ([] = the
    contract holds)."""
    errors = []
    delivered, undelivered = {}, {}
    for e in transfer_spans(trace):
        a = e.get("args", {})
        if "uid" not in a:
            continue
        bucket = delivered if a.get("delivered") else undelivered
        bucket[a["uid"]] = bucket.get(a["uid"], 0) + 1
    want_arr = [r["uid"] for r in records if r.get("event") == "arrival"]
    want_lost = [r["uid"] for r in records if r.get("event") == "lost"]
    for uid in want_arr:
        if delivered.get(uid) != 1:
            errors.append(f"arrival uid {uid}: "
                          f"{delivered.get(uid, 0)} delivered spans "
                          "(want exactly 1)")
    for uid in want_lost:
        if undelivered.get(uid) != 1:
            errors.append(f"lost uid {uid}: "
                          f"{undelivered.get(uid, 0)} lost spans "
                          "(want exactly 1)")
    for uid in set(delivered) - set(want_arr):
        errors.append(f"delivered span uid {uid} has no arrival record")
    for uid in set(undelivered) - set(want_lost):
        errors.append(f"lost span uid {uid} has no lost record")
    return errors


def trace_wire_bytes(trace) -> float:
    """Total bytes annotated on delivered wire spans — the number the
    benchmark cross-checks against ``wire_bytes()`` accounting and the
    HLO-measured cross-pod bytes."""
    return float(sum(e.get("args", {}).get("wire_bytes", 0.0)
                     for e in transfer_spans(trace)
                     if e.get("args", {}).get("delivered")))


# ---------------------------------------------------------------------------
# CLI validator (used by the CI obs job)
# ---------------------------------------------------------------------------

def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Validate Chrome trace-event files produced by "
                    "repro.obs (exit 1 on the first invalid file).")
    ap.add_argument("paths", nargs="+", help="trace JSON files")
    args = ap.parse_args(argv)
    bad = 0
    for path in args.paths:
        with open(path) as f:
            trace = json.load(f)
        errors = validate_trace(trace)
        n_spans = sum(1 for e in trace.get("traceEvents", ())
                      if isinstance(e, dict) and e.get("ph") == "X")
        if errors:
            bad += 1
            print(f"[INVALID] {path}: {len(errors)} error(s)")
            for e in errors[:10]:
                print("   ", e)
        else:
            print(f"[ok] {path}: "
                  f"{len(trace['traceEvents'])} events, "
                  f"{n_spans} spans, "
                  f"{trace_wire_bytes(trace):.0f} B on the wire")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
