"""Logical-axis based sharding specification.

Every parameter leaf is annotated at init time with a tuple of *logical*
axis names (one per array dim, ``None`` for unsharded). A rules table maps
logical names onto mesh axes; the mapping is divisibility-aware (an axis
whose size does not divide the mesh axis size falls back to replication,
e.g. starcoder2's 4 KV heads on a 16-way model axis) and greedy by
priority (for a given mesh axis, the highest-priority divisible logical
axis present on the param gets it; e.g. whisper's 20 heads don't divide 16
so the d_model/"embed" axis is sharded instead).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Logical axis -> mesh axis. Order in PRIORITY decides who wins a mesh axis
# when several logical axes on one param map to it.
DEFAULT_RULES: dict[str, str] = {
    "replica": "pod",    # stacked DiLoCo replicas live one-per-pod
    "batch": "data",
    "experts": "model",
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "vocab": "model",
    "inner": "model",    # mamba/xlstm expanded inner dim
    "embed": "model",    # fallback: shard d_model rows when heads don't divide
}

PRIORITY = ["replica", "batch", "experts", "heads", "kv_heads", "ff",
            "vocab", "inner", "embed"]


class Boxed:
    """A parameter value paired with its logical axis names."""

    __slots__ = ("value", "axes")

    def __init__(self, value, axes):
        assert value.ndim == len(axes), (value.shape, axes)
        self.value = value
        self.axes = tuple(axes)

    def __repr__(self):
        return f"Boxed({self.value.shape}, axes={self.axes})"


def is_boxed(x) -> bool:
    return isinstance(x, Boxed)


def unbox(tree):
    """Split a tree of Boxed leaves into (params, axes-spec) trees."""
    params = jax.tree.map(lambda b: b.value, tree, is_leaf=is_boxed)
    specs = jax.tree.map(lambda b: b.axes, tree, is_leaf=is_boxed)
    return params, specs


def logical_to_pspec(axes: tuple, shape: tuple, mesh: Mesh,
                     rules: dict[str, str] | None = None) -> P:
    """Map logical axes to a PartitionSpec on ``mesh``, divisibility-aware."""
    rules = rules or DEFAULT_RULES
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    assignment: dict[int, str] = {}     # dim index -> mesh axis
    used_mesh: set[str] = set()
    # Greedy by priority: each mesh axis goes to the best divisible dim.
    for logical in PRIORITY:
        target = rules.get(logical)
        if target is None or target not in mesh_sizes or target in used_mesh:
            continue
        for i, name in enumerate(axes):
            if name == logical and i not in assignment \
                    and shape[i] % mesh_sizes[target] == 0 and shape[i] > 0:
                assignment[i] = target
                used_mesh.add(target)
                break
    return P(*[assignment.get(i) for i in range(len(axes))])


def tree_shardings(spec_tree, param_tree, mesh: Mesh,
                   rules: dict[str, str] | None = None,
                   extra_leading: tuple = ()):
    """NamedSharding tree for a param tree given its logical-axes tree.

    ``extra_leading`` prepends logical axes (e.g. ("replica",) for stacked
    DiLoCo replicas) to every leaf's axes.
    """
    def one(axes, p):
        axes = tuple(extra_leading) + tuple(axes)
        shape = p.shape if hasattr(p, "shape") else np.shape(p)
        return NamedSharding(mesh, logical_to_pspec(axes, shape, mesh, rules))
    return jax.tree.map(one, spec_tree, param_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def batch_pspec(mesh: Mesh, batch_size: int, ndim: int,
                include_pod: bool = False) -> P:
    """PartitionSpec for an activation/batch array: shard dim 0 over data
    (and pod when requested), divisibility-aware; rest replicated."""
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    axes = []
    if include_pod and "pod" in mesh_sizes:
        axes.append("pod")
    if "data" in mesh_sizes:
        axes.append("data")
    total = int(np.prod([mesh_sizes[a] for a in axes])) if axes else 1
    while axes and batch_size % total != 0:
        total //= mesh_sizes[axes.pop()]
    first = tuple(axes) if axes else None
    return P(first, *([None] * (ndim - 1)))


def constrain(x, pspec: P):
    """with_sharding_constraint that is a no-op outside a mesh context
    AND inside manual-sharding contexts (shard_map): when the spec's
    axes are manual the arrays are already device-local shards — a
    GSPMD constraint is meaningless there and rejected by jax, so the
    sharded streaming round (core/pod_collectives.py) can run the same
    model code the auto-sharded paths use."""
    try:
        from jax._src.core import get_axis_env
        manual = set(getattr(get_axis_env(), "axis_sizes", {}))
    except Exception:                               # pragma: no cover
        manual = set()
    if manual:
        named = {a for part in pspec if part is not None
                 for a in (part if isinstance(part, tuple) else (part,))}
        if named & manual:
            return x
    try:
        return jax.lax.with_sharding_constraint(x, pspec)
    except (ValueError, RuntimeError):
        return x
