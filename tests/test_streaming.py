"""Tests for the streaming outer-sync subsystem (core/streaming.py,
core/fragments.py, kernels/quantize.py) and the PR's satellites
(round-offset eval cadence, single-worker donation).

Pins the subsystem's contracts:
  * P=1 / α=1 / τ=0 / f32 transport is bit-identical to the
    synchronous scanned driver — streaming is a strict generalization;
  * the fragment scheduler sends and applies every fragment exactly
    once per round for P ∈ {1, 2, 4}, including H values P does not
    divide, with τ-delayed applies wrapping into the next round;
  * the partitioner covers every parameter element exactly once with
    contiguous per-layer fragments, and pattern overrides pin leaves;
  * quantize→dequantize round trips respect the per-block error bound
    and the Pallas kernels (interpret mode) match the jnp oracles.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import DiLoCoConfig, TrainConfig, ModelConfig
from repro.core import diloco, fragments, streaming
from repro.data.sharding import make_regime
from repro.kernels import ops as kops
from repro.kernels import quantize as kquant
from repro.kernels import ref as kref
from repro.models.registry import Arch

K, H, B, S, VOCAB = 2, 4, 2, 16, 64


@pytest.fixture(scope="module")
def setup():
    cfg = ModelConfig(name="tiny", family="dense", n_layers=2,
                      d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                      vocab_size=VOCAB, remat=False, attn_chunk=32)
    arch = Arch(cfg=cfg)
    loss_fn = lambda p, b: arch.loss(p, b)
    sampler = make_regime("non_iid", k=K, vocab_size=VOCAB, seed=0)
    params, _ = arch.init(jax.random.PRNGKey(0), cfg)
    return arch, loss_fn, sampler, params


def _tcfg(rounds):
    return TrainConfig(inner_lr=3e-3, warmup_steps=2,
                       total_steps=rounds * H, batch_size=B, seq_len=S)


# ---------------------------------------------------------------------------
# streaming ≡ synchronous at the degenerate point
# ---------------------------------------------------------------------------

def test_stream_p1_bit_identical_to_sync(setup):
    """P=1, α=1, τ=0, f32 transport == the synchronous scanned driver,
    to the bit (states and metrics), including drop masks + weights."""
    arch, loss_fn, sampler, params = setup
    R = 3
    rng = np.random.default_rng(0)
    drops = (rng.random((R, K)) >= 0.5).astype(np.float32)
    drops[:, 0] = 1.0
    acts = np.ones((R, K), np.float32)
    weights = jnp.asarray([0.75, 0.25])

    dcfg = DiLoCoConfig(k=K, H=H)
    run = diloco.make_run(loss_fn, sampler.sample_all_shards, dcfg,
                          _tcfg(R), rounds_per_call=R, total_steps=R * H,
                          batch_size=B, seq_len=S, donate=False)
    st, ms = run(diloco.init_state(params, dcfg), jax.random.PRNGKey(5),
                 jnp.asarray(drops), jnp.asarray(acts), weights)

    dcfg_s = DiLoCoConfig(k=K, H=H, streaming_fragments=1,
                          stream_alpha=1.0, stream_tau=0,
                          outer_grad_dtype="float32")
    run_s = diloco.make_run(loss_fn, sampler.sample_all_shards, dcfg_s,
                            _tcfg(R), rounds_per_call=R,
                            total_steps=R * H, batch_size=B, seq_len=S,
                            donate=False)
    ss, ms_s = run_s(streaming.init_state(params, dcfg_s),
                     jax.random.PRNGKey(5), jnp.asarray(drops),
                     jnp.asarray(acts), weights)

    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(ss.base)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for key in ("inner_loss", "inner_loss_last", "outer_gnorm"):
        np.testing.assert_array_equal(np.asarray(ms[key]),
                                      np.asarray(ms_s[key]))


def test_streaming_overlap_quantized_runs_and_stays_finite(setup):
    """P=2, τ=1, α=0.5, int4 transport: the staggered/stale/quantized
    path trains, every fragment arms, and the state stays finite."""
    arch, loss_fn, sampler, params = setup
    R = 3
    dcfg = DiLoCoConfig(k=K, H=H, streaming_fragments=2,
                        stream_alpha=0.5, stream_tau=1,
                        outer_grad_dtype="int4")
    run = diloco.make_run(loss_fn, sampler.sample_all_shards, dcfg,
                          _tcfg(R), rounds_per_call=R, total_steps=R * H,
                          batch_size=B, seq_len=S, donate=False)
    ss, ms = run(streaming.init_state(params, dcfg),
                 jax.random.PRNGKey(5))
    assert np.all(np.asarray(ss.armed) == 1.0)
    for leaf in jax.tree.leaves(ss):
        assert np.isfinite(np.asarray(leaf)).all()
    losses = np.asarray(ms["inner_loss"])
    assert np.isfinite(losses).all()
    # global params actually moved (the outer step is live)
    moved = any(not np.array_equal(np.asarray(a), np.asarray(b))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(ss.global_params)))
    assert moved


def test_streaming_rejects_non_nesterov(setup):
    arch, loss_fn, sampler, params = setup
    dcfg = DiLoCoConfig(k=K, H=H, streaming_fragments=2,
                        outer_opt="adam")
    with pytest.raises(NotImplementedError):
        streaming.make_stream_round_body(
            loss_fn, sampler.sample_all_shards, dcfg, _tcfg(1))


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("P", [1, 2, 4])
@pytest.mark.parametrize("Hh", [4, 5, 7])
def test_schedule_covers_every_fragment_once(P, Hh):
    for tau in (0, min(2, Hh - 1)):
        sched = fragments.schedule(P, Hh, tau)
        assert sum(steps for steps, _ in sched.phases) == Hh
        sends = [e.fragment for _, acts in sched.phases
                 for e in acts if e.kind == "send"]
        applies = [e.fragment for _, acts in sched.phases
                   for e in acts if e.kind == "apply"]
        assert sorted(sends) == list(range(P))
        assert sorted(applies) == list(range(P))
        assert all(0 < o <= Hh for o in sched.send_offsets)
        # τ-delayed applies that overflow the round are marked wrapped
        for p in range(P):
            wrapped = sched.apply_offsets[p] > Hh
            ev = [e for _, acts in sched.phases for e in acts
                  if e.kind == "apply" and e.fragment == p]
            assert ev[0].wrapped == wrapped


def test_schedule_orders_apply_before_send_at_equal_offset():
    """A collective landing at the same offset as another fragment's
    send completes (applies) before the new snapshot is taken."""
    sched = fragments.schedule(2, 4, tau=2)
    # fragment 1 sends at 2, applies at 4; fragment 0 sends at 4
    last_acts = [acts for _, acts in sched.phases if acts][-1]
    kinds = [(e.kind, e.fragment) for e in last_acts]
    assert kinds.index(("apply", 1)) < kinds.index(("send", 0))


def test_inflight_slot_matches_deferral_predicate():
    """The double-buffered in-flight slot exists exactly when the
    issue/consume split is live (τ>0 AND a quantized wire dtype); the
    eager paths keep ``inflight=None``, which is not a pytree leaf —
    so τ=0 and f32 state trees are structurally identical to the
    pre-overlap StreamState (donation, sharding and the cross-commit
    bit-identity hash all see the same tree)."""
    params = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}
    base = dict(k=2, H=4, streaming_fragments=2, stream_alpha=0.5)
    eager = [DiLoCoConfig(**base, stream_tau=0, outer_grad_dtype="int4"),
             DiLoCoConfig(**base, stream_tau=1)]          # f32 default
    for cfg in eager:
        assert not streaming.deferred_consume(cfg)
        st = streaming.init_state(params, cfg)
        assert st.inflight is None
    ref_treedef = jax.tree_util.tree_structure(
        streaming.init_state(params, eager[0]))
    for dt in ("int4", "bfloat16"):
        cfg = DiLoCoConfig(**base, stream_tau=1, outer_grad_dtype=dt)
        assert streaming.deferred_consume(cfg)
        st = streaming.init_state(params, cfg)
        assert st.inflight is not None
        assert len(st.inflight) == 2          # one slot per fragment
        # deferral is marked in the human-readable sync plan too
        assert all(row["deferred"]
                   for row in streaming.sync_plan(params, cfg))
    # eager tree: no extra leaves vs a None-inflight replace
    st_q = streaming.init_state(
        params, DiLoCoConfig(**base, stream_tau=1,
                             outer_grad_dtype="int4"))
    assert jax.tree_util.tree_structure(
        st_q._replace(inflight=None)) == ref_treedef


def test_schedule_validates_tau():
    with pytest.raises(ValueError):
        fragments.schedule(2, 4, tau=4)
    with pytest.raises(ValueError):
        fragments.schedule(2, 4, tau=-1)


def test_schedule_rejects_more_fragments_than_offsets():
    """P > H would force two fragments onto one sync instant and break
    the peak-bytes-per-sync accounting — rejected up front."""
    with pytest.raises(ValueError):
        fragments.schedule(5, 4)
    # P == H is the densest legal stagger: one sync per inner step
    sched = fragments.schedule(4, 4)
    assert sorted(sched.send_offsets) == [1, 2, 3, 4]


# ---------------------------------------------------------------------------
# partitioner
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("P", [1, 2, 4])
def test_partition_covers_every_element_exactly_once(setup, P):
    _, _, _, params = setup
    part = fragments.partition_params(params, P)
    assert part.n == P
    assert sum(part.sizes) == sum(l.size
                                  for l in jax.tree.leaves(params))
    total = jax.tree.map(lambda p: jnp.zeros_like(p), params)
    for mk in part.masks:
        total = jax.tree.map(
            lambda t, q, p: t + jnp.broadcast_to(q, p.shape),
            total, mk, params)
    for leaf in jax.tree.leaves(total):
        np.testing.assert_array_equal(np.asarray(leaf),
                                      np.ones_like(np.asarray(leaf)))


def test_partition_stacked_fragments_are_contiguous(setup):
    """Per-layer fragment assignment of stacked block leaves is a
    contiguous band per fragment (the paper's block-range fragments)."""
    _, _, _, params = setup
    part = fragments.partition_params(params, 2)
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    for p in range(2):
        mleaves, _ = jax.tree_util.tree_flatten(part.masks[p])
        for (kp, leaf), mk in zip(flat, mleaves):
            if "stack" not in jax.tree_util.keystr(kp) or mk.ndim == 0:
                continue
            vec = np.asarray(mk).reshape(-1)
            on = np.flatnonzero(vec > 0)
            if on.size:
                assert np.array_equal(on,
                                      np.arange(on[0], on[-1] + 1))


def test_partition_pattern_override(setup):
    _, _, _, params = setup
    part = fragments.partition_params(
        params, 4, overrides=((r"embed", 3),))
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    m3, _ = jax.tree_util.tree_flatten(part.masks[3])
    m0, _ = jax.tree_util.tree_flatten(part.masks[0])
    for (kp, _), v3, v0 in zip(flat, m3, m0):
        if "embed" in jax.tree_util.keystr(kp):
            assert float(np.asarray(v3)) == 1.0     # pinned to frag 3
            assert float(np.asarray(v0)) == 0.0


def test_partition_rejects_bad_override(setup):
    _, _, _, params = setup
    with pytest.raises(ValueError):
        fragments.partition_params(params, 2, overrides=((r"embed", 5),))


# ---------------------------------------------------------------------------
# quantized transport
# ---------------------------------------------------------------------------

def test_quant_roundtrip_error_bounds():
    """int4: |x − dq(q(x))| ≤ amax_block / 14 per 128-elem block of the
    flattened tensor; bf16: relative error ≤ 2^-8; zeros exact."""
    x = jax.random.normal(jax.random.PRNGKey(0), (37, 41)) * 3.0
    dq = np.asarray(kops.quant_roundtrip(x, "int4", mode="ref"))
    flat = np.asarray(x).reshape(-1)
    n = flat.size
    rows = -(-n // 128)
    fp = np.pad(flat, (0, rows * 128 - n)).reshape(rows, 128)
    dp = np.pad(dq.reshape(-1), (0, rows * 128 - n)).reshape(rows, 128)
    amax = np.abs(fp).max(axis=1, keepdims=True)
    assert (np.abs(fp - dp) <= amax / 13.99 + 1e-12).all()

    dq16 = np.asarray(kops.quant_roundtrip(x, "bfloat16", mode="ref"))
    assert (np.abs(np.asarray(x) - dq16)
            <= np.abs(np.asarray(x)) * 2.0 ** -8 + 1e-12).all()

    z = jnp.zeros((5, 7))
    assert np.asarray(kops.quant_roundtrip(z, "int4",
                                           mode="ref")).sum() == 0.0
    with pytest.raises(ValueError):
        kops.quant_roundtrip(x, "fp8", mode="ref")


def test_quant_kernels_interpret_match_oracle():
    """The Pallas kernels (interpret mode on CPU) match the jnp oracles
    to float tolerance, and the int4 wire format round-trips."""
    x = jax.random.normal(jax.random.PRNGKey(1), (33, 50)) * 2.0
    for dt in ("bfloat16", "int4"):
        r = np.asarray(kops.quant_roundtrip(x, dt, mode="ref"))
        k = np.asarray(kops.quant_roundtrip(x, dt, mode="interpret"))
        np.testing.assert_allclose(r, k, rtol=2e-6, atol=2e-6)

    x2d = jax.random.normal(jax.random.PRNGKey(2), (10, 128))
    c_r, s_r = kref.quantize_int4(x2d)
    c_k, s_k = kquant.quantize_int4(x2d, interpret=True)
    assert c_k.dtype == jnp.int8
    assert np.abs(np.asarray(c_k)).max() <= 7
    np.testing.assert_allclose(np.asarray(s_r), np.asarray(s_k),
                               rtol=2e-6, atol=0)
    d_k = kquant.dequantize_int4(c_k, s_k, interpret=True)
    np.testing.assert_allclose(np.asarray(kref.dequantize_int4(c_r, s_r)),
                               np.asarray(d_k), rtol=2e-6, atol=2e-6)


def test_transport_bytes_accounting():
    assert kops.transport_bytes(1000, "float32") == 4000.0
    assert kops.transport_bytes(1000, "bfloat16") == 2000.0
    assert kops.transport_bytes(128, "int4") == 128 * 0.5 + 4.0


# ---------------------------------------------------------------------------
# satellites: round-offset eval cadence, single-worker donation
# ---------------------------------------------------------------------------

def test_round_offset_aligns_chunked_eval_cadence(setup):
    """Two chunks of 2 rounds with eval_every=3 + round_offset
    reproduce the unchunked cadence: the global round-3 eval fires in
    chunk 2 (it would be skipped with chunk-local indices)."""
    arch, loss_fn, sampler, params = setup
    R = 4
    dcfg = DiLoCoConfig(k=K, H=H)
    tcfg = _tcfg(R)
    val = sampler.sample_validation(jax.random.PRNGKey(9), 4, S)

    full = diloco.make_run(loss_fn, sampler.sample_all_shards, dcfg,
                           tcfg, rounds_per_call=R, total_steps=R * H,
                           batch_size=B, seq_len=S, eval_tokens=val,
                           eval_every=3, donate=False)
    _, ms_full = full(diloco.init_state(params, dcfg),
                      jax.random.PRNGKey(5))

    chunk = diloco.make_run(loss_fn, sampler.sample_all_shards, dcfg,
                            tcfg, rounds_per_call=2, total_steps=R * H,
                            batch_size=B, seq_len=S, eval_tokens=val,
                            eval_every=3, donate=False)
    state = diloco.init_state(params, dcfg)
    key = jax.random.PRNGKey(5)
    vals = []
    for off in (0, 2):
        state, ms = chunk(state, key, round_offset=off)
        key = ms["next_key"]
        vals.extend(np.asarray(ms["val_loss"]).tolist())

    vf = np.asarray(ms_full["val_loss"])
    # unchunked: evals at global rounds 3 and 4 (last round forced)
    assert np.isnan(vf[0]) and np.isnan(vf[1])
    assert np.isfinite(vf[2]) and np.isfinite(vf[3])
    # chunked with offset: round 3 eval fires mid-chunk-2 and matches
    # (rounds 2 and 4 are chunk-final, so they eval as well)
    assert np.isnan(vals[0])
    assert np.isfinite(vals[2])
    np.testing.assert_allclose(vals[2], float(vf[2]), rtol=1e-6)
    np.testing.assert_allclose(vals[3], float(vf[3]), rtol=1e-6)


def test_single_worker_step_donation(setup):
    """The donated single-worker step trains in place across iterations
    and matches the non-donated step."""
    arch, loss_fn, sampler, params = setup
    from repro.optim import adamw
    tcfg = _tcfg(2)
    batch = {"tokens": sampler.sample_validation(
        jax.random.PRNGKey(3), B, S)}

    outs = {}
    for donate in (False, True):
        step = diloco.make_single_worker_step(loss_fn, tcfg,
                                              total_steps=2 * H,
                                              donate=donate)
        p = jax.tree.map(jnp.copy, params)
        opt = adamw.init(p)
        for i in range(3):
            p, opt, m = step(p, opt, batch, jnp.asarray(i))
        outs[donate] = (p, float(m["loss"]))
    for a, b in zip(jax.tree.leaves(outs[False][0]),
                    jax.tree.leaves(outs[True][0])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert np.isfinite(outs[True][1])
