"""DiLoCo (Algorithm 1): Distributed Low-Communication training.

Two optimization processes:
  * inner — every replica independently runs H steps of AdamW on its own
    data shard (no cross-replica communication);
  * outer — every H steps the per-replica parameter deltas
    Δ_i = θ^(t-1) − θ_i^(t) are averaged (the only cross-replica
    collective) and applied by an outer optimizer (Nesterov by default)
    to the global parameter copy, which is then re-dispatched.

The k replicas are carried *stacked* on a leading (k, ...) axis of every
parameter/optimizer leaf, and the inner step is ``vmap``-ed over that
axis. This one formulation serves both execution modes:

  * CPU / single host: vmap runs the k replicas as a batch dimension —
    the benchmark path used to reproduce the paper's figures;
  * TPU multi-pod: the leading axis is sharded over the mesh's "pod"
    axis (one replica per pod). GSPMD partitions the vmap so the inner
    step contains *zero* cross-pod collectives (verified structurally in
    the dry-run) while the outer step's replica-mean lowers to exactly
    one all-reduce over "pod" of model-size bytes — fired once every H
    steps, the paper's communication reduction.

Robustness features from the paper are first-class:
  * ``drop_mask`` (Fig 8) — replicas whose outer gradient is dropped keep
    training from their *own* parameters instead of the global copy;
  * ``active_mask`` (Fig 7, adaptive compute) — inactive replicas are
    parked on the global copy and excluded from the average;
  * ``prune_frac`` (Tab 6) — sign-consistent magnitude pruning of outer
    gradients before averaging (see ``core/compression.py``);
  * ``weights`` — shard-size-weighted averaging for imbalanced
    non-i.i.d. shards (paper §6.1).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DiLoCoConfig, TrainConfig
from repro.optim import adamw, precision
from repro.optim.schedule import make_warmup_cosine
from . import outer_opt
from .compression import sign_prune


class DiLoCoState(NamedTuple):
    """Carried across rounds. replica_* leaves have a leading (k,) axis.

    Under a mixed precision policy (``dcfg.param_dtype`` narrower than
    ``dcfg.master_dtype``) ``replica_params`` and the inner m/v moments
    ride at ``param_dtype`` while ``inner_state.master`` carries the
    per-replica ``master_dtype`` master copies; ``global_params`` and
    the outer state always stay at the caller's (f32) precision.
    """
    global_params: Any            # θ^(t-1), the shared copy
    outer_state: outer_opt.OuterState
    replica_params: Any           # (k, ...) per-replica θ_i
    inner_state: adamw.AdamWState  # (k, ...) per-replica AdamW m/v/count
    outer_t: jnp.ndarray          # outer step counter t
    inner_steps_done: jnp.ndarray  # per-replica scalar (shared schedule)


def broadcast_replicas(tree, k: int):
    return jax.tree.map(
        lambda p: jnp.broadcast_to(p, (k,) + p.shape).copy(), tree)


def init_state(params, dcfg: DiLoCoConfig) -> DiLoCoState:
    """Start DiLoCo from (possibly pretrained) ``params``.

    ``params`` arrive at master precision (f32). Under a mixed policy
    (``dcfg.param_dtype`` narrower than ``dcfg.master_dtype``) the
    replica working params and AdamW moments are allocated at
    ``param_dtype`` and each replica's inner state carries a
    ``master_dtype`` master copy; the global params and outer state
    always stay at the caller's precision.

    ``global_params`` is a copy, not an alias of the caller's tree —
    the scanned driver (``make_run``) donates the state's buffers, and
    donating an aliased tree would delete the caller's params.
    """
    pol = precision.policy_of(dcfg)
    rep = broadcast_replicas(params, dcfg.k)
    # init allocates moments at param_dtype and a master only under a
    # mixed policy; the working replicas are the param_dtype cast
    inner = jax.vmap(functools.partial(adamw.init, policy=pol))(rep)
    rep = precision.cast_tree(rep, pol.param_dtype)
    return DiLoCoState(
        global_params=jax.tree.map(jnp.copy, params),
        outer_state=outer_opt.init(params),
        replica_params=rep,
        inner_state=inner,
        outer_t=jnp.zeros((), jnp.int32),
        inner_steps_done=jnp.zeros((), jnp.int32),
    )


# ---------------------------------------------------------------------------
# inner optimization (lines 4-9)
# ---------------------------------------------------------------------------

def make_inner_step(loss_fn: Callable, tcfg: TrainConfig,
                    total_steps: int | None = None):
    """One AdamW step for ONE replica. loss_fn(params, batch) ->
    (loss, metrics). Returns step(params, opt_state, batch, step_idx)."""
    sched = make_warmup_cosine(tcfg.inner_lr, tcfg.warmup_steps,
                               total_steps or tcfg.total_steps)
    pol = precision.policy_of(tcfg)

    def step(params, opt_state, batch, step_idx):
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        grads, gnorm = adamw.clip_by_global_norm(grads, tcfg.grad_clip)
        lr = sched(step_idx)
        params, opt_state = adamw.update(
            grads, opt_state, params, lr=lr, b1=tcfg.b1, b2=tcfg.b2,
            eps=tcfg.eps, weight_decay=tcfg.weight_decay,
            mode=getattr(tcfg, "kernel_mode", "ref"), policy=pol)
        # metrics stay f32 whatever the replica dtype (no-op for f32)
        return params, opt_state, {"loss": loss.astype(jnp.float32),
                                   "gnorm": gnorm, "lr": lr}

    return step


def inner_phase(inner_step, replica_params, inner_state, batches,
                step0, *, active_mask=None):
    """H inner steps for all k replicas (vmap over k, scan over H).

    batches: tokens (k, H, B, S) or a dict of such; step0: scalar global
    inner-step index of the phase start (for the shared lr schedule).
    ``active_mask`` (k,) float — inactive replicas keep params unchanged
    (adaptive compute pool; they burn no "real" compute on hardware
    because their island simply isn't there).
    Returns (replica_params, inner_state, metrics (k, H) dict).
    """
    def one_replica(params, opt_state, batches_h, active):
        def body(carry, xs):
            p, s = carry
            batch, h = xs
            p2, s2, m = inner_step(p, s, batch, step0 + h)
            p2 = jax.tree.map(lambda a, b: jnp.where(active > 0, a, b),
                              p2, p)
            s2 = jax.tree.map(lambda a, b: jnp.where(active > 0, a, b),
                              s2, s)
            return (p2, s2), m

        H = jax.tree.leaves(batches_h)[0].shape[0]
        (params, opt_state), ms = jax.lax.scan(
            body, (params, opt_state), (batches_h, jnp.arange(H)))
        return params, opt_state, ms

    k = jax.tree.leaves(replica_params)[0].shape[0]
    if active_mask is None:
        active_mask = jnp.ones((k,), jnp.float32)
    return jax.vmap(one_replica)(replica_params, inner_state, batches,
                                 active_mask)


# ---------------------------------------------------------------------------
# outer optimization (lines 11-14)
# ---------------------------------------------------------------------------

def outer_step(state: DiLoCoState, dcfg: DiLoCoConfig, *,
               drop_mask=None, active_mask=None, weights=None,
               compute_cosine: bool = False, bomb_mask=None):
    """Average outer gradients and update the global copy.

    drop_mask (k,) float: 1 = outer grad communicated, 0 = dropped
    (replica keeps its own params for the next phase — Fig 8 semantics).
    active_mask (k,) float: 0 = replica not part of the pool this round.
    weights (k,) float: shard-size weights (uniform if None).
    bomb_mask (k,) float: fault injection — 1 poisons the replica's
    outer delta to NaN before the reduce (``faults.Scenario.nan_masks``
    rows; a corrupted-gradient stand-in the guard must catch).
    Returns (new_state, metrics).
    """
    k = dcfg.k
    ones = jnp.ones((k,), jnp.float32)
    drop_mask = ones if drop_mask is None else drop_mask
    active_mask = ones if active_mask is None else active_mask
    weights = ones if weights is None else weights
    m = drop_mask * active_mask * weights                     # (k,)

    kernel_mode = getattr(dcfg, "kernel_mode", "ref")
    masters = state.inner_state.master       # None unless mixed policy

    # Δ_i = θ^(t-1) − θ_i^(t)   (line 12). Under a mixed policy the
    # deltas are computed master-vs-master at full precision — the bf16
    # working copies never enter the outer gradient.
    rep_src = masters if masters is not None else state.replica_params
    deltas = jax.tree.map(lambda g, r: g[None] - r,
                          state.global_params, rep_src)
    if bomb_mask is not None:
        deltas = jax.tree.map(
            lambda d: jnp.where(
                bomb_mask.reshape((k,) + (1,) * (d.ndim - 1)) > 0,
                jnp.asarray(jnp.nan, d.dtype), d), deltas)
    if dcfg.prune_frac > 0:
        deltas = jax.vmap(
            lambda d: sign_prune(d, dcfg.prune_frac, mode=kernel_mode)
        )(deltas)

    guard_metrics = {}
    if getattr(dcfg, "guard_outer", False):
        # per-replica sanity: a delta with ANY non-finite value is
        # excluded from the reduce (weight 0 — identical to the
        # drop-its-weight path, tested) and its values zeroed so
        # NaN·0 cannot leak through the contraction. On finite rounds
        # every op here is an exact identity, keeping the guarded
        # clean path bit-identical to the unguarded one.
        fin = jnp.stack([jnp.all(jnp.isfinite(
            d.astype(jnp.float32).reshape(k, -1)), axis=1)
            for d in jax.tree.leaves(deltas)]).all(axis=0)     # (k,)
        ok = fin.astype(jnp.float32)
        deltas = jax.tree.map(
            lambda d: jnp.where(jnp.isfinite(d.astype(jnp.float32)),
                                d, jnp.zeros((), d.dtype)), deltas)
        m = m * ok
        guard_metrics["guard_rejected"] = (1.0 - ok).sum()
        if getattr(dcfg, "guard_clip", 0.0) > 0:
            # norm-outlier clipping: scale any replica whose delta
            # norm exceeds guard_clip × the median (of surviving
            # replicas) down to that ceiling, before the reduce
            norms = jnp.sqrt(sum(
                jnp.sum(jnp.square(d.astype(jnp.float32)
                                   .reshape(k, -1)), axis=1)
                for d in jax.tree.leaves(deltas)))             # (k,)
            med = jnp.nanmedian(jnp.where(ok > 0, norms, jnp.nan))
            med = jnp.where(jnp.isfinite(med), med, 0.0)
            ceil = dcfg.guard_clip * med
            scale = jnp.where(norms > ceil,
                              ceil / jnp.maximum(norms, 1e-30), 1.0)
            deltas = jax.tree.map(
                lambda d: d * scale.reshape(
                    (k,) + (1,) * (d.ndim - 1)).astype(d.dtype),
                deltas)
            guard_metrics["guard_clipped"] = (scale < 1.0).sum()\
                .astype(jnp.float32)
    denom = jnp.maximum(m.sum(), 1e-9)

    # weighted average over communicating replicas. On the pod-sharded
    # path this contraction is THE cross-pod all-reduce.
    avg = jax.tree.map(
        lambda d: jnp.tensordot(m, d, axes=(0, 0)) / denom, deltas)

    new_global, new_outer = outer_opt.update(
        avg, state.outer_state, state.global_params,
        kind=dcfg.outer_opt, lr=dcfg.outer_lr,
        momentum=dcfg.outer_momentum, b2=dcfg.outer_adam_b2,
        eps=dcfg.outer_adam_eps, kernel_mode=kernel_mode)

    # re-dispatch (line 3 of next phase): communicated & active replicas
    # adopt θ^(t); dropped replicas continue from their own θ_i; inactive
    # replicas park on θ^(t) (they'll be reset when re-activated anyway).
    # The adopted copy is cast to the replica storage dtype (identity
    # under the f32 policy); masters adopt at full precision.
    adopt = jnp.maximum(drop_mask, 1.0 - active_mask)         # (k,)
    new_replicas = jax.tree.map(
        lambda g, r: jnp.where(
            adopt.reshape((k,) + (1,) * g.ndim) > 0,
            g[None].astype(r.dtype), r),
        new_global, state.replica_params)
    new_inner = state.inner_state
    if masters is not None:
        new_masters = jax.tree.map(
            lambda g, w: jnp.where(
                adopt.reshape((k,) + (1,) * g.ndim) > 0, g[None], w),
            new_global, masters)
        new_inner = state.inner_state._replace(master=new_masters)

    metrics = {
        "outer_gnorm": _tree_norm(avg),
        "drop_frac": 1.0 - drop_mask.mean(),
        **guard_metrics,
    }
    if compute_cosine:
        cos_mean, cos_std = _pairwise_cosine(deltas, m)
        metrics["cos_mean"] = cos_mean
        metrics["cos_std"] = cos_std

    return DiLoCoState(
        global_params=new_global,
        outer_state=new_outer,
        replica_params=new_replicas,
        inner_state=new_inner,
        outer_t=state.outer_t + 1,
        inner_steps_done=state.inner_steps_done,
    ), metrics


def _tree_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def _pairwise_cosine(deltas, mask):
    """Mean/std of pairwise cosine similarity between replicas' outer
    gradients (Fig 10/11). deltas: tree of (k, ...) leaves."""
    flat = jnp.concatenate(
        [d.reshape(d.shape[0], -1).astype(jnp.float32)
         for d in jax.tree.leaves(deltas)], axis=1)           # (k, P)
    norm = jnp.linalg.norm(flat, axis=1, keepdims=True)
    unit = flat / jnp.maximum(norm, 1e-12)
    sim = unit @ unit.T                                        # (k, k)
    k = flat.shape[0]
    pair = mask[:, None] * mask[None, :] * (1 - jnp.eye(k))
    denom = jnp.maximum(pair.sum(), 1e-9)
    mean = (sim * pair).sum() / denom
    var = (jnp.square(sim - mean) * pair).sum() / denom
    return mean, jnp.sqrt(var)


# ---------------------------------------------------------------------------
# round drivers (one outer iteration = H inner steps + outer step)
# ---------------------------------------------------------------------------

def _make_round_body(loss_fn, sample_fn, dcfg: DiLoCoConfig,
                     tcfg: TrainConfig, *, total_steps=None,
                     compute_cosine=False, batch_size=None, seq_len=None,
                     mesh=None, nan_bombs=None):
    """Un-jitted round: the computation shared by ``make_round`` (one
    jit dispatch per round) and ``make_run`` (R rounds scanned inside
    one jit).

    When ``dcfg.streaming_fragments > 0`` the round is the *streaming*
    round (fragment-scheduled outer sync, see ``core/streaming.py``);
    the state is then a ``streaming.StreamState`` (build with
    ``streaming.init_state``). With ``dcfg.transport == "sharded"`` the
    streaming round runs under shard_map over ``mesh``'s "pod" axis
    and the fragment reductions are real cross-pod collectives
    (``core/pod_collectives.py``)."""
    if precision.policy_of(dcfg) != precision.policy_of(tcfg):
        raise ValueError(
            "DiLoCoConfig and TrainConfig precision policies disagree: "
            f"dcfg=({dcfg.param_dtype}, {dcfg.master_dtype}) vs "
            f"tcfg=({tcfg.param_dtype}, {tcfg.master_dtype}); the state "
            "layout (dcfg) must match the inner step (tcfg)")
    transport = getattr(dcfg, "transport", "simulated")
    if nan_bombs is not None and (transport != "simulated"
                                  or getattr(dcfg,
                                             "streaming_fragments", 0)):
        raise ValueError(
            "nan_bombs poison the classic outer reduce "
            "(transport='simulated', streaming_fragments=0); other "
            "transports would silently ignore the injection")
    if transport == "gossip":
        # gossip reuses streaming_fragments as its partial-averaging
        # schedule, so it must be routed before the streaming check
        from . import gossip
        return gossip.make_gossip_round_body(
            loss_fn, sample_fn, dcfg, tcfg, total_steps=total_steps,
            compute_cosine=compute_cosine, batch_size=batch_size,
            seq_len=seq_len, mesh=mesh)
    if transport == "async":
        raise ValueError(
            "transport='async' is barrier-free — there is no round to "
            "build: drive it with core.async_diloco.AsyncEngine (or "
            "run_async) and a faults.Scenario")
    if getattr(dcfg, "streaming_fragments", 0):
        from . import streaming
        return streaming.make_stream_round_body(
            loss_fn, sample_fn, dcfg, tcfg, total_steps=total_steps,
            compute_cosine=compute_cosine, batch_size=batch_size,
            seq_len=seq_len, mesh=mesh)
    if transport != "simulated":
        raise ValueError(
            "transport='sharded' is a streaming-path feature: set "
            "streaming_fragments >= 1 (the classic synchronous outer "
            "step gets its cross-pod all-reduce from GSPMD — see "
            "launch/dryrun.py build_outer_step)")
    inner_step_tok = make_inner_step(
        lambda p, b: loss_fn(p, b), tcfg, total_steps)
    B = batch_size or tcfg.batch_size
    S = seq_len or tcfg.seq_len
    bombs_const = (None if nan_bombs is None
                   else np.asarray(nan_bombs, np.float32))

    def round_body(state: DiLoCoState, key, drop_mask=None,
                   active_mask=None, weights=None):
        H = dcfg.H
        keys = jax.random.split(key, H)
        toks = jax.vmap(lambda kk: sample_fn(kk, B, S))(keys)  # (H,k',B,S)
        toks = jnp.swapaxes(toks, 0, 1)[:dcfg.k]               # (k,H,B,S)
        batches = {"tokens": toks}
        rp, is_, ms = inner_phase(
            inner_step_tok, state.replica_params, state.inner_state,
            batches, state.inner_steps_done, active_mask=active_mask)
        state = state._replace(
            replica_params=rp, inner_state=is_,
            inner_steps_done=state.inner_steps_done + H)
        bomb = None
        if bombs_const is not None:
            # indexed by the state's own round counter (not the scan
            # index) so a resumed run picks up the schedule in place
            bomb = jnp.take(jnp.asarray(bombs_const),
                            jnp.minimum(state.outer_t,
                                        bombs_const.shape[0] - 1),
                            axis=0)
        state, om = outer_step(state, dcfg, drop_mask=drop_mask,
                               active_mask=active_mask, weights=weights,
                               compute_cosine=compute_cosine,
                               bomb_mask=bomb)
        om["inner_loss"] = ms["loss"].mean()
        om["inner_loss_last"] = ms["loss"][:, -1].mean()
        return state, om

    return round_body


def make_round(loss_fn, sample_fn, dcfg: DiLoCoConfig, tcfg: TrainConfig,
               *, total_steps: int | None = None,
               compute_cosine: bool = False,
               batch_size: int | None = None,
               seq_len: int | None = None,
               mesh=None, nan_bombs=None):
    """Build the jitted DiLoCo round.

    sample_fn(key, batch, seq_len) -> (k, B, S) int32 tokens, one batch
    per shard. Returns round(state, key, drop_mask, active_mask, weights)
    -> (state, metrics). Data for all H steps is sampled *inside* the
    round via fold_in so the jitted function stays closed over the
    sampler constants only. ``mesh`` is required (and only used) by the
    sharded streaming transport. ``nan_bombs`` ((rounds, k) float mask,
    classic transport only) injects NaN outer gradients on the masked
    (round, worker) cells — rows indexed by the state's own ``outer_t``.
    """
    round_body = _make_round_body(
        loss_fn, sample_fn, dcfg, tcfg, total_steps=total_steps,
        compute_cosine=compute_cosine, batch_size=batch_size,
        seq_len=seq_len, mesh=mesh, nan_bombs=nan_bombs)
    return jax.jit(round_body)


def split_chain(key, n: int):
    """((2,) carry, (n, 2) subs) uint32 — the carry key and sub-keys
    the sequential host pattern ``key, sub = jax.random.split(key)``
    would produce over n iterations, computed in-graph. Lets the
    scanned driver consume the exact same randomness as the legacy
    per-round Python loop; the carry (returned as ``next_key`` in
    ``make_run`` metrics) seeds the next chunk of a chunked run."""
    def body(carry, _):
        carry, sub = jax.random.split(carry)
        return carry, sub

    return jax.lax.scan(body, key, None, length=n)


def make_run(loss_fn, sample_fn, dcfg: DiLoCoConfig, tcfg: TrainConfig,
             *, rounds_per_call: int,
             total_steps: int | None = None,
             compute_cosine: bool = False,
             batch_size: int | None = None,
             seq_len: int | None = None,
             eval_tokens=None, eval_every: int = 1,
             donate: bool = True, mesh=None, nan_bombs=None):
    """Build the scanned multi-round driver: R = ``rounds_per_call``
    full DiLoCo rounds execute inside ONE jitted call via ``lax.scan``,
    so the host dispatches once per R rounds instead of once per round
    (and never blocks on a host-side eval between rounds).

    Returns ``run(state, key, drop_masks, active_masks, weights) ->
    (state, metrics)`` where drop/active masks are stacked ``(R, k)``
    arrays (or None for all-ones) and every metric comes back stacked
    along a leading (R,) axis, plus ``metrics["next_key"]`` — the
    advanced carry key that seeds the next chunk of a chunked run.
    Round t consumes the key the legacy pattern ``key, sub =
    split(key)`` would have given it, so one ``run`` call is
    bit-identical to R iterations of ``make_round``.

    ``eval_tokens`` (B, S) enables in-graph periodic eval: rounds where
    the *global* round index ``(round_offset + t + 1) % eval_every == 0``
    (and the last round of the call) report ``val_loss``; skipped
    rounds report NaN and pay no eval FLOPs (``lax.cond``). Chunked
    callers (several ``run`` calls covering one logical training run)
    pass ``round_offset`` = rounds already completed so the cadence
    stays aligned across chunk boundaries; the offset is a traced
    scalar, so every chunk reuses one compiled function.

    ``donate=True`` donates the DiLoCoState carry — the k×(params +
    AdamW m/v) replica buffers are updated in place instead of
    double-buffered, halving steady-state optimizer memory.

    When ``dcfg.streaming_fragments > 0`` the scanned rounds are
    streaming rounds (``core/streaming.py``): pass/expect a
    ``streaming.StreamState`` instead of a ``DiLoCoState``. With
    ``dcfg.transport == "sharded"`` pass ``mesh`` (a mesh with a "pod"
    axis) and place the state with
    ``pod_collectives.shard_stream_state`` first — the scanned rounds
    then issue real per-fragment pod-axis collectives from inside the
    one jit.
    """
    round_body = _make_round_body(
        loss_fn, sample_fn, dcfg, tcfg, total_steps=total_steps,
        compute_cosine=compute_cosine, batch_size=batch_size,
        seq_len=seq_len, mesh=mesh, nan_bombs=nan_bombs)
    R = int(rounds_per_call)
    ev_toks = None if eval_tokens is None else jnp.asarray(eval_tokens)

    def run_fn(state: DiLoCoState, key, drop_masks=None,
               active_masks=None, weights=None, round_offset=0):
        ones = jnp.ones((R, dcfg.k), jnp.float32)
        drop_masks = ones if drop_masks is None else drop_masks
        active_masks = ones if active_masks is None else active_masks
        round_offset = jnp.asarray(round_offset, jnp.int32)
        next_key, subs = split_chain(key, R)

        def body(st, xs):
            sub, drop, act, t = xs
            st, m = round_body(st, sub, drop, act, weights)
            if ev_toks is not None:
                g = round_offset + t + 1          # global 1-based round
                do_eval = (g % eval_every == 0) | (t == R - 1)
                m["val_loss"] = jax.lax.cond(
                    do_eval,
                    lambda p: loss_fn(p, {"tokens": ev_toks})[0]
                    .astype(jnp.float32),
                    lambda p: jnp.full((), jnp.nan, jnp.float32),
                    st.global_params)
            return st, m

        state, ms = jax.lax.scan(
            body, state,
            (subs, drop_masks, active_masks, jnp.arange(R)))
        ms["next_key"] = next_key     # seeds the next chunk (not (R,))
        return state, ms

    if donate:
        return jax.jit(run_fn, donate_argnums=(0,))
    return jax.jit(run_fn)


def make_eval(loss_fn):
    @jax.jit
    def eval_fn(params, tokens):
        loss, _ = loss_fn(params, {"tokens": tokens})
        return loss
    return eval_fn


# ---------------------------------------------------------------------------
# single-worker pretraining / baselines share the same inner step
# ---------------------------------------------------------------------------

def make_single_worker_step(loss_fn, tcfg: TrainConfig,
                            total_steps: int | None = None, *,
                            donate: bool = True):
    """Plain (non-DiLoCo) training step — used for the paper's pretraining
    stage and the single-worker baselines of Table 2 / Fig 2.

    ``donate=True`` donates (params, opt_state), so the per-step update
    runs in place instead of double-buffering params + AdamW m/v —
    callers must rebind both to the returned values (every in-repo loop
    already does)."""
    inner = make_inner_step(lambda p, b: loss_fn(p, b), tcfg, total_steps)

    def step(params, opt_state, batch, idx):
        return inner(params, opt_state, batch, idx)

    if donate:
        return jax.jit(step, donate_argnums=(0, 1))
    return jax.jit(step)


def outer_wire_bytes(params, dcfg: DiLoCoConfig) -> float:
    """Bytes ONE replica ships for the CLASSIC synchronous outer step:
    the full outer gradient at the transport dtype (the config
    validation in launch/train.py pins that to float32 off the
    streaming path — quantized wire lives on the fragment transports,
    which account per fragment via ``streaming.sync_plan`` /
    ``gossip.frag_bytes``). The telemetry layer stamps this on each
    round's transfer span so every transport's trace carries byte
    annotations from the same ``kops.transport_bytes`` accounting."""
    from repro.kernels import ops as kops
    n = sum(int(leaf.size) for leaf in jax.tree.leaves(params))
    return float(kops.transport_bytes(n, dcfg.outer_grad_dtype))
