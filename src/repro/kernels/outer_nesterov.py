"""Fused outer Nesterov update — Pallas TPU kernel.

DiLoCo's outer step (Algorithm 1 line 14) touches every parameter once
per round: read (θ, Δ, b), write (θ, b). Fusing the momentum update and
the Nesterov-corrected parameter step into one VMEM pass makes the outer
step strictly bandwidth-bound at 3 reads + 2 writes — it runs in the
shadow of the cross-pod all-reduce that produced Δ.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import compat


def _nesterov_kernel(sc_ref, p_ref, d_ref, b_ref, p_out, b_out, *,
                     momentum):
    lr = sc_ref[0]
    p = p_ref[...].astype(jnp.float32)
    d = d_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    b_new = momentum * b + d
    p_out[...] = (p - lr * (momentum * b_new + d)).astype(p_out.dtype)
    b_out[...] = b_new.astype(b_out.dtype)


def outer_nesterov(p, delta, buf, *, lr, momentum=0.9,
                   block_rows: int = 256, interpret: bool = False):
    """θ ← θ − lr·(μ·b_new + Δ), b_new = μ·b + Δ. Any-shape tensor.
    Returns (p_new, buf_new)."""
    shape, dtype = p.shape, p.dtype
    n = p.size
    cols = 128
    rows = -(-n // cols)
    pad = rows * cols - n

    def to2d(x):
        x = x.reshape(-1)
        if pad:
            x = jnp.pad(x, (0, pad))
        return x.reshape(rows, cols)

    p2, d2, b2 = map(to2d, (p, delta, buf))
    br = min(block_rows, rows)
    rows_p = -(-rows // br) * br
    if rows_p != rows:
        padr = rows_p - rows
        p2, d2, b2 = (jnp.pad(x, ((0, padr), (0, 0)))
                      for x in (p2, d2, b2))
    scalars = jnp.asarray([lr], jnp.float32)

    tile = pl.BlockSpec((br, cols), lambda i: (i, 0))
    outs = pl.pallas_call(
        functools.partial(_nesterov_kernel, momentum=momentum),
        grid=(rows_p // br,),
        in_specs=[pl.BlockSpec(memory_space=compat.SMEM),
                  tile, tile, tile],
        out_specs=(tile, tile),
        out_shape=(jax.ShapeDtypeStruct((rows_p, cols), dtype),
                   jax.ShapeDtypeStruct((rows_p, cols), buf.dtype)),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(scalars, p2, d2, b2)

    def back(x, dt):
        return x.reshape(-1)[:n].reshape(shape).astype(dt)

    return back(outs[0], dtype), back(outs[1], buf.dtype)
