"""Host-side anomaly guard: rolling loss statistics, spike verdicts,
and the rollback-and-skip escalation bookkeeping.

Two tiers of defense (ISSUE 10):

- **In-graph** (``core.diloco.outer_step`` under ``dcfg.guard_outer``):
  per-replica NaN/Inf rejection and optional norm-outlier clipping
  *before* the outer reduce. Free of host syncs — it rides the scanned
  round body — and bit-identical on clean rounds.
- **Host-side** (this module): the launcher feeds each finished
  chunk's per-round losses to ``AnomalyGuard.observe``; a non-finite
  loss or a spike beyond ``spike`` rolling standard deviations trips a
  verdict. The launcher's escalation is then: restore the last good
  snapshot (``CheckpointManager.latest_good``), mark the offending
  round skipped (its drop-mask row zeroed — the outer reduce
  contributes nothing and every replica re-dispatches from the
  unchanged global), and re-run the chunk, bounded by
  ``max_rollbacks``.

The guard only *reads* metrics the chunk boundary already
materialized, so it adds zero host syncs per chunk (gated by the
``ingest_calls`` counter in BENCH_resilience.json).
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass


@dataclass(frozen=True)
class GuardConfig:
    window: int = 8         # rolling-statistics window (rounds)
    spike: float = 4.0      # trip at mean + spike * std
    min_history: int = 4    # verdicts need this much history first
    min_std: float = 1e-3   # std floor so a flat window can't hair-trigger
    max_rollbacks: int = 2  # escalation budget for the whole run

    def __post_init__(self):
        if self.window < 1:
            raise ValueError(f"window must be >= 1, got {self.window}")
        if self.spike <= 0:
            raise ValueError(f"spike must be > 0, got {self.spike}")
        if self.min_history < 1:
            raise ValueError(
                f"min_history must be >= 1, got {self.min_history}")
        if self.max_rollbacks < 0:
            raise ValueError(
                f"max_rollbacks must be >= 0, got {self.max_rollbacks}")


class AnomalyGuard:
    """Rolling loss monitor. ``observe`` is called once per finished
    round (host side, after the chunk's metrics land); anomalous
    observations are NOT folded into the rolling window, so one spike
    cannot poison the baseline it is judged against."""

    def __init__(self, cfg: GuardConfig = GuardConfig(), *,
                 recorder=None):
        self.cfg = cfg
        self.recorder = recorder
        self._window: deque = deque(maxlen=cfg.window)
        self.rollbacks_used = 0
        self.skipped_rounds: set = set()
        self.verdicts: list = []

    # -- statistics ----------------------------------------------------
    def stats(self) -> tuple:
        """(mean, std) of the rolling window (nan, nan when empty)."""
        if not self._window:
            return float("nan"), float("nan")
        n = len(self._window)
        mean = sum(self._window) / n
        var = sum((x - mean) ** 2 for x in self._window) / n
        return mean, math.sqrt(var)

    # -- verdicts ------------------------------------------------------
    def observe(self, round_idx: int, loss: float) -> dict:
        """Judge one round's mean inner loss. Returns a verdict dict
        ``{"ok": bool, "reason": str | None, "round": int, ...}``."""
        loss = float(loss)
        mean, std = self.stats()
        verdict = {"ok": True, "reason": None, "round": int(round_idx),
                   "loss": loss, "mean": mean, "std": std}
        if not math.isfinite(loss):
            verdict.update(ok=False, reason="non_finite")
        elif (len(self._window) >= self.cfg.min_history
              and loss > mean + self.cfg.spike * max(std,
                                                     self.cfg.min_std)):
            verdict.update(ok=False, reason="spike")
        if verdict["ok"]:
            self._window.append(loss)
        else:
            self._emit("anomaly", verdict)
        self.verdicts.append(verdict)
        return verdict

    def observe_chunk(self, first_round: int, losses) -> list:
        """Judge a whole chunk (losses in round order). Returns the
        verdicts of the anomalous rounds (empty = chunk is clean)."""
        bad = []
        for i, loss in enumerate(losses):
            v = self.observe(first_round + i, loss)
            if not v["ok"]:
                bad.append(v)
        return bad

    # -- escalation bookkeeping ---------------------------------------
    def can_rollback(self) -> bool:
        return self.rollbacks_used < self.cfg.max_rollbacks

    def rolled_back(self, *, to_round: int, skip_round: int) -> None:
        """Record one executed rollback: the run was restored to the
        snapshot at ``to_round`` and ``skip_round`` will be skipped on
        the re-run."""
        self.rollbacks_used += 1
        self.skipped_rounds.add(int(skip_round))
        self._emit("rollback", {"round": int(skip_round),
                                "restored_to": int(to_round),
                                "rollbacks_used": self.rollbacks_used})

    def _emit(self, action: str, fields: dict) -> None:
        if self.recorder is None:
            return
        f = {k: v for k, v in fields.items() if k != "round"}
        self.recorder.guard_event(action=action,
                                  round=fields.get("round", -1), **f)
