"""Data-shard assignment: i.i.d. vs non-i.i.d. regimes (paper §3.1).

The paper builds non-i.i.d. shards by k-Means clustering C4 documents on a
pretrained model's features, which yields (a) distinct per-shard
distributions and (b) *imbalanced* shard sizes (they weight outer grads by
shard size at k=64). We model both: ``make_regime`` returns a sampler
whose shards have controllable distribution skew (alpha) and a size
profile (balanced or Zipf-imbalanced, mirroring cluster imbalance).
"""
from __future__ import annotations

import numpy as np

from .pipeline import MarkovMixture


def make_regime(regime: str, *, k: int = 8, vocab_size: int = 256,
                seed: int = 0, alpha_noniid: float = 2.0,
                imbalanced: bool = False) -> MarkovMixture:
    assert regime in ("iid", "non_iid"), regime
    alpha = 0.0 if regime == "iid" else alpha_noniid
    if imbalanced:
        sizes = 1.0 / np.arange(1, k + 1, dtype=np.float32)  # Zipf profile
        sizes = sizes / sizes.sum() * k
    else:
        sizes = np.ones((k,), np.float32)
    return MarkovMixture(vocab_size=vocab_size, k=k, alpha=alpha,
                         seed=seed, shard_sizes=sizes)


def shard_weights(sampler: MarkovMixture, weighted: bool) -> np.ndarray:
    """Outer-gradient averaging weights (uniform, or by shard size)."""
    if weighted:
        w = sampler.shard_sizes
    else:
        w = np.ones((sampler.k,), np.float32)
    return (w / w.sum()).astype(np.float32)
