"""Pallas TPU kernels for DiLoCo's compute hot-spots.

flash_attention.py  blocked online-softmax attention (inner-loop compute)
fused_adamw.py      one-VMEM-pass inner AdamW update (memory-bound)
sign_prune.py       fused sign election + magnitude pruning (Table 6)
outer_nesterov.py   fused outer Nesterov update
ops.py              backend dispatch (kernel on TPU, jnp oracle elsewhere)
ref.py              pure-jnp oracles for every kernel
compat.py           Pallas TPU API names across jax releases

The fused optimizer kernels are wired into the training hot path via
``kernel_mode`` on TrainConfig (inner AdamW) and DiLoCoConfig (outer
Nesterov, sign pruning): ``ref`` = legacy jnp tree maps, ``auto`` =
kernels on TPU / oracles elsewhere, ``pallas``/``interpret`` = forced.
"""
