"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the numerical ground truth the kernels are tested
against (tests sweep shapes/dtypes with interpret=True). They are also
the implementations used on non-TPU backends via ``ops.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# flash attention (causal / sliding-window GQA)
# ---------------------------------------------------------------------------

def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float | None = None):
    """q: (B, Sq, H, d); k/v: (B, Skv, G, d) with H % G == 0.

    Full-softmax reference (materializes scores — oracle only; use on
    small shapes).
    """
    B, Sq, H, d = q.shape
    _, Sk, G, _ = k.shape
    rep = H // G
    scale = d ** -0.5 if scale is None else scale
    qh = (q * scale).reshape(B, Sq, G, rep, d).astype(jnp.float32)
    s = jnp.einsum("bqgrd,bkgd->bgrqk", qh, k.astype(jnp.float32))
    qpos = jnp.arange(Sq)
    kpos = jnp.arange(Sk)
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= kpos[None, :] <= qpos[:, None] + (Sk - Sq)
    if window and window > 0:
        ok &= kpos[None, :] > qpos[:, None] + (Sk - Sq) - window
    s = jnp.where(ok[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrqk,bkgd->bqgrd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# fused AdamW update
# ---------------------------------------------------------------------------

def fused_adamw(p, g, m, v, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                weight_decay=0.1, c1=1.0, c2=1.0):
    """Single fused AdamW step on one tensor. c1/c2 are the bias
    corrections (1-b1^t, 1-b2^t) computed by the caller."""
    pf, gf = p.astype(jnp.float32), g.astype(jnp.float32)
    mf, vf = m.astype(jnp.float32), v.astype(jnp.float32)
    m_new = b1 * mf + (1.0 - b1) * gf
    v_new = b2 * vf + (1.0 - b2) * jnp.square(gf)
    step = (m_new / c1) / (jnp.sqrt(v_new / c2) + eps) + weight_decay * pf
    p_new = pf - lr * step
    return (p_new.astype(p.dtype), m_new.astype(m.dtype),
            v_new.astype(v.dtype))


def fused_adamw_mixed(g, m, v, master, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                      weight_decay=0.1, c1=1.0, c2=1.0,
                      param_dtype=jnp.bfloat16):
    """Mixed-precision fused AdamW step on one tensor.

    The master copy (typically f32) is the authoritative parameter
    value; grads/moments arrive at the replica storage dtype (typically
    bf16). Everything is computed in f32 and stored back at each
    operand's own dtype; the working copy of the params is emitted at
    ``param_dtype`` in the same pass — no separate cast chain.

    Returns (p_working, m_new, v_new, master_new).
    """
    gf = g.astype(jnp.float32)
    mf, vf = m.astype(jnp.float32), v.astype(jnp.float32)
    wf = master.astype(jnp.float32)
    m_new = b1 * mf + (1.0 - b1) * gf
    v_new = b2 * vf + (1.0 - b2) * jnp.square(gf)
    step = (m_new / c1) / (jnp.sqrt(v_new / c2) + eps) + weight_decay * wf
    w_new = wf - lr * step
    return (w_new.astype(param_dtype), m_new.astype(m.dtype),
            v_new.astype(v.dtype), w_new.astype(master.dtype))


# ---------------------------------------------------------------------------
# per-neuron sign pruning (TIES-style) of outer gradients
# ---------------------------------------------------------------------------

def bisect_threshold(mag, keep_count, iters: int = 26):
    """Per-row magnitude threshold t s.t. count(|x| >= t) <= keep_count,
    found by fixed-iteration bisection (kernel-expressible, unlike a
    quantile). mag: (R, C) >= 0; keep_count: int. Returns (R, 1)."""
    lo = jnp.zeros((mag.shape[0], 1), jnp.float32)
    hi = jnp.max(mag, axis=-1, keepdims=True) * (1.0 + 1e-6) + 1e-30

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((mag >= mid).astype(jnp.int32), -1, keepdims=True)
        too_many = cnt > keep_count
        return jnp.where(too_many, mid, lo), jnp.where(too_many, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return hi


def sign_prune(x, frac: float):
    """x: (R, C). Per row: elect sign by magnitude mass, keep entries
    agreeing with the elected sign AND in the top (1-frac) fraction by
    magnitude (threshold via deterministic bisection)."""
    mag = jnp.abs(x.astype(jnp.float32))
    pos = jnp.sum(jnp.where(x > 0, mag, 0.0), -1, keepdims=True)
    neg = jnp.sum(jnp.where(x < 0, mag, 0.0), -1, keepdims=True)
    elected = jnp.where(pos >= neg, 1.0, -1.0)
    agrees = jnp.sign(x.astype(jnp.float32)) == elected
    keep_count = max(int(round((1.0 - frac) * x.shape[-1])), 1)
    thresh = bisect_threshold(mag, keep_count)
    keep = agrees & (mag >= thresh)
    return jnp.where(keep, x, jnp.zeros_like(x))


# ---------------------------------------------------------------------------
# low-precision outer-gradient transport (streaming DiLoCo)
# ---------------------------------------------------------------------------

INT4_LEVELS = 7.0          # symmetric int4: codes in [-7, 7]
# scale = amax × this constant, NOT amax / 7: XLA strength-reduces a
# divide-by-constant into a reciprocal multiply in some compilation
# contexts (jit bodies) but not others (interpret-mode kernels), a
# 1-ulp divergence that would break the oracle-bitwise-equal contract
# between this reference and kernels/quantize.py. One pre-rounded f32
# reciprocal multiplied identically everywhere is rewrite-proof.
INV_INT4_LEVELS = float(np.float32(1.0 / INT4_LEVELS))


def quantize_int4(x):
    """Blockwise symmetric int4 quantization. x: (R, C) with each row a
    block sharing one f32 scale (the streaming transport flattens
    tensors to (blocks, 128)). Returns (codes int8 in [-7, 7],
    scales (R, 1) f32). All-zero blocks get scale 0 and codes 0."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = amax * INV_INT4_LEVELS
    q = jnp.round(xf / jnp.where(scale > 0, scale, 1.0))
    q = jnp.clip(q, -INT4_LEVELS, INT4_LEVELS).astype(jnp.int8)
    return q, scale


def dequantize_int4(codes, scales):
    """Inverse of ``quantize_int4``: (R, C) int8 × (R, 1) f32 -> f32."""
    return codes.astype(jnp.float32) * scales


def pack_int4(codes):
    """Nibble-pack int4 codes: flat (n,) int8 in [-7, 7] -> (ceil(n/2),)
    int8 wire bytes. Byte b holds element 2b in its low nibble and
    element 2b+1 in its high nibble (4-bit two's complement); an odd
    tail pads one zero nibble. This IS the wire format the packed
    transport all-gathers — 2 codes per byte."""
    n = codes.shape[0]
    if n % 2:
        codes = jnp.pad(codes, (0, 1))
    c = codes.reshape(-1, 2).astype(jnp.int32) & 0xF
    return (c[:, 0] | (c[:, 1] << 4)).astype(jnp.int8)


def unpack_int4(packed, n: int):
    """Inverse of ``pack_int4``: (ceil(n/2),) int8 wire bytes -> (n,)
    int8 codes in [-7, 7] (4-bit two's complement sign extension)."""
    p = packed.astype(jnp.int32) & 0xFF
    nib = jnp.stack([p & 0xF, (p >> 4) & 0xF], axis=-1).reshape(-1)[:n]
    return ((nib ^ 8) - 8).astype(jnp.int8)


def quantize_pack_int4(x):
    """Oracle for the fused quantize+nibble-pack kernel: (R, 128) f32
    blocks -> (packed (R, 64) int8 wire bytes, scales (R, 1) f32,
    local (R, 128) f32 dequantized sender payload) — the exact
    composition quantize_int4 → pack_int4 → dequantize_int4, so the
    one-pass kernel is verified bitwise against the multi-pass path."""
    codes, scales = quantize_int4(x)
    rows, cols = codes.shape
    packed = pack_int4(codes.reshape(-1)).reshape(rows, cols // 2)
    return packed, scales, dequantize_int4(codes, scales)


def unpack_dequantize_int4(packed, scales):
    """Oracle for the fused unpack+dequantize consumer: (R, 64) int8
    wire bytes × (R, 1) f32 scales -> (R, 128) f32 values — the exact
    composition unpack_int4 → dequantize_int4."""
    rows, cols = packed.shape
    codes = unpack_int4(packed.reshape(-1),
                        rows * cols * 2).reshape(rows, cols * 2)
    return dequantize_int4(codes, scales)


def unpack_dequantize_reduce(packed, scales, m):
    """Oracle for the fused unpack+dequantize+reduce consumer: decode
    every replica's wire blocks and mask-combine them in one pass.
    packed (k, R, 64) int8, scales (k, R, 1) f32, m (k,) f32 ->
    (R, 128) f32 = Σ_k m_k · codes_k · scale_k (the caller divides by
    the mask sum). The reduction is the elementwise masked sum over the
    leading replica axis — the same accumulation the kernel runs."""
    vals = jax.vmap(unpack_dequantize_int4)(packed, scales)
    return jnp.sum(m.reshape(-1, 1, 1) * vals, axis=0)


def fake_quant(x, dtype: str):
    """Quantize→dequantize round trip simulating low-precision
    transport of outer gradients. x: (R, C) blocks (int4) or any shape
    (bfloat16). Returns the same shape/dtype as x."""
    if dtype == "float32":
        return x
    if dtype == "bfloat16":
        return x.astype(jnp.bfloat16).astype(x.dtype)
    if dtype == "int4":
        codes, scales = quantize_int4(x)
        return dequantize_int4(codes, scales).astype(x.dtype)
    raise ValueError(f"unknown transport dtype {dtype!r}")


# ---------------------------------------------------------------------------
# fused outer Nesterov update
# ---------------------------------------------------------------------------

def outer_nesterov(p, delta, buf, *, lr, momentum=0.9):
    """θ ← θ − lr·(μ·b_new + Δ) with b_new = μ·b + Δ. Returns (p, buf)."""
    pf = p.astype(jnp.float32)
    df = delta.astype(jnp.float32)
    bf = buf.astype(jnp.float32)
    b_new = momentum * bf + df
    p_new = pf - lr * (momentum * b_new + df)
    return p_new.astype(p.dtype), b_new.astype(buf.dtype)
