"""DiLoCo — the paper's primary contribution.

diloco.py       Algorithm 1 (inner AdamW phases + outer Nesterov step)
outer_opt.py    outer optimizers (Nesterov / SGD / SGDM / Adam)
compression.py  per-neuron sign pruning of outer gradients (Table 6)
schedules.py    adaptive compute pool & communication-drop schedules
"""
from . import compression, diloco, outer_opt, schedules  # noqa: F401
