"""Beyond-paper: asynchronous DiLoCo (the paper's §5 future work).

Heterogeneous islands (speeds 1x/2x/4x) never wait for each other: a
finished worker's outer gradient is applied immediately with a
staleness discount λ^τ and the worker re-dispatches from the fresh
global copy.

Comparisons at equal WALL-CLOCK:
  * synchronous DiLoCo paced by the SLOWEST island (the paper's §5
    complaint: "waiting for all workers ... is rather inefficient");
  * async with λ=0.7 (staleness-discounted);
  * async with λ=1.0 (no compensation — ablation).

Expectation: async beats the straggler-paced synchronous run at equal
wall-clock, and the staleness discount is what keeps it stable.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.configs.base import TrainConfig
from repro.core import diloco
from repro.core.async_diloco import AsyncConfig, run_async
from . import common as C

SPEEDS = (1, 1, 1, 1, 2, 2, 4, 4)     # heterogeneous islands


def run(scale: int = 1):
    p = dict(C.DEFAULTS)
    k, H = len(SPEEDS), p["H"]
    ticks = 24 * scale                # wall-clock budget
    arch, loss_fn, sampler = C.make_setup("non_iid", k=k)
    params0, pre = C.pretrain(arch, loss_fn, sampler, p["pretrain"],
                              batch=p["batch"], seq=p["seq"],
                              lr=p["inner_lr"], warmup=p["warmup"],
                              total=p["pretrain"] + ticks * H)
    ev = diloco.make_eval(loss_fn)
    val = sampler.sample_validation(jax.random.PRNGKey(10_000), 64,
                                    p["seq"])
    tcfg = TrainConfig(inner_lr=p["inner_lr"], warmup_steps=p["warmup"],
                       total_steps=pre + ticks * H,
                       batch_size=p["batch"], seq_len=p["seq"])

    # --- synchronous DiLoCo paced by the slowest island: one outer
    # round per max(SPEEDS) ticks ---
    sync_rounds = ticks // max(SPEEDS)
    h, _ = C.run_diloco(arch, loss_fn, sampler, params0, k=k, H=H,
                        rounds=sync_rounds, step0=pre, batch=p["batch"],
                        seq=p["seq"], eval_every=sync_rounds)
    sync_ppl = C.final_ppl(h)

    # --- async variants ---
    out = {}
    for lam in (0.7, 1.0):
        acfg = AsyncConfig(k=k, H=H, staleness_lambda=lam, speeds=SPEEDS)
        sample_one = lambda key, B, S: sampler.sample_validation(key, B,
                                                                 S)
        gp, hist = run_async(
            lambda pp, bb: loss_fn(pp, bb),
            lambda key, B, S: sampler.sample_shard(
                key, jax.random.randint(key, (), 0, k), B, S),
            params0, acfg, tcfg, ticks=ticks, eval_fn=ev,
            eval_tokens=val)
        out[lam] = {"ppl": hist[-1]["ppl"],
                    "outer_updates": hist[-1]["version"],
                    "mean_staleness": float(np.mean(
                        [r["staleness"] for r in hist]))}

    payload = {
        "speeds": SPEEDS, "ticks": ticks,
        "sync_straggler_ppl": sync_ppl,
        "sync_outer_updates": sync_rounds,
        "async": {str(k2): v for k2, v in out.items()},
        "claims": {
            "async_beats_straggler_paced_sync":
                out[0.7]["ppl"] < sync_ppl,
            "async_more_updates_per_wallclock":
                out[0.7]["outer_updates"] > sync_rounds,
            "staleness_discount_not_harmful":
                out[0.7]["ppl"] < out[1.0]["ppl"] * 1.05,
        }}
    C.save("beyond_async", payload)
    return payload


if __name__ == "__main__":
    res = run()
    print("sync (straggler-paced) ppl:", round(res["sync_straggler_ppl"], 1),
          "updates:", res["sync_outer_updates"])
    for lam, v in res["async"].items():
        print(f"async λ={lam}: ppl={v['ppl']:.1f} "
              f"updates={v['outer_updates']} "
              f"staleness={v['mean_staleness']:.2f}")
    print(res["claims"])
