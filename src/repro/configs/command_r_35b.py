"""command-r-35b [dense, hf:CohereForAI/c4ai-command-r-v01]: 40L,
d_model=8192, 64 heads, GQA kv=8, d_ff=22528, vocab=256000, no biases,
parallel attention+MLP block, tied embeddings."""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="command-r-35b", family="dense",
        n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=22_528, vocab_size=256_000,
        pos_emb="rope", rope_theta=8e6, norm="layernorm",
        act="silu", mlp_gated=True, parallel_block=True,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="command-r-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, d_ff=256, vocab_size=256, attn_chunk=64)
