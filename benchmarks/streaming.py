"""Streaming outer-sync benchmark: fragment scheduling, overlap, and
quantized transport vs the synchronous outer step.

Runs the same DiLoCo workload (equal rounds, equal inner steps) under
the classic synchronous driver and under ``core/streaming.py`` with
several (P fragments, α, τ, transport dtype) settings, then derives the
communication profile every configuration would put on a real
interconnect:

  peak_bytes_per_sync     bytes one replica sends at its largest single
                          sync event — the *peak-bandwidth* bill.
                          Synchronous DiLoCo syncs the full model in
                          f32; streaming syncs one fragment at the
                          transport precision, so this drops ≥ P×
                          (× another 2–7.5× from quantization).
  round_bytes             total bytes per replica per round (all P
                          fragment syncs vs one full-model sync).
  bandwidth_curves        estimated wall-clock per run over a sweep of
                          interconnect bandwidths: measured compute time
                          plus per-sync stalls, where a streaming sync
                          may hide up to τ inner steps of its transfer
                          behind compute (the overlap simulator's
                          semantics) while the synchronous barrier hides
                          nothing.
  claims.bit_identical_P1_vs_sync   the regression gate: P=1, α=1, τ=0,
                          f32 transport must be bit-identical to the
                          synchronous scanned driver.
  claims.peak_bytes_reduced_geP     every quantized streaming config
                          must cut peak bytes-per-sync by at least its
                          own P×.

Results go to ``BENCH_streaming.json`` at the repo root (see
benchmarks/README.md for the field-by-field reading guide).

Run:  PYTHONPATH=src python -m benchmarks.streaming [--rounds 6 ...]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

# standalone runs get 8 fake CPU devices so the sharded-transport
# configs exercise REAL pod-axis collectives; under benchmarks.run
# (jax already imported by an earlier module) the sharded rows are
# skipped instead — set the flag in the environment to include them
if "jax" not in sys.modules and \
        "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 "
        + os.environ.get("XLA_FLAGS", ""))

import jax
import jax.numpy as jnp
import numpy as np

from . import common as C
from repro.configs.base import DiLoCoConfig, TrainConfig
from repro.core import diloco, fragments, pod_collectives, streaming
from repro.kernels import ops as kops
from repro.kernels.ops import transport_bytes
from repro.launch import hlo_analysis as H_hlo
from repro.launch.mesh import make_pod_mesh

ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
OUT_PATH = os.path.join(ROOT, "BENCH_streaming.json")

BANDWIDTHS = [1e6, 1e7, 1e8, 1e9, 1e10, 1e11]   # bytes/s


def stream_configs(k: int, H: int):
    """(name, DiLoCoConfig) list. The first entry is the synchronous
    baseline; stream_P1_f32 is the bit-identity gate; *_sharded rows
    rerun a simulated config with transport="sharded" — one replica
    per pod on a fake-device mesh, real pod-axis collectives — and
    gate on state parity against their simulated twin."""
    tau = min(1, H - 1)
    P4 = min(4, H)
    cfgs = [
        ("sync", DiLoCoConfig(k=k, H=H)),
        ("stream_P1_f32",
         DiLoCoConfig(k=k, H=H, streaming_fragments=1)),
        ("stream_P2_f32",
         DiLoCoConfig(k=k, H=H, streaming_fragments=2, stream_alpha=0.5,
                      stream_tau=tau)),
        ("stream_P2_bf16",
         DiLoCoConfig(k=k, H=H, streaming_fragments=2, stream_alpha=0.5,
                      stream_tau=tau, outer_grad_dtype="bfloat16")),
        ("stream_P4_int4",
         DiLoCoConfig(k=k, H=H, streaming_fragments=P4, stream_alpha=0.5,
                      stream_tau=tau, outer_grad_dtype="int4")),
    ]
    if len(jax.devices()) % k == 0 and len(jax.devices()) >= k:
        for src in ("stream_P2_f32", "stream_P2_bf16",
                    "stream_P4_int4"):
            base = dict(cfgs)[src]
            cfgs.append((src + "_sharded",
                         dataclasses.replace(base,
                                             transport="sharded")))
    return cfgs


def comm_profile(params, dcfg: DiLoCoConfig) -> dict:
    """Static wire profile of one replica's outer sync per round.
    Bytes are exact per ``ops.transport_bytes``: int4 pays its f32
    scale per started 128-element block of each contiguous leaf region
    a fragment ships (the unit the sender packs and quantizes).

    Rows whose transport actually packs the wire (sharded + quantized +
    pack_wire) use the PACKED byte model as their main figures — the
    exact size of the buffers the lowered all-gather ships, which the
    HLO gate checks — and record the legacy fake-quant model alongside
    for comparison; all other rows keep the legacy model as main."""
    total = int(sum(l.size for l in jax.tree.leaves(params)))
    if not dcfg.streaming_fragments:
        fb = transport_bytes(total, "float32")
        return {"peak_bytes_per_sync": fb,
                "round_bytes": fb,
                "syncs_per_round": 1,
                "fragment_elems": [total],
                "fragment_bytes": [fb],
                "transport": "float32"}
    part = fragments.partition_params(params, dcfg.streaming_fragments,
                                      overrides=dcfg.stream_overrides)
    dt = dcfg.outer_grad_dtype
    per_frag = [sum(transport_bytes(e, dt) for e in regs)
                for regs in part.region_sizes]
    per_frag_packed = [sum(transport_bytes(e, dt, packed=True)
                           for e in regs)
                       for regs in part.region_sizes]
    packed_active = (dcfg.transport == "sharded"
                     and getattr(dcfg, "pack_wire", True)
                     and dt in ("bfloat16", "int4"))
    main = per_frag_packed if packed_active else per_frag
    return {"peak_bytes_per_sync": max(main),
            "round_bytes": sum(main),
            "round_bytes_packed_model": sum(per_frag_packed),
            "round_bytes_legacy_model": sum(per_frag),
            "fragment_region_elems": [list(r)
                                      for r in part.region_sizes],
            "packed_wire": packed_active,
            "syncs_per_round": part.n,
            "fragment_elems": list(part.sizes),
            "fragment_bytes": main,
            "transport": dt}


def bench_one(loss_fn, sampler, params, name, dcfg, tcfg, *, rounds,
              batch, seq, val, seed, repeats):
    """Time one driver config (min-of-repeats after warmup). Sharded
    configs get a one-replica-band-per-pod mesh and an HLO wire
    profile (real pod-axis all-reduce count/bytes + the interleaving
    structure) alongside the timing."""
    mesh = None
    if getattr(dcfg, "transport", "simulated") == "sharded":
        mesh = make_pod_mesh(dcfg.k)
    run = diloco.make_run(loss_fn, sampler.sample_all_shards, dcfg,
                          tcfg, rounds_per_call=rounds,
                          total_steps=rounds * dcfg.H, batch_size=batch,
                          seq_len=seq, eval_tokens=val, eval_every=1,
                          donate=False, mesh=mesh)

    def init():
        if dcfg.streaming_fragments:
            st = streaming.init_state(params, dcfg)
            if mesh is not None:
                st = pod_collectives.shard_stream_state(st, mesh)
            return st
        return diloco.init_state(params, dcfg)

    def one():
        state = init()
        jax.block_until_ready(state)
        t0 = time.perf_counter()
        state, ms = run(state, jax.random.PRNGKey(seed + 2))
        jax.block_until_ready((state, ms))
        return time.perf_counter() - t0, state, ms

    one()                                            # compile warmup
    results = [one() for _ in range(repeats)]
    t = min(r[0] for r in results)
    _, state, ms = results[0]
    rec = {"name": name, "total_s": t,
           "round_latency_ms": 1e3 * t / rounds,
           "final_val_loss": float(np.asarray(ms["val_loss"])[-1]),
           "state": state}
    if mesh is not None:
        # compiled-HLO wire profile — what the collective program
        # REALLY ships. Lowered as a dedicated rounds_per_call=1
        # program (one extra small compile) so the per-round bytes are
        # exact by construction: the R-round program's scan trip count
        # is not reliably recoverable from post-optimization HLO, so
        # dividing its totals by R would silently mis-scale.
        cpp = len(jax.devices()) // pod_collectives.pods_of(mesh)
        run1 = diloco.make_run(
            loss_fn, sampler.sample_all_shards, dcfg, tcfg,
            rounds_per_call=1, total_steps=rounds * dcfg.H,
            batch_size=batch, seq_len=seq, donate=False, mesh=mesh)
        hlo = run1.lower(init(),
                         jax.random.PRNGKey(seed + 2)).compile().as_text()
        coll = H_hlo.collective_stats(hlo, chips_per_pod=cpp)
        inter = H_hlo.stream_interleaving(hlo, chips_per_pod=cpp)
        rec["wire"] = {
            "pods": pod_collectives.pods_of(mesh),
            "hlo_cross_pod_bytes_per_round": coll.cross_pod_bytes,
            "hlo_cross_gather_bytes_per_round":
                coll.cross_by_op.get("all-gather", 0),
            "hlo_cross_by_op": dict(coll.cross_by_op),
            "hlo_collectives_by_op": dict(coll.by_op),
            "pod_collectives": inter["pod_collectives"],
            "pod_all_reduces": inter["pod_all_reduces"],
            "sync_by_op": inter["sync_by_op"],
            "syncs_with_compute_after":
                inter["syncs_with_compute_after"],
            "syncs_inside_compute": inter["syncs_inside_compute"],
        }
    return rec


def bandwidth_curve(profile, *, rounds, compute_s, H, tau) -> dict:
    """Estimated total wall-clock at each simulated bandwidth: measured
    compute plus per-sync transfer stalls. A streaming sync has τ inner
    steps of compute to hide its transfer behind; the synchronous
    barrier overlaps nothing."""
    t_step = compute_s / (rounds * H)
    peak = profile["peak_bytes_per_sync"]
    n_syncs = profile["syncs_per_round"]
    per_frag = profile["fragment_bytes"]
    est = []
    for bw in BANDWIDTHS:
        stall = sum(max(0.0, b / bw - tau * t_step) for b in per_frag)
        est.append(compute_s + rounds * stall)
    return {"bandwidth_bytes_per_s": BANDWIDTHS,
            "est_total_s": est,
            "min_bw_for_full_overlap":
                (max(per_frag) / (tau * t_step) if tau > 0 else None),
            "peak_bytes_per_sync": peak,
            "syncs_per_round": n_syncs}


def fakequant_micro(*, n_elems=1 << 18, repeats=5, seed=0) -> dict:
    """Fused fake-quant kernel vs XLA's cast chain, per transport dtype.

    ``ref`` is what XLA builds from the jnp oracle (for bf16 literally a
    down/up cast chain; for int4 the blockwise quantize math with codes
    and scales materialized); ``kernel`` is the fused one-VMEM-pass
    Pallas round trip. On TPU the kernel path runs compiled
    (mode="pallas"); elsewhere it runs the interpreter, which measures
    correctness overhead, not speed — ``kernel_mode`` records which one
    this report used, so only same-mode numbers are comparable."""
    on_tpu = jax.default_backend() == "tpu"
    kmode = "pallas" if on_tpu else "interpret"
    x = jax.random.normal(jax.random.PRNGKey(seed), (n_elems,))
    out = {"n_elems": n_elems, "kernel_mode": kmode}
    for dt in ("bfloat16", "int4"):
        per = {}
        for label, mode in (("ref_ms", "ref"), ("kernel_ms", kmode)):
            fn = jax.jit(lambda y, m=mode, d=dt:
                         kops.quant_roundtrip(y, d, mode=m))
            jax.block_until_ready(fn(x))            # compile warmup
            ts = []
            for _ in range(repeats):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(x))
                ts.append(time.perf_counter() - t0)
            per[label] = 1e3 * min(ts)
        per["wire_bytes"] = transport_bytes(n_elems, dt)
        out[dt] = per
    return out


def run(scale: int = 1, *, k=4, H=6, rounds=6, batch=2, seq=32,
        eval_batch=16, repeats=3, seed=0, out=OUT_PATH):
    rounds = rounds * scale
    arch, loss_fn, sampler = C.make_setup(k=k, seed=seed)
    total = rounds * H
    params, _ = C.pretrain(arch, loss_fn, sampler, 0, batch=batch,
                           seq=seq, lr=3e-3, warmup=10, total=total,
                           seed=seed)
    val = sampler.sample_validation(jax.random.PRNGKey(10_000),
                                    eval_batch, seq)
    tcfg = TrainConfig(inner_lr=3e-3, warmup_steps=10, total_steps=total,
                       batch_size=batch, seq_len=seq)
    print(f"k={k} H={H} rounds={rounds} batch={batch} seq={seq} "
          f"backend={jax.default_backend()}")

    runs, states = {}, {}
    for name, dcfg in stream_configs(k, H):
        r = bench_one(loss_fn, sampler, params, name, dcfg, tcfg,
                      rounds=rounds, batch=batch, seq=seq, val=val,
                      seed=seed, repeats=repeats)
        states[name] = r.pop("state")
        r["comm"] = comm_profile(params, dcfg)
        r["curve"] = bandwidth_curve(
            r["comm"], rounds=rounds, compute_s=r["total_s"], H=H,
            tau=dcfg.stream_tau if dcfg.streaming_fragments else 0)
        # "transport" historically meant the wire dtype here; that now
        # collides with DiLoCoConfig.transport (simulated|sharded), so
        # the config records both under unambiguous keys instead
        r["config"] = {"P": dcfg.streaming_fragments,
                       "alpha": dcfg.stream_alpha,
                       "tau": dcfg.stream_tau,
                       "wire_dtype": dcfg.outer_grad_dtype,
                       "backend": dcfg.transport}
        runs[name] = r
        print(f"{name:16s} {r['round_latency_ms']:8.2f} ms/round  "
              f"val={r['final_val_loss']:.4f}  "
              f"peak_sync={r['comm']['peak_bytes_per_sync']:.0f} B")

    sync_state = states["sync"]
    p1_state = states["stream_P1_f32"].base
    bit_identical = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(sync_state),
                        jax.tree.leaves(p1_state)))

    # sharded-transport parity gates against each run's simulated twin
    # (one replica per pod — see core/pod_collectives.py): the f32 row
    # must match bit-for-bit; quantized rows match within quant-error
    # bounds (re-fused quantize math shifts near-tie codes by one
    # step). Every sharded row's fragment collectives must interleave
    # into inner compute with none inside the inner-step loops.
    sharded_identical, sharded_close, sharded_interleaved = {}, {}, True
    for name in list(runs):
        if not name.endswith("_sharded"):
            continue
        twin = name[:-len("_sharded")]
        pairs = list(zip(jax.tree.leaves(states[twin]),
                         jax.tree.leaves(states[name])))
        worst = max(float(np.max(np.abs(
            np.asarray(a, np.float64) - np.asarray(b, np.float64))))
            for a, b in pairs)
        if runs[name]["config"]["wire_dtype"] == "float32":
            sharded_identical[name] = all(
                np.array_equal(np.asarray(a), np.asarray(b))
                for a, b in pairs)
        else:
            sharded_close[name] = worst <= 5e-3
        runs[name]["vs_simulated_max_abs_diff"] = worst
        w = runs[name]["wire"]
        P = runs[name]["config"]["P"]
        if (w["pod_collectives"] < P
                or w["syncs_with_compute_after"] < P - 1
                or w["syncs_inside_compute"] != 0):
            sharded_interleaved = False

    # packed-wire gates — measured, not modeled: the bytes the lowered
    # round's pod-crossing all-gathers actually ship must match the
    # packed static model (within alignment slack), arrive as exactly
    # ONE gather per fragment per sync, and (int4) cut the real wire
    # ≥ 5× vs what the same regions would cost at f32
    packed_match, packed_gathers, int4_reduction = {}, {}, {}
    for name, r in runs.items():
        if not (name.endswith("_sharded")
                and r["comm"].get("packed_wire")):
            continue
        w, P = r["wire"], r["config"]["P"]
        model = k * r["comm"]["round_bytes_packed_model"]
        meas = w["hlo_cross_gather_bytes_per_round"]
        w["packed_model_gathered_bytes"] = model
        w["measured_over_packed_model"] = (meas / model if model
                                           else None)
        # two-sided: the gather output is k×W bytes by construction
        # (observed ratio 1.000), so shipping *fewer* bytes than the
        # model charges is as much a regression as shipping more
        packed_match[name] = bool(0.95 * model <= meas <= 1.35 * model)
        packed_gathers[name] = bool(
            w["sync_by_op"].get("all-gather", 0) == P)
        if r["config"]["wire_dtype"] == "int4":
            f32_model = k * sum(
                transport_bytes(e, "float32")
                for regs in r["comm"]["fragment_region_elems"]
                for e in regs)
            w["f32_wire_reduction"] = (f32_model / meas if meas
                                       else 0.0)
            int4_reduction[name] = bool(
                meas and f32_model / meas >= 5.0)

    sync_peak = runs["sync"]["comm"]["peak_bytes_per_sync"]
    reductions = {}
    ge_p = True
    for name, r in runs.items():
        P = r["config"]["P"]
        if not P:
            continue
        red = sync_peak / r["comm"]["peak_bytes_per_sync"]
        reductions[name] = red
        if r["config"]["wire_dtype"] != "float32" and red < P:
            ge_p = False

    fq = fakequant_micro(repeats=repeats, seed=seed)
    print("fakequant micro (n=%d, %s): bf16 ref=%.3fms kernel=%.3fms  "
          "int4 ref=%.3fms kernel=%.3fms"
          % (fq["n_elems"], fq["kernel_mode"],
             fq["bfloat16"]["ref_ms"], fq["bfloat16"]["kernel_ms"],
             fq["int4"]["ref_ms"], fq["int4"]["kernel_ms"]))

    report = {
        "config": {"k": k, "H": H, "rounds": rounds, "batch": batch,
                   "seq": seq, "backend": jax.default_backend(),
                   "model_params": int(sum(
                       l.size for l in jax.tree.leaves(params)))},
        "fakequant_micro": fq,
        "runs": runs,
        "sync_peak_bytes_per_sync": sync_peak,
        "peak_bytes_reduction": reductions,
        "claims": {
            "bit_identical_P1_vs_sync": bool(bit_identical),
            "peak_bytes_reduced_geP": bool(ge_p),
            "all_losses_finite": bool(all(
                np.isfinite(r["final_val_loss"])
                for r in runs.values())),
            "sharded_configs_ran": bool(sharded_identical
                                        or sharded_close),
        },
        "sharded_identical": {n: bool(v)
                              for n, v in sharded_identical.items()},
        "sharded_close": {n: bool(v)
                          for n, v in sharded_close.items()},
    }
    if sharded_identical or sharded_close:
        # only meaningful when the sharded rows actually ran — an
        # all({}) claim would read "true" on a run that never
        # exercised the sharded transport
        report["claims"].update({
            "sharded_f32_bit_identical_to_simulated": bool(
                sharded_identical
                and all(sharded_identical.values())),
            "sharded_quantized_within_tolerance": bool(
                sharded_close and all(sharded_close.values())),
            "sharded_collectives_interleaved": bool(
                sharded_interleaved),
        })
    if packed_match:
        # HLO-measured packed-wire gates (omitted, like the sharded
        # parity gates, when no packed sharded row could run)
        report["claims"].update({
            "sharded_packed_bytes_within_1p35x_model": bool(
                all(packed_match.values())),
            "sharded_one_gather_per_fragment_per_sync": bool(
                all(packed_gathers.values())),
            "sharded_int4_wire_reduction_ge5x": bool(
                int4_reduction and all(int4_reduction.values())),
        })
        for name in packed_match:
            w = runs[name]["wire"]
            print(f"packed wire {name}: measured="
                  f"{w['hlo_cross_gather_bytes_per_round']} B/round "
                  f"model={w['packed_model_gathered_bytes']:.0f} B "
                  f"(x{w['measured_over_packed_model']:.3f}) "
                  f"gathers={w['sync_by_op'].get('all-gather', 0)}"
                  + (f"  f32-wire reduction "
                     f"{w['f32_wire_reduction']:.2f}x"
                     if "f32_wire_reduction" in w else ""))
    print(f"bit-identical P=1: {bit_identical}   "
          f"peak-bytes reductions: "
          + "  ".join(f"{n}={v:.2f}x" for n, v in reductions.items()))
    if sharded_identical or sharded_close:
        print("sharded transport: "
              + "  ".join(f"{n}: bitwise={v}"
                          for n, v in sharded_identical.items())
              + "  " + "  ".join(f"{n}: close={v}"
                                 for n, v in sharded_close.items())
              + f"  interleaved={sharded_interleaved}")
    else:
        print("sharded transport: skipped (device count "
              f"{len(jax.devices())} not a k={k} pod multiple — set "
              "--xla_force_host_platform_device_count)")

    with open(out, "w") as f:
        json.dump(report, f, indent=1)
    print("wrote", out)
    C.save("streaming", report)
    return report


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--H", type=int, default=6)
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--eval-batch", type=int, default=16)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=OUT_PATH)
    a = ap.parse_args(argv)
    return run(1, k=a.k, H=a.H, rounds=a.rounds, batch=a.batch,
               seq=a.seq, eval_batch=a.eval_batch, repeats=a.repeats,
               seed=a.seed, out=a.out)


if __name__ == "__main__":
    main()
