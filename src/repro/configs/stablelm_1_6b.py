"""stablelm-1.6b [dense, hf:stabilityai/stablelm-2-1_6b]: 24L,
d_model=2048, 32 heads MHA (kv=32), d_ff=5632, vocab=100352,
partial RoPE (25%), LayerNorm."""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-1.6b", family="dense",
        n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=5632, vocab_size=100_352,
        pos_emb="rope", rope_pct=0.25, norm="layernorm",
        act="silu", mlp_gated=True,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="stablelm-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=4, d_ff=256, vocab_size=256, attn_chunk=64)
