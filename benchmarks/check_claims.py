"""CI claims gate: every ``BENCH_*.json`` claim must be true, and no
previously-present claim may silently disappear.

Each benchmark writes a ``claims`` dict of named booleans — the
regression gates (bit-identity, byte reductions, HLO-measured wire
matches, ...). Two failure modes this script closes:

  * a claim flips to false — the benchmark itself only *records* it;
    nothing fails CI without this gate;
  * a claim (or a whole benchmark file) silently vanishes — e.g. a
    refactor renames the key or a guard starts skipping the rows that
    produce it, and the gate would "pass" by checking nothing.

``benchmarks/claims_manifest.json`` is the committed record of which
claims each BENCH file is expected to carry. The gate fails if a
manifest claim is missing from the file (or the file is missing
entirely) and warns on new unmanifested claims so they get committed.

Run:    PYTHONPATH=src python -m benchmarks.check_claims
Update: PYTHONPATH=src python -m benchmarks.check_claims \
            --update-manifest   (after intentionally adding claims)
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
MANIFEST = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "claims_manifest.json")


def load_claims(root: str) -> dict:
    """{bench-file-name: {claim: bool}} for every BENCH_*.json in
    ``root`` (files without a claims dict map to {})."""
    out = {}
    for path in sorted(glob.glob(os.path.join(root, "BENCH_*.json"))):
        with open(path) as f:
            data = json.load(f)
        claims = data.get("claims", {})
        if not isinstance(claims, dict):
            raise ValueError(f"{path}: 'claims' is not a dict")
        out[os.path.basename(path)] = claims
    return out


def informational(entry) -> bool:
    """True for claims recorded but NOT gated: a benchmark demotes a
    measurement it cannot stand behind on this backend (e.g. CPU runs
    emulate bf16 math in f32, so bf16 latency rows are noise, not
    perf claims) by writing ``{"value": ..., "informational": true,
    "backend": ...}`` instead of a bare boolean."""
    return isinstance(entry, dict) and bool(entry.get("informational"))


def check(claims_by_file: dict, manifest: dict) -> list:
    """All gate violations, as human-readable strings (empty = pass)."""
    errors = []
    for fname, claims in claims_by_file.items():
        for name, val in claims.items():
            # claims are named booleans, but some benchmarks keep the
            # measured figure next to the gate (e.g. wallclock's
            # speedup_x) — any FALSY entry fails, truthy records pass,
            # and informational entries are never gated
            if informational(val):
                continue
            if not val:
                errors.append(f"{fname}: claim '{name}' is "
                              f"{val!r} (must be true)")
    for fname, expected in manifest.items():
        claims = claims_by_file.get(fname)
        if claims is None:
            errors.append(f"{fname}: benchmark file missing but listed "
                          "in the claims manifest")
            continue
        for name in expected:
            if name not in claims:
                errors.append(
                    f"{fname}: claim '{name}' disappeared (present in "
                    "benchmarks/claims_manifest.json; regenerate the "
                    "benchmark or update the manifest deliberately)")
    return errors


def unmanifested(claims_by_file: dict, manifest: dict) -> list:
    return [f"{fname}: '{name}'"
            for fname, claims in claims_by_file.items()
            for name in claims
            if name not in manifest.get(fname, [])]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--root", default=ROOT,
                    help="directory holding the BENCH_*.json files")
    ap.add_argument("--manifest", default=MANIFEST)
    ap.add_argument("--update-manifest", action="store_true",
                    help="rewrite the manifest from the current files "
                         "(claims may be added, never dropped)")
    args = ap.parse_args(argv)

    claims_by_file = load_claims(args.root)
    manifest = {}
    if os.path.exists(args.manifest):
        with open(args.manifest) as f:
            manifest = json.load(f)

    if args.update_manifest:
        # merge, never drop: a claim once manifested stays required
        for fname, claims in claims_by_file.items():
            manifest[fname] = sorted(set(manifest.get(fname, []))
                                     | set(claims))
        with open(args.manifest, "w") as f:
            json.dump(manifest, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.manifest}")

    errors = check(claims_by_file, manifest)
    for fname, claims in claims_by_file.items():
        info = sum(1 for v in claims.values() if informational(v))
        ok = sum(1 for v in claims.values()
                 if v and not informational(v))
        gated = len(claims) - info
        tail = f" (+{info} informational)" if info else ""
        print(f"{fname}: {ok}/{gated} claims true{tail}")
    for miss in unmanifested(claims_by_file, manifest):
        print(f"note: unmanifested claim {miss} (run with "
              "--update-manifest to pin it)")
    if errors:
        for e in errors:
            print(f"FAIL: {e}", file=sys.stderr)
        return 1
    print("claims gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
