"""Hypothesis property tests for ``fragments.Partition`` × pod
sharding: for arbitrary fragment counts P, round lengths H that P does
not divide, τ-overlap, override patterns, pod bandings and 0/1 drop
masks, every leaf element of every communicating replica is reduced by
exactly one fragment collective per round — the invariant the sharded
transport (core/pod_collectives.py) relies on to never double-reduce
or skip a parameter. Plus the packed-wire invariants: int4 nibble
pack→unpack is the identity on the code grid for arbitrary lengths
(odd, ragged, sub-block), and the one-buffer wire codec decodes to the
sender's exact payload.

(Separate from tests/test_pod_collectives.py so the module-level
hypothesis importorskip cannot take the multi-device suite with it.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import fragments  # noqa: E402
from repro.kernels import ops as kops  # noqa: E402
from repro.kernels import ref as kref  # noqa: E402


def _toy_tree():
    return {"embed": np.zeros((7, 4), np.float32),
            "stack_w": np.zeros((5, 3, 2), np.float32),
            "stack_b": np.zeros((5, 2), np.float32),
            "head": np.zeros((4, 3), np.float32)}


@st.composite
def _pod_cases(draw):
    Hh = draw(st.integers(1, 8))
    P = draw(st.integers(1, min(6, Hh)))
    tau = draw(st.integers(0, Hh - 1))
    pods = draw(st.sampled_from([1, 2, 4]))
    k = pods * draw(st.integers(1, 2))
    over = draw(st.sampled_from(
        [(), ((r"embed", 0),), ((r"head", P - 1),),
         ((r"embed", P - 1), (r"stack_b", 0))]))
    drop = draw(st.lists(st.sampled_from([0.0, 1.0]), min_size=k,
                         max_size=k))
    return Hh, P, tau, pods, k, tuple(over), tuple(drop)


def _count_band(c, mk, p, band, m):
    add = np.broadcast_to(np.asarray(mk, np.float32), p.shape)
    sel = m[band].reshape((-1,) + (1,) * p.ndim)
    c = c.copy()
    c[band] += sel * add[None]
    return c


@given(_pod_cases())
@settings(max_examples=40, deadline=None)
def test_every_element_reduced_exactly_once_per_round(case):
    """Summed over one round's send events, every leaf element of every
    communicating replica enters exactly one fragment collective, and
    dropped replicas' elements enter none — per pod band, covering all
    k replicas exactly once."""
    Hh, P, tau, pods, k, over, drop = case
    params = _toy_tree()
    part = fragments.partition_params(params, P, overrides=over)
    sched = fragments.schedule(P, Hh, tau)

    sends = [e.fragment for _, acts in sched.phases
             for e in acts if e.kind == "send"]
    assert sorted(sends) == list(range(P))   # each fragment sends once

    k_loc = k // pods
    m = np.asarray(drop, np.float32)
    counts = jax.tree.map(
        lambda p: np.zeros((k,) + p.shape, np.float32), params)
    for pod in range(pods):
        band = slice(pod * k_loc, (pod + 1) * k_loc)
        for frag in sends:
            counts = jax.tree.map(
                lambda c, mk, p: _count_band(c, mk, p, band, m),
                counts, part.masks[frag], params)
    for c in jax.tree.leaves(counts):
        comm = m.reshape((k,) + (1,) * (c.ndim - 1))
        np.testing.assert_array_equal(
            c, np.broadcast_to(comm, c.shape))


@given(st.integers(1, 2000), st.integers(0, 2**31 - 1))
@settings(max_examples=60, deadline=None)
def test_pack_unpack_identity_on_code_grid(n, seed):
    """Nibble pack→unpack is the identity for every int4 code vector of
    every length — odd tails, sub-byte, sub-block, multi-block — so a
    packed transport can never corrupt a payload."""
    rng = np.random.default_rng(seed)
    codes = rng.integers(-7, 8, size=(n,)).astype(np.int8)
    packed = kref.pack_int4(jnp.asarray(codes))
    assert packed.shape == (-(-n // 2),)
    np.testing.assert_array_equal(
        np.asarray(kref.unpack_int4(packed, n)), codes)


@given(st.integers(1, 1500), st.integers(0, 2**31 - 1),
       st.sampled_from(["int4", "bfloat16"]), st.floats(1e-4, 1e4))
@settings(max_examples=40, deadline=None)
def test_wire_codec_decodes_to_sender_payload(n, seed, dt, scale):
    """wire_decode(wire_encode(x)) is bit-exact to the sender's own
    dequantized payload for arbitrary region lengths and magnitudes —
    codes ride the nibble grid, scales ride bit-cast f32, bf16 rides
    bit-cast uint16; nothing on the wire can shift a value."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray((scale * rng.normal(size=(n,))).astype(np.float32))
    wire, local = kops.wire_encode(x, dt, mode="ref")
    assert wire.shape[0] == kops.wire_elems(n, dt)
    dec = kops.wire_decode(wire, n, dt, mode="ref")
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(local))
    # int4 wire bytes match the packed accounting exactly
    if dt == "int4":
        assert wire.shape[0] == kops.transport_bytes(n, dt, packed=True)


@given(_pod_cases())
@settings(max_examples=40, deadline=None)
def test_issue_consume_schedule_property(case):
    """The double-buffered overlap schedule, as pure algebra: each
    round issues every fragment's collective exactly once and consumes
    it exactly once, exactly τ inner steps after its issue; a consume
    never races its own issue (non-wrapped consumes after the send,
    wrapped consumes the PREVIOUS round's buffer before the slot is
    re-issued); and τ=0 degenerates to the PR 2 simulated schedule —
    every apply rides the same sync instant as its send, nothing
    wraps."""
    Hh, P, tau, *_ = case
    sched = fragments.schedule(P, Hh, tau)

    # flatten one round into an ordered event list with positions
    order, step = [], 0
    for steps, acts in sched.phases:
        step += steps
        for e in acts:
            order.append((e.kind, e.fragment, e.wrapped, step))
    sends = {f: i for i, (kind, f, _, _) in enumerate(order)
             if kind == "send"}
    applies = {f: (i, w) for i, (kind, f, w, _) in enumerate(order)
               if kind == "apply"}
    assert sorted(sends) == list(range(P))
    assert sorted(applies) == list(range(P))

    for p in range(P):
        # consume lands exactly τ inner steps after the issue
        assert sched.apply_offsets[p] - sched.send_offsets[p] == tau
        i_apply, wrapped = applies[p]
        if wrapped:
            # τ pushed the consume past round end: it drains the
            # previous round's buffer BEFORE this round's re-issue
            # overwrites the slot
            assert sched.apply_offsets[p] > Hh
            assert i_apply < sends[p]
        else:
            assert sched.apply_offsets[p] <= Hh
            assert i_apply > sends[p]

    if tau == 0:
        assert not any(w for _, w in applies.values())
        # same sync instant, apply immediately after its own send
        for p in range(P):
            assert order[sends[p]][3] == order[applies[p][0]][3]


@given(st.integers(1, 600), st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_fused_quantize_pack_ragged_matches_ref(n, seed):
    """The fused one-pass quantize+nibble-pack kernel (interpret mode)
    is bitwise the ref pipeline for arbitrary region lengths — odd
    tails, sub-lane-pair, sub-block — i.e. the ragged fallback/padding
    inside the fused dispatch is byte-identical to ``ref.pack_int4``'s
    odd-tail pad."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    wire_r, loc_r = kops.wire_encode(x, "int4", mode="ref")
    wire_k, loc_k = kops.wire_encode(x, "int4", mode="interpret")
    np.testing.assert_array_equal(np.asarray(wire_r), np.asarray(wire_k))
    np.testing.assert_array_equal(np.asarray(loc_r), np.asarray(loc_k))
    np.testing.assert_array_equal(
        np.asarray(kops.wire_decode(wire_r, n, "int4", mode="ref")),
        np.asarray(kops.wire_decode(wire_r, n, "int4",
                                    mode="interpret")))


@given(st.integers(1, 6), st.integers(1, 8))
@settings(max_examples=30, deadline=None)
def test_partition_masks_tile_exactly_once(P, seed):
    """Fragment masks are a partition of unity on every leaf for any P
    (the per-element guarantee the reduce-once property builds on)."""
    params = _toy_tree()
    rng = np.random.default_rng(seed)
    over = ()
    if seed % 3 == 0:
        over = ((r"embed", int(rng.integers(P))),)
    part = fragments.partition_params(params, P, overrides=over)
    total = jax.tree.map(lambda p: np.zeros_like(p), params)
    for mk in part.masks:
        total = jax.tree.map(
            lambda t, q, p: t + np.broadcast_to(
                np.asarray(q, np.float32), p.shape),
            total, mk, params)
    for leaf in jax.tree.leaves(total):
        np.testing.assert_array_equal(leaf, np.ones_like(leaf))
