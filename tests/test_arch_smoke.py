"""Per-architecture smoke tests (assignment requirement).

For every assigned architecture: instantiate the REDUCED variant of the
same family (≤2 layers, d_model≤512, ≤4 experts — see each config's
``smoke_config``), run one forward/train step on CPU, assert output
shapes and absence of NaNs; additionally check that decode from a
prefilled cache reproduces the prefill logits (cache correctness) and
that one AdamW step decreases loss on a repeated batch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, TrainConfig
from repro.core import diloco
from repro.models.registry import get_smoke_arch, ARCH_NAMES

ASSIGNED = ARCH_NAMES[:10]
ALL = ARCH_NAMES


def _batch(arch, key, B=2, S=32):
    cfg = arch.cfg
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32)
    batch = {"tokens": toks}
    if cfg.family == "vlm":
        batch["patches"] = 0.1 * jax.random.normal(
            jax.random.fold_in(key, 1), (B, cfg.n_patches, cfg.d_model))
    if cfg.family == "encdec":
        batch["frames"] = 0.1 * jax.random.normal(
            jax.random.fold_in(key, 1), (B, cfg.n_frames, cfg.d_model))
    return batch


def test_smoke_configs_are_reduced():
    for name in ALL:
        cfg = get_smoke_arch(name).cfg
        assert cfg.n_layers <= 4, name
        assert cfg.d_model <= 512, name
        assert cfg.n_experts <= 4, name


@pytest.mark.parametrize("name", ALL)
def test_forward_shapes_and_finite(name):
    arch = get_smoke_arch(name)
    cfg = arch.cfg
    key = jax.random.PRNGKey(0)
    params, axes = arch.init(key, cfg)
    batch = _batch(arch, jax.random.PRNGKey(1))
    loss, metrics = arch.loss(params, batch)
    assert np.isfinite(float(loss)), name
    from repro.models import model as M
    logits, _, aux = M.forward(params, cfg, batch["tokens"], extra=batch)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.vocab_size), name
    assert np.all(np.isfinite(np.asarray(logits, np.float32))), name
    assert np.isfinite(float(aux)), name


@pytest.mark.parametrize("name", ALL)
def test_train_step_decreases_loss(name):
    arch = get_smoke_arch(name)
    tcfg = TrainConfig(inner_lr=3e-3, warmup_steps=0, total_steps=100,
                       batch_size=2, seq_len=32)
    step = diloco.make_single_worker_step(
        lambda p, b: arch.loss(p, b), tcfg)
    from repro.optim import adamw
    params, _ = arch.init(jax.random.PRNGKey(0), arch.cfg)
    opt = adamw.init(params)
    batch = _batch(arch, jax.random.PRNGKey(1))
    losses = []
    for i in range(5):
        params, opt, m = step(params, opt, batch, jnp.asarray(i))
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses)), name
    assert losses[-1] < losses[0], (name, losses)


@pytest.mark.parametrize("name", ALL)
def test_decode_matches_prefill(name):
    arch = get_smoke_arch(name)
    cfg = arch.cfg
    params, _ = arch.init(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(1)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S + 2), 0, cfg.vocab_size,
                              jnp.int32)
    batch = _batch(arch, key, B, S)
    batch["tokens"] = toks[:, :S]
    logits, cache = arch.prefill(params, batch, cache_len=S + 2)
    lg = []
    for i in range(2):
        step_logits, cache = arch.decode(
            params, cache, toks[:, S + i:S + i + 1],
            jnp.asarray(S + i, jnp.int32))
        lg.append(step_logits)
    full = dict(batch)
    full["tokens"] = toks
    logits_full, _ = arch.prefill(params, full, cache_len=S + 2)
    np.testing.assert_allclose(
        np.asarray(lg[0][:, 0], np.float32),
        np.asarray(logits_full[:, S], np.float32), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(
        np.asarray(lg[1][:, 0], np.float32),
        np.asarray(logits_full[:, S + 1], np.float32), rtol=2e-4,
        atol=2e-4)


@pytest.mark.parametrize("name", ["stablelm_1_6b", "zamba2_2_7b",
                                  "xlstm_350m"])
def test_sliding_window_decode(name):
    """Ring-buffer cache: decoding past the window stays finite and
    matches a windowed prefill recomputation."""
    arch = get_smoke_arch(name)
    cfg = arch.cfg.replace(window=8)
    params, _ = arch.init(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(3)
    B, S = 1, 24
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32)
    from repro.models import model as M
    # windowed cacheless forward over the whole sequence (oracle) —
    # prefill-through-a-window-sized-ring only guarantees logits of the
    # final window (earlier keys are evicted by design)
    logits_all, _, _ = M.forward(params, cfg, toks, window=8)
    # prefill 8, then decode the rest one-by-one through the ring cache
    logits_p, cache = M.prefill(params, cfg, toks[:, :8], window=8,
                                cache_len=S)
    errs = []
    for i in range(8, S):
        lg, cache = M.decode_step(params, cfg, cache, toks[:, i:i + 1],
                                  jnp.asarray(i, jnp.int32), window=8)
        errs.append(float(jnp.max(jnp.abs(
            lg[:, 0].astype(jnp.float32)
            - logits_all[:, i].astype(jnp.float32)))))
    assert max(errs) < 2e-4, (name, max(errs))


def test_moe_routes_to_multiple_experts():
    arch = get_smoke_arch("olmoe_1b_7b")
    cfg = arch.cfg
    params, _ = arch.init(jax.random.PRNGKey(0), cfg)
    batch = _batch(arch, jax.random.PRNGKey(1), B=4, S=64)
    loss, metrics = arch.loss(params, batch)
    # Switch-style aux floor is K (frac sums to K over experts);
    # balanced-ish routing at init keeps it near the floor
    K = cfg.top_k
    assert 0.9 * K < float(metrics["aux"]) < 2.0 * K


def test_ssm_chunked_vs_recurrent():
    """Mamba2 chunked SSD (train) == step-by-step recurrence (decode)."""
    from repro.models import ssm
    arch = get_smoke_arch("zamba2_2_7b")
    cfg = arch.cfg
    key = jax.random.PRNGKey(0)
    p, _ = jax.tree.flatten({})[1], None
    from repro.sharding.spec import unbox
    params_boxed = ssm.init_mamba2(key, cfg)
    params = jax.tree.map(lambda b: b.value, params_boxed,
                          is_leaf=lambda x: hasattr(x, "axes"))
    B, T = 2, 16
    x = 0.1 * jax.random.normal(jax.random.fold_in(key, 1),
                                (B, T, cfg.d_model))
    y_chunk, _ = ssm.apply_mamba2(params, x, cfg)
    st, tail = ssm.init_mamba2_state(cfg, B)
    ys = []
    for t in range(T):
        y1, (st, tail) = ssm.apply_mamba2(params, x[:, t:t + 1], cfg,
                                          state=st, conv_tail=tail)
        ys.append(y1)
    y_rec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_rec),
                               rtol=2e-4, atol=2e-4)


def test_input_specs_cover_all_shapes():
    for name in ASSIGNED:
        from repro.models.registry import get_arch
        arch = get_arch(name)
        for sname, shape in SHAPES.items():
            specs = arch.input_specs(shape)
            assert "tokens" in specs
            B = shape.global_batch
            if shape.kind == "decode":
                assert specs["tokens"].shape == (B, 1)
            else:
                assert specs["tokens"].shape == (B, shape.seq_len)
