"""Continuous batching for the serving path.

vLLM-style slot scheduler on top of the registry's prefill/decode
entry points: a fixed pool of B slots decodes in ONE batched
`decode_step` per tick; finished slots are refilled from the request
queue without stalling the others.

Alignment trick (keeps the batched ring cache simple): all slots share
one global clock `t`. A request with prompt length L admitted at tick t
is prefilled at absolute positions [t−L, t) — RoPE and sliding-window
masks depend only on RELATIVE positions, so each request's logits are
identical to running it in isolation (tested). The per-slot cache
position tracks (`pos` rows, -1 = empty) guarantee a fresh request
never attends to its slot's previous occupant. The clock only warms up
(jumps forward to fit a long prompt) while NO slot is active: jumping
it mid-run would open a position gap in every incumbent's ring, so
too-long prompts are deferred until the advancing clock reaches them.

Two cache layouts behind the same scheduler:

  contiguous (paged=False)  the seed layout: every slot owns a full
      (C,)-long ring row; admission host-edits the row via a
      ``dynamic_update_slice`` tree-map.
  paged (paged=True, default)  fixed-size pages in ONE shared pool per
      layer group + a per-slot page table (``models/model.py``
      ``init_paged_cache``): short requests only occupy the pages their
      positions touch, and admission is a page-table edit plus a jitted
      prefill that scatters K/V straight into the pool. Outputs are
      bit-identical to the contiguous layout (the gathered dense view
      reconstructs the exact ring; tested across families).

The per-tick step (decode + sample) is ONE jitted call with a donated
cache carry; the host syncs once per tick on the (B,) sampled tokens
instead of per-slot ``int()`` pulls.

Works for rotary/window/SSM families (position-translation-invariant);
absolute-position models (whisper's learned embeddings) are rejected.
"""
from __future__ import annotations

import collections
import functools
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (L,) int32
    max_new: int
    out: list = field(default_factory=list)
    done: bool = False
    submit_tick: int = -1
    finish_tick: int = -1


def _leaf_name(path) -> str:
    last = path[-1]
    return str(getattr(last, "key", getattr(last, "name", last)))


class ContinuousBatcher:
    """Slot-based continuous batching engine.

    engine = ContinuousBatcher(arch, params, slots=4, cache_len=256)
    engine.submit(prompt_tokens, max_new=32) -> rid
    engine.run_until_drained() -> {rid: np.ndarray(generated)}

    ``paged=True`` (default) uses the paged KV cache; ``page_size``
    must divide the effective ring length, ``n_pages`` defaults to full
    provisioning (slots * pages_per_slot — admission never waits).
    ``packed_weights`` = ``checkpoint.load_packed(...)`` result serves
    int4-packed weights: the jitted steps take the uint8 buffers as
    their weight argument and dequantize in-graph (requires paged mode;
    ``params`` then only supplies structure/shapes — ShapeDtypeStructs
    are enough).
    """

    def __init__(self, arch, params, *, slots: int, cache_len: int,
                 temperature: float = 0.0, seed: int = 0,
                 paged: bool = True, page_size: int = 16,
                 n_pages: int | None = None, packed_weights=None):
        self.arch = arch
        self.cfg = arch.cfg
        if self.cfg.pos_emb == "learned":
            raise ValueError(
                "continuous batching requires translation-invariant "
                "positions (rope/none); learned absolute embeddings "
                "break the shared-clock alignment")
        self.B = slots
        self.C = cache_len
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self.queue: collections.deque[Request] = collections.deque()
        self.active: list[Request | None] = [None] * slots
        self.remaining = np.zeros(slots, np.int64)
        self.last_tok = np.zeros(slots, np.int64)
        self._next_rid = 0
        self.clock = 0
        self.ticks = 0
        self.paged = paged
        # effective attention-ring length (windowed configs cap it)
        self.C_eff = min(cache_len, self.cfg.window) \
            if self.cfg.window else cache_len

        if packed_weights is not None and not paged:
            raise ValueError("packed int4 weight serving requires the "
                             "paged engine (jitted prefill)")
        if packed_weights is not None:
            from repro.checkpoint import checkpoint as ckpt
            man = packed_weights["manifest"]
            shapes = jax.tree.map(
                lambda l: jax.ShapeDtypeStruct(
                    np.shape(l), getattr(l, "dtype", jnp.float32)),
                params)
            self._weights = {k: jnp.asarray(v) for k, v
                             in packed_weights["buffers"].items()}
            self._make_params = functools.partial(
                ckpt.unpack_params, manifest=man, example_tree=shapes)
        else:
            self._weights = params
            self._make_params = lambda w: w

        if paged:
            self.page_size = page_size
            if self.C_eff % page_size:
                raise ValueError(
                    f"cache_len (effective {self.C_eff}) must be a "
                    f"multiple of page_size={page_size}")
            self.pages_per_slot = self.C_eff // page_size
            self.n_pages = n_pages or slots * self.pages_per_slot
            self.cache = M.init_paged_cache(
                self.cfg, slots, cache_len, jnp.float32,
                page_size=page_size, n_pages=self.n_pages,
                window=self.cfg.window)
            self.table = np.full((slots, self.pages_per_slot), -1,
                                 np.int32)
            self.free_pages: collections.deque[int] = collections.deque(
                range(self.n_pages))
            self.slot_pages: list[list[int]] = [[] for _ in range(slots)]
            self._jit_prefill_cache: dict[int, Callable] = {}
        else:
            self.cache = M.init_cache(self.cfg, slots, cache_len,
                                      jnp.float32, window=self.cfg.window)
        self._jit_step = self._make_step()
        self.finished: dict[int, np.ndarray] = {}
        self.latencies: dict[int, int] = {}      # rid -> ticks-to-finish

    # ---- public API ----
    def submit(self, prompt, max_new: int) -> int:
        rid = self._next_rid
        self._next_rid += 1
        if self.paged:
            # worst-case alignment: an unaligned start straddles one
            # extra page. Deferring such a request would deadlock, so
            # reject it up front.
            need = self._pages_for_span(self.page_size - 1,
                                        len(prompt) + max_new)
            if len(need) > self.n_pages:
                raise ValueError(
                    f"request spans {len(need)} pages but the pool has "
                    f"{self.n_pages}; raise n_pages or cache_len")
        self.queue.append(Request(rid, np.asarray(prompt, np.int64),
                                  max_new, submit_tick=self.ticks))
        return rid

    def run_until_drained(self, max_ticks: int = 100_000):
        for _ in range(max_ticks):
            if not self.queue and all(r is None for r in self.active):
                break
            self.tick()
        return dict(self.finished)

    # ---- sampling ----
    def _sample_host(self, logits_last):
        """First-token sampling at admission (host side, tiny)."""
        if self.temperature > 0:
            self.key, sub = jax.random.split(self.key)
            return int(jax.random.categorical(
                sub, logits_last / self.temperature, -1)[0])
        return int(jnp.argmax(logits_last[0]))

    # ---- fused decode+sample tick step ----
    def _make_step(self):
        temp = self.temperature
        cfg = self.cfg
        make_params = self._make_params

        def step(weights, cache, table, toks, pos, key):
            params = make_params(weights)
            logits, cache = M.decode_step(
                params, cfg, cache, toks[:, None], pos,
                window=cfg.window, page_table=table)
            if temp > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, logits[:, -1] / temp,
                                             -1)
            else:
                nxt = jnp.argmax(logits[:, -1], -1)
            return nxt.astype(jnp.int32), cache, key

        return jax.jit(step, donate_argnums=(1,))

    # ---- contiguous admission (seed layout, host-side row edit) ----
    # cache leaves are (layer_groups, batch, ...): batch is axis 1
    def _row(self, tree, i):
        return jax.tree.map(lambda a: a[:, i:i + 1], tree)

    def _set_row(self, tree, row, i):
        return jax.tree.map(
            lambda a, r: jax.lax.dynamic_update_slice(
                a, r.astype(a.dtype), (0, i) + (0,) * (a.ndim - 2)),
            tree, row)

    def _blank_row(self):
        return M.init_cache(self.cfg, 1, self.C, jnp.float32,
                            window=self.cfg.window)

    def _admit_contiguous(self, slot: int, req: Request):
        L = len(req.prompt)
        start = self.clock - L          # prompt occupies [t-L, t)
        assert start >= 0, "advance the clock before admitting"
        row = self._set_row(self.cache, self._blank_row(), slot)
        row_cache = self._row(row, slot)
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        logits, row_cache, _ = M.forward(
            self._make_params(self._weights), self.cfg, toks,
            cache=row_cache, cache_pos=jnp.asarray(start, jnp.int32),
            window=self.cfg.window or None)
        self.cache = self._set_row(row, row_cache, slot)
        return logits[:, -1]

    # ---- paged admission (page-table edit + jitted pool prefill) ----
    def _pages_for_span(self, start: int, span: int) -> list[int]:
        """Logical ring pages touched by positions [start, start+span)."""
        C, ps = self.C_eff, self.page_size
        if span >= C:
            return list(range(self.pages_per_slot))
        pages, seen = [], set()
        for p in range(start, start + span):
            lp = (p % C) // ps
            if lp not in seen:
                seen.add(lp)
                pages.append(lp)
        return pages

    def _free_slot_pages(self, slot: int):
        for pg in self.slot_pages[slot]:
            self.free_pages.append(pg)
        self.slot_pages[slot] = []
        self.table[slot] = -1

    def _make_prefill(self, L: int):
        """Jitted prefill for prompt length L (cached per L): clears the
        position tracks of the slot's freshly-mapped pages, runs the
        forward over a blank per-slot row view with the pool leaves
        shared, and merges per-slot rows back — all in one compiled
        call with a donated cache."""
        cfg = self.cfg
        make_params = self._make_params
        paged_names = M.PAGED_LEAF_NAMES

        def prefill(weights, cache, toks, slot, start, tbl_row, reset):
            params = make_params(weights)

            def clear(path, a):
                if _leaf_name(path) == "posp":
                    # (n_groups, n_pages, psize): wipe reused pages
                    return a.at[:, reset].set(-1, mode="drop")
                return a

            cache = jax.tree_util.tree_map_with_path(clear, cache)

            def row_view(path, a):
                name = _leaf_name(path)
                if name in paged_names:
                    return a               # shared pool, passed whole
                blank_shape = a.shape[:1] + (1,) + a.shape[2:]
                if name == "pos":          # per-slot ring tracks
                    return jnp.full(blank_shape, -1, a.dtype)
                return jnp.zeros(blank_shape, a.dtype)

            row = jax.tree_util.tree_map_with_path(row_view, cache)
            logits, row, _ = M.forward(
                params, cfg, toks, cache=row, cache_pos=start,
                window=cfg.window or None, page_table=tbl_row)

            def merge(path, full, r):
                if _leaf_name(path) in paged_names:
                    return r               # pool was updated in place
                return jax.lax.dynamic_update_slice(
                    full, r.astype(full.dtype),
                    (0, slot) + (0,) * (full.ndim - 2))

            cache = jax.tree_util.tree_map_with_path(merge, cache, row)
            return logits[:, -1], cache

        return jax.jit(prefill, donate_argnums=(1,))

    def _admit_paged(self, slot: int, req: Request):
        """Map pages + jitted prefill. Returns the (1, V) last-position
        logits, or None if the pool lacks free pages right now."""
        L = len(req.prompt)
        start = self.clock - L
        assert start >= 0, "advance the clock before admitting"
        lps = self._pages_for_span(start, L + req.max_new)
        if len(lps) > len(self.free_pages):
            return None
        new_pages = [self.free_pages.popleft() for _ in lps]
        self.slot_pages[slot] = list(new_pages)
        self.table[slot] = -1
        self.table[slot, lps] = new_pages
        # fixed-size reset vector (out-of-range sentinel pads) so one
        # compiled prefill serves any admission of this prompt length
        reset = np.full(self.pages_per_slot, self.n_pages, np.int32)
        reset[:len(new_pages)] = new_pages
        fn = self._jit_prefill_cache.get(L)
        if fn is None:
            fn = self._jit_prefill_cache[L] = self._make_prefill(L)
        logits_last, self.cache = fn(
            self._weights, self.cache,
            jnp.asarray(req.prompt, jnp.int32)[None],
            jnp.asarray(slot, jnp.int32),
            jnp.asarray(start, jnp.int32),
            jnp.asarray(self.table[slot:slot + 1]),
            jnp.asarray(reset))
        return logits_last

    # ---- slot lifecycle ----
    def _finish(self, slot: int):
        req = self.active[slot]
        req.done = True
        req.finish_tick = self.ticks
        self.finished[req.rid] = np.asarray(req.out, np.int64)
        self.latencies[req.rid] = max(req.finish_tick - req.submit_tick,
                                      1)
        self.active[slot] = None
        if self.paged:
            self._free_slot_pages(slot)

    def tick(self):
        # 1. admit pending requests into free slots. The clock may only
        #    warm up while NOTHING is active (bug fix: a mid-run jump
        #    leaves a position gap in every incumbent's ring — wrong
        #    relative distances from that tick on). Too-long prompts are
        #    deferred; the clock advances one per tick, so they admit as
        #    soon as it catches up. First-fit among admissible keeps
        #    short requests flowing past a deferred long one.
        for i in range(self.B):
            if self.active[i] is not None or not self.queue:
                continue
            any_active = any(r is not None for r in self.active)
            pick = None
            for qi, req in enumerate(self.queue):
                if any_active and len(req.prompt) > self.clock:
                    continue               # would need a clock jump
                pick = qi
                break
            if pick is None:
                break
            req = self.queue[pick]
            if len(req.prompt) > self.clock:
                self.clock = len(req.prompt)   # warm-up: pool is idle
            if self.paged:
                logits_last = self._admit_paged(i, req)
                if logits_last is None:    # pool full: retry next tick
                    break
                del self.queue[pick]
            else:
                del self.queue[pick]
                logits_last = self._admit_contiguous(i, req)
            self.active[i] = req
            self.remaining[i] = req.max_new
            first = self._sample_host(logits_last)
            self.last_tok[i] = first
            req.out.append(first)
            self.remaining[i] -= 1
            # bug fix: a max_new=1 request is DONE after its prefill
            # token — finish before the batched decode appends another
            if self.remaining[i] <= 0:
                self._finish(i)
        if all(r is None for r in self.active):
            self.ticks += 1
            return
        # 2. one fused decode+sample step for every slot (empty slots
        #    decode garbage — masked by their pos tracks / dropped by
        #    their unmapped page tables — and are discarded below)
        toks = jnp.asarray(self.last_tok, jnp.int32)
        table = jnp.asarray(self.table) if self.paged else None
        nxt_dev, self.cache, self.key = self._jit_step(
            self._weights, self.cache, table, toks,
            jnp.asarray(self.clock, jnp.int32), self.key)
        self.clock += 1
        self.ticks += 1
        nxt = np.asarray(nxt_dev)          # the ONE host sync per tick
        # 3. bookkeeping per slot
        for i in range(self.B):
            req = self.active[i]
            if req is None:
                continue
            self.last_tok[i] = int(nxt[i])
            req.out.append(int(nxt[i]))
            self.remaining[i] -= 1
            if self.remaining[i] <= 0:
                self._finish(i)

    @property
    def utilization(self) -> float:
        return sum(r is not None for r in self.active) / self.B
