"""Unified run telemetry: one record schema for every transport.

The launch scripts used to keep five divergent history shapes
(pretrain / sync-round / async-event / gossip-round / benchmark rows),
each inventing its own keys and its own print lines. ``RunRecorder``
replaces them with one typed emitter per record kind:

  * ``pretrain(...)``   — single-worker warmup steps;
  * ``round(...)``      — one barrier-paced outer round (sync /
    streaming / sharded / gossip), fed from the scanned driver's
    stacked metrics at chunk boundaries;
  * ``async_event(...)``— one ``AsyncEngine`` event record (arrival /
    lost / leave / join), enriched in place.

Every record carries ``kind`` ("round" | "event"), ``phase``
("pretrain" | "diloco" | "diloco_async") and ``transport`` on top of
its measurement fields, so one consumer reads any run. Wire-byte
fields are accumulated into ``wire_bytes_total`` — the counter
``benchmarks/obs.py`` cross-checks against the HLO-measured cross-pod
bytes of the lowered round.

The recorder is HOST-ONLY by construction: it never launches device
work. The scanned driver hands it a stacked metrics tree once per
chunk via ``ingest_chunk`` (counted — the no-extra-device-syncs gate),
and every emitter takes already-materialized scalars. With the default
``log_format="text"`` the console lines are byte-identical to the
pre-recorder driver output; ``"json"`` emits one JSON object per line
instead.

``to_jsonable`` is the serialization audit: numpy scalars and (numpy
or jax) arrays in a record must not crash ``json.dump`` — they are
converted, not trusted to be Python types.
"""
from __future__ import annotations

import json

import numpy as np

SCHEMA_VERSION = 1


def to_jsonable(obj):
    """Recursively convert ``obj`` into plain JSON-dumpable Python:
    numpy scalars -> int/float/bool, numpy/jax arrays -> nested lists,
    tuples -> lists, dict keys -> str. Values already plain pass
    through unchanged (floats keep their bits — NaN stays NaN, the
    divergence marker, exactly as ``json.dump`` has always written
    it)."""
    if isinstance(obj, np.generic):
        return obj.item()
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, dict):
        return {str(k): to_jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [to_jsonable(v) for v in obj]
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    if hasattr(obj, "__array__"):      # jax.Array and friends
        return to_jsonable(np.asarray(obj))
    return str(obj)                    # last resort: never crash a dump


def _round_text(rec, rounds) -> str:
    """The sync/streaming/sharded/gossip progress line — byte-identical
    to the pre-recorder driver's print."""
    vl = rec["val_loss"]
    val_s = "   skip" if vl is None else \
        f"{vl:.4f} ppl={np.exp(vl):.2f}"
    return (f"[round {rec['round']}/{rounds}] "
            f"inner={rec['inner_loss']:.4f} val={val_s} "
            f"active={rec['active']}")


def _async_text(rec) -> str:
    """The async event line — byte-identical to the pre-recorder
    driver's print (including the trailing space of an eval-less
    arrival)."""
    if rec["event"] == "arrival":
        vs = (f"val={rec['val_loss']:.4f} ppl={rec['ppl']:.2f}"
              if "val_loss" in rec else "")
        return (f"[tick {rec['tick']}] worker {rec['worker']} "
                f"stale={rec['staleness']} w={rec['weight']:.3f} "
                f"inner={rec['inner_loss']:.4f} {vs}")
    return (f"[tick {rec['tick']}] {rec['event']} "
            f"worker {rec['worker']}")


class RunRecorder:
    """One run's telemetry: manifest + typed records + console lines.

    manifest    run-level facts: schema version, transport, the CLI
                config, the static wire plan
                (``attach_wire_plan``), the HLO-measured wire profile
                (``attach_hlo_profile``), free-form notes.
    records     the unified history — what ``--out`` serializes and
                ``launch.train.run`` returns.
    log_format  "text" (default; byte-identical to the legacy console
                output) or "json" (one JSON object per line).
    printer     sink for console lines (tests/benchmarks pass a no-op).
    """

    def __init__(self, *, transport: str = "simulated",
                 log_format: str = "text", manifest: dict | None = None,
                 printer=print):
        if log_format not in ("text", "json"):
            raise ValueError(f"log_format must be 'text' or 'json', "
                             f"got {log_format!r}")
        self.transport = transport
        self.log_format = log_format
        self._print = printer
        self.manifest: dict = {"schema": SCHEMA_VERSION,
                               "transport": transport}
        if manifest:
            self.manifest.update(manifest)
        self.records: list = []
        self.wire_bytes_total: float = 0.0
        self.ingest_calls: int = 0

    # ---- console plumbing ----

    def _say(self, text: str, rec: dict | None = None):
        if self.log_format == "json":
            self._print(json.dumps(to_jsonable(
                rec if rec is not None else {"note": text})), flush=True)
        else:
            self._print(text, flush=True)

    def note(self, text: str, **fields):
        """A status line that is not a measurement (transport headers,
        output paths, timings). Printed, and kept in the manifest —
        NOT in the record history."""
        self.manifest.setdefault("notes", []).append(
            {"note": text, **fields} if fields else {"note": text})
        self._say(text, {"note": text, **fields})

    # ---- typed record emitters ----

    def _emit(self, rec: dict, text: str) -> dict:
        self.records.append(rec)
        self.wire_bytes_total += float(rec.get("wire_bytes") or 0.0)
        self._say(text, rec)
        return rec

    def pretrain(self, *, step: int, loss, val_loss) -> dict:
        rec = {"kind": "round", "phase": "pretrain",
               "transport": self.transport, "inner_steps": int(step),
               "inner_loss": float(loss), "val_loss": float(val_loss)}
        return self._emit(rec, f"[pretrain {step}] "
                               f"loss={float(loss):.4f} "
                               f"val={float(val_loss):.4f}")

    def round(self, *, round: int, rounds: int, inner_steps: int,
              inner_loss, val_loss, outer_gnorm, active: int,
              dropped: int | None = None, wire_bytes=None,
              gossip_edges=None, extras: dict | None = None,
              evaled: bool = True) -> dict:
        """One outer round of a barrier-paced transport. ``evaled``
        False marks a round the eval cadence skipped (val_loss is
        recorded as None, never as a stale number)."""
        rec = {"kind": "round", "phase": "diloco",
               "transport": self.transport, "round": int(round),
               "inner_steps": int(inner_steps),
               "inner_loss": float(inner_loss),
               "val_loss": None if not evaled else float(val_loss),
               "outer_gnorm": float(outer_gnorm), "active": int(active)}
        if dropped is not None:
            rec["dropped"] = int(dropped)
        if wire_bytes is not None:
            rec["wire_bytes"] = float(wire_bytes)
        if gossip_edges is not None:
            rec["gossip_edges"] = [list(e) for e in gossip_edges]
        if extras:
            rec.update({k: float(v) for k, v in extras.items()})
        return self._emit(rec, _round_text(rec, rounds))

    def guard_event(self, *, action: str, round: int,
                    **fields) -> dict:
        """One anomaly-guard verdict (``resilience.guard``): a spike /
        non-finite detection, a rollback, or a skipped round. Pure
        host-side bookkeeping — emitting it touches no device value."""
        rec = {"kind": "event", "phase": "guard",
               "transport": self.transport, "event": action,
               "round": int(round), **fields}
        detail = " ".join(f"{k}={v}" for k, v in fields.items())
        return self._emit(
            rec, f"[guard] {action} round={int(round)} {detail}".rstrip())

    def async_event(self, rec: dict) -> dict:
        """Ingest one ``AsyncEngine`` event record (already keyed by
        ``event``/``tick``/``worker``), stamping the unified kind /
        phase / transport fields in place."""
        rec = {"kind": "event", "phase": "diloco_async",
               "transport": self.transport, **rec}
        return self._emit(rec, _async_text(rec))

    # ---- device boundary ----

    def ingest_chunk(self, stacked_metrics):
        """Materialize one chunk's stacked device metrics as a numpy
        tree — the recorder's ONLY contact with device values. One call
        per scanned chunk; ``ingest_calls`` counts them, which is how
        ``benchmarks/obs.py`` gates that recording adds no device
        syncs beyond the chunk boundaries the driver already pays."""
        import jax
        self.ingest_calls += 1
        return jax.tree.map(np.asarray, stacked_metrics)

    # ---- manifest attachments ----

    def attach_wire_plan(self, plan):
        """Static per-fragment outer-sync plan (see
        ``streaming.sync_plan`` / ``diloco.outer_wire_bytes``): what
        the transport is *scheduled* to ship each round."""
        self.manifest["wire_plan"] = [dict(p) for p in plan]

    def attach_hlo_profile(self, profile: dict, fn: str = "round"):
        """HLO-measured wire profile of the lowered program (see
        ``hlo_analysis.wire_profile``): what the compiled collective
        program REALLY ships — the trace's byte annotations are
        cross-checked against this."""
        self.manifest.setdefault("hlo_profile", {})[fn] = dict(profile)

    # ---- output ----

    @property
    def history(self) -> list:
        return self.records

    def round_records(self) -> list:
        return [r for r in self.records if r["kind"] == "round"
                and r["phase"] != "pretrain"]

    def event_records(self) -> list:
        return [r for r in self.records if r["kind"] == "event"]

    def payload(self, *, args: dict | None = None) -> dict:
        """The serializable run bundle: superset of the legacy
        ``{"args", "history"}`` shape plus the manifest."""
        return to_jsonable({"args": args, "manifest": self.manifest,
                            "history": self.records})

    def dump(self, path: str, *, args: dict | None = None) -> str:
        with open(path, "w") as f:
            json.dump(self.payload(args=args), f, indent=1)
        return path
