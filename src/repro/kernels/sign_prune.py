"""Per-neuron sign pruning of outer gradients — Pallas TPU kernel.

Table 6: pruning 50% of outer-gradient values before averaging costs
+0.39% perplexity, halving DiLoCo's (already rare) communication. The
fused kernel runs right before the cross-pod all-reduce: one VMEM pass
per row-tile performs (1) sign election by magnitude mass, (2) a
fixed-iteration bisection for the per-row magnitude threshold (a
quantile is not a single-pass operation; bisection over the count is,
and matches ``ref.sign_prune`` exactly), (3) the mask-and-zero.

Rows of a weight matrix = neurons; each tile holds ``block_rows``
complete rows so the row-reductions stay tile-local.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import compat


def _prune_kernel(x_ref, o_ref, *, keep_count, valid_cols, iters):
    x = x_ref[...].astype(jnp.float32)                        # (br, C)
    col = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    valid = col < valid_cols
    x = jnp.where(valid, x, 0.0)
    mag = jnp.abs(x)

    pos = jnp.sum(jnp.where(x > 0, mag, 0.0), -1, keepdims=True)
    neg = jnp.sum(jnp.where(x < 0, mag, 0.0), -1, keepdims=True)
    elected = jnp.where(pos >= neg, 1.0, -1.0)
    agrees = jnp.sign(x) == elected

    lo = jnp.zeros((x.shape[0], 1), jnp.float32)
    hi = jnp.max(mag, axis=-1, keepdims=True) * (1.0 + 1e-6) + 1e-30

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((mag >= mid).astype(jnp.int32), -1, keepdims=True)
        too_many = cnt > keep_count
        return jnp.where(too_many, mid, lo), jnp.where(too_many, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    keep = agrees & (mag >= hi)
    o_ref[...] = jnp.where(keep, x_ref[...],
                           jnp.zeros_like(x_ref[...]))


def sign_prune(x, frac: float, *, block_rows: int = 64,
               iters: int = 26, interpret: bool = False):
    """x: (R, C) — per-row sign-consistent magnitude pruning.

    Matches ``ref.sign_prune`` bit-for-bit (same election, same
    bisection). Columns are padded to a multiple of 128 for lane
    alignment; padding never survives (masked to zero).
    """
    if frac <= 0:
        return x
    R, C = x.shape
    keep_count = max(int(round((1.0 - frac) * C)), 1)
    C_p = -(-C // 128) * 128
    br = min(block_rows, R)
    R_p = -(-R // br) * br
    xp = jnp.pad(x, ((0, R_p - R), (0, C_p - C)))

    out = pl.pallas_call(
        functools.partial(_prune_kernel, keep_count=keep_count,
                          valid_cols=C, iters=iters),
        grid=(R_p // br,),
        in_specs=[pl.BlockSpec((br, C_p), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, C_p), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R_p, C_p), x.dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(xp)
    return out[:R, :C]
