"""Figures 10 & 11: cosine similarity between replicas' outer gradients.

Tracks the mean/std pairwise cosine of the k outer gradients per round
for i.i.d. vs non-i.i.d. shards and for k=4 vs k=8. Expectations:
i.i.d. similarity >> non-i.i.d. similarity (Fig 10) and similarity
decreases with more non-i.i.d. shards (Fig 11)."""
from __future__ import annotations

import numpy as np

from . import common as C


def run(scale: int = 1):
    p = dict(C.DEFAULTS)
    rounds = 12 * scale
    rows = []
    for regime in ("iid", "non_iid"):
        arch, loss_fn, base_sampler = C.make_setup(regime, k=8)
        for k in (4, 8):
            # fixed 8-shard process regrouped among k workers (k=4
            # workers each hold a 2-shard mixture -> more similar
            # outer grads than 8 single-shard workers, as in Fig 11)
            sampler = base_sampler.regroup(k)
            params0, pre = C.pretrain(
                arch, loss_fn, sampler, p["pretrain"], batch=p["batch"],
                seq=p["seq"], lr=p["inner_lr"], warmup=p["warmup"],
                total=p["pretrain"] + rounds * p["H"])
            h, _ = C.run_diloco(arch, loss_fn, sampler, params0, k=k,
                                H=p["H"], rounds=rounds, step0=pre,
                                cosine_stats=True, batch=p["batch"],
                                seq=p["seq"])
            cs = [r["cos_mean"] for r in h]
            rows.append(dict(regime=regime, k=k,
                             cos_mean=float(np.mean(cs)),
                             cos_last=cs[-1], curve=h))
    cm = {(r["regime"], r["k"]): r["cos_mean"] for r in rows}
    payload = {"rows": rows,
               "claims": {
                   "iid_more_similar_than_noniid":
                       cm[("iid", 8)] > cm[("non_iid", 8)],
                   "more_noniid_shards_less_similar":
                       cm[("non_iid", 8)] <= cm[("non_iid", 4)] + 0.02}}
    C.save("fig10_cosine_similarity", payload)
    return payload


if __name__ == "__main__":
    out = run()
    for r in out["rows"]:
        print(f"{r['regime']:8s} k={r['k']} cos_mean={r['cos_mean']:.4f}")
    print(out["claims"])
