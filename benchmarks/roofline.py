"""Roofline report: aggregates the dry-run JSON records into the
EXPERIMENTS.md §Roofline table.

Per (arch × shape × mesh × fn): compute/memory/collective terms in
seconds, the dominant term, MODEL_FLOPS = 6·N_active·D (2·N_active·D
for inference) and the useful-compute ratio MODEL_FLOPS / HLO_FLOPs.
"""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                          "dryrun")


def load_records(pattern: str = "*.json", include_opt: bool = True):
    recs = []
    dirs = [DRYRUN_DIR]
    if include_opt:
        dirs.append(DRYRUN_DIR + "_opt")
    for d in dirs:
        for path in sorted(glob.glob(os.path.join(d, pattern))):
            try:
                data = json.load(open(path))
            except Exception:
                continue
            for r in data:
                if "error" in r:
                    continue
                r["optimized"] = (d.endswith("_opt")
                                  or bool(r.get("variant")))
                recs.append(r)
    # dedupe on (arch, shape, mesh, fn, variant), keeping the latest
    seen = {}
    for r in recs:
        seen[(r["arch"], r["shape"], r.get("mesh"), r["fn"],
              str(r.get("variant", {})))] = r
    return list(seen.values())


def one_sentence(rec) -> str:
    b = rec["roofline"]["bound"]
    if b == "collective_s":
        cross = rec["collectives"]["cross_pod_bytes"]
        if cross and cross > rec["collectives"]["intra_pod_bytes"]:
            return ("cross-pod traffic dominates - raise H (DiLoCo) or "
                    "overlap the outer all-reduce")
        return ("intra-pod collectives dominate - fewer/larger FSDP "
                "all-gathers (bigger microbatch) or 1D sharding")
    if b == "memory_s":
        return ("HBM-bound - fuse optimizer/elementwise passes, cast "
                "activations to bf16, or raise arithmetic intensity")
    return "MXU-bound - already near roofline; only algorithmic wins left"


def table(recs, *, fns=None) -> str:
    rows = []
    head = ("| arch | shape | mesh | fn | cfg | compute_s | memory_s | "
            "collective_s (x-pod) | bound | MF ratio | next lever |")
    sep = "|" + "---|" * 11
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"],
                                         str(r.get("mesh")), r["fn"],
                                         r.get("optimized", False))):
        if fns and r["fn"] not in fns:
            continue
        t = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r.get('mesh')} | {r['fn']} "
            f"| {'opt' if r.get('optimized') else 'base'} "
            f"| {t['compute_s']:.2e} | {t['memory_s']:.2e} "
            f"| {t['collective_s']:.2e} ({t['collective_cross_s']:.1e}) "
            f"| {t['bound'].replace('_s', '')} "
            f"| {t.get('model_flops_ratio', 0):.2f} "
            f"| {one_sentence(r)} |")
    return "\n".join([head, sep] + rows)


def summary(recs) -> dict:
    bounds = {}
    for r in recs:
        bounds[r["roofline"]["bound"]] = \
            bounds.get(r["roofline"]["bound"], 0) + 1
    worst = sorted(
        (r for r in recs if r["fn"] in ("inner_train_step", "prefill",
                                        "serve_step")),
        key=lambda r: r["roofline"].get("model_flops_ratio", 0))
    return {"n_records": len(recs), "bound_histogram": bounds,
            "worst_useful_compute": [
                (r["arch"], r["shape"], r["fn"],
                 round(r["roofline"].get("model_flops_ratio", 0), 3))
                for r in worst[:5]]}


def run(scale: int = 1):
    recs = load_records()
    payload = {"summary": summary(recs),
               "n_single_pod": sum(1 for r in recs if not r["multi_pod"]),
               "n_multi_pod": sum(1 for r in recs if r["multi_pod"])}
    md = table(recs)
    os.makedirs(os.path.join(DRYRUN_DIR, ".."), exist_ok=True)
    out_md = os.path.join(DRYRUN_DIR, "..", "roofline_table.md")
    with open(out_md, "w") as f:
        f.write(md + "\n")
    payload["table_path"] = os.path.abspath(out_md)
    from . import common as C
    C.save("roofline", payload)
    return payload


if __name__ == "__main__":
    out = run()
    print(json.dumps(out["summary"], indent=1))
    print("table:", out["table_path"])
