"""Continuous batching for the serving path.

vLLM-style slot scheduler on top of the registry's prefill/decode
entry points: a fixed pool of B slots decodes in ONE batched
`decode_step` per tick; finished slots are refilled from the request
queue without stalling the others.

Alignment trick (keeps the batched ring cache simple): all slots share
one global clock `t`. A request with prompt length L admitted at tick t
is prefilled at absolute positions [t−L, t) — RoPE and sliding-window
masks depend only on RELATIVE positions, so each request's logits are
identical to running it in isolation (tested). The per-slot cache
position tracks (`pos` rows, -1 = empty) guarantee a fresh request
never attends to its slot's previous occupant.

Works for rotary/window/SSM families (position-translation-invariant);
absolute-position models (whisper's learned embeddings) are rejected.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (L,) int32
    max_new: int
    out: list = field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    """Slot-based continuous batching engine.

    engine = ContinuousBatcher(arch, params, slots=4, cache_len=256)
    engine.submit(prompt_tokens, max_new=32) -> rid
    engine.run_until_drained() -> {rid: np.ndarray(generated)}
    """

    def __init__(self, arch, params, *, slots: int, cache_len: int,
                 temperature: float = 0.0, seed: int = 0):
        self.arch = arch
        self.cfg = arch.cfg
        if self.cfg.pos_emb == "learned":
            raise ValueError(
                "continuous batching requires translation-invariant "
                "positions (rope/none); learned absolute embeddings "
                "break the shared-clock alignment")
        self.params = params
        self.B = slots
        self.C = cache_len
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self.queue: collections.deque[Request] = collections.deque()
        self.active: list[Request | None] = [None] * slots
        self.remaining = np.zeros(slots, np.int64)
        self.last_tok = np.zeros(slots, np.int64)
        self._next_rid = 0
        self.clock = 0
        self.cache = M.init_cache(self.cfg, slots, cache_len,
                                  jnp.float32, window=self.cfg.window)
        self._jit_decode = jax.jit(
            lambda p, c, t, pos: arch.decode(p, c, t, pos))
        self.finished: dict[int, np.ndarray] = {}

    # ---- public API ----
    def submit(self, prompt, max_new: int) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, np.asarray(prompt, np.int64),
                                  max_new))
        return rid

    def run_until_drained(self, max_ticks: int = 100_000):
        for _ in range(max_ticks):
            if not self.queue and all(r is None for r in self.active):
                break
            self.tick()
        return dict(self.finished)

    # ---- engine ----
    # cache leaves are (layer_groups, batch, ...): batch is axis 1
    def _row(self, tree, i):
        return jax.tree.map(lambda a: a[:, i:i + 1], tree)

    def _set_row(self, tree, row, i):
        return jax.tree.map(
            lambda a, r: jax.lax.dynamic_update_slice(
                a, r.astype(a.dtype), (0, i) + (0,) * (a.ndim - 2)),
            tree, row)

    def _blank_row(self):
        one = M.init_cache(self.cfg, 1, self.C, jnp.float32,
                           window=self.cfg.window)
        return one

    def _admit(self, slot: int, req: Request):
        """Prefill ``req`` into ``slot`` at clock-aligned positions."""
        L = len(req.prompt)
        start = self.clock - L          # prompt occupies [t-L, t)
        assert start >= 0, "advance the clock before admitting"
        row = self._set_row(self.cache, self._blank_row(), slot)
        row_cache = self._row(row, slot)
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        logits, row_cache, _ = M.forward(
            self.params, self.cfg, toks, cache=row_cache,
            cache_pos=jnp.asarray(start, jnp.int32),
            window=self.cfg.window or None)
        self.cache = self._set_row(row, row_cache, slot)
        self.active[slot] = req
        self.remaining[slot] = req.max_new
        self.last_tok[slot] = int(jnp.argmax(logits[0, -1]))
        req.out.append(int(self.last_tok[slot]))
        self.remaining[slot] -= 1

    def tick(self):
        # 1. admit pending requests into free slots
        for i in range(self.B):
            if self.active[i] is None and self.queue:
                req = self.queue[0]
                if self.clock < len(req.prompt):
                    self.clock = len(req.prompt)   # warm up the clock
                self.queue.popleft()
                self._admit(i, req)
        if all(r is None for r in self.active):
            return
        # 2. one batched decode step for every slot (empty slots decode
        #    garbage into their own rows — masked by their pos tracks
        #    and discarded)
        toks = jnp.asarray(self.last_tok, jnp.int32)[:, None]
        logits, self.cache = self._jit_decode(
            self.params, self.cache, toks,
            jnp.asarray(self.clock, jnp.int32))
        self.clock += 1
        if self.temperature > 0:
            self.key, sub = jax.random.split(self.key)
            nxt = np.asarray(jax.random.categorical(
                sub, logits[:, -1] / self.temperature, -1))
        else:
            nxt = np.asarray(jnp.argmax(logits[:, -1], -1))
        # 3. bookkeeping per slot
        for i in range(self.B):
            req = self.active[i]
            if req is None:
                continue
            self.last_tok[i] = int(nxt[i])
            req.out.append(int(nxt[i]))
            self.remaining[i] -= 1
            if self.remaining[i] <= 0:
                req.done = True
                self.finished[req.rid] = np.asarray(req.out, np.int64)
                self.active[i] = None

    @property
    def utilization(self) -> float:
        return sum(r is not None for r in self.active) / self.B
