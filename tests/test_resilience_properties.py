"""Property: cutting a gossip run at ANY round boundary, pushing the
carry through a real on-disk CheckpointManager snapshot (wrap -> npz ->
manifest -> verify -> restore -> unwrap), and finishing the remaining
rounds is bit-identical to the uninterrupted run.

Gossip is the adversarial transport for this property: its random
pair matching draws from a PRNG folded per round (``gossip.PAIR_FOLD``
keyed by the round key chain and ``outer_t``), so the restore must
preserve not just the parameters but the exact point in the pairing
stream — any drift and the workers mix with the wrong partners forever
after.

The deterministic parametrized sweep always runs; when hypothesis is
installed it additionally fuzzes the (cut, seed) space and shrinks any
failing schedule."""
from __future__ import annotations

import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import DiLoCoConfig, TrainConfig
from repro.core import diloco, gossip
from repro.resilience import CheckpointManager, tree_sha256, unwrap, wrap

try:
    from hypothesis import given, settings, strategies as hst
    HAVE_HYPOTHESIS = True
except ImportError:          # container without hypothesis: the
    HAVE_HYPOTHESIS = False  # deterministic sweep below still runs

ROUNDS = 4
K = 4


def quad_loss(p, batch):
    t = batch["tokens"].astype(jnp.float32).mean() / 7.0
    return (jnp.sum((p["w"] - t) ** 2)
            + 0.1 * jnp.sum(jnp.square(p["b"]))), {}


def tiny_params():
    return {"w": jnp.arange(8.0) / 8.0, "b": jnp.ones((3,))}


def sample_all(k):
    def fn(key, B, S):
        return jax.random.randint(key, (k, B, S), 0, 7, jnp.int32)
    return fn


def make_cfgs():
    dcfg = DiLoCoConfig(k=K, H=2, transport="gossip",
                        streaming_fragments=2, outer_lr=0.3,
                        gossip_pairing="random")
    tcfg = TrainConfig(inner_lr=0.05, warmup_steps=2, total_steps=64,
                       batch_size=2, seq_len=4)
    return dcfg, tcfg


_RUNS: dict = {}


def get_run(n: int):
    """One compiled scanned driver per chunk size (donate off — the
    property reuses carries across both halves of the comparison)."""
    if n not in _RUNS:
        dcfg, tcfg = make_cfgs()
        _RUNS[n] = diloco.make_run(quad_loss, sample_all(K), dcfg, tcfg,
                                   rounds_per_call=n, total_steps=64,
                                   batch_size=2, seq_len=4,
                                   donate=False)
    return _RUNS[n]


def check_cut_and_restore(cut: int, seed: int):
    dcfg, _ = make_cfgs()
    key0 = jax.random.PRNGKey(seed)

    # uninterrupted reference: all ROUNDS in one chunk
    ref, ref_ms = get_run(ROUNDS)(gossip.init_state(tiny_params(), dcfg),
                                  key0, None, None, None)

    # cut run: `cut` rounds, snapshot to disk, restore, finish
    state, ms = get_run(cut)(gossip.init_state(tiny_params(), dcfg),
                             key0, None, None, None)
    tmp = tempfile.mkdtemp(prefix="res_prop_")
    try:
        mgr = CheckpointManager(tmp)
        env = wrap(state, ms["next_key"], cut)
        mgr.save(cut, env)
        assert mgr.latest_good() == cut
        state2, key2, rounds_done = unwrap(mgr.load(cut, env))
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    assert rounds_done == cut
    resumed, res_ms = get_run(ROUNDS - cut)(
        state2, key2, None, None, None,
        jnp.asarray(rounds_done, jnp.int32))

    # the resumed tail is bitwise the reference: state, key chain, and
    # the per-round inner losses of the suffix all agree exactly
    assert tree_sha256(resumed) == tree_sha256(ref)
    np.testing.assert_array_equal(np.asarray(res_ms["next_key"]),
                                  np.asarray(ref_ms["next_key"]))
    np.testing.assert_array_equal(
        np.asarray(res_ms["inner_loss"]),
        np.asarray(ref_ms["inner_loss"])[cut:])
    assert int(np.asarray(resumed.outer_t)) == ROUNDS


@pytest.mark.parametrize("cut", range(1, ROUNDS))
def test_gossip_cut_and_restore_every_boundary(cut):
    check_cut_and_restore(cut, seed=0)


def test_gossip_cut_and_restore_other_seed():
    # a different key chain exercises different random pairings
    check_cut_and_restore(2, seed=1234)


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(cut=hst.integers(1, ROUNDS - 1),
           seed=hst.integers(0, 2 ** 16))
    def test_gossip_cut_and_restore_fuzzed(cut, seed):
        check_cut_and_restore(cut, seed)
