"""Run telemetry subsystem (repro/obs): the unified record schema,
JSON safety of numpy/jax-valued histories, verbatim preservation of
the classic console lines, the Chrome trace builder + structural
validator, and the exactly-once correspondence between engine events
and trace transfer spans on a real (tiny) async run.

The expensive cross-checks (recorder-off bitwise identity against the
bare driver, HLO-measured wire bytes at ratio 1.000) live in
benchmarks/obs.py; this module keeps the schema and trace geometry
honest at unit-test speed.
"""
from __future__ import annotations

import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import DiLoCoConfig
from repro.core import diloco, faults, gossip, streaming
from repro.core.faults import Arrival, Lost, Scenario
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.metrics import RunRecorder, to_jsonable

from test_async_engine import make_engine, tiny_params


# ---------------------------------------------------------------------------
# to_jsonable: nothing the drivers produce may crash json.dump
# ---------------------------------------------------------------------------

def test_to_jsonable_numpy_and_jax_values():
    payload = {"f32": np.float32(1.5), "i64": np.int64(7),
               "arr": np.arange(3), "jax": jnp.ones((2,)),
               "nested": [{"b": np.bool_(True)}, (np.float16(2.0),)],
               "none": None, "s": "x"}
    out = json.loads(json.dumps(to_jsonable(payload)))
    assert out["f32"] == 1.5 and out["i64"] == 7
    assert out["arr"] == [0, 1, 2] and out["jax"] == [1.0, 1.0]
    assert out["nested"][0]["b"] is True
    assert out["none"] is None


def test_to_jsonable_handles_nan_and_foreign_objects():
    out = to_jsonable({"nan": float("nan"), "obj": object()})
    assert math.isnan(out["nan"])
    assert isinstance(out["obj"], str)


# ---------------------------------------------------------------------------
# RunRecorder: schema, text verbatim, json lines, notes
# ---------------------------------------------------------------------------

def _capture_recorder(**kw):
    lines = []
    rec = RunRecorder(printer=lambda s, **_: lines.append(s), **kw)
    return rec, lines


def test_round_text_is_the_classic_console_line():
    rec, lines = _capture_recorder()
    rec.round(round=3, rounds=20, inner_steps=150, inner_loss=5.1234,
              val_loss=4.5678, outer_gnorm=0.01, active=7)
    assert lines == [f"[round 3/20] inner=5.1234 "
                     f"val=4.5678 ppl={np.exp(4.5678):.2f} active=7"]
    rec.round(round=4, rounds=20, inner_steps=200, inner_loss=5.0,
              val_loss=4.0, outer_gnorm=0.01, active=7, evaled=False)
    assert lines[-1] == "[round 4/20] inner=5.0000 val=   skip active=7"
    assert rec.round_records()[-1]["val_loss"] is None


def test_json_log_format_emits_one_record_per_line():
    rec, lines = _capture_recorder(log_format="json")
    rec.pretrain(step=200, loss=np.float32(6.0), val_loss=5.9)
    rec.round(round=1, rounds=2, inner_steps=4, inner_loss=5.5,
              val_loss=5.4, outer_gnorm=0.1, active=4,
              wire_bytes=np.float64(1024.0))
    rec.note("done")
    parsed = [json.loads(s) for s in lines]
    assert parsed[0]["phase"] == "pretrain"
    assert parsed[1]["wire_bytes"] == 1024.0
    assert parsed[2] == {"note": "done"}
    # notes annotate the manifest, not the record history
    assert len(rec.records) == 2
    assert rec.manifest["notes"] == [{"note": "done"}]


def test_recorder_payload_roundtrips_with_jax_scalars():
    rec, _ = _capture_recorder(transport="gossip")
    rec.round(round=1, rounds=1, inner_steps=2,
              inner_loss=jnp.float32(5.0), val_loss=jnp.float32(4.9),
              outer_gnorm=jnp.float32(0.1), active=2,
              gossip_edges=((0, 1),),
              extras={"gossip_spread": np.float32(0.5)})
    out = json.loads(json.dumps(rec.payload(args={"k": 2})))
    assert out["history"][0]["gossip_edges"] == [[0, 1]]
    assert out["manifest"]["transport"] == "gossip"


def test_ingest_chunk_materializes_and_counts():
    rec, _ = _capture_recorder()
    ms = rec.ingest_chunk({"val_loss": jnp.arange(3.0)})
    assert isinstance(ms["val_loss"], np.ndarray)
    assert rec.ingest_calls == 1


# ---------------------------------------------------------------------------
# static wire accounting helpers
# ---------------------------------------------------------------------------

def test_sync_plan_charges_the_streaming_metric_bytes():
    params = tiny_params()
    dcfg = DiLoCoConfig(k=2, H=4, streaming_fragments=2, stream_tau=3)
    plan = streaming.sync_plan(params, dcfg)
    assert [row["fragment"] for row in plan] == [0, 1]
    assert all(row["apply_step"] == row["send_step"] + 3
               for row in plan)
    # tau pushes the last fragment's apply past H: the overlap window
    assert plan[1]["crosses_round"]
    total_elems = sum(int(x.size) for x in jax.tree.leaves(params))
    assert sum(row["elems"] for row in plan) == total_elems
    assert all(row["wire_bytes"] > 0 for row in plan)


def test_outer_wire_bytes_is_the_full_model_in_f32():
    params = tiny_params()
    dcfg = DiLoCoConfig(k=2, H=4)
    n = sum(int(x.size) for x in jax.tree.leaves(params))
    from repro.kernels.ops import transport_bytes
    assert diloco.outer_wire_bytes(params, dcfg) == \
        transport_bytes(n, "float32")


def test_pairing_edges_match_the_partner_map():
    # butterfly stage 0 on k=4: hypercube neighbours
    assert gossip.pairing_edges(4, 0, "butterfly") == ((0, 1), (2, 3))
    assert gossip.pairing_edges(4, 1, "butterfly") == ((0, 2), (1, 3))
    # random pairing is a function of the shared fold of the round key
    key = jax.random.PRNGKey(3)
    e1 = gossip.pairing_edges(4, 0, "random", round_key=key)
    assert e1 == gossip.pairing_edges(4, 0, "random", round_key=key)
    for i, j in e1:
        assert 0 <= i < j < 4
    with pytest.raises(ValueError):
        gossip.pairing_edges(4, 0, "random")


# ---------------------------------------------------------------------------
# trace builder + validator
# ---------------------------------------------------------------------------

def test_trace_builder_geometry_and_validation():
    tb = obs_trace.TraceBuilder()
    tb.process(1, "workers")
    tb.thread(1, 0, "worker 0")
    tb.thread(1, 0, "worker 0")            # dedup'd
    tb.span("inner", pid=1, tid=0, start=2, dur=3, cat="compute")
    tb.instant("arrival", pid=1, tid=0, tick=5)
    trace = tb.to_json()
    assert obs_trace.validate_trace(trace) == []
    metas = [e for e in trace["traceEvents"] if e["ph"] == "M"]
    assert len(metas) == 2
    span = next(e for e in trace["traceEvents"] if e["ph"] == "X")
    assert span["ts"] == 2 * obs_trace.TICK_US
    assert span["dur"] == 3 * obs_trace.TICK_US


def test_validate_trace_flags_malformed_events():
    good = obs_trace.TraceBuilder().to_json()
    assert obs_trace.validate_trace(good) == []
    assert obs_trace.validate_trace({"nope": 1})
    bad_ph = {"traceEvents": [{"name": "x", "ph": "Z", "pid": 0,
                               "tid": 0, "ts": 0.0}]}
    assert obs_trace.validate_trace(bad_ph)
    neg_ts = {"traceEvents": [{"name": "x", "ph": "i", "pid": 0,
                               "tid": 0, "ts": -1.0, "s": "t"}]}
    assert obs_trace.validate_trace(neg_ts)
    neg_dur = {"traceEvents": [{"name": "x", "ph": "X", "pid": 0,
                               "tid": 0, "ts": 0.0, "dur": -5.0}]}
    assert obs_trace.validate_trace(neg_dur)


def test_round_trace_structure_sync_and_streaming():
    k, rounds, H = 3, 4, 4
    history = [{"round": r + 1, "inner_loss": 5.0, "val_loss": 4.9,
                "outer_gnorm": 0.1, "active": k}
               for r in range(rounds)]
    plan = ({"fragment": 0, "send_step": 2, "apply_step": 3,
             "elems": 8, "wire_bytes": 32.0},
            {"fragment": 1, "send_step": 4, "apply_step": 5,
             "elems": 8, "wire_bytes": 32.0})
    tb = obs_trace.round_trace(transport="simulated", k=k,
                               rounds=rounds, H=H, history=history,
                               plan=plan)
    trace = tb.to_json()
    assert obs_trace.validate_trace(trace) == []
    spans = [e for e in trace["traceEvents"] if e["ph"] == "X"]
    rspans = [e for e in spans if e["pid"] == obs_trace.PID_ROUNDS]
    assert len(rspans) == rounds
    inner = [e for e in spans if e["pid"] == obs_trace.PID_WORKERS]
    assert len(inner) == rounds * k
    gathers = [e for e in spans if e["pid"] == obs_trace.PID_FRAGMENTS]
    assert len(gathers) == rounds * len(plan)
    # fragment 1's apply crosses the round boundary -> flagged
    assert all(e["args"]["crosses_round"] ==
               (e["args"]["fragment"] == 1) for e in gathers)
    assert obs_trace.trace_wire_bytes(trace) == rounds * 64.0


def test_round_trace_draws_gossip_exchanges_and_faults():
    scen = Scenario(speeds=(1, 2), latency=(0, 1),
                    preemptions=((1, 1, 2),))
    drops, acts = scen.round_masks(2, 3)
    tb = obs_trace.round_trace(
        transport="gossip", k=2, rounds=3, H=2, scenario=scen,
        drops=drops, acts=acts,
        gossip_rounds=[{"round": 0, "fragment": 0,
                        "edges": [[0, 1]]}])
    trace = tb.to_json()
    assert obs_trace.validate_trace(trace) == []
    names = [e["name"] for e in trace["traceEvents"]]
    assert "exchange" in names
    assert "preempted" in names
    # gossip ships pairwise exchanges, never an all-reduce send
    assert "outer send" not in names


# ---------------------------------------------------------------------------
# exactly-once: engine events <-> trace transfer spans
# ---------------------------------------------------------------------------

def _faulty_scenario():
    return Scenario(speeds=(1, 2, 1, 3), latency=(1, 1, 2, 1),
                    drop_prob=0.4, max_retries=1, retry_backoff=1,
                    preemptions=((2, 3, 6),), seed=7)


def test_async_trace_corresponds_exactly_once_to_engine_events():
    scen = _faulty_scenario()
    eng = make_engine(4, 2, scenario=scen, seed=1)
    rec, _ = _capture_recorder(transport="async")
    state = eng.init_state(tiny_params())
    state, hist = eng.run(state, ticks=9, recorder=rec)
    # the recorder saw every engine event, stamped with the schema keys
    assert [{k: v for k, v in r.items()
             if k not in ("kind", "phase", "transport")}
            for r in rec.event_records()] == list(hist)
    tb = obs_trace.async_trace(scen, 4, 9, history=hist,
                               wire_bytes=eng.wire_bytes())
    trace = tb.to_json()
    assert obs_trace.validate_trace(trace) == []
    assert obs_trace.span_event_correspondence(trace, hist) == []
    arrivals = [r for r in hist if r["event"] == "arrival"]
    delivered = [s for s in obs_trace.transfer_spans(trace)
                 if s["args"].get("delivered")]
    assert len(arrivals) == len(delivered) > 0
    assert obs_trace.trace_wire_bytes(trace) == \
        pytest.approx(sum(r["wire_bytes"] for r in arrivals))


def test_async_trace_timeline_only_matches_synthetic_records():
    """The trace is drawable from the timeline alone (no engine): its
    spans still biject with the timeline's terminal events."""
    scen = _faulty_scenario()
    k, ticks = 4, 8
    ev = scen.timeline(k, ticks)
    records = []
    for e in ev:
        if isinstance(e, Arrival):
            records.append({"event": "arrival", "uid": e.uid})
        elif isinstance(e, Lost):
            records.append({"event": "lost", "uid": e.uid})
    trace = obs_trace.async_trace(scen, k, ticks).to_json()
    assert obs_trace.validate_trace(trace) == []
    assert obs_trace.span_event_correspondence(trace, records) == []


def test_span_event_correspondence_catches_mismatches():
    scen = _faulty_scenario()
    ev = scen.timeline(4, 8)
    arrivals = [e for e in ev if isinstance(e, Arrival)]
    assert arrivals
    records = [{"event": "arrival", "uid": e.uid} for e in arrivals]
    trace = obs_trace.async_trace(scen, 4, 8).to_json()
    # a record the trace never drew
    assert obs_trace.span_event_correspondence(
        trace, records + [{"event": "arrival", "uid": 10_000}])
    # a span with no record
    assert obs_trace.span_event_correspondence(trace, records[:-1])


# ---------------------------------------------------------------------------
# CLI validator
# ---------------------------------------------------------------------------

def test_trace_cli_validates_files(tmp_path, capsys):
    good = tmp_path / "good.json"
    obs_trace.async_trace(Scenario.uniform(2), 2, 3).write(str(good))
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"traceEvents": [{"ph": "Q"}]}))
    assert obs_trace.main([str(good)]) == 0
    assert obs_trace.main([str(good), str(bad)]) == 1
    out = capsys.readouterr().out
    assert "[ok]" in out and "[INVALID]" in out


# ---------------------------------------------------------------------------
# dryrun manifest folding
# ---------------------------------------------------------------------------

def test_dryrun_manifest_of_folds_hlo_profiles():
    from repro.launch import dryrun
    records = [{"arch": "a", "shape": "s", "fn": "diloco_outer_step",
                "mesh": "2x2", "chips": 4,
                "collectives": {"cross_pod_bytes": 128.0,
                                "cross_by_op": {"all-reduce": 128.0}}},
               {"arch": "a", "shape": "s", "error": "boom"}]
    m = dryrun.manifest_of(records, config={"fns": "outer"})
    assert m["transport"] == "dryrun"
    prof = m["hlo_profile"]["a/s/diloco_outer_step"]
    assert prof["collectives"]["cross_pod_bytes"] == 128.0
    assert len(m["hlo_profile"]) == 1          # errors are not profiles
    json.dumps(obs_metrics.to_jsonable(m))
