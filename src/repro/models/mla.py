"""Multi-head Latent Attention (DeepSeek-V2).

Keys/values are compressed into a rank-``kv_lora_rank`` latent c_kv plus a
small decoupled-RoPE key shared across heads; only (c_kv, k_rope) is
cached — the cache is ~(r + dr)/(2·H·dh) the size of a dense GQA cache.

Decode uses the *absorbed* formulation: scores are computed directly in
latent space by folding W_uk into the query (q_eff = q_nope · W_uk), so
the per-step cost never up-projects the whole cache. The absorbed score
is exactly ⟨[q_eff; q_rope], [c_kv; k_rope]⟩ which lets us reuse the
generic chunked online-softmax `attention` with a single latent "head".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import (dense_init, zeros_init, ones_init, apply_norm,
                     apply_rope, attention)


def init_mla(key, cfg):
    D = cfg.d_model
    H = cfg.n_heads
    dh = cfg.resolved_head_dim          # nope dims per head
    dv = cfg.resolved_v_head_dim
    dr = cfg.rope_head_dim
    r = cfg.kv_lora_rank
    ks = jax.random.split(key, 7)
    return {
        "wq": dense_init(ks[0], (D, H, dh + dr), ("embed", "heads", None),
                         cfg.init_scale),
        "w_dkv": dense_init(ks[1], (D, r), ("embed", None), cfg.init_scale),
        "w_kr": dense_init(ks[2], (D, dr), ("embed", None), cfg.init_scale),
        "ckv_norm": ones_init((r,), (None,)),
        "w_uk": dense_init(ks[3], (r, H, dh), (None, "heads", None),
                           cfg.init_scale),
        "w_uv": dense_init(ks[4], (r, H, dv), (None, "heads", None),
                           cfg.init_scale),
        "wo": dense_init(ks[5], (H, dv, D), ("heads", None, "embed"),
                         cfg.init_scale),
    }


def _project_qkv_latent(p, x, cfg, positions):
    dt = x.dtype
    dh = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    q_nope, q_rope = q[..., :dh], q[..., dh:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    c_kv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"].astype(dt))
    c_kv = apply_norm({"scale": p["ckv_norm"]}, c_kv, "rmsnorm")
    k_rope = jnp.einsum("bsd,dr->bsr", x, p["w_kr"].astype(dt))
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0]
    return q_nope, q_rope, c_kv, k_rope


def apply_mla(p, x, cfg, *, positions, cache=None, cache_pos=None):
    """Returns (out, new_cache). cache = {"ckv": (B,C,r), "kr": (B,C,dr),
    "pos": (1,C)}; train/prefill when cache is None."""
    dt = x.dtype
    dh = cfg.resolved_head_dim
    dr = cfg.rope_head_dim
    scale = (dh + dr) ** -0.5
    q_nope, q_rope, c_kv, k_rope = _project_qkv_latent(p, x, cfg, positions)

    if cache is None:
        # training/prefill: up-project latents to per-head K/V (MHA-like)
        k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uk"].astype(dt))
        v = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uv"].astype(dt))
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (*k_nope.shape[:3], dr))], -1)
        qq = jnp.concatenate([q_nope, q_rope], -1)
        out = attention(qq, k, v, causal=True, window=cfg.window,
                        chunk=cfg.attn_chunk, scale=scale)
        o = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
        return o, None

    # decode: absorbed scores in latent space. Ring-buffer scatter write
    # (wrap-correct; >C tokens at once keep only the last C).
    B, S = x.shape[:2]
    C = cache["ckv"].shape[1]
    if S > C:
        c_kv, k_rope = c_kv[:, -C:], k_rope[:, -C:]
        cache_pos_eff = cache_pos + (S - C)
        S_eff = C
    else:
        cache_pos_eff, S_eff = cache_pos, S
    offs = jnp.arange(S_eff, dtype=jnp.int32)
    upd = jnp.broadcast_to((cache_pos_eff + offs)[None],
                           (x.shape[0], S_eff))
    if S_eff == 1:   # decode: dynamic_update_slice partitions locally
        slot0 = cache_pos_eff % C
        ckv = jax.lax.dynamic_update_slice(cache["ckv"], c_kv,
                                           (0, slot0, 0))
        kr = jax.lax.dynamic_update_slice(cache["kr"], k_rope,
                                          (0, slot0, 0))
        pos_t = jax.lax.dynamic_update_slice(cache["pos"], upd,
                                             (0, slot0))
    else:
        slots = (cache_pos_eff + offs) % C
        ckv = cache["ckv"].at[:, slots].set(c_kv)
        kr = cache["kr"].at[:, slots].set(k_rope)
        pos_t = cache["pos"].at[:, slots].set(upd)
    new_cache = {"ckv": ckv, "kr": kr, "pos": pos_t}

    q_eff = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"].astype(dt))
    q_lat = jnp.concatenate([q_eff, q_rope], -1)        # (B,S,H,r+dr)
    k_lat = jnp.concatenate([ckv, kr], -1)[:, :, None]  # (B,C,1,r+dr)
    v_lat = ckv[:, :, None]                             # (B,C,1,r)
    kv_pos = pos_t if S <= 8 else pos_t[0]
    ctx = attention(q_lat, k_lat, v_lat, causal=True, window=cfg.window,
                    q_offset=cache_pos, kv_positions=kv_pos,
                    kv_valid=kv_pos >= 0, chunk=cfg.attn_chunk,
                    scale=scale,
                    kv_shard=cfg.decode_kv_shard or None)  # (B,S,H,r)
    out = jnp.einsum("bshr,rhk->bshk", ctx, p["w_uv"].astype(dt))
    o = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
    return o, new_cache


def init_mla_cache(cfg, batch: int, cache_len: int, dtype):
    return {
        "ckv": jnp.zeros((batch, cache_len, cfg.kv_lora_rank), dtype),
        "kr": jnp.zeros((batch, cache_len, cfg.rope_head_dim), dtype),
        "pos": jnp.full((batch, cache_len), -1, jnp.int32),
    }
