"""Figure 8: asynchronous communication (dropped outer gradients).

Each replica's outer gradient is dropped with probability p per round;
a dropped replica continues from its own parameters. Expectation:
graceful degradation — even 50% drop costs only a few percent PPL
(paper: +2.1% in the non-i.i.d. setting)."""
from __future__ import annotations

from . import common as C

DROPS = [0.0, 0.1, 0.3, 0.5]


def run(scale: int = 1):
    p = dict(C.DEFAULTS)
    rounds = 20 * scale
    rows = []
    for regime in ("iid", "non_iid"):
        arch, loss_fn, sampler = C.make_setup(regime, k=p["k"])
        params0, pre = C.pretrain(
            arch, loss_fn, sampler, p["pretrain"], batch=p["batch"],
            seq=p["seq"], lr=p["inner_lr"], warmup=p["warmup"],
            total=p["pretrain"] + rounds * p["H"])
        for dp in DROPS:
            h, _ = C.run_diloco(arch, loss_fn, sampler, params0,
                                k=p["k"], H=p["H"], rounds=rounds,
                                step0=pre, drop_prob=dp,
                                batch=p["batch"], seq=p["seq"],
                                eval_every=rounds)
            rows.append(dict(regime=regime, drop=dp,
                             ppl=C.final_ppl(h)))
    ppl = {(r["regime"], r["drop"]): r["ppl"] for r in rows}
    payload = {"rows": rows,
               "claims": {
                   "graceful_50pct_noniid":
                       ppl[("non_iid", 0.5)] / ppl[("non_iid", 0.0)]
                       < 1.10,
                   "graceful_50pct_iid":
                       ppl[("iid", 0.5)] / ppl[("iid", 0.0)] < 1.10}}
    C.save("fig8_async_drop", payload)
    return payload


if __name__ == "__main__":
    out = run()
    for r in out["rows"]:
        print(f"{r['regime']:8s} drop={r['drop']:.1f} ppl={r['ppl']:.3f}")
    print(out["claims"])
