"""Robustness scenario: unreliable workers + elastic compute pool.

Simulates the paper's two operational studies together:
  * every round, each island's outer gradient is dropped with 30%
    probability (network failure / preemption — Fig 8);
  * halfway through, the pool doubles from 4 to 8 islands (Fig 7).

Shows training proceeds smoothly through both events.

  PYTHONPATH=src python examples/robustness_drop.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DiLoCoConfig, TrainConfig
from repro.core import diloco, schedules
from repro.data.sharding import make_regime
from repro.models.registry import get_smoke_arch

K, H, ROUNDS, DROP = 8, 10, 12, 0.3
arch = get_smoke_arch("diloco_60m")
loss_fn = lambda p, b: arch.loss(p, b)
params, _ = arch.init(jax.random.PRNGKey(0), arch.cfg)
sampler = make_regime("non_iid", k=K, vocab_size=arch.cfg.vocab_size)

dcfg = DiLoCoConfig(k=K, H=H, drop_prob=DROP)
tcfg = TrainConfig(inner_lr=3e-3, warmup_steps=10,
                   total_steps=ROUNDS * H, batch_size=8, seq_len=64)
state = diloco.init_state(params, dcfg)
round_fn = diloco.make_round(loss_fn, sampler.sample_all_shards, dcfg,
                             tcfg, batch_size=8, seq_len=64)
evaluate = diloco.make_eval(loss_fn)
val = sampler.sample_validation(jax.random.PRNGKey(42), 64, 64)

rng = np.random.default_rng(0)
drops = schedules.drop_masks(rng, DROP, K, ROUNDS)
key = jax.random.PRNGKey(1)
for t in range(ROUNDS):
    # elastic pool: 4 islands for the first half, 8 after
    n_active = 4 if t < ROUNDS // 2 else 8
    act = jnp.asarray(schedules.active_mask(n_active, K))
    key, sub = jax.random.split(key)
    state, m = round_fn(state, sub, jnp.asarray(drops[t]), act)
    ppl = np.exp(float(evaluate(state.global_params, val)))
    dropped = int(K - drops[t].sum())
    print(f"round {t + 1:2d}: {n_active} islands active, "
          f"{dropped} outer-grad(s) dropped -> val ppl {ppl:.1f}")
print("\nno round failed: dropped islands kept training from their own "
      "params;\nnew islands joined from the global copy (Fig 7+8 "
      "semantics).")
