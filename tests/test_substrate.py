"""Substrate tests: data pipeline, sharding rules, optimizer, schedule,
checkpointing, compute/drop schedules."""
from __future__ import annotations

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import schedules
from repro.data.pipeline import MarkovMixture
from repro.data.sharding import make_regime, shard_weights
from repro.optim import adamw
from repro.optim.schedule import warmup_cosine, make_warmup_cosine
from repro.sharding.spec import (Boxed, logical_to_pspec, unbox,
                                 batch_pspec)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_markov_deterministic():
    s = MarkovMixture(vocab_size=64, k=4, alpha=1.0, seed=0)
    a = s.sample_all_shards(jax.random.PRNGKey(1), 4, 32)
    b = s.sample_all_shards(jax.random.PRNGKey(1), 4, 32)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (4, 4, 32)
    assert a.dtype == jnp.int32
    assert (a >= 0).all() and (a < 64).all()


def test_iid_shards_share_distribution():
    """alpha=0 (iid): per-shard bigram statistics agree closely."""
    s = make_regime("iid", k=2, vocab_size=16, seed=0)
    toks = np.asarray(s.sample_all_shards(jax.random.PRNGKey(0), 64, 256))

    def bigram(t):
        h = np.zeros((16, 16))
        for row in t.reshape(-1, t.shape[-1]):
            np.add.at(h, (row[:-1], row[1:]), 1)
        return h / h.sum()

    d = np.abs(bigram(toks[0]) - bigram(toks[1])).sum()
    assert d < 0.15, d


def test_non_iid_shards_differ():
    s = make_regime("non_iid", k=2, vocab_size=16, seed=0)
    toks = np.asarray(s.sample_all_shards(jax.random.PRNGKey(0), 64, 256))

    def bigram(t):
        h = np.zeros((16, 16))
        for row in t.reshape(-1, t.shape[-1]):
            np.add.at(h, (row[:-1], row[1:]), 1)
        return h / h.sum()

    d = np.abs(bigram(toks[0]) - bigram(toks[1])).sum()
    assert d > 0.5, d


def test_entropy_floor_reachable():
    s = MarkovMixture(vocab_size=32, k=2, alpha=0.0, seed=0)
    floor = s.entropy_floor()
    assert 0 < floor < np.log(32) + 1e-6


def test_shard_weights():
    s = make_regime("non_iid", k=4, vocab_size=16, imbalanced=True)
    w = shard_weights(s, weighted=True)
    assert w.shape == (4,)
    np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-6)
    assert w[0] > w[-1]          # Zipf profile
    u = shard_weights(s, weighted=False)
    np.testing.assert_allclose(u, 0.25)


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

class _FakeMesh:
    def __init__(self, sizes: dict):
        self.axis_names = tuple(sizes)
        self.devices = np.empty(tuple(sizes.values()))


def test_logical_to_pspec_divisibility_fallback():
    mesh = _FakeMesh({"data": 16, "model": 16})
    # starcoder2 KV: 4 kv heads don't divide 16 -> embed rows take model
    spec = logical_to_pspec(("embed", "kv_heads", None), (4608, 4, 128),
                            mesh)
    assert tuple(spec) == ("model", None, None)
    # whisper embed table: vocab 51866 doesn't divide -> embed gets it
    spec = logical_to_pspec(("vocab", "embed"), (51866, 1280), mesh)
    assert tuple(spec) == (None, "model")
    # clean case: heads win over embed
    spec = logical_to_pspec(("embed", "heads", None), (4096, 32, 128),
                            mesh)
    assert tuple(spec) == (None, "model", None)


def test_replica_axis_maps_to_pod():
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    spec = logical_to_pspec(("replica", "embed", "ff"), (2, 1024, 4096),
                            mesh)
    assert tuple(spec) == ("pod", None, "model")


def test_batch_pspec_divisibility():
    mesh = _FakeMesh({"data": 16, "model": 16})
    assert tuple(batch_pspec(mesh, 256, 2)) == (("data",), None) \
        or tuple(batch_pspec(mesh, 256, 2)) == ("data", None)
    # batch=1 cannot shard
    spec = batch_pspec(mesh, 1, 2)
    assert spec[0] is None


def test_boxed_unbox_roundtrip():
    tree = {"a": Boxed(jnp.ones((2, 3)), ("embed", "ff")),
            "b": {"c": Boxed(jnp.zeros((4,)), (None,))}}
    params, axes = unbox(tree)
    assert params["a"].shape == (2, 3)
    assert axes["a"] == ("embed", "ff")
    assert axes["b"]["c"] == (None,)


# ---------------------------------------------------------------------------
# optimizer & schedule
# ---------------------------------------------------------------------------

def test_adamw_matches_numpy_reference():
    key = jax.random.PRNGKey(0)
    p = {"w": jax.random.normal(key, (8, 4))}
    st = adamw.init(p)
    lr, b1, b2, eps, wd = 1e-3, 0.9, 0.95, 1e-8, 0.1
    pn = np.array(p["w"])
    m = np.zeros_like(pn)
    v = np.zeros_like(pn)
    cur = p
    for t in range(1, 5):
        g = {"w": jnp.full((8, 4), 0.5)}
        cur, st = adamw.update(g, st, cur, lr=lr, b1=b1, b2=b2, eps=eps,
                               weight_decay=wd)
        gn = np.full((8, 4), 0.5)
        m = b1 * m + (1 - b1) * gn
        v = b2 * v + (1 - b2) * gn * gn
        mh, vh = m / (1 - b1 ** t), v / (1 - b2 ** t)
        pn = pn - lr * (mh / (np.sqrt(vh) + eps) + wd * pn)
        np.testing.assert_allclose(cur["w"], pn, rtol=1e-5, atol=1e-6)


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, gn = adamw.clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(gn), np.sqrt(90 + 160), rtol=1e-6)
    total = np.sqrt(sum(np.sum(np.square(x))
                        for x in jax.tree.leaves(clipped)))
    np.testing.assert_allclose(total, 1.0, rtol=1e-5)


def test_warmup_cosine_shape():
    sched = make_warmup_cosine(1e-3, 100, 1000)
    assert float(sched(0)) == 0.0
    np.testing.assert_allclose(float(sched(100)), 1e-3, rtol=1e-5)
    assert float(sched(1000)) < float(sched(500)) < 1e-3
    np.testing.assert_allclose(float(sched(1000)), 1e-4, rtol=1e-2)


# ---------------------------------------------------------------------------
# schedules (Fig 7 / Fig 8)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,first,last", [
    ("constant_local", 1, 1), ("constant_distributed", 8, 8),
    ("doubling", 4, 8), ("halving", 8, 4),
    ("ramp_up", 1, 8), ("ramp_down", 8, 1)])
def test_compute_schedules(kind, first, last):
    s = schedules.compute_schedule(kind, 8, 10)
    assert s[0] == first and s[-1] == last
    assert s.min() >= 1 and s.max() <= 8


def test_doubling_equals_halving_total():
    a = schedules.compute_schedule("doubling", 8, 10)
    b = schedules.compute_schedule("halving", 8, 10)
    assert a.sum() == b.sum()


@given(p=st.floats(0.05, 0.9), seed=st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_drop_masks_rate(p, seed):
    rng = np.random.default_rng(seed)
    m = schedules.drop_masks(rng, p, 16, 200)
    rate = 1.0 - m.mean()
    assert abs(rate - p) < 0.08


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip():
    from repro.checkpoint import checkpoint as ckpt
    tree = {"params": {"w": jnp.arange(6.0).reshape(2, 3),
                       "b": jnp.ones((3,))},
            "step": jnp.asarray(7)}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck.npz")
        ckpt.save(path, tree, metadata={"note": "test"})
        like = jax.tree.map(jnp.zeros_like, tree)
        out = ckpt.restore(path, like)
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
            np.testing.assert_array_equal(a, b)
        assert ckpt.load_metadata(path)["note"] == "test"


def test_checkpoint_shape_mismatch_raises():
    from repro.checkpoint import checkpoint as ckpt
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck.npz")
        ckpt.save(path, {"w": jnp.ones((2, 2))})
        with pytest.raises(ValueError):
            ckpt.restore(path, {"w": jnp.ones((3, 3))})


def test_markov_regroup_holds_process_fixed():
    """regroup(k) keeps the validation mixture identical and gives the
    k=1 worker exactly the mixture distribution."""
    s16 = MarkovMixture(vocab_size=32, k=16, alpha=1.0, seed=0)
    s4 = s16.regroup(4)
    s1 = s16.regroup(1)
    np.testing.assert_array_equal(np.asarray(s16._mix_logits),
                                  np.asarray(s4._mix_logits))
    np.testing.assert_allclose(np.asarray(s1._logits[0]),
                               np.asarray(s16._mix_logits), rtol=1e-5)
    t = s4.sample_all_shards(jax.random.PRNGKey(0), 2, 16)
    assert t.shape == (4, 2, 16)
    assert s16.entropy_floor() == s4.entropy_floor()
