"""Backend dispatch for the Pallas kernels.

Each op picks the Pallas kernel on TPU (or when forced via
``mode='pallas'`` / ``mode='interpret'``) and the pure-jnp oracle from
``ref.py`` otherwise — so CPU runs (tests, benchmarks) and TPU runs
share one call site. Tree-level helpers apply the fused optimizer
kernels leaf-by-leaf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import flash_attention as _flash
from . import fused_adamw as _adamw
from . import outer_nesterov as _nesterov
from . import quantize as _quant
from . import sign_prune as _prune
from . import ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(mode: str):
    """-> (use_kernel, interpret)."""
    if mode == "auto":
        return (_on_tpu(), False)
    if mode == "pallas":
        return (True, False)
    if mode == "interpret":
        return (True, True)
    if mode == "ref":
        return (False, False)
    raise ValueError(mode)


# ---------------------------------------------------------------------------
# flash attention — q: (B, S, H, d) model layout; kernel uses (B, H, S, d)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _fa_vjp(causal, window, scale, block_q, block_k, interpret):
    return _flash.make_flash_attention_vjp(
        causal=causal, window=window, scale=scale, block_q=block_q,
        block_k=block_k, interpret=interpret)


def flash_attention(q, k, v, *, causal=True, window=0, scale=None,
                    mode: str = "auto", block_q: int = 128,
                    block_k: int = 128):
    """Differentiable flash attention (custom_vjp with flash backward
    kernels on the kernel path)."""
    use_kernel, interpret = _resolve(mode)
    if not use_kernel:
        return ref.flash_attention(q.transpose(0, 2, 1, 3),
                                   k.transpose(0, 2, 1, 3),
                                   v.transpose(0, 2, 1, 3),
                                   causal=causal, window=window,
                                   scale=scale).transpose(0, 2, 1, 3)
    fa = _fa_vjp(causal, window, scale, block_q, block_k, interpret)
    out = fa(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
             v.transpose(0, 2, 1, 3))
    return out.transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# fused AdamW — tree-level
# ---------------------------------------------------------------------------

def adamw_update_tree(params, grads, m, v, *, lr, count, b1=0.9, b2=0.95,
                      eps=1e-8, weight_decay=0.1, mode: str = "auto"):
    """One fused AdamW step over a whole param tree. ``count`` is the
    post-increment step (for bias correction)."""
    use_kernel, interpret = _resolve(mode)
    cf = jnp.asarray(count, jnp.float32)
    c1 = 1.0 - b1 ** cf
    c2 = 1.0 - b2 ** cf

    def one(p, g, mm, vv):
        if use_kernel:
            return _adamw.fused_adamw(
                p, g, mm, vv, lr=lr, c1=c1, c2=c2, b1=b1, b2=b2, eps=eps,
                weight_decay=weight_decay, interpret=interpret)
        return ref.fused_adamw(p, g, mm, vv, lr=lr, b1=b1, b2=b2,
                               eps=eps, weight_decay=weight_decay,
                               c1=c1, c2=c2)

    out = jax.tree.map(one, params, grads, m, v)
    leaves = lambda i: jax.tree.map(
        lambda t: t[i], out, is_leaf=lambda x: isinstance(x, tuple))
    return leaves(0), leaves(1), leaves(2)


def adamw_update_tree_mixed(grads, m, v, master, *, lr, count,
                            param_dtype, b1=0.9, b2=0.95, eps=1e-8,
                            weight_decay=0.1, mode: str = "auto"):
    """One mixed-precision fused AdamW step over a whole tree: the
    high-precision ``master`` tree is authoritative, grads/moments ride
    at the replica storage dtype, and the ``param_dtype`` working copy
    is emitted in the same pass. Returns (params, m, v, master)."""
    use_kernel, interpret = _resolve(mode)
    cf = jnp.asarray(count, jnp.float32)
    c1 = 1.0 - b1 ** cf
    c2 = 1.0 - b2 ** cf

    def one(g, mm, vv, w):
        if use_kernel:
            return _adamw.fused_adamw_mixed(
                g, mm, vv, w, lr=lr, c1=c1, c2=c2, b1=b1, b2=b2,
                eps=eps, weight_decay=weight_decay,
                param_dtype=param_dtype, interpret=interpret)
        return ref.fused_adamw_mixed(
            g, mm, vv, w, lr=lr, b1=b1, b2=b2, eps=eps,
            weight_decay=weight_decay, c1=c1, c2=c2,
            param_dtype=param_dtype)

    out = jax.tree.map(one, grads, m, v, master)
    leaves = lambda i: jax.tree.map(
        lambda t: t[i], out, is_leaf=lambda x: isinstance(x, tuple))
    return leaves(0), leaves(1), leaves(2), leaves(3)


# ---------------------------------------------------------------------------
# sign pruning — matrix + tree-level
# ---------------------------------------------------------------------------

def sign_prune(x, frac: float, *, mode: str = "auto"):
    """x: (R, C)."""
    if frac <= 0:
        return x
    use_kernel, interpret = _resolve(mode)
    if use_kernel:
        return _prune.sign_prune(x, frac, interpret=interpret)
    return ref.sign_prune(x, frac)


def sign_prune_tree(tree, frac: float, *, mode: str = "auto"):
    """Leaves are reshaped to (leading-dim rows, flattened cols)."""
    if frac <= 0:
        return tree

    def one(x):
        if x.ndim == 0:
            return x
        flat = x.reshape(1, -1) if x.ndim == 1 \
            else x.reshape(x.shape[0], -1)
        return sign_prune(flat, frac, mode=mode).reshape(x.shape)

    return jax.tree.map(one, tree)


# ---------------------------------------------------------------------------
# low-precision outer-gradient transport — tensor + tree-level
# ---------------------------------------------------------------------------

# Wire cost of one transported element: int4 carries 0.5 B of codes
# plus one f32 scale per 128-element block. The per-element figure for
# int4 is the large-tensor amortization; exact wire bytes (with the
# ceil'd per-block scale count) come from ``transport_bytes``.
QUANT_BLOCK = 128
TRANSPORT_BYTES_PER_ELEM = {
    "float32": 4.0,
    "bfloat16": 2.0,
    "int4": 0.5 + 4.0 / QUANT_BLOCK,
}


def quant_roundtrip(x, dtype: str, *, mode: str = "auto"):
    """Simulated low-precision transport: quantize→dequantize round trip
    at ``dtype`` ("float32" = identity). int4 uses one f32 scale per
    128-element block of the flattened tensor (the same (blocks, 128)
    layout as the fused optimizer kernels)."""
    if dtype == "float32":
        return x
    if dtype not in TRANSPORT_BYTES_PER_ELEM:
        raise ValueError(f"unknown transport dtype {dtype!r}")
    use_kernel, interpret = _resolve(mode)
    if use_kernel:
        return _quant.fake_quant(x, dtype, interpret=interpret)
    if dtype == "bfloat16":
        return ref.fake_quant(x, dtype)
    # int4 oracle on the kernel's block layout, so ref == kernel exactly
    shape, out_dtype = x.shape, x.dtype
    n = x.size
    rows = -(-n // QUANT_BLOCK)
    flat = x.reshape(-1).astype(jnp.float32)
    if rows * QUANT_BLOCK != n:
        flat = jnp.pad(flat, (0, rows * QUANT_BLOCK - n))
    out = ref.fake_quant(flat.reshape(rows, QUANT_BLOCK), dtype)
    return out.reshape(-1)[:n].reshape(shape).astype(out_dtype)


def quant_roundtrip_tree(tree, dtype: str, *, mode: str = "auto"):
    if dtype == "float32":
        return tree
    return jax.tree.map(lambda x: quant_roundtrip(x, dtype, mode=mode),
                        tree)


def transport_bytes(n_elems: int, dtype: str) -> float:
    """Simulated wire bytes for ``n_elems`` outer-gradient elements.

    int4 charges 0.5 B of codes per element plus one f32 scale per
    (started) 128-element block of the flattened tensor — a tensor that
    does not divide evenly still ships a scale for its ragged tail, so
    the scale overhead is ceil(n/128) blocks, not n/128.
    """
    if dtype not in TRANSPORT_BYTES_PER_ELEM:
        raise ValueError(f"unknown transport dtype {dtype!r}")
    if dtype == "int4":
        blocks = -(-int(n_elems) // QUANT_BLOCK)
        return n_elems * 0.5 + 4.0 * blocks
    return n_elems * TRANSPORT_BYTES_PER_ELEM[dtype]


# ---------------------------------------------------------------------------
# outer Nesterov — tree-level
# ---------------------------------------------------------------------------

def nesterov_update_tree(params, delta, buf, *, lr, momentum=0.9,
                         mode: str = "auto"):
    use_kernel, interpret = _resolve(mode)

    def one(p, d, b):
        if use_kernel:
            return _nesterov.outer_nesterov(p, d, b, lr=lr,
                                            momentum=momentum,
                                            interpret=interpret)
        return ref.outer_nesterov(p, d, b, lr=lr, momentum=momentum)

    out = jax.tree.map(one, params, delta, buf)
    leaves = lambda i: jax.tree.map(
        lambda t: t[i], out, is_leaf=lambda x: isinstance(x, tuple))
    return leaves(0), leaves(1)
