"""Hypothesis property tests for the trace layer: for ARBITRARY fault
scenarios, the tick-domain Chrome trace drawn from the timeline is
structurally valid and its transfer spans biject exactly-once with the
timeline's terminal events (Arrival <-> delivered span, Lost <->
undelivered span), and the barrier-paced round trace stays valid under
any round-mask projection.

(Separate from tests/test_obs.py so the module-level hypothesis
importorskip cannot take the deterministic suite with it — same split
as tests/test_async_properties.py. The deterministic module covers
the same properties on a fixed faulty scenario when hypothesis is
absent.)
"""
from __future__ import annotations

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.faults import Arrival, Lost, Scenario  # noqa: E402
from repro.obs import trace as obs_trace  # noqa: E402


@st.composite
def _scenarios(draw):
    k = draw(st.integers(2, 5))
    pre = ()
    if draw(st.booleans()):
        leave = draw(st.integers(1, 6))
        rejoin = draw(st.sampled_from([0, leave + 1, leave + 3]))
        pre = ((draw(st.integers(0, k - 1)), leave, rejoin),)
    s = Scenario(
        speeds=tuple(draw(st.lists(st.integers(1, 3), min_size=k,
                                   max_size=k))),
        latency=tuple(draw(st.lists(st.integers(0, 2), min_size=k,
                                    max_size=k))),
        latency_jitter=draw(st.sampled_from([0.0, 0.5])),
        drop_prob=draw(st.sampled_from([0.0, 0.3, 0.7])),
        max_retries=draw(st.integers(0, 2)),
        retry_backoff=draw(st.integers(1, 2)),
        preemptions=pre,
        seed=draw(st.integers(0, 10_000)))
    ticks = draw(st.integers(2, 10))
    return k, s, ticks


def _records_of(events):
    recs = []
    for e in events:
        if isinstance(e, Arrival):
            recs.append({"event": "arrival", "uid": e.uid})
        elif isinstance(e, Lost):
            recs.append({"event": "lost", "uid": e.uid})
    return recs


@given(_scenarios())
@settings(max_examples=60, deadline=None)
def test_async_trace_valid_and_spans_biject_with_timeline(case):
    """Every Arrival in the timeline owns exactly one delivered
    transfer span, every Lost exactly one undelivered span, no span is
    orphaned, and the whole trace passes structural validation."""
    k, s, ticks = case
    events = s.timeline(k, ticks)
    trace = obs_trace.async_trace(s, k, ticks).to_json()
    assert obs_trace.validate_trace(trace) == []
    assert obs_trace.span_event_correspondence(
        trace, _records_of(events)) == []


@given(_scenarios())
@settings(max_examples=40, deadline=None)
def test_async_trace_span_windows_match_event_ticks(case):
    """A delivered transfer span closes at its Arrival's tick and a
    lost span at its Lost's give-up tick — the trace never invents or
    shifts time."""
    k, s, ticks = case
    by_uid = {e.uid: e for e in s.timeline(k, ticks)
              if isinstance(e, (Arrival, Lost))}
    trace = obs_trace.async_trace(s, k, ticks).to_json()
    for span in obs_trace.transfer_spans(trace):
        uid = span["args"]["uid"]
        end_tick = (span["ts"] + span["dur"]) / obs_trace.TICK_US
        assert end_tick == pytest.approx(by_uid[uid].tick)
        assert span["args"]["delivered"] == isinstance(
            by_uid[uid], Arrival)


@given(_scenarios())
@settings(max_examples=40, deadline=None)
def test_round_trace_valid_under_any_mask_projection(case):
    """The barrier-paced trace built from the scenario's round-mask
    projection (what train.py draws for sync/streaming/sharded) is
    structurally valid, and its per-round inner spans never exceed
    active x rounds."""
    k, s, ticks = case
    rounds = max(1, ticks // max(1, s.sync_round_ticks(k)))
    drops, acts = s.round_masks(k, rounds)
    trace = obs_trace.round_trace(
        transport="simulated", k=k, rounds=rounds, H=4, scenario=s,
        drops=drops, acts=acts, wire_bytes=64.0).to_json()
    assert obs_trace.validate_trace(trace) == []
    inner = [e for e in trace["traceEvents"]
             if e.get("ph") == "X" and e["name"] == "inner phase"]
    assert len(inner) == int(acts.sum())
