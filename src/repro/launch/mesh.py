"""Production mesh construction (TPU v5e target).

Single-pod: (data=16, model=16) — 256 chips, one DiLoCo island.
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; the "pod" axis IS
DiLoCo's replica axis: each pod holds one model replica, inner steps
never communicate across it, and the outer step's one all-reduce rides
the (slow) cross-pod links once every H steps.

Functions, not module constants — importing this module must not touch
jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests use small fake-device meshes)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def pods_of(mesh) -> int:
    names = dict(zip(mesh.axis_names, mesh.devices.shape))
    return names.get("pod", 1)


def chips_of(mesh) -> int:
    return mesh.devices.size
