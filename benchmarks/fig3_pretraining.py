"""Figure 3: impact of the number of pretraining steps.

Total step budget fixed; the pretrain/DiLoCo split varies — including
DiLoCo entirely from scratch. Expectation: final quality is robust to
the split; from-scratch costs at most a small degradation (paper:
-0.1 PPL)."""
from __future__ import annotations

from . import common as C

SPLITS = [0, 50, 100, 200]     # micro analog of {0, 12k, 24k, 48k}


def run(scale: int = 1):
    p = dict(C.DEFAULTS)
    total = 400 * scale
    rows = []
    arch, loss_fn, sampler = C.make_setup("non_iid", k=p["k"])
    for pre_steps in SPLITS:
        params0, pre = C.pretrain(arch, loss_fn, sampler, pre_steps,
                                  batch=p["batch"], seq=p["seq"],
                                  lr=p["inner_lr"], warmup=p["warmup"],
                                  total=total)
        rounds = (total - pre_steps) // p["H"]
        h, _ = C.run_diloco(arch, loss_fn, sampler, params0, k=p["k"],
                            H=p["H"], rounds=rounds, step0=pre,
                            batch=p["batch"], seq=p["seq"],
                            eval_every=max(rounds // 5, 1))
        rows.append(dict(pretrain_steps=pre_steps, rounds=rounds,
                         ppl=C.final_ppl(h), curve=h))
    ppls = [r["ppl"] for r in rows]
    payload = {"rows": rows,
               "claims": {
                   "robust_to_split":
                       (max(ppls) - min(ppls)) / min(ppls) < 0.10,
                   "from_scratch_works":
                       rows[0]["ppl"] < 3.0 * min(ppls)}}
    C.save("fig3_pretraining", payload)
    return payload


if __name__ == "__main__":
    out = run()
    for r in out["rows"]:
        print(f"pretrain={r['pretrain_steps']:4d} ppl={r['ppl']:.3f}")
    print(out["claims"])
