"""Exact global FLOPs / modeled HBM traffic from the jaxpr.

``compiled.cost_analysis()`` counts a ``lax.scan`` body ONCE — layer
scans and microbatch accumulation make it undercount by 10–100×. The
jaxpr, by contrast, carries every scan's static ``length``; walking it
with trip multipliers gives exact global FLOP counts (including remat
recompute and the AD transpose, which are explicit equations after
tracing grad).

Two byte models bracket the truth:
  * ``bytes``      — upper bound: every equation's outputs (plus dot /
    gather operand traffic). Pessimistic: XLA fuses elementwise chains,
    and hand-fused kernels (the Pallas flash attention) keep whole
    scan bodies in VMEM.
  * ``bytes_min``  — fused lower bound: a ``lax.scan`` is ONE fused op
    (reads xs/consts, writes ys, carry does one HBM round-trip per
    iteration); interior intermediates are free. Matmul/gather traffic
    outside scans still counts. This is what perfect kernel fusion
    achieves — the flash-attention kernel hits it for the attention
    scan by construction.

The roofline reports both; the dominant-term analysis uses ``bytes``
(conservative) and EXPERIMENTS.md quotes the bracket.
"""
from __future__ import annotations

import numpy as np

import jax

_TRANSPARENT = ("pjit", "closed_call", "remat", "remat2", "checkpoint",
                "custom_jvp_call", "custom_vjp_call",
                "custom_vjp_call_jaxpr", "core_call")


def _aval_bytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0


def _in_bytes(eqn) -> int:
    return sum(_aval_bytes(v.aval) for v in eqn.invars
               if hasattr(v, "aval"))


def _out_bytes(eqn) -> int:
    return sum(_aval_bytes(v.aval) for v in eqn.outvars)


def _dot_flops(eqn) -> int:
    out = eqn.outvars[0].aval
    dn = eqn.params["dimension_numbers"]
    (lc, _), _ = dn
    lhs = eqn.invars[0].aval
    k = 1
    for d in lc:
        k *= lhs.shape[d]
    return 2 * int(np.prod(out.shape)) * int(k)


def _sub(p, key):
    j = p[key]
    return j.jaxpr if hasattr(j, "jaxpr") else j


_NESTED_MEMO: dict = {}


def _has_nested_scan(jaxpr) -> bool:
    """True if any scan/while lives (transitively) inside ``jaxpr``."""
    key = id(jaxpr)
    if key in _NESTED_MEMO:
        return _NESTED_MEMO[key]
    _NESTED_MEMO[key] = False            # cycle guard
    found = False
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim in ("scan", "while"):
            found = True
            break
        p = eqn.params
        for k in ("jaxpr", "call_jaxpr", "body_jaxpr", "cond_jaxpr"):
            if k in p and _has_nested_scan(_sub(p, k)):
                found = True
                break
        if not found and prim == "cond":
            for br in p["branches"]:
                if _has_nested_scan(br.jaxpr if hasattr(br, "jaxpr")
                                    else br):
                    found = True
                    break
        if found:
            break
    _NESTED_MEMO[key] = found
    return found


def _walk(jaxpr, mult: int, acc: dict, count_min: bool):
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        p = eqn.params

        if prim == "scan":
            body = _sub(p, "jaxpr")
            length = int(p["length"])
            acc["bytes"] += mult * _out_bytes(eqn)
            if count_min:
                n_consts = int(p.get("num_consts", 0))
                n_carry = int(p["num_carry"])
                carry_bytes = sum(
                    _aval_bytes(v.aval)
                    for v in body.invars[n_consts:n_consts + n_carry])
                if _has_nested_scan(body):
                    # outer loop (layers / microbatches): carry does an
                    # HBM round-trip per iteration, xs/ys stream once,
                    # and the interior still counts (kernels don't fuse
                    # across whole layers)
                    acc["bytes_min"] += mult * (
                        _in_bytes(eqn) + _out_bytes(eqn)
                        + 2 * carry_bytes * length)
                    _walk(body, mult * length, acc, True)
                else:
                    # innermost scan (online-softmax attention, SSD
                    # chunk recurrence, xLSTM cell): a hand-fused kernel
                    # keeps the body in VMEM — I/O only at the boundary
                    acc["bytes_min"] += mult * (_in_bytes(eqn)
                                                + _out_bytes(eqn))
                    _walk(body, mult * length, acc, False)
            else:
                _walk(body, mult * length, acc, False)
            continue

        if prim == "while":
            _walk(_sub(p, "body_jaxpr"), mult, acc, False)
            _walk(_sub(p, "cond_jaxpr"), mult, acc, False)
            if count_min:
                acc["bytes_min"] += mult * (_in_bytes(eqn)
                                            + _out_bytes(eqn))
            continue

        if prim == "cond":
            for br in p["branches"]:
                _walk(br.jaxpr if hasattr(br, "jaxpr") else br, mult,
                      acc, count_min)
            continue

        if "jaxpr" in p or "call_jaxpr" in p:
            body = _sub(p, "jaxpr" if "jaxpr" in p else "call_jaxpr")
            # pjit/remat wrappers are fusion-transparent
            _walk(body, mult, acc, count_min)
            continue

        if prim == "dot_general":
            acc["flops"] += mult * _dot_flops(eqn)
            io = _in_bytes(eqn) + _out_bytes(eqn)
            acc["bytes"] += mult * io
            if count_min:
                acc["bytes_min"] += mult * io
            acc["dots"] += mult
        elif prim in ("gather", "scatter", "scatter-add", "scatter_add",
                      "dynamic_slice", "dynamic_update_slice"):
            io = _in_bytes(eqn) + _out_bytes(eqn)
            acc["bytes"] += mult * io
            if count_min:
                acc["bytes_min"] += mult * io
        else:
            acc["bytes"] += mult * _out_bytes(eqn)
    return acc


def jaxpr_cost(fn, *abstract_args) -> dict:
    """Trace ``fn`` on ShapeDtypeStructs and return
    {"flops", "bytes", "bytes_min", "dots"} — global totals."""
    closed = jax.make_jaxpr(fn)(*abstract_args)
    acc = {"flops": 0, "bytes": 0, "bytes_min": 0, "dots": 0}
    return _walk(closed.jaxpr, 1, acc, True)
