"""Outer-gradient compression (paper §6.2, Table 6).

Per-neuron sign pruning following the TIES heuristic (Yadav et al. 2023):
for each *neuron* (row of a weight matrix) elect the dominant sign by
total magnitude mass, then prune — within that row — the entries that
either disagree with the elected sign or fall in the smallest-magnitude
``frac`` quantile. The paper finds pruning 50% of outer-gradient values
costs +0.39% perplexity, making DiLoCo's rare communication compressible
on top of being rare.

The pure-jnp implementation here is the oracle for the fused Pallas
kernel in ``repro.kernels.sign_prune`` (on TPU the election + threshold +
mask fuse into one VMEM pass over the delta right before the cross-pod
all-reduce).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


from repro.kernels import ops as kops


def sign_prune_matrix(x, frac: float, *, mode: str = "auto"):
    """x: (R, C) — prune per row (dispatches kernel vs jnp oracle)."""
    return kops.sign_prune(x, frac, mode=mode)


def sign_prune(tree, frac: float, *, mode: str = "auto"):
    """Apply per-neuron sign pruning to every leaf of an outer-gradient
    tree. Leaves are reshaped to (rows, cols) with the leading dim as
    rows (a 'neuron' = one output row); vectors prune globally. The
    Pallas kernel is used on TPU, the jnp oracle elsewhere — identical
    semantics (see kernels/sign_prune.py)."""
    return kops.sign_prune_tree(tree, frac, mode=mode)


def density(tree) -> jnp.ndarray:
    """Fraction of non-zero entries — the achieved compression ratio."""
    nz = sum(jnp.sum(l != 0) for l in jax.tree.leaves(tree))
    n = sum(l.size for l in jax.tree.leaves(tree))
    return nz / n
