"""xlstm-350m [ssm, arXiv:2405.04517]: 24 blocks, d_model=1024,
4 heads, d_ff=0 (gated projections inside the cells), vocab=50304,
3 mLSTM blocks per 1 sLSTM block."""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m", family="ssm",
        n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab_size=50_304,
        slstm_every=4, pos_emb="none", norm="layernorm",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="xlstm-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=4, vocab_size=256, slstm_every=2)
