"""whisper-large-v3 [audio, arXiv:2212.04356]: 32L enc + 32L dec,
d_model=1280, 20 heads (MHA; GQA kv=20), d_ff=5120, vocab=51866.
Conv/mel frontend is STUBBED: input_specs provides (B, 1500, d_model)
frame embeddings consumed by the encoder."""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3", family="encdec",
        n_layers=32, n_enc_layers=32,
        d_model=1280, n_heads=20, n_kv_heads=20,
        d_ff=5120, vocab_size=51_866,
        pos_emb="learned", norm="layernorm", act="gelu", mlp_gated=False,
        attn_bias=True, mlp_bias=True, tie_embeddings=True,
        n_frames=1500, max_position=1 << 16,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="whisper-smoke", n_layers=2, n_enc_layers=2, d_model=128,
        n_heads=4, n_kv_heads=4, d_ff=256, vocab_size=256, n_frames=16,
        attn_chunk=64, max_position=4096)
