"""Mamba2 (SSD — state-space duality) blocks, chunked-scan formulation.

Training/prefill uses the chunked SSD algorithm: within-chunk quadratic
(attention-like) term + inter-chunk linear recurrence carried by
``lax.scan`` — memory is O(chunk²) not O(T²), so 500k contexts lower with
bounded buffers. Decode is the exact single-step recurrence with constant
state (B, H, N, P) + a (conv_width-1)-deep causal-conv tail state.

TPU adaptation: the chunk recurrence is a sequential scan over chunks
(maps to an XLA while loop); within-chunk einsums are MXU-shaped
(cs=256 multiples of 128 work well). The expanded inner dim is sharded
over the "model" mesh axis (head-parallel); the scan carries only the
per-device state shard, so the recurrence itself needs no collectives.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init, zeros_init, ones_init, apply_norm


def init_mamba2(key, cfg):
    D = cfg.d_model
    d_inner = cfg.ssm_expand * D
    H = cfg.ssm_heads or max(1, d_inner // 64)
    N = cfg.ssm_state
    conv_ch = d_inner + 2 * N
    ks = jax.random.split(key, 6)
    # in_proj -> [z(d_inner), x(d_inner), B(N), C(N), dt(H)]
    d_in_total = 2 * d_inner + 2 * N + H
    p = {
        "in_proj": dense_init(ks[0], (D, d_in_total), ("embed", "inner"),
                              cfg.init_scale),
        "out_proj": dense_init(ks[1], (d_inner, D), ("inner", "embed"),
                               cfg.init_scale),
        "conv_w": dense_init(ks[2], (cfg.ssm_conv, conv_ch), (None, "inner"),
                             0.2),
        "conv_b": zeros_init((conv_ch,), ("inner",)),
        "A_log": dense_init(ks[3], (H,), (None,), 1.0),
        "D": ones_init((H,), (None,)),
        "dt_bias": zeros_init((H,), (None,)),
        "norm": ones_init((d_inner,), ("inner",)),
    }
    return p


def _split_proj(cfg, proj):
    D = cfg.d_model
    d_inner = cfg.ssm_expand * D
    H = cfg.ssm_heads or max(1, d_inner // 64)
    N = cfg.ssm_state
    z = proj[..., :d_inner]
    xc = proj[..., d_inner:2 * d_inner]
    Bm = proj[..., 2 * d_inner:2 * d_inner + N]
    Cm = proj[..., 2 * d_inner + N:2 * d_inner + 2 * N]
    dt = proj[..., 2 * d_inner + 2 * N:]
    return z, xc, Bm, Cm, dt, d_inner, H, N


def _causal_conv(x, w, b, tail=None):
    """Depthwise causal conv. x: (B,T,C); w: (W,C). tail: (B,W-1,C) carried
    decode state (pre-pended history). Returns (y, new_tail)."""
    W = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], W - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W)) + b
    new_tail = xp[:, -(W - 1):] if W > 1 else tail
    return jax.nn.silu(y), new_tail


def ssd_chunked(x, dt, A, Bm, Cm, Dp, chunk: int):
    """SSD scan. x: (B,T,H,P); dt: (B,T,H) (post-softplus); A: (H,) <0;
    Bm, Cm: (B,T,N); Dp: (H,). Returns y: (B,T,H,P), final state
    (B,H,N,P)."""
    Bsz, T, H, P = x.shape
    N = Bm.shape[-1]
    if T % chunk != 0:
        chunk = 1 if T < chunk else T  # degenerate fallback
    nc, cs = T // chunk, chunk

    dA = dt * A[None, None]                                   # (B,T,H) <= 0
    xdt = x * dt[..., None]
    r = lambda a: a.reshape(Bsz, nc, cs, *a.shape[2:])
    dAc, xc, Bc, Cc = r(dA), r(xdt), r(Bm), r(Cm)
    cum = jnp.cumsum(dAc, axis=2)                             # (B,nc,cs,H)
    cum_end = cum[:, :, -1]                                   # (B,nc,H)

    # within-chunk (diagonal) term; mask BEFORE exp (seg>0 off-diagonal
    # would overflow and poison the backward pass with inf*0)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]       # (B,nc,i,j,H)
    ii = jnp.arange(cs)
    causal = ii[:, None] >= ii[None, :]
    seg = jnp.where(causal[None, None, :, :, None], seg, -jnp.inf)
    L = jnp.exp(seg)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc,
                        preferred_element_type=jnp.float32)
    ydiag = jnp.einsum("bcij,bcijh,bcjhp->bcihp", scores, L, xc,
                       preferred_element_type=jnp.float32)

    # per-chunk input state: sum_j exp(cum_end - cum_j) B_j (dt_j x_j)
    decay_in = jnp.exp(cum_end[:, :, None] - cum)             # (B,nc,cs,H)
    chunk_states = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", Bc, decay_in, xc,
                              preferred_element_type=jnp.float32)

    # inter-chunk recurrence
    def step(state, inp):
        cstate, cend = inp                                    # (B,H,N,P),(B,H)
        new = state * jnp.exp(cend)[..., None, None] + cstate
        return new, state                                     # emit prev

    init = jnp.zeros((Bsz, H, N, P), jnp.float32)
    final, prev_states = jax.lax.scan(
        step, init, (jnp.moveaxis(chunk_states, 1, 0),
                     jnp.moveaxis(cum_end, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)             # (B,nc,H,N,P)

    # off-diagonal: y_i += exp(cum_i) C_i . state_prev
    yoff = jnp.einsum("bcin,bcih,bchnp->bcihp", Cc, jnp.exp(cum),
                      prev_states, preferred_element_type=jnp.float32)
    y = (ydiag + yoff).reshape(Bsz, T, H, P)
    y = y + x * Dp[None, None, :, None]
    return y.astype(x.dtype), final


def ssd_decode_step(x, dt, A, Bm, Cm, Dp, state):
    """One-token recurrence. x: (B,1,H,P); dt: (B,1,H); Bm/Cm: (B,1,N);
    state: (B,H,N,P)."""
    dA = jnp.exp(dt[:, 0] * A[None])                          # (B,H)
    upd = jnp.einsum("bn,bh,bhp->bhnp", Bm[:, 0], dt[:, 0], x[:, 0],
                     preferred_element_type=jnp.float32)
    state = state * dA[..., None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0], state,
                   preferred_element_type=jnp.float32)
    y = y + x[:, 0] * Dp[None, :, None]
    return y[:, None].astype(x.dtype), state


def apply_mamba2(p, x, cfg, *, state=None, conv_tail=None):
    """x: (B,T,D). state/conv_tail given => decode mode (T==1).
    Returns (out, (new_state, new_conv_tail))."""
    dt_ = x.dtype
    proj = jnp.einsum("btd,de->bte", x, p["in_proj"].astype(dt_))
    z, xc, Bm, Cm, dtr, d_inner, H, N = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([xc, Bm, Cm], axis=-1)
    conv_out, new_tail = _causal_conv(conv_in, p["conv_w"].astype(dt_),
                                      p["conv_b"].astype(dt_), conv_tail)
    xc = conv_out[..., :d_inner]
    Bm = conv_out[..., d_inner:d_inner + N]
    Cm = conv_out[..., d_inner + N:]
    P_ = d_inner // H
    xh = xc.reshape(*xc.shape[:2], H, P_)
    dt_soft = jax.nn.softplus(dtr.astype(jnp.float32)
                              + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    Dp = p["D"].astype(jnp.float32)

    if state is not None and x.shape[1] == 1:
        y, new_state = ssd_decode_step(xh, dt_soft, A, Bm, Cm, Dp, state)
    else:
        # train or prefill (prefill starts from the zeroed state)
        y, new_state = ssd_chunked(xh, dt_soft, A, Bm, Cm, Dp,
                                   cfg.ssm_chunk)
    y = y.reshape(*y.shape[:2], d_inner)
    # gated RMSNorm (mamba2 style) then down-projection
    y = apply_norm({"scale": p["norm"]}, y * jax.nn.silu(z), "rmsnorm")
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"].astype(dt_))
    return out, (new_state, new_tail)


def init_mamba2_state(cfg, batch: int, dtype=jnp.float32):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = cfg.ssm_heads or max(1, d_inner // 64)
    N = cfg.ssm_state
    conv_ch = d_inner + 2 * N
    return (jnp.zeros((batch, H, N, d_inner // H), jnp.float32),
            jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype))
