"""Table 3: impact of the number of replicas (i.i.d. and non-i.i.d.).

Fixed inner steps per replica; k swept. With more replicas the model
consumes more data/compute per round. Expectation: more replicas help,
with diminishing returns beyond ~8 (paper sees 16.23 -> 15.02 -> 14.91
going 1 -> 8 -> 16 in the non-i.i.d. regime).

The data-generating process is a FIXED 16-shard mixture regrouped
among the k workers (`MarkovMixture.regroup`) so the validation task is
identical across k — varying the sampler's own k would silently change
what is being learned."""
from __future__ import annotations

from . import common as C

K_SWEEP = [1, 4, 8, 16]


def run(scale: int = 1):
    p = dict(C.DEFAULTS)
    rounds = 15 * scale
    out_rows = []
    for regime in ("iid", "non_iid"):
        arch, loss_fn, base_sampler = C.make_setup(regime, k=16)
        for k in K_SWEEP:
            sampler = base_sampler.regroup(k)
            params0, pre = C.pretrain(
                arch, loss_fn, sampler, p["pretrain"], batch=p["batch"],
                seq=p["seq"], lr=p["inner_lr"], warmup=p["warmup"],
                total=p["pretrain"] + rounds * p["H"])
            h, _ = C.run_diloco(arch, loss_fn, sampler, params0, k=k,
                                H=p["H"], rounds=rounds, step0=pre,
                                batch=p["batch"], seq=p["seq"],
                                eval_every=rounds)
            out_rows.append(dict(regime=regime, k=k, ppl=C.final_ppl(h)))
    ppl = {(r["regime"], r["k"]): r["ppl"] for r in out_rows}
    payload = {"rows": out_rows,
               "claims": {
                   "more_replicas_help_noniid":
                       ppl[("non_iid", 8)] < ppl[("non_iid", 1)],
                   "more_replicas_help_iid":
                       ppl[("iid", 8)] < ppl[("iid", 1)],
                   "diminishing_returns_after_8":
                       (ppl[("non_iid", 8)] - ppl[("non_iid", 16)])
                       < (ppl[("non_iid", 1)] - ppl[("non_iid", 8)])}}
    C.save("table3_replicas", payload)
    return payload


if __name__ == "__main__":
    out = run()
    for r in out["rows"]:
        print(f"{r['regime']:8s} k={r['k']:3d} ppl={r['ppl']:.3f}")
    print(out["claims"])
