"""DiLoCo training driver (CLI).

Runs the paper's algorithm end-to-end: optional single-worker
pretraining phase, then T rounds of (H inner AdamW steps × k replicas +
one outer Nesterov step), with the paper's robustness features
switchable from the command line (data regime, communication drops,
adaptive compute schedule, outer-gradient pruning, outer optimizer).

On CPU this drives the reduced-scale models (--smoke, default) used by
the benchmark suite; the same functions lower onto the production mesh
(see dryrun.py) for TPU execution.

Example:
  PYTHONPATH=src python -m repro.launch.train \
      --arch diloco_150m --smoke --k 4 --H 20 --rounds 30 \
      --regime non_iid --outer-opt nesterov
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import resilience
from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import DiLoCoConfig, TrainConfig
from repro.core import diloco, faults, schedules
from repro.data.sharding import make_regime, shard_weights
from repro.models.registry import get_arch, get_smoke_arch
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


def _int_list(spec: str, k: int, name: str) -> tuple:
    """Parse a comma list of ints; a single value broadcasts to k."""
    try:
        vals = [int(x) for x in spec.split(",") if x.strip()]
    except ValueError:
        raise SystemExit(f"{name} wants comma-separated ints, "
                         f"got {spec!r}")
    if len(vals) == 1:
        vals = vals * k
    if len(vals) != k:
        raise SystemExit(f"{name} needs 1 or k={k} values, "
                         f"got {len(vals)}")
    return tuple(vals)


def scenario_of(args) -> faults.Scenario | None:
    """Build the ``faults.Scenario`` scripted by the CLI fault flags,
    or None when no fault flag is set (the legacy mask path — kept
    bit-identical for existing sync/streaming/sharded defaults).

    Round-driven transports project the scenario onto per-round masks
    (``Scenario.round_masks``); the async engine consumes its full
    event timeline. ``--drop-prob`` alone does NOT trigger a scenario
    (the legacy i.i.d. drop-mask path keeps its exact rng stream);
    combined with any other fault flag it becomes the scenario's
    per-send drop probability with retry/backoff semantics.
    """
    used = (args.speeds or args.link_latency
            or args.latency_jitter > 0 or args.max_retries > 0
            or args.preempt or args.transport == "async"
            or args.crash_at_tick >= 0 or args.crash_at_round >= 0
            or args.nan_bomb)
    if not used:
        return None
    k = args.k
    preempts = []
    for spec in args.preempt:
        parts = spec.split(":")
        if len(parts) not in (2, 3):
            raise SystemExit(
                f"--preempt wants WORKER:LEAVE[:REJOIN], got {spec!r}")
        w, leave = int(parts[0]), int(parts[1])
        rejoin = int(parts[2]) if len(parts) == 3 else 0
        preempts.append((w, leave, rejoin))
    scen = faults.Scenario(
        speeds=_int_list(args.speeds, k, "--speeds")
        if args.speeds else (1,) * k,
        latency=_int_list(args.link_latency, k, "--link-latency")
        if args.link_latency else (),
        latency_jitter=args.latency_jitter,
        drop_prob=args.drop_prob,
        max_retries=args.max_retries,
        retry_backoff=args.retry_backoff,
        preemptions=tuple(preempts),
        seed=args.seed)
    # crash / NaN-bomb injections ride the scenario too. Round-domain
    # flags convert through the barrier pacing T (one round = T ticks),
    # so Scenario.crash_round / nan_masks project them right back.
    T = scen.sync_round_ticks(k)
    crash_tick = args.crash_at_tick
    if args.crash_at_round >= 0:
        crash_tick = args.crash_at_round * T
    bombs = []
    for spec in args.nan_bomb:
        parts = spec.split(":")
        if len(parts) != 2:
            raise SystemExit(
                f"--nan-bomb wants WORKER:ROUND, got {spec!r}")
        bombs.append((int(parts[0]), int(parts[1]) * T))
    if crash_tick >= 0 or bombs:
        scen = dataclasses.replace(scen, crash_tick=crash_tick,
                                   nan_bombs=tuple(bombs))
    return scen


def build(args):
    arch = (get_smoke_arch if args.smoke else get_arch)(args.arch)
    cfg = arch.cfg
    if not args.stream_fragments and args.transport in ("simulated",
                                                        "sharded"):
        # these knobs only act on the streaming outer path — silently
        # running the classic full-precision outer step while the CLI
        # says "int4" would mislabel every reported number
        ignored = [flag for flag, on in (
            ("--outer-grad-dtype", args.outer_grad_dtype != "float32"),
            ("--stream-alpha", args.stream_alpha != 1.0),
            ("--stream-tau", args.stream_tau != 0),
            ("--error-feedback", args.error_feedback),
            ("--transport", args.transport != "simulated"),
            ("--no-pack-wire", not args.pack_wire),
            ("--pods", args.pods != 0)) if on]
        if ignored:
            raise SystemExit(
                f"{', '.join(ignored)} require(s) --stream-fragments "
                ">= 1 (streaming outer sync); the classic outer step "
                "would ignore them")
    if args.transport in ("async", "gossip"):
        # barrier-free transports: streaming mechanics that have no
        # meaning off the fragment-round path are rejected, not ignored
        bad = [flag for flag, on in (
            ("--stream-alpha", args.stream_alpha != 1.0),
            ("--stream-tau", args.stream_tau != 0),
            ("--no-pack-wire", not args.pack_wire),
            ("--pods", args.pods != 0),
            ("--legacy-loop", args.legacy_loop),
            ("--cosine-stats", args.cosine_stats)) if on]
        if args.transport == "async" and args.stream_fragments:
            bad.insert(0, "--stream-fragments")
        if bad:
            raise SystemExit(
                f"{', '.join(bad)} do(es) not act on "
                f"--transport {args.transport}")
    if args.pods and args.transport != "sharded":
        # --pods only shapes the sharded-transport mesh; accepting it
        # on the simulated path would fake a multi-pod layout
        raise SystemExit("--pods requires --transport sharded")
    if args.restore and args.transport != "async":
        raise SystemExit("--restore resumes a full async engine state; "
                         "round transports resume from --checkpoint-dir "
                         "snapshots (--resume auto) instead")
    # ---- resilience flag validation ----
    if not args.checkpoint_dir:
        need_dir = [flag for flag, on in (
            ("--resume", bool(args.resume)),
            ("--checkpoint-every", args.checkpoint_every > 0)) if on]
        if need_dir:
            raise SystemExit(f"{', '.join(need_dir)} require(s) "
                             "--checkpoint-dir")
    if args.legacy_loop and (args.checkpoint_dir or args.guard
                             or args.crash_at_round >= 0
                             or args.nan_bomb):
        raise SystemExit("--checkpoint-dir/--guard/--crash-at-round/"
                         "--nan-bomb need the scanned driver's chunk "
                         "boundaries; drop --legacy-loop")
    if args.crash_at_tick >= 0 and args.crash_at_round >= 0:
        raise SystemExit("--crash-at-tick and --crash-at-round are "
                         "exclusive (tick = async domain, round = "
                         "barrier domain)")
    if args.crash_at_tick >= 0 and args.transport != "async":
        raise SystemExit("--crash-at-tick addresses the async event "
                         "timeline; round transports use "
                         "--crash-at-round")
    if args.nan_bomb and (args.transport != "simulated"
                          or args.stream_fragments):
        raise SystemExit("--nan-bomb injects into the classic outer "
                         "reduce (--transport simulated, no "
                         "--stream-fragments)")
    if args.guard_clip > 0 and not args.guard_outer:
        raise SystemExit("--guard-clip scales deltas inside the "
                         "in-graph guard; add --guard-outer")
    if args.resume and args.resume != "auto" \
            and not args.resume.isdigit():
        raise SystemExit(f"--resume wants 'auto' or a snapshot step, "
                         f"got {args.resume!r}")
    dcfg = DiLoCoConfig(k=args.k, H=args.H, outer_opt=args.outer_opt,
                        outer_lr=args.outer_lr,
                        outer_momentum=args.outer_momentum,
                        drop_prob=args.drop_prob,
                        prune_frac=args.prune_frac,
                        weighted_avg=args.weighted,
                        kernel_mode=args.kernel_mode,
                        streaming_fragments=args.stream_fragments,
                        stream_alpha=args.stream_alpha,
                        stream_tau=args.stream_tau,
                        outer_grad_dtype=args.outer_grad_dtype,
                        error_feedback=args.error_feedback,
                        transport=args.transport,
                        pack_wire=args.pack_wire,
                        param_dtype=args.param_dtype,
                        master_dtype=args.master_dtype,
                        staleness_lambda=args.staleness_lambda,
                        gossip_pairing=args.gossip_pairing,
                        gossip_mix=args.gossip_mix,
                        guard_outer=args.guard_outer,
                        guard_clip=args.guard_clip)
    total = args.pretrain_steps + args.rounds * args.H
    tcfg = TrainConfig(inner_lr=args.inner_lr, warmup_steps=args.warmup,
                       total_steps=total, batch_size=args.batch,
                       seq_len=args.seq, seed=args.seed,
                       kernel_mode=args.kernel_mode,
                       param_dtype=args.param_dtype,
                       master_dtype=args.master_dtype)
    sampler = make_regime(args.regime, k=args.k,
                          vocab_size=cfg.vocab_size, seed=args.seed,
                          imbalanced=args.weighted)
    return arch, cfg, dcfg, tcfg, sampler


def _run_async_phase(args, dcfg, tcfg, loss_fn, sampler, params,
                     ev, val, rec):
    """Barrier-free driver: the event loop replaces the round loop.

    One tick = the fastest worker's phase; ``--ticks 0`` matches the
    wall-clock budget a barrier-paced run of --rounds rounds would pay
    under the same scenario, so async-vs-sync numbers compare at equal
    simulated time. ``rec`` (the run's ``RunRecorder``) receives every
    engine event as it happens and owns the console output."""
    from repro.core import async_diloco
    scenario = scenario_of(args) or faults.Scenario.uniform(args.k)
    samplers = tuple(
        (lambda i: lambda kk, B, S: sampler.sample_shard(kk, i, B, S))(i)
        for i in range(args.k))
    eng = async_diloco.AsyncEngine(
        loss_fn, samplers, dcfg, tcfg, scenario=scenario,
        total_steps=tcfg.total_steps, eval_fn=ev, eval_tokens=val,
        seed=args.seed)
    mgr = (resilience.CheckpointManager(args.checkpoint_dir,
                                        retain=args.retain)
           if args.checkpoint_dir else None)
    resumed_from = -1
    if args.resume and mgr is not None:
        step = (mgr.latest_good() if args.resume == "auto"
                else int(args.resume))
        if step is None:
            rec.note("resume: no verified snapshot, starting fresh")
            state = eng.init_state(params)
        else:
            state = async_diloco.state_from_tree(
                mgr.load_tree(step), params)
            resumed_from = step
            rec.note(f"resumed async snapshot {step}: "
                     f"version={state.version} "
                     f"events_done={state.events_done}")
    elif args.restore:
        state = async_diloco.state_from_tree(
            ckpt.restore_tree(args.restore), params)
        rec.note(f"restored async state: version={state.version} "
                 f"events_done={state.events_done}")
    else:
        state = eng.init_state(params)
    ticks = args.ticks or scenario.sync_round_ticks(args.k) * args.rounds
    eng._bind(state)
    rec.attach_wire_plan([{"fragment": 0, "wire_bytes":
                           float(eng.wire_bytes()),
                           "wire_dtype": dcfg.outer_grad_dtype}])
    rec.note(f"async transport: lambda={dcfg.staleness_lambda} "
             f"k={args.k} {ticks} tick(s), {eng.wire_bytes()} B/apply")
    on_crash = None
    if scenario.crash_tick >= 0:
        def on_crash(_state):
            rec.note(f"crash: SIGKILL at tick {scenario.crash_tick}")
            os.kill(os.getpid(), signal.SIGKILL)
    t0 = time.time()
    if mgr is not None and args.checkpoint_every > 0:
        # sliced event loop: a durable snapshot every N events — the
        # engine's events_done cursor is the resume point
        hist = []
        while True:
            state, h = eng.run(state, ticks=ticks,
                               max_events=args.checkpoint_every,
                               recorder=rec, on_crash=on_crash)
            hist.extend(h)
            mgr.save(state.events_done,
                     async_diloco.state_to_tree(state),
                     metadata={"transport": "async", "k": args.k,
                               "events_done": state.events_done})
            if len(h) < args.checkpoint_every:
                break
    else:
        state, hist = eng.run(state, ticks=ticks, recorder=rec,
                              on_crash=on_crash)
    n_arr = sum(1 for r in hist if r["event"] == "arrival")
    rec.note(f"done in {time.time() - t0:.1f}s; {n_arr} applications "
             f"over {ticks} ticks; entropy floor = "
             f"{sampler.entropy_floor():.4f}")
    if args.trace:
        tb = obs_trace.async_trace(scenario, args.k, ticks,
                                   history=hist,
                                   wire_bytes=eng.wire_bytes())
        tb.write(args.trace, other_data={"manifest": rec.manifest})
        rec.note(f"trace: {args.trace}")
    if args.out:
        rec.dump(args.out, args=vars(args))
        rec.note(f"wrote {args.out}")
    if args.checkpoint:
        # FULL engine state (workers, snapshots, outer, cursor): a
        # later --restore resumes the identical event suffix
        ckpt.save(args.checkpoint, async_diloco.state_to_tree(state),
                  metadata={"transport": "async", "k": args.k,
                            "H": args.H, "ticks": ticks,
                            "events_done": state.events_done})
        rec.note(f"checkpoint: {args.checkpoint}")
    if args.state_hash_out:
        vals = [r["val_loss"] for r in hist if "val_loss" in r]
        ckpt.atomic_write_json(args.state_hash_out, {
            "state_sha256": resilience.tree_sha256(
                async_diloco.state_to_tree(state)),
            "final_val_loss": vals[-1] if vals else None,
            "resumed_from_step": resumed_from,
            "events_done": int(state.events_done),
            "ingest_calls": rec.ingest_calls,
            "rollbacks": 0}, indent=2)
        rec.note(f"state hash: {args.state_hash_out}")
    return rec.records


def run(args, recorder=None):
    """Drive the configured run end-to-end. ``recorder`` overrides the
    run's ``RunRecorder`` (benchmarks pass a silenced one and inspect
    its counters); by default one is built from ``--log-format``.
    Returns the unified record history (``recorder.records``)."""
    arch, cfg, dcfg, tcfg, sampler = build(args)
    loss_fn = lambda p, b: arch.loss(p, b)
    key = jax.random.PRNGKey(args.seed)
    key, init_key = jax.random.split(key)
    params, _ = arch.init(init_key, cfg)
    ev = diloco.make_eval(loss_fn)
    val = sampler.sample_validation(jax.random.PRNGKey(10_000),
                                    args.eval_batch, args.seq)
    rec = recorder if recorder is not None else obs_metrics.RunRecorder(
        transport=args.transport, log_format=args.log_format)
    rec.manifest.setdefault("config", dict(vars(args)))

    # ---- resilience: durable snapshots + resume picker ----
    mgr = (resilience.CheckpointManager(args.checkpoint_dir,
                                        retain=args.retain)
           if args.checkpoint_dir else None)
    resume_step = None
    if args.resume and mgr is not None and args.transport != "async":
        resume_step = (mgr.latest_good() if args.resume == "auto"
                       else int(args.resume))
        if resume_step is None:
            rec.note("resume: no verified snapshot, starting fresh")
        elif not mgr.verify(resume_step):
            raise SystemExit(f"--resume {resume_step}: snapshot fails "
                             "integrity verification")

    # ---- pretraining phase (paper: 24k steps before DiLoCo) ----
    # A resumed run skips it: the snapshot's state/key already carry
    # the pretrain phase's full effect (params and rng consumption).
    if args.pretrain_steps and resume_step is None:
        step = diloco.make_single_worker_step(loss_fn, tcfg,
                                              total_steps=tcfg.total_steps)
        from repro.optim import adamw, precision
        pol = precision.policy_of(tcfg)
        opt = adamw.init(params, policy=pol)
        # fresh=True: the step donates (work, opt); an identity cast
        # would alias params and the donation would delete them
        work = precision.cast_tree(params, pol.param_dtype, fresh=True)
        for i in range(args.pretrain_steps):
            key, sub = jax.random.split(key)
            batch = {"tokens": sampler.sample_validation(
                sub, args.batch, args.seq)}
            work, opt, m = step(work, opt, batch, jnp.asarray(i))
            if (i + 1) % args.log_every == 0:
                vl = float(ev(work, val))
                rec.pretrain(step=i + 1, loss=float(m["loss"]),
                             val_loss=vl)
        # hand the master-precision params to the DiLoCo phase (the
        # working copy is a rounded view under a mixed policy); the
        # upcast keeps the DiLoCo globals/outer state f32 even under
        # the pure-bf16 policy, where no master exists
        params = precision.cast_tree(adamw.master_params(work, opt),
                                     jnp.float32)

    # ---- DiLoCo phase ----
    if dcfg.transport == "async":
        return _run_async_phase(args, dcfg, tcfg, loss_fn, sampler,
                                params, ev, val, rec)
    mesh = None
    frag_wire = None           # gossip: per-fragment exchange bytes
    round_wire = None          # classic/streaming: bytes/replica/round
    plan = ()
    if dcfg.transport == "gossip":
        from repro.core import gossip
        state = gossip.init_state(params, dcfg)
        frag_wire = gossip.frag_bytes(params, dcfg)
        rec.attach_wire_plan([{"fragment": i, "wire_bytes": float(b),
                               "wire_dtype": dcfg.outer_grad_dtype}
                              for i, b in enumerate(frag_wire)])
        rec.note(f"gossip transport: {dcfg.gossip_pairing} pairing, "
                 f"mix={dcfg.gossip_mix}, "
                 f"P={max(1, dcfg.streaming_fragments)} fragment(s), "
                 f"{max(frag_wire)} B/exchange")
    elif dcfg.streaming_fragments:
        from repro.core import streaming
        state = streaming.init_state(params, dcfg)
        plan = streaming.sync_plan(params, dcfg)
        round_wire = sum(row["wire_bytes"] for row in plan)
        rec.attach_wire_plan(plan)
        if dcfg.transport == "sharded":
            from repro.core import pod_collectives
            from repro.launch.mesh import make_pod_mesh
            # default: the largest pod count that bands k evenly AND
            # tiles the visible devices (min(k, devices) alone crashes
            # on e.g. k=4 over 6 devices although pods=2 works)
            n_dev = jax.device_count()
            pods = args.pods or max(
                (p for p in range(2, args.k + 1)
                 if args.k % p == 0 and n_dev % p == 0), default=1)
            if pods < 2:
                raise SystemExit(
                    "--transport sharded needs >= 2 pods, but no pod "
                    f"count >= 2 divides both k={args.k} and the "
                    f"{jax.device_count()} visible device(s) — a "
                    "1-pod mesh would silently run zero real "
                    "cross-pod collectives. On a CPU host set "
                    "XLA_FLAGS=--xla_force_host_platform_device_"
                    "count=N (a multiple of k) before jax starts")
            mesh = make_pod_mesh(pods)
            rec.note(f"sharded transport: "
                     f"{pod_collectives.pods_of(mesh)} "
                     f"pods × {args.k // pod_collectives.pods_of(mesh)} "
                     "replicas/pod")
    else:
        state = diloco.init_state(params, dcfg)
        round_wire = diloco.outer_wire_bytes(params, dcfg)
        rec.attach_wire_plan([{"fragment": 0, "send_step": args.H,
                               "apply_step": args.H,
                               "wire_bytes": float(round_wire),
                               "wire_dtype": dcfg.outer_grad_dtype}])
    # ---- resume + (re-)placement ----
    # Snapshots live at HOST placement: the example captured here (its
    # arrays outlive donation — only shapes/dtypes are read) restores
    # a snapshot saved under ANY pod count; shard_stream_state then
    # re-places it onto THIS run's mesh — the elastic-resize path.
    snapshot_example = resilience.wrap(state, key, 0)
    rounds_done = 0
    if resume_step is not None:
        state, key, rounds_done = resilience.unwrap(
            mgr.load(resume_step, snapshot_example))
        rec.note(f"resumed snapshot {resume_step}: "
                 f"{rounds_done} round(s) done")
    if mesh is not None:
        from repro.core import pod_collectives
        state = pod_collectives.shard_stream_state(state, mesh)

    def load_snapshot(step):
        """Restore snapshot ``step`` and re-place it for this run
        (the guard's rollback path)."""
        st, kk, rd = resilience.unwrap(mgr.load(step, snapshot_example))
        if mesh is not None:
            st = pod_collectives.shard_stream_state(st, mesh)
        return st, kk, rd

    rng = np.random.default_rng(args.seed)
    drops = schedules.drop_masks(rng, args.drop_prob, args.k, args.rounds)
    sched = schedules.compute_schedule(args.compute_schedule, args.k,
                                       args.rounds)
    acts = schedules.active_masks(sched, args.k)
    scen = scenario_of(args)
    if scen is not None:
        # project the scripted fault scenario onto the barrier-paced
        # run: scenario drops (with retry semantics) replace the legacy
        # i.i.d. masks; preemption spans compose with the compute
        # schedule's active masks
        drops, s_acts = scen.round_masks(args.k, args.rounds)
        acts = np.asarray(acts) * s_acts
        rec.note(f"faults: barrier round = "
                 f"{scen.sync_round_ticks(args.k)} "
                 "tick(s) (slowest worker + slowest link)")
    weights = jnp.asarray(shard_weights(sampler, args.weighted))
    # crash / NaN-bomb injections projected onto the round domain
    nan_masks = None
    if scen is not None and scen.nan_bombs:
        nan_masks = scen.nan_masks(args.k, args.rounds)
        rec.note(f"nan bombs armed: {int(nan_masks.sum())} "
                 "(worker, round) cell(s)")
    crash_round = scen.crash_round(args.k) if scen is not None else -1
    guard = None
    if args.guard:
        guard = resilience.AnomalyGuard(
            resilience.GuardConfig(window=args.guard_window,
                                   spike=args.guard_spike,
                                   max_rollbacks=args.guard_rollbacks),
            recorder=rec)
    gossip_rounds = []

    def emit_round(t, m, i=None, evaled=True, round_key=None):
        """Emit the round-t record from metrics dict ``m`` (scalar
        entries for the legacy loop, (R,) stacked entries at index
        ``i`` for the scanned driver) through the recorder. ``evaled``
        False marks a round skipped by the eval cadence — a NaN on an
        *evaled* round is a genuine divergence and is reported as
        such. ``round_key`` (the round's split-chain sub-key) lets the
        gossip transport record the realized pairing edges."""
        pick = (lambda x: float(x)) if i is None else \
            (lambda x: float(x[i]))
        # optional transport metrics recorded under their own names —
        # the unified schema keeps them flat, one key space for all
        extras = {kk: pick(m[kk]) for kk in
                  ("inner_loss_last", "drop_frac", "gossip_spread",
                   "gossip_frag", "exchange_frac",
                   "stream_peak_sync_bytes", "stream_round_sync_bytes")
                  if kk in m}
        if args.cosine_stats:
            extras["cos_mean"] = pick(m["cos_mean"])
            extras["cos_std"] = pick(m["cos_std"])
        edges = None
        wire = round_wire
        if frag_wire is not None:       # gossip: the round's fragment
            P = len(frag_wire)
            wire = frag_wire[t % P]
            from repro.core import gossip
            edges = gossip.pairing_edges(args.k, t,
                                         args.gossip_pairing,
                                         round_key=round_key)
            gossip_rounds.append({"round": t, "fragment": t % P,
                                  "edges": [list(e) for e in edges]})
        rec.round(
            round=t + 1, rounds=args.rounds,
            inner_steps=args.pretrain_steps + (t + 1) * args.H,
            inner_loss=pick(m["inner_loss"]),
            val_loss=pick(m["val_loss"]),
            outer_gnorm=pick(m["outer_gnorm"]),
            # count from the final mask row, not the schedule: a
            # scenario preemption zeroes workers the schedule keeps
            active=int(np.asarray(acts[t]).sum()),
            dropped=int(args.k - np.asarray(drops[t]).sum()),
            wire_bytes=wire, gossip_edges=edges, extras=extras,
            evaled=evaled)

    t0 = time.time()
    if args.legacy_loop:
        # One jit dispatch + one blocking host eval per round — kept for
        # comparison (see benchmarks/wallclock.py).
        rnd = diloco.make_round(loss_fn, sampler.sample_all_shards, dcfg,
                                tcfg, total_steps=tcfg.total_steps,
                                compute_cosine=args.cosine_stats,
                                batch_size=args.batch, seq_len=args.seq,
                                mesh=mesh)
        for t in range(args.rounds):
            key, sub = jax.random.split(key)
            state, m = rnd(state, sub, jnp.asarray(drops[t]),
                           jnp.asarray(acts[t]), weights)
            m = dict(m, val_loss=ev(state.global_params, val))
            emit_round(t, m, round_key=sub)
    else:
        # Scanned driver: chunks of `rounds_per_call` rounds run inside
        # one jit each (donated carry, in-graph eval every round); the
        # host only touches metrics at chunk boundaries. All the
        # resilience hooks (snapshots, crash, guard) live at those same
        # boundaries — they add zero host syncs per chunk.
        rpc = max(1, min(args.rounds_per_call or args.rounds,
                         args.rounds))
        ckpt_every = args.checkpoint_every if mgr is not None else 0
        runs = {}
        guarded = False       # flips after a guard rollback: the
        #                       replay escalates to the in-graph guard

        def get_run(n):
            kk = (n, guarded)
            if kk not in runs:
                d = (dataclasses.replace(dcfg, guard_outer=True)
                     if guarded else dcfg)
                runs[kk] = diloco.make_run(
                    loss_fn, sampler.sample_all_shards, d, tcfg,
                    rounds_per_call=n, total_steps=tcfg.total_steps,
                    compute_cosine=args.cosine_stats,
                    batch_size=args.batch, seq_len=args.seq,
                    eval_tokens=val, eval_every=args.eval_every,
                    mesh=mesh, nan_bombs=nan_masks)
            return runs[kk]

        t = rounds_done
        while t < args.rounds:
            n = min(rpc, args.rounds - t)
            if ckpt_every:
                # land chunk boundaries on the snapshot cadence
                n = min(n, ckpt_every - t % ckpt_every)
            if 0 <= crash_round and t <= crash_round:
                # ... and on the scripted kill point
                n = min(n, crash_round + 1 - t)
            subs = None
            if frag_wire is not None:
                # host replica of the in-graph split_chain: the round
                # keys the body consumed, for the pairing-edge record
                subs, kk = [], key
                for _ in range(n):
                    kk, sub = jax.random.split(kk)
                    subs.append(sub)
            # round_offset keeps the in-graph eval cadence globally
            # aligned across chunk boundaries (traced: chunks of equal
            # size share one compiled function)
            state, ms = get_run(n)(state, key,
                                   jnp.asarray(drops[t:t + n]),
                                   jnp.asarray(acts[t:t + n]), weights,
                                   round_offset=t)
            key = ms.pop("next_key")
            ms = rec.ingest_chunk(ms)
            for i in range(n):
                evaled = ((t + i + 1) % args.eval_every == 0
                          or i == n - 1)
                emit_round(t + i, ms, i, evaled=evaled,
                           round_key=None if subs is None else subs[i])
            t += n
            # (1) scripted kill: BEFORE this boundary's snapshot, so
            # the resume has to replay the crashed round from the last
            # durable state
            if 0 <= crash_round < t:
                rec.note(f"crash: SIGKILL at round boundary {t}")
                os.kill(os.getpid(), signal.SIGKILL)
            # (2) anomaly guard: judge the chunk from metrics already
            # materialized; on anomaly, roll back to the last good
            # snapshot and replay with the in-graph guard armed
            if guard is not None:
                losses = [float(ms["val_loss"][i])
                          if ((t - n + i + 1) % args.eval_every == 0
                              or i == n - 1)
                          else float(ms["inner_loss"][i])
                          for i in range(n)]
                bad = guard.observe_chunk(t - n, losses)
                if bad and mgr is not None and guard.can_rollback():
                    back = mgr.latest_good()
                    if back is not None and back < t:
                        state, key, t = load_snapshot(back)
                        guard.rolled_back(to_round=back,
                                          skip_round=bad[0]["round"])
                        guarded = True
                        continue
            # (3) durable snapshot at the cadence (host placement is
            # restored by the example on load, so a snapshot taken on
            # a pods=p mesh resumes under pods=p')
            if ckpt_every and (t % ckpt_every == 0 or t == args.rounds):
                mgr.save(t, resilience.wrap(state, key, t),
                         metadata={"transport": args.transport,
                                   "k": args.k, "H": args.H,
                                   "rounds_done": t})

    rec.note(f"done in {time.time() - t0:.1f}s; "
             f"entropy floor = {sampler.entropy_floor():.4f} "
             f"(ppl {np.exp(sampler.entropy_floor()):.2f})")
    if args.trace:
        overlap = None
        if mesh is not None and plan \
                and any(row.get("deferred") for row in plan):
            # deferred sharded transport: overlay the MEASURED
            # issue→consume offsets on the fragment lanes. A dedicated
            # rounds_per_call=1 lowering (no compile — stream_overlap
            # reads the pre-optimization text) keeps the per-round
            # offsets exact regardless of the chunking above.
            from repro.core import pod_collectives as _pc
            from repro.launch import hlo_analysis as _hlo
            run1 = diloco.make_run(
                loss_fn, sampler.sample_all_shards, dcfg, tcfg,
                rounds_per_call=1, total_steps=tcfg.total_steps,
                batch_size=args.batch, seq_len=args.seq,
                donate=False, mesh=mesh)
            overlap = _hlo.stream_overlap(
                run1.lower(state, key).compiler_ir("hlo")
                .as_hlo_text(),
                chips_per_pod=jax.device_count() // _pc.pods_of(mesh),
                tau=dcfg.stream_tau)
            rec.note(
                f"overlap (HLO-measured): {overlap['n_deferred']} "
                f"deferred wires, min {overlap['min_steps_between']} "
                f"steps / {overlap['min_dots_between']} dots "
                f"issue->consume (tau={dcfg.stream_tau})")
        tb = obs_trace.round_trace(
            transport=args.transport, k=args.k, rounds=args.rounds,
            H=args.H, scenario=scen, drops=np.asarray(drops),
            acts=np.asarray(acts), history=rec.round_records(),
            plan=plan, wire_bytes=round_wire,
            gossip_rounds=gossip_rounds, overlap=overlap)
        tb.write(args.trace, other_data={"manifest": rec.manifest})
        rec.note(f"trace: {args.trace}")
    if args.out:
        rec.dump(args.out, args=vars(args))
        rec.note(f"wrote {args.out}")
    if args.checkpoint:
        ckpt.save(args.checkpoint,
                  {"params": state.global_params,
                   "outer_buf": state.outer_state.buf},
                  metadata={"rounds": args.rounds, "k": args.k,
                            "H": args.H})
        rec.note(f"checkpoint: {args.checkpoint}")
    if args.state_hash_out:
        rrecs = rec.round_records()
        vals = [r["val_loss"] for r in rrecs
                if r.get("val_loss") is not None]
        ckpt.atomic_write_json(args.state_hash_out, {
            "state_sha256": resilience.tree_sha256(state),
            "leaf_sha256": resilience.leaf_hashes(state),
            "final_val_loss": vals[-1] if vals else None,
            "final_inner_loss": (rrecs[-1]["inner_loss"]
                                 if rrecs else None),
            "resumed_from_step": (-1 if resume_step is None
                                  else int(resume_step)),
            "rounds_done": args.rounds,
            "ingest_calls": rec.ingest_calls,
            "rollbacks": 0 if guard is None else guard.rollbacks_used},
            indent=2)
        rec.note(f"state hash: {args.state_hash_out}")
    return rec.records


def make_parser():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="diloco_150m")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--H", type=int, default=50)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--pretrain-steps", type=int, default=0)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--eval-batch", type=int, default=64)
    ap.add_argument("--inner-lr", type=float, default=1e-3)
    ap.add_argument("--warmup", type=int, default=50)
    ap.add_argument("--outer-opt", default="nesterov",
                    choices=["nesterov", "sgd", "sgdm", "adam"])
    ap.add_argument("--outer-lr", type=float, default=0.7)
    ap.add_argument("--outer-momentum", type=float, default=0.9)
    ap.add_argument("--regime", default="non_iid",
                    choices=["iid", "non_iid"])
    ap.add_argument("--drop-prob", type=float, default=0.0)
    ap.add_argument("--prune-frac", type=float, default=0.0)
    ap.add_argument("--weighted", action="store_true")
    ap.add_argument("--compute-schedule", default="constant_distributed",
                    choices=["constant_local", "constant_distributed",
                             "doubling", "halving", "ramp_up", "ramp_down"])
    ap.add_argument("--cosine-stats", action="store_true")
    ap.add_argument("--kernel-mode", default="ref",
                    choices=["auto", "pallas", "interpret", "ref"],
                    help="fused optimizer kernels: auto=Pallas on TPU, "
                         "ref=legacy jnp tree maps (bit-identical)")
    ap.add_argument("--rounds-per-call", type=int, default=0,
                    help="rounds scanned inside one jit "
                         "(0 = all rounds in a single call)")
    ap.add_argument("--eval-every", type=int, default=1,
                    help="in-graph eval cadence in rounds (scanned "
                         "driver; globally aligned across chunks)")
    ap.add_argument("--stream-fragments", type=int, default=0,
                    help="streaming outer sync: number of parameter "
                         "fragments P (0 = classic synchronous outer "
                         "step; see core/streaming.py)")
    ap.add_argument("--stream-alpha", type=float, default=1.0,
                    help="streaming merge weight "
                         "θ_i <- α·θ_global + (1-α)·θ_i")
    ap.add_argument("--stream-tau", type=int, default=0,
                    help="inner steps between a fragment's snapshot "
                         "and its application (simulated in-flight "
                         "collective)")
    ap.add_argument("--outer-grad-dtype", default="float32",
                    choices=["float32", "bfloat16", "int4"],
                    help="transport precision of outer gradients on "
                         "the simulated wire")
    ap.add_argument("--error-feedback", action="store_true",
                    help="streaming: keep each replica's transport "
                         "quantization residual and add it to the next "
                         "round's delta (kills the int4/bf16 rounding "
                         "bias at no wire cost)")
    ap.add_argument("--transport", default="simulated",
                    choices=["simulated", "sharded", "async", "gossip"],
                    help="outer-sync backend: 'sharded' runs each "
                         "replica on its own pod mesh slice and "
                         "reduces every fragment with a real pod-axis "
                         "collective (needs >= --pods devices; on CPU "
                         "set --xla_force_host_platform_device_count); "
                         "'async' is the barrier-free event loop "
                         "(core/async_diloco.py) driven by the fault "
                         "flags below; 'gossip' is NoLoCo-style "
                         "pairwise partial averaging with no global "
                         "collective (core/gossip.py)")
    ap.add_argument("--staleness-lambda", type=float, default=1.0,
                    help="async transport: an outer gradient tau outer "
                         "steps stale is applied at weight lambda^tau/k")
    ap.add_argument("--gossip-pairing", default="butterfly",
                    choices=["butterfly", "random"],
                    help="gossip partner schedule: butterfly (hypercube "
                         "dims, k a power of 2, provably exact mixing "
                         "in log2 k rounds) or a fresh random perfect "
                         "matching per round")
    ap.add_argument("--gossip-mix", type=float, default=0.5,
                    help="gossip adoption rate: g_i <- g_i + "
                         "mix*(g_partner - g_i) on the scheduled "
                         "fragment")
    ap.add_argument("--ticks", type=int, default=0,
                    help="async horizon in wall-clock ticks (1 tick = "
                         "fastest worker's phase; 0 = the ticks a "
                         "barrier-paced run of --rounds would take "
                         "under the same scenario)")
    ap.add_argument("--speeds", default="",
                    help="fault scenario: comma per-worker phase "
                         "duration in ticks (single value broadcasts; "
                         "e.g. 1,1,1,4 = one 4x straggler)")
    ap.add_argument("--link-latency", default="",
                    help="fault scenario: comma per-worker one-way "
                         "link latency in ticks added to every send")
    ap.add_argument("--latency-jitter", type=float, default=0.0,
                    help="fault scenario: lognormal sigma multiplying "
                         "each send's latency draw")
    ap.add_argument("--max-retries", type=int, default=0,
                    help="fault scenario: resends after a dropped "
                         "attempt; a payload whose every attempt drops "
                         "is permanently lost")
    ap.add_argument("--retry-backoff", type=int, default=1,
                    help="fault scenario: ticks between a dropped "
                         "attempt and its resend")
    ap.add_argument("--preempt", action="append", default=[],
                    metavar="W:LEAVE[:REJOIN]",
                    help="fault scenario: worker W leaves at tick "
                         "LEAVE and rejoins at REJOIN (omit/0 = gone "
                         "for good); repeatable")
    ap.add_argument("--restore", default="",
                    help="async transport: resume from a full-state "
                         "checkpoint written by --checkpoint (replays "
                         "the identical event suffix)")
    ap.add_argument("--no-pack-wire", dest="pack_wire",
                    action="store_false", default=True,
                    help="sharded quantized transport: gather the "
                         "legacy dequantized-f32 payload per leaf "
                         "instead of the packed int4 codes+scales / "
                         "bf16 wire buffer (default: packed — the "
                         "collective ships what the accounting charges)")
    ap.add_argument("--pods", type=int, default=0,
                    help="pod count of the sharded-transport mesh "
                         "(0 = min(k, device count); must divide k)")
    ap.add_argument("--param-dtype", default="float32",
                    choices=["float32", "bfloat16"],
                    help="storage dtype of the per-replica working "
                         "params + AdamW moments (bfloat16 halves the "
                         "donated params+moments carry)")
    ap.add_argument("--master-dtype", default="float32",
                    choices=["float32", "bfloat16"],
                    help="storage dtype of the master-side state; when "
                         "wider than --param-dtype each replica carries "
                         "a master copy in its AdamW state and outer "
                         "deltas are computed master-vs-master")
    ap.add_argument("--legacy-loop", action="store_true",
                    help="use the per-round Python loop instead of the "
                         "scanned driver")
    ap.add_argument("--log-every", type=int, default=200)
    ap.add_argument("--log-format", default="text",
                    choices=["text", "json"],
                    help="progress-line format: 'text' keeps the "
                         "classic console lines, 'json' prints one "
                         "JSON record per line (same unified schema "
                         "as --out)")
    ap.add_argument("--trace", default="",
                    help="write a tick-domain Chrome trace-event JSON "
                         "of the run (workers, fragments, transfers, "
                         "faults) — open in Perfetto / "
                         "chrome://tracing")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="")
    ap.add_argument("--checkpoint", default="")
    # ---- resilience (src/repro/resilience/) ----
    ap.add_argument("--checkpoint-dir", default="",
                    help="durable snapshot directory (atomic npz + "
                         "sha256 manifest per snapshot, retention, "
                         "resume picker) — all five transports")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="snapshot cadence: every N rounds (round "
                         "transports) / every N events (async); "
                         "0 = only what --checkpoint writes")
    ap.add_argument("--resume", default="",
                    help="'auto' resumes from the newest snapshot in "
                         "--checkpoint-dir that passes integrity "
                         "verification (falling back past corrupt "
                         "ones); a number resumes that exact step")
    ap.add_argument("--retain", type=int, default=3,
                    help="snapshots kept in --checkpoint-dir (oldest "
                         "deleted first)")
    ap.add_argument("--crash-at-round", type=int, default=-1,
                    help="fault injection: SIGKILL this process at the "
                         "chunk boundary right after the given round "
                         "completes, BEFORE that boundary's snapshot "
                         "(round transports)")
    ap.add_argument("--crash-at-tick", type=int, default=-1,
                    help="fault injection: splice a Crash event into "
                         "the async timeline at this tick (the engine "
                         "SIGKILLs the process when it reaches it)")
    ap.add_argument("--nan-bomb", action="append", default=[],
                    metavar="W:ROUND",
                    help="fault injection: poison worker W's outer "
                         "gradient to NaN in the given round "
                         "(repeatable; classic simulated transport)")
    ap.add_argument("--guard", action="store_true",
                    help="host-side anomaly guard: rolling loss spike "
                         "detection at chunk boundaries, with "
                         "rollback-to-last-snapshot + in-graph-guard "
                         "escalation when --checkpoint-dir is set")
    ap.add_argument("--guard-window", type=int, default=8,
                    help="guard rolling-statistics window (rounds)")
    ap.add_argument("--guard-spike", type=float, default=4.0,
                    help="guard spike threshold in rolling std devs")
    ap.add_argument("--guard-rollbacks", type=int, default=2,
                    help="guard escalation budget: rollbacks allowed "
                         "per run")
    ap.add_argument("--guard-outer", action="store_true",
                    help="in-graph guard: exclude replicas with "
                         "non-finite outer deltas from the outer "
                         "reduce (bit-identical on clean rounds)")
    ap.add_argument("--guard-clip", type=float, default=0.0,
                    help="with --guard-outer: clip each replica's "
                         "outer-delta norm to this multiple of the "
                         "median replica norm (0 = off)")
    ap.add_argument("--state-hash-out", default="",
                    help="write a JSON with the final state's sha256, "
                         "final losses and resume provenance — the "
                         "bit-identity gate the resilience benchmarks "
                         "compare across processes")
    return ap


if __name__ == "__main__":
    run(make_parser().parse_args())
