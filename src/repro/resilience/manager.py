"""Durable checkpoint manager: atomic snapshots, integrity manifests,
retention, and a resume picker that falls back past corrupt files.

Layout under ``directory``::

    ckpt_00000012.npz                # the snapshot (checkpoint.save)
    ckpt_00000012.npz.manifest.json  # per-entry sha256 over the npz
    ckpt_00000012.npz.meta.json      # optional caller metadata

The manifest hashes the *on-disk* representation (each npz entry's
stored dtype/shape/bytes — bf16 leaves hash as their uint16 bit view,
exactly as written), so ``verify`` catches truncation, bit rot and
partial writes without needing the example tree. The npz itself is
written atomically (``checkpoint._atomic_savez``: tmp + fsync +
rename), so the failure mode ``verify`` guards against is corruption
*after* the write (or snapshots produced by older non-atomic writers),
plus deliberate corruption in the fault-injection benchmarks.

``latest_good()`` walks snapshots newest → oldest and returns the
first that verifies — the ``--resume auto`` picker.
"""
from __future__ import annotations

import hashlib
import os
import re
import zipfile

import numpy as np

from ..checkpoint import checkpoint as ckpt

_PAT = re.compile(r"^ckpt_(\d{8})\.npz$")
_MANIFEST_SUFFIX = ".manifest.json"


def _npz_entry_hashes(path: str) -> dict:
    """sha256 of every entry's stored dtype/shape/bytes. Raises on a
    file that cannot even be opened as a zip (truncated header)."""
    out = {}
    with np.load(path) as data:
        for name in sorted(data.files):
            a = data[name]
            h = hashlib.sha256()
            h.update(a.dtype.str.encode())
            h.update(repr(tuple(a.shape)).encode())
            h.update(np.ascontiguousarray(a).tobytes())
            out[name] = h.hexdigest()
    return out


class CheckpointManager:
    """Versioned snapshots of one run. ``step`` is the round cursor at
    the cut (monotone; the filename key)."""

    def __init__(self, directory: str, *, retain: int = 3):
        if retain < 1:
            raise ValueError(f"retain must be >= 1, got {retain}")
        self.directory = str(directory)
        self.retain = int(retain)
        os.makedirs(self.directory, exist_ok=True)

    # -- paths ---------------------------------------------------------
    def path_of(self, step: int) -> str:
        return os.path.join(self.directory, f"ckpt_{int(step):08d}.npz")

    def steps(self) -> list:
        """All snapshot steps on disk, ascending (manifest presence not
        required — an unverifiable snapshot still occupies its slot so
        retention and fallback see it)."""
        out = []
        for name in os.listdir(self.directory):
            m = _PAT.match(name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    # -- write side ----------------------------------------------------
    def save(self, step: int, tree, metadata: dict | None = None) -> str:
        """Atomically write snapshot ``step`` + its integrity manifest,
        then apply retention. Returns the snapshot path."""
        path = self.path_of(step)
        ckpt.save(path, tree, metadata)
        manifest = {"step": int(step), "format": 1,
                    "entries": _npz_entry_hashes(path)}
        ckpt.atomic_write_json(path + _MANIFEST_SUFFIX, manifest,
                               indent=2, sort_keys=True)
        self._apply_retention()
        return path

    def _apply_retention(self) -> None:
        for step in self.steps()[:-self.retain]:
            self.delete(step)

    def delete(self, step: int) -> None:
        path = self.path_of(step)
        for p in (path, path + _MANIFEST_SUFFIX, path + ".meta.json"):
            if os.path.exists(p):
                os.unlink(p)

    # -- read side -----------------------------------------------------
    def verify(self, step: int) -> bool:
        """True iff snapshot ``step`` exists, has a manifest, and every
        npz entry's recomputed hash matches it."""
        path = self.path_of(step)
        mpath = path + _MANIFEST_SUFFIX
        if not (os.path.exists(path) and os.path.exists(mpath)):
            return False
        try:
            with open(mpath) as f:
                import json
                manifest = json.load(f)
            actual = _npz_entry_hashes(path)
        except (zipfile.BadZipFile, ValueError, KeyError, OSError,
                EOFError):
            return False
        return manifest.get("entries") == actual

    def latest_good(self) -> int | None:
        """Newest snapshot step that verifies; None if none do."""
        for step in reversed(self.steps()):
            if self.verify(step):
                return step
        return None

    def load(self, step: int, example):
        """Restore snapshot ``step`` into the structure and dtypes of
        ``example`` (``checkpoint.restore``)."""
        return ckpt.restore(self.path_of(step), example)

    def load_tree(self, step: int) -> dict:
        """Structure-free dicts-only restore (``restore_tree``) — for
        dynamic layouts like the async engine's snapshot table."""
        return ckpt.restore_tree(self.path_of(step))
