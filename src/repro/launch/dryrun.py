import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
on the production meshes, prove memory fits, and extract the roofline
inputs (FLOPs, HBM bytes, collective bytes by pod-crossing).

Per (arch × shape):
  single-pod (16, 16)  "data","model"
    train_4k     -> inner_train_step   (one DiLoCo island's hot loop)
    prefill_32k  -> prefill
    decode_32k   -> serve_step (1 new token against a seq_len KV cache)
    long_500k    -> serve_step (sliding-window / SSM constant state)
  multi-pod (2, 16, 16)  "pod","data","model"   [--multi-pod]
    train_4k     -> diloco_inner_step  (vmap over the pod axis — must
                    contain ZERO cross-pod collective bytes)
                 -> diloco_outer_step  (the once-per-H all-reduce)
                 -> ddp_train_step     (sync baseline, for Table 2 comm)
    serve shapes -> same fns with batch over ("pod","data")

Sharding: parameters use 2-D FSDP×TP (logical rules: heads/ff/vocab/
experts -> "model"; d_model rows -> "data"), optimizer state follows
params, activations are sharded over ("data", ..., "model") Megatron
sequence-parallel style, training accumulates over microbatches so the
per-device live set fits v5e's 16 GB.
"""
import argparse
import dataclasses
import functools
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, ShapeConfig
from repro.launch import hlo_analysis as H
from repro.launch.mesh import make_production_mesh, chips_of
from repro.launch.jaxpr_cost import jaxpr_cost
from repro.models.registry import get_arch, ARCH_NAMES, Arch
from repro.optim import adamw
from repro.sharding.spec import (DEFAULT_RULES, PRIORITY, logical_to_pspec,
                                 batch_pspec)

# second sharding pass: FSDP over "data" for the d_model rows
FSDP_RULES = dict(DEFAULT_RULES)
FSDP_RULES.update({"embed_fsdp": "data"})

TRAIN_MICROBATCHES = 8


# ---------------------------------------------------------------------------
# shardings
# ---------------------------------------------------------------------------

def param_pspec(axes: tuple, shape: tuple, mesh: Mesh,
                fsdp: bool = True) -> P:
    """2-D param sharding: model-parallel pass (priority rules), then an
    FSDP pass putting 'embed' rows on "data" if still free.

    Exception: *gathered* tables (axes start with "vocab") whose vocab
    dim does not divide the model axis are fully replicated — XLA's SPMD
    partitioner mis-lowers gathers from feature-sharded tables (verifier
    failure), and a gather from a data-sharded table all-gathers the
    table every step anyway."""
    mesh_sizes0 = dict(zip(mesh.axis_names, mesh.devices.shape))
    if (axes and axes[0] == "vocab" and "model" in mesh_sizes0
            and shape[0] % mesh_sizes0["model"] != 0):
        return P(*([None] * len(axes)))
    spec = list(logical_to_pspec(axes, shape, mesh))
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if fsdp and "data" in mesh_sizes and "data" not in spec:
        for i, name in enumerate(axes):
            if (spec[i] is None and name == "embed"
                    and shape[i] % mesh_sizes["data"] == 0):
                spec[i] = "data"
                break
    return P(*spec)


def param_shardings(axes_tree, shapes_tree, mesh, *, leading=(),
                    fsdp: bool = True):
    def one(ax, s):
        ax = tuple(leading) + tuple(ax)
        return NamedSharding(mesh, param_pspec(ax, s.shape, mesh,
                                               fsdp=fsdp))
    return jax.tree.map(one, axes_tree, shapes_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def cache_pspec(shape: tuple, mesh: Mesh, *, include_pod: bool) -> P:
    """Decode-cache sharding: leading (groups) dim replicated, batch dim
    over ("pod"?, "data") when divisible, and ONE more dim over "model"
    (kv-heads first, then feature, then sequence)."""
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    nd = len(shape)
    spec = [None] * nd
    if nd >= 2:
        axes = []
        if include_pod and "pod" in mesh_sizes:
            axes.append("pod")
        axes.append("data")
        total = int(np.prod([mesh_sizes[a] for a in axes]))
        while axes and shape[1] % total != 0:
            total //= mesh_sizes[axes.pop()]
        if axes:
            spec[1] = tuple(axes) if len(axes) > 1 else axes[0]
    # "model" placement: kv-heads first (head-parallel attention, zero
    # collectives), then the sequence dim (flash-decoding: tiny softmax-
    # partial reduces), and only then feature dims (which contract —
    # per-layer score-sized psums)
    if "model" in mesh_sizes and nd >= 3:
        for i in [3, 2, nd - 1, nd - 2]:
            if 2 <= i < nd and spec[i] is None \
                    and shape[i] % mesh_sizes["model"] == 0 and shape[i] > 1:
                spec[i] = "model"
                break
    # batch too small for the data axis (e.g. B=1 long-context decode):
    # shard the sequence dim over "data" instead — flash-decoding style
    # KV parallelism (softmax partials reduce over tiny per-head terms)
    if spec[1] is None and "data" in mesh_sizes and nd >= 4:
        for i in [2, nd - 2]:
            if 2 <= i < nd and spec[i] is None \
                    and shape[i] % mesh_sizes["data"] == 0 and shape[i] > 1:
                spec[i] = "data"
                break
    return P(*spec)


def cache_shardings(cache_shapes, mesh, *, include_pod: bool):
    def one(s):
        # integer tracks (ring-buffer position maps) are tiny; sharding
        # them on a different dim than their K/V forces GSPMD to emit
        # cache-sized resharding all-reduces per layer — replicate them
        if not jnp.issubdtype(s.dtype, jnp.floating):
            return _replicated(mesh)
        return NamedSharding(
            mesh, cache_pspec(s.shape, mesh, include_pod=include_pod))
    return jax.tree.map(one, cache_shapes)


def _replicated(mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# lowered functions
# ---------------------------------------------------------------------------

def _abstract(arch: Arch, cfg, dtype):
    shapes, axes = arch.abstract_params(cfg)
    cast = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, dtype if jnp.issubdtype(s.dtype, jnp.floating)
            else s.dtype), shapes)
    return cast, axes


def build_train_step(arch: Arch, cfg, *, groups: int,
                     microbatches: int = TRAIN_MICROBATCHES,
                     cast_outside_mb: bool = False,
                     kernel_mode: str = "auto"):
    """(params, m, v, count, batch) -> (params, m, v, count, loss).
    Gradient accumulation over ``microbatches`` splits of the batch.

    ``cast_outside_mb``: hoist the f32->bf16 cast (and with it the FSDP
    parameter all-gather) OUT of the microbatch scan — the gathered bf16
    weights become loop-invariant, so GSPMD gathers them once per step
    instead of once per microbatch (§Perf hillclimb).

    ``kernel_mode="auto"`` (default) routes the AdamW update through
    the fused Pallas kernel on TPU, so the dry-run's HLO analysis
    exercises the kernels structurally; on CPU hosts auto resolves to
    the jnp reference, leaving the CPU-lite tests unchanged."""
    def loss16(p16, batch):
        return arch.loss(p16, batch, cfg=cfg, groups=groups)

    def cast(params):
        return jax.tree.map(
            lambda x: x.astype(jnp.bfloat16)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, params)

    def step(params, m, v, count, batch):
        B = batch["tokens"].shape[0]
        mb = microbatches if B % microbatches == 0 else 1
        split = jax.tree.map(
            lambda x: x.reshape((mb, B // mb) + x.shape[1:]), batch)

        if cast_outside_mb:
            p16 = cast(params)

            def micro(acc, mb_batch):
                (loss, _), g = jax.value_and_grad(
                    loss16, has_aux=True)(p16, mb_batch)
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32) / mb, acc, g)
                return acc, loss
        else:
            def micro(acc, mb_batch):
                (loss, _), g = jax.value_and_grad(
                    lambda p, b: loss16(cast(p), b), has_aux=True)(
                    params, mb_batch)
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32) / mb, acc, g)
                return acc, loss

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        grads, losses = jax.lax.scan(micro, zeros, split)
        grads, _ = adamw.clip_by_global_norm(grads, 1.0)
        new_params, st = adamw.update(
            grads, adamw.AdamWState(m, v, count), params, lr=4e-4,
            mode=kernel_mode)
        return new_params, st.m, st.v, st.count, losses.mean()

    return step


def build_outer_step(arch: Arch, cfg, k: int, *,
                     kernel_mode: str = "auto"):
    """(global_params, replica_params(k,...), buf) ->
    (new_global, new_buf, new_replicas). The replica-mean IS the
    cross-pod all-reduce; everything else is elementwise. The Nesterov
    update goes through the fused kernel dispatch (Pallas on TPU, jnp
    oracle elsewhere) so the analyzed HLO matches production."""
    from repro.kernels import ops as kops

    def step(global_params, replica_params, buf):
        delta = jax.tree.map(lambda g, r: g[None] - r,
                             global_params, replica_params)
        avg = jax.tree.map(lambda d: jnp.mean(d, axis=0), delta)
        new_global, new_buf = kops.nesterov_update_tree(
            global_params, avg, buf, lr=0.7, momentum=0.9,
            mode=kernel_mode)
        new_replicas = jax.tree.map(
            lambda g: jnp.broadcast_to(g[None], (k,) + g.shape),
            new_global)
        return new_global, new_buf, new_replicas

    return step


STREAM_FRAGMENTS = 2
STREAM_H = 4
STREAM_ROUNDS = 2
STREAM_TAU = 0


def build_stream_run(arch: Arch, cfg, *, k: int, mesh, batch: int,
                     seq_len: int, fragments_: int = STREAM_FRAGMENTS,
                     H_inner: int = STREAM_H,
                     rounds: int = STREAM_ROUNDS,
                     kernel_mode: str = "auto",
                     wire_dtype: str = "float32",
                     tau: int = STREAM_TAU):
    """The sharded streaming DiLoCo round on the multi-pod mesh: the
    scanned ``make_run`` driver with ``transport="sharded"`` — inner
    steps are pod-local shard_map compute and every fragment's outer
    gradient is a real pod-axis collective at its staggered offset.
    ``wire_dtype`` selects the transport precision: quantized dtypes
    lower the PACKED wire (one coalesced codes+scales all-gather per
    fragment) so the dry-run's collective bytes are the real ones.
    ``tau`` opens the issue→consume window: with ``tau > 0`` and a
    quantized wire each fragment's gather is issued at its snapshot
    offset and consumed τ inner steps later through the in-flight
    carry slot (core/streaming.deferred_consume).
    Returns (jitted_run, abstract_state, abstract_key). The HLO is
    checked for the paper's overlap structure via
    ``hlo_analysis.stream_interleaving`` (optimized text) and
    ``hlo_analysis.stream_overlap`` (pre-optimization text)."""
    from repro.configs.base import DiLoCoConfig, TrainConfig
    from repro.core import diloco as core_diloco
    from repro.core import streaming as core_streaming

    dcfg = DiLoCoConfig(k=k, H=H_inner, streaming_fragments=fragments_,
                        transport="sharded", kernel_mode=kernel_mode,
                        outer_grad_dtype=wire_dtype, stream_tau=tau)
    total = rounds * H_inner
    tcfg = TrainConfig(total_steps=total, warmup_steps=1,
                       batch_size=batch, seq_len=seq_len,
                       kernel_mode=kernel_mode)
    vocab = cfg.vocab_size

    def loss_fn(p, b):
        return arch.loss(p, b, cfg=cfg, groups=1)

    def sample_fn(key, B, S):
        return jax.random.randint(key, (k, B, S), 0, vocab, jnp.int32)

    run = core_diloco.make_run(
        loss_fn, sample_fn, dcfg, tcfg, rounds_per_call=rounds,
        total_steps=total, batch_size=batch, seq_len=seq_len,
        donate=False, mesh=mesh)
    pshapes, _ = _abstract(arch, cfg, jnp.float32)
    state = jax.eval_shape(
        lambda p: core_streaming.init_state(p, dcfg), pshapes)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return run, state, key


def build_gossip_exchange(arch: Arch, cfg, k: int, *, stage: int = 0,
                          mix: float = 0.5):
    """(est(k,...)) -> est: one butterfly pairwise partial-averaging
    exchange (core/gossip.py) on pod-stacked estimates. ``stage`` is
    static and the partner map i XOR 2^stage is realized as the
    structured ``butterfly_swap`` (reshape+flip), so under SPMD the
    exchange lowers to a pod-axis permutation collective — gossip's
    point-to-point wire, with NO collective spanning all pods (a plain
    partner take is opaque to the partitioner and all-gathers the full
    worker axis instead; asserted in tests/test_dryrun_lite.py)."""
    from repro.core import gossip as core_gossip

    def step(est):
        partner = core_gossip.partner_map(k, stage, "butterfly")
        mask = jax.tree.map(lambda g: 1.0, est)
        return core_gossip.mix_round(
            est, partner, mask, mix=mix,
            exchange=core_gossip.butterfly_swap(stage, k))

    return step


def build_prefill(arch: Arch, cfg, *, groups: int):
    def fn(params, batch):
        logits, cache = arch.prefill(params, batch, cfg=cfg, groups=groups)
        return logits[:, -1:], cache
    return fn


def build_decode(arch: Arch, cfg, *, groups: int):
    def fn(params, cache, tokens, pos):
        return arch.decode(params, cache, tokens, pos, cfg=cfg,
                           groups=groups)
    return fn


# ---------------------------------------------------------------------------
# per-pair dry run
# ---------------------------------------------------------------------------

def _analyse(name, lowered, compiled, *, chips, chips_per_pod,
             jcost=None, extra=None):
    xla_flops, xla_bytes = H.cost_items(compiled)
    # jaxpr-walk totals (scan-length-exact, global); XLA's numbers count
    # while bodies once — kept for reference only.
    flops = jcost["flops"] if jcost else xla_flops
    nbytes = jcost["bytes"] if jcost else xla_bytes
    nbytes_min = jcost["bytes_min"] if jcost else xla_bytes
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    coll = H.collective_stats(hlo, chips_per_pod=chips_per_pod)
    terms = H.roofline(flops, nbytes, coll, chips=chips)
    terms["memory_min_s"] = nbytes_min / (chips * H.HBM_BW)
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes"):
            if hasattr(ma, attr):
                mem[attr] = int(getattr(ma, attr))
    except Exception as e:  # pragma: no cover
        mem["error"] = str(e)
    rec = {"fn": name, "flops": flops, "hbm_bytes": nbytes,
           "hbm_bytes_min": nbytes_min,
           "xla_flops": xla_flops, "xla_bytes": xla_bytes,
           "collectives": coll.as_dict(), "roofline": terms, "memory": mem}
    if extra:
        rec.update(extra)
    return rec


def model_flops(param_count: float, active_count: float, shape: ShapeConfig
                ) -> float:
    """6·N_active·D for train, 2·N_active·D for inference."""
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    factor = 6.0 if shape.kind == "train" else 2.0
    return factor * active_count * tokens


def count_params(shapes_tree, axes_tree, cfg):
    total = sum(np.prod(s.shape) for s in jax.tree.leaves(shapes_tree))
    if not cfg.n_experts:
        return float(total), float(total)
    expert = 0
    for s, ax in zip(jax.tree.leaves(shapes_tree),
                     jax.tree.leaves(axes_tree,
                                     is_leaf=lambda x: isinstance(x, tuple))):
        if "experts" in ax:
            expert += np.prod(s.shape)
    active = total - expert * (1.0 - cfg.top_k / cfg.n_experts)
    return float(total), float(active)


def dryrun_pair(arch_name: str, shape_name: str, *, multi_pod: bool,
                microbatches: int = TRAIN_MICROBATCHES,
                fns: tuple = ("main",), mesh=None,
                variant: dict | None = None,
                kernel_mode: str = "auto",
                stream_wire: str = "float32",
                stream_tau: int = STREAM_TAU) -> list[dict]:
    """Lower+compile the pair; returns one record per lowered fn.

    ``variant`` (perf hillclimbing; recorded in each record):
      fsdp: bool          — False: params model-sharded only (1-D TP)
      cast_outside_mb: bool — hoist FSDP all-gather out of the mb scan
      remat: bool         — override activation checkpointing
      microbatches: int   — override accumulation factor
      moe_groups: int     — override MoE token-grouping factor

    ``kernel_mode`` defaults to "auto": the fused Pallas optimizer
    kernels are part of the lowered train/outer steps on TPU, so the
    HLO analysis exercises them structurally; CPU hosts fall back to
    the jnp oracles (unchanged lite tests).
    """
    variant = dict(variant or {})
    microbatches = int(variant.get("microbatches", microbatches))
    t0 = time.time()
    arch = get_arch(arch_name)
    shape = SHAPES[shape_name]
    cfg = arch.shape_cfg(shape)
    train = shape.kind == "train"
    # training: f32 master params, bf16 compute; serving: bf16 params
    cfg = cfg.replace(compute_dtype="bfloat16",
                      param_dtype="float32" if train else "bfloat16")
    if "remat" in variant:
        cfg = cfg.replace(remat=bool(variant["remat"]))
    if "decode_kv_shard" in variant:
        cfg = cfg.replace(decode_kv_shard=variant["decode_kv_shard"])
    if variant.get("seq_parallel"):
        cfg = cfg.replace(act_seq_shard=True, act_model_shard=False)
    if variant.get("no_act_shard"):
        cfg = cfg.replace(act_model_shard=False)
    fsdp = bool(variant.get("fsdp", True))
    cast_outside_mb = bool(variant.get("cast_outside_mb", False))
    pure_dp = bool(variant.get("pure_dp", False))
    if pure_dp:
        # small-model regime: batch over BOTH mesh axes, params
        # replicated, no Megatron activation sharding
        cfg = cfg.replace(act_batch_axes=("data", "model"),
                          act_model_shard=False)
    mesh = mesh or make_production_mesh(multi_pod=multi_pod)
    chips = chips_of(mesh)
    msizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    cpp = (chips // msizes["pod"]) if "pod" in msizes else None
    groups = int(variant.get("moe_groups", msizes.get("data", 1)))
    k = msizes.get("pod", 1)

    # pad vocab to the model-axis multiple (production practice —
    # Megatron/MaxText pad embeddings for clean sharding; whisper's
    # 51866 -> 51872). Logits over pad ids are unused.
    ms = msizes.get("model", 1)
    vocab_pad = (-cfg.vocab_size) % ms
    if vocab_pad:
        cfg = cfg.replace(vocab_size=cfg.vocab_size + vocab_pad)

    pdtype = jnp.float32 if train else jnp.bfloat16
    pshapes, paxes = _abstract(arch, cfg, pdtype)
    if pure_dp:
        psh = jax.tree.map(lambda s: _replicated(mesh), pshapes)
    else:
        psh = param_shardings(paxes, pshapes, mesh, fsdp=fsdp)
    total_p, active_p = count_params(pshapes, paxes, cfg)
    mf = model_flops(total_p, active_p, shape)

    in_specs = arch.input_specs(shape, dtype=jnp.bfloat16)
    tok_shape = in_specs["tokens"].shape
    if pure_dp and tok_shape[0] % chips == 0:
        axes_all = tuple(mesh.axis_names)
        bsh = {kk: NamedSharding(mesh, P(axes_all,
                                         *([None] * (v.ndim - 1))))
               for kk, v in in_specs.items()}
    else:
        bsh = {kk: NamedSharding(
            mesh, batch_pspec(mesh, v.shape[0], v.ndim,
                              include_pod=not train))
            for kk, v in in_specs.items()}

    records = []
    base = {"arch": arch_name, "shape": shape_name,
            "mesh": "x".join(map(str, mesh.devices.shape)),
            "multi_pod": multi_pod, "chips": chips,
            "params": total_p, "active_params": active_p,
            "model_flops": mf, "tokens": tok_shape,
            "vocab_pad": vocab_pad, "variant": variant,
            "microbatches": microbatches if train else 1}

    def record(name, jitted, args, raw_fn=None):
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
        jcost = None
        if raw_fn is not None:
            try:
                jcost = jaxpr_cost(raw_fn, *args)
            except Exception:
                jcost = None
        rec = _analyse(name, lowered, compiled, chips=chips,
                       chips_per_pod=cpp, jcost=jcost, extra=dict(base))
        if name == "diloco_stream_round":
            # the paper's overlap structure, asserted from the HLO:
            # per-fragment pod-axis all-reduces interleaved with
            # inner-step compute, none inside the inner-step scans
            rec["stream_interleaving"] = {
                kk: vv for kk, vv in H.stream_interleaving(
                    compiled.as_text(), chips_per_pod=cpp).items()
                if kk != "events"}
            # issue→consume separation of each wire collective,
            # measured on the pre-optimization lowering where emission
            # order survives as instruction ids (deferred wires only
            # appear with --stream-tau > 0 and a quantized wire)
            try:
                rec["stream_overlap"] = {
                    kk: vv for kk, vv in H.stream_overlap(
                        lowered.compiler_ir("hlo").as_hlo_text(),
                        chips_per_pod=cpp,
                        tau=stream_tau or None).items()
                    if kk != "rows"}
            except Exception as e:  # pragma: no cover
                rec["stream_overlap"] = {"error": str(e)}
        rec["roofline"]["model_flops_ratio"] = (
            mf / rec["flops"] if rec["flops"] else 0.0)
        rec["compile_s"] = round(time.time() - t0, 1)
        records.append(rec)
        return rec

    with mesh:
        if train:
            step = build_train_step(arch, cfg, groups=groups,
                                    microbatches=microbatches,
                                    cast_outside_mb=cast_outside_mb,
                                    kernel_mode=kernel_mode)
            fshapes = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                pshapes)
            cnt = jax.ShapeDtypeStruct((), jnp.int32)
            if not multi_pod:
                jitted = jax.jit(
                    step,
                    in_shardings=(psh, psh, psh, _replicated(mesh), bsh),
                    out_shardings=(psh, psh, psh, _replicated(mesh),
                                   _replicated(mesh)))
                record("inner_train_step", jitted,
                       (pshapes, fshapes, fshapes, cnt, in_specs),
                       raw_fn=step)
            else:
                # --- DiLoCo inner: vmap over the pod/replica axis.
                # (A partial-manual shard_map over "pod" would make the
                # no-cross-pod property definitional, but XLA 's SPMD
                # partitioner CHECK-fails on gathers under subgrouped
                # manual sharding; with the sort-free MoE dispatch the
                # vmap path verifies clean — asserted from the HLO.)
                vstep = jax.vmap(step, spmd_axis_name="pod")
                stack = lambda t: jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct((k,) + s.shape, s.dtype),
                    t)
                psh_k = param_shardings(paxes, stack(pshapes), mesh,
                                        leading=("replica",), fsdp=fsdp)
                rep = NamedSharding(mesh, P("pod"))
                # per-replica batch: tokens (k, B/k? ) — paper semantics:
                # each replica consumes its own global_batch; dry-run
                # splits the assigned global batch across replicas.
                binner = {kk: jax.ShapeDtypeStruct(
                    (k, v.shape[0] // k) + v.shape[1:], v.dtype)
                    for kk, v in in_specs.items()}
                bsh_k = {kk: NamedSharding(
                    mesh, P("pod", *batch_pspec(
                        mesh, v.shape[1], v.ndim - 1)))
                    for kk, v in binner.items()}
                cnt_k = jax.ShapeDtypeStruct((k,), jnp.int32)
                jitted = jax.jit(
                    vstep,
                    in_shardings=(psh_k, psh_k, psh_k, rep, bsh_k),
                    out_shardings=(psh_k, psh_k, psh_k, rep, rep))
                if "main" in fns or "inner" in fns:
                    record("diloco_inner_step", jitted,
                           (stack(pshapes), stack(fshapes), stack(fshapes),
                            cnt_k, binner), raw_fn=vstep)
                if "main" in fns or "outer" in fns:
                    outer = build_outer_step(arch, cfg, k,
                                             kernel_mode=kernel_mode)
                    jit_outer = jax.jit(
                        outer, in_shardings=(psh, psh_k, psh),
                        out_shardings=(psh, psh, psh_k))
                    record("diloco_outer_step", jit_outer,
                           (pshapes, stack(pshapes), pshapes),
                           raw_fn=outer)
                if "stream" in fns:
                    # sharded streaming round: P fragments of the outer
                    # sync issued as real pod-axis collectives from
                    # inside the scanned round (small H/R — the point
                    # is the collective structure, not the step count)
                    srun, sstate, skey = build_stream_run(
                        arch, cfg, k=k, mesh=mesh,
                        batch=max(1, tok_shape[0] // k),
                        seq_len=shape.seq_len, kernel_mode=kernel_mode,
                        wire_dtype=stream_wire, tau=stream_tau)
                    rec = record("diloco_stream_round", srun,
                                 (sstate, skey))
                    rec["stream_wire"] = stream_wire
                    rec["stream_tau"] = stream_tau
                if "gossip" in fns:
                    # barrier-free tier: one pairwise exchange, pod-
                    # permutation collective only (no all-pod reduce)
                    gstep = build_gossip_exchange(arch, cfg, k)
                    jit_g = jax.jit(gstep, in_shardings=(psh_k,),
                                    out_shardings=psh_k)
                    record("gossip_exchange", jit_g,
                           (stack(pshapes),), raw_fn=gstep)
                if "main" in fns or "ddp" in fns:
                    # synchronous DDP baseline: params replicated across
                    # pods, batch over (pod, data) -> per-step cross-pod
                    # gradient all-reduce (Table 2 comm accounting)
                    bddp = {kk: NamedSharding(
                        mesh, batch_pspec(mesh, v.shape[0], v.ndim,
                                          include_pod=True))
                        for kk, v in in_specs.items()}
                    jit_ddp = jax.jit(
                        step,
                        in_shardings=(psh, psh, psh, _replicated(mesh),
                                      bddp),
                        out_shardings=(psh, psh, psh, _replicated(mesh),
                                       _replicated(mesh)))
                    record("ddp_train_step", jit_ddp,
                           (pshapes, fshapes, fshapes, cnt, in_specs),
                           raw_fn=step)
        elif shape.kind == "prefill":
            fn = build_prefill(arch, cfg, groups=groups)
            jitted = jax.jit(fn, in_shardings=(psh, bsh))
            record("prefill", jitted, (pshapes, in_specs), raw_fn=fn)
        else:  # decode
            fn = build_decode(arch, cfg, groups=groups)
            cshapes = arch.cache_specs(shape, dtype=jnp.bfloat16)
            csh = cache_shardings(cshapes, mesh,
                                  include_pod=multi_pod)
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            jitted = jax.jit(
                fn, in_shardings=(psh, csh, bsh["tokens"],
                                  _replicated(mesh)),
                out_shardings=(NamedSharding(
                    mesh, batch_pspec(mesh, tok_shape[0], 3,
                                      include_pod=multi_pod)), csh))
            record("serve_step", jitted,
                   (pshapes, cshapes, in_specs["tokens"], pos), raw_fn=fn)
    return records


# ---------------------------------------------------------------------------
# run manifest
# ---------------------------------------------------------------------------

def manifest_of(records, *, config=None) -> dict:
    """Fold dry-run records into a ``RunRecorder`` manifest: the static
    HLO wire profile of each lowered fn (collective bytes by op,
    cross-pod bytes, stream-interleaving stats) under the same
    ``hlo_profile`` key a live run's trace annotations are
    cross-checked against (see ``obs.metrics`` / benchmarks/obs.py)."""
    from repro.obs import metrics as obs_metrics
    rec = obs_metrics.RunRecorder(transport="dryrun",
                                  printer=lambda *_a, **_k: None)
    if config is not None:
        rec.manifest["config"] = dict(config)
    for r in records:
        if "error" in r:
            continue
        prof = {"arch": r.get("arch"), "shape": r.get("shape"),
                "mesh": r.get("mesh"), "chips": r.get("chips"),
                "collectives": r.get("collectives")}
        if "stream_interleaving" in r:
            prof["interleaving"] = r["stream_interleaving"]
        key = f"{r.get('arch')}/{r.get('shape')}/{r.get('fn', '?')}"
        rec.attach_hlo_profile(prof, fn=key)
    return rec.manifest


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help="architecture id or 'all'")
    ap.add_argument("--shape", default="all",
                    help="input-shape id or 'all'")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--fns", default="main",
                    help="comma list: main|inner|outer|ddp|stream|gossip")
    ap.add_argument("--microbatches", type=int, default=TRAIN_MICROBATCHES)
    ap.add_argument("--variant", default="",
                    help='JSON dict, e.g. {"fsdp": false}')
    ap.add_argument("--kernel-mode", default="auto",
                    choices=["auto", "pallas", "interpret", "ref"],
                    help="fused optimizer kernels in the lowered steps "
                         "(auto = Pallas on TPU, jnp oracle elsewhere)")
    ap.add_argument("--stream-wire", default="float32",
                    choices=["float32", "bfloat16", "int4"],
                    help="transport precision of the --fns stream "
                         "round: quantized dtypes lower the packed "
                         "wire (coalesced codes+scales all-gathers), "
                         "so the analyzed cross-pod bytes are real")
    ap.add_argument("--stream-tau", type=int, default=STREAM_TAU,
                    help="issue→consume window of the --fns stream "
                         "round: with tau > 0 and a quantized "
                         "--stream-wire each fragment's gather is "
                         "issued at its snapshot offset and consumed "
                         "tau inner steps later (the overlap stats "
                         "report the measured separation)")
    ap.add_argument("--out", default="")
    ap.add_argument("--manifest", default="",
                    help="write the static HLO wire profile (collective "
                         "bytes by op, cross-pod bytes, interleaving "
                         "stats per lowered fn) as a run manifest JSON")
    args = ap.parse_args()

    archs = ARCH_NAMES if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    out = []
    for a in archs:
        for s in shapes:
            try:
                recs = dryrun_pair(a, s, multi_pod=args.multi_pod,
                                   microbatches=args.microbatches,
                                   fns=tuple(args.fns.split(",")),
                                   variant=json.loads(args.variant)
                                   if args.variant else None,
                                   kernel_mode=args.kernel_mode,
                                   stream_wire=args.stream_wire,
                                   stream_tau=args.stream_tau)
            except Exception as e:
                recs = [{"arch": a, "shape": s,
                         "multi_pod": args.multi_pod,
                         "error": f"{type(e).__name__}: {e}"}]
            for r in recs:
                tag = "OK" if "error" not in r else "FAIL"
                print(f"[{tag}] {a} × {s} × "
                      f"{'multi' if args.multi_pod else 'single'} "
                      f"{r.get('fn', '')} "
                      f"flops={r.get('flops', 0):.3e} "
                      f"coll={r.get('collectives', {}).get('total_bytes', 0):.3e} "
                      f"cross={r.get('collectives', {}).get('cross_pod_bytes', 0):.3e} "
                      f"bound={r.get('roofline', {}).get('bound', '-')}",
                      flush=True)
                if "error" in r:
                    print("   ", r["error"], flush=True)
                elif "stream_overlap" in r:
                    st = r.get("stream_interleaving", {})
                    ov = r["stream_overlap"]
                    print(f"    stream: "
                          f"{st.get('pod_all_reduces', 0)} pod syncs, "
                          f"{st.get('syncs_with_compute_after', 0)} with "
                          f"compute after; overlap: "
                          f"{ov.get('n_deferred', 0)} deferred wires, "
                          f"min {ov.get('min_steps_between', 0)} steps / "
                          f"{ov.get('min_dots_between', 0)} dots "
                          f"issue->consume"
                          + (f" (tau={ov['tau']} ok={ov['ok']})"
                             if "ok" in ov else ""), flush=True)
            out.extend(recs)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(out, f, indent=1)
        print("wrote", args.out)
    if args.manifest:
        from repro.obs.metrics import to_jsonable
        with open(args.manifest, "w") as f:
            json.dump(to_jsonable(manifest_of(out, config=vars(args))),
                      f, indent=1)
        print("wrote", args.manifest)


if __name__ == "__main__":
    main()
