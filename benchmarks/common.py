"""Shared harness for the paper-reproduction benchmarks.

Every benchmark reproduces one table/figure of the paper at micro scale
on CPU: a small Chinchilla-style transformer (the paper's own family,
reduced), the Markov-mixture data substrate whose i.i.d./non-i.i.d.
shard structure mirrors the paper's C4 clustering, and the full DiLoCo
implementation from repro.core. Perplexities are real (models genuinely
learn toward the mixture's entropy floor), so the paper's *orderings
and trends* are measurable even though absolute numbers differ from C4.

Canonical setting (scaled from the paper's 150M/H=500/k=8):
  model 2L d64; k=8 replicas; H=10 inner steps; 20 rounds; pretrain 50
  steps. One benchmark ~= tens of seconds on CPU.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DiLoCoConfig, TrainConfig, ModelConfig
from repro.core import diloco, schedules
from repro.data.sharding import make_regime, shard_weights
from repro.models.registry import Arch
from repro.optim import adamw

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "bench")

VOCAB = 256        # keeps the entropy floor far from the trained models
ALPHA_NONIID = 1.0  # shard skew: distinct but related distributions,
                    # mirroring C4 clusters (all English web text)
DEFAULTS = dict(k=8, H=10, rounds=40, batch=8, seq=64, inner_lr=3e-3,
                warmup=20, pretrain=200, seed=0)


def bench_model() -> Arch:
    cfg = ModelConfig(
        name="bench-chinchilla", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=256, vocab_size=VOCAB,
        pos_emb="rope", remat=False, attn_chunk=64)
    return Arch(cfg=cfg)


def make_setup(regime="non_iid", k=8, seed=0, imbalanced=False):
    arch = bench_model()
    loss_fn = lambda p, b: arch.loss(p, b)
    sampler = make_regime(regime, k=max(k, 1), vocab_size=VOCAB,
                          seed=seed, imbalanced=imbalanced,
                          alpha_noniid=ALPHA_NONIID)
    return arch, loss_fn, sampler


def pretrain(arch, loss_fn, sampler, steps, *, batch, seq, lr, warmup,
             total, seed=0):
    """Single-worker pretraining on the mixture (paper §3.1)."""
    params, _ = arch.init(jax.random.PRNGKey(seed), arch.cfg)
    if steps <= 0:
        return params, 0
    tcfg = TrainConfig(inner_lr=lr, warmup_steps=warmup, total_steps=total,
                       batch_size=batch, seq_len=seq)
    step = diloco.make_single_worker_step(loss_fn, tcfg, total_steps=total)
    opt = adamw.init(params)
    key = jax.random.PRNGKey(seed + 1)
    for i in range(steps):
        key, sub = jax.random.split(key)
        b = {"tokens": sampler.sample_validation(sub, batch, seq)}
        params, opt, _ = step(params, opt, b, jnp.asarray(i))
    return params, steps


def run_diloco(arch, loss_fn, sampler, params, *, k, H, rounds,
               outer_opt="nesterov", outer_lr=0.7, outer_momentum=0.9,
               drop_prob=0.0, prune_frac=0.0, weighted=False,
               compute_schedule="constant_distributed",
               cosine_stats=False, eval_every=1, step0=0,
               batch=8, seq=64, inner_lr=3e-3, warmup=20, seed=0,
               eval_batch=64, adam_eps=0.1, kernel_mode="ref",
               use_scan=True, donate=True):
    """Run T rounds; returns history list of per-round dicts.

    Default path: the scanned driver (``diloco.make_run``) — all T
    rounds execute inside one jitted call with in-graph periodic eval
    and a donated state carry, so the host dispatches once per run
    instead of once per round. ``use_scan=False`` falls back to the
    legacy per-round Python loop (one dispatch + one blocking host eval
    per round); both paths consume the same key chain and produce
    bit-identical states in ``kernel_mode="ref"``.
    """
    dcfg = DiLoCoConfig(k=k, H=H, outer_opt=outer_opt, outer_lr=outer_lr,
                        outer_momentum=outer_momentum,
                        drop_prob=drop_prob, prune_frac=prune_frac,
                        outer_adam_eps=adam_eps, kernel_mode=kernel_mode)
    total = step0 + rounds * H
    tcfg = TrainConfig(inner_lr=inner_lr, warmup_steps=warmup,
                       total_steps=total, batch_size=batch, seq_len=seq,
                       kernel_mode=kernel_mode)
    state = diloco.init_state(params, dcfg)
    state = state._replace(inner_steps_done=jnp.asarray(step0))
    val = sampler.sample_validation(jax.random.PRNGKey(10_000),
                                    eval_batch, seq)
    rng = np.random.default_rng(seed)
    drops = schedules.drop_masks(rng, drop_prob, k, rounds)
    sched = schedules.compute_schedule(compute_schedule, k, rounds)
    acts = schedules.active_masks(sched, k)
    weights = jnp.asarray(shard_weights(sampler, weighted)[:k])
    weights = weights / weights.sum()
    key = jax.random.PRNGKey(seed + 2)
    hist = []

    def record(t, vl, inner_loss, cos_mean=None, cos_std=None):
        rec = {"round": t + 1,
               "inner_steps": step0 + (t + 1) * H,
               "compute_steps": int(sched[:t + 1].sum()) * H + step0,
               "val_loss": vl, "ppl": float(np.exp(vl)),
               "inner_loss": inner_loss,
               "active": int(sched[t])}
        if cosine_stats:
            rec["cos_mean"] = cos_mean
            rec["cos_std"] = cos_std
        hist.append(rec)

    if use_scan:
        run = diloco.make_run(
            loss_fn, sampler.sample_all_shards, dcfg, tcfg,
            rounds_per_call=rounds, total_steps=total,
            compute_cosine=cosine_stats, batch_size=batch, seq_len=seq,
            eval_tokens=val, eval_every=eval_every, donate=donate)
        state, ms = run(state, key, jnp.asarray(drops),
                        jnp.asarray(acts), weights)
        ms = jax.tree.map(np.asarray, ms)
        for t in range(rounds):
            # same cadence as the legacy loop — a NaN on an eval round
            # is a genuine divergence and is recorded as such
            if (t + 1) % eval_every == 0 or t == rounds - 1:
                record(t, float(ms["val_loss"][t]),
                       float(ms["inner_loss"][t]),
                       float(ms["cos_mean"][t]) if cosine_stats else None,
                       float(ms["cos_std"][t]) if cosine_stats else None)
        return hist, state

    rnd = diloco.make_round(loss_fn, sampler.sample_all_shards, dcfg,
                            tcfg, total_steps=total,
                            compute_cosine=cosine_stats,
                            batch_size=batch, seq_len=seq)
    ev = diloco.make_eval(loss_fn)
    for t in range(rounds):
        key, sub = jax.random.split(key)
        state, m = rnd(state, sub, jnp.asarray(drops[t]),
                       jnp.asarray(acts[t]), weights)
        if (t + 1) % eval_every == 0 or t == rounds - 1:
            vl = float(ev(state.global_params, val))
            record(t, vl, float(m["inner_loss"]),
                   float(m["cos_mean"]) if cosine_stats else None,
                   float(m["cos_std"]) if cosine_stats else None)
    return hist, state


def run_baseline(arch, loss_fn, sampler, params, *, steps, batch=8,
                 seq=64, inner_lr=3e-3, warmup=20, seed=0, step0=0,
                 eval_every=10, eval_batch=64, total=None):
    """Single-worker AdamW baseline on the mixture stream."""
    # the donated step updates (params, opt) in place — work on a copy
    # so callers can reuse their params tree across runs
    params = jax.tree.map(jnp.copy, params)
    tcfg = TrainConfig(inner_lr=inner_lr, warmup_steps=warmup,
                       total_steps=total or (step0 + steps),
                       batch_size=batch, seq_len=seq)
    step = diloco.make_single_worker_step(loss_fn, tcfg,
                                          total_steps=total
                                          or (step0 + steps))
    ev = diloco.make_eval(loss_fn)
    val = sampler.sample_validation(jax.random.PRNGKey(10_000),
                                    eval_batch, seq)
    opt = adamw.init(params)
    key = jax.random.PRNGKey(seed + 3)
    hist = []
    for i in range(steps):
        key, sub = jax.random.split(key)
        b = {"tokens": sampler.sample_validation(sub, batch, seq)}
        params, opt, m = step(params, opt, b, jnp.asarray(step0 + i))
        if (i + 1) % eval_every == 0 or i == steps - 1:
            vl = float(ev(params, val))
            hist.append({"step": step0 + i + 1, "val_loss": vl,
                         "ppl": float(np.exp(vl))})
    return hist, params


def save(name: str, payload: dict) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    payload = dict(payload)
    payload["benchmark"] = name
    payload["timestamp"] = time.strftime("%Y-%m-%d %H:%M:%S")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def final_ppl(hist) -> float:
    return hist[-1]["ppl"]


def comm_bytes_per_replica(params, *, sync_steps: int, prune_frac=0.0
                           ) -> float:
    """Bytes one replica transmits for its outer gradients over a run
    (the communication column of Table 2)."""
    pbytes = sum(l.size * 4 for l in jax.tree.leaves(params))
    return pbytes * sync_steps * (1.0 - prune_frac)
