"""DiLoCo training driver (CLI).

Runs the paper's algorithm end-to-end: optional single-worker
pretraining phase, then T rounds of (H inner AdamW steps × k replicas +
one outer Nesterov step), with the paper's robustness features
switchable from the command line (data regime, communication drops,
adaptive compute schedule, outer-gradient pruning, outer optimizer).

On CPU this drives the reduced-scale models (--smoke, default) used by
the benchmark suite; the same functions lower onto the production mesh
(see dryrun.py) for TPU execution.

Example:
  PYTHONPATH=src python -m repro.launch.train \
      --arch diloco_150m --smoke --k 4 --H 20 --rounds 30 \
      --regime non_iid --outer-opt nesterov
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import DiLoCoConfig, TrainConfig
from repro.core import diloco, schedules
from repro.data.sharding import make_regime, shard_weights
from repro.models.registry import get_arch, get_smoke_arch


def build(args):
    arch = (get_smoke_arch if args.smoke else get_arch)(args.arch)
    cfg = arch.cfg
    if not args.stream_fragments:
        # these knobs only act on the streaming outer path — silently
        # running the classic full-precision outer step while the CLI
        # says "int4" would mislabel every reported number
        ignored = [flag for flag, on in (
            ("--outer-grad-dtype", args.outer_grad_dtype != "float32"),
            ("--stream-alpha", args.stream_alpha != 1.0),
            ("--stream-tau", args.stream_tau != 0),
            ("--error-feedback", args.error_feedback),
            ("--transport", args.transport != "simulated"),
            ("--no-pack-wire", not args.pack_wire),
            ("--pods", args.pods != 0)) if on]
        if ignored:
            raise SystemExit(
                f"{', '.join(ignored)} require(s) --stream-fragments "
                ">= 1 (streaming outer sync); the classic outer step "
                "would ignore them")
    if args.pods and args.transport != "sharded":
        # --pods only shapes the sharded-transport mesh; accepting it
        # on the simulated path would fake a multi-pod layout
        raise SystemExit("--pods requires --transport sharded")
    dcfg = DiLoCoConfig(k=args.k, H=args.H, outer_opt=args.outer_opt,
                        outer_lr=args.outer_lr,
                        outer_momentum=args.outer_momentum,
                        drop_prob=args.drop_prob,
                        prune_frac=args.prune_frac,
                        weighted_avg=args.weighted,
                        kernel_mode=args.kernel_mode,
                        streaming_fragments=args.stream_fragments,
                        stream_alpha=args.stream_alpha,
                        stream_tau=args.stream_tau,
                        outer_grad_dtype=args.outer_grad_dtype,
                        error_feedback=args.error_feedback,
                        transport=args.transport,
                        pack_wire=args.pack_wire,
                        param_dtype=args.param_dtype,
                        master_dtype=args.master_dtype)
    total = args.pretrain_steps + args.rounds * args.H
    tcfg = TrainConfig(inner_lr=args.inner_lr, warmup_steps=args.warmup,
                       total_steps=total, batch_size=args.batch,
                       seq_len=args.seq, seed=args.seed,
                       kernel_mode=args.kernel_mode,
                       param_dtype=args.param_dtype,
                       master_dtype=args.master_dtype)
    sampler = make_regime(args.regime, k=args.k,
                          vocab_size=cfg.vocab_size, seed=args.seed,
                          imbalanced=args.weighted)
    return arch, cfg, dcfg, tcfg, sampler


def run(args):
    arch, cfg, dcfg, tcfg, sampler = build(args)
    loss_fn = lambda p, b: arch.loss(p, b)
    key = jax.random.PRNGKey(args.seed)
    key, init_key = jax.random.split(key)
    params, _ = arch.init(init_key, cfg)
    ev = diloco.make_eval(loss_fn)
    val = sampler.sample_validation(jax.random.PRNGKey(10_000),
                                    args.eval_batch, args.seq)
    history = []

    # ---- pretraining phase (paper: 24k steps before DiLoCo) ----
    if args.pretrain_steps:
        step = diloco.make_single_worker_step(loss_fn, tcfg,
                                              total_steps=tcfg.total_steps)
        from repro.optim import adamw, precision
        pol = precision.policy_of(tcfg)
        opt = adamw.init(params, policy=pol)
        # fresh=True: the step donates (work, opt); an identity cast
        # would alias params and the donation would delete them
        work = precision.cast_tree(params, pol.param_dtype, fresh=True)
        for i in range(args.pretrain_steps):
            key, sub = jax.random.split(key)
            batch = {"tokens": sampler.sample_validation(
                sub, args.batch, args.seq)}
            work, opt, m = step(work, opt, batch, jnp.asarray(i))
            if (i + 1) % args.log_every == 0:
                vl = float(ev(work, val))
                history.append({"phase": "pretrain", "inner_steps": i + 1,
                                "val_loss": vl})
                print(f"[pretrain {i + 1}] loss={float(m['loss']):.4f} "
                      f"val={vl:.4f}", flush=True)
        # hand the master-precision params to the DiLoCo phase (the
        # working copy is a rounded view under a mixed policy); the
        # upcast keeps the DiLoCo globals/outer state f32 even under
        # the pure-bf16 policy, where no master exists
        params = precision.cast_tree(adamw.master_params(work, opt),
                                     jnp.float32)

    # ---- DiLoCo phase ----
    mesh = None
    if dcfg.streaming_fragments:
        from repro.core import streaming
        state = streaming.init_state(params, dcfg)
        if dcfg.transport == "sharded":
            from repro.core import pod_collectives
            from repro.launch.mesh import make_pod_mesh
            # default: the largest pod count that bands k evenly AND
            # tiles the visible devices (min(k, devices) alone crashes
            # on e.g. k=4 over 6 devices although pods=2 works)
            n_dev = jax.device_count()
            pods = args.pods or max(
                (p for p in range(2, args.k + 1)
                 if args.k % p == 0 and n_dev % p == 0), default=1)
            if pods < 2:
                raise SystemExit(
                    "--transport sharded needs >= 2 pods, but no pod "
                    f"count >= 2 divides both k={args.k} and the "
                    f"{jax.device_count()} visible device(s) — a "
                    "1-pod mesh would silently run zero real "
                    "cross-pod collectives. On a CPU host set "
                    "XLA_FLAGS=--xla_force_host_platform_device_"
                    "count=N (a multiple of k) before jax starts")
            mesh = make_pod_mesh(pods)
            state = pod_collectives.shard_stream_state(state, mesh)
            print(f"sharded transport: {pod_collectives.pods_of(mesh)} "
                  f"pods × {args.k // pod_collectives.pods_of(mesh)} "
                  "replicas/pod", flush=True)
    else:
        state = diloco.init_state(params, dcfg)
    rng = np.random.default_rng(args.seed)
    drops = schedules.drop_masks(rng, args.drop_prob, args.k, args.rounds)
    sched = schedules.compute_schedule(args.compute_schedule, args.k,
                                       args.rounds)
    acts = schedules.active_masks(sched, args.k)
    weights = jnp.asarray(shard_weights(sampler, args.weighted))

    def emit_round(t, m, i=None, evaled=True):
        """Append the round-t record from metrics dict ``m`` (scalar
        entries for the legacy loop, (R,) stacked entries at index
        ``i`` for the scanned driver) and print the progress line.
        ``evaled`` False marks a round skipped by the eval cadence —
        a NaN on an *evaled* round is a genuine divergence and is
        reported as such."""
        pick = (lambda x: float(x)) if i is None else \
            (lambda x: float(x[i]))
        vl = pick(m["val_loss"])
        skipped = not evaled
        rec = {"phase": "diloco", "round": t + 1,
               "inner_steps": args.pretrain_steps + (t + 1) * args.H,
               "inner_loss": pick(m["inner_loss"]),
               "val_loss": None if skipped else vl,
               "outer_gnorm": pick(m["outer_gnorm"]),
               "active": int(sched[t])}
        if args.cosine_stats:
            rec["cos_mean"] = pick(m["cos_mean"])
            rec["cos_std"] = pick(m["cos_std"])
        history.append(rec)
        val_s = "   skip" if skipped else \
            f"{vl:.4f} ppl={np.exp(vl):.2f}"
        print(f"[round {t + 1}/{args.rounds}] "
              f"inner={rec['inner_loss']:.4f} val={val_s} "
              f"active={rec['active']}", flush=True)

    t0 = time.time()
    if args.legacy_loop:
        # One jit dispatch + one blocking host eval per round — kept for
        # comparison (see benchmarks/wallclock.py).
        rnd = diloco.make_round(loss_fn, sampler.sample_all_shards, dcfg,
                                tcfg, total_steps=tcfg.total_steps,
                                compute_cosine=args.cosine_stats,
                                batch_size=args.batch, seq_len=args.seq,
                                mesh=mesh)
        for t in range(args.rounds):
            key, sub = jax.random.split(key)
            state, m = rnd(state, sub, jnp.asarray(drops[t]),
                           jnp.asarray(acts[t]), weights)
            m = dict(m, val_loss=ev(state.global_params, val))
            emit_round(t, m)
    else:
        # Scanned driver: chunks of `rounds_per_call` rounds run inside
        # one jit each (donated carry, in-graph eval every round); the
        # host only touches metrics at chunk boundaries.
        rpc = max(1, min(args.rounds_per_call or args.rounds,
                         args.rounds))
        runs = {}
        t = 0
        while t < args.rounds:
            n = min(rpc, args.rounds - t)
            if n not in runs:
                runs[n] = diloco.make_run(
                    loss_fn, sampler.sample_all_shards, dcfg, tcfg,
                    rounds_per_call=n, total_steps=tcfg.total_steps,
                    compute_cosine=args.cosine_stats,
                    batch_size=args.batch, seq_len=args.seq,
                    eval_tokens=val, eval_every=args.eval_every,
                    mesh=mesh)
            # round_offset keeps the in-graph eval cadence globally
            # aligned across chunk boundaries (traced: chunks of equal
            # size share one compiled function)
            state, ms = runs[n](state, key, jnp.asarray(drops[t:t + n]),
                                jnp.asarray(acts[t:t + n]), weights,
                                round_offset=t)
            key = ms.pop("next_key")
            ms = jax.tree.map(np.asarray, ms)
            for i in range(n):
                evaled = ((t + i + 1) % args.eval_every == 0
                          or i == n - 1)
                emit_round(t + i, ms, i, evaled=evaled)
            t += n

    print(f"done in {time.time() - t0:.1f}s; "
          f"entropy floor = {sampler.entropy_floor():.4f} "
          f"(ppl {np.exp(sampler.entropy_floor()):.2f})")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"args": vars(args), "history": history}, f, indent=1)
        print("wrote", args.out)
    if args.checkpoint:
        ckpt.save(args.checkpoint,
                  {"params": state.global_params,
                   "outer_buf": state.outer_state.buf},
                  metadata={"rounds": args.rounds, "k": args.k,
                            "H": args.H})
        print("checkpoint:", args.checkpoint)
    return history


def make_parser():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="diloco_150m")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--H", type=int, default=50)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--pretrain-steps", type=int, default=0)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--eval-batch", type=int, default=64)
    ap.add_argument("--inner-lr", type=float, default=1e-3)
    ap.add_argument("--warmup", type=int, default=50)
    ap.add_argument("--outer-opt", default="nesterov",
                    choices=["nesterov", "sgd", "sgdm", "adam"])
    ap.add_argument("--outer-lr", type=float, default=0.7)
    ap.add_argument("--outer-momentum", type=float, default=0.9)
    ap.add_argument("--regime", default="non_iid",
                    choices=["iid", "non_iid"])
    ap.add_argument("--drop-prob", type=float, default=0.0)
    ap.add_argument("--prune-frac", type=float, default=0.0)
    ap.add_argument("--weighted", action="store_true")
    ap.add_argument("--compute-schedule", default="constant_distributed",
                    choices=["constant_local", "constant_distributed",
                             "doubling", "halving", "ramp_up", "ramp_down"])
    ap.add_argument("--cosine-stats", action="store_true")
    ap.add_argument("--kernel-mode", default="ref",
                    choices=["auto", "pallas", "interpret", "ref"],
                    help="fused optimizer kernels: auto=Pallas on TPU, "
                         "ref=legacy jnp tree maps (bit-identical)")
    ap.add_argument("--rounds-per-call", type=int, default=0,
                    help="rounds scanned inside one jit "
                         "(0 = all rounds in a single call)")
    ap.add_argument("--eval-every", type=int, default=1,
                    help="in-graph eval cadence in rounds (scanned "
                         "driver; globally aligned across chunks)")
    ap.add_argument("--stream-fragments", type=int, default=0,
                    help="streaming outer sync: number of parameter "
                         "fragments P (0 = classic synchronous outer "
                         "step; see core/streaming.py)")
    ap.add_argument("--stream-alpha", type=float, default=1.0,
                    help="streaming merge weight "
                         "θ_i <- α·θ_global + (1-α)·θ_i")
    ap.add_argument("--stream-tau", type=int, default=0,
                    help="inner steps between a fragment's snapshot "
                         "and its application (simulated in-flight "
                         "collective)")
    ap.add_argument("--outer-grad-dtype", default="float32",
                    choices=["float32", "bfloat16", "int4"],
                    help="transport precision of outer gradients on "
                         "the simulated wire")
    ap.add_argument("--error-feedback", action="store_true",
                    help="streaming: keep each replica's transport "
                         "quantization residual and add it to the next "
                         "round's delta (kills the int4/bf16 rounding "
                         "bias at no wire cost)")
    ap.add_argument("--transport", default="simulated",
                    choices=["simulated", "sharded"],
                    help="streaming collective backend: 'sharded' runs "
                         "each replica on its own pod mesh slice and "
                         "reduces every fragment with a real pod-axis "
                         "collective (needs >= --pods devices; on CPU "
                         "set --xla_force_host_platform_device_count)")
    ap.add_argument("--no-pack-wire", dest="pack_wire",
                    action="store_false", default=True,
                    help="sharded quantized transport: gather the "
                         "legacy dequantized-f32 payload per leaf "
                         "instead of the packed int4 codes+scales / "
                         "bf16 wire buffer (default: packed — the "
                         "collective ships what the accounting charges)")
    ap.add_argument("--pods", type=int, default=0,
                    help="pod count of the sharded-transport mesh "
                         "(0 = min(k, device count); must divide k)")
    ap.add_argument("--param-dtype", default="float32",
                    choices=["float32", "bfloat16"],
                    help="storage dtype of the per-replica working "
                         "params + AdamW moments (bfloat16 halves the "
                         "donated params+moments carry)")
    ap.add_argument("--master-dtype", default="float32",
                    choices=["float32", "bfloat16"],
                    help="storage dtype of the master-side state; when "
                         "wider than --param-dtype each replica carries "
                         "a master copy in its AdamW state and outer "
                         "deltas are computed master-vs-master")
    ap.add_argument("--legacy-loop", action="store_true",
                    help="use the per-round Python loop instead of the "
                         "scanned driver")
    ap.add_argument("--log-every", type=int, default=200)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="")
    ap.add_argument("--checkpoint", default="")
    return ap


if __name__ == "__main__":
    run(make_parser().parse_args())
