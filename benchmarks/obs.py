"""Observability gates: the run telemetry subsystem must be free and
must be honest.

Three families of claims, written to ``BENCH_obs.json``:

  free     the recorder is pure plumbing. A recorded run's final
           global params are BITWISE identical to a bare reference
           driver (the pre-telemetry scanned loop replicated inline:
           same seeding, same masks, same chunking) — claims
           ``recorder_off_bit_identical``. And the recorder adds no
           device syncs: the scanned driver still materializes
           metrics ONCE per chunk (``recorder_single_ingest_per_
           chunk`` counts ``RunRecorder.ingest_chunk`` calls).

  honest   the Chrome traces drawn from the tick-domain world are
           structurally sound on every transport (``trace_valid_*``
           via ``obs.trace.validate_trace``), every engine-applied
           delta on a faulty async run corresponds to EXACTLY one
           delivered transfer span and every lost send to exactly one
           undelivered span (``span_application_exactly_once_k4_
           faulty``), and the byte annotations are the real wire: on
           the sharded int4 transport, trace bytes == the static
           ``sync_plan`` model == the HLO-measured cross-pod
           all-gather bytes of the lowered program, at ratio 1.000
           (``trace_wire_matches_hlo_ratio_1``).

  durable  every transport's history JSON-serializes and round-trips
           (``history_json_all_transports`` — numpy scalars must not
           crash ``json.dump``).

Run:  PYTHONPATH=src python -m benchmarks.obs [--trace-dir DIR]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

# standalone runs get 8 fake CPU devices so the sharded-transport rows
# exercise REAL pod-axis collectives (same convention as
# benchmarks/streaming.py)
if "jax" not in sys.modules and \
        "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 "
        + os.environ.get("XLA_FLAGS", ""))

import jax
import jax.numpy as jnp
import numpy as np

from . import common as C
from repro.checkpoint import checkpoint as ckpt
from repro.core import diloco, pod_collectives, schedules, streaming
from repro.data.sharding import shard_weights
from repro.launch import hlo_analysis as H_hlo
from repro.launch import train
from repro.launch.mesh import make_pod_mesh
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
OUT_PATH = os.path.join(ROOT, "BENCH_obs.json")

FAULT_FLAGS = ["--speeds", "1,2,1,3", "--link-latency", "1,1,2,1",
               "--max-retries", "1", "--preempt", "2:4:8"]


def make_args(*extra):
    base = ["--arch", "diloco_60m", "--k", "4", "--H", "4",
            "--rounds", "3", "--batch", "2", "--seq", "32",
            "--eval-batch", "8"]
    return train.make_parser().parse_args(base + list(extra))


def silent(transport):
    return obs_metrics.RunRecorder(transport=transport,
                                   printer=lambda *_a, **_k: None)


def reference_final_params(args):
    """The pre-telemetry scanned driver, replicated inline with no
    recorder anywhere near it: identical seeding, masks, chunking and
    ``make_run`` products as ``train.run``. The bitwise comparison of
    its final global params against a recorded run is the
    recorder-off gate."""
    arch, cfg, dcfg, tcfg, sampler = train.build(args)
    loss_fn = lambda p, b: arch.loss(p, b)
    key = jax.random.PRNGKey(args.seed)
    key, init_key = jax.random.split(key)
    params, _ = arch.init(init_key, cfg)
    val = sampler.sample_validation(jax.random.PRNGKey(10_000),
                                    args.eval_batch, args.seq)
    state = diloco.init_state(params, dcfg)
    rng = np.random.default_rng(args.seed)
    drops = schedules.drop_masks(rng, args.drop_prob, args.k,
                                 args.rounds)
    sched = schedules.compute_schedule(args.compute_schedule, args.k,
                                       args.rounds)
    acts = schedules.active_masks(sched, args.k)
    weights = jnp.asarray(shard_weights(sampler, args.weighted))
    rpc = max(1, min(args.rounds_per_call or args.rounds, args.rounds))
    runs, t = {}, 0
    while t < args.rounds:
        n = min(rpc, args.rounds - t)
        if n not in runs:
            runs[n] = diloco.make_run(
                loss_fn, sampler.sample_all_shards, dcfg, tcfg,
                rounds_per_call=n, total_steps=tcfg.total_steps,
                compute_cosine=args.cosine_stats,
                batch_size=args.batch, seq_len=args.seq,
                eval_tokens=val, eval_every=args.eval_every, mesh=None)
        state, ms = runs[n](state, key, jnp.asarray(drops[t:t + n]),
                            jnp.asarray(acts[t:t + n]), weights,
                            round_offset=t)
        key = ms.pop("next_key")
        t += n
    return state.global_params


def sharded_hlo_cross_bytes(args):
    """HLO-measured cross-pod all-gather bytes of ONE round of the
    sharded program ``train.run`` executes — a dedicated
    rounds_per_call=1 lowering so the per-round bytes are exact (same
    convention and reasoning as benchmarks/streaming.py)."""
    arch, cfg, dcfg, tcfg, sampler = train.build(args)
    loss_fn = lambda p, b: arch.loss(p, b)
    params, _ = arch.init(jax.random.PRNGKey(1), cfg)
    mesh = make_pod_mesh(dcfg.k)
    cpp = len(jax.devices()) // pod_collectives.pods_of(mesh)
    run1 = diloco.make_run(loss_fn, sampler.sample_all_shards, dcfg,
                           tcfg, rounds_per_call=1,
                           total_steps=tcfg.total_steps,
                           batch_size=args.batch, seq_len=args.seq,
                           donate=False, mesh=mesh)
    st = pod_collectives.shard_stream_state(
        streaming.init_state(params, dcfg), mesh)
    hlo = run1.lower(st, jax.random.PRNGKey(2)).compile().as_text()
    profile = H_hlo.wire_profile(hlo, chips_per_pod=cpp,
                                 interleaving=False)
    return (profile["collectives"]["cross_by_op"].get("all-gather", 0),
            profile)


def run_transport(name, extra, trace_dir):
    """One recorded tiny run of a transport: returns (recorder,
    trace dict, trace path, history json round-trip ok)."""
    tpath = os.path.join(trace_dir, f"trace_{name}.json")
    transport = "simulated"
    if "--transport" in extra:
        transport = extra[extra.index("--transport") + 1]
    args = make_args("--trace", tpath, *extra)
    rec = silent(transport)
    train.run(args, recorder=rec)
    with open(tpath) as f:
        trace = json.load(f)
    payload = rec.payload(args=vars(args))
    try:
        ok = json.loads(json.dumps(payload))["history"] is not None
    except (TypeError, ValueError):
        ok = False
    return rec, trace, tpath, ok


def run(repeats=1, *, out=OUT_PATH, trace_dir=None):
    t_start = time.time()
    trace_dir = trace_dir or tempfile.mkdtemp(prefix="obs_traces_")
    os.makedirs(trace_dir, exist_ok=True)
    report = {"bench": "obs", "devices": len(jax.devices()),
              "trace_dir": trace_dir}

    # ---- free: recorder-off bitwise identity ----------------------
    ck = os.path.join(trace_dir, "obs_gate.ckpt")
    recorded_args = make_args("--checkpoint", ck)
    train.run(recorded_args, recorder=silent("simulated"))
    recorded = ckpt.restore_tree(ck)["params"]
    reference = reference_final_params(make_args())
    bit_identical = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(recorded),
                        jax.tree.leaves(reference)))
    print(f"recorder-off bitwise identity: {bit_identical}")

    # ---- free: one metrics materialization per chunk --------------
    rec6 = silent("simulated")
    train.run(make_args("--rounds", "6", "--rounds-per-call", "3"),
              recorder=rec6)
    single_ingest = (rec6.ingest_calls == 2
                     and len(rec6.round_records()) == 6)
    print(f"ingest calls for 6 rounds @ rpc=3: {rec6.ingest_calls} "
          f"({len(rec6.round_records())} round records)")

    # ---- honest + durable: every transport ------------------------
    transports = {
        "sync": FAULT_FLAGS,
        "streaming": ["--stream-fragments", "2", "--stream-tau", "1"],
        "sharded": ["--transport", "sharded", "--stream-fragments",
                    "2", "--outer-grad-dtype", "int4"],
        "async": ["--transport", "async", "--ticks", "12",
                  *FAULT_FLAGS],
        "gossip": ["--transport", "gossip", "--stream-fragments", "2",
                   "--gossip-pairing", "random", *FAULT_FLAGS],
    }
    trace_valid, json_ok, rows = {}, {}, {}
    for name, extra in transports.items():
        rec, trace, tpath, ok = run_transport(name, extra, trace_dir)
        errs = obs_trace.validate_trace(trace)
        trace_valid[name] = not errs
        json_ok[name] = ok
        rows[name] = {"trace": tpath,
                      "trace_events": len(trace["traceEvents"]),
                      "transfer_spans":
                          len(obs_trace.transfer_spans(trace)),
                      "trace_wire_bytes":
                          obs_trace.trace_wire_bytes(trace),
                      "records": len(rec.records),
                      "validate_errors": errs[:5],
                      "json_roundtrip": ok}
        rows[name]["recorder"] = {"wire_bytes_total":
                                  rec.wire_bytes_total,
                                  "ingest_calls": rec.ingest_calls}
        if name == "async":
            events = rec.event_records()
            c_errs = obs_trace.span_event_correspondence(trace, events)
            rows[name]["correspondence_errors"] = c_errs[:5]
            rows[name]["applied_deltas"] = sum(
                1 for r in events if r["event"] == "arrival")
            exactly_once = not c_errs and rows[name]["applied_deltas"] > 0
        if name == "sharded":
            plan_row_bytes = sum(
                r["wire_bytes"] for r in rec.manifest["wire_plan"])
            meas, profile = sharded_hlo_cross_bytes(make_args(*extra))
            model = recorded_args.k * plan_row_bytes
            hlo_ratio = meas / model if model else 0.0
            tw = rows[name]["trace_wire_bytes"]
            trace_ratio = (tw / (recorded_args.rounds * plan_row_bytes)
                           if plan_row_bytes else 0.0)
            rows[name]["wire_check"] = {
                "plan_bytes_per_replica_round": plan_row_bytes,
                "hlo_cross_gather_bytes_per_round": meas,
                "model_bytes_per_round": model,
                "hlo_over_model": hlo_ratio,
                "trace_over_plan": trace_ratio,
                "hlo_profile": profile}
        print(f"{name}: trace_valid={trace_valid[name]} "
              f"json={json_ok[name]} "
              f"spans={rows[name]['transfer_spans']}")

    wc = rows["sharded"]["wire_check"]
    wire_ratio_1 = (abs(wc["hlo_over_model"] - 1.0) < 1e-9
                    and abs(wc["trace_over_plan"] - 1.0) < 1e-9)
    print(f"sharded wire: HLO/model={wc['hlo_over_model']:.3f} "
          f"trace/plan={wc['trace_over_plan']:.3f}")

    report["transports"] = rows
    report["claims"] = {
        "recorder_off_bit_identical": bool(bit_identical),
        "recorder_single_ingest_per_chunk": bool(single_ingest),
        "span_application_exactly_once_k4_faulty": bool(exactly_once),
        "trace_wire_matches_hlo_ratio_1": bool(wire_ratio_1),
        "history_json_all_transports": bool(all(json_ok.values())),
    }
    for name in transports:
        report["claims"][f"trace_valid_{name}"] = bool(
            trace_valid[name])
    report["total_s"] = round(time.time() - t_start, 1)

    with open(out, "w") as f:
        json.dump(obs_metrics.to_jsonable(report), f, indent=1)
    print("wrote", out)
    C.save("obs", report)
    return report


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=OUT_PATH)
    ap.add_argument("--trace-dir", default="",
                    help="keep the per-transport trace JSONs here "
                         "(default: a temp dir)")
    a = ap.parse_args(argv)
    report = run(out=a.out, trace_dir=a.trace_dir or None)
    bad = [k for k, v in report["claims"].items() if not v]
    if bad:
        print("FAILED claims:", ", ".join(bad))
        return 1
    print("all claims hold:", ", ".join(sorted(report["claims"])))
    return 0


if __name__ == "__main__":
    sys.exit(main())
