"""Streaming outer sync: fragment-scheduled, overlap-capable, quantized
DiLoCo communication (Streaming DiLoCo, Douillard et al., 2025).

Classic DiLoCo's one remaining cost is the every-H-steps outer
all-reduce of full model-size bytes — a full-model barrier. This module
replaces it with a *stream* of fragment-sized collectives:

  * the parameter tree is split into P contiguous fragments
    (``core/fragments.py``), each with its own outer Nesterov state;
  * fragment p's outer step fires at inner offset p·H/P of the round,
    so at any instant only ~1/P of the model is on the wire — peak
    bytes-per-sync drop P×;
  * the collective is *overlapped* with compute: the fragment's outer
    gradient is snapshotted at the send offset, and the reduced result
    is applied ``tau`` inner steps later (possibly in the next round) —
    modeling an all-reduce that runs concurrently with inner training
    on stale fragment params;
  * instead of hard-resetting replicas to the new global fragment, the
    synced fragment is *merged* with each replica's local progress;
  * outer gradients take a per-replica quantize→dequantize round trip
    at the transport precision before the simulated all-reduce
    (``kernels/quantize.py``), cutting wire bytes another 2×–7.5×.
    int4 scale blocks are formed over each replica's flattened leaf, so
    they never mix two replicas' values; blocks may still span a leaf's
    fragment-band boundary within one replica — a known approximation
    of a sender that packs each fragment region separately.

Knob ↔ paper-term map (DiLoCoConfig):

  streaming_fragments  P, the paper's number of fragments; 0 = classic
                       synchronous DiLoCo, 1 = one full-model fragment
                       (bit-identical to synchronous with the defaults
                       below — tested).
  stream_alpha         α, the mixing weight of the merge
                       θ_i ← α·θ_global + (1−α)·θ_i  (paper eq. 4;
                       α=1 recovers the classic hard reset).
  stream_tau           the overlap window in inner steps between a
                       fragment's snapshot and its application (the
                       paper simulates the collective finishing within
                       τ steps of compute; τ=0 = blocking collective).
  outer_grad_dtype     transport precision of the outer gradients on
                       the wire: float32 | bfloat16 | int4 (per-block
                       f32 scales; the paper's low-precision
                       collectives).
  stream_overrides     ((path-regex, fragment), ...) pattern overrides
                       for the fragment partitioner.
  transport            collective backend: "simulated" (replica-stacked
                       averaging on one device — this module's original
                       semantics) or "sharded" (each replica on its own
                       "pod" mesh slice, fragments reduced by real
                       pod-axis collectives under shard_map — see
                       core/pod_collectives.py; pass mesh=... to
                       make_round/make_run).
  pack_wire            sharded quantized transport only: True (default)
                       ships the real packed payload — every leaf
                       region's int4 codes+scales (or bf16 elements)
                       coalesced into ONE wire buffer per fragment,
                       reduced by a single pod-axis all-gather — so the
                       lowered HLO carries exactly the bytes the packed
                       static model charges; False keeps the legacy
                       per-leaf dequantized-f32 gathers for comparison.

The streaming round plugs into the scanned driver: ``diloco.make_run``
(and ``make_round``) dispatch here when ``streaming_fragments > 0``, so
R streaming rounds still execute inside ONE jit. State is
``StreamState`` (build with ``init_state``), which carries the classic
``DiLoCoState`` plus the in-flight reduced fragments (``pending``) and
a per-fragment first-send latch (``armed``).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DiLoCoConfig, TrainConfig
from repro.optim import precision
from . import diloco, fragments, outer_opt, pod_collectives
from .compression import sign_prune


class StreamState(NamedTuple):
    """Streaming carry = classic DiLoCo state + stream bookkeeping.

    pending: param-shaped tree holding, per fragment region, the most
    recently reduced (averaged, transport-quantized) outer gradient —
    written at the fragment's send, consumed at its apply τ steps later.
    armed: (P,) float latch, 1 after a fragment's first send — applies
    before the first send (wrapped applies in round 0) are no-ops.
    residual: per-replica (k, ...) error-feedback accumulator for the
    quantized transport (``dcfg.error_feedback``): each replica keeps
    the rounding error its quantizer introduced and adds it to the next
    round's delta, so the mean transport bias decays to zero at no wire
    cost. None when error feedback is off or transport is float32.
    inflight: the double-buffered in-flight collective slot (quantized
    transports at τ>0 only, else None). One entry per fragment, each
    ``(payload, mask)``: the RAW gathered wire — the (k, W) packed byte
    buffer on the packed transport, the (k, ...) per-leaf stacked
    payload elsewhere — plus the (k,) communication-mask snapshot taken
    at the send. The collective is *issued* at the fragment's send
    offset and its result is first *consumed* (decoded + mask-reduced
    into ``pending``) at the apply τ inner steps later, so the τ
    inner-step dots sit between collective-start and first use in
    program order. None entries mark override-emptied fragments. The
    mask snapshot makes wrapped fragments (applied in the NEXT round,
    under a different drop mask) reduce with the mask of the round
    that sent them — exactly the values the eager path produced.
    """
    base: diloco.DiLoCoState
    pending: Any
    armed: jnp.ndarray
    residual: Any = None
    inflight: Any = None

    # conveniences so StreamState is a drop-in for DiLoCoState readers
    @property
    def global_params(self):
        return self.base.global_params

    @property
    def outer_state(self):
        return self.base.outer_state

    @property
    def replica_params(self):
        return self.base.replica_params

    @property
    def inner_state(self):
        return self.base.inner_state

    @property
    def outer_t(self):
        return self.base.outer_t

    @property
    def inner_steps_done(self):
        return self.base.inner_steps_done


def deferred_consume(dcfg: DiLoCoConfig) -> bool:
    """True when the streaming round runs the real issue/consume split:
    each fragment's collective is issued at the send offset and its raw
    result is first consumed τ inner steps later at the apply. Only the
    quantized transports defer — their sharded reduction is already a
    gather + local decode, so the decode moves wholesale to the apply;
    f32 keeps the eager weighted psum whose bit-identity to the
    simulated tensordot is a standing cross-commit gate. τ=0 has no
    window to overlap, so it keeps the eager path (and the PR 7 state
    tree) too."""
    return (int(dcfg.streaming_fragments) >= 1
            and int(dcfg.stream_tau) > 0
            and dcfg.outer_grad_dtype in ("bfloat16", "int4"))


def _packed_wire(dcfg: DiLoCoConfig) -> bool:
    return (getattr(dcfg, "transport", "simulated") == "sharded"
            and getattr(dcfg, "pack_wire", True)
            and dcfg.outer_grad_dtype in ("bfloat16", "int4"))


def _init_inflight(params, dcfg: DiLoCoConfig):
    """Zero-filled in-flight slots matching what round_core stores per
    fragment: the packed transport buffers the (k, W) gathered wire
    bytes, every other transport the (k, ...) stacked per-leaf payload
    restricted to the fragment's active leaves; both pair the buffer
    with a (k,) mask snapshot. None when the config has no deferral."""
    from repro.kernels import ops as kops
    if not deferred_consume(dcfg):
        return None
    P = max(1, int(dcfg.streaming_fragments))
    part = fragments.partition_params(params, P,
                                      overrides=dcfg.stream_overrides)
    k = int(dcfg.k)
    mask0 = lambda: jnp.zeros((k,), jnp.float32)
    slots = []
    if _packed_wire(dcfg):
        regs = fragments.fragment_regions(part, params)
        wdt = kops.wire_dtype(dcfg.outer_grad_dtype)
        for p in range(P):
            W = sum(kops.wire_elems(r.elems, dcfg.outer_grad_dtype)
                    for r in regs[p])
            slots.append(None if W == 0 else
                         (jnp.zeros((k, W), wdt), mask0()))
    else:
        leaves = jax.tree_util.tree_leaves(params)
        for p in range(P):
            mk_l = jax.tree_util.tree_leaves(part.masks[p])
            active = [bool(np.any(np.asarray(mm))) for mm in mk_l]
            if not any(active):
                slots.append(None)
                continue
            payload = tuple(
                jnp.zeros((k,) + l.shape, jnp.float32) if on else None
                for on, l in zip(active, leaves))
            slots.append((payload, mask0()))
    return tuple(slots)


def init_state(params, dcfg: DiLoCoConfig) -> StreamState:
    """Start streaming DiLoCo from ``params`` (cf. diloco.init_state)."""
    P = max(1, int(dcfg.streaming_fragments))
    residual = None
    if dcfg.error_feedback and dcfg.outer_grad_dtype != "float32":
        residual = jax.tree.map(
            lambda p: jnp.zeros((dcfg.k,) + p.shape, jnp.float32),
            params)
    return StreamState(
        base=diloco.init_state(params, dcfg),
        pending=jax.tree.map(jnp.zeros_like, params),
        armed=jnp.zeros((P,), jnp.float32),
        residual=residual,
        inflight=_init_inflight(params, dcfg))


def quantize_with_feedback(d, res, dtype: str, *, mode: str = "ref"):
    """One error-feedback transport step: quantize ``d + res`` (the
    fresh delta plus the residual the quantizer left behind last time)
    and return (quantized, new_residual). Over repeated rounds the
    residual re-injects every rounding error into a later transport, so
    the *mean* transported value converges to the true mean delta —
    the quantization bias vanishes at no wire cost."""
    from repro.kernels import ops as kops
    d_in = d + res
    q = kops.quant_roundtrip(d_in, dtype, mode=mode)
    return q, d_in - q


def make_stream_round_body(loss_fn, sample_fn, dcfg: DiLoCoConfig,
                           tcfg: TrainConfig, *, total_steps=None,
                           compute_cosine: bool = False,
                           batch_size=None, seq_len=None, mesh=None):
    """Un-jitted streaming round, signature-compatible with
    ``diloco._make_round_body``: round_body(StreamState, key, drop_mask,
    active_mask, weights) -> (StreamState, metrics).

    The round is a static sequence of inner-step segments delimited by
    the fragment schedule's send/apply events; with P=1, α=1, τ=0 and
    float32 transport it is one full-H segment followed by a full-tree
    send+apply — bit-identical to the synchronous round (tested).

    ``dcfg.transport`` selects the collective backend: "simulated"
    averages the replica-stacked arrays on one device; "sharded" runs
    the round under ``shard_map`` over ``mesh``'s "pod" axis — each pod
    carries a contiguous band of k/pods replicas, inner steps are pure
    pod-local compute, and every fragment is reduced by a real pod-axis
    collective (``core/pod_collectives.py``) at its staggered offset.
    """
    P = int(dcfg.streaming_fragments)
    if P < 1:
        raise ValueError("make_stream_round_body needs "
                         "streaming_fragments >= 1")
    if dcfg.outer_opt != "nesterov":
        raise NotImplementedError(
            "streaming outer sync supports outer_opt='nesterov' only "
            f"(got {dcfg.outer_opt!r})")
    transport = getattr(dcfg, "transport", "simulated")
    if transport not in ("simulated", "sharded"):
        raise ValueError(f"unknown transport {transport!r}: expected "
                         "'simulated' or 'sharded'")
    sharded = transport == "sharded"
    if sharded:
        n_pods = pod_collectives.validate_mesh(mesh, dcfg.k)
        if compute_cosine:
            raise NotImplementedError(
                "compute_cosine needs cross-pod delta gathers; run it "
                "on transport='simulated'")
        axis = pod_collectives.POD_AXIS
    else:
        n_pods, axis = 1, None
    # packed wire: the sharded quantized transport ships real
    # codes+scales bytes, one coalesced all-gather per fragment
    packed = _packed_wire(dcfg)
    # defer: issue the collective at the send, first consume its raw
    # result at the apply τ steps later (see deferred_consume)
    defer = deferred_consume(dcfg)
    k_loc = dcfg.k // n_pods
    sched = fragments.schedule(P, dcfg.H, dcfg.stream_tau)
    alpha = float(dcfg.stream_alpha)
    qdtype = dcfg.outer_grad_dtype
    kernel_mode = getattr(dcfg, "kernel_mode", "ref")
    mixed = precision.policy_of(dcfg).mixed
    inner_step_tok = diloco.make_inner_step(
        lambda p, b: loss_fn(p, b), tcfg, total_steps)
    B = batch_size or tcfg.batch_size
    S = seq_len or tcfg.seq_len

    def round_core(sstate: StreamState, key, drop_mask,
                   active_mask, weights):
        from repro.kernels import ops as kops

        st = sstate.base
        part = fragments.partition_params(
            st.global_params, P, overrides=dcfg.stream_overrides)
        k, H = dcfg.k, dcfg.H
        # masks/weights stay full (k,) on every pod — the mask algebra
        # (denom, drop_frac) is then the exact op sequence of the
        # simulated path; only replica-banded tensors go local
        m = drop_mask * active_mask * weights
        denom = jnp.maximum(m.sum(), 1e-9)
        adopt = jnp.maximum(drop_mask, 1.0 - active_mask)
        if axis is not None:
            m_loc = pod_collectives.band_slice(m, k_loc, axis)
            act_loc = pod_collectives.band_slice(active_mask, k_loc,
                                                 axis)
            adopt_loc = pod_collectives.band_slice(adopt, k_loc, axis)
        else:
            m_loc, act_loc, adopt_loc = m, active_mask, adopt

        keys = jax.random.split(key, H)
        toks = jax.vmap(lambda kk: sample_fn(kk, B, S))(keys)
        toks = jnp.swapaxes(toks, 0, 1)                    # (k',H,B,S)
        if axis is not None:
            # every pod samples the full shard set (replicated compute,
            # bitwise the simulated data) and keeps its own band
            toks = pod_collectives.band_slice(toks, k_loc, axis)
        else:
            toks = toks[:k]                                 # (k,H,B,S)
        batches = {"tokens": toks}

        gp = st.global_params
        rp = st.replica_params
        ist = st.inner_state
        buf = st.outer_state.buf
        buf2 = st.outer_state.buf2
        count = st.outer_state.count
        pending = sstate.pending
        armed = sstate.armed
        residual = sstate.residual
        if defer and sstate.inflight is None:
            raise ValueError(
                "deferred streaming round (quantized, tau>0) needs the "
                "in-flight slot: build the state with "
                "streaming.init_state under the same DiLoCoConfig")
        inflight = (list(sstate.inflight) if sstate.inflight is not None
                    else None)
        pos = 0
        seg_ms = []
        deltas_acc = (jax.tree.map(jnp.zeros_like, rp)
                      if compute_cosine else None)

        # per-fragment static leaf activity: a sync only computes on
        # leaves its fragment touches (masks are concrete at trace
        # time), so whole-leaf work for the other fragments is skipped
        # outright; the residual waste is confined to stacked leaves a
        # fragment splits by layer.
        treedef = jax.tree_util.tree_structure(gp)
        leaves = jax.tree_util.tree_leaves
        leaf_active = [tuple(bool(np.any(np.asarray(l))) for l in
                             leaves(mk)) for mk in part.masks]
        lr_, mu = dcfg.outer_lr, dcfg.outer_momentum
        frag_regions = (fragments.fragment_regions(part, gp)
                        if packed else None)

        def packed_issue(frag, gp_, src_, residual_):
            """Issue one packed-wire fragment collective: per leaf
            region, quantize the local band's delta (+ error-feedback
            residual) to the real wire format (``kops.wire_encode``),
            concatenate every region's buffer, and start ONE pod-axis
            all-gather of the coalesced bytes. Scale blocks are formed
            per replica per region on the local shard (pod-local by
            construction); residuals never touch the wire. Returns the
            RAW gathered (k, W) wire — undecoded, so the consumer can
            run τ steps later — and the updated residual; (None,
            residual) for an override-emptied fragment."""
            regs = frag_regions[frag]
            if not regs:          # override-emptied fragment: no wire
                return None, residual_
            gp_l, src_l = leaves(gp_), leaves(src_)
            res_l = (list(leaves(residual_))
                     if residual_ is not None else None)
            comm = (m_loc > 0)[:, None]
            wires, res_entries = [], []
            for r in regs:
                d = gp_l[r.leaf][None] - src_l[r.leaf]
                if dcfg.prune_frac > 0:
                    d = jax.vmap(lambda dd: sign_prune(
                        dd, dcfg.prune_frac, mode=kernel_mode))(d)
                d_r = fragments.region_take(d, r, lead_axes=1)
                if res_l is not None:
                    res_r = fragments.region_take(res_l[r.leaf], r,
                                                  lead_axes=1)
                    d_r = d_r + res_r
                wire, local = jax.vmap(lambda v: kops.wire_encode(
                    v, qdtype, mode=kernel_mode))(d_r)
                wires.append(wire)
                if res_l is not None:
                    # communicating replicas consume their residual;
                    # dropped/inactive ones keep accumulating (their
                    # payload never enters the mean)
                    res_entries.append((r, jnp.where(
                        comm, d_r - local, res_r)))
            gathered = pod_collectives.gather_wire(
                jnp.concatenate(wires, axis=1), axis=axis)
            for r, nres in res_entries:
                res_l[r.leaf] = fragments.region_put(
                    res_l[r.leaf], r, nres, lead_axes=1)
            new_res = (jax.tree_util.tree_unflatten(treedef, res_l)
                       if res_l is not None else None)
            return gathered, new_res

        def packed_reduce(frag, gathered, m_r, denom_r, pending_):
            """Consume one fragment's gathered wire: dequantize each
            region and mask-reduce in the simulated path's op order,
            writing the result into ``pending``. ``m_r``/``denom_r``
            are the communication mask and its sum AT THE SEND (the
            in-flight snapshot when deferred) so a wrapped fragment is
            reduced with the round that produced it."""
            regs = frag_regions[frag]
            pend_l = list(leaves(pending_))
            off = 0
            for r in regs:
                W = kops.wire_elems(r.elems, qdtype)
                # the simulated transport's decode+reduce, verbatim
                # (fused to one kernel launch under kernel_mode)
                a = kops.wire_reduce(
                    gathered[:, off:off + W], r.elems, qdtype,
                    m_r, denom_r, mode=kernel_mode)
                off += W
                pend_l[r.leaf] = fragments.region_put(
                    pend_l[r.leaf], r, a)
            return jax.tree_util.tree_unflatten(treedef, pend_l)

        for steps, acts in sched.phases:
            if steps:
                seg = jax.tree.map(lambda t: t[:, pos:pos + steps],
                                   batches)
                rp, ist, ms = diloco.inner_phase(
                    inner_step_tok, rp, ist, seg,
                    st.inner_steps_done + pos, active_mask=act_loc)
                seg_ms.append(ms)
                pos += steps
            for ev in acts:
                mk_l = leaves(part.masks[ev.fragment])
                act_l = leaf_active[ev.fragment]
                if ev.kind == "send" and packed:
                    gathered, residual = packed_issue(
                        ev.fragment, gp,
                        ist.master if mixed else rp, residual)
                    if gathered is None:
                        pass          # override-emptied fragment
                    elif defer:
                        # double-buffer: park the RAW wire + the mask
                        # snapshot; the decode runs at the apply, τ
                        # inner steps of dots from here
                        inflight[ev.fragment] = (gathered, m)
                    else:
                        pending = packed_reduce(
                            ev.fragment, gathered, m, denom, pending)
                    armed = armed.at[ev.fragment].set(1.0)
                elif ev.kind == "send":
                    # snapshot Δ_i = θ_frag − θ_i,frag (master-vs-master
                    # under a mixed policy), quantize for the wire, and
                    # reduce — the simulated all-reduce starts here and
                    # lands τ steps later at the apply
                    da_l = (leaves(deltas_acc) if compute_cosine
                            else [None] * len(mk_l))
                    src_l = (leaves(ist.master) if mixed
                             else leaves(rp))
                    res_l = (leaves(residual) if residual is not None
                             else [None] * len(mk_l))
                    new_pd, new_da, new_res, new_il = [], [], [], []
                    for on, q, g, r, pe, da, res in zip(
                            act_l, mk_l, leaves(gp), src_l,
                            leaves(pending), da_l, res_l):
                        if not on:
                            new_pd.append(pe)
                            new_da.append(da)
                            new_res.append(res)
                            new_il.append(None)
                            continue
                        d = g[None] - r
                        if dcfg.prune_frac > 0:
                            d = jax.vmap(
                                lambda dd: sign_prune(
                                    dd, dcfg.prune_frac,
                                    mode=kernel_mode))(d)
                        # quantize per replica (vmap over the k axis):
                        # a real sender's int4 scale blocks never span
                        # two replicas' deltas, so neither do ours
                        if res is not None:
                            d, nres = jax.vmap(
                                lambda dd, rr: quantize_with_feedback(
                                    dd, rr, qdtype, mode=kernel_mode)
                            )(d, res)
                            # only replicas whose packet enters the
                            # average consume their residual; dropped /
                            # inactive replicas never sent, so their
                            # error keeps accumulating for later rounds
                            comm = (m_loc > 0).reshape(
                                (k_loc,) + (1,) * (nres.ndim - 1))
                            new_res.append(
                                jnp.where((q > 0) & comm, nres, res))
                        else:
                            d = jax.vmap(
                                lambda dd: kops.quant_roundtrip(
                                    dd, qdtype, mode=kernel_mode))(d)
                            new_res.append(res)
                        if defer:
                            # issue only: gather the stacked payload
                            # (identity on the simulated transport) and
                            # park it; the reduce runs at the apply
                            new_il.append(
                                pod_collectives.fragment_gather(
                                    d, dtype=qdtype, axis=axis)
                                if axis is not None else d)
                            new_pd.append(pe)
                        else:
                            if axis is not None:
                                # THE cross-pod collective: psum for
                                # f32, gather + local dequant-reduce
                                # for the quantized wire (pod-local
                                # scale blocks)
                                a = pod_collectives.fragment_mean(
                                    d, m, m_loc, denom, dtype=qdtype,
                                    axis=axis)
                            else:
                                a = (jnp.tensordot(m, d, axes=(0, 0))
                                     / denom)
                            new_pd.append(jnp.where(q > 0, a, pe))
                        if compute_cosine:
                            new_da.append(jnp.where(q > 0, d, da))
                    if defer and any(x is not None for x in new_il):
                        inflight[ev.fragment] = (tuple(new_il), m)
                    pending = jax.tree_util.tree_unflatten(treedef,
                                                           new_pd)
                    if residual is not None:
                        residual = jax.tree_util.tree_unflatten(
                            treedef, new_res)
                    if compute_cosine:
                        deltas_acc = jax.tree_util.tree_unflatten(
                            treedef, new_da)
                    armed = armed.at[ev.fragment].set(1.0)
                else:                                       # apply
                    if defer and inflight[ev.fragment] is not None:
                        # CONSUME: first use of the collective issued
                        # τ inner steps ago — decode the raw payload
                        # and mask-reduce with the mask snapshotted at
                        # the send (a wrapped fragment crossed a round
                        # boundary; this round's drop mask is not the
                        # one that sent it)
                        payload, m_snap = inflight[ev.fragment]
                        # pin the consume AFTER the overlap window in
                        # the schedule, not just the source: the decode
                        # depends only on the gathered bytes, so
                        # without this barrier the backend is free to
                        # hoist it back next to the collective and
                        # re-serialize the wire. Tying it to the
                        # post-window replica params (an output of the
                        # τ inner steps) makes "issued at the send,
                        # consumed τ dots later" a dataflow fact the
                        # lowered program order must honor (identity on
                        # values; HLO-gated in hlo_analysis)
                        payload = jax.lax.optimization_barrier(
                            (payload, leaves(rp)[0]))[0]
                        denom_snap = jnp.maximum(m_snap.sum(), 1e-9)
                        if packed:
                            pending = packed_reduce(
                                ev.fragment, payload, m_snap,
                                denom_snap, pending)
                        else:
                            pend_l = list(leaves(pending))
                            for li, (on, q) in enumerate(
                                    zip(act_l, mk_l)):
                                if not on:
                                    continue
                                a = jnp.tensordot(
                                    m_snap, payload[li],
                                    axes=(0, 0)) / denom_snap
                                pend_l[li] = jnp.where(q > 0, a,
                                                       pend_l[li])
                            pending = jax.tree_util.tree_unflatten(
                                treedef, pend_l)
                    # fused-dispatch Nesterov (same math as
                    # outer_opt.update(kind="nesterov")) on the
                    # fragment's leaves only, latched on the first send
                    ok = armed[ev.fragment] > 0
                    mst_l = leaves(ist.master) if mixed else None
                    new_gp, new_buf, new_rp, new_mst = [], [], [], []
                    for li, (on, q, g, b, pe, r) in enumerate(zip(
                            act_l, mk_l, leaves(gp), leaves(buf),
                            leaves(pending), leaves(rp))):
                        w = mst_l[li] if mixed else None
                        if not on:
                            new_gp.append(g)
                            new_buf.append(b)
                            new_rp.append(r)
                            new_mst.append(w)
                            continue
                        if kernel_mode != "ref":
                            g2, b2 = kops.nesterov_update_tree(
                                g, pe, b, lr=lr_, momentum=mu,
                                mode=kernel_mode)
                        else:
                            b2 = mu * b + pe
                            g2 = g - lr_ * (mu * b2 + pe)
                        sel = (q > 0) & ok
                        g2 = jnp.where(sel, g2, g)
                        new_gp.append(g2)
                        new_buf.append(jnp.where(sel, b2, b))
                        # merge against the high-precision copy when
                        # one exists; the replica working copy adopts
                        # the result at its storage dtype
                        hp = w if mixed else r
                        tgt = (jnp.broadcast_to(g2[None], hp.shape)
                               if alpha >= 1.0
                               else alpha * g2[None] + (1.0 - alpha) * hp)
                        c = (sel & (adopt_loc.reshape(
                            (k_loc,) + (1,) * g2.ndim) > 0))
                        new_rp.append(jnp.where(c, tgt.astype(r.dtype),
                                                r))
                        if mixed:
                            new_mst.append(jnp.where(c, tgt, w))
                    gp = jax.tree_util.tree_unflatten(treedef, new_gp)
                    buf = jax.tree_util.tree_unflatten(treedef, new_buf)
                    rp = jax.tree_util.tree_unflatten(treedef, new_rp)
                    if mixed:
                        ist = ist._replace(
                            master=jax.tree_util.tree_unflatten(
                                treedef, new_mst))
                    count = jnp.where(ok, count + 1, count)

        ms = {key_: jnp.concatenate([sm[key_] for sm in seg_ms], axis=1)
              for key_ in seg_ms[0]}
        new_base = diloco.DiLoCoState(
            global_params=gp,
            outer_state=outer_opt.OuterState(buf, buf2, count),
            replica_params=rp,
            inner_state=ist,
            outer_t=st.outer_t + 1,
            inner_steps_done=st.inner_steps_done + H)

        if axis is not None:
            # loss metrics live per local replica band: fold the bands
            # into the global replica mean (equal bands, exact mean)
            loss_mean = pod_collectives.replica_mean(ms["loss"],
                                                     axis=axis)
            loss_last = pod_collectives.replica_mean(ms["loss"][:, -1],
                                                     axis=axis)
        else:
            loss_mean = ms["loss"].mean()
            loss_last = ms["loss"][:, -1].mean()
        om = {
            "outer_gnorm": diloco._tree_norm(pending),
            "drop_frac": 1.0 - drop_mask.mean(),
            "inner_loss": loss_mean,
            "inner_loss_last": loss_last,
            # wire bytes one replica sends: peak per sync event and
            # total over the round's P syncs (exact: int4's per-block
            # f32 scales are charged per contiguous leaf region, the
            # unit the sender packs and quantizes; on the packed
            # transport this is the byte-exact size of the gathered
            # buffers, on the simulated paths the legacy static model)
            "stream_peak_sync_bytes":
                jnp.float32(max(sum(kops.transport_bytes(e, qdtype,
                                                         packed=packed)
                                    for e in regs)
                                for regs in part.region_sizes)),
            "stream_round_sync_bytes":
                jnp.float32(sum(kops.transport_bytes(e, qdtype,
                                                     packed=packed)
                                for regs in part.region_sizes
                                for e in regs)),
        }
        if compute_cosine:
            cm, cs = diloco._pairwise_cosine(deltas_acc, m)
            om["cos_mean"], om["cos_std"] = cm, cs
        return StreamState(new_base, pending, armed, residual,
                           tuple(inflight) if inflight is not None
                           else None), om

    def round_body(sstate: StreamState, key, drop_mask=None,
                   active_mask=None, weights=None):
        ones = jnp.ones((dcfg.k,), jnp.float32)
        drop_mask = ones if drop_mask is None else drop_mask
        active_mask = ones if active_mask is None else active_mask
        weights = ones if weights is None else weights
        if not sharded:
            return round_core(sstate, key, drop_mask, active_mask,
                              weights)
        specs = pod_collectives.stream_state_specs(sstate)
        fn = pod_collectives.shard_round_body(round_core, mesh, specs)
        return fn(sstate, key, drop_mask, active_mask, weights)

    return round_body


def sync_plan(params, dcfg: DiLoCoConfig) -> tuple:
    """Static per-fragment outer-sync plan for one streaming round —
    the tick-domain schedule telemetry draws (``obs/trace.py``) and
    the run manifest ships. One dict per fragment: send/apply
    inner-step offsets (``fragments.schedule``), element count,
    contiguous region count, and the per-replica wire bytes one sync
    event ships — the SAME per-region charge the round metrics
    ``stream_peak_sync_bytes`` / ``stream_round_sync_bytes`` use
    (byte-exact packed accounting on the packed sharded transport,
    the legacy static model elsewhere), so trace annotations, round
    metrics, and the HLO-measured gather bytes all reconcile."""
    from repro.kernels import ops as kops
    P = max(1, int(dcfg.streaming_fragments))
    part = fragments.partition_params(params, P,
                                      overrides=dcfg.stream_overrides)
    sched = fragments.schedule(P, dcfg.H, dcfg.stream_tau)
    packed = (getattr(dcfg, "transport", "simulated") == "sharded"
              and getattr(dcfg, "pack_wire", True)
              and dcfg.outer_grad_dtype in ("bfloat16", "int4"))
    plan = []
    for p in range(P):
        regs = part.region_sizes[p]
        plan.append({
            "fragment": p,
            "send_step": int(sched.send_offsets[p]),
            "apply_step": int(sched.apply_offsets[p]),
            "elems": int(part.sizes[p]),
            "regions": len(regs),
            "wire_dtype": dcfg.outer_grad_dtype,
            "packed": packed,
            "wire_bytes": float(sum(
                kops.transport_bytes(int(e), dcfg.outer_grad_dtype,
                                     packed=packed) for e in regs)),
            "crosses_round": int(sched.apply_offsets[p]) > int(dcfg.H),
            # True when the collective's raw result is first consumed
            # at the apply (real issue/consume overlap) rather than
            # decoded eagerly at the send
            "deferred": deferred_consume(dcfg),
        })
    return tuple(plan)
