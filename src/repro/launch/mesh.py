"""Production mesh construction (TPU v5e target).

Single-pod: (data=16, model=16) — 256 chips, one DiLoCo island.
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; the "pod" axis IS
DiLoCo's replica axis: each pod holds one model replica, inner steps
never communicate across it, and the outer step's one all-reduce rides
the (slow) cross-pod links once every H steps.

Functions, not module constants — importing this module must not touch
jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests use small fake-device meshes)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_pod_mesh(pods: int, *, n_devices: int | None = None):
    """(pod=pods, data=rest) mesh over the visible devices — the home
    of the sharded streaming transport (transport="sharded"): one
    contiguous band of DiLoCo replicas per pod slice, fragment
    collectives over the "pod" axis. On a CPU host, fake the device
    count with --xla_force_host_platform_device_count=N first."""
    n = n_devices or len(jax.devices())
    if pods < 1 or n % pods != 0:
        raise ValueError(
            f"cannot lay {pods} pods over {n} devices: pods must "
            "divide the device count")
    return jax.make_mesh((pods, n // pods), ("pod", "data"))


def pods_of(mesh) -> int:
    names = dict(zip(mesh.axis_names, mesh.devices.shape))
    return names.get("pod", 1)


def chips_of(mesh) -> int:
    return mesh.devices.size
