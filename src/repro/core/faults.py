"""Fault-injection harness: scripted failure scenarios for every
outer-sync transport.

The paper's robustness results (Fig 7/8) and its §5 asynchronous
future work are all statements about *failure modes*: stragglers,
dropped outer gradients, preemptible capacity leaving and joining
mid-run, slow WAN links. This module turns those modes into one
reusable, deterministic ``Scenario`` object that every transport tier
consumes through the view that fits its execution model:

  * round-driven paths (sync / streaming / sharded / gossip) consume
    ``round_masks`` — per-round (R, k) drop and active masks in the
    exact stacked layout ``diloco.make_run`` takes — plus
    ``sync_round_ticks`` for the wallclock bill a barrier pays per
    round (the slowest worker plus the slowest link);
  * the barrier-free async engine (``core/async_diloco.py``) consumes
    ``timeline`` — the full ordered event stream (phase completions
    with per-link latency, send drops with retry/backoff, preemption
    leave/join) that drives its no-barrier apply loop.

Determinism is the point: a Scenario is a pure function of its fields
(the rng is seeded per scenario), so a preempted-and-restored run
replays the *same* timeline and can be bit-compared against an
uninterrupted one, and hypothesis can shrink failing schedules.

Time is measured in abstract wall-clock *ticks*: 1 tick = the fastest
worker's phase (H inner steps) — the unit the seed async simulation
already used.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import NamedTuple

import numpy as np


class Arrival(NamedTuple):
    """A worker's outer gradient reaching the parameter server.

    ``uid`` identifies the underlying phase completion: retries of a
    dropped send share the uid of the payload they resend, and at most
    one Arrival per uid ever appears in a timeline — the exactly-once
    contract the apply-loop property tests check.
    """
    tick: int          # arrival (application) time at the server
    worker: int
    uid: int           # unique phase-completion id
    dispatch_tick: int  # when the phase's params were dispatched
    finish_tick: int   # when the phase's compute finished
    attempt: int       # 0 = first send, n = n-th retry that got through


class Leave(NamedTuple):
    """Preemption: the worker disappears at ``tick`` (any phase still
    in flight is lost with it)."""
    tick: int
    worker: int


class Join(NamedTuple):
    """(Re-)admission: the worker re-dispatches from the global copy
    current at ``tick`` and starts a fresh phase."""
    tick: int
    worker: int


class Lost(NamedTuple):
    """A phase whose send exhausted every retry: the delta is gone for
    good (Fig 8 drop semantics — the worker keeps its own params and
    moves on). Recorded so accounting can prove no silent loss, and so
    a trace can draw the doomed phase's compute + retry window."""
    tick: int          # when the last retry failed
    worker: int
    uid: int
    dispatch_tick: int = -1  # when the phase's params were dispatched
    finish_tick: int = -1    # when its compute finished (first send)


class Crash(NamedTuple):
    """The PROCESS dies at ``tick`` — not a worker fault but a
    crash-grade one: whatever is not durably checkpointed is gone.
    Sorted after every other event at its tick (the crash takes the
    tick's work down with it, having observed it), consumes no rng
    draws and no uid, so a timeline with a Crash is the crash-free
    timeline with one event spliced in: a run resumed from a snapshot
    taken before the crash replays the identical suffix."""
    tick: int


@dataclass(frozen=True)
class Scenario:
    """One scripted failure scenario, deterministic given its fields.

    speeds          per-worker phase duration in ticks (1 = fastest);
                    () = all 1s. len must equal k when non-empty.
    latency         per-worker one-way link latency in ticks added to
                    every send (simulated WAN distance); () = all 0.
    latency_jitter  lognormal multiplicative jitter sigma applied to
                    each send's latency draw (0 = deterministic links).
    drop_prob       probability each send attempt is dropped.
    max_retries     resends after a dropped attempt; a payload whose
                    every attempt drops is permanently Lost.
    retry_backoff   ticks between a dropped attempt and its resend.
    preemptions     ((worker, leave_tick, rejoin_tick), ...) — the
                    worker vanishes at leave_tick and re-dispatches
                    from the global copy at rejoin_tick. rejoin_tick
                    <= 0 means it never returns (elastic shrink).
    seed            rng seed for drops and jitter.
    crash_tick      < 0 disables; >= 0 splices a ``Crash`` event into
                    the timeline at that tick (the driver SIGKILLs
                    itself — the resilience benchmark's kill switch).
    nan_bombs       ((worker, tick), ...) — worker's outer gradient is
                    poisoned to NaN for the phase covering that tick
                    (round transports: round tick // sync_round_ticks
                    via ``nan_masks``). A hardware-corruption stand-in
                    the anomaly guard must reject.
    """
    speeds: tuple = ()
    latency: tuple = ()
    latency_jitter: float = 0.0
    drop_prob: float = 0.0
    max_retries: int = 0
    retry_backoff: int = 1
    preemptions: tuple = ()
    seed: int = 0
    crash_tick: int = -1
    nan_bombs: tuple = ()

    def __post_init__(self):
        """k-independent input validation — loud errors instead of
        silent mis-simulation (k-dependent checks — worker ranges —
        live in the resolved_* / _preempt_of / nan_masks views)."""
        if not 0.0 <= float(self.drop_prob) <= 1.0:
            raise ValueError(
                f"drop_prob must be in [0, 1], got {self.drop_prob}")
        if float(self.latency_jitter) < 0:
            raise ValueError(f"latency_jitter must be >= 0, got "
                             f"{self.latency_jitter}")
        if int(self.max_retries) < 0:
            raise ValueError(f"max_retries must be >= 0, got "
                             f"{self.max_retries}")
        if int(self.retry_backoff) < 1:
            raise ValueError(f"retry_backoff must be >= 1 tick, got "
                             f"{self.retry_backoff}")
        for pre in self.preemptions:
            if len(pre) != 3:
                raise ValueError(
                    f"preemptions entries are (worker, leave, rejoin) "
                    f"triples, got {pre!r}")
            w, leave, rejoin = (int(x) for x in pre)
            if leave < 0:
                raise ValueError(
                    f"worker {w} leave tick must be >= 0, got {leave}")
            # rejoin <= 0 is the "never returns" sentinel, so only its
            # ordering vs leave is checked (in _preempt_of, per k)
        for bomb in self.nan_bombs:
            if len(bomb) != 2:
                raise ValueError(
                    f"nan_bombs entries are (worker, tick) pairs, "
                    f"got {bomb!r}")
            w, t = (int(x) for x in bomb)
            if t < 0:
                raise ValueError(
                    f"nan bomb for worker {w} has negative tick {t}")

    # ---- named constructors for the canonical scenarios ----

    @staticmethod
    def uniform(k: int, **kw) -> "Scenario":
        return Scenario(speeds=(1,) * k, **kw)

    @staticmethod
    def stragglers(k: int, slow: tuple = (2, 4), **kw) -> "Scenario":
        """Heterogeneous pod speeds: the last ``len(slow)`` workers run
        slow[i]× slower than the rest (the beyond_async setting)."""
        speeds = [1] * k
        for i, s in enumerate(slow):
            speeds[k - len(slow) + i] = int(s)
        return Scenario(speeds=tuple(speeds), **kw)

    @staticmethod
    def wan(k: int, base_latency: int = 1, jitter: float = 0.5,
            **kw) -> "Scenario":
        """Per-link simulated WAN latency with lognormal jitter."""
        return Scenario(speeds=(1,) * k,
                        latency=(int(base_latency),) * k,
                        latency_jitter=float(jitter), **kw)

    @staticmethod
    def preempt(k: int, worker: int, leave: int, rejoin: int,
                **kw) -> "Scenario":
        """One worker preempted at ``leave``, back at ``rejoin``."""
        return Scenario(speeds=(1,) * k,
                        preemptions=((int(worker), int(leave),
                                      int(rejoin)),), **kw)

    @staticmethod
    def drop(k: int, prob: float, max_retries: int = 0,
             retry_backoff: int = 1, **kw) -> "Scenario":
        """Outer-gradient drop with optional retry/backoff."""
        return Scenario(speeds=(1,) * k, drop_prob=float(prob),
                        max_retries=int(max_retries),
                        retry_backoff=int(retry_backoff), **kw)

    # ---- derived views ----

    def resolved_speeds(self, k: int) -> tuple:
        s = tuple(int(x) for x in self.speeds) or (1,) * k
        if len(s) != k:
            raise ValueError(f"speeds has {len(s)} entries for k={k}")
        if any(x < 1 for x in s):
            raise ValueError(f"speeds must be >= 1 ticks, got {s}")
        return s

    def resolved_latency(self, k: int) -> tuple:
        l = tuple(int(x) for x in self.latency) or (0,) * k
        if len(l) != k:
            raise ValueError(f"latency has {len(l)} entries for k={k}")
        if any(x < 0 for x in l):
            raise ValueError(f"latency must be >= 0 ticks, got {l}")
        return l

    def _preempt_of(self, k: int) -> dict:
        """worker -> sorted ((leave, rejoin), ...); validates ticks."""
        out: dict[int, list] = {}
        for w, leave, rejoin in self.preemptions:
            w, leave, rejoin = int(w), int(leave), int(rejoin)
            if not 0 <= w < k:
                raise ValueError(f"preemption worker {w} out of range "
                                 f"for k={k}")
            if 0 < rejoin <= leave:
                raise ValueError(
                    f"worker {w} rejoin tick {rejoin} must be after "
                    f"its leave tick {leave}")
            out.setdefault(w, []).append((leave, rejoin))
        for w, spans in out.items():
            spans.sort()
            for (l1, r1), (l2, _) in zip(spans, spans[1:]):
                if r1 <= 0 or l2 < r1:
                    raise ValueError(
                        f"worker {w} preemption spans overlap: "
                        f"{spans}")
        return out

    def sync_round_ticks(self, k: int) -> int:
        """Wall-clock ticks one BARRIER outer round costs: every worker
        waits for the slowest phase plus the slowest (base) link —
        the bill the barrier-free transports avoid."""
        return (max(self.resolved_speeds(k))
                + max(self.resolved_latency(k)))

    def _bombs_of(self, k: int) -> tuple:
        """Validated ((worker, tick), ...); rejects unknown workers."""
        out = []
        for w, t in self.nan_bombs:
            w, t = int(w), int(t)
            if not 0 <= w < k:
                raise ValueError(
                    f"nan bomb worker {w} out of range for k={k}")
            out.append((w, t))
        return tuple(out)

    def crash_round(self, k: int) -> int:
        """The barrier-paced round a ``crash_tick`` falls in (< 0 when
        no crash is scripted): round r spans ticks [r*T, (r+1)*T)."""
        if self.crash_tick < 0:
            return -1
        return int(self.crash_tick) // self.sync_round_ticks(k)

    def nan_masks(self, k: int, rounds: int):
        """(rounds, k) float mask, 1 where a scripted NaN bomb poisons
        the worker's outer gradient that round (tick -> round via the
        barrier pacing, like ``round_masks``)."""
        T = self.sync_round_ticks(k)
        bombs = np.zeros((rounds, k), np.float32)
        for w, t in self._bombs_of(k):
            r = t // T
            if r < rounds:
                bombs[r, w] = 1.0
        return bombs

    def round_masks(self, k: int, rounds: int):
        """(drops, actives) — two (rounds, k) float arrays in the
        stacked layout ``diloco.make_run`` consumes, projecting this
        scenario onto a barrier-paced run: round r spans ticks
        [r*T, (r+1)*T) with T = ``sync_round_ticks``. A send attempt
        that drops (after exhausting its retries within the barrier)
        zeroes the drop mask; a worker preempted anywhere in the
        round's span is inactive for it."""
        T = self.sync_round_ticks(k)
        rng = np.random.default_rng(self.seed)
        drops = np.ones((rounds, k), np.float32)
        if self.drop_prob > 0:
            # a barrier gives every payload max_retries+1 attempts
            attempts = 1 + max(0, int(self.max_retries))
            p_lost = float(self.drop_prob) ** attempts
            drops = (rng.random((rounds, k)) >= p_lost
                     ).astype(np.float32)
        actives = np.ones((rounds, k), np.float32)
        for w, spans in self._preempt_of(k).items():
            for leave, rejoin in spans:
                end = rejoin if rejoin > 0 else rounds * T
                for r in range(rounds):
                    lo, hi = r * T, (r + 1) * T
                    if lo < end and hi > leave:
                        actives[r, w] = 0.0
        return drops, actives

    def _resolve_send(self, rng, base_lat: int, finish: int):
        """Resolve one payload's send attempts. Returns
        (arrival_tick, None, attempt) when some attempt gets through
        or (None, give_up_tick, None) when every attempt drops. Draw
        order is fixed (jitter then drop, per attempt) so the stream
        is deterministic; a fault-free link consumes zero draws."""
        send = finish
        for attempt in range(1 + max(0, int(self.max_retries))):
            delay = base_lat
            if self.latency_jitter > 0 and base_lat > 0:
                delay = int(round(base_lat * float(
                    rng.lognormal(0.0, self.latency_jitter))))
            dropped = (self.drop_prob > 0
                       and rng.random() < self.drop_prob)
            if not dropped:
                return send + delay, None, attempt
            send += max(1, int(self.retry_backoff))
        return None, send, None

    @staticmethod
    def _emit_preemption(events: list, worker: int, span, ticks: int):
        """Emit Leave (and Join when the worker comes back inside the
        horizon). Returns the rejoin tick, or None if the worker is
        gone for the rest of the run."""
        leave, rejoin = span
        if leave < ticks:
            events.append(Leave(leave, worker))
        if rejoin <= 0 or rejoin >= ticks:
            return None
        events.append(Join(rejoin, worker))
        return rejoin

    def timeline(self, k: int, ticks: int) -> tuple:
        """The ordered event stream of a barrier-free run over
        ``ticks`` wall-clock ticks: Arrival / Leave / Join / Lost
        events sorted by (tick, kind, worker) with Join first (a
        rejoining worker re-dispatches before same-tick arrivals
        apply). Pure function of the scenario — replaying a prefix and
        resuming mid-stream yields the identical suffix (the
        checkpoint-restore contract).

        Worker lifecycle: dispatch at tick t, compute finishes at
        t + speed; each send attempt pays its link latency (jittered);
        a dropped attempt retries after ``retry_backoff`` ticks, up to
        ``max_retries`` times, after which the payload is Lost and the
        worker continues from its OWN params under the same dispatch
        version (Fig 8 semantics — the next success recovers the lost
        mass because its delta spans both phases). On an Arrival the
        worker re-dispatches from the fresh global copy at the arrival
        tick. With zero faults and unit speeds this reduces exactly to
        the seed's tick loop.

        Preemption cuts the phase in flight; payloads still on the
        wire (or mid-retry) when their sender leaves are discarded by
        the server — so every Arrival is guaranteed to land on a
        worker that has been continuously present since the payload's
        dispatch, the invariant the async engine's slot bookkeeping
        asserts. A ``uid`` is consumed by every phase whose compute
        finished (delivered, Lost, or discarded), making uids stable
        identifiers across resumes.
        """
        speeds = self.resolved_speeds(k)
        lat = self.resolved_latency(k)
        pre = self._preempt_of(k)
        # one independent stream per worker: event generation for
        # worker i must not consume draws that belong to worker j, or
        # changing one worker's schedule would reshuffle everyone's
        rngs = [np.random.default_rng((self.seed, i)) for i in range(k)]
        events: list = []
        uid = 0
        for i in range(k):
            spans = list(pre.get(i, []))
            t = 0                      # current dispatch tick
            while t < ticks:
                nxt = spans[0] if spans else None
                finish = t + speeds[i]
                if nxt is not None and nxt[0] < finish:
                    # preemption cuts the phase mid-compute: no uid
                    spans.pop(0)
                    t = self._emit_preemption(events, i, nxt, ticks)
                    if t is None:
                        break
                    continue
                if finish > ticks:
                    break              # compute runs past the horizon
                arr, gave_up, attempt = self._resolve_send(
                    rngs[i], lat[i], finish)
                if arr is not None:
                    if nxt is not None and nxt[0] < arr:
                        # payload on the wire when the sender leaves:
                        # the server discards it (membership change)
                        uid += 1
                        spans.pop(0)
                        t = self._emit_preemption(events, i, nxt, ticks)
                        if t is None:
                            break
                        continue
                    if arr > ticks:
                        break          # in flight past the horizon
                    events.append(Arrival(arr, i, uid, t, finish,
                                          attempt))
                    uid += 1
                    t = arr            # re-dispatch from fresh global
                    continue
                # every attempt dropped: sender gives up at gave_up
                if nxt is not None and nxt[0] < gave_up:
                    uid += 1
                    spans.pop(0)
                    t = self._emit_preemption(events, i, nxt, ticks)
                    if t is None:
                        break
                    continue
                uid += 1
                if gave_up > ticks:
                    break              # still retrying at the horizon
                events.append(Lost(gave_up, i, uid - 1, t, finish))
                t = gave_up            # continue from own params
        if 0 <= int(self.crash_tick) < ticks:
            # the process dies AFTER the tick's worker events (the
            # crash observes them; sort key below puts it last) — and
            # consumes no rng/uid, so the crash-free timeline is this
            # one minus the Crash: a resume replays the exact suffix
            events.append(Crash(int(self.crash_tick)))
        order = {Join: 0, Arrival: 1, Lost: 2, Leave: 3, Crash: 4}
        events.sort(key=lambda e: (e.tick, order[type(e)],
                                   getattr(e, "worker", -1)))
        return tuple(events)


def staleness_weight(staleness, lam: float, k: int):
    """The async transport's delay-compensation policy: an outer
    gradient ``staleness`` outer steps late is applied at weight
    λ^staleness / k — 1/k is the worker's share of one synchronous
    round's evidence, λ^τ the discount. Monotone non-increasing in the
    delay for λ <= 1 (tested)."""
    if not 0.0 <= lam <= 1.0:
        raise ValueError(f"staleness lambda must be in [0, 1], "
                         f"got {lam}")
    return (lam ** staleness) / float(k)
