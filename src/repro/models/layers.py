"""Core transformer building blocks, pure-functional JAX.

All init fns return trees of ``Boxed(value, logical_axes)`` leaves (see
sharding/spec.py). All apply fns take plain param trees (unboxed).

The attention implementation is a chunked online-softmax ("flash-style")
formulation in pure jnp: it never materializes the (Sq, Skv) score matrix
for long sequences, which keeps dry-run compile memory bounded at 32k/500k
context, and doubles as the numerical oracle for the Pallas TPU kernel in
``repro.kernels.flash_attention``.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.spec import Boxed

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, axes, scale=0.02, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) > 1 else 1
    std = min(scale, (1.0 / max(fan_in, 1)) ** 0.5)
    return Boxed(jax.random.normal(key, shape, dtype) * std, axes)


def zeros_init(shape, axes, dtype=jnp.float32):
    return Boxed(jnp.zeros(shape, dtype), axes)


def ones_init(shape, axes, dtype=jnp.float32):
    return Boxed(jnp.ones(shape, dtype), axes)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(kind: str, dim: int):
    if kind == "rmsnorm":
        return {"scale": ones_init((dim,), (None,))}
    return {"scale": ones_init((dim,), (None,)),
            "bias": zeros_init((dim,), (None,))}


def apply_norm(params, x, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True)
                               + eps)
    else:
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32)
    if "bias" in params:
        y = y + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_head_norm(scale, x, eps: float = 1e-6):
    """qk-norm: RMSNorm over the head dim of (B, S, H, hd)."""
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# positions
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float, pct: float = 1.0):
    rot = int(head_dim * pct) // 2 * 2
    inv = 1.0 / (theta ** (np.arange(0, rot, 2, dtype=np.float32) / rot))
    return jnp.asarray(inv), rot


def apply_rope(x, positions, theta: float, pct: float = 1.0):
    """x: (B, S, H, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    inv, rot = rope_freqs(hd, theta, pct)
    if rot == 0:
        return x
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * inv[None]     # (S, r/2)
        ang = ang[None, :, None, :]                                   # (1,S,1,r/2)
    else:
        ang = positions[..., None].astype(jnp.float32) * inv         # (B,S,r/2)
        ang = ang[:, :, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], -1).reshape(xr.shape)
    return jnp.concatenate([out, xp], -1).astype(x.dtype)


def sincos_positions(seq_len: int, dim: int, dtype=jnp.float32):
    pos = np.arange(seq_len)[:, None]
    i = np.arange(dim // 2)[None]
    ang = pos / (10_000 ** (2 * i / dim))
    emb = np.concatenate([np.sin(ang), np.cos(ang)], -1)
    return jnp.asarray(emb, dtype)


# ---------------------------------------------------------------------------
# attention (chunked online-softmax == flash oracle)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _mask_bias(q_pos, k_pos, causal: bool, window: int, kv_valid):
    """(..., q, k) additive bias. q_pos (Sq,); k_pos (Sk,) or (B, Sk)
    (per-slot position tracks — continuous batching); kv_valid same
    leading shape as k_pos."""
    kp = k_pos[..., None, :]                   # (..., 1, Sk)
    qp = q_pos[:, None]                        # (Sq, 1)
    ok = jnp.ones(jnp.broadcast_shapes(kp.shape, qp.shape), bool)
    if causal:
        ok &= kp <= qp
    if window and window > 0:
        ok &= kp > qp - window
    if kv_valid is not None:
        ok &= kv_valid[..., None, :]
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def attention(q, k, v, *, causal=True, window=0, q_offset=0,
              kv_positions=None, kv_valid=None, chunk=1024,
              softcap: float = 0.0, scale: float | None = None,
              kv_shard: str | None = None):
    """GQA attention. q: (B,Sq,H,dh); k: (B,Sk,G,dh); v: (B,Sk,G,dv).

    Uses a direct path for short kv and a lax.scan chunked online-softmax
    path for long kv (bounded memory: never materializes (Sq, Sk)).
    ``q_offset``: absolute position of q[0] (decode). ``kv_positions``:
    absolute positions of kv entries (defaults to arange, used by ring
    caches). ``kv_valid``: bool (Sk,) validity (partially-filled caches).
    """
    B, Sq, H, dh = q.shape
    _, Sk, G, _ = k.shape
    dv = v.shape[-1]
    rep = H // G
    scale = dh ** -0.5 if scale is None else scale
    qh = (q * scale).reshape(B, Sq, G, rep, dh)
    q_pos = q_offset + jnp.arange(Sq)
    if kv_positions is None:
        kv_positions = jnp.arange(Sk)

    # Direct path when the score matrix is small: short kv, OR few
    # queries (decode: Sq==1 — scores are (B,G,r,1,Sk), trivially small;
    # the chunked lax.scan would shuffle the sharded KV cache through
    # per-chunk reshapes that GSPMD reshards with cache-sized
    # all-reduces every layer).
    if Sk <= max(2 * chunk, 2048) or Sq <= 8:
        s = jnp.einsum("bqgrd,bkgd->bgrqk", qh, k,
                       preferred_element_type=jnp.float32)
        if kv_shard:
            # flash-decoding: keep the kv dim of the scores sharded so
            # the partitioner computes windowed partial softmax + a tiny
            # psum instead of all-gathering the (huge) sequence-sharded
            # KV cache to every device
            from repro.sharding.spec import constrain as _c
            from jax.sharding import PartitionSpec as _P
            s = _c(s, _P(None, None, None, None, kv_shard))
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        bias = _mask_bias(q_pos, kv_positions, causal, window, kv_valid)
        if bias.ndim == 3:          # per-slot tracks: (B, Sq, Sk)
            bias = bias[:, None, None]
        s = s + bias
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bgrqk,bkgd->bqgrd", p.astype(v.dtype), v,
                       preferred_element_type=jnp.float32)
        return o.reshape(B, Sq, H, dv).astype(q.dtype)

    # chunked path (shared position track only — per-slot (B, Sk)
    # tracks always take the direct path above since they imply Sq<=8)
    assert kv_positions.ndim == 1, "chunked path needs shared positions"
    assert Sk % chunk == 0, (Sk, chunk)
    nchunks = Sk // chunk
    ks = jnp.moveaxis(k.reshape(B, nchunks, chunk, G, dh), 1, 0)
    vs = jnp.moveaxis(v.reshape(B, nchunks, chunk, G, dv), 1, 0)
    kpos = kv_positions.reshape(nchunks, chunk)
    kval = (kv_valid.reshape(nchunks, chunk) if kv_valid is not None
            else jnp.ones((nchunks, chunk), bool))

    def body(carry, xs):
        acc, m, l = carry
        kc, vc, kp, kvld = xs
        s = jnp.einsum("bqgrd,bkgd->bgrqk", qh, kc,
                       preferred_element_type=jnp.float32)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        s = s + _mask_bias(q_pos, kp, causal, window, kvld)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bgrqk,bkgd->bgrqd", p.astype(vc.dtype), vc,
            preferred_element_type=jnp.float32)
        return (acc, m_new, l), None

    acc0 = jnp.zeros((B, G, rep, Sq, dv), jnp.float32)
    m0 = jnp.full((B, G, rep, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, G, rep, Sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0),
                                  (ks, vs, kpos, kval))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(o, 3, 1).reshape(B, Sq, H, dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# standard GQA attention block (init + apply, with optional KV cache)
# ---------------------------------------------------------------------------

def init_attention(key, cfg):
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 6)
    D, H, G = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    p = {
        "wq": dense_init(ks[0], (D, H, hd), ("embed", "heads", None),
                         cfg.init_scale),
        "wk": dense_init(ks[1], (D, G, hd), ("embed", "kv_heads", None),
                         cfg.init_scale),
        "wv": dense_init(ks[2], (D, G, hd), ("embed", "kv_heads", None),
                         cfg.init_scale),
        "wo": dense_init(ks[3], (H, hd, D), ("heads", None, "embed"),
                         cfg.init_scale),
    }
    if cfg.attn_bias:
        p["bq"] = zeros_init((H, hd), ("heads", None))
        p["bk"] = zeros_init((G, hd), ("kv_heads", None))
        p["bv"] = zeros_init((G, hd), ("kv_heads", None))
        p["bo"] = zeros_init((D,), (None,))
    if cfg.qk_norm:
        p["q_norm"] = ones_init((hd,), (None,))
        p["k_norm"] = ones_init((hd,), (None,))
    return p


def project_cross_kv(p, cfg, kv_x):
    """Project cross-attention K/V once (cached at prefill; recomputing
    them per decode step costs ~2·S_src·D² FLOPs per layer per step)."""
    dt = kv_x.dtype
    k = jnp.einsum("bsd,dgk->bsgk", kv_x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dgk->bsgk", kv_x, p["wv"].astype(dt))
    if "bk" in p:
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return k, v


def paged_kv_update(cache, page_table, k, v, cache_pos):
    """Write new tokens into a paged K/V pool and gather the dense ring
    view.

    cache: {"kp": (n_pages, psize, G, hd), "vp": ..., "posp":
    (n_pages, psize)} — a pool of fixed-size pages shared by all slots.
    page_table: (B, pages_per_slot) int32, the physical page backing
    each logical page of each slot's ring (-1 = unmapped: writes are
    dropped, reads come back empty). The logical ring has length
    C = pages_per_slot * psize; token at absolute position p lives at
    logical page (p % C) // psize, offset (p % C) % psize — exactly the
    contiguous ring layout, so the gathered dense view is value-equal
    to a contiguous cache and attention over it is bit-identical.

    Returns (new_cache, k_dense (B,C,G,hd), v_dense, kv_pos (B,C)).
    """
    kp, vp, posp = cache["kp"], cache["vp"], cache["posp"]
    n_pages, psize = kp.shape[0], kp.shape[1]
    B_, pages_per_slot = page_table.shape
    C = pages_per_slot * psize
    S_new = k.shape[1]
    if S_new > C:               # static shapes: python-level branch
        k = k[:, -C:]
        v = v[:, -C:]
        cache_pos_eff = cache_pos + (S_new - C)
        S_eff = C
    else:
        cache_pos_eff = cache_pos
        S_eff = S_new
    offs = jnp.arange(S_eff, dtype=jnp.int32)
    ring = (cache_pos_eff + offs) % C                   # (S_eff,)
    # unmapped table entries become an out-of-range sentinel: scatters
    # drop them (mode="drop"), gathers read back fill values — so a
    # slot with no page mapped never corrupts the shared pool (the
    # batched decode "writes" for empty slots too, like the contiguous
    # engine, but here those writes vanish instead of landing in a row)
    phys = jnp.where(page_table >= 0, page_table, n_pages)
    page_i = phys[:, ring // psize]                      # (B, S_eff)
    off_b = jnp.broadcast_to((ring % psize)[None], (B_, S_eff))
    upd = jnp.broadcast_to((cache_pos_eff + offs)[None], (B_, S_eff))
    kp = kp.at[page_i, off_b].set(k, mode="drop")
    vp = vp.at[page_i, off_b].set(v, mode="drop")
    posp = posp.at[page_i, off_b].set(upd, mode="drop")
    kd = jnp.take(kp, phys, axis=0, mode="fill",
                  fill_value=0).reshape((B_, C) + kp.shape[2:])
    vd = jnp.take(vp, phys, axis=0, mode="fill",
                  fill_value=0).reshape((B_, C) + vp.shape[2:])
    kv_pos = jnp.take(posp, phys, axis=0, mode="fill",
                      fill_value=-1).reshape(B_, C)
    return {"kp": kp, "vp": vp, "posp": posp}, kd, vd, kv_pos


def apply_attention(p, x, cfg, *, positions, cache=None, cache_pos=None,
                    window=0, causal=True, kv_x=None, kv_positions=None,
                    cross_kv=None, page_table=None):
    """Self- or cross-attention with optional decode cache.

    cache: dict {"k": (B, C, G, hd), "v": ..., } ring buffer of size C;
    a paged cache ({"kp", "vp", "posp"} page pool, see paged_kv_update)
    is used instead when present — ``page_table`` is required then.
    cache_pos: int32 scalar — absolute position of the incoming token(s).
    kv_x: if given, cross-attention keys/values come from kv_x.
    cross_kv: (k, v) precomputed cross K/V (see project_cross_kv).
    Returns (out, new_cache).
    """
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    if cross_kv is not None:
        k, v = cross_kv
        k = k.astype(dt)
        v = v.astype(dt)
        kv_x = True          # marks the cross-attention path below
        if "bq" in p:
            q = q + p["bq"].astype(dt)
    else:
        src = x if kv_x is None else kv_x
        k = jnp.einsum("bsd,dgk->bsgk", src, p["wk"].astype(dt))
        v = jnp.einsum("bsd,dgk->bsgk", src, p["wv"].astype(dt))
        if "bq" in p:
            q = q + p["bq"].astype(dt)
            k = k + p["bk"].astype(dt)
            v = v + p["bv"].astype(dt)
    if cfg.qk_norm:
        q = rms_head_norm(p["q_norm"], q)
        k = rms_head_norm(p["k_norm"], k)
    if cfg.pos_emb == "rope" and kv_x is None:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_pct)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_pct)
    elif cfg.pos_emb == "rope":   # cross-attn: rotate queries only
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_pct)

    new_cache = None
    if cache is not None and kv_x is None and "kp" in cache:
        # paged ring: same layout/maths as the contiguous branch below,
        # but the storage is a page pool indexed through the engine's
        # per-slot page table
        if page_table is None:
            raise ValueError("paged attention cache needs a page_table")
        new_cache, ck, cv, kv_pos = paged_kv_update(
            cache, page_table, k, v, cache_pos)
        kv_pos1 = kv_pos if q.shape[1] <= 8 else kv_pos[0]
        kv_valid = kv_pos1 >= 0
        out = attention(q, ck, cv, causal=causal, window=window,
                        q_offset=cache_pos, kv_positions=kv_pos1,
                        kv_valid=kv_valid, chunk=cfg.attn_chunk)
    elif cache is not None and kv_x is None:
        # Ring buffer of size C: token at absolute position p lives in slot
        # p % C. A "pos" track records each slot's absolute position
        # (-1 = empty) so masking stays exact after wrap-around. Writes
        # use a scatter over explicit slot indices (wrap-correct); when
        # more than C tokens arrive at once only the last C survive.
        C = cache["k"].shape[1]
        B_ = cache["k"].shape[0]
        S_new = k.shape[1]
        if S_new > C:               # static shapes: python-level branch
            k = k[:, -C:]
            v = v[:, -C:]
            cache_pos_eff = cache_pos + (S_new - C)
            S_eff = C
        else:
            cache_pos_eff = cache_pos
            S_eff = S_new
        offs = jnp.arange(S_eff, dtype=jnp.int32)
        upd = jnp.broadcast_to((cache_pos_eff + offs)[None, :],
                               (B_, S_eff))
        if S_eff == 1:
            # decode hot path: a 1-token write never wraps — use
            # dynamic_update_slice, which SPMD-partitions locally
            # (array-index scatters fall back to a select+all-reduce of
            # the whole cache per layer)
            slot0 = cache_pos_eff % C
            ck = jax.lax.dynamic_update_slice(cache["k"], k,
                                              (0, slot0, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v,
                                              (0, slot0, 0, 0))
            kv_pos = jax.lax.dynamic_update_slice(cache["pos"], upd,
                                                  (0, slot0))
        else:
            slots = (cache_pos_eff + offs) % C                # unique
            ck = cache["k"].at[:, slots].set(k)
            cv = cache["v"].at[:, slots].set(v)
            kv_pos = cache["pos"].at[:, slots].set(upd)
        new_cache = {"k": ck, "v": cv, "pos": kv_pos}
        # decode (direct path): per-slot (B, C) position tracks so
        # continuous batching masks each slot's own history; prefill
        # (chunked path): rows share a clock — pass row 0
        if q.shape[1] <= 8:
            kv_pos1 = kv_pos
        else:
            kv_pos1 = kv_pos[0]
        kv_valid = kv_pos1 >= 0
        out = attention(q, ck, cv, causal=causal, window=window,
                        q_offset=cache_pos, kv_positions=kv_pos1,
                        kv_valid=kv_valid, chunk=cfg.attn_chunk,
                        kv_shard=cfg.decode_kv_shard or None)
    elif (cfg.use_pallas and kv_x is None and kv_positions is None
            and cfg.resolved_head_dim % 128 == 0 and q.shape[1] % 128 == 0):
        # TPU hot path: Pallas flash kernel (see kernels/flash_attention)
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=causal, window=window)
    else:
        q_offset = 0
        out = attention(q, k, v, causal=causal, window=window,
                        q_offset=q_offset,
                        kv_positions=kv_positions, chunk=cfg.attn_chunk)

    o = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
    if "bo" in p:
        o = o + p["bo"].astype(dt)
    return o, new_cache


def init_attn_cache(cfg, batch: int, cache_len: int, dtype):
    hd = cfg.resolved_head_dim
    G = cfg.n_kv_heads
    return {
        "k": jnp.zeros((batch, cache_len, G, hd), dtype),
        "v": jnp.zeros((batch, cache_len, G, hd), dtype),
        "pos": jnp.full((batch, cache_len), -1, jnp.int32),
    }


def init_paged_attn_cache(cfg, n_pages: int, page_size: int, dtype):
    """Shared page pool replacing the per-slot (B, C) ring rows: slots
    map logical ring pages to pool pages through the engine-held page
    table, so short requests only occupy the pages they touch."""
    hd = cfg.resolved_head_dim
    G = cfg.n_kv_heads
    return {
        "kp": jnp.zeros((n_pages, page_size, G, hd), dtype),
        "vp": jnp.zeros((n_pages, page_size, G, hd), dtype),
        "posp": jnp.full((n_pages, page_size), -1, jnp.int32),
    }


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, cfg, d_ff: int | None = None):
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], (D, F), ("embed", "ff"), cfg.init_scale),
         "w_down": dense_init(ks[1], (F, D), ("ff", "embed"),
                              cfg.init_scale)}
    if cfg.mlp_gated:
        p["w_gate"] = dense_init(ks[2], (D, F), ("embed", "ff"),
                                 cfg.init_scale)
    if cfg.mlp_bias:
        p["b_up"] = zeros_init((F,), ("ff",))
        p["b_down"] = zeros_init((D,), (None,))
    return p


def _act(x, kind: str):
    return jax.nn.silu(x) if kind == "silu" else jax.nn.gelu(x)


def apply_mlp(p, x, cfg):
    dt = x.dtype
    h = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt))
    if "b_up" in p:
        h = h + p["b_up"].astype(dt)
    if "w_gate" in p:
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(dt))
        h = _act(g, cfg.act) * h
    else:
        h = _act(h, cfg.act)
    o = jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(dt))
    if "b_down" in p:
        o = o + p["b_down"].astype(dt)
    return o


# ---------------------------------------------------------------------------
# embeddings / head
# ---------------------------------------------------------------------------

def init_embedding(key, cfg):
    return {"table": dense_init(key, (cfg.vocab_size, cfg.d_model),
                                ("vocab", "embed"), 1.0)}


def embed(p, tokens, cfg):
    return p["table"][tokens].astype(_dt(cfg))


def init_lm_head(key, cfg):
    if cfg.tie_embeddings:
        return {}
    return {"w": dense_init(key, (cfg.d_model, cfg.vocab_size),
                            ("embed", "vocab"), cfg.init_scale)}


def lm_logits(head_p, emb_p, x, cfg):
    if cfg.tie_embeddings:
        w = emb_p["table"].astype(x.dtype).T
    else:
        w = head_p["w"].astype(x.dtype)
    logits = jnp.einsum("bsd,dv->bsv", x, w,
                        preferred_element_type=jnp.float32)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits


def _dt(cfg):
    return jnp.dtype(cfg.compute_dtype)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def next_token_loss(logits, tokens, mask=None):
    """Cross-entropy of logits[:, :-1] predicting tokens[:, 1:].

    Fused formulation: nll = logsumexp(logits) − logits[target].
    log_softmax would materialize a second (B, S, V) f32 tensor — at
    train_4k × 128k vocab that is ~134 GB of extra HBM traffic per step
    (§Perf iteration: memory-term lever shared by every train pair)."""
    lg = logits[:, :-1].astype(jnp.float32)
    tgt = tokens[:, 1:]
    lse = jax.nn.logsumexp(lg, axis=-1)                       # (B, S-1)
    picked = jnp.take_along_axis(lg, tgt[..., None], -1)[..., 0]
    nll = lse - picked
    if mask is not None:
        m = mask[:, 1:].astype(jnp.float32)
        return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(nll)
