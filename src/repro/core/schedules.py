"""Compute-pool schedules (Fig 7) and communication-drop masks (Fig 8).

The adaptive-compute study varies how many replicas are active per outer
round; the async study drops each replica's outer gradient independently
with probability p. Both are expressed as per-round (k,) float masks fed
to ``core.diloco.outer_step`` / ``inner_phase``.
"""
from __future__ import annotations

import numpy as np


def compute_schedule(kind: str, k: int, n_rounds: int) -> np.ndarray:
    """(n_rounds,) int — active replica count per round.

    Kinds (paper Fig 7): constant_local (1), constant_distributed (k),
    doubling (k/2 then k), halving (k then k/2), ramp_up (1 -> k),
    ramp_down (k -> 1).
    """
    t = np.arange(n_rounds)
    half = n_rounds // 2
    if kind == "constant_local":
        n = np.ones(n_rounds)
    elif kind == "constant_distributed":
        n = np.full(n_rounds, k)
    elif kind == "doubling":
        n = np.where(t < half, k // 2, k)
    elif kind == "halving":
        n = np.where(t < half, k, k // 2)
    elif kind == "ramp_up":
        n = np.clip(np.round(1 + (k - 1) * t / max(n_rounds - 1, 1)), 1, k)
    elif kind == "ramp_down":
        n = np.clip(np.round(k - (k - 1) * t / max(n_rounds - 1, 1)), 1, k)
    else:
        raise ValueError(kind)
    return n.astype(np.int32)


def active_mask(n_active: int, k: int) -> np.ndarray:
    """(k,) float mask with the first ``n_active`` replicas active."""
    m = np.zeros((k,), np.float32)
    m[:n_active] = 1.0
    return m


def active_masks(schedule: np.ndarray, k: int) -> np.ndarray:
    """(n_rounds, k) float — per-round active masks for a compute
    schedule, in the stacked layout the scanned driver consumes."""
    return np.stack([active_mask(int(n), k) for n in schedule])


def drop_masks(rng: np.random.Generator, drop_prob: float, k: int,
               n_rounds: int) -> np.ndarray:
    """(n_rounds, k) float — 1 = communicated, 0 = dropped (Fig 8)."""
    if drop_prob <= 0:
        return np.ones((n_rounds, k), np.float32)
    return (rng.random((n_rounds, k)) >= drop_prob).astype(np.float32)


def total_compute(schedule: np.ndarray, H: int) -> int:
    """Total inner steps summed over replicas (the x-axis of Fig 7)."""
    return int(schedule.sum()) * H
