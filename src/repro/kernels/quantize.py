"""Low-precision outer-gradient transport — Pallas TPU kernels.

Streaming DiLoCo sends each fragment's outer gradient through the
cross-pod collective in low precision. On hardware that is a real
pack/unpack around the all-reduce; in this repo's simulated transport
the gradient takes a quantize→dequantize round trip before the in-graph
replica average, so the *numerics* of the low-precision collective are
exact while the bytes saved are accounted analytically.

Kernels, all on the (blocks, 128) layout every optimizer kernel
in this package uses (one f32 scale per 128-element block):

  * ``quantize_int4``   — codes int8 in [-7, 7] + per-block f32 scale
                          (the wire format: 0.5 B/elem + 4 B/block);
  * ``dequantize_int4`` — codes × scale back to f32;
  * ``pack_int4``       — nibble-pack (R, 128) codes into (R, 64) wire
                          bytes (two 4-bit two's-complement codes per
                          int8 byte; flattening the output row-major
                          gives bytes in element order);
  * ``unpack_int4``     — the exact inverse, with sign extension;
  * ``fake_quant``      — the fused round trip in ONE VMEM pass (codes
                          and scales never touch HBM), used on the
                          simulated transport path. Also serves bf16
                          (cast down/up in-register);
  * ``quantize_pack_int4``       — the fused SENDER pass: f32 blocks →
                          (R, 64) packed wire bytes + (R, 1) scales +
                          the dequantized local payload, all in ONE
                          VMEM pass (the intermediate unpacked codes
                          never touch HBM — previously quantize then
                          pack then dequantize, three launches);
  * ``unpack_dequantize_int4``   — the fused RECEIVER pass: wire bytes
                          × scales → f32 values, one launch;
  * ``unpack_dequantize_reduce`` — the fused receiver pass over every
                          replica at once: (k, R, 64) wire bytes ×
                          (k, R, 1) scales × (k,) mask → the masked
                          sum (R, 128), decode and reduction in one
                          launch (the deferred streaming consumer).

The jnp oracles live in ``ref.py``; ``ops.quant_roundtrip`` (and the
packed-wire codecs ``ops.wire_encode``/``ops.wire_decode``) dispatch
between them and these kernels via ``kernel_mode``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import compat
from .fused_adamw import _to_blocks
from .ref import INT4_LEVELS, INV_INT4_LEVELS


def _pad2d(x, block_rows):
    """Flatten any-shape x to a padded (rows_p, 128) f32 layout —
    the shared block scaffold of ``fused_adamw._to_blocks``.
    Returns (x2d, rows_p, br, n)."""
    (x2d,), rows_p, br, n = _to_blocks(
        (x.astype(jnp.float32),), block_rows)
    return x2d, rows_p, br, n


def _quantize_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = amax * INV_INT4_LEVELS
    q = jnp.round(x / jnp.where(scale > 0, scale, 1.0))
    q_ref[...] = jnp.clip(q, -INT4_LEVELS, INT4_LEVELS).astype(q_ref.dtype)
    s_ref[...] = scale.astype(s_ref.dtype)


def _dequantize_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = (q_ref[...].astype(jnp.float32)
                  * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def _pack_kernel(c_ref, o_ref):
    # (br, 128) codes -> (br, 64) bytes: lane pairs (2j, 2j+1) fold into
    # byte j, so the row-major flatten of the output is in element order
    c = c_ref[...].astype(jnp.int32) & 0xF
    pairs = c.reshape(c.shape[0], -1, 2)
    o_ref[...] = (pairs[..., 0] | (pairs[..., 1] << 4)).astype(jnp.int8)


def _unpack_kernel(p_ref, o_ref):
    p = p_ref[...].astype(jnp.int32) & 0xFF
    nib = jnp.stack([p & 0xF, (p >> 4) & 0xF], axis=-1)
    nib = nib.reshape(nib.shape[0], -1)
    # 4-bit two's complement sign extension
    o_ref[...] = ((nib ^ 8) - 8).astype(jnp.int8)


def _quantize_pack_kernel(x_ref, p_ref, s_ref, l_ref):
    # one VMEM pass: block scale, int4 codes, nibble-pack AND the
    # sender's dequantized local payload — the (br, 128) code tile
    # lives only in registers/VMEM, never in HBM
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = amax * INV_INT4_LEVELS
    q = jnp.clip(jnp.round(x / jnp.where(scale > 0, scale, 1.0)),
                 -INT4_LEVELS, INT4_LEVELS)
    c = q.astype(jnp.int32) & 0xF
    pairs = c.reshape(c.shape[0], -1, 2)
    p_ref[...] = (pairs[..., 0] | (pairs[..., 1] << 4)).astype(jnp.int8)
    s_ref[...] = scale.astype(s_ref.dtype)
    l_ref[...] = (q * scale).astype(l_ref.dtype)


def _unpack_dequant_kernel(p_ref, s_ref, o_ref):
    p = p_ref[...].astype(jnp.int32) & 0xFF
    nib = jnp.stack([p & 0xF, (p >> 4) & 0xF], axis=-1)
    nib = nib.reshape(nib.shape[0], -1)
    codes = ((nib ^ 8) - 8).astype(jnp.float32)
    o_ref[...] = (codes * s_ref[...].astype(jnp.float32)
                  ).astype(o_ref.dtype)


def _unpack_dequant_reduce_kernel(p_ref, s_ref, m_ref, o_ref):
    # (k, br, 64) wire bytes -> masked sum over k, decoded in-register
    p = p_ref[...].astype(jnp.int32) & 0xFF
    nib = jnp.stack([p & 0xF, (p >> 4) & 0xF], axis=-1)
    nib = nib.reshape(nib.shape[0], nib.shape[1], -1)
    codes = ((nib ^ 8) - 8).astype(jnp.float32)
    vals = codes * s_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.sum(m_ref[...].astype(jnp.float32) * vals,
                         axis=0).astype(o_ref.dtype)


def _fake_quant_kernel(x_ref, o_ref, *, dtype):
    x = x_ref[...].astype(jnp.float32)
    if dtype == "bfloat16":
        o_ref[...] = x.astype(jnp.bfloat16).astype(o_ref.dtype)
        return
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = amax * INV_INT4_LEVELS
    q = jnp.clip(jnp.round(x / jnp.where(scale > 0, scale, 1.0)),
                 -INT4_LEVELS, INT4_LEVELS)
    o_ref[...] = (q * scale).astype(o_ref.dtype)


def quantize_int4(x2d, *, block_rows: int = 256, interpret: bool = False):
    """x2d: (R, 128) f32 blocks -> (codes (R, 128) int8, scales (R, 1)
    f32). Rows must already be padded to the block layout."""
    rows, cols = x2d.shape
    br = min(block_rows, rows)
    rows_p = -(-rows // br) * br
    if rows_p != rows:
        x2d = jnp.pad(x2d, ((0, rows_p - rows), (0, 0)))
    tile = pl.BlockSpec((br, cols), lambda i: (i, 0))
    stile = pl.BlockSpec((br, 1), lambda i: (i, 0))
    codes, scales = pl.pallas_call(
        _quantize_kernel,
        grid=(rows_p // br,),
        in_specs=[tile],
        out_specs=(tile, stile),
        out_shape=(jax.ShapeDtypeStruct((rows_p, cols), jnp.int8),
                   jax.ShapeDtypeStruct((rows_p, 1), jnp.float32)),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x2d)
    return codes[:rows], scales[:rows]


def dequantize_int4(codes, scales, *, block_rows: int = 256,
                    interpret: bool = False):
    """(R, 128) int8 codes × (R, 1) f32 scales -> (R, 128) f32."""
    rows, cols = codes.shape
    br = min(block_rows, rows)
    rows_p = -(-rows // br) * br
    if rows_p != rows:
        codes = jnp.pad(codes, ((0, rows_p - rows), (0, 0)))
        scales = jnp.pad(scales, ((0, rows_p - rows), (0, 0)))
    tile = pl.BlockSpec((br, cols), lambda i: (i, 0))
    stile = pl.BlockSpec((br, 1), lambda i: (i, 0))
    out = pl.pallas_call(
        _dequantize_kernel,
        grid=(rows_p // br,),
        in_specs=[tile, stile],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((rows_p, cols), jnp.float32),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(codes, scales)
    return out[:rows]


def pack_int4(codes, *, block_rows: int = 256, interpret: bool = False):
    """Nibble-pack (R, 128) int8 codes -> (R, 64) int8 wire bytes (two
    4-bit two's-complement codes per byte; row-major flatten of the
    output is element-ordered — ``ref.pack_int4`` on the flat codes)."""
    rows, cols = codes.shape
    br = min(block_rows, rows)
    rows_p = -(-rows // br) * br
    if rows_p != rows:
        codes = jnp.pad(codes, ((0, rows_p - rows), (0, 0)))
    tile = pl.BlockSpec((br, cols), lambda i: (i, 0))
    otile = pl.BlockSpec((br, cols // 2), lambda i: (i, 0))
    out = pl.pallas_call(
        _pack_kernel,
        grid=(rows_p // br,),
        in_specs=[tile],
        out_specs=otile,
        out_shape=jax.ShapeDtypeStruct((rows_p, cols // 2), jnp.int8),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(codes)
    return out[:rows]


def unpack_int4(packed, *, block_rows: int = 256,
                interpret: bool = False):
    """Inverse of ``pack_int4``: (R, 64) int8 bytes -> (R, 128) int8
    codes in [-7, 7]."""
    rows, cols = packed.shape
    br = min(block_rows, rows)
    rows_p = -(-rows // br) * br
    if rows_p != rows:
        packed = jnp.pad(packed, ((0, rows_p - rows), (0, 0)))
    tile = pl.BlockSpec((br, cols), lambda i: (i, 0))
    otile = pl.BlockSpec((br, cols * 2), lambda i: (i, 0))
    out = pl.pallas_call(
        _unpack_kernel,
        grid=(rows_p // br,),
        in_specs=[tile],
        out_specs=otile,
        out_shape=jax.ShapeDtypeStruct((rows_p, cols * 2), jnp.int8),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(packed)
    return out[:rows]


def quantize_pack_int4(x2d, *, block_rows: int = 256,
                       interpret: bool = False):
    """The fused sender pass: (R, 128) f32 blocks -> (packed (R, 64)
    int8 wire bytes, scales (R, 1) f32, local (R, 128) f32 dequantized
    payload) in ONE kernel launch. Bitwise equal to the composition
    ``quantize_int4`` → ``pack_int4`` → ``dequantize_int4``
    (``ref.quantize_pack_int4`` — tested)."""
    rows, cols = x2d.shape
    br = min(block_rows, rows)
    rows_p = -(-rows // br) * br
    if rows_p != rows:
        x2d = jnp.pad(x2d, ((0, rows_p - rows), (0, 0)))
    tile = pl.BlockSpec((br, cols), lambda i: (i, 0))
    ptile = pl.BlockSpec((br, cols // 2), lambda i: (i, 0))
    stile = pl.BlockSpec((br, 1), lambda i: (i, 0))
    packed, scales, local = pl.pallas_call(
        _quantize_pack_kernel,
        grid=(rows_p // br,),
        in_specs=[tile],
        out_specs=(ptile, stile, tile),
        out_shape=(jax.ShapeDtypeStruct((rows_p, cols // 2), jnp.int8),
                   jax.ShapeDtypeStruct((rows_p, 1), jnp.float32),
                   jax.ShapeDtypeStruct((rows_p, cols), jnp.float32)),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x2d)
    return packed[:rows], scales[:rows], local[:rows]


def unpack_dequantize_int4(packed, scales, *, block_rows: int = 256,
                           interpret: bool = False):
    """The fused receiver pass: (R, 64) int8 wire bytes × (R, 1) f32
    scales -> (R, 128) f32 values in ONE kernel launch (previously
    unpack then dequantize, two launches)."""
    rows, cols = packed.shape
    br = min(block_rows, rows)
    rows_p = -(-rows // br) * br
    if rows_p != rows:
        packed = jnp.pad(packed, ((0, rows_p - rows), (0, 0)))
        scales = jnp.pad(scales, ((0, rows_p - rows), (0, 0)))
    tile = pl.BlockSpec((br, cols), lambda i: (i, 0))
    stile = pl.BlockSpec((br, 1), lambda i: (i, 0))
    otile = pl.BlockSpec((br, cols * 2), lambda i: (i, 0))
    out = pl.pallas_call(
        _unpack_dequant_kernel,
        grid=(rows_p // br,),
        in_specs=[tile, stile],
        out_specs=otile,
        out_shape=jax.ShapeDtypeStruct((rows_p, cols * 2), jnp.float32),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(packed, scales)
    return out[:rows]


def unpack_dequantize_reduce(packed, scales, m, *, block_rows: int = 256,
                             interpret: bool = False):
    """The fused deferred-consume pass: decode EVERY replica's wire
    blocks and mask-combine them in one launch. packed (k, R, 64) int8,
    scales (k, R, 1) f32, m (k,) f32 -> (R, 128) f32 masked sum
    Σ_k m_k · codes_k · scale_k (caller divides by the mask sum).
    Oracle: ``ref.unpack_dequantize_reduce``."""
    k, rows, cols = packed.shape
    br = min(block_rows, rows)
    rows_p = -(-rows // br) * br
    if rows_p != rows:
        packed = jnp.pad(packed, ((0, 0), (0, rows_p - rows), (0, 0)))
        scales = jnp.pad(scales, ((0, 0), (0, rows_p - rows), (0, 0)))
    m3 = m.reshape(k, 1, 1).astype(jnp.float32)
    tile = pl.BlockSpec((k, br, cols), lambda i: (0, i, 0))
    stile = pl.BlockSpec((k, br, 1), lambda i: (0, i, 0))
    mtile = pl.BlockSpec((k, 1, 1), lambda i: (0, 0, 0))
    otile = pl.BlockSpec((br, cols * 2), lambda i: (i, 0))
    out = pl.pallas_call(
        _unpack_dequant_reduce_kernel,
        grid=(rows_p // br,),
        in_specs=[tile, stile, mtile],
        out_specs=otile,
        out_shape=jax.ShapeDtypeStruct((rows_p, cols * 2), jnp.float32),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(packed, scales, m3)
    return out[:rows]


def fake_quant(x, dtype: str, *, block_rows: int = 256,
               interpret: bool = False):
    """Fused quantize→dequantize round trip on a tensor of any shape.
    ``dtype``: "bfloat16" or "int4". Returns x's shape/dtype."""
    if dtype == "float32":
        return x
    shape, out_dtype = x.shape, x.dtype
    x2d, rows_p, br, n = _pad2d(x, block_rows)
    tile = pl.BlockSpec((br, 128), lambda i: (i, 0))
    out = pl.pallas_call(
        functools.partial(_fake_quant_kernel, dtype=dtype),
        grid=(rows_p // br,),
        in_specs=[tile],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((rows_p, 128), jnp.float32),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x2d)
    return out.reshape(-1)[:n].reshape(shape).astype(out_dtype)
