"""Table 2: trade-offs of training algorithms (the paper's main result).

Five rows at micro scale, all starting from the same pretrained model:
  1. Baseline           — finetune, same batch, N steps
  2. Baseline 8x batch  — data-parallel: communicates grads EVERY step
  3. Baseline 8x micro  — same updates as (2) via microbatching: no
                          communication but 8x wall-clock
  4. Baseline 8x steps  — 8N updates (8x wall-clock)
  5. DiLoCo k=8         — N steps of wall-clock, communicates N/H times

Columns: communication (bytes transmitted per replica), wall-clock time
proxy (sequential optimizer steps), compute (total inner steps x batch)
and final validation perplexity. Expected ordering (paper): DiLoCo
beats (1) and (2) on PPL with H x less communication than (2); (4) is
the only thing better, at 8x the time.
"""
from __future__ import annotations

import jax

from . import common as C


def pre_total(p, N):
    return p["pretrain"] + N


def run(scale: int = 1):
    p = dict(C.DEFAULTS)
    rounds = 20 * scale
    H, k = p["H"], p["k"]
    N = rounds * H                       # DiLoCo wall-clock inner steps
    arch, loss_fn, sampler = C.make_setup("non_iid", k=k)
    params0, pre = C.pretrain(arch, loss_fn, sampler, p["pretrain"],
                              batch=p["batch"], seq=p["seq"],
                              lr=p["inner_lr"], warmup=p["warmup"],
                              total=pre_total(p, N))
    pbytes = sum(l.size * 4 for l in jax.tree.leaves(params0))
    rows = []

    # 1. baseline, same batch
    h, _ = C.run_baseline(arch, loss_fn, sampler, params0, steps=N,
                          batch=p["batch"], seq=p["seq"], step0=pre,
                          total=pre + N, inner_lr=p["inner_lr"])
    rows.append(dict(name="baseline", comm_bytes=0, time_steps=N,
                     compute=N * p["batch"], ppl=C.final_ppl(h)))

    # 2. 8x batch via data parallelism: gradient exchange every step
    h, _ = C.run_baseline(arch, loss_fn, sampler, params0, steps=N,
                          batch=k * p["batch"], seq=p["seq"], step0=pre,
                          total=pre + N, inner_lr=p["inner_lr"])
    ppl_big = C.final_ppl(h)
    rows.append(dict(name="baseline_8x_batch_dp", comm_bytes=pbytes * N,
                     time_steps=N, compute=N * k * p["batch"],
                     ppl=ppl_big))

    # 3. 8x batch via microbatching: same maths as (2), zero comm,
    #    8x time
    rows.append(dict(name="baseline_8x_microbatch", comm_bytes=0,
                     time_steps=N * k, compute=N * k * p["batch"],
                     ppl=ppl_big))

    # 4. 8x updates
    h, _ = C.run_baseline(arch, loss_fn, sampler, params0, steps=N * k,
                          batch=p["batch"], seq=p["seq"], step0=pre,
                          total=pre + N * k, inner_lr=p["inner_lr"])
    rows.append(dict(name="baseline_8x_updates", comm_bytes=0,
                     time_steps=N * k, compute=N * k * p["batch"],
                     ppl=C.final_ppl(h)))

    # 5. DiLoCo
    h, _ = C.run_diloco(arch, loss_fn, sampler, params0, k=k, H=H,
                        rounds=rounds, step0=pre, batch=p["batch"],
                        seq=p["seq"], inner_lr=p["inner_lr"])
    rows.append(dict(name="diloco", comm_bytes=pbytes * (N // H),
                     time_steps=N, compute=N * k * p["batch"],
                     ppl=C.final_ppl(h)))

    payload = {"rows": rows, "H": H, "k": k, "N": N,
               "param_bytes": pbytes,
               "claims": {
                   "diloco_beats_baseline":
                       rows[4]["ppl"] < rows[0]["ppl"],
                   "diloco_close_or_better_than_8x_dp":
                       rows[4]["ppl"] < rows[1]["ppl"] * 1.03,
                   "comm_reduction_vs_dp":
                       rows[1]["comm_bytes"] / max(rows[4]["comm_bytes"],
                                                   1)}}
    C.save("table2_tradeoffs", payload)
    return payload


if __name__ == "__main__":
    out = run()
    for r in out["rows"]:
        print(f"{r['name']:26s} comm={r['comm_bytes']:.2e} "
              f"time={r['time_steps']:6d} ppl={r['ppl']:.3f}")
    print(out["claims"])
