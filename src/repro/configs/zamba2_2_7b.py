"""zamba2-2.7b [hybrid, arXiv:2411.15242]: 54 Mamba2 layers
(d_state=64) + one SHARED attention+MLP block invoked every 6 layers
(9 invocations, tied weights), d_model=2560, 32 heads (kv=32),
d_ff=10240, vocab=32000."""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid",
        n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
        d_ff=10_240, vocab_size=32_000,
        ssm_state=64, ssm_expand=2, ssm_heads=80, ssm_chunk=256,
        shared_attn_every=6, pos_emb="rope", norm="layernorm", act="gelu",
        mlp_gated=False,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="zamba2-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=4, d_ff=256, vocab_size=256, ssm_state=16,
        ssm_heads=4, ssm_chunk=32, shared_attn_every=2, attn_chunk=64)
