"""Continuous batching: the engine's outputs must be IDENTICAL to
running each request in isolation (shared-clock alignment is exact for
translation-invariant positions), and slots must refill dynamically."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.batching import ContinuousBatcher
from repro.launch.serve import greedy_decode
from repro.models.registry import get_smoke_arch


def _isolated(arch, params, prompt, gen):
    toks = greedy_decode(arch, params, jnp.asarray(prompt)[None],
                         gen=gen)
    return np.asarray(toks[0], np.int64)


@pytest.mark.parametrize("name", ["stablelm_1_6b", "zamba2_2_7b"])
def test_continuous_matches_isolated(name):
    arch = get_smoke_arch(name)
    params, _ = arch.init(jax.random.PRNGKey(0), arch.cfg)
    key = jax.random.PRNGKey(1)
    prompts = [
        np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                      (L,), 0, arch.cfg.vocab_size))
        for i, L in enumerate([12, 7, 19, 5])]
    gens = [6, 9, 4, 8]

    eng = ContinuousBatcher(arch, params, slots=2, cache_len=96)
    rids = [eng.submit(p, g) for p, g in zip(prompts, gens)]
    out = eng.run_until_drained()
    assert set(out) == set(rids)

    for rid, p, g in zip(rids, prompts, gens):
        want = _isolated(arch, params, p, g)
        np.testing.assert_array_equal(out[rid], want,
                                      err_msg=f"{name} rid={rid}")


def test_slots_refill():
    arch = get_smoke_arch("stablelm_1_6b")
    params, _ = arch.init(jax.random.PRNGKey(0), arch.cfg)
    eng = ContinuousBatcher(arch, params, slots=2, cache_len=64)
    for i in range(5):
        eng.submit(np.arange(4) + i, 3)
    out = eng.run_until_drained()
    assert len(out) == 5                 # 5 requests through 2 slots
    assert all(len(v) == 3 for v in out.values())


def test_learned_positions_rejected():
    arch = get_smoke_arch("whisper_large_v3")
    params, _ = arch.init(jax.random.PRNGKey(0), arch.cfg)
    with pytest.raises(ValueError):
        ContinuousBatcher(arch, params, slots=2, cache_len=64)
