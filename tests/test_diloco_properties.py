"""Property tests of the DiLoCo algorithm (core/diloco.py).

The paper defines exact equivalences at parameter corners — these pin
the implementation to Algorithm 1:
  * OuterOpt=SGD(lr=1)  => outer step == plain replica averaging (FedAvg)
  * k=1, SGD(lr=1)      => outer step == adopting the single replica
  * worker permutation invariance of the outer update
  * drop-mask semantics: dropped replica keeps its own params
  * active-mask semantics: inactive replicas are parked & excluded
  * H=1 + inner SGD + outer SGD(lr=1) == large-batch data parallelism
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.base import DiLoCoConfig, TrainConfig
from repro.core import diloco, outer_opt


def tiny_params(key, scale=1.0):
    k1, k2 = jax.random.split(key)
    return {"w": scale * jax.random.normal(k1, (4, 3)),
            "b": scale * jax.random.normal(k2, (3,))}


def randomized_state(key, dcfg, spread=1.0):
    params = tiny_params(key)
    state = diloco.init_state(params, dcfg)
    noise = jax.tree.map(
        lambda p: spread * jax.random.normal(
            jax.random.fold_in(key, 7),
            (dcfg.k,) + p.shape), params)
    return state._replace(
        replica_params=jax.tree.map(jnp.add, state.replica_params, noise))


def leaves_allclose(a, b, **kw):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(x, y, **kw)


# ---------------------------------------------------------------------------
# corner equivalences
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 2**30), k=st.sampled_from([1, 2, 4, 8]))
@settings(max_examples=20, deadline=None)
def test_sgd_lr1_is_fedavg(seed, k):
    """θ^(t) = θ - 1·mean(θ - θ_i) = mean(θ_i): exact FedAvg."""
    dcfg = DiLoCoConfig(k=k, outer_opt="sgd", outer_lr=1.0)
    state = randomized_state(jax.random.PRNGKey(seed), dcfg)
    new, _ = diloco.outer_step(state, dcfg)
    want = jax.tree.map(lambda r: r.mean(0), state.replica_params)
    leaves_allclose(new.global_params, want, rtol=1e-6, atol=1e-6)
    # all replicas re-dispatched to the new global copy
    for x, y in zip(jax.tree.leaves(new.replica_params),
                    jax.tree.leaves(new.global_params)):
        for i in range(k):
            np.testing.assert_allclose(x[i], y, rtol=1e-6, atol=1e-6)


@given(seed=st.integers(0, 2**30))
@settings(max_examples=15, deadline=None)
def test_permutation_invariance(seed):
    dcfg = DiLoCoConfig(k=4, outer_opt="nesterov")
    state = randomized_state(jax.random.PRNGKey(seed), dcfg)
    perm = np.array([2, 0, 3, 1])
    state_p = state._replace(
        replica_params=jax.tree.map(lambda r: r[perm],
                                    state.replica_params))
    a, _ = diloco.outer_step(state, dcfg)
    b, _ = diloco.outer_step(state_p, dcfg)
    leaves_allclose(a.global_params, b.global_params, rtol=1e-6, atol=1e-6)


@given(seed=st.integers(0, 2**30),
       dropped=st.integers(0, 3))
@settings(max_examples=15, deadline=None)
def test_drop_mask_semantics(seed, dropped):
    """Dropped replica keeps its own params; average excludes it."""
    dcfg = DiLoCoConfig(k=4, outer_opt="sgd", outer_lr=1.0)
    state = randomized_state(jax.random.PRNGKey(seed), dcfg)
    mask = np.ones(4, np.float32)
    mask[dropped] = 0.0
    new, _ = diloco.outer_step(state, dcfg, drop_mask=jnp.asarray(mask))
    keep = [i for i in range(4) if i != dropped]
    want = jax.tree.map(lambda r: r[np.array(keep)].mean(0),
                        state.replica_params)
    leaves_allclose(new.global_params, want, rtol=1e-6, atol=1e-6)
    # the dropped replica continues from ITS OWN params (Fig 8)
    for x_new, x_old in zip(jax.tree.leaves(new.replica_params),
                            jax.tree.leaves(state.replica_params)):
        np.testing.assert_allclose(x_new[dropped], x_old[dropped],
                                   rtol=1e-7, atol=1e-7)


def test_active_mask_excludes_inactive():
    dcfg = DiLoCoConfig(k=4, outer_opt="sgd", outer_lr=1.0)
    state = randomized_state(jax.random.PRNGKey(3), dcfg)
    act = jnp.asarray([1.0, 1.0, 0.0, 0.0])
    new, _ = diloco.outer_step(state, dcfg, active_mask=act)
    want = jax.tree.map(lambda r: r[:2].mean(0), state.replica_params)
    leaves_allclose(new.global_params, want, rtol=1e-6, atol=1e-6)


def test_weighted_average():
    dcfg = DiLoCoConfig(k=2, outer_opt="sgd", outer_lr=1.0)
    state = randomized_state(jax.random.PRNGKey(4), dcfg)
    w = jnp.asarray([3.0, 1.0])
    new, _ = diloco.outer_step(state, dcfg, weights=w)
    want = jax.tree.map(lambda r: (3 * r[0] + r[1]) / 4,
                        state.replica_params)
    leaves_allclose(new.global_params, want, rtol=1e-6, atol=1e-6)


def test_nesterov_matches_manual():
    """One Nesterov outer step against the hand-written recurrence."""
    dcfg = DiLoCoConfig(k=2, outer_opt="nesterov", outer_lr=0.7,
                        outer_momentum=0.9)
    state = randomized_state(jax.random.PRNGKey(5), dcfg)
    delta = jax.tree.map(lambda g, r: g - r.mean(0),
                         state.global_params, state.replica_params)
    buf = jax.tree.map(lambda d: d, delta)                 # b1 = Δ (b0=0)
    want = jax.tree.map(lambda p, b, d: p - 0.7 * (0.9 * b + d),
                        state.global_params, buf, delta)
    new, _ = diloco.outer_step(state, dcfg)
    leaves_allclose(new.global_params, want, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# H=1 + inner/outer SGD == large-batch data parallelism (paper §2)
# ---------------------------------------------------------------------------

def test_h1_sgd_equals_data_parallel():
    key = jax.random.PRNGKey(0)
    params = tiny_params(key)

    def loss_fn(p, batch):
        x, y = batch["x"], batch["y"]
        pred = x @ p["w"] + p["b"]
        return jnp.mean((pred - y) ** 2), {}

    k, B = 4, 8
    lr = 0.05
    kx, ky = jax.random.split(jax.random.PRNGKey(1))
    X = jax.random.normal(kx, (k, B, 4))
    Y = jax.random.normal(ky, (k, B, 3))

    # --- DiLoCo: k workers, H=1, inner SGD, outer SGD(lr=1) ---
    def inner_sgd(p, batch):
        g = jax.grad(lambda q: loss_fn(q, batch)[0])(p)
        return jax.tree.map(lambda a, b: a - lr * b, p, g)

    replicas = [inner_sgd(params, {"x": X[i], "y": Y[i]})
                for i in range(k)]
    mean_rep = jax.tree.map(
        lambda *ls: jnp.stack(ls).mean(0), *replicas)

    # --- large-batch SGD over the concatenated batch ---
    big = {"x": X.reshape(k * B, 4), "y": Y.reshape(k * B, 3)}
    g = jax.grad(lambda q: loss_fn(q, big)[0])(params)
    want = jax.tree.map(lambda a, b: a - lr * b, params, g)

    leaves_allclose(mean_rep, want, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# outer optimizers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["sgd", "sgdm", "nesterov", "adam"])
def test_outer_opt_against_numpy(kind):
    key = jax.random.PRNGKey(0)
    params = tiny_params(key)
    state = outer_opt.init(params)
    delta = jax.tree.map(lambda p: 0.1 * jnp.ones_like(p), params)
    lr, mu, b2, eps = 0.7, 0.9, 0.95, 0.1

    p_np = {k2: np.array(v) for k2, v in params.items()}
    buf = {k2: np.zeros_like(v) for k2, v in p_np.items()}
    buf2 = {k2: np.zeros_like(v) for k2, v in p_np.items()}
    p, s = params, state
    for t in range(1, 4):
        p, s = outer_opt.update(delta, s, p, kind=kind, lr=lr,
                                momentum=mu, b2=b2, eps=eps)
        for k2 in p_np:
            d = 0.1 * np.ones_like(p_np[k2])
            if kind == "sgd":
                p_np[k2] -= lr * d
            elif kind == "sgdm":
                buf[k2] = mu * buf[k2] + d
                p_np[k2] -= lr * buf[k2]
            elif kind == "nesterov":
                buf[k2] = mu * buf[k2] + d
                p_np[k2] -= lr * (mu * buf[k2] + d)
            else:
                buf[k2] = mu * buf[k2] + (1 - mu) * d
                buf2[k2] = b2 * buf2[k2] + (1 - b2) * d * d
                mh = buf[k2] / (1 - mu ** t)
                vh = buf2[k2] / (1 - b2 ** t)
                p_np[k2] -= lr * mh / (np.sqrt(vh) + eps)
            np.testing.assert_allclose(p[k2], p_np[k2], rtol=1e-5,
                                       atol=1e-6, err_msg=f"{kind} t={t}")


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 2**30),
       frac=st.sampled_from([0.25, 0.5, 0.75]))
@settings(max_examples=20, deadline=None)
def test_sign_prune_density(seed, frac):
    from repro.core.compression import sign_prune, density
    x = {"w": jax.random.normal(jax.random.PRNGKey(seed), (8, 64))}
    pruned = sign_prune(x, frac)
    d = float(density(pruned))
    assert d <= 1.0 - frac + 0.02
    # pruning keeps values verbatim (no rescale in Tab 6's variant)
    kept = np.asarray(pruned["w"] != 0)
    np.testing.assert_allclose(np.asarray(pruned["w"])[kept],
                               np.asarray(x["w"])[kept])


def test_sign_prune_zero_frac_identity():
    from repro.core.compression import sign_prune
    x = {"w": jnp.arange(12.0).reshape(3, 4)}
    out = sign_prune(x, 0.0)
    np.testing.assert_array_equal(out["w"], x["w"])
