"""Tests for the dispatch-free round path: the scanned multi-round
driver (``diloco.make_run``) and the fused optimizer kernels behind
``kernel_mode``.

Pins the two contracts the refactor must keep:
  * the scanned driver is bit-identical to R iterations of the legacy
    per-round loop (same key chain, ref mode);
  * the fused AdamW / Nesterov kernels (interpret mode on CPU) match
    the legacy jnp tree maps through a full DiLoCo round.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import DiLoCoConfig, TrainConfig, ModelConfig
from repro.core import diloco, outer_opt
from repro.data.sharding import make_regime
from repro.models.registry import Arch
from repro.optim import adamw

K, H, B, S, VOCAB = 2, 3, 2, 16, 64


@pytest.fixture(scope="module")
def setup():
    cfg = ModelConfig(name="tiny", family="dense", n_layers=1,
                      d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                      vocab_size=VOCAB, remat=False, attn_chunk=32)
    arch = Arch(cfg=cfg)
    loss_fn = lambda p, b: arch.loss(p, b)
    sampler = make_regime("non_iid", k=K, vocab_size=VOCAB, seed=0)
    params, _ = arch.init(jax.random.PRNGKey(0), cfg)
    return arch, loss_fn, sampler, params


def _cfgs(kernel_mode="ref", rounds=4):
    dcfg = DiLoCoConfig(k=K, H=H, kernel_mode=kernel_mode)
    tcfg = TrainConfig(inner_lr=3e-3, warmup_steps=2,
                       total_steps=rounds * H, batch_size=B, seq_len=S,
                       kernel_mode=kernel_mode)
    return dcfg, tcfg


def test_scanned_run_bit_identical_to_legacy_loop(setup):
    """One make_run call == R iterations of make_round, to the bit."""
    arch, loss_fn, sampler, params = setup
    R = 4
    dcfg, tcfg = _cfgs(rounds=R)

    state_l = diloco.init_state(params, dcfg)
    rnd = diloco.make_round(loss_fn, sampler.sample_all_shards, dcfg,
                            tcfg, total_steps=R * H, batch_size=B,
                            seq_len=S)
    key = jax.random.PRNGKey(5)
    inner_losses = []
    for _ in range(R):
        key, sub = jax.random.split(key)
        state_l, m = rnd(state_l, sub)
        inner_losses.append(float(m["inner_loss"]))

    state_s = diloco.init_state(params, dcfg)
    run = diloco.make_run(loss_fn, sampler.sample_all_shards, dcfg,
                          tcfg, rounds_per_call=R, total_steps=R * H,
                          batch_size=B, seq_len=S, donate=False)
    state_s, ms = run(state_s, jax.random.PRNGKey(5))

    for a, b in zip(jax.tree.leaves(state_l), jax.tree.leaves(state_s)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_allclose(np.asarray(ms["inner_loss"]),
                               np.asarray(inner_losses), rtol=1e-6)


def test_scanned_run_with_masks_matches_legacy(setup):
    """Stacked (R, k) drop/active masks reproduce per-round masks."""
    arch, loss_fn, sampler, params = setup
    R = 3
    dcfg, tcfg = _cfgs(rounds=R)
    rng = np.random.default_rng(0)
    drops = (rng.random((R, K)) >= 0.5).astype(np.float32)
    drops[:, 0] = 1.0                       # keep the average non-empty
    acts = np.ones((R, K), np.float32)
    weights = jnp.asarray([0.75, 0.25])

    state_l = diloco.init_state(params, dcfg)
    rnd = diloco.make_round(loss_fn, sampler.sample_all_shards, dcfg,
                            tcfg, total_steps=R * H, batch_size=B,
                            seq_len=S)
    key = jax.random.PRNGKey(7)
    for t in range(R):
        key, sub = jax.random.split(key)
        state_l, _ = rnd(state_l, sub, jnp.asarray(drops[t]),
                         jnp.asarray(acts[t]), weights)

    state_s = diloco.init_state(params, dcfg)
    run = diloco.make_run(loss_fn, sampler.sample_all_shards, dcfg,
                          tcfg, rounds_per_call=R, total_steps=R * H,
                          batch_size=B, seq_len=S, donate=False)
    state_s, _ = run(state_s, jax.random.PRNGKey(7), jnp.asarray(drops),
                     jnp.asarray(acts), weights)

    for a, b in zip(jax.tree.leaves(state_l), jax.tree.leaves(state_s)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_scanned_run_in_graph_eval_and_donation(setup):
    """Periodic in-graph eval: NaN on skipped rounds, a real loss on
    eval rounds; the donated carry survives repeated calls and does not
    delete the caller's params."""
    arch, loss_fn, sampler, params = setup
    R = 4
    dcfg, tcfg = _cfgs(rounds=2 * R)
    val = sampler.sample_validation(jax.random.PRNGKey(9), 4, S)
    ev = diloco.make_eval(loss_fn)
    run = diloco.make_run(loss_fn, sampler.sample_all_shards, dcfg,
                          tcfg, rounds_per_call=R, total_steps=2 * R * H,
                          batch_size=B, seq_len=S, eval_tokens=val,
                          eval_every=2, donate=True)
    state = diloco.init_state(params, dcfg)
    state, ms = run(state, jax.random.PRNGKey(1))
    state, ms = run(state, jax.random.PRNGKey(2))   # donated second call
    vl = np.asarray(ms["val_loss"])
    assert np.isnan(vl[0]) and np.isnan(vl[2])
    assert np.isfinite(vl[1]) and np.isfinite(vl[3])
    # in-graph eval agrees with the host-side eval of the final state
    np.testing.assert_allclose(
        vl[-1], float(ev(state.global_params, val)), rtol=1e-6)
    # the caller's params tree is still alive after donation
    assert np.isfinite(float(jax.tree.leaves(params)[0].sum()))


@pytest.mark.parametrize("shape", [(64,), (33, 7), (4, 32, 16)])
def test_fused_adamw_interpret_matches_legacy_update(shape):
    """adamw.update(mode='interpret') — the Pallas kernel — matches the
    legacy jnp tree map."""
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 2)
    params = {"w": jax.random.normal(ks[0], shape)}
    grads = {"w": jax.random.normal(ks[1], shape)}
    st = adamw.init(params)
    st = adamw.AdamWState(st.m, st.v, jnp.asarray(3, jnp.int32))
    ref_p, ref_st = adamw.update(grads, st, params, lr=1e-2, mode="ref")
    ker_p, ker_st = adamw.update(grads, st, params, lr=1e-2,
                                 mode="interpret")
    np.testing.assert_allclose(ref_p["w"], ker_p["w"], rtol=2e-6,
                               atol=2e-6)
    np.testing.assert_allclose(ref_st.m["w"], ker_st.m["w"], rtol=2e-6,
                               atol=2e-6)
    np.testing.assert_allclose(ref_st.v["w"], ker_st.v["w"], rtol=2e-6,
                               atol=2e-6)
    assert int(ker_st.count) == int(ref_st.count) == 4


def test_fused_nesterov_interpret_matches_legacy_update():
    """outer_opt.update(kernel_mode='interpret') matches the legacy
    Nesterov tree map."""
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 3)
    params = {"w": jax.random.normal(ks[0], (17, 9))}
    delta = {"w": jax.random.normal(ks[1], (17, 9))}
    st = outer_opt.init(params)
    st = outer_opt.OuterState(
        {"w": jax.random.normal(ks[2], (17, 9))}, st.buf2, st.count)
    ref_p, ref_st = outer_opt.update(delta, st, params, kind="nesterov",
                                     lr=0.7, kernel_mode="ref")
    ker_p, ker_st = outer_opt.update(delta, st, params, kind="nesterov",
                                     lr=0.7, kernel_mode="interpret")
    np.testing.assert_allclose(ref_p["w"], ker_p["w"], rtol=2e-6,
                               atol=2e-6)
    np.testing.assert_allclose(ref_st.buf["w"], ker_st.buf["w"],
                               rtol=2e-6, atol=2e-6)


def test_full_round_interpret_matches_ref(setup):
    """kernel_mode='interpret' (fused AdamW + Nesterov through the
    Pallas kernels) matches kernel_mode='ref' through a full round."""
    arch, loss_fn, sampler, params = setup
    states = {}
    for mode in ("ref", "interpret"):
        dcfg, tcfg = _cfgs(kernel_mode=mode, rounds=1)
        st = diloco.init_state(params, dcfg)
        rnd = diloco.make_round(loss_fn, sampler.sample_all_shards,
                                dcfg, tcfg, total_steps=H, batch_size=B,
                                seq_len=S)
        st, _ = rnd(st, jax.random.PRNGKey(3))
        states[mode] = st
    for a, b in zip(jax.tree.leaves(states["ref"]),
                    jax.tree.leaves(states["interpret"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-6)


def test_kernel_mode_ref_is_default_and_unchanged(setup):
    """The default configs run the legacy tree-map path — guard against
    a silent default flip changing numerics for every existing user."""
    assert DiLoCoConfig().kernel_mode == "ref"
    assert TrainConfig().kernel_mode == "ref"
