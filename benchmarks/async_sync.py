"""Barrier-free outer sync vs the synchronous baseline — the tentpole
benchmark for the async + gossip transports. Writes
``BENCH_async.json`` at the repo root (superseding the old
``beyond_async`` results module, which is now a thin wrapper over
this) — the regression record every future PR measures the barrier-free
tier against.

Three measured comparisons, each with a gated claim:

  equal tokens    sync DiLoCo, async (uniform speeds, λ=1) and gossip
                  (butterfly, mix=0.5) train on the SAME total token
                  budget (k·H·R inner steps). Barrier-free application
                  and pairwise mixing must stay within 1.10× of the
                  synchronous perplexity — removing the barrier is a
                  scheduling change, not a model-quality change.
  stragglers      heterogeneous speeds (1,1,2,4): the synchronous
                  barrier paces the fleet at the SLOWEST island, the
                  async engine applies every finished delta
                  immediately. At equal wall-clock, async must deliver
                  more outer updates and a better perplexity, and the
                  λ=0.7 staleness discount must not hurt (§5's
                  "waiting ... is rather inefficient").
  faults          a drop/retry scenario (p=0.5, two retries): every
                  applied delta must match the fault timeline's
                  exactly-once contract, and graceful degradation must
                  hold — ≤1.10× the perplexity of a fault-free async
                  run with a MATCHED number of applied deltas (drops
                  cost wall-clock; they must not poison the model the
                  surviving deltas build — Fig 8's finding, carried to
                  the barrier-free tier).

Plus the wire accounting claim: an int4+error-feedback async
application ships one packed transfer ≥5× smaller than raw f32.

Run:  PYTHONPATH=src python -m benchmarks.async_sync [--rounds 16 ...]
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import numpy as np

from repro.configs.base import DiLoCoConfig, TrainConfig
from repro.core import diloco, faults, gossip
from repro.core.async_diloco import AsyncEngine
from . import common as C

ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
OUT_PATH = os.path.join(ROOT, "BENCH_async.json")

STRAGGLER_SPEEDS = (1, 1, 2, 4)

# last in-process result, so the superseded beyond_async wrapper can
# re-export the straggler slice without re-running the whole benchmark
LAST_RESULT: dict | None = None


def _tcfg(p, total):
    return TrainConfig(inner_lr=p["inner_lr"], warmup_steps=p["warmup"],
                       total_steps=total, batch_size=p["batch"],
                       seq_len=p["seq"])


def _async_run(loss_fn, sampler, params0, p, *, k, lam, scenario,
               ticks, total, pre, dcfg_kw=None, seed=0):
    """One AsyncEngine run; returns (final ppl, history, engine)."""
    dcfg = DiLoCoConfig(k=k, H=p["H"], transport="async",
                        staleness_lambda=lam, **(dcfg_kw or {}))
    eng = AsyncEngine(
        loss_fn,
        tuple((lambda i: lambda kk, B, S: sampler.sample_shard(
            kk, i, B, S))(i) for i in range(k)),
        dcfg, _tcfg(p, total), scenario=scenario, total_steps=total,
        seed=seed)
    state = eng.init_state(params0)
    state.inner_done = pre           # lr schedule continues the pretrain
    state, hist = eng.run(state, ticks=ticks)
    ev = diloco.make_eval(loss_fn)
    val = sampler.sample_validation(jax.random.PRNGKey(10_000), 64,
                                    p["seq"])
    vl = float(ev(state.global_params, val))
    return float(np.exp(vl)), hist, eng


def _gossip_run(loss_fn, sampler, params0, p, *, k, rounds, total, pre):
    dcfg = DiLoCoConfig(k=k, H=p["H"], transport="gossip",
                        gossip_pairing="butterfly", gossip_mix=0.5)
    val = sampler.sample_validation(jax.random.PRNGKey(10_000), 64,
                                    p["seq"])
    run = diloco.make_run(loss_fn, sampler.sample_all_shards, dcfg,
                          _tcfg(p, total), rounds_per_call=rounds,
                          total_steps=total, batch_size=p["batch"],
                          seq_len=p["seq"], eval_tokens=val,
                          eval_every=rounds)
    state = gossip.init_state(params0, dcfg)
    state = state._replace(inner_steps_done=jax.numpy.asarray(pre))
    state, ms = run(state, jax.random.PRNGKey(p["seed"] + 2), None,
                    None, None)
    return float(np.exp(float(np.asarray(ms["val_loss"])[-1])))


def run(scale: int = 1, *, k=4, rounds=16, straggler_ticks=24,
        drop_prob=0.5, pretrain=150, seed=0, out=OUT_PATH, **overrides):
    p = dict(C.DEFAULTS, k=k, seed=seed, pretrain=pretrain, **overrides)
    rounds = rounds * scale
    straggler_ticks = straggler_ticks * scale
    H = p["H"]
    arch, loss_fn, sampler = C.make_setup("non_iid", k=k, seed=seed)
    budget = max(rounds * H * k, straggler_ticks * H * k)
    params0, pre = C.pretrain(arch, loss_fn, sampler, p["pretrain"],
                              batch=p["batch"], seq=p["seq"],
                              lr=p["inner_lr"], warmup=p["warmup"],
                              total=p["pretrain"] + budget, seed=seed)
    total = pre + budget

    # --- equal token budget: sync vs async (uniform, λ=1) vs gossip ---
    h, _ = C.run_diloco(arch, loss_fn, sampler, params0, k=k, H=H,
                        rounds=rounds, step0=pre, batch=p["batch"],
                        seq=p["seq"], inner_lr=p["inner_lr"],
                        warmup=p["warmup"], eval_every=rounds, seed=seed)
    sync_ppl = C.final_ppl(h)
    async_ppl, _, _ = _async_run(
        loss_fn, sampler, params0, p, k=k, lam=1.0,
        scenario=faults.Scenario.uniform(k), ticks=rounds, total=total,
        pre=pre, seed=seed)
    gossip_ppl = _gossip_run(loss_fn, sampler, params0, p, k=k,
                             rounds=rounds, total=total, pre=pre)

    # --- stragglers at equal wall-clock ---
    scen_str = faults.Scenario(speeds=STRAGGLER_SPEEDS[:k])
    barrier = scen_str.sync_round_ticks(k)
    sync_str_rounds = max(1, straggler_ticks // barrier)
    h, _ = C.run_diloco(arch, loss_fn, sampler, params0, k=k, H=H,
                        rounds=sync_str_rounds, step0=pre,
                        batch=p["batch"], seq=p["seq"],
                        inner_lr=p["inner_lr"], warmup=p["warmup"],
                        eval_every=sync_str_rounds, seed=seed)
    sync_str_ppl = C.final_ppl(h)
    straggler = {"sync": {"ppl": sync_str_ppl,
                          "outer_updates": sync_str_rounds,
                          "barrier_ticks": barrier}}
    for lam in (0.7, 1.0):
        ppl, hist, _ = _async_run(
            loss_fn, sampler, params0, p, k=k, lam=lam,
            scenario=scen_str, ticks=straggler_ticks, total=total,
            pre=pre, seed=seed)
        arr = [r for r in hist if r["event"] == "arrival"]
        straggler[f"async_lam{lam}"] = {
            "ppl": ppl, "outer_updates": len(arr),
            "mean_staleness": float(np.mean(
                [r["staleness"] for r in arr])) if arr else 0.0}

    # --- drop/retry faults: exactly-once + graceful degradation ---
    # two retries: each transfer independently drops with p, so ~p^3 of
    # phases are lost outright — degradation-with-retry, not blackout
    scen_drop = faults.Scenario(speeds=(1,) * k, drop_prob=drop_prob,
                                max_retries=2, seed=seed)
    drop_ppl, hist, _ = _async_run(
        loss_fn, sampler, params0, p, k=k, lam=1.0, scenario=scen_drop,
        ticks=rounds, total=total, pre=pre, seed=seed)
    ev_stream = scen_drop.timeline(k, rounds)
    want = sorted(e.uid for e in ev_stream
                  if isinstance(e, faults.Arrival))
    got = sorted(r["uid"] for r in hist if r["event"] == "arrival")
    lost = sum(1 for r in hist if r["event"] == "lost")
    # retries/losses cost wall-clock, so the drop run applies fewer
    # deltas than the full fault-free run — the degradation claim
    # compares against a fault-free run with a MATCHED applied count
    # (faults must not poison what the surviving deltas build)
    ref_ticks = max(1, round(len(got) / k))
    ref_ppl, _, _ = _async_run(
        loss_fn, sampler, params0, p, k=k, lam=1.0,
        scenario=faults.Scenario.uniform(k), ticks=ref_ticks,
        total=total, pre=pre, seed=seed)
    # and the synchronous transport under the same drop rate — Fig 8's
    # graceful-degradation finding, pinned here so the claim rides the
    # gated BENCH file (fig8_async_drop keeps the full drop sweep)
    h, _ = C.run_diloco(arch, loss_fn, sampler, params0, k=k, H=H,
                        rounds=rounds, step0=pre, drop_prob=drop_prob,
                        batch=p["batch"], seq=p["seq"],
                        inner_lr=p["inner_lr"], warmup=p["warmup"],
                        eval_every=rounds, seed=seed)
    sync_drop_ppl = C.final_ppl(h)

    # --- packed wire accounting (one transfer per application) ---
    _, whist, eng_q = _async_run(
        loss_fn, sampler, params0, p, k=k, lam=1.0,
        scenario=faults.Scenario.uniform(k), ticks=2, total=total,
        pre=pre, dcfg_kw=dict(outer_grad_dtype="int4",
                              error_feedback=True), seed=seed)
    int4_bytes = eng_q.wire_bytes()
    f32_bytes = 4 * eng_q._n_elems

    a7, a10 = straggler["async_lam0.7"], straggler["async_lam1.0"]
    payload = {
        "config": {"k": k, "H": H, "rounds": rounds,
                   "straggler_speeds": STRAGGLER_SPEEDS[:k],
                   "straggler_ticks": straggler_ticks,
                   "drop_prob": drop_prob, "pretrain": pre,
                   "batch": p["batch"], "seq": p["seq"], "seed": seed},
        "equal_tokens": {"sync_ppl": sync_ppl, "async_ppl": async_ppl,
                         "gossip_ppl": gossip_ppl},
        "straggler": straggler,
        "drop": {"ppl": drop_ppl, "fault_free_matched_ppl": ref_ppl,
                 "matched_ticks": ref_ticks,
                 "applied": len(got), "lost": lost,
                 "sync_drop_ppl": sync_drop_ppl},
        "wire": {"int4_bytes_per_apply": int4_bytes,
                 "f32_bytes_per_apply": f32_bytes,
                 "applies_recorded": len(
                     [r for r in whist if r["event"] == "arrival"])},
        "claims": {
            "async_ppl_within_1p10_of_sync_equal_tokens":
                async_ppl <= 1.10 * sync_ppl,
            "gossip_ppl_within_1p10_of_sync_equal_tokens":
                gossip_ppl <= 1.10 * sync_ppl,
            "async_beats_straggler_paced_sync":
                a7["ppl"] < sync_str_ppl,
            "async_more_updates_per_wallclock":
                a7["outer_updates"] > sync_str_rounds,
            "staleness_discount_not_harmful":
                a7["ppl"] < a10["ppl"] * 1.05,
            "async_graceful_under_50pct_drop":
                drop_ppl <= 1.10 * ref_ppl,
            "sync_graceful_under_50pct_drop_noniid":
                sync_drop_ppl <= 1.10 * sync_ppl,
            "async_exactly_once_under_drop": got == want,
            "async_int4_wire_reduction_ge5x":
                f32_bytes >= 5 * int4_bytes,
        }}

    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
    print("wrote", out)
    C.save("async_sync", payload)
    global LAST_RESULT
    LAST_RESULT = payload
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--H", type=int, default=C.DEFAULTS["H"])
    ap.add_argument("--rounds", type=int, default=16)
    ap.add_argument("--straggler-ticks", type=int, default=24)
    ap.add_argument("--drop-prob", type=float, default=0.5)
    ap.add_argument("--batch", type=int, default=C.DEFAULTS["batch"])
    ap.add_argument("--seq", type=int, default=C.DEFAULTS["seq"])
    ap.add_argument("--pretrain", type=int, default=150)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=OUT_PATH)
    a = ap.parse_args(argv)
    res = run(1, k=a.k, rounds=a.rounds,
              straggler_ticks=a.straggler_ticks, drop_prob=a.drop_prob,
              pretrain=a.pretrain, seed=a.seed, out=a.out,
              H=a.H, batch=a.batch, seq=a.seq)
    eq = res["equal_tokens"]
    print(f"equal tokens: sync={eq['sync_ppl']:.2f} "
          f"async={eq['async_ppl']:.2f} gossip={eq['gossip_ppl']:.2f}")
    st = res["straggler"]
    print(f"stragglers:   sync={st['sync']['ppl']:.2f} "
          f"({st['sync']['outer_updates']} upd)  "
          f"async λ=0.7 {st['async_lam0.7']['ppl']:.2f} "
          f"({st['async_lam0.7']['outer_updates']} upd)")
    print(f"drop p={res['config']['drop_prob']}: "
          f"ppl={res['drop']['ppl']:.2f} applied={res['drop']['applied']} "
          f"lost={res['drop']['lost']}")
    print(res["claims"])
    return 0 if all(v for v in res["claims"].values()) else 1


if __name__ == "__main__":
    raise SystemExit(main())
