"""Figure 5: i.i.d. vs non-i.i.d. data regimes.

Expectation: i.i.d. converges faster early, but final generalization is
comparable — DiLoCo is robust to shard distribution."""
from __future__ import annotations

from . import common as C


def run(scale: int = 1):
    p = dict(C.DEFAULTS)
    rounds = 25 * scale
    rows = []
    for regime in ("iid", "non_iid"):
        arch, loss_fn, sampler = C.make_setup(regime, k=p["k"])
        params0, pre = C.pretrain(
            arch, loss_fn, sampler, p["pretrain"], batch=p["batch"],
            seq=p["seq"], lr=p["inner_lr"], warmup=p["warmup"],
            total=p["pretrain"] + rounds * p["H"])
        h, _ = C.run_diloco(arch, loss_fn, sampler, params0, k=p["k"],
                            H=p["H"], rounds=rounds, step0=pre,
                            batch=p["batch"], seq=p["seq"])
        rows.append(dict(regime=regime, ppl=C.final_ppl(h), curve=h))
    ppl = {r["regime"]: r["ppl"] for r in rows}
    early = {r["regime"]: r["curve"][max(len(r["curve"]) // 5, 1) - 1]
             ["ppl"] for r in rows}
    payload = {"rows": rows,
               "claims": {
                   "final_generalization_comparable":
                       abs(ppl["iid"] - ppl["non_iid"])
                       / ppl["iid"] < 0.08,
                   "iid_faster_early": early["iid"]
                       <= early["non_iid"] * 1.05}}
    C.save("fig5_data_regimes", payload)
    return payload


if __name__ == "__main__":
    out = run()
    for r in out["rows"]:
        print(f"{r['regime']:8s} final ppl={r['ppl']:.3f}")
    print(out["claims"])
