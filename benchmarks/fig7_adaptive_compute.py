"""Figure 7: adaptive compute pool.

The number of active replicas varies over training per six schedules.
Expectation: final quality tracks TOTAL compute, not its allocation in
time — doubling ~= halving, ramp_up ~= ramp_down."""
from __future__ import annotations

from . import common as C

SCHEDULES = ["constant_local", "constant_distributed", "doubling",
             "halving", "ramp_up", "ramp_down"]


def run(scale: int = 1):
    p = dict(C.DEFAULTS)
    rounds = 20 * scale
    arch, loss_fn, sampler = C.make_setup("iid", k=p["k"])
    params0, pre = C.pretrain(arch, loss_fn, sampler, p["pretrain"],
                              batch=p["batch"], seq=p["seq"],
                              lr=p["inner_lr"], warmup=p["warmup"],
                              total=p["pretrain"] + rounds * p["H"])
    rows = []
    for sched in SCHEDULES:
        h, _ = C.run_diloco(arch, loss_fn, sampler, params0, k=p["k"],
                            H=p["H"], rounds=rounds, step0=pre,
                            compute_schedule=sched, batch=p["batch"],
                            seq=p["seq"])
        rows.append(dict(schedule=sched, ppl=C.final_ppl(h),
                         total_compute=h[-1]["compute_steps"], curve=h))
    ppl = {r["schedule"]: r["ppl"] for r in rows}
    payload = {"rows": rows,
               "claims": {
                   "doubling_equals_halving":
                       abs(ppl["doubling"] - ppl["halving"])
                       / ppl["halving"] < 0.08,
                   "ramps_equal":
                       abs(ppl["ramp_up"] - ppl["ramp_down"])
                       / ppl["ramp_down"] < 0.08,
                   "more_total_compute_better":
                       ppl["constant_distributed"]
                       < ppl["constant_local"]}}
    C.save("fig7_adaptive_compute", payload)
    return payload


if __name__ == "__main__":
    out = run()
    for r in out["rows"]:
        print(f"{r['schedule']:22s} compute={r['total_compute']:7d} "
              f"ppl={r['ppl']:.3f}")
    print(out["claims"])
