"""Hypothesis property tests for ``fragments.Partition`` × pod
sharding: for arbitrary fragment counts P, round lengths H that P does
not divide, τ-overlap, override patterns, pod bandings and 0/1 drop
masks, every leaf element of every communicating replica is reduced by
exactly one fragment collective per round — the invariant the sharded
transport (core/pod_collectives.py) relies on to never double-reduce
or skip a parameter.

(Separate from tests/test_pod_collectives.py so the module-level
hypothesis importorskip cannot take the multi-device suite with it.)
"""
from __future__ import annotations

import jax
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import fragments  # noqa: E402


def _toy_tree():
    return {"embed": np.zeros((7, 4), np.float32),
            "stack_w": np.zeros((5, 3, 2), np.float32),
            "stack_b": np.zeros((5, 2), np.float32),
            "head": np.zeros((4, 3), np.float32)}


@st.composite
def _pod_cases(draw):
    Hh = draw(st.integers(1, 8))
    P = draw(st.integers(1, min(6, Hh)))
    tau = draw(st.integers(0, Hh - 1))
    pods = draw(st.sampled_from([1, 2, 4]))
    k = pods * draw(st.integers(1, 2))
    over = draw(st.sampled_from(
        [(), ((r"embed", 0),), ((r"head", P - 1),),
         ((r"embed", P - 1), (r"stack_b", 0))]))
    drop = draw(st.lists(st.sampled_from([0.0, 1.0]), min_size=k,
                         max_size=k))
    return Hh, P, tau, pods, k, tuple(over), tuple(drop)


def _count_band(c, mk, p, band, m):
    add = np.broadcast_to(np.asarray(mk, np.float32), p.shape)
    sel = m[band].reshape((-1,) + (1,) * p.ndim)
    c = c.copy()
    c[band] += sel * add[None]
    return c


@given(_pod_cases())
@settings(max_examples=40, deadline=None)
def test_every_element_reduced_exactly_once_per_round(case):
    """Summed over one round's send events, every leaf element of every
    communicating replica enters exactly one fragment collective, and
    dropped replicas' elements enter none — per pod band, covering all
    k replicas exactly once."""
    Hh, P, tau, pods, k, over, drop = case
    params = _toy_tree()
    part = fragments.partition_params(params, P, overrides=over)
    sched = fragments.schedule(P, Hh, tau)

    sends = [e.fragment for _, acts in sched.phases
             for e in acts if e.kind == "send"]
    assert sorted(sends) == list(range(P))   # each fragment sends once

    k_loc = k // pods
    m = np.asarray(drop, np.float32)
    counts = jax.tree.map(
        lambda p: np.zeros((k,) + p.shape, np.float32), params)
    for pod in range(pods):
        band = slice(pod * k_loc, (pod + 1) * k_loc)
        for frag in sends:
            counts = jax.tree.map(
                lambda c, mk, p: _count_band(c, mk, p, band, m),
                counts, part.masks[frag], params)
    for c in jax.tree.leaves(counts):
        comm = m.reshape((k,) + (1,) * (c.ndim - 1))
        np.testing.assert_array_equal(
            c, np.broadcast_to(comm, c.shape))


@given(st.integers(1, 6), st.integers(1, 8))
@settings(max_examples=30, deadline=None)
def test_partition_masks_tile_exactly_once(P, seed):
    """Fragment masks are a partition of unity on every leaf for any P
    (the per-element guarantee the reduce-once property builds on)."""
    params = _toy_tree()
    rng = np.random.default_rng(seed)
    over = ()
    if seed % 3 == 0:
        over = ((r"embed", int(rng.integers(P))),)
    part = fragments.partition_params(params, P, overrides=over)
    total = jax.tree.map(lambda p: np.zeros_like(p), params)
    for mk in part.masks:
        total = jax.tree.map(
            lambda t, q, p: t + np.broadcast_to(
                np.asarray(q, np.float32), p.shape),
            total, mk, params)
    for leaf in jax.tree.leaves(total):
        np.testing.assert_array_equal(leaf, np.ones_like(leaf))
