"""Unified stacked-scan LM engine for every architecture family.

A ``plan`` describes the repeating layer pattern; layers of each pattern
position are stacked with a leading (n_groups,) dim and executed with
``lax.scan`` over groups (compile time & HLO size stay O(pattern), not
O(depth) — essential for the 100-layer VLM dry-run on CPU). Within a
group the (short) pattern is unrolled.

Special pattern entries:
  "SHARED" — zamba2-style: a single *tied* block (params outside the
  scan; gradients accumulate through the scan closure) invoked once per
  group; per-invocation KV caches still scan.

Encoder-decoder (whisper) adds a separate encoder stack; VLM/whisper pass
their stubbed modality embeddings as ``cross_src``.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.sharding.spec import Boxed, unbox, constrain
from jax.sharding import PartitionSpec as P

from . import layers as L
from . import blocks as BLK


@dataclass(frozen=True)
class Plan:
    pattern: tuple              # kinds per group, may contain "SHARED"
    n_groups: int
    shared_kind: str = ""      # kind of the SHARED block (zamba2)
    enc_layers: int = 0         # whisper encoder depth
    cross_src: str = ""        # batch key of stubbed modality embeddings


def make_plan(cfg) -> Plan:
    f = cfg.family
    if f == "dense":
        return Plan(("attn_mlp",), cfg.n_layers)
    if f == "moe":
        kind = "mla_moe" if cfg.mla else "attn_mlp"
        return Plan((kind,), cfg.n_layers)
    if f == "vlm":
        e = cfg.cross_attn_every
        n_cross = cfg.n_layers // e
        assert cfg.n_layers % e == 0
        return Plan(("attn_mlp",) * (e - 1) + ("cross_mlp",), n_cross,
                    cross_src="patches")
    if f == "encdec":
        return Plan(("self_cross_mlp",), cfg.n_layers,
                    enc_layers=cfg.n_enc_layers, cross_src="frames")
    if f == "hybrid":
        e = cfg.shared_attn_every
        assert cfg.n_layers % e == 0
        return Plan(("mamba2",) * e + ("SHARED",), cfg.n_layers // e,
                    shared_kind="attn_mlp")
    if f == "ssm":
        if cfg.slstm_every:
            e = cfg.slstm_every
            assert cfg.n_layers % e == 0
            return Plan(("mlstm",) * (e - 1) + ("slstm",),
                        cfg.n_layers // e)
        return Plan(("mamba2",), cfg.n_layers)
    raise ValueError(f)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(key, cfg):
    """Returns a Boxed tree; call sharding.spec.unbox() to split."""
    plan = make_plan(cfg)
    ks = jax.random.split(key, 8 + len(plan.pattern))
    params = {"embed": L.init_embedding(ks[0], cfg),
              "ln_f": L.init_norm(cfg.norm, cfg.d_model),
              "head": L.init_lm_head(ks[1], cfg)}
    if cfg.pos_emb == "learned":
        params["pos_table"] = L.dense_init(
            ks[2], (min(cfg.max_position, 1 << 16), cfg.d_model),
            (None, "embed"), cfg.init_scale)
    for i, kind in enumerate(plan.pattern):
        if kind == "SHARED":
            continue
        params[f"stack{i}"] = BLK.stacked_init(ks[3 + i], cfg, kind,
                                               plan.n_groups)
    if plan.shared_kind:
        params["shared"] = BLK.init_block(ks[-1], cfg, plan.shared_kind)
    if plan.enc_layers:
        params["encoder"] = BLK.stacked_init(ks[-2], cfg, "enc_attn_mlp",
                                             plan.enc_layers)
        params["enc_ln_f"] = L.init_norm(cfg.norm, cfg.d_model)
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _run_encoder(params, cfg, frames):
    """Whisper-style encoder over stubbed frame embeddings (B, T, D)."""
    x = frames.astype(jnp.dtype(cfg.compute_dtype))
    x = x + L.sincos_positions(x.shape[1], cfg.d_model, x.dtype)[None]
    pos = jnp.arange(x.shape[1])

    def body(x, lp):
        x, _, _ = BLK.apply_block(lp, x, cfg, "enc_attn_mlp", positions=pos,
                                  window=0)
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["encoder"])
    return L.apply_norm(params["enc_ln_f"], x, cfg.norm)


def forward(params, cfg, tokens, *, extra=None, cache=None, cache_pos=None,
            groups: int = 1, window=None, page_table=None):
    """Core forward. tokens: (B, S). cache/cache_pos => decode/prefill.

    ``page_table``: (B, pages_per_slot) int32 for paged caches (see
    ``init_paged_cache``) — shared by every layer group.
    Returns (logits, new_cache, aux). new_cache is None when cache is None.
    """
    plan = make_plan(cfg)
    dt = jnp.dtype(cfg.compute_dtype)
    B, S = tokens.shape
    x = L.embed(params["embed"], tokens, cfg)
    # activations: batch over cfg.act_batch_axes, d_model over "model"
    # when act_model_shard (Megatron sequence-parallel-style residual
    # sharding — 16x smaller remat stash on the production mesh; small
    # models flip to pure-DP with batch over both axes instead).
    # No-op off-mesh.
    ba = tuple(cfg.act_batch_axes)
    if cfg.act_seq_shard:
        # Megatron sequence-parallelism: the residual stream is sharded
        # over (batch=data, seq=model); GSPMD places all-gather before
        # attn/mlp interiors and reduce-scatter after — half the wire
        # bytes of the all-reduce pattern, same 16x remat-stash saving
        x = constrain(x, P(ba if len(ba) > 1 else ba[0], "model", None))
    else:
        x = constrain(x, P(ba if len(ba) > 1 else ba[0], None,
                           "model" if cfg.act_model_shard else None))

    if cache_pos is None:
        cache_pos = jnp.zeros((), jnp.int32)
    positions = cache_pos + jnp.arange(S)
    if cfg.pos_emb == "learned":
        # positions are contiguous (cache_pos + arange) — a dynamic
        # slice, not a gather, so SPMD partitioning of the table stays
        # trivial (gather of a model-sharded table trips the partitioner)
        tbl = params["pos_table"].astype(dt)
        start = jnp.clip(cache_pos, 0, tbl.shape[0] - S)
        x = x + jax.lax.dynamic_slice_in_dim(tbl, start, S, 0)[None]
    elif cfg.pos_emb == "sincos":
        x = x + L.sincos_positions(S, cfg.d_model, dt)[None]

    cross_src = None
    if plan.cross_src and extra is not None and plan.cross_src in extra:
        src = extra[plan.cross_src]
        if plan.enc_layers:
            src = _run_encoder(params, cfg, src)
        cross_src = src.astype(dt)
    # decode (extra absent): blocks read their cached cross K/V — the
    # modality source is projected exactly once, at prefill

    stacked_params = tuple(
        params[f"stack{i}"] if k != "SHARED" else None
        for i, k in enumerate(plan.pattern))
    stacked_caches = tuple(
        cache[f"cache{i}"] if cache is not None else None
        for i in range(len(plan.pattern)))

    def group_body(carry, xs):
        x, aux = carry
        lps, lcs = xs
        new_cs = []
        for i, kind in enumerate(plan.pattern):
            k = plan.shared_kind if kind == "SHARED" else kind
            p = params["shared"] if kind == "SHARED" else lps[i]
            x, c, a = BLK.apply_block(
                p, x, cfg, k, positions=positions, cache=lcs[i],
                cache_pos=cache_pos, kv_x=cross_src, groups=groups,
                window=window, page_table=page_table)
            new_cs.append(c)
            aux = aux + a
        return (x, aux), tuple(new_cs)

    body = jax.checkpoint(group_body) if (cfg.remat and cache is None) \
        else group_body
    (x, aux), new_caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)),
        (stacked_params, stacked_caches))

    x = L.apply_norm(params["ln_f"], x, cfg.norm)
    logits = L.lm_logits(params.get("head", {}), params["embed"], x, cfg)

    new_cache = None
    if cache is not None:
        new_cache = {f"cache{i}": new_caches[i]
                     for i in range(len(plan.pattern))}
    return logits, new_cache, aux


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def loss_fn(params, cfg, batch, *, groups: int = 1):
    logits, _, aux = forward(params, cfg, batch["tokens"],
                             extra=batch, groups=groups)
    ce = L.next_token_loss(logits, batch["tokens"])
    total = ce + cfg.router_aux_coef * aux
    return total, {"loss": ce, "aux": aux}


def init_cache(cfg, batch: int, cache_len: int, dtype, *,
               window: int = 0):
    """Zeroed decode cache. ``window``>0 bounds attention cache length."""
    plan = make_plan(cfg)
    eff = min(cache_len, window) if window else cache_len
    out = {}
    for i, kind in enumerate(plan.pattern):
        k = plan.shared_kind if kind == "SHARED" else kind
        c1 = BLK.init_block_cache(cfg, k, batch, eff, dtype)
        out[f"cache{i}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(
                a, (plan.n_groups,) + a.shape).copy(), c1)
    return out


# cache-leaf names that live in the shared page pool (no batch axis
# after the group axis) — everything else is a per-slot row
PAGED_LEAF_NAMES = ("kp", "vp", "posp")


def init_paged_cache(cfg, batch: int, cache_len: int, dtype, *,
                     page_size: int, n_pages: int, window: int = 0):
    """Paged decode cache: standard-attention K/V rings become ONE
    shared pool of ``n_pages`` fixed-size pages per layer group; the
    engine maps each slot's logical ring (length eff = min(cache_len,
    window or cache_len), eff % page_size == 0) onto pool pages through
    a (batch, eff // page_size) page table passed to ``forward``.
    Non-attention leaves (SSM states, MLA rings, cross K/V) keep their
    per-slot rows exactly as ``init_cache`` lays them out."""
    plan = make_plan(cfg)
    eff = min(cache_len, window) if window else cache_len
    if eff % page_size:
        raise ValueError(
            f"effective cache length {eff} must be a multiple of "
            f"page_size {page_size} (the paged ring must tile exactly "
            "to stay bit-identical to the contiguous ring)")
    out = {}
    for i, kind in enumerate(plan.pattern):
        k = plan.shared_kind if kind == "SHARED" else kind
        c1 = BLK.init_paged_block_cache(cfg, k, batch, eff, dtype,
                                        n_pages=n_pages,
                                        page_size=page_size)
        out[f"cache{i}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(
                a, (plan.n_groups,) + a.shape).copy(), c1)
    return out


def prefill(params, cfg, tokens, *, extra=None, window: int = 0,
            groups: int = 1, cache_len: int = 0):
    """Run the full prompt, building the decode cache. Returns
    (logits, cache). ``cache_len`` sizes the cache for subsequent decode
    (default: prompt length only)."""
    B, S = tokens.shape
    dt = jnp.dtype(cfg.compute_dtype)
    cache = init_cache(cfg, B, max(cache_len, S), dt, window=window)
    logits, cache, _ = forward(params, cfg, tokens, extra=extra,
                               cache=cache, cache_pos=jnp.zeros((), jnp.int32),
                               groups=groups, window=window or None)
    return logits, cache


def decode_step(params, cfg, cache, tokens, pos, *, window: int = 0,
                groups: int = 1, page_table=None):
    """One decode step. tokens: (B, 1); pos: scalar int32 absolute
    position. Returns (logits, new_cache)."""
    logits, cache, _ = forward(params, cfg, tokens, cache=cache,
                               cache_pos=pos, groups=groups,
                               window=window or None,
                               page_table=page_table)
    return logits, cache
