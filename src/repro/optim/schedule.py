"""LR schedules: linear warmup + cosine decay (paper setting)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, peak_lr, warmup_steps, total_steps,
                  min_ratio=0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
    progress = jnp.clip((step - warmup_steps)
                        / jnp.maximum(total_steps - warmup_steps, 1), 0., 1.)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * progress))
    return jnp.where(step < warmup_steps, warm, peak_lr * cos)


def make_warmup_cosine(peak_lr, warmup_steps, total_steps, min_ratio=0.1):
    """Factory form: returns sched(step) -> lr."""
    return lambda step: warmup_cosine(
        step, peak_lr=peak_lr, warmup_steps=warmup_steps,
        total_steps=total_steps, min_ratio=min_ratio)


def constant(step, *, peak_lr, warmup_steps=0, **_):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
    return jnp.where(step < warmup_steps, warm, peak_lr)
