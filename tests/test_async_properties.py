"""Hypothesis property tests for the fault harness and the async
engine's exactly-once contract: for arbitrary scenarios (speeds,
latencies, drops, retries, preemption spans), the timeline assigns
every finished phase's uid to AT MOST one terminal event, every
Arrival lands on a continuously-present worker, the event stream is a
pure function of the scenario (prefix-resume identity), round-mask
projections stay consistent with the event stream, and the engine
applies every Arrival exactly once in whatever order completions land.

(Separate from tests/test_faults.py / test_async_engine.py so the
module-level hypothesis importorskip cannot take the deterministic
suites with it — same split as tests/test_pod_properties.py. The
deterministic seeded sweeps over there cover the same properties when
hypothesis is absent.)
"""
from __future__ import annotations

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import faults  # noqa: E402
from repro.core.faults import (Arrival, Join, Leave, Lost,  # noqa: E402
                               Scenario)

from test_faults import _presence_ok  # noqa: E402


@st.composite
def _scenarios(draw):
    k = draw(st.integers(2, 5))
    pre = ()
    if draw(st.booleans()):
        leave = draw(st.integers(1, 6))
        rejoin = draw(st.sampled_from([0, leave + 1, leave + 3]))
        pre = ((draw(st.integers(0, k - 1)), leave, rejoin),)
    s = Scenario(
        speeds=tuple(draw(st.lists(st.integers(1, 3), min_size=k,
                                   max_size=k))),
        latency=tuple(draw(st.lists(st.integers(0, 2), min_size=k,
                                    max_size=k))),
        latency_jitter=draw(st.sampled_from([0.0, 0.5])),
        drop_prob=draw(st.sampled_from([0.0, 0.3, 0.7])),
        max_retries=draw(st.integers(0, 2)),
        retry_backoff=draw(st.integers(1, 2)),
        preemptions=pre,
        seed=draw(st.integers(0, 10_000)))
    ticks = draw(st.integers(2, 10))
    return k, s, ticks


@given(_scenarios())
@settings(max_examples=60, deadline=None)
def test_terminal_events_are_exactly_once_and_live(case):
    """Every finished phase resolves to at most one terminal event
    (Arrival xor Lost), arrivals land only on continuously-present
    workers, and the stream is tick-ordered within bounds."""
    k, s, ticks = case
    ev = s.timeline(k, ticks)
    uids = [e.uid for e in ev if isinstance(e, (Arrival, Lost))]
    assert len(uids) == len(set(uids))
    assert _presence_ok(ev, k)
    assert [e.tick for e in ev] == sorted(e.tick for e in ev)
    for e in ev:
        assert 1 <= e.tick <= ticks
        if isinstance(e, Arrival):
            assert e.dispatch_tick < e.finish_tick <= e.tick
            assert 0 <= e.attempt <= s.max_retries


@given(_scenarios())
@settings(max_examples=40, deadline=None)
def test_timeline_is_pure_and_prefix_resumable(case):
    """timeline() is a pure function of (scenario, k, ticks), and any
    prefix cut resumes to the identical suffix — the property the
    engine's checkpoint-restore (events_done cursor) relies on."""
    k, s, ticks = case
    ev = s.timeline(k, ticks)
    again = s.timeline(k, ticks)
    assert ev == again
    for cut in (0, len(ev) // 2, len(ev)):
        assert ev[cut:] == again[cut:]


@given(_scenarios())
@settings(max_examples=40, deadline=None)
def test_longer_horizon_extends_the_event_stream(case):
    """Simulating further never rewrites history: events at tick <= T
    are identical whether the horizon is T or T + more — modulo uid,
    which is horizon-scoped (uid = worker * horizon + phase index), and
    modulo boundary-sensitive events: Lost materializes only once
    retries exhaust INSIDE the horizon (a longer horizon keeps
    retrying), and Leave/Join AT the final tick are suppressed by the
    short horizon (nothing can happen after them). Events strictly
    inside the horizon are stable."""
    k, s, ticks = case
    short = [e for e in s.timeline(k, ticks)]
    longer = [e for e in s.timeline(k, ticks + 4) if e.tick <= ticks]

    def stable(evs):
        out = []
        for e in evs:
            if e.tick >= ticks or isinstance(e, Lost):
                continue
            if isinstance(e, Arrival):
                if e.attempt > 0:
                    continue
                e = e._replace(uid=-1)
            out.append(e)
        return out

    assert stable(short) == stable(longer)


@given(_scenarios())
@settings(max_examples=30, deadline=None)
def test_round_masks_agree_with_timeline_presence(case):
    """active-mask projections never mark a worker active in a round
    fully covered by one of its gone spans."""
    k, s, ticks = case
    T = s.sync_round_ticks(k)
    rounds = max(1, ticks // T)
    _, acts = s.round_masks(k, rounds)
    gone = {}
    for (w, leave, rejoin) in s.preemptions:
        gone[w] = (leave, rejoin if rejoin > 0 else float("inf"))
    for r in range(rounds):
        lo, hi = r * T, (r + 1) * T       # tick span of round r
        for w, (gl, gh) in gone.items():
            if gl <= lo and hi <= gh:
                assert acts[r, w] == 0.0


@given(st.integers(0, 12), st.floats(0.0, 1.0), st.integers(1, 8))
@settings(max_examples=60, deadline=None)
def test_staleness_weight_bounds_and_monotonicity(tau, lam, k):
    w = faults.staleness_weight(tau, lam, k)
    assert 0.0 <= w <= 1.0 / k
    assert w <= faults.staleness_weight(max(0, tau - 1), lam, k)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000))
def test_engine_applies_every_arrival_exactly_once(seed):
    """Engine-level exactly-once: run the real AsyncEngine on a random
    scenario and check the applied-uid set equals the timeline's
    Arrival uids, in completion order, with one version bump each."""
    import jax.numpy as jnp  # deferred: keep collection cheap

    from repro.configs.base import DiLoCoConfig, TrainConfig
    from repro.core import async_diloco

    rng = np.random.default_rng(seed)
    k = int(rng.integers(2, 4))
    s = Scenario(
        speeds=tuple(int(x) for x in rng.integers(1, 3, k)),
        latency=tuple(int(x) for x in rng.integers(0, 2, k)),
        drop_prob=float(rng.choice([0.0, 0.4])),
        max_retries=1, seed=int(rng.integers(0, 100)))

    def loss(p, batch):
        t = batch["tokens"].astype(jnp.float32).mean() / 7.0
        return jnp.sum((p["w"] - t) ** 2), {}

    import jax
    sample = lambda key, B, S: jax.random.randint(key, (B, S), 0, 7,
                                                  jnp.int32)
    dcfg = DiLoCoConfig(k=k, H=2, transport="async", outer_lr=0.3)
    tcfg = TrainConfig(inner_lr=0.05, warmup_steps=2, total_steps=64,
                       batch_size=2, seq_len=4)
    eng = async_diloco.AsyncEngine(loss, sample, dcfg, tcfg,
                                   scenario=s, total_steps=64, seed=0)
    state = eng.init_state({"w": jnp.arange(4.0) / 4.0})
    ticks = 4
    state, recs = eng.run(state, ticks=ticks)
    ev = s.timeline(k, ticks)
    want = sorted(e.uid for e in ev if isinstance(e, Arrival))
    got = sorted(r["uid"] for r in recs if r["event"] == "arrival")
    assert got == want
    assert int(state.version) == len(want)
    lost = sorted(e.uid for e in ev if isinstance(e, Lost))
    assert sorted(r["uid"] for r in recs
                  if r["event"] == "lost") == lost
