"""Mixture-of-Experts FFN: token-choice top-k router, capacity-based
sort/gather/scatter dispatch, optional shared experts (DeepSeek-V2 style).

TPU adaptation: instead of a GPU-style ragged grouped-GEMM, tokens are
grouped per data-shard (a static ``groups`` axis constrained to the
"data" mesh axis), sorted by expert id *locally* (sort along an unsharded
axis = no communication), packed into a capacity-bounded (E, C, D) buffer,
and the buffer's expert axis is sharded over the "model" mesh axis — the
dispatch/return resharding between token-sharded and expert-sharded
layouts is GSPMD's all-to-all, exactly the expert-parallel collective the
roofline accounts for. Tokens beyond capacity are dropped (standard
token-choice behaviour; capacity_factor controls the drop rate).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.sharding.spec import constrain
from jax.sharding import PartitionSpec as P

from .layers import dense_init, _act


def init_moe(key, cfg):
    D, E, F = cfg.d_model, cfg.n_experts, cfg.moe_d_ff or cfg.d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (D, E), ("embed", None), cfg.init_scale),
        "w_up": dense_init(ks[1], (E, D, F), ("experts", "embed", None),
                           cfg.init_scale),
        "w_gate": dense_init(ks[2], (E, D, F), ("experts", "embed", None),
                             cfg.init_scale),
        "w_down": dense_init(ks[3], (E, F, D), ("experts", None, "embed"),
                             cfg.init_scale),
    }
    if cfg.n_shared_experts:
        Fs = cfg.n_shared_experts * F
        kss = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_up": dense_init(kss[0], (D, Fs), ("embed", "ff"),
                               cfg.init_scale),
            "w_gate": dense_init(kss[1], (D, Fs), ("embed", "ff"),
                                 cfg.init_scale),
            "w_down": dense_init(kss[2], (Fs, D), ("ff", "embed"),
                                 cfg.init_scale),
        }
    return p


def _capacity(tokens: int, top_k: int, n_experts: int, cf: float) -> int:
    c = math.ceil(tokens * top_k * cf / n_experts)
    return max(8, (c + 7) // 8 * 8)


def _topk_iterative(probs, K: int):
    """Top-k via K arg-max sweeps — numerically identical to lax.top_k
    (modulo tie order) but SORT-FREE: XLA's SPMD partitioner all-gathers
    sharded batch dims of (variadic) sorts, which would leak cross-pod
    traffic into DiLoCo's inner step; argmax reductions partition clean.
    """
    p = probs
    vals, idxs = [], []
    for _ in range(K):
        i = jnp.argmax(p, axis=-1)
        v = jnp.take_along_axis(p, i[..., None], -1)[..., 0]
        vals.append(v)
        idxs.append(i)
        p = p - jax.nn.one_hot(i, p.shape[-1], dtype=p.dtype) * 1e9
    return jnp.stack(vals, -1), jnp.stack(idxs, -1).astype(jnp.int32)


def _dispatch_group(x, probs, idx, E: int, C: int):
    """Group one shard's tokens by expert into an (E*C+1, D) buffer.

    x: (T, D); probs/idx: (T, K). Returns (buffer, slot, keep):
    slot (T, K) int32 position of each assignment in the flat buffer
    (E*C = dropped), keep (T, K) bool.

    Position-within-expert ranks come from a cumsum over the one-hot
    assignment matrix (sort-free; see _topk_iterative for why).
    """
    T, K = idx.shape
    e_flat = idx.reshape(-1)                                   # (T*K,)
    oh = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)            # (TK, E)
    # rank of assignment j within its expert = #prior assignments of
    # the same expert
    rank = (jnp.cumsum(oh, axis=0) - oh).reshape(-1, E)
    pos = jnp.sum(rank * oh, axis=-1)                          # (TK,)
    keep_flat = pos < C
    slot = jnp.where(keep_flat, e_flat * C + pos, E * C)
    tok_of_flat = jnp.arange(T * K, dtype=jnp.int32) // K
    buffer = jnp.zeros((E * C + 1, x.shape[-1]), x.dtype)
    buffer = buffer.at[slot].set(x[tok_of_flat], mode="drop")
    return buffer, slot.reshape(T, K), keep_flat.reshape(T, K)


def apply_moe(p, x, cfg, *, groups: int = 1):
    """x: (B, S, D) -> (out, aux_loss). ``groups`` = static token-grouping
    factor (set to the data-parallel degree for sharded execution)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    G = math.gcd(T, max(groups, 1))
    Tg = T // G
    dt = x.dtype
    xf = x.reshape(G, Tg, D)
    xf = constrain(xf, P("data", None, None))

    logits = jnp.einsum("gtd,de->gte", xf, p["router"].astype(dt),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                     # (G,Tg,E)
    top_p, top_i = _topk_iterative(probs, K)                    # (G,Tg,K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    C = _capacity(Tg, K, E, cfg.capacity_factor)
    buffer, slot, keep = jax.vmap(
        lambda xx, pp, ii: _dispatch_group(xx, pp, ii, E, C))(xf, top_p,
                                                              top_i)
    # (G, E*C+1, D) -> expert compute with E sharded over "model"
    xb = buffer[:, :E * C].reshape(G, E, C, D)
    xb = constrain(xb, P("data", "model", None, None))
    up = jnp.einsum("gecd,edf->gecf", xb, p["w_up"].astype(dt))
    gate = jnp.einsum("gecd,edf->gecf", xb, p["w_gate"].astype(dt))
    h = _act(gate, cfg.act) * up
    yb = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(dt))
    yb = constrain(yb, P("data", None, None, None))
    yb = jnp.concatenate(
        [yb.reshape(G, E * C, D), jnp.zeros((G, 1, D), dt)], axis=1)

    # combine: gather each assignment's output, weight, sum over K
    y_asn = jnp.take_along_axis(
        yb, slot.reshape(G, Tg * K)[..., None], axis=1)          # (G,TgK,D)
    y_asn = y_asn.reshape(G, Tg, K, D)
    w = (top_p * keep).astype(dt)
    y = jnp.einsum("gtkd,gtk->gtd", y_asn, w)

    if "shared" in p:
        sp = p["shared"]
        hu = jnp.einsum("gtd,df->gtf", xf, sp["w_up"].astype(dt))
        hg = jnp.einsum("gtd,df->gtf", xf, sp["w_gate"].astype(dt))
        y = y + jnp.einsum("gtf,fd->gtd", _act(hg, cfg.act) * hu,
                           sp["w_down"].astype(dt))

    # load-balancing aux loss (Switch-style)
    frac = jnp.mean(jax.nn.one_hot(top_i, E, dtype=jnp.float32),
                    axis=(0, 1, 2))                              # (E,)
    mean_p = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac * mean_p)
    return y.reshape(B, S, D), aux
