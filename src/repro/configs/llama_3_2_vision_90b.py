"""llama-3.2-vision-90b [vlm, hf:meta-llama/Llama-3.2-11B-Vision]:
100L (80 self + 20 gated cross-attn, every 5th), d_model=8192, 64 heads,
GQA kv=8, d_ff=28672, vocab=128256. ViT/projector STUBBED: input_specs
provides (B, 1601, d_model) patch embeddings."""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b", family="vlm",
        n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=28_672, vocab_size=128_256,
        pos_emb="rope", rope_theta=5e5, norm="rmsnorm", act="silu",
        cross_attn_every=5, n_patches=1601,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="llama-vision-smoke", n_layers=4, d_model=128, n_heads=4,
        n_kv_heads=2, d_ff=256, vocab_size=256, cross_attn_every=2,
        n_patches=16, attn_chunk=64)
