"""AdamW inner optimizer (paper: the standard LM optimizer), from scratch.

Decoupled weight decay per Loshchilov & Hutter 2019; bias-corrected
moments. The functional API mirrors optax: ``init`` then ``update``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    m: dict
    v: dict
    count: jnp.ndarray


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p)
    return AdamWState(m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params),
                      count=jnp.zeros((), jnp.int32))


def update(grads, state: AdamWState, params, *, lr, b1=0.9, b2=0.95,
           eps=1e-8, weight_decay=0.1, mode: str = "ref"):
    """One AdamW step. ``lr`` may be a scalar traced value (schedule).

    ``mode`` selects the backend: ``ref`` is the legacy pure-jnp tree
    map below; ``auto``/``pallas``/``interpret`` route through the fused
    single-VMEM-pass kernel in ``repro.kernels`` (one read of each of
    p/g/m/v, one write of p/m/v per step instead of XLA's split
    fusions).
    """
    count = state.count + 1
    if mode != "ref":
        from repro.kernels import ops as kops
        new_p, new_m, new_v = kops.adamw_update_tree(
            params, grads, state.m, state.v, lr=lr, count=count, b1=b1,
            b2=b2, eps=eps, weight_decay=weight_decay, mode=mode)
        return new_p, AdamWState(new_m, new_v, count)
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * jnp.square(g)
        mhat = m / c1
        vhat = v / c2
        step = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p
        return p - lr * step, m, v

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(new_m, new_v, count)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), gn
