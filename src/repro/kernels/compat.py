"""Pallas TPU API compatibility across jax releases.

jax renamed the TPU-specific Pallas types between release lines:

  * ``pltpu.TPUCompilerParams`` (<= 0.4.x)  ->  ``pltpu.CompilerParams``
  * ``pltpu.TPUMemorySpace``   (<= 0.4.x)  ->  ``pltpu.MemorySpace``

Every kernel in this package imports the names from here so the package
works on either side of the rename.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams
MemorySpace = getattr(pltpu, "MemorySpace", None) or pltpu.TPUMemorySpace
SMEM = MemorySpace.SMEM
