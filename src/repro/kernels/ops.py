"""Backend dispatch for the Pallas kernels.

Each op picks the Pallas kernel on TPU (or when forced via
``mode='pallas'`` / ``mode='interpret'``) and the pure-jnp oracle from
``ref.py`` otherwise — so CPU runs (tests, benchmarks) and TPU runs
share one call site. Tree-level helpers apply the fused optimizer
kernels leaf-by-leaf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import flash_attention as _flash
from . import fused_adamw as _adamw
from . import outer_nesterov as _nesterov
from . import quantize as _quant
from . import sign_prune as _prune
from . import ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(mode: str):
    """-> (use_kernel, interpret)."""
    if mode == "auto":
        return (_on_tpu(), False)
    if mode == "pallas":
        return (True, False)
    if mode == "interpret":
        return (True, True)
    if mode == "ref":
        return (False, False)
    raise ValueError(mode)


# ---------------------------------------------------------------------------
# flash attention — q: (B, S, H, d) model layout; kernel uses (B, H, S, d)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _fa_vjp(causal, window, scale, block_q, block_k, interpret):
    return _flash.make_flash_attention_vjp(
        causal=causal, window=window, scale=scale, block_q=block_q,
        block_k=block_k, interpret=interpret)


def flash_attention(q, k, v, *, causal=True, window=0, scale=None,
                    mode: str = "auto", block_q: int = 128,
                    block_k: int = 128):
    """Differentiable flash attention (custom_vjp with flash backward
    kernels on the kernel path)."""
    use_kernel, interpret = _resolve(mode)
    if not use_kernel:
        return ref.flash_attention(q.transpose(0, 2, 1, 3),
                                   k.transpose(0, 2, 1, 3),
                                   v.transpose(0, 2, 1, 3),
                                   causal=causal, window=window,
                                   scale=scale).transpose(0, 2, 1, 3)
    fa = _fa_vjp(causal, window, scale, block_q, block_k, interpret)
    out = fa(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
             v.transpose(0, 2, 1, 3))
    return out.transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# fused AdamW — tree-level
# ---------------------------------------------------------------------------

def adamw_update_tree(params, grads, m, v, *, lr, count, b1=0.9, b2=0.95,
                      eps=1e-8, weight_decay=0.1, mode: str = "auto"):
    """One fused AdamW step over a whole param tree. ``count`` is the
    post-increment step (for bias correction)."""
    use_kernel, interpret = _resolve(mode)
    cf = jnp.asarray(count, jnp.float32)
    c1 = 1.0 - b1 ** cf
    c2 = 1.0 - b2 ** cf

    def one(p, g, mm, vv):
        if use_kernel:
            return _adamw.fused_adamw(
                p, g, mm, vv, lr=lr, c1=c1, c2=c2, b1=b1, b2=b2, eps=eps,
                weight_decay=weight_decay, interpret=interpret)
        return ref.fused_adamw(p, g, mm, vv, lr=lr, b1=b1, b2=b2,
                               eps=eps, weight_decay=weight_decay,
                               c1=c1, c2=c2)

    out = jax.tree.map(one, params, grads, m, v)
    leaves = lambda i: jax.tree.map(
        lambda t: t[i], out, is_leaf=lambda x: isinstance(x, tuple))
    return leaves(0), leaves(1), leaves(2)


def adamw_update_tree_mixed(grads, m, v, master, *, lr, count,
                            param_dtype, b1=0.9, b2=0.95, eps=1e-8,
                            weight_decay=0.1, mode: str = "auto"):
    """One mixed-precision fused AdamW step over a whole tree: the
    high-precision ``master`` tree is authoritative, grads/moments ride
    at the replica storage dtype, and the ``param_dtype`` working copy
    is emitted in the same pass. Returns (params, m, v, master)."""
    use_kernel, interpret = _resolve(mode)
    cf = jnp.asarray(count, jnp.float32)
    c1 = 1.0 - b1 ** cf
    c2 = 1.0 - b2 ** cf

    def one(g, mm, vv, w):
        if use_kernel:
            return _adamw.fused_adamw_mixed(
                g, mm, vv, w, lr=lr, c1=c1, c2=c2, b1=b1, b2=b2,
                eps=eps, weight_decay=weight_decay,
                param_dtype=param_dtype, interpret=interpret)
        return ref.fused_adamw_mixed(
            g, mm, vv, w, lr=lr, b1=b1, b2=b2, eps=eps,
            weight_decay=weight_decay, c1=c1, c2=c2,
            param_dtype=param_dtype)

    out = jax.tree.map(one, grads, m, v, master)
    leaves = lambda i: jax.tree.map(
        lambda t: t[i], out, is_leaf=lambda x: isinstance(x, tuple))
    return leaves(0), leaves(1), leaves(2), leaves(3)


# ---------------------------------------------------------------------------
# sign pruning — matrix + tree-level
# ---------------------------------------------------------------------------

def sign_prune(x, frac: float, *, mode: str = "auto"):
    """x: (R, C)."""
    if frac <= 0:
        return x
    use_kernel, interpret = _resolve(mode)
    if use_kernel:
        return _prune.sign_prune(x, frac, interpret=interpret)
    return ref.sign_prune(x, frac)


def sign_prune_tree(tree, frac: float, *, mode: str = "auto"):
    """Leaves are reshaped to (leading-dim rows, flattened cols)."""
    if frac <= 0:
        return tree

    def one(x):
        if x.ndim == 0:
            return x
        flat = x.reshape(1, -1) if x.ndim == 1 \
            else x.reshape(x.shape[0], -1)
        return sign_prune(flat, frac, mode=mode).reshape(x.shape)

    return jax.tree.map(one, tree)


# ---------------------------------------------------------------------------
# low-precision outer-gradient transport — tensor + tree-level
# ---------------------------------------------------------------------------

# Wire cost of one transported element: int4 carries 0.5 B of codes
# plus one f32 scale per 128-element block. The per-element figure for
# int4 is the large-tensor amortization; exact wire bytes (with the
# ceil'd per-block scale count) come from ``transport_bytes``.
QUANT_BLOCK = 128
# Packed int4 wire sections are padded to this byte boundary so the f32
# scale section that follows the nibble-packed codes stays word-aligned
# (what a real sender's framing would do; charged by the packed model).
WIRE_ALIGN = 4
TRANSPORT_BYTES_PER_ELEM = {
    "float32": 4.0,
    "bfloat16": 2.0,
    "int4": 0.5 + 4.0 / QUANT_BLOCK,
}


def quant_roundtrip(x, dtype: str, *, mode: str = "auto"):
    """Simulated low-precision transport: quantize→dequantize round trip
    at ``dtype`` ("float32" = identity). int4 uses one f32 scale per
    128-element block of the flattened tensor (the same (blocks, 128)
    layout as the fused optimizer kernels)."""
    if dtype == "float32":
        return x
    if dtype not in TRANSPORT_BYTES_PER_ELEM:
        raise ValueError(f"unknown transport dtype {dtype!r}")
    use_kernel, interpret = _resolve(mode)
    if use_kernel:
        return _quant.fake_quant(x, dtype, interpret=interpret)
    if dtype == "bfloat16":
        return ref.fake_quant(x, dtype)
    # int4 oracle on the kernel's block layout, so ref == kernel exactly
    shape, out_dtype = x.shape, x.dtype
    n = x.size
    rows = -(-n // QUANT_BLOCK)
    flat = x.reshape(-1).astype(jnp.float32)
    if rows * QUANT_BLOCK != n:
        flat = jnp.pad(flat, (0, rows * QUANT_BLOCK - n))
    out = ref.fake_quant(flat.reshape(rows, QUANT_BLOCK), dtype)
    return out.reshape(-1)[:n].reshape(shape).astype(out_dtype)


def quant_roundtrip_tree(tree, dtype: str, *, mode: str = "auto"):
    if dtype == "float32":
        return tree
    return jax.tree.map(lambda x: quant_roundtrip(x, dtype, mode=mode),
                        tree)


def transport_bytes(n_elems: int, dtype: str, *,
                    packed: bool = False) -> float:
    """Wire bytes for ``n_elems`` outer-gradient elements.

    ``packed=False`` (the legacy fake-quant model, kept for comparison):
    int4 charges 0.5 B of codes per element plus one f32 scale per
    (started) 128-element block of the flattened tensor — a tensor that
    does not divide evenly still ships a scale for its ragged tail, so
    the scale overhead is ceil(n/128) blocks, not n/128.

    ``packed=True`` is the EXACT byte count of the packed wire buffer
    ``wire_encode`` builds (and the sharded transport all-gathers):
    int4 nibble-packs two codes per int8 byte — an odd element count
    still ships its ragged final byte, so the code section is
    ceil(n/2) bytes, padded to the ``WIRE_ALIGN`` word boundary —
    followed by one f32 scale per started 128-element block. float32 /
    bfloat16 ship whole elements, so their packed and legacy models
    coincide.
    """
    if dtype not in TRANSPORT_BYTES_PER_ELEM:
        raise ValueError(f"unknown transport dtype {dtype!r}")
    if dtype == "int4":
        n = int(n_elems)
        blocks = -(-n // QUANT_BLOCK)
        if packed:
            code_bytes = -(-n // 2)
            code_bytes += (-code_bytes) % WIRE_ALIGN
            return float(code_bytes + 4 * blocks)
        return n * 0.5 + 4.0 * blocks
    return n_elems * TRANSPORT_BYTES_PER_ELEM[dtype]


# ---------------------------------------------------------------------------
# packed int4 wire: codes+scales as one byte buffer (sharded transport)
# ---------------------------------------------------------------------------

def _block_pad(flat, rows):
    if rows * QUANT_BLOCK != flat.shape[0]:
        flat = jnp.pad(flat, (0, rows * QUANT_BLOCK - flat.shape[0]))
    return flat.reshape(rows, QUANT_BLOCK)


def pack_int4(codes, *, mode: str = "auto"):
    """Nibble-pack flat (n,) int8 codes in [-7, 7] -> (ceil(n/2),) int8
    wire bytes (two 4-bit two's-complement codes per byte, element
    order). Exact inverse: ``unpack_int4``."""
    n = codes.shape[0]
    use_kernel, interpret = _resolve(mode)
    if not use_kernel:
        return ref.pack_int4(codes)
    rows = -(-n // QUANT_BLOCK)
    c2d = _block_pad(codes, rows)
    out = _quant.pack_int4(c2d, interpret=interpret)
    return out.reshape(-1)[:-(-n // 2)]


def unpack_int4(packed, n: int, *, mode: str = "auto"):
    """Inverse of ``pack_int4``: (ceil(n/2),) int8 bytes -> (n,) int8
    codes with 4-bit two's-complement sign extension."""
    use_kernel, interpret = _resolve(mode)
    if not use_kernel:
        return ref.unpack_int4(packed, n)
    rows = -(-n // QUANT_BLOCK)
    half = QUANT_BLOCK // 2
    p = packed
    if p.shape[0] != rows * half:
        p = jnp.pad(p, (0, rows * half - p.shape[0]))
    out = _quant.unpack_int4(p.reshape(rows, half), interpret=interpret)
    return out.reshape(-1)[:n]


def wire_dtype(dtype: str):
    """Element dtype of the wire buffer ``wire_encode`` builds. bf16
    rides as bit-cast uint16: shipping raw bits denies XLA the
    convert-hoisting rewrite that would widen the collective back to
    f32 (observed on the CPU backend — the convert is free to cross an
    all-gather, a bitcast is not)."""
    if dtype == "int4":
        return jnp.uint8
    if dtype == "bfloat16":
        return jnp.uint16
    raise ValueError(f"no packed wire for transport dtype {dtype!r}")


def wire_elems(n_elems: int, dtype: str) -> int:
    """Length of the wire buffer for one region of ``n_elems``
    (elements of ``wire_dtype``; for int4 that is exactly
    ``transport_bytes(n, 'int4', packed=True)`` bytes)."""
    if dtype == "int4":
        return int(transport_bytes(n_elems, dtype, packed=True))
    if dtype == "bfloat16":
        return int(n_elems)
    raise ValueError(f"no packed wire for transport dtype {dtype!r}")


def wire_encode(x, dtype: str, *, mode: str = "auto"):
    """Encode one flat (n,) region for the packed wire.

    Returns ``(wire, local)``: ``wire`` is what the collective ships —
    bf16 the raw bf16 elements, int4 ONE uint8 buffer laying out the
    nibble-packed codes (ceil(n/2) bytes, zero-padded to the
    ``WIRE_ALIGN`` boundary) followed by the per-128-block f32 scales
    bit-cast to bytes; ``local`` is the dequantized f32 value of the
    sender's own payload (what ``wire_decode`` will recover on every
    receiver — used for the error-feedback residual without a second
    decode).
    """
    if dtype == "bfloat16":
        w = x.reshape(-1).astype(jnp.bfloat16)
        # ship the raw bf16 bits as uint16 (see wire_dtype)
        return (jax.lax.bitcast_convert_type(w, jnp.uint16),
                w.astype(jnp.float32))
    if dtype != "int4":
        raise ValueError(f"no packed wire for transport dtype {dtype!r}")
    n = x.shape[0]
    rows = -(-n // QUANT_BLOCK)
    x2d = _block_pad(x.reshape(-1).astype(jnp.float32), rows)
    use_kernel, interpret = _resolve(mode)
    if use_kernel:
        # the fused sender pass: scale + codes + nibble-pack + local
        # dequant in ONE kernel launch per region. A ragged tail (n not
        # lane-pair-aligned) is handled by the zero-padded block layout:
        # codes past n quantize to 0, so the ragged final byte's high
        # nibble is 0 — byte-identical to ref.pack_int4's odd-tail pad
        # (tested on the property grid).
        packed2d, scales, local2d = _quant.quantize_pack_int4(
            x2d, interpret=interpret)
        code_bytes = packed2d.reshape(-1)[:-(-n // 2)]
    else:
        codes, scales = ref.quantize_int4(x2d)
        local2d = ref.dequantize_int4(codes, scales)
        code_bytes = ref.pack_int4(codes.reshape(-1)[:n])
    pad = (-code_bytes.shape[0]) % WIRE_ALIGN
    if pad:
        code_bytes = jnp.pad(code_bytes, (0, pad))
    scale_bytes = jax.lax.bitcast_convert_type(
        scales.reshape(rows), jnp.uint8).reshape(-1)
    wire = jnp.concatenate(
        [jax.lax.bitcast_convert_type(code_bytes, jnp.uint8),
         scale_bytes])
    local = local2d.reshape(-1)[:n]
    return wire, local


def wire_decode(wire, n_elems: int, dtype: str, *, mode: str = "auto"):
    """Decode one region's wire buffer back to (n,) f32 — the exact
    value the sender's ``wire_encode`` reported as ``local`` (pack →
    unpack is the identity on the int4 code grid, and the f32 scales
    ride bit-exact)."""
    if dtype == "bfloat16":
        return jax.lax.bitcast_convert_type(
            wire, jnp.bfloat16).astype(jnp.float32)
    if dtype != "int4":
        raise ValueError(f"no packed wire for transport dtype {dtype!r}")
    n = int(n_elems)
    rows = -(-n // QUANT_BLOCK)
    cb = -(-n // 2)
    pad = (-cb) % WIRE_ALIGN
    use_kernel, interpret = _resolve(mode)
    scales = jax.lax.bitcast_convert_type(
        wire[cb + pad:].reshape(rows, 4), jnp.float32)
    if use_kernel:
        # fused unpack+dequantize: ONE launch per region (padding wire
        # bytes with zeros appends zero codes past n — sliced off)
        half = QUANT_BLOCK // 2
        p = jax.lax.bitcast_convert_type(wire[:cb], jnp.int8)
        if cb != rows * half:
            p = jnp.pad(p, (0, rows * half - cb))
        vals = _quant.unpack_dequantize_int4(
            p.reshape(rows, half), scales.reshape(rows, 1),
            interpret=interpret)
    else:
        codes = ref.unpack_int4(
            jax.lax.bitcast_convert_type(wire[:cb], jnp.int8), n)
        vals = ref.dequantize_int4(_block_pad(codes, rows),
                                   scales.reshape(rows, 1))
    return vals.reshape(-1)[:n]


def wire_reduce(gathered, n_elems: int, dtype: str, m, denom, *,
                mode: str = "auto"):
    """Consume one region's GATHERED wire: decode every replica's
    buffer and mask-reduce to the transported mean — the deferred
    streaming round's apply-side op (``tensordot(m, decoded) / denom``,
    the simulated transport's reduction verbatim on the ref path).
    gathered: (k, W) wire buffers in replica order; m: (k,) mask;
    denom: the mask sum. int4 under a kernel mode runs the fused
    unpack+dequantize+reduce consumer — decode and reduction in ONE
    kernel launch instead of per-replica unpack/dequant pairs."""
    use_kernel, interpret = _resolve(mode)
    if dtype == "int4" and use_kernel:
        n = int(n_elems)
        rows = -(-n // QUANT_BLOCK)
        cb = -(-n // 2)
        pad = (-cb) % WIRE_ALIGN
        half = QUANT_BLOCK // 2
        k = gathered.shape[0]
        p = jax.lax.bitcast_convert_type(gathered[:, :cb], jnp.int8)
        if cb != rows * half:
            p = jnp.pad(p, ((0, 0), (0, rows * half - cb)))
        scales = jax.lax.bitcast_convert_type(
            gathered[:, cb + pad:].reshape(k, rows, 4), jnp.float32)
        red = _quant.unpack_dequantize_reduce(
            p.reshape(k, rows, half), scales.reshape(k, rows, 1),
            m, interpret=interpret)
        return red.reshape(-1)[:n] / denom
    vals = jax.vmap(
        lambda w: wire_decode(w, n_elems, dtype, mode=mode))(gathered)
    return jnp.tensordot(m, vals, axes=(0, 0)) / denom


# ---------------------------------------------------------------------------
# outer Nesterov — tree-level
# ---------------------------------------------------------------------------

def nesterov_update_tree(params, delta, buf, *, lr, momentum=0.9,
                         mode: str = "auto"):
    use_kernel, interpret = _resolve(mode)

    def one(p, d, b):
        if use_kernel:
            return _nesterov.outer_nesterov(p, d, b, lr=lr,
                                            momentum=momentum,
                                            interpret=interpret)
        return ref.outer_nesterov(p, d, b, lr=lr, momentum=momentum)

    out = jax.tree.map(one, params, delta, buf)
    leaves = lambda i: jax.tree.map(
        lambda t: t[i], out, is_leaf=lambda x: isinstance(x, tuple))
    return leaves(0), leaves(1)
