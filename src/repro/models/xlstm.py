"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

Faithful to the xLSTM paper's cell equations with exponential gating and
max-stabilizer state m. Training runs the recurrence with ``lax.scan``
over time (compiles to a while loop — HLO stays small at any T); decode
is the identical single-step cell, so train/decode agreement is exact
(tested). Structure simplification (noted in DESIGN.md): the projection
block around each cell is a gated up/down projection rather than the
paper's full pre/post conv stack.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init, zeros_init, ones_init, apply_norm


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg):
    D = cfg.d_model
    H = cfg.n_heads
    dh = D // H
    ks = jax.random.split(key, 8)
    return {
        "wq": dense_init(ks[0], (D, H, dh), ("embed", "heads", None),
                         cfg.init_scale),
        "wk": dense_init(ks[1], (D, H, dh), ("embed", "heads", None),
                         cfg.init_scale),
        "wv": dense_init(ks[2], (D, H, dh), ("embed", "heads", None),
                         cfg.init_scale),
        "wi": dense_init(ks[3], (D, H), ("embed", "heads"), cfg.init_scale),
        "wf": dense_init(ks[4], (D, H), ("embed", "heads"), cfg.init_scale),
        "bi": zeros_init((H,), ("heads",)),
        "bf": Boxed_bias_f(H),
        "wz": dense_init(ks[5], (D, D), ("embed", "inner"), cfg.init_scale),
        "wo": dense_init(ks[6], (D, D), ("inner", "embed"), cfg.init_scale),
        "norm": ones_init((D,), (None,)),
    }


def Boxed_bias_f(H):
    """Forget-gate bias init ~ +3 so exp-gates start near 'remember'."""
    from repro.sharding.spec import Boxed
    return Boxed(jnp.full((H,), 3.0, jnp.float32), ("heads",))


def mlstm_cell(carry, inp):
    """One timestep. carry: (C, n, m) with C (B,H,dk,dv), n (B,H,dk),
    m (B,H). inp: (q, k, v, i_pre, f_pre) at one t."""
    C, n, m = carry
    q, k, v, i_pre, f_pre = inp
    # log-space stabilized exponential gating
    logf = jax.nn.log_sigmoid(f_pre)                      # (B,H)
    m_new = jnp.maximum(logf + m, i_pre)
    fg = jnp.exp(logf + m - m_new)
    ig = jnp.exp(i_pre - m_new)
    C = C * fg[..., None, None] + ig[..., None, None] \
        * (k[..., :, None] * v[..., None, :])
    n = n * fg[..., None] + ig[..., None] * k
    num = jnp.einsum("bhkv,bhk->bhv", C, q)
    den = jnp.abs(jnp.einsum("bhk,bhk->bh", n, q))
    h = num / jnp.maximum(den, 1.0)[..., None]
    return (C, n, m_new), h


def apply_mlstm(p, x, cfg, *, state=None):
    """x: (B,T,D). state: optional (C,n,m) for decode. Returns
    (out, new_state)."""
    dt_ = x.dtype
    B, T, D = x.shape
    H = cfg.n_heads
    dh = D // H
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"].astype(dt_)) * dh ** -0.5
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"].astype(dt_)) * dh ** -0.5
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"].astype(dt_))
    i_pre = (jnp.einsum("btd,dh->bth", x, p["wi"].astype(dt_))
             + p["bi"].astype(dt_)).astype(jnp.float32)
    f_pre = (jnp.einsum("btd,dh->bth", x, p["wf"].astype(dt_))
             + p["bf"].astype(dt_)).astype(jnp.float32)

    if state is None:
        state = init_mlstm_state(cfg, B, dh)
    qf, kf, vf = (a.astype(jnp.float32) for a in (q, k, v))
    if T == 1:
        new_state, h = mlstm_cell(state, (qf[:, 0], kf[:, 0], vf[:, 0],
                                          i_pre[:, 0], f_pre[:, 0]))
        h = h[:, None]
    else:
        tfirst = lambda a: jnp.moveaxis(a, 1, 0)
        new_state, hs = jax.lax.scan(
            mlstm_cell, state,
            (tfirst(qf), tfirst(kf), tfirst(vf), tfirst(i_pre),
             tfirst(f_pre)))
        h = jnp.moveaxis(hs, 0, 1)
    h = h.reshape(B, T, D).astype(dt_)
    z = jnp.einsum("btd,de->bte", x, p["wz"].astype(dt_))
    h = apply_norm({"scale": p["norm"]}, h, "rmsnorm") * jax.nn.silu(z)
    return jnp.einsum("bte,ed->btd", h, p["wo"].astype(dt_)), new_state


def init_mlstm_state(cfg, batch: int, dh: int | None = None):
    H = cfg.n_heads
    dh = dh or cfg.d_model // H
    return (jnp.zeros((batch, H, dh, dh), jnp.float32),
            jnp.zeros((batch, H, dh), jnp.float32),
            jnp.full((batch, H), -1e30, jnp.float32))


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg):
    D = cfg.d_model
    H = cfg.n_heads
    ks = jax.random.split(key, 10)
    dh = D // H
    mk = lambda kk: dense_init(kk, (D, D), ("embed", "inner"),
                               cfg.init_scale)
    rk = lambda kk: dense_init(kk, (H, dh, dh), ("heads", None, None),
                               cfg.init_scale)
    return {
        "wz": mk(ks[0]), "wi": mk(ks[1]), "wf": mk(ks[2]), "wo": mk(ks[3]),
        "rz": rk(ks[4]), "ri": rk(ks[5]), "rf": rk(ks[6]), "ro": rk(ks[7]),
        "bz": zeros_init((D,), (None,)), "bi": zeros_init((D,), (None,)),
        "bf": Boxed_bias_f_vec(D), "bo": zeros_init((D,), (None,)),
        "w_down": dense_init(ks[8], (D, D), ("inner", "embed"),
                             cfg.init_scale),
        "norm": ones_init((D,), (None,)),
    }


def Boxed_bias_f_vec(D):
    from repro.sharding.spec import Boxed
    return Boxed(jnp.full((D,), 3.0, jnp.float32), (None,))


def slstm_cell(p, cfg, carry, xt):
    """xt: (B, D) pre-activations dict inputs; carry: (c, n, h, m) each
    (B, H, dh) except m (B, H)."""
    c, n, h, m = carry
    B = xt["z"].shape[0]
    H = cfg.n_heads
    dh = cfg.d_model // H
    hh = h.reshape(B, H, dh)
    rec = lambda w: jnp.einsum("bhk,hkl->bhl", hh, w)
    z = jnp.tanh(xt["z"].reshape(B, H, dh) + rec(p["rz"]))
    i_pre = xt["i"].reshape(B, H, dh) + rec(p["ri"])
    f_pre = xt["f"].reshape(B, H, dh) + rec(p["rf"])
    o = jax.nn.sigmoid(xt["o"].reshape(B, H, dh) + rec(p["ro"]))
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + m, i_pre)
    fg = jnp.exp(logf + m - m_new)
    ig = jnp.exp(i_pre - m_new)
    c = fg * c + ig * z
    n = fg * n + ig
    h_new = o * c / jnp.maximum(n, 1.0)
    return (c, n, h_new.reshape(B, H * dh), m_new), h_new.reshape(B, H * dh)


def apply_slstm(p, x, cfg, *, state=None):
    dt_ = x.dtype
    B, T, D = x.shape
    H = cfg.n_heads
    dh = D // H
    pre = {g: (jnp.einsum("btd,de->bte", x, p["w" + g].astype(dt_))
               + p["b" + g].astype(dt_)).astype(jnp.float32)
           for g in ("z", "i", "f", "o")}
    if state is None:
        state = init_slstm_state(cfg, B)
    pf32 = {k: p[k].astype(jnp.float32) for k in ("rz", "ri", "rf", "ro")}
    cell = lambda carry, xt: slstm_cell(pf32, cfg, carry, xt)
    if T == 1:
        new_state, h = cell(state, {k: v[:, 0] for k, v in pre.items()})
        hs = h[:, None]
    else:
        xs = {k: jnp.moveaxis(v, 1, 0) for k, v in pre.items()}
        new_state, hs = jax.lax.scan(cell, state, xs)
        hs = jnp.moveaxis(hs, 0, 1)
    hs = apply_norm({"scale": p["norm"]}, hs.astype(dt_), "rmsnorm")
    return jnp.einsum("bte,ed->btd", hs, p["w_down"].astype(dt_)), new_state


def init_slstm_state(cfg, batch: int):
    H = cfg.n_heads
    dh = cfg.d_model // H
    z = jnp.zeros((batch, H, dh), jnp.float32)
    return (z, z, jnp.zeros((batch, H * dh), jnp.float32),
            jnp.full((batch, H, dh), -1e30, jnp.float32))
