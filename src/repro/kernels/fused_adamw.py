"""Fused AdamW update — Pallas TPU kernels.

The inner optimizer is DiLoCo's per-step memory bill: each AdamW step
reads (p, g, m, v) and writes (p, m, v) — 7 tensor-sized HBM transfers
that XLA sometimes splits across fusions. These kernels perform the
whole update in ONE VMEM pass per tile: a (block_r, 128)-tile of each
operand streams in, the update math runs on the VPU in f32, and the
outputs stream out. Bandwidth-optimal: bytes moved = the operand reads
plus the result writes, nothing else.

Two variants share one tiling scaffold:

  * ``fused_adamw``       — uniform precision: reads (p, g, m, v),
    writes (p, m, v) at their own dtypes;
  * ``fused_adamw_mixed`` — mixed precision (see optim/precision.py):
    reads the low-precision grads/moments and the high-precision master
    params, writes the updated master AND the ``param_dtype`` working
    copy in the same pass, so the working-copy cast XLA would otherwise
    materialize as a separate HBM round trip is fused away. Bytes moved
    (bf16 state, f32 master): 2+2+2+4 reads, 2+2+2+4 writes per element
    vs the all-f32 kernel's 16/12.

Scalars (lr and the bias corrections c1 = 1-β1^t, c2 = 1-β2^t) arrive as
a small SMEM-resident array so the same compiled kernel serves every
step of the schedule.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import compat


def _to_blocks(tensors, block_rows: int):
    """Flatten same-shape tensors to a shared padded (rows_p, 128)
    layout. Returns (tensors_2d, rows_p, block_rows, n_elems)."""
    n = tensors[0].size
    cols = 128
    rows = -(-n // cols)
    br = min(block_rows, rows)
    rows_p = -(-rows // br) * br

    def to2d(x):
        x = x.reshape(-1)
        if rows_p * cols != n:
            x = jnp.pad(x, (0, rows_p * cols - n))
        return x.reshape(rows_p, cols)

    return [to2d(x) for x in tensors], rows_p, br, n


def _call_blocked(kernel, tensors_2d, rows_p, br, out_dtypes,
                  scalars, interpret):
    """Run ``kernel`` over the (rows_p, 128) layout with the shared
    SMEM-scalars + one-tile-per-operand grid spec."""
    tile = pl.BlockSpec((br, 128), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=(rows_p // br,),
        in_specs=[pl.BlockSpec(memory_space=compat.SMEM)]
        + [tile] * len(tensors_2d),
        out_specs=(tile,) * len(out_dtypes),
        out_shape=tuple(jax.ShapeDtypeStruct((rows_p, 128), d)
                        for d in out_dtypes),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(scalars, *tensors_2d)


def _scalars(lr, c1, c2):
    return jnp.stack([jnp.asarray(lr, jnp.float32),
                      jnp.asarray(c1, jnp.float32),
                      jnp.asarray(c2, jnp.float32)])


def _adamw_kernel(sc_ref, p_ref, g_ref, m_ref, v_ref,
                  p_out, m_out, v_out, *, b1, b2, eps, weight_decay):
    lr, c1, c2 = sc_ref[0], sc_ref[1], sc_ref[2]
    p = p_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    m_new = b1 * m + (1.0 - b1) * g
    v_new = b2 * v + (1.0 - b2) * g * g
    step = (m_new / c1) / (jnp.sqrt(v_new / c2) + eps) + weight_decay * p
    p_out[...] = (p - lr * step).astype(p_out.dtype)
    m_out[...] = m_new.astype(m_out.dtype)
    v_out[...] = v_new.astype(v_out.dtype)


def fused_adamw(p, g, m, v, *, lr, c1, c2, b1=0.9, b2=0.95, eps=1e-8,
                weight_decay=0.1, block_rows: int = 256,
                interpret: bool = False):
    """One AdamW step on a single tensor of any shape.

    lr/c1/c2 may be traced scalars. Returns (p_new, m_new, v_new).
    """
    shape = p.shape
    out_dtypes = (p.dtype, m.dtype, v.dtype)
    t2d, rows_p, br, n = _to_blocks((p, g, m, v), block_rows)
    kernel = functools.partial(_adamw_kernel, b1=b1, b2=b2, eps=eps,
                               weight_decay=weight_decay)
    outs = _call_blocked(kernel, t2d, rows_p, br, out_dtypes,
                         _scalars(lr, c1, c2), interpret)
    return tuple(o.reshape(-1)[:n].reshape(shape).astype(d)
                 for o, d in zip(outs, out_dtypes))


# ---------------------------------------------------------------------------
# mixed-precision variant: bf16 replica state + higher-precision master
# ---------------------------------------------------------------------------

def _adamw_mixed_kernel(sc_ref, g_ref, m_ref, v_ref, w_ref,
                        p_out, m_out, v_out, w_out,
                        *, b1, b2, eps, weight_decay):
    lr, c1, c2 = sc_ref[0], sc_ref[1], sc_ref[2]
    g = g_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)          # master — authoritative
    m_new = b1 * m + (1.0 - b1) * g
    v_new = b2 * v + (1.0 - b2) * g * g
    step = (m_new / c1) / (jnp.sqrt(v_new / c2) + eps) + weight_decay * w
    w_new = w - lr * step
    p_out[...] = w_new.astype(p_out.dtype)      # bf16 working copy
    m_out[...] = m_new.astype(m_out.dtype)
    v_out[...] = v_new.astype(v_out.dtype)
    w_out[...] = w_new.astype(w_out.dtype)


def fused_adamw_mixed(g, m, v, master, *, lr, c1, c2, b1=0.9, b2=0.95,
                      eps=1e-8, weight_decay=0.1,
                      param_dtype=jnp.bfloat16, block_rows: int = 256,
                      interpret: bool = False):
    """One mixed-precision AdamW step on a single tensor of any shape
    (see the module docstring). lr/c1/c2 may be traced scalars.
    Returns (p_working, m_new, v_new, master_new).
    """
    shape = master.shape
    out_dtypes = (jnp.dtype(param_dtype), m.dtype, v.dtype, master.dtype)
    t2d, rows_p, br, n = _to_blocks((g, m, v, master), block_rows)
    kernel = functools.partial(_adamw_mixed_kernel, b1=b1, b2=b2,
                               eps=eps, weight_decay=weight_decay)
    outs = _call_blocked(kernel, t2d, rows_p, br, out_dtypes,
                         _scalars(lr, c1, c2), interpret)
    return tuple(o.reshape(-1)[:n].reshape(shape).astype(d)
                 for o, d in zip(outs, out_dtypes))
