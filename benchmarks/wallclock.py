"""Wall-clock benchmark: legacy per-round loop vs scanned driver.

The legacy driver re-dispatches one jitted round from Python every
outer iteration and blocks on a host-side eval before the next round —
per-round cost = round compute + jit dispatch + device→host sync +
eval dispatch. The scanned driver (``diloco.make_run``) executes R
rounds inside ONE jit via ``lax.scan`` with the eval computed in-graph
and the state carry donated, so the host pays one dispatch per R
rounds and the carry is not double-buffered.

Both paths run the identical computation (same key chain, same
``kernel_mode``) so the delta is pure driver overhead. Results go to
``BENCH_wallclock.json`` at the repo root — the perf trajectory every
future PR measures itself against:

  tokens_per_sec          training tokens processed per wall second
  round_latency_ms        wall time per DiLoCo round (compute + driver)
  dispatch_overhead_ms    legacy minus scanned round latency — the
                          per-round cost of Python dispatch + blocking
                          eval that the scanned driver eliminates
  peak_state_bytes_est    optimizer-state footprint: legacy double-
                          buffers the k×(params + AdamW m/v) carry,
                          donation updates it in place

The overlap row (PR 8) times the sharded packed-int4 streaming round
with the issue→consume window open (``stream_tau=1``: each fragment's
all-gather is issued at its snapshot offset and consumed τ inner steps
later through the in-flight carry slot) against the same round with the
window closed (``stream_tau=0``: eager consume at the send offset).
Same model, data, mesh and wire format — the only delta is the
deferral, so the pair isolates what the double-buffered slot costs or
saves. On CPU there is no async collective engine to hide latency in,
so the gate is *no regression* (small slack for host noise) plus the
HLO-measured separation (``launch/hlo_analysis.stream_overlap``) that
proves the structure TPU/GPU latency-hiding schedulers exploit.

Run:  PYTHONPATH=src python -m benchmarks.wallclock [--rounds 8 ...]
"""
from __future__ import annotations

import argparse
import json
import os
import time

# the overlap row needs a (pod, data) mesh — force 8 host devices
# BEFORE jax initializes (a no-op when the caller already pinned
# XLA_FLAGS, e.g. the CI multidevice/overlap jobs)
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import jax

from . import common as C
from repro.configs.base import DiLoCoConfig, TrainConfig
from repro.core import diloco, pod_collectives, streaming
from repro.data.sharding import make_regime
from repro.launch import hlo_analysis
from repro.launch.mesh import make_mesh

ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
OUT_PATH = os.path.join(ROOT, "BENCH_wallclock.json")


def tree_bytes(tree) -> int:
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(tree))


def bench_drivers(loss_fn, sampler, params, dcfg, tcfg, *, rounds, batch,
                  seq, eval_batch, seed, repeats):
    """Time the legacy loop and the scanned driver, interleaved.

    Legacy: per-round jit dispatch + blocking host eval every round.
    Scanned: one jit per run — lax.scan over rounds, in-graph eval,
    donated carry. The repeats alternate legacy/scanned so background
    load drift hits both paths equally; min-of-repeats per path.
    Returns (t_legacy, t_scanned, loss_legacy, loss_scanned).
    """
    total = rounds * dcfg.H
    val = sampler.sample_validation(jax.random.PRNGKey(10_000),
                                    eval_batch, seq)
    rnd = diloco.make_round(loss_fn, sampler.sample_all_shards, dcfg,
                            tcfg, total_steps=total, batch_size=batch,
                            seq_len=seq)
    ev = diloco.make_eval(loss_fn)
    run = diloco.make_run(loss_fn, sampler.sample_all_shards, dcfg, tcfg,
                          rounds_per_call=rounds, total_steps=total,
                          batch_size=batch, seq_len=seq, eval_tokens=val,
                          eval_every=1, donate=True)

    def one_legacy():
        state = diloco.init_state(params, dcfg)
        jax.block_until_ready(state)
        key = jax.random.PRNGKey(seed + 2)
        losses = []
        t0 = time.perf_counter()
        for _ in range(rounds):
            key, sub = jax.random.split(key)
            state, m = rnd(state, sub)
            losses.append(float(ev(state.global_params, val)))
        jax.block_until_ready(state)
        return time.perf_counter() - t0, losses[-1]

    def one_scanned():
        state = diloco.init_state(params, dcfg)
        jax.block_until_ready(state)
        t0 = time.perf_counter()
        state, ms = run(state, jax.random.PRNGKey(seed + 2))
        jax.block_until_ready((state, ms))
        return time.perf_counter() - t0, float(ms["val_loss"][-1])

    one_legacy(), one_scanned()                 # compile warmup
    pairs = [(one_legacy(), one_scanned()) for _ in range(repeats)]
    t_leg = min(l[0] for l, _ in pairs)
    t_scan = min(s[0] for _, s in pairs)
    return t_leg, t_scan, pairs[0][0][1], pairs[0][1][1]


def bench_overlap(loss_fn, params, *, H, rounds, batch, seq, seed,
                  repeats, kernel_mode):
    """Time the sharded packed-int4 streaming round at τ=1 (overlap
    window open, deferred consume through the in-flight carry slot)
    vs τ=0 (eager consume), interleaved min-of-repeats, and attach the
    pre-optimization-HLO issue→consume separation stats for the τ=1
    lowering. Returns None when the pod mesh cannot form (< 8
    devices)."""
    if jax.device_count() < 8:
        return None
    pods, fragments = 2, 2
    mesh = make_mesh((pods, jax.device_count() // pods), ("pod", "data"))
    sampler = make_regime("non_iid", k=pods, vocab_size=C.VOCAB,
                          seed=seed, alpha_noniid=C.ALPHA_NONIID)
    total = rounds * H
    key = jax.random.PRNGKey(seed + 2)

    runs, calls = {}, {}
    for tau in (1, 0):
        dcfg = DiLoCoConfig(k=pods, H=H, streaming_fragments=fragments,
                            stream_tau=tau, stream_alpha=0.5,
                            outer_grad_dtype="int4", transport="sharded",
                            kernel_mode=kernel_mode)
        tcfg = TrainConfig(inner_lr=3e-3, warmup_steps=10,
                           total_steps=total, batch_size=batch,
                           seq_len=seq, kernel_mode=kernel_mode)
        run_fn = diloco.make_run(loss_fn, sampler.sample_all_shards,
                                 dcfg, tcfg, rounds_per_call=rounds,
                                 total_steps=total, batch_size=batch,
                                 seq_len=seq, donate=False, mesh=mesh)
        state0 = pod_collectives.shard_stream_state(
            streaming.init_state(params, dcfg), mesh)
        lowered = run_fn.lower(state0, key)
        entry = {"tau": tau}
        if tau > 0:
            # overlap structure is measured where it exists: emission
            # order on pre-optimization HLO (see stream_overlap)
            entry["hlo_overlap"] = hlo_analysis.stream_overlap(
                lowered.compiler_ir("hlo").as_hlo_text(),
                chips_per_pod=jax.device_count() // pods, tau=tau)
        calls[tau] = (lowered.compile(), state0)
        runs[tau] = entry

    def one(tau):
        call, state0 = calls[tau]
        jax.block_until_ready(state0)
        t0 = time.perf_counter()
        out = call(state0, key)
        jax.block_until_ready(out)
        return time.perf_counter() - t0

    one(1), one(0)                              # warmup
    times = {1: [], 0: []}
    for _ in range(repeats):    # interleave so load drift hits both
        times[1].append(one(1))
        times[0].append(one(0))
    for tau, entry in runs.items():
        t = min(times[tau])
        entry["total_s"] = t
        entry["round_latency_ms"] = 1e3 * t / rounds
    return {"pods": pods, "fragments": fragments, "wire_dtype": "int4",
            "tau1": runs[1], "tau0": runs[0],
            "speedup_tau1_vs_tau0": (runs[0]["round_latency_ms"]
                                     / runs[1]["round_latency_ms"])}


def run(scale: int = 1, *, k=4, H=5, rounds=16, batch=2, seq=32,
        eval_batch=16, repeats=5, kernel_mode="ref", seed=0,
        out=OUT_PATH):
    rounds = rounds * scale
    arch, loss_fn, sampler = C.make_setup(k=k, seed=seed)
    total = rounds * H
    params, _ = C.pretrain(arch, loss_fn, sampler, 0, batch=batch,
                           seq=seq, lr=3e-3, warmup=10, total=total,
                           seed=seed)
    dcfg = DiLoCoConfig(k=k, H=H, kernel_mode=kernel_mode)
    tcfg = TrainConfig(inner_lr=3e-3, warmup_steps=10, total_steps=total,
                       batch_size=batch, seq_len=seq,
                       kernel_mode=kernel_mode)
    kw = dict(rounds=rounds, batch=batch, seq=seq, eval_batch=eval_batch,
              seed=seed, repeats=repeats)

    print(f"k={k} H={H} rounds={rounds} batch={batch} seq={seq} "
          f"kernel_mode={kernel_mode} backend={jax.default_backend()}")
    t_leg, t_scan, loss_leg, loss_scan = bench_drivers(
        loss_fn, sampler, params, dcfg, tcfg, **kw)
    overlap = bench_overlap(loss_fn, params, H=H, rounds=rounds,
                            batch=batch, seq=seq, seed=seed,
                            repeats=repeats, kernel_mode=kernel_mode)

    if overlap is not None:
        t1 = overlap["tau1"]["round_latency_ms"]
        t0o = overlap["tau0"]["round_latency_ms"]
        ov = overlap["tau1"]["hlo_overlap"]
        claims_overlap = {
            # CPU has no async collective engine, so the wall-clock
            # gate is no-regression with host-noise slack; the HLO gate
            # is exact (every deferred wire's issue and consume are
            # >= tau inner steps apart in emission order)
            "overlap_no_regression": bool(t1 <= 1.10 * t0o),
            "overlap_hlo_issue_consume_separated": bool(ov["ok"]),
        }
    else:
        note = {"value": None, "informational": True,
                "reason": "pod mesh needs >= 8 devices"}
        claims_overlap = {
            "overlap_no_regression": dict(note),
            "overlap_hlo_issue_consume_separated": dict(note),
        }

    tokens = k * H * rounds * batch * seq
    state_bytes = tree_bytes(diloco.init_state(params, dcfg))
    report = {
        "config": {"k": k, "H": H, "rounds": rounds, "batch": batch,
                   "seq": seq, "eval_batch": eval_batch,
                   "kernel_mode": kernel_mode,
                   "backend": jax.default_backend(),
                   "model_params": int(sum(
                       l.size for l in jax.tree.leaves(params)))},
        "legacy": {
            "total_s": t_leg,
            "round_latency_ms": 1e3 * t_leg / rounds,
            "tokens_per_sec": tokens / t_leg,
            "final_val_loss": loss_leg,
            "peak_state_bytes_est": 2 * state_bytes,  # double-buffered
        },
        "scanned": {
            "total_s": t_scan,
            "round_latency_ms": 1e3 * t_scan / rounds,
            "tokens_per_sec": tokens / t_scan,
            "final_val_loss": loss_scan,
            "peak_state_bytes_est": state_bytes,      # donated carry
        },
        "dispatch_overhead_ms_per_round":
            1e3 * (t_leg - t_scan) / rounds,
        "speedup": t_leg / t_scan,
        "overlap": overlap,
        "claims": {
            "scanned_beats_legacy_round_latency": t_scan < t_leg,
            "same_final_loss": abs(loss_leg - loss_scan) < 1e-4,
            "speedup_x": float(t_leg / t_scan),
            **claims_overlap,
        },
    }
    print(f"legacy : {report['legacy']['round_latency_ms']:8.2f} ms/round"
          f"  {report['legacy']['tokens_per_sec']:10.0f} tok/s")
    print(f"scanned: {report['scanned']['round_latency_ms']:8.2f} ms/round"
          f"  {report['scanned']['tokens_per_sec']:10.0f} tok/s")
    print(f"speedup: {report['speedup']:.3f}x  "
          f"(dispatch overhead "
          f"{report['dispatch_overhead_ms_per_round']:.2f} ms/round)")
    if overlap is not None:
        print(f"overlap: tau=1 {t1:8.2f} ms/round vs tau=0 "
              f"{t0o:8.2f} ms/round  "
              f"(x{overlap['speedup_tau1_vs_tau0']:.3f}; "
              f"min {ov['min_steps_between']} steps / "
              f"{ov['min_dots_between']} dots issue->consume, "
              f"{ov['n_deferred']} deferred wires)")
    else:
        print("overlap: skipped (pod mesh needs >= 8 devices)")

    with open(out, "w") as f:
        json.dump(report, f, indent=1)
    print("wrote", out)
    C.save("wallclock", report)
    return report


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--H", type=int, default=5)
    ap.add_argument("--rounds", type=int, default=16)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--eval-batch", type=int, default=16)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--kernel-mode", default="ref",
                    choices=["auto", "pallas", "interpret", "ref"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=OUT_PATH)
    a = ap.parse_args(argv)
    return run(1, k=a.k, H=a.H, rounds=a.rounds, batch=a.batch,
               seq=a.seq, eval_batch=a.eval_batch, repeats=a.repeats,
               kernel_mode=a.kernel_mode, seed=a.seed, out=a.out)


if __name__ == "__main__":
    main()
