"""Config system: model architecture, input shapes, DiLoCo, training."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | encdec | vlm | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads

    # --- attention ---
    pos_emb: str = "rope"       # rope | learned | sincos | none
    rope_theta: float = 10_000.0
    rope_pct: float = 1.0       # fraction of head_dim rotated
    qk_norm: bool = False
    attn_bias: bool = False
    mlp_bias: bool = False
    parallel_block: bool = False  # command-r style (attn & mlp share input)
    window: int = 0             # >0: sliding-window attention
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    act: str = "silu"           # silu | gelu
    mlp_gated: bool = True
    tie_embeddings: bool = False
    max_position: int = 1 << 20

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0           # per-expert hidden dim
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # --- MLA (DeepSeek-V2) ---
    mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64
    v_head_dim: int = 0         # 0 -> head_dim

    # --- encoder-decoder (whisper) ---
    n_enc_layers: int = 0
    n_frames: int = 1500        # stubbed audio frontend output length

    # --- VLM ---
    cross_attn_every: int = 0   # every Nth layer is a cross-attn layer
    n_patches: int = 0          # stubbed vision frontend output length
    vision_dim: int = 0         # 0 -> d_model (projector stubbed)

    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv: int = 4
    shared_attn_every: int = 0  # zamba2: shared attn block every N layers
    slstm_every: int = 0        # xlstm: every Nth block is sLSTM

    # --- numerics / execution ---
    act_batch_axes: tuple = ("data",)   # mesh axes carrying the batch
    act_model_shard: bool = True        # residual d_model over "model"
    act_seq_shard: bool = False         # Megatron SP: residual seq dim
    decode_kv_shard: str = ""           # flash-decoding axis for caches
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    attn_chunk: int = 1024      # kv-chunk size of online-softmax attention
    remat: bool = True
    logit_softcap: float = 0.0
    init_scale: float = 0.02
    use_pallas: bool = False    # use Pallas kernels (TPU) instead of jnp ref

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def resolved_v_head_dim(self) -> int:
        return self.v_head_dim or self.resolved_head_dim


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


# The four assigned input shapes.
SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# Sliding window used by full-attention archs for long_500k.
LONG_CONTEXT_WINDOW = 4_096


@dataclass(frozen=True)
class DiLoCoConfig:
    """Algorithm 1 hyper-parameters (paper defaults in comments)."""
    k: int = 8                  # number of replicas / islands
    H: int = 500                # inner steps per outer step
    outer_opt: str = "nesterov"  # nesterov | sgd | sgdm | adam
    outer_lr: float = 0.7       # paper: 0.7 for Nesterov
    outer_momentum: float = 0.9
    outer_adam_b2: float = 0.95
    outer_adam_eps: float = 0.1  # paper: raised to 0.1 for stability
    drop_prob: float = 0.0      # async-communication dropout (Fig 8)
    prune_frac: float = 0.0     # sign-pruning of outer grads (Tab 6)
    weighted_avg: bool = False  # weight outer grads by shard size
    sync_inner_state: bool = False  # paper: False (3x comm for no gain)
    # Backend for the fused outer-optimizer / pruning kernels:
    #   ref       — legacy pure-jnp tree maps (bit-identical to the
    #               pre-kernel implementation);
    #   auto      — Pallas kernels on TPU, jnp oracles elsewhere;
    #   pallas    — force the Pallas kernels (TPU);
    #   interpret — Pallas kernels in interpret mode (CPU testing).
    kernel_mode: str = "ref"
    # --- streaming outer sync (Streaming DiLoCo; see core/streaming.py) ---
    # 0 disables streaming (classic full-model outer step every H steps).
    # P >= 1 splits the parameter tree into P fragments, each synced on
    # its own staggered schedule within the round. P=1 with the defaults
    # below reproduces the synchronous path bit-exactly.
    streaming_fragments: int = 0
    stream_alpha: float = 1.0    # merge θ_i ← α·θ_global + (1−α)·θ_i
    stream_tau: int = 0          # inner steps between a fragment's
    #                              snapshot and its application (the
    #                              simulated in-flight collective)
    outer_grad_dtype: str = "float32"  # transport precision of outer
    #                              gradients: float32 | bfloat16 | int4
    stream_overrides: tuple = ()  # ((path-regex, fragment_idx), ...)
    #                              forcing whole leaves into a fragment
    # Error-feedback accumulation for quantized outer gradients: each
    # replica keeps its transport rounding residual locally and adds it
    # to the next round's delta, driving the mean quantization bias to
    # zero at no wire cost. Only meaningful with a low-precision
    # outer_grad_dtype on the streaming path.
    error_feedback: bool = False
    # Transport backend of the streaming outer sync:
    #   simulated — replica-stacked averaging on one device (the CPU
    #               benchmark path; the historical PR 2 semantics);
    #   sharded   — each replica lives on its own "pod" mesh slice
    #               (core/pod_collectives.py) and every fragment is
    #               reduced by a real pod-axis collective issued from
    #               inside the scanned round: float32 rides a weighted
    #               psum all-reduce; quantized transports all-gather the
    #               per-pod payloads (scale blocks stay pod-local) and
    #               reduce locally in the simulated path's exact op
    #               order. Requires a mesh with a "pod" axis at
    #               round-build time (make_round/make_run mesh=...).
    #   async     — barrier-free (core/async_diloco.py): no round
    #               structure at all; each worker's outer gradient is
    #               applied the moment it arrives at the parameter
    #               server, discounted by staleness_lambda^τ / k.
    #               Driven by AsyncEngine + a faults.Scenario, not by
    #               make_round (which rejects it).
    #   gossip    — NoLoCo-style pairwise partial averaging
    #               (core/gossip.py): no collective spans all k
    #               workers; each round every worker averages its
    #               global estimate with ONE partner's. Round-shaped,
    #               so it routes through make_round/make_run.
    transport: str = "simulated"
    # --- async transport (transport="async") ---
    # Delay compensation: an outer gradient applied τ outer steps after
    # its dispatch is weighted λ^τ / k (λ=1 disables discounting; the
    # 1/k is each worker's share of a synchronous round's evidence).
    staleness_lambda: float = 1.0
    # --- gossip transport (transport="gossip") ---
    #   butterfly — partner(i, t) = i XOR 2^(t mod log2 k): pairwise
    #               averaging along hypercube dimensions; log2(k)
    #               consecutive rounds mix any initial disagreement to
    #               the exact global mean (proven in tests).
    #   random    — a fresh uniform perfect matching each round.
    gossip_pairing: str = "butterfly"
    # Fraction of the partner's global estimate adopted per pairwise
    # exchange: g_i ← (1−mix)·g_i + mix·g_j. 0.5 (symmetric averaging)
    # is what the butterfly exactness proof assumes.
    gossip_mix: float = 0.5
    # Packed wire on the sharded transport (quantized dtypes only):
    # True (default) ships the REAL payload — int4 nibble-packs two
    # codes per int8 byte and lays codes + per-block f32 scales out in
    # ONE byte buffer per fragment (all leaf regions coalesced), bf16
    # ships one coalesced bf16 buffer — so the lowered collective
    # carries exactly the bytes ops.transport_bytes(..., packed=True)
    # charges, with one pod-axis all-gather per fragment per sync.
    # False keeps the legacy transport for comparison: per-leaf gathers
    # of the dequantized f32 payload, bytes charged by the static model
    # only. Ignored by transport="simulated" (no wire) and by the f32
    # dtype (which rides the psum all-reduce either way).
    pack_wire: bool = True
    # --- outer-gradient anomaly guard (resilience/guard.py) ---
    # guard_outer=True adds per-replica sanity checks to the classic
    # outer reduce: a replica whose outer delta contains any non-finite
    # value is excluded from the average (exactly as if its weight were
    # zero — its params still re-dispatch from the new global, which is
    # the recovery). On all-finite rounds the guarded reduce is
    # bit-identical to the unguarded one (multiplying the mask by 1.0
    # and where-ing finite values through are exact identities — gated
    # by BENCH_resilience.json).
    guard_outer: bool = False
    # > 0: additionally clip each replica's outer-delta norm to
    # guard_clip × the median replica norm before the reduce (the
    # norm-outlier escalation tier; 0 keeps norms untouched so clean
    # runs stay bit-identical).
    guard_clip: float = 0.0
    # --- replica-state precision policy (see optim/precision.py) ---
    # param_dtype:  storage dtype of the per-replica working params AND
    #               AdamW moments ("bfloat16" halves the params+moments
    #               donated carry).
    # master_dtype: storage dtype of the master-side state; when wider
    #               than param_dtype a per-replica master copy of the
    #               params is carried in the inner AdamW state and the
    #               outer deltas are computed master-vs-master.
    # MUST match the TrainConfig policy of the same run (checked by the
    # round builders). (float32, float32) is bit-identical to the
    # historical all-f32 path.
    param_dtype: str = "float32"
    master_dtype: str = "float32"


@dataclass(frozen=True)
class TrainConfig:
    inner_lr: float = 4e-4      # paper Table 5
    warmup_steps: int = 1_000
    total_steps: int = 88_000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    batch_size: int = 512       # per-replica batch (paper)
    seq_len: int = 1_024
    pretrain_steps: int = 24_000
    seed: int = 0
    # Backend for the fused inner-AdamW kernel (see DiLoCoConfig).
    kernel_mode: str = "ref"
    # Replica-state precision policy (see DiLoCoConfig / the full
    # explanation in optim/precision.py). Governs the dtypes the inner
    # AdamW step reads and writes; keep in sync with the DiLoCoConfig
    # of the same run.
    param_dtype: str = "float32"
    master_dtype: str = "float32"
