import os

# Tests see the CPU platform with 8 fake host devices. The device-count
# flag MUST be set here (before anything imports jax): XLA reads it at
# backend initialization, so a module-level os.environ write in a test
# file silently no-ops whenever another test module initialized jax
# first (the old tests/test_dryrun_lite.py footgun). Centralizing it in
# conftest makes every multi-device test (tests/test_pod_collectives.py,
# in-process dry-run lowerings) compose regardless of collection order.
# Single-device tests are unaffected: un-sharded computations still run
# on device 0. (launch/dryrun.py forces 512 fake devices — in its own
# process.)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
if "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 "
        + os.environ.get("XLA_FLAGS", ""))

import jax

jax.config.update("jax_enable_x64", False)
