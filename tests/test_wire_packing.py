"""Packed-wire subsystem units: int4 nibble pack/unpack (oracle ≡
kernel, roundtrip identity), the one-buffer wire codec
(codes+scales layout, byte-exact against the packed accounting),
``transport_bytes(packed=True)`` accounting, the fragment region index
the coalesced gather flattens, the donated-carry aliasing regression
(every state-building path must hand the donated jit FRESH buffers,
even where ``astype``/``device_put`` would be the identity), and the
CI claims gate script.

Multi-device pieces (shard_stream_state) run on the 8 fake CPU devices
tests/conftest.py forces.
"""
from __future__ import annotations

import importlib.util
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fragments, pod_collectives, streaming
from repro.configs.base import DiLoCoConfig
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.launch.mesh import make_mesh
from repro.optim import adamw, precision

# ---------------------------------------------------------------------------
# transport_bytes: exact packed accounting (satellite 1)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,expected", [
    (1, 4 + 4),            # 1 code byte -> aligned to 4, 1 scale
    (2, 4 + 4),            # ragged final byte shared by 2 codes
    (8, 4 + 4),            # 4 code bytes, already aligned
    (127, 64 + 4),         # ceil(127/2)=64 code bytes, 1 block
    (128, 64 + 4),
    (129, 68 + 8),         # 65 -> pad to 68; 2 started blocks
    (255, 128 + 8),
    (256, 128 + 8),
    (300, 152 + 12),       # 150 -> 152; 3 started blocks
])
def test_packed_int4_accounting(n, expected):
    assert kops.transport_bytes(n, "int4", packed=True) == expected
    # and it is exactly the wire buffer length the codec builds
    assert kops.wire_elems(n, "int4") == expected


def test_packed_vs_legacy_models():
    # even, block-aligned sizes: the packed model equals the legacy
    # fake-quant model (0.5 B/elem + 4 B/block); ragged/odd sizes pay
    # real bytes (whole final byte + alignment) the fraction hides
    assert kops.transport_bytes(256, "int4", packed=True) == \
        kops.transport_bytes(256, "int4")
    assert kops.transport_bytes(255, "int4", packed=True) > \
        kops.transport_bytes(255, "int4")
    # f32 / bf16 ship whole elements: packed == legacy
    for dt in ("float32", "bfloat16"):
        assert kops.transport_bytes(123, dt, packed=True) == \
            kops.transport_bytes(123, dt)
    with pytest.raises(ValueError):
        kops.transport_bytes(10, "int3", packed=True)


# ---------------------------------------------------------------------------
# pack/unpack: oracle ≡ kernel, roundtrip identity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1, 2, 3, 127, 128, 129, 257, 1000])
@pytest.mark.parametrize("mode", ["ref", "interpret"])
def test_pack_unpack_roundtrip(n, mode):
    rng = np.random.default_rng(n)
    codes = jnp.asarray(rng.integers(-7, 8, size=(n,)).astype(np.int8))
    packed = kops.pack_int4(codes, mode=mode)
    assert packed.shape == (-(-n // 2),) and packed.dtype == jnp.int8
    out = kops.unpack_int4(packed, n, mode=mode)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(codes))


@pytest.mark.parametrize("n", [5, 128, 1000])
def test_pack_kernel_matches_oracle_bitwise(n):
    rng = np.random.default_rng(n + 7)
    codes = jnp.asarray(rng.integers(-7, 8, size=(n,)).astype(np.int8))
    np.testing.assert_array_equal(
        np.asarray(kops.pack_int4(codes, mode="ref")),
        np.asarray(kops.pack_int4(codes, mode="interpret")))
    packed = kops.pack_int4(codes, mode="ref")
    np.testing.assert_array_equal(
        np.asarray(kops.unpack_int4(packed, n, mode="ref")),
        np.asarray(kops.unpack_int4(packed, n, mode="interpret")))


def test_pack_nibble_layout():
    """Byte b = elem 2b low nibble | elem 2b+1 high nibble (two's
    complement) — the exact layout a receiver must assume."""
    codes = jnp.asarray([1, -1, 7, -7, 0], jnp.int8)
    packed = np.asarray(ref.pack_int4(codes))
    assert packed[0] == np.int8((1 | (0xF << 4)) - (1 << 8))  # 0xF1
    assert packed[1] == np.int8(0x97 - (1 << 8))              # 7 | 9<<4
    assert packed[2] == 0x00                                  # 0 | pad


# ---------------------------------------------------------------------------
# wire codec: one buffer, byte-exact, value-preserving
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1, 3, 128, 129, 300, 1000])
@pytest.mark.parametrize("mode", ["ref", "interpret"])
def test_int4_wire_codec_roundtrip(n, mode):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    wire, local = kops.wire_encode(x, "int4", mode=mode)
    assert wire.dtype == jnp.uint8
    assert wire.shape == (kops.wire_elems(n, "int4"),)
    dec = kops.wire_decode(wire, n, "int4", mode=mode)
    # decode recovers the sender's own dequantized value bit-for-bit
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(local))
    # and the payload is the fake-quant roundtrip of the same region
    rt = kops.quant_roundtrip(x, "int4", mode=mode)
    np.testing.assert_array_equal(np.asarray(local), np.asarray(rt))


@pytest.mark.parametrize("n", [1, 255, 256])
def test_bf16_wire_codec(n):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    wire, local = kops.wire_encode(x, "bfloat16")
    # raw bf16 bits as uint16: 2 B/elem on the wire, and XLA cannot
    # hoist a widening convert across the collective (no convert)
    assert wire.dtype == jnp.uint16 and wire.shape == (n,)
    dec = kops.wire_decode(wire, n, "bfloat16")
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(local))
    np.testing.assert_array_equal(
        np.asarray(local),
        np.asarray(x.astype(jnp.bfloat16).astype(jnp.float32)))


def test_wire_codec_rejects_f32():
    with pytest.raises(ValueError):
        kops.wire_encode(jnp.ones((4,)), "float32")
    with pytest.raises(ValueError):
        kops.wire_dtype("float32")


# ---------------------------------------------------------------------------
# fused quantize+pack: one VMEM pass, bitwise vs the ref oracles
# ---------------------------------------------------------------------------


def _n_pallas_calls(fn, *args):
    """Number of Pallas kernel launches in fn's jaxpr."""
    return str(jax.make_jaxpr(fn)(*args)).count("pallas_call[")


@pytest.mark.parametrize("n", [1, 3, 127, 128, 129, 1000])
def test_fused_encode_decode_bitwise_and_one_launch(n):
    """The fused quantize+nibble-pack kernel emits wire bytes, scales
    AND the sender's local dequant in ONE launch, bitwise-equal to the
    ref pipeline (quantize → pack → dequantize); the fused
    unpack+dequantize consumer is likewise one launch, bitwise-equal
    to ref unpack → dequantize — including odd/ragged tails."""
    rng = np.random.default_rng(n + 11)
    x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    wire_r, loc_r = kops.wire_encode(x, "int4", mode="ref")
    wire_k, loc_k = kops.wire_encode(x, "int4", mode="interpret")
    np.testing.assert_array_equal(np.asarray(wire_r), np.asarray(wire_k))
    np.testing.assert_array_equal(np.asarray(loc_r), np.asarray(loc_k))
    np.testing.assert_array_equal(
        np.asarray(kops.wire_decode(wire_r, n, "int4", mode="ref")),
        np.asarray(kops.wire_decode(wire_r, n, "int4",
                                    mode="interpret")))
    assert _n_pallas_calls(
        lambda v: kops.wire_encode(v, "int4", mode="interpret"), x) == 1
    assert _n_pallas_calls(
        lambda w: kops.wire_decode(w, n, "int4", mode="interpret"),
        wire_r) == 1


@pytest.mark.parametrize("n", [5, 128, 300])
def test_wire_reduce_matches_simulated_reduction(n):
    """``wire_reduce`` (the fused unpack+dequantize+masked-reduce
    consumer of a gathered wire) equals the simulated transport's
    decode-then-tensordot reduction, for both modes, in ONE launch on
    the kernel path — including a dropped replica's zeroed mask row."""
    k = 3
    rng = np.random.default_rng(n)
    xs = [jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
          for _ in range(k)]
    gathered = jnp.stack(
        [kops.wire_encode(x, "int4", mode="ref")[0] for x in xs])
    m = jnp.asarray([1.0, 0.0, 1.0])
    denom = jnp.maximum(m.sum(), 1e-9)
    out_r = kops.wire_reduce(gathered, n, "int4", m, denom, mode="ref")
    out_k = kops.wire_reduce(gathered, n, "int4", m, denom,
                             mode="interpret")
    np.testing.assert_allclose(np.asarray(out_r), np.asarray(out_k),
                               rtol=1e-6, atol=1e-7)
    # and it IS the simulated reduction: Σ_r m_r · decode(wire_r)/denom
    vals = jnp.stack([kops.wire_decode(w, n, "int4", mode="ref")
                      for w in gathered])
    expect = jnp.tensordot(m, vals, axes=(0, 0)) / denom
    np.testing.assert_array_equal(np.asarray(out_r), np.asarray(expect))
    assert _n_pallas_calls(
        lambda g: kops.wire_reduce(g, n, "int4", m, denom,
                                   mode="interpret"), gathered) == 1


# ---------------------------------------------------------------------------
# fragment regions: the static index the coalesced wire flattens
# ---------------------------------------------------------------------------


def _toy_params():
    return {"embed": jnp.arange(28.0).reshape(7, 4),
            "stack_w": jnp.arange(30.0).reshape(5, 3, 2),
            "stack_b": jnp.arange(10.0).reshape(5, 2),
            "head": jnp.arange(12.0).reshape(4, 3)}


@pytest.mark.parametrize("P", [1, 2, 3, 4])
def test_fragment_regions_match_region_sizes(P):
    params = _toy_params()
    part = fragments.partition_params(params, P)
    regions = fragments.fragment_regions(part, params)
    assert len(regions) == P
    for p in range(P):
        assert tuple(r.elems for r in regions[p]) == \
            tuple(part.region_sizes[p])
    # every region take/put roundtrips and covers each element once
    leaves = jax.tree_util.tree_leaves(params)
    covered = [np.zeros(l.shape, np.int32) for l in leaves]
    for regs in regions:
        for r in regs:
            flat = fragments.region_take(leaves[r.leaf], r)
            assert flat.shape == (r.elems,)
            zero = jnp.zeros_like(leaves[r.leaf])
            put = fragments.region_put(zero, r, flat)
            got = np.asarray(fragments.region_take(put, r))
            np.testing.assert_array_equal(got, np.asarray(flat))
            ones = fragments.region_put(
                jnp.zeros_like(leaves[r.leaf]), r, jnp.ones((r.elems,)))
            covered[r.leaf] += np.asarray(ones, np.int32)
    for c in covered:
        np.testing.assert_array_equal(c, np.ones_like(c))


def test_region_take_with_leading_replica_axis():
    params = _toy_params()
    part = fragments.partition_params(params, 2)
    regions = fragments.fragment_regions(part, params)
    leaf = jnp.stack([params["stack_w"], params["stack_w"] + 100.0])
    for regs in regions:
        for r in regs:
            if r.leaf == 1 and r.start is not None:  # stack_w band
                flat = fragments.region_take(leaf, r, lead_axes=1)
                assert flat.shape == (2, r.elems)
                back = fragments.region_put(
                    jnp.zeros_like(leaf), r, flat, lead_axes=1)
                np.testing.assert_array_equal(
                    np.asarray(fragments.region_take(back, r,
                                                     lead_axes=1)),
                    np.asarray(flat))


# ---------------------------------------------------------------------------
# donated-carry aliasing regression (satellite 3): every state-building
# path hands the donated jit FRESH buffers
# ---------------------------------------------------------------------------


def _donate_all(tree):
    """Donate every leaf of ``tree`` to a trivial jit (the scanned
    driver's donation pattern) — any leaf aliasing a caller buffer
    deletes that buffer."""
    f = jax.jit(lambda t: jax.tree.map(lambda x: x * 1, t),
                donate_argnums=0)
    return f(tree)


def _assert_alive(tree):
    for leaf in jax.tree_util.tree_leaves(tree):
        np.asarray(leaf)  # raises RuntimeError if deleted


def test_adamw_init_master_is_fresh_even_when_astype_is_identity():
    """Mixed policy with f32 incoming params: the f32 master would be
    an alias under ``astype`` (same dtype ⇒ identity) — ``init`` must
    copy so donating the state leaves the caller's params alive."""
    params = {"w": jnp.arange(12.0).reshape(3, 4)}
    pol = precision.make_policy("bfloat16", "float32")
    st = adamw.init(params, policy=pol)
    _donate_all(st)
    _assert_alive(params)


def test_shard_stream_state_is_fresh_even_when_device_put_is_identity():
    """``jax.device_put`` returns its argument unchanged when the leaf
    already carries the target sharding — re-placing an already-sharded
    state must still hand back fresh buffers (donating the result would
    otherwise delete the caller's state)."""
    params = {"w": jnp.arange(64.0).reshape(8, 8)}
    dcfg = DiLoCoConfig(k=2, H=4, streaming_fragments=2,
                        transport="sharded")
    mesh = make_mesh((2, 4), ("pod", "data"))
    state = streaming.init_state(params, dcfg)
    placed = pod_collectives.shard_stream_state(state, mesh)
    # second placement: every device_put is now the identity
    placed2 = pod_collectives.shard_stream_state(placed, mesh)
    for a, b in zip(jax.tree_util.tree_leaves(placed),
                    jax.tree_util.tree_leaves(placed2)):
        assert a is not b
    _donate_all(placed2)
    _assert_alive(placed)
    _assert_alive(params)


def test_precision_cast_fresh_survives_donation():
    """``cast_tree(..., fresh=True)`` (the pretrain handoff path) must
    copy even when the cast is the identity."""
    params = {"w": jnp.arange(6.0)}
    work = precision.cast_tree(params, jnp.float32, fresh=True)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(work)):
        assert a is not b
    _donate_all(work)
    _assert_alive(params)
    # the plain cast IS the identity for matching dtypes — the very
    # footgun fresh=True exists for
    alias = precision.cast_tree(params, jnp.float32)
    assert jax.tree_util.tree_leaves(alias)[0] is \
        jax.tree_util.tree_leaves(params)[0]


def test_stream_init_state_survives_donation():
    """streaming.init_state (global copy, replica broadcast, zeros)
    must never alias the caller's params."""
    params = {"w": jnp.arange(12.0).reshape(3, 4)}
    dcfg = DiLoCoConfig(k=2, H=4, streaming_fragments=2,
                        outer_grad_dtype="int4", error_feedback=True)
    st = streaming.init_state(params, dcfg)
    _donate_all(st)
    _assert_alive(params)


# ---------------------------------------------------------------------------
# CI claims gate (satellite 4)
# ---------------------------------------------------------------------------


def _load_check_claims():
    """benchmarks/ is not a package on sys.path under pytest — load
    the gate script by file path."""
    path = (pathlib.Path(__file__).resolve().parents[1]
            / "benchmarks" / "check_claims.py")
    spec = importlib.util.spec_from_file_location("check_claims", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_claims_gate(tmp_path):
    cc = _load_check_claims()

    bench = {"claims": {"a_true": True, "b_true": True}}
    (tmp_path / "BENCH_x.json").write_text(json.dumps(bench))
    claims = cc.load_claims(str(tmp_path))
    assert claims == {"BENCH_x.json": bench["claims"]}

    # all true + manifest satisfied -> no errors
    manifest = {"BENCH_x.json": ["a_true", "b_true"]}
    assert cc.check(claims, manifest) == []

    # a false claim fails
    bad = {"BENCH_x.json": {"a_true": False}}
    assert any("'a_true'" in e for e in cc.check(bad, {}))

    # a manifested claim that disappeared fails
    assert any("disappeared" in e for e in cc.check(
        {"BENCH_x.json": {"a_true": True}}, manifest))

    # a manifested FILE that disappeared fails
    assert any("missing" in e for e in cc.check({}, manifest))

    # unmanifested claims are reported (for --update-manifest)
    assert cc.unmanifested(claims, {}) == \
        ["BENCH_x.json: 'a_true'", "BENCH_x.json: 'b_true'"]

    # informational entries are recorded but never gated — a falsy
    # value (e.g. a CPU-emulated bf16 latency row) does not fail, and
    # the manifest still sees the key as present
    info = {"BENCH_x.json": {
        "a_true": True,
        "cpu_latency": {"value": False, "informational": True,
                        "backend": "cpu"}}}
    assert cc.informational(info["BENCH_x.json"]["cpu_latency"])
    assert not cc.informational(True)
    assert cc.check(info, {"BENCH_x.json": ["a_true",
                                            "cpu_latency"]}) == []


def test_claims_gate_main(tmp_path):
    cc = _load_check_claims()

    (tmp_path / "BENCH_ok.json").write_text(
        json.dumps({"claims": {"fine": True}}))
    man = tmp_path / "manifest.json"
    man.write_text(json.dumps({"BENCH_ok.json": ["fine"]}))
    assert cc.main(["--root", str(tmp_path),
                    "--manifest", str(man)]) == 0
    # flip the claim -> exit 1
    (tmp_path / "BENCH_ok.json").write_text(
        json.dumps({"claims": {"fine": False}}))
    assert cc.main(["--root", str(tmp_path),
                    "--manifest", str(man)]) == 1
    # --update-manifest merges but never drops
    (tmp_path / "BENCH_ok.json").write_text(
        json.dumps({"claims": {"fine": True, "extra": True}}))
    assert cc.main(["--root", str(tmp_path), "--manifest", str(man),
                    "--update-manifest"]) == 0
    merged = json.loads(man.read_text())
    assert sorted(merged["BENCH_ok.json"]) == ["extra", "fine"]
