"""Batched serving driver: prefill a batch of prompts, then decode.

The inference-time half of the paper's claim ("the resulting model has
the same size and speed as a model trained in fully synchronous mode"):
a DiLoCo-trained checkpoint serves exactly like any other — the server
is architecture-agnostic (every assigned arch works via the registry)
and uses the same prefill/decode entry points the dry-run lowers onto
the production mesh.

Example:
  PYTHONPATH=src python -m repro.launch.serve \
      --arch zamba2_2_7b --smoke --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.models.registry import get_arch, get_smoke_arch


def greedy_decode(arch, params, prompts, *, gen: int, extra=None,
                  temperature: float = 0.0, seed: int = 0):
    """prompts: (B, S) int32. Returns (B, gen) int32 generated tokens."""
    B, S = prompts.shape
    cfg = arch.cfg
    batch = {"tokens": prompts}
    if extra:
        batch.update(extra)
    logits, cache = arch.prefill(params, batch, cache_len=S + gen)
    jit_decode = jax.jit(
        lambda p, c, t, pos: arch.decode(p, c, t, pos))

    key = jax.random.PRNGKey(seed)
    # the FIRST generated token comes from the prefill logits and must
    # obey the same sampling policy as the rest (it used to always be
    # argmax, silently ignoring temperature at position 0)
    if temperature > 0:
        key, sub = jax.random.split(key)
        tok = jax.random.categorical(
            sub, logits[:, -1] / temperature, -1).astype(jnp.int32)[:, None]
    else:
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    out = [tok]
    for i in range(gen - 1):
        logits, cache = jit_decode(params, cache, tok,
                                   jnp.asarray(S + i, jnp.int32))
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits[:, -1] / temperature, -1
            ).astype(jnp.int32)[:, None]
        else:
            tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def run(args):
    arch = (get_smoke_arch if args.smoke else get_arch)(args.arch)
    cfg = arch.cfg
    key = jax.random.PRNGKey(args.seed)
    params, _ = arch.init(key, cfg)
    packed = None
    if args.packed_checkpoint:
        packed = ckpt.load_packed(args.packed_checkpoint)
        print("loaded packed weights", args.packed_checkpoint,
              f"({packed['manifest']['packed_bytes']} bytes, "
              f"{packed['manifest']['dtype']})")
    elif args.checkpoint:
        params = ckpt.restore(args.checkpoint, {"params": params})["params"]
        print("restored", args.checkpoint)

    B, S = args.batch, args.prompt_len
    prompts = jax.random.randint(jax.random.fold_in(key, 1), (B, S), 0,
                                 cfg.vocab_size, jnp.int32)
    extra = {}
    if cfg.family == "vlm":
        extra["patches"] = 0.1 * jax.random.normal(
            jax.random.fold_in(key, 2), (B, cfg.n_patches, cfg.d_model))
    if cfg.family == "encdec":
        extra["frames"] = 0.1 * jax.random.normal(
            jax.random.fold_in(key, 2), (B, cfg.n_frames, cfg.d_model))

    t0 = time.time()
    if args.continuous:
        from repro.launch.batching import ContinuousBatcher
        ps = args.page_size
        clen = S + args.gen
        if not args.contiguous_cache:   # paged ring must tile exactly
            clen = -(-clen // ps) * ps
        eng = ContinuousBatcher(
            arch, params, slots=B, cache_len=clen,
            temperature=args.temperature, seed=args.seed,
            paged=not args.contiguous_cache, page_size=args.page_size,
            packed_weights=packed)
        rids = [eng.submit(np.asarray(prompts[i]), args.gen)
                for i in range(B)]
        done = eng.run_until_drained()
        toks = jnp.asarray(np.stack([done[r] for r in rids]))
    else:
        if packed is not None:
            params = ckpt.unpack_params(
                {k: jnp.asarray(v) for k, v in packed["buffers"].items()},
                manifest=packed["manifest"], example_tree=params)
        toks = greedy_decode(arch, params, prompts, gen=args.gen,
                             extra=extra, temperature=args.temperature,
                             seed=args.seed)
        toks.block_until_ready()
    dt = time.time() - t0
    total = B * args.gen
    print(f"arch={args.arch} batch={B} prompt={S} gen={args.gen} "
          f"-> {total} tokens in {dt:.2f}s ({total / dt:.1f} tok/s, "
          f"first batch includes compile)")
    print("sample tokens[0,:16]:", np.asarray(toks[0, :16]))
    return toks


def make_parser():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="diloco_150m")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--packed-checkpoint", default="",
                    help="int4 packed-weights checkpoint "
                         "(checkpoint.save_packed)")
    ap.add_argument("--continuous", action="store_true",
                    help="serve through the continuous-batching engine "
                         "instead of one static batch")
    ap.add_argument("--contiguous-cache", action="store_true",
                    help="with --continuous: seed per-slot ring rows "
                         "instead of the paged pool")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    return ap


if __name__ == "__main__":
    run(make_parser().parse_args())
