"""Run telemetry demo: three tiny DiLoCo runs, three Chrome traces.

The same driver (``launch/train.py``) records every run through the
unified ``obs.metrics.RunRecorder`` schema and — with ``--trace`` —
maps the tick-domain world onto Chrome trace-event JSON:

  trace_sync.json    barrier-paced rounds under a fault scenario:
                     heterogeneous worker speeds, link latencies and a
                     mid-run preemption. One lane per worker; round
                     spans annotated with loss/ppl; outer sends pay
                     their link latency; the preempted worker's gap is
                     drawn as a fault span.
  trace_async.json   the barrier-free engine on the SAME scenario:
                     inner phases, per-send retries (dropped-send
                     instants), in-flight transfer spans that close at
                     the tick the delta is applied, and lost sends.
  trace_gossip.json  pairwise partial averaging: per-round exchange
                     markers on both endpoints of every realized edge
                     (butterfly pairing), one fragment per round.
  trace_overlap.json overlapped streaming on the sharded transport:
                     int4 packed wire, τ=1 — each fragment lane shows
                     the scheduled gather span (snapshot → merge) PLUS
                     the HLO-measured "consume (measured)" marker at
                     the offset where the lowered program actually
                     consumes the in-flight collective, τ inner steps
                     after issue.

Open any of them at https://ui.perfetto.dev (or chrome://tracing) —
or validate structurally:

  PYTHONPATH=src python -m repro.obs.trace /tmp/trace_*.json

Run:  PYTHONPATH=src python examples/trace_run.py [--outdir DIR]
"""
import argparse
import json
import os

# the overlap demo needs a pod mesh — force 8 host devices before jax
# initializes (no-op when XLA_FLAGS is already pinned)
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

from repro.launch import train

FAULTS = ["--speeds", "1,2,1,3", "--link-latency", "1,1,2,1",
          "--max-retries", "1", "--preempt", "2:4:8"]
BASE = ["--arch", "diloco_60m", "--k", "4", "--H", "4", "--rounds",
        "3", "--batch", "4", "--seq", "32", "--eval-batch", "8"]

RUNS = {
    "sync": FAULTS,
    "async": ["--transport", "async", "--ticks", "12", *FAULTS],
    "gossip": ["--transport", "gossip", "--stream-fragments", "2"],
    "overlap": ["--transport", "sharded", "--stream-fragments", "2",
                "--stream-tau", "1", "--stream-alpha", "0.5",
                "--outer-grad-dtype", "int4", "--k", "2",
                "--pods", "2"],
}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="/tmp")
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)
    for name, extra in RUNS.items():
        path = os.path.join(args.outdir, f"trace_{name}.json")
        print(f"=== {name} -> {path} ===")
        train.run(train.make_parser().parse_args(
            BASE + extra + ["--trace", path]))
        with open(path) as f:
            trace = json.load(f)
        spans = sum(1 for e in trace["traceEvents"]
                    if e.get("ph") == "X")
        print(f"    {len(trace['traceEvents'])} events, {spans} spans\n")
    print(f"open the traces at https://ui.perfetto.dev "
          f"(files in {args.outdir})")


if __name__ == "__main__":
    main()
