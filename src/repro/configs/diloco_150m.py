"""The paper's 150M Chinchilla-style transformer (Table 1): 12L,
hidden 896, 16 heads, K/V size 64, vocab 32000."""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="diloco-150m", family="dense",
        n_layers=12, d_model=896, n_heads=16, n_kv_heads=16,
        head_dim=64, d_ff=3584, vocab_size=32_000,
        pos_emb="rope", norm="rmsnorm", act="silu", mlp_gated=True,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="diloco-150m-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=4, head_dim=32, d_ff=256, vocab_size=256,
        attn_chunk=64)
