"""Tree checkpointing: flat-key npz arrays + json metadata.

Supports saving/restoring arbitrary pytrees of arrays (params, optimizer
states, DiLoCo state) with structure recovered from a like-structured
example tree. Writes are atomic (tmp + rename).
"""
from __future__ import annotations

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "//"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(_path_str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(path: str, tree, metadata: dict | None = None) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    flat = _flatten(tree)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)),
                               suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    if metadata is not None:
        with open(path + ".meta.json", "w") as f:
            json.dump(metadata, f, indent=2, default=str)


def restore(path: str, example_tree):
    """Restore into the structure of ``example_tree``."""
    with np.load(path) as data:
        flat_example, treedef = jax.tree_util.tree_flatten_with_path(
            example_tree)
        leaves = []
        for p, ex in flat_example:
            key = _SEP.join(_path_str(q) for q in p)
            if key not in data:
                raise KeyError(f"checkpoint missing key {key!r}")
            arr = data[key]
            if tuple(arr.shape) != tuple(np.shape(ex)):
                raise ValueError(
                    f"shape mismatch for {key}: ckpt {arr.shape} vs "
                    f"example {np.shape(ex)}")
            leaves.append(jnp.asarray(arr, dtype=ex.dtype
                                      if hasattr(ex, "dtype") else None))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_metadata(path: str) -> dict:
    with open(path + ".meta.json") as f:
        return json.load(f)
