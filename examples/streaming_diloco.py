"""Streaming DiLoCo example: fragment-scheduled outer sync with
overlap and quantized transport.

Trains the same reduced model twice — classic synchronous DiLoCo
(every-H-steps full-model outer step) and streaming DiLoCo
(P fragments synced on a staggered schedule, applies delayed τ inner
steps to model an in-flight collective, outer gradients sent as int4) —
and prints the loss trajectories next to the wire-bytes profile each
run would put on a real interconnect.

  PYTHONPATH=src python examples/streaming_diloco.py

--sharded swaps the simulated transport for the REAL pod-axis
collective path (core/pod_collectives.py): each replica on its own
"pod" mesh slice, every fragment reduced by a cross-pod collective
from inside the scanned jit. Needs >= k devices, e.g.:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/streaming_diloco.py --sharded

The same knobs are available on the training CLI:

  PYTHONPATH=src python -m repro.launch.train \
      --arch diloco_150m --smoke --k 4 --H 20 --rounds 10 \
      --stream-fragments 4 --stream-alpha 0.5 --stream-tau 2 \
      --outer-grad-dtype int4
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DiLoCoConfig, TrainConfig
from repro.core import diloco, fragments, streaming
from repro.data.sharding import make_regime
from repro.kernels.ops import transport_bytes
from repro.models.registry import get_smoke_arch

ap = argparse.ArgumentParser()
ap.add_argument("--k", type=int, default=4)
ap.add_argument("--H", type=int, default=10)
ap.add_argument("--rounds", type=int, default=8)
ap.add_argument("--fragments", type=int, default=4)
ap.add_argument("--alpha", type=float, default=0.5)
ap.add_argument("--tau", type=int, default=2)
ap.add_argument("--wire-dtype", default="int4",
                choices=["float32", "bfloat16", "int4"],
                help="transport precision of outer gradients")
ap.add_argument("--sharded", action="store_true",
                help="real pod-axis collectives on a (pod, data) mesh "
                     "(one replica band per pod; needs >= k devices)")
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=64)
args = ap.parse_args()

arch = get_smoke_arch("diloco_150m")
loss_fn = lambda p, b: arch.loss(p, b)
sampler = make_regime("non_iid", k=args.k,
                      vocab_size=arch.cfg.vocab_size)
total = args.rounds * args.H
tcfg = TrainConfig(inner_lr=3e-3, warmup_steps=20, total_steps=total,
                   batch_size=args.batch, seq_len=args.seq)
params, _ = arch.init(jax.random.PRNGKey(0), arch.cfg)
n_params = sum(l.size for l in jax.tree.leaves(params))
val = sampler.sample_validation(jax.random.PRNGKey(42), 64, args.seq)

configs = {
    "sync": DiLoCoConfig(k=args.k, H=args.H),
    "stream": DiLoCoConfig(
        k=args.k, H=args.H, streaming_fragments=args.fragments,
        stream_alpha=args.alpha, stream_tau=args.tau,
        outer_grad_dtype=args.wire_dtype,
        transport="sharded" if args.sharded else "simulated"),
}

mesh = None
if args.sharded:
    from repro.core import pod_collectives
    from repro.launch.mesh import make_pod_mesh
    n_dev = len(jax.devices())
    if n_dev < args.k or n_dev % args.k != 0:
        raise SystemExit(
            f"--sharded wants one pod per replica: {args.k} replicas "
            f"need a device count that is a multiple of {args.k}, "
            f"got {n_dev}. On a CPU host set XLA_FLAGS=--xla_force_"
            "host_platform_device_count="
            f"{args.k * max(1, -(-8 // args.k))} (before jax starts) "
            "— a smaller mesh would silently run zero real cross-pod "
            "collectives")
    mesh = make_pod_mesh(args.k)

histories = {}
for name, dcfg in configs.items():
    sharded = getattr(dcfg, "transport", "simulated") == "sharded"
    run = diloco.make_run(loss_fn, sampler.sample_all_shards, dcfg,
                          tcfg, rounds_per_call=args.rounds,
                          total_steps=total, batch_size=args.batch,
                          seq_len=args.seq, eval_tokens=val,
                          eval_every=1, mesh=mesh if sharded else None)
    state = (streaming.init_state(params, dcfg)
             if dcfg.streaming_fragments
             else diloco.init_state(params, dcfg))
    if sharded:
        state = pod_collectives.shard_stream_state(state, mesh)
    state, ms = run(state, jax.random.PRNGKey(7))
    histories[name] = np.asarray(ms["val_loss"])

print(f"\nmodel: {arch.cfg.name} ({n_params / 1e6:.2f}M params), "
      f"k={args.k} H={args.H} rounds={args.rounds}")
print(f"streaming: P={args.fragments} alpha={args.alpha} "
      f"tau={args.tau} wire={args.wire_dtype} "
      f"transport={'sharded' if args.sharded else 'simulated'}\n")
print(f"{'round':>5s} {'sync val':>10s} {'stream val':>11s}")
for t in range(args.rounds):
    print(f"{t + 1:5d} {histories['sync'][t]:10.4f} "
          f"{histories['stream'][t]:11.4f}")

part = fragments.partition_params(params, args.fragments)
sync_peak = transport_bytes(n_params, "float32")
# exact wire bytes: int4's f32 scales charged per contiguous leaf
# region (matches benchmarks/streaming.py and BENCH_streaming.json)
stream_peak = max(sum(transport_bytes(e, args.wire_dtype) for e in regs)
                  for regs in part.region_sizes)
print(f"\nwire profile (per replica):")
print(f"  sync   : 1 × {sync_peak / 1e6:8.2f} MB per round "
      f"(full model, f32, blocking barrier)")
print(f"  stream : {args.fragments} × ≤{stream_peak / 1e6:8.2f} MB per "
      f"round ({args.wire_dtype}, each with {args.tau} inner steps of "
      f"overlap)")
print(f"  peak bytes-per-sync reduction: "
      f"{sync_peak / stream_peak:.1f}x")

if args.sharded:
    # lower one sharded round and read the cross-pod bytes off the
    # compiled HLO — the MEASURED column is what the collectives
    # really ship; the static columns are models of it
    from repro.launch import hlo_analysis as H_hlo
    dcfg = configs["stream"]
    run1 = diloco.make_run(loss_fn, sampler.sample_all_shards, dcfg,
                           tcfg, rounds_per_call=1, total_steps=total,
                           batch_size=args.batch, seq_len=args.seq,
                           donate=False, mesh=mesh)
    st1 = pod_collectives.shard_stream_state(
        streaming.init_state(params, dcfg), mesh)
    hlo = run1.lower(st1, jax.random.PRNGKey(7)).compile().as_text()
    cpp = len(jax.devices()) // pod_collectives.pods_of(mesh)
    coll = H_hlo.collective_stats(hlo, chips_per_pod=cpp)
    per_round = {
        dt: sum(transport_bytes(e, dt) for regs in part.region_sizes
                for e in regs) for dt in ("float32", "bfloat16", "int4")}
    packed = {dt: sum(transport_bytes(e, dt, packed=True)
                      for regs in part.region_sizes for e in regs)
              for dt in ("float32", "bfloat16", "int4")}
    # quantized wire: count the all-gather share only (the same
    # quantity the BENCH gate checks — metric pmeans are not wire) and
    # divide by k (gathered results stack all k replicas); the f32
    # psum's result is already fragment-sized (one reduced copy)
    meas = (coll.cross_by_op.get("all-gather", 0) / args.k
            if args.wire_dtype != "float32" else coll.cross_pod_bytes)
    print(f"\ncross-pod bytes per replica per round "
          f"(k={args.k}, {pod_collectives.pods_of(mesh)} pods):")
    print(f"  {'wire dtype':>10s} {'legacy model':>14s} "
          f"{'packed model':>14s} {'HLO-measured':>14s}")
    for dt in ("float32", "bfloat16", "int4"):
        m = f"{meas:14.0f}" if dt == args.wire_dtype else f"{'-':>14s}"
        print(f"  {dt:>10s} {per_round[dt]:14.0f} {packed[dt]:14.0f}"
              f" {m}")
    print("  (HLO-measured is REAL — the lowered round's pod-crossing "
          "all-gather bytes (psum for f32);\n   the model columns are "
          "static accounting. packed == measured is the PR gate.)")
