"""Crash-grade recovery: durable checkpoints, anomaly guard, harness.

The subsystem has three legs (ISSUE 10):

- ``manager``     — CheckpointManager: atomic npz snapshots with a
                    per-leaf sha256 manifest, retention, and a resume
                    picker that skips truncated/corrupt files.
- ``state_codec`` — wraps any transport's carry (DiLoCoState /
                    StreamState / GossipState / the async engine's
                    tree) together with the host RNG key and the round
                    cursor into one checkpointable pytree, and hashes
                    trees for bit-identity gates.
- ``guard``       — host-side rolling loss statistics with a spike
                    detector and the rollback-and-skip escalation
                    verdicts (the in-graph NaN/Inf rejection lives in
                    ``core.diloco.outer_step`` under
                    ``dcfg.guard_outer``).
- ``harness``     — subprocess driver for crash/corrupt experiments
                    (SIGKILL a live run, corrupt its newest snapshot,
                    relaunch with ``--resume auto``).
"""
from . import guard, harness, manager, state_codec  # noqa: F401
from .guard import AnomalyGuard, GuardConfig  # noqa: F401
from .manager import CheckpointManager  # noqa: F401
from .state_codec import leaf_hashes, tree_sha256, unwrap, wrap  # noqa: F401
