"""Run the full benchmark suite: one module per paper table/figure,
plus the roofline aggregation over the dry-run records.

  PYTHONPATH=src python -m benchmarks.run [--only NAME] [--scale N]

Each module writes results/bench/<name>.json with a ``claims`` dict of
named booleans validating the paper's qualitative findings at micro
scale; this driver prints a pass/fail summary and exits non-zero if a
claim fails.
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time
import traceback

MODULES = [
    "table2_tradeoffs",       # main result (Fig 2 / Table 2)
    "fig3_pretraining",
    "fig4_comm_frequency",
    "fig5_data_regimes",
    "fig6_outer_optimizers",
    "fig7_adaptive_compute",
    "fig8_async_drop",
    "fig9_single_worker",
    "table3_replicas",
    "table6_pruning",
    "fig10_cosine_similarity",
    "async_sync",             # barrier-free transports (async + gossip)
    "beyond_async",           # superseded wrapper over async_sync
    "roofline",               # §Roofline aggregation over dry-run JSON
    "wallclock",              # perf: scanned driver vs legacy loop
    "streaming",              # comm: fragment-scheduled outer sync
]


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default="")
    ap.add_argument("--scale", type=int, default=1,
                    help="round multiplier (bigger = closer to paper)")
    args = ap.parse_args(argv)

    mods = [m for m in MODULES if not args.only or args.only in m]
    results, failed = {}, []
    for name in mods:
        t0 = time.time()
        print(f"=== {name} ===", flush=True)
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            out = mod.run(args.scale)
        except Exception:
            traceback.print_exc()
            failed.append((name, "exception"))
            continue
        claims = out.get("claims", {})
        for cname, ok in claims.items():
            if isinstance(ok, bool):
                flag = "PASS" if ok else "FAIL"
                if not ok:
                    failed.append((name, cname))
                print(f"  [{flag}] {cname}")
            else:
                print(f"  [info] {cname} = "
                      + (f"{ok:.1f}" if isinstance(ok, float) else
                         str(ok)))
        results[name] = claims
        print(f"  ({time.time() - t0:.1f}s)", flush=True)

    print("\n=== SUMMARY ===")
    n_claims = sum(len(c) for c in results.values())
    print(f"{len(results)}/{len(mods)} benchmarks ran, "
          f"{n_claims} claims checked, {len(failed)} failed")
    for name, cname in failed:
        print(f"  FAILED: {name} :: {cname}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
