"""Gossip outer sync: NoLoCo-style pairwise partial averaging
(cf. arXiv 2506.10911) — the transport tier with NO collective that
spans all k workers.

Synchronous DiLoCo's outer step is one all-reduce over every replica:
a single straggler or lost link stalls the fleet. The gossip transport
removes the global collective entirely:

  * every worker keeps its OWN estimate g_i of the global parameters
    and its own outer Nesterov state;
  * each round, the worker applies its own outer gradient
    d_i = g_i − θ_i through its own momentum buffer — a purely local
    update, no wire at all;
  * the only communication is ONE pairwise exchange per worker per
    round: i receives partner j's fresh estimate and partially adopts
    it on the round's scheduled fragment,
        g_i ← g_i + mix · mask_p · (g_j − g_i),
    so per-round wire bytes are fragment-sized and point-to-point.

Pairings (``dcfg.gossip_pairing``):

  butterfly  partner(i, t) = i XOR 2^(t mod log2 k) — pairwise
             exchanges along hypercube dimensions. With mix=0.5 and a
             full-tree fragment, log2(k) consecutive rounds mix ANY
             initial disagreement to the exact global mean: averaging
             along dimension b equalizes every pair differing only in
             bit b, and induction over dimensions reaches the mean of
             all 2^L values — the proven mixing schedule (tested
             exactly in tests/test_gossip.py). Requires k a power of 2.
  random     a fresh uniform perfect matching each round (odd k leaves
             one worker unpaired); mixes in expectation — the NoLoCo
             setting.

Fragment scheduling reuses ``core/fragments.py``: with
``streaming_fragments = P > 1`` round t exchanges only fragment
(t mod P) — NoLoCo's partial parameter averaging — cutting per-round
bytes another P×. The exchanged payload takes a quantize→dequantize
round trip at ``outer_grad_dtype`` (float32 | bfloat16) through the
shared transport codec; int4 is rejected (absolute-parameter
quantization, unlike the zero-centered outer gradients the int4 path
was built for, is not meaningful at 4 bits).

Fault semantics (``core/faults.py`` round projections):
  drop_mask[i] = 0   worker i's link is down this round: every pair
                     containing i skips its exchange (both endpoints
                     keep their own estimate); i's LOCAL outer update
                     still applies — nothing was on the wire.
  active_mask[i] = 0 worker i is preempted: no inner steps, no local
                     update, no exchange for its pairs.

The round is signature-compatible with ``diloco._make_round_body`` and
plugs into ``make_round``/``make_run`` via ``transport="gossip"``;
``GossipState.global_params`` (the consensus mean of the k estimates)
makes it a drop-in for the drivers' eval hooks.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DiLoCoConfig, TrainConfig
from repro.optim import adamw, precision
from . import diloco, fragments, outer_opt


class GossipState(NamedTuple):
    """Gossip carry. Leaves of global_est / outer_state / replica_* all
    lead with the (k,) worker axis — there is no single global copy,
    only k estimates (``global_params`` exposes their consensus mean
    for eval and checkpoint readers)."""
    global_est: Any                # (k, ...) per-worker estimate g_i
    outer_state: outer_opt.OuterState   # (k, ...) leaves, (k,) count
    replica_params: Any            # (k, ...) working params θ_i
    inner_state: adamw.AdamWState  # (k, ...) AdamW moments (+ master)
    outer_t: jnp.ndarray           # round counter (drives the pairing)
    inner_steps_done: jnp.ndarray

    @property
    def global_params(self):
        """Consensus estimate: the mean over workers. Equals every g_i
        exactly once a butterfly sweep has fully mixed a quiescent
        fleet; the natural eval/checkpoint view otherwise."""
        return jax.tree.map(lambda g: g.mean(axis=0), self.global_est)


def validate(dcfg: DiLoCoConfig):
    k = dcfg.k
    if dcfg.gossip_pairing not in ("butterfly", "random"):
        raise ValueError(
            f"gossip_pairing must be butterfly|random, got "
            f"{dcfg.gossip_pairing!r}")
    if dcfg.gossip_pairing == "butterfly" and k & (k - 1):
        raise ValueError(
            f"butterfly pairing needs k a power of 2, got k={k} "
            "(use gossip_pairing='random')")
    if not 0.0 <= dcfg.gossip_mix <= 1.0:
        raise ValueError(f"gossip_mix must be in [0,1], got "
                         f"{dcfg.gossip_mix}")
    if dcfg.outer_grad_dtype == "int4":
        raise ValueError(
            "gossip exchanges absolute parameter estimates, not "
            "zero-centered outer gradients: int4 transport is not "
            "meaningful here (use float32 or bfloat16)")
    if dcfg.error_feedback:
        raise ValueError(
            "error_feedback applies to quantized outer-gradient "
            "transports; the gossip exchange has no residual to carry")
    if dcfg.prune_frac > 0:
        raise ValueError("prune_frac is not supported on the gossip "
                         "transport (deltas never cross the wire)")


def init_state(params, dcfg: DiLoCoConfig) -> GossipState:
    """Start gossip DiLoCo from ``params`` (cf. diloco.init_state):
    every worker begins with the same estimate and zero disagreement."""
    validate(dcfg)
    pol = precision.policy_of(dcfg)
    rep = diloco.broadcast_replicas(params, dcfg.k)
    inner = jax.vmap(lambda p: adamw.init(p, policy=pol))(rep)
    rep = precision.cast_tree(rep, pol.param_dtype)
    k = dcfg.k
    z = lambda p: jnp.zeros((k,) + p.shape, p.dtype)
    return GossipState(
        global_est=diloco.broadcast_replicas(params, k),
        outer_state=outer_opt.OuterState(
            buf=jax.tree.map(z, params), buf2=jax.tree.map(z, params),
            count=jnp.zeros((k,), jnp.int32)),
        replica_params=rep,
        inner_state=inner,
        outer_t=jnp.zeros((), jnp.int32),
        inner_steps_done=jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# pairing + mixing (the pure exchange step — proven exact in tests)
# ---------------------------------------------------------------------------

# fold_in tag deriving the round's pairing key from its round key —
# shared by the in-graph round body and the host-side telemetry view,
# so pairing_edges() reconstructs the EXACT edges the exchange used
PAIR_FOLD = 0x90551b


def partner_map(k: int, t, pairing: str, key=None):
    """(k,) int32 partner indices for round ``t``. An involution:
    partner[partner[i]] == i, with partner[i] == i meaning "sit out"
    (k=1, or the odd worker of a random matching). ``t`` may be a
    traced scalar (butterfly); random pairing draws from ``key``."""
    if k == 1:
        return jnp.zeros((1,), jnp.int32)
    idx = jnp.arange(k, dtype=jnp.int32)
    if pairing == "butterfly":
        L = k.bit_length() - 1              # log2(k), k a power of 2
        stage = jnp.asarray(t, jnp.int32) % L
        return idx ^ jnp.left_shift(jnp.int32(1), stage)
    if pairing == "random":
        perm = jax.random.permutation(key, k).astype(jnp.int32)
        m = k // 2
        partner = idx                        # odd worker: self
        partner = partner.at[perm[0:2 * m:2]].set(perm[1:2 * m:2])
        partner = partner.at[perm[1:2 * m:2]].set(perm[0:2 * m:2])
        return partner
    raise ValueError(pairing)


def pairing_edges(k: int, t: int, pairing: str,
                  round_key=None) -> tuple:
    """Host-side telemetry view of round ``t``'s exchange graph:
    sorted (i, j) pairs with i < j (self-paired workers sit out, so
    an odd random matching's leftover never appears). ``round_key``
    is the SAME per-round key the round body receives (the split-chain
    sub-key); the pairing key is derived from it with ``PAIR_FOLD``
    exactly as the in-graph exchange does, so the edges recorded are
    the edges realized — required for random pairing, ignored for
    butterfly (which is a pure function of t)."""
    key = None
    if pairing == "random":
        if round_key is None:
            raise ValueError("random pairing edges need the round key")
        key = jax.random.fold_in(round_key, PAIR_FOLD)
    pm = np.asarray(partner_map(k, t, pairing, key=key))
    return tuple(sorted({(min(i, int(pm[i])), max(i, int(pm[i])))
                         for i in range(k) if int(pm[i]) != i}))


def mix_round(est, partner, mask_tree, *, mix: float, ok=None,
              quant_dtype: str = "float32", kernel_mode: str = "ref",
              exchange=None):
    """One pairwise partial-averaging exchange on a (k, ...) estimate
    tree: every worker adopts ``mix`` of its partner's (transport-
    quantized) estimate on the masked region,

        g_i ← g_i + mix · ok_i · mask · (Q(g_partner[i]) − g_i).

    ``ok`` (k,) float gates each exchange (drop/inactive endpoints);
    ``mask_tree`` restricts it to the scheduled fragment (broadcastable
    per-leaf masks from ``fragments.partition_params``). Pure — the
    butterfly exactness proof runs directly on this function.

    ``exchange`` overrides the default ``jnp.take(payload, partner)``
    per-leaf with a custom (k, ...) -> (k, ...) permutation. It must
    realize the SAME partner map — it exists because a general take is
    opaque to the SPMD partitioner (it lowers to an all-gather of the
    whole worker axis), while a structured swap of a pod-sharded axis
    lowers to a pod permutation collective (see
    ``launch/dryrun.py::build_gossip_exchange``)."""
    k = jax.tree.leaves(est)[0].shape[0]
    ok = jnp.ones((k,), jnp.float32) if ok is None else ok
    gate = (ok * (partner != jnp.arange(k, dtype=jnp.int32))
            .astype(jnp.float32))

    def leaf(g, m):
        payload = g
        if quant_dtype != "float32":
            from repro.kernels import ops as kops
            payload = jax.vmap(
                lambda x: kops.quant_roundtrip(x, quant_dtype,
                                               mode=kernel_mode))(g)
        recv = (jnp.take(payload, partner, axis=0) if exchange is None
                else exchange(payload))
        sel = gate.reshape((k,) + (1,) * (g.ndim - 1))
        m = jnp.broadcast_to(jnp.asarray(m, g.dtype), g.shape[1:])
        return g + mix * sel * m[None] * (recv - g)

    return jax.tree.map(leaf, est, mask_tree)


def butterfly_swap(stage: int, k: int):
    """The butterfly stage-``stage`` partner exchange (i XOR 2^stage)
    as a structured reshape+flip of the worker axis — semantically
    identical to ``jnp.take(g, partner_map(k, stage, 'butterfly'))``
    (tested) but transparent to the SPMD partitioner: on a pod-sharded
    worker axis it lowers to a pairwise permutation collective instead
    of an all-worker gather."""
    B = 1 << int(stage)
    if k % (2 * B):
        raise ValueError(f"stage {stage} needs 2^{int(stage) + 1} | k, "
                         f"got k={k}")

    def swap(g):
        r = g.reshape((k // (2 * B), 2, B) + g.shape[1:])
        return jnp.flip(r, axis=1).reshape(g.shape)

    return swap


# ---------------------------------------------------------------------------
# the round
# ---------------------------------------------------------------------------

def make_gossip_round_body(loss_fn, sample_fn, dcfg: DiLoCoConfig,
                           tcfg: TrainConfig, *, total_steps=None,
                           compute_cosine: bool = False,
                           batch_size=None, seq_len=None, mesh=None):
    """Un-jitted gossip round, signature-compatible with
    ``diloco._make_round_body``: round_body(GossipState, key,
    drop_mask, active_mask, weights) -> (GossipState, metrics).

    ``weights`` is accepted for signature compatibility and ignored —
    there is no global average to weight. ``mesh`` must be None: the
    gossip tier is the simulated (replica-stacked) execution; on a pod
    mesh each exchange lowers to a pod-axis collective-permute (see
    launch/dryrun.py's gossip lowering)."""
    validate(dcfg)
    if mesh is not None:
        raise ValueError(
            "transport='gossip' runs replica-stacked (simulated); "
            "pod-sharded gossip is demonstrated by the dryrun lowering "
            "only — drop mesh=")
    if precision.policy_of(dcfg) != precision.policy_of(tcfg):
        raise ValueError(
            "DiLoCoConfig and TrainConfig precision policies disagree")
    inner_step_tok = diloco.make_inner_step(
        lambda p, b: loss_fn(p, b), tcfg, total_steps)
    B = batch_size or tcfg.batch_size
    S = seq_len or tcfg.seq_len
    k = dcfg.k
    P = max(1, int(dcfg.streaming_fragments))
    mode = getattr(dcfg, "kernel_mode", "ref")

    # fragment masks, stacked (P,)+leaf_shape per leaf so a traced
    # round index can select the scheduled fragment with one take.
    # Built lazily from the state's leaf shapes at first trace (the
    # round builder never sees a params example).
    mask_cache: list = []

    def _stacked_masks(global_est):
        if not mask_cache:
            example = jax.tree.map(
                lambda g: np.zeros(g.shape[1:], g.dtype), global_est)
            part = fragments.partition_params(
                example, P, overrides=dcfg.stream_overrides)
            # pure-numpy constants: this runs inside an active jit
            # trace, where any jnp op would produce (and leak) tracers
            mask_cache.append(jax.tree.map(
                lambda p, *ms: np.stack(
                    [np.broadcast_to(np.asarray(m, np.float32),
                                     p.shape) for m in ms]),
                example, *part.masks))
        return mask_cache[0]

    def round_body(state: GossipState, key, drop_mask=None,
                   active_mask=None, weights=None):
        del weights
        H = dcfg.H
        ones = jnp.ones((k,), jnp.float32)
        drop_mask = ones if drop_mask is None else drop_mask
        active_mask = ones if active_mask is None else active_mask

        keys = jax.random.split(key, H)
        toks = jax.vmap(lambda kk: sample_fn(kk, B, S))(keys)
        toks = jnp.swapaxes(toks, 0, 1)[:k]
        rp, is_, ms = diloco.inner_phase(
            inner_step_tok, state.replica_params, state.inner_state,
            {"tokens": toks}, state.inner_steps_done,
            active_mask=active_mask)

        # local outer update: d_i = g_i − θ_i through worker i's OWN
        # Nesterov state — no wire, full weight (each estimate
        # integrates only its own evidence; mixing spreads it)
        masters = is_.master
        rep_src = masters if masters is not None else rp
        deltas = jax.tree.map(lambda g, r: g - r.astype(g.dtype),
                              state.global_est, rep_src)

        def upd(d, st, g):
            return outer_opt.update(
                d, st, g, kind=dcfg.outer_opt, lr=dcfg.outer_lr,
                momentum=dcfg.outer_momentum, b2=dcfg.outer_adam_b2,
                eps=dcfg.outer_adam_eps, kernel_mode=mode)

        new_g, new_outer = jax.vmap(upd)(deltas, state.outer_state,
                                         state.global_est)
        sel = lambda n, o: jax.tree.map(
            lambda a, b: jnp.where(
                active_mask.reshape((k,) + (1,) * (a.ndim - 1)) > 0,
                a, b), n, o)
        new_g = sel(new_g, state.global_est)
        new_outer = outer_opt.OuterState(
            sel(new_outer.buf, state.outer_state.buf),
            sel(new_outer.buf2, state.outer_state.buf2),
            jnp.where(active_mask > 0, new_outer.count,
                      state.outer_state.count))

        # the exchange: partner's fresh estimate, scheduled fragment
        pair_key = jax.random.fold_in(key, PAIR_FOLD)
        partner = partner_map(k, state.outer_t, dcfg.gossip_pairing,
                              key=pair_key)
        comm = drop_mask * active_mask
        ok = comm * jnp.take(comm, partner)
        frag = state.outer_t % P
        mask_p = jax.tree.map(lambda sm: jnp.take(sm, frag, axis=0),
                              _stacked_masks(state.global_est))
        mixed = mix_round(new_g, partner, mask_p, mix=dcfg.gossip_mix,
                          ok=ok, quant_dtype=dcfg.outer_grad_dtype,
                          kernel_mode=mode)

        # re-dispatch: active workers adopt their own mixed estimate
        # (their local update never left the node — nothing to drop)
        pol = precision.policy_of(dcfg)
        adopt = active_mask
        new_rep = jax.tree.map(
            lambda g, r: jnp.where(
                adopt.reshape((k,) + (1,) * (g.ndim - 1)) > 0,
                g.astype(r.dtype), r), mixed, rp)
        new_inner = is_
        if masters is not None:
            new_masters = jax.tree.map(
                lambda g, w: jnp.where(
                    adopt.reshape((k,) + (1,) * (g.ndim - 1)) > 0,
                    g, w), mixed, masters)
            new_inner = is_._replace(master=new_masters)

        consensus = jax.tree.map(lambda g: g.mean(axis=0), mixed)
        spread = diloco._tree_norm(jax.tree.map(
            lambda g, c: g - c[None], mixed, consensus))
        metrics = {
            "inner_loss": ms["loss"].mean(),
            "inner_loss_last": ms["loss"][:, -1].mean(),
            "outer_gnorm": diloco._tree_norm(
                jax.tree.map(lambda d: d.mean(axis=0), deltas)),
            "drop_frac": 1.0 - drop_mask.mean(),
            "gossip_spread": spread,
            "gossip_frag": frag.astype(jnp.float32),
            "exchange_frac": ok.mean(),
        }
        return GossipState(
            global_est=mixed,
            outer_state=new_outer,
            replica_params=new_rep,
            inner_state=new_inner,
            outer_t=state.outer_t + 1,
            inner_steps_done=state.inner_steps_done + H), metrics

    return round_body


def frag_bytes(params, dcfg: DiLoCoConfig) -> list:
    """Per-fragment exchange bytes one worker RECEIVES per round (the
    pairwise payload: the partner's estimate restricted to the
    scheduled fragment, at the transport dtype)."""
    from repro.kernels import ops as kops
    P = max(1, int(dcfg.streaming_fragments))
    part = fragments.partition_params(params, P,
                                      overrides=dcfg.stream_overrides)
    return [kops.transport_bytes(int(n), dcfg.outer_grad_dtype)
            for n in part.sizes]
