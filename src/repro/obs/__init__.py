"""Run telemetry: unified metrics schema + tick-domain trace export.

Two layers, both transport-agnostic:

  * ``obs.metrics`` — ``RunRecorder``, ONE record schema for every
    transport's per-round / per-event history (replacing the divergent
    ad-hoc shapes the launch scripts used to invent), plus the run
    manifest that ships the static wire plan and HLO-measured profile
    alongside the records.
  * ``obs.trace`` — maps the tick-domain world the repo already
    computes (``faults.Scenario.timeline`` events, transfer in-flight
    windows, the streaming fragment schedule) onto Chrome trace-event
    JSON viewable in Perfetto.

Gated by ``benchmarks/obs.py`` → ``BENCH_obs.json``.
"""
from repro.obs import metrics, trace  # noqa: F401
