"""Serving scenario: batched generation from a DiLoCo-trained model.

Trains briefly with DiLoCo, checkpoints the global params, restores
them in a "server" and decodes a batch of prompts — demonstrating the
paper's inference-time claim: the DiLoCo model is a perfectly ordinary
checkpoint (same size/speed as synchronous training would produce).

Works with any registered architecture (--arch zamba2_2_7b serves the
hybrid SSM; --arch whisper_large_v3 the encoder-decoder, etc.).

  PYTHONPATH=src python examples/serve_checkpoint.py [--arch ID]
"""
import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import DiLoCoConfig, TrainConfig
from repro.core import diloco
from repro.data.sharding import make_regime
from repro.launch.serve import greedy_decode
from repro.models.registry import get_smoke_arch

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="stablelm_1_6b")
ap.add_argument("--rounds", type=int, default=4)
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--gen", type=int, default=16)
args = ap.parse_args()

arch = get_smoke_arch(args.arch)
cfg = arch.cfg
loss_fn = lambda p, b: arch.loss(p, b)
params, _ = arch.init(jax.random.PRNGKey(0), cfg)
sampler = make_regime("iid", k=4, vocab_size=cfg.vocab_size)

# --- train a little with DiLoCo and checkpoint the global copy ---
dcfg = DiLoCoConfig(k=4, H=10)
tcfg = TrainConfig(inner_lr=3e-3, warmup_steps=10, total_steps=40,
                   batch_size=8, seq_len=64)
state = diloco.init_state(params, dcfg)
rnd = diloco.make_round(loss_fn, sampler.sample_all_shards, dcfg, tcfg,
                        batch_size=8, seq_len=64)
key = jax.random.PRNGKey(1)
for t in range(args.rounds):
    key, sub = jax.random.split(key)
    state, m = rnd(state, sub)
    print(f"train round {t + 1}: inner {float(m['inner_loss']):.3f}")
path = "/tmp/diloco_serve_ckpt.npz"
ckpt.save(path, {"params": state.global_params})
print("saved", path)

# --- "server": restore and decode a batch ---
like = {"params": jax.tree.map(jnp.zeros_like, state.global_params)}
served = ckpt.restore(path, like)["params"]
prompts = sampler.sample_validation(jax.random.PRNGKey(7), args.batch,
                                    32)
extra = {}
if cfg.family == "vlm":
    extra["patches"] = 0.1 * jax.random.normal(
        jax.random.PRNGKey(8), (args.batch, cfg.n_patches, cfg.d_model))
if cfg.family == "encdec":
    extra["frames"] = 0.1 * jax.random.normal(
        jax.random.PRNGKey(8), (args.batch, cfg.n_frames, cfg.d_model))
toks = greedy_decode(arch, served, prompts, gen=args.gen, extra=extra)
print(f"decoded {args.batch}x{args.gen} tokens from the restored "
      f"checkpoint ({cfg.name}):")
print(np.asarray(toks))
