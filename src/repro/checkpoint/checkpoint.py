"""Tree checkpointing: flat-key npz arrays + json metadata.

Supports saving/restoring arbitrary pytrees of arrays (params, optimizer
states, DiLoCo state) with structure recovered from a like-structured
example tree. Writes are atomic (tmp + rename).
"""
from __future__ import annotations

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "//"

# npz cannot represent the ml_dtypes extension types (bfloat16 leaves
# of a mixed-precision state serialize as raw void bytes that nothing
# can cast back) — such leaves ride the wire as a uint16 bit-view, with
# their true dtype names recorded under this sentinel key.
_DTYPES_KEY = "__leaf_dtypes__"
_VIEW_OF = {"bfloat16": np.uint16}


def _flatten(tree) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(_path_str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _encode_extension_dtypes(flat: dict) -> dict:
    """Bit-view extension-typed arrays to a native dtype and append the
    ``_DTYPES_KEY`` manifest (absent when every leaf is native)."""
    names = []
    for key, arr in list(flat.items()):
        dt = str(arr.dtype)
        if dt in _VIEW_OF:
            flat[key] = arr.view(_VIEW_OF[dt])
            names.append(f"{key}={dt}")
    if names:
        flat[_DTYPES_KEY] = np.asarray(names)
    return flat


def _decode_leaf(data, key: str, views: dict) -> np.ndarray:
    arr = data[key]
    if key in views:
        arr = arr.view(jnp.dtype(views[key]))
    return arr


def _views_of(data) -> dict:
    if _DTYPES_KEY not in getattr(data, "files", ()):
        return {}
    return dict(s.rsplit("=", 1) for s in data[_DTYPES_KEY].tolist())


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(path: str, tree, metadata: dict | None = None) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    flat = _encode_extension_dtypes(_flatten(tree))
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)),
                               suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    if metadata is not None:
        with open(path + ".meta.json", "w") as f:
            json.dump(metadata, f, indent=2, default=str)


def restore(path: str, example_tree):
    """Restore into the structure of ``example_tree``."""
    with np.load(path) as data:
        views = _views_of(data)
        flat_example, treedef = jax.tree_util.tree_flatten_with_path(
            example_tree)
        leaves = []
        for p, ex in flat_example:
            key = _SEP.join(_path_str(q) for q in p)
            if key not in data:
                raise KeyError(f"checkpoint missing key {key!r}")
            arr = _decode_leaf(data, key, views)
            if tuple(arr.shape) != tuple(np.shape(ex)):
                raise ValueError(
                    f"shape mismatch for {key}: ckpt {arr.shape} vs "
                    f"example {np.shape(ex)}")
            leaves.append(jnp.asarray(arr, dtype=ex.dtype
                                      if hasattr(ex, "dtype") else None))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore_tree(path: str) -> dict:
    """Structure-free restore: rebuild a nested dict straight from the
    flat checkpoint keys, no example tree needed.

    Every path segment becomes a dict key — including list/tuple
    indices, which come back as ``"[i]"`` string keys — so the result
    is a dicts-only *view* of whatever tree was saved. Use it when the
    saved structure is dynamic (e.g. the async engine's live-snapshot
    table, whose version keys differ run to run); re-shape any subtree
    whose true structure you know with ``reshape_like``.
    """
    out: dict = {}
    with np.load(path) as data:
        views = _views_of(data)
        for key in data.files:
            if key == _DTYPES_KEY:
                continue
            node = out
            parts = key.split(_SEP)
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = jnp.asarray(_decode_leaf(data, key, views))
    return out


def reshape_like(tree, example):
    """Re-shape a dicts-only view (from ``restore_tree``) onto the real
    structure of ``example`` — NamedTuples, lists, custom nodes and
    all. Works because ``_path_str`` renders a dict key ``"[0]"`` and a
    list index 0 identically: the two trees flatten to the same flat
    keys, so leaves transfer by key and re-assemble under the example's
    treedef. Leaf dtypes follow the checkpoint (the example only
    supplies structure); shapes must match."""
    by_key = _flatten(tree)
    flat_ex, treedef = jax.tree_util.tree_flatten_with_path(example)
    leaves = []
    for p, ex in flat_ex:
        key = _SEP.join(_path_str(q) for q in p)
        if key not in by_key:
            raise KeyError(f"restored tree missing key {key!r}")
        arr = by_key[key]
        if tuple(np.shape(arr)) != tuple(np.shape(ex)):
            raise ValueError(
                f"shape mismatch for {key}: restored {np.shape(arr)} "
                f"vs example {np.shape(ex)}")
        leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_metadata(path: str) -> dict:
    with open(path + ".meta.json") as f:
        return json.load(f)
