"""Robustness scenarios, one per outer-sync transport.

  1. synchronous — every round each island's outer gradient is dropped
     with 30% probability (Fig 8) and the pool doubles halfway (Fig 7);
  2. async — barrier-free: heterogeneous speeds (1x/2x/4x), dropped
     transfers with one retry, a worker preempted mid-run; the run is
     cut at an arbitrary event, checkpointed, restored into a FRESH
     engine and finished — identically to the uninterrupted run;
  3. gossip — randomized pairwise partial averaging, no collective
     spanning the pool: half the exchanges masked out, training still
     proceeds and the workers stay in consensus;
  4. crash — a REAL training process is SIGKILL'd mid-run by an
     injected Crash event, then relaunched with ``--resume auto``: it
     picks the newest verified snapshot and finishes bit-identically
     to a run that was never killed.

  PYTHONPATH=src python examples/robustness_drop.py
"""
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import DiLoCoConfig, TrainConfig
from repro.core import async_diloco, diloco, faults, gossip, schedules
from repro.data.sharding import make_regime
from repro.models.registry import get_smoke_arch

K, H, ROUNDS, DROP = 8, 10, 12, 0.3
arch = get_smoke_arch("diloco_60m")
loss_fn = lambda p, b: arch.loss(p, b)
params, _ = arch.init(jax.random.PRNGKey(0), arch.cfg)
sampler = make_regime("non_iid", k=K, vocab_size=arch.cfg.vocab_size)
evaluate = diloco.make_eval(loss_fn)
val = sampler.sample_validation(jax.random.PRNGKey(42), 64, 64)

# --- 1. synchronous: drops + elastic pool -----------------------------
print("=== synchronous: 30% outer-grad drop + elastic pool ===")
dcfg = DiLoCoConfig(k=K, H=H, drop_prob=DROP)
tcfg = TrainConfig(inner_lr=3e-3, warmup_steps=10,
                   total_steps=ROUNDS * H, batch_size=8, seq_len=64)
state = diloco.init_state(params, dcfg)
round_fn = diloco.make_round(loss_fn, sampler.sample_all_shards, dcfg,
                             tcfg, batch_size=8, seq_len=64)
rng = np.random.default_rng(0)
drops = schedules.drop_masks(rng, DROP, K, ROUNDS)
key = jax.random.PRNGKey(1)
for t in range(ROUNDS):
    # elastic pool: 4 islands for the first half, 8 after
    n_active = 4 if t < ROUNDS // 2 else 8
    act = jnp.asarray(schedules.active_mask(n_active, K))
    key, sub = jax.random.split(key)
    state, m = round_fn(state, sub, jnp.asarray(drops[t]), act)
    ppl = np.exp(float(evaluate(state.global_params, val)))
    dropped = int(K - drops[t].sum())
    print(f"round {t + 1:2d}: {n_active} islands active, "
          f"{dropped} outer-grad(s) dropped -> val ppl {ppl:.1f}")

# --- 2. async: stragglers + drops + preempt, cut + restore ------------
print("\n=== async: stragglers, drops, preemption — checkpoint "
      "mid-run, restore, finish ===")
KA, TICKS = 4, 10
scen = faults.Scenario(speeds=(1, 1, 2, 4), drop_prob=0.2,
                       max_retries=1, preemptions=((1, 3, 6),), seed=7)
adcfg = DiLoCoConfig(k=KA, H=H, transport="async", staleness_lambda=0.7)
atcfg = TrainConfig(inner_lr=3e-3, warmup_steps=10,
                    total_steps=TICKS * H * KA, batch_size=8,
                    seq_len=64)
shard = tuple((lambda i: lambda kk, B, S: sampler.sample_shard(
    kk, i, B, S))(i) for i in range(KA))

eng = async_diloco.AsyncEngine(loss_fn, shard, adcfg, atcfg,
                               scenario=scen,
                               total_steps=TICKS * H * KA, seed=0)
astate = eng.init_state(params)
astate, hist1 = eng.run(astate, ticks=TICKS, max_events=5)
print(f"cut after {len(hist1)} events "
      f"(version {int(astate.version)}); checkpointing full state...")
path = "/tmp/robustness_async.npz"
ckpt.save(path, async_diloco.state_to_tree(astate))
del eng, astate                               # fresh-process stand-in

eng2 = async_diloco.AsyncEngine(loss_fn, shard, adcfg, atcfg,
                                scenario=scen,
                                total_steps=TICKS * H * KA, seed=0)
astate = async_diloco.state_from_tree(ckpt.restore_tree(path), params)
astate, hist2 = eng2.run(astate, ticks=TICKS)
for r in hist1 + hist2:
    if r["event"] == "arrival":
        print(f"tick {r['tick']:2d}: worker {r['worker']} delta applied"
              f" (staleness {r['staleness']}, weight {r['weight']:.3f})")
    else:
        print(f"tick {r['tick']:2d}: worker {r['worker']} {r['event']}")
ppl = np.exp(float(evaluate(astate.global_params, val)))
print(f"restored run finished: {int(astate.version)} applications, "
      f"val ppl {ppl:.1f} — same as the uninterrupted run would give "
      "(stable per-uid RNG + event cursor replay the suffix exactly).")

# --- 3. gossip: pairwise mixing with half the exchanges lost ----------
print("\n=== gossip: random pairwise averaging, 50% exchanges "
      "dropped ===")
gdcfg = DiLoCoConfig(k=KA, H=H, transport="gossip",
                     gossip_pairing="random", gossip_mix=0.5)
grun = diloco.make_run(loss_fn, sampler.sample_all_shards, gdcfg, atcfg,
                       rounds_per_call=ROUNDS,
                       total_steps=ROUNDS * H * KA, batch_size=8,
                       seq_len=64, eval_tokens=val, eval_every=3)
gstate = gossip.init_state(params, gdcfg)
gdrops = jnp.asarray(schedules.drop_masks(
    np.random.default_rng(3), 0.5, KA, ROUNDS))
gstate, ms = grun(gstate, jax.random.PRNGKey(2), gdrops, None, None)
for t in range(ROUNDS):
    vl = float(np.asarray(ms["val_loss"])[t])
    tail = (f"val ppl {np.exp(vl):.1f}" if np.isfinite(vl) else
            "(no eval this round)")
    print(f"round {t + 1:2d}: exchanged "
          f"{float(np.asarray(ms['exchange_frac'])[t]):.2f} of pairs, "
          f"consensus spread "
          f"{float(np.asarray(ms['gossip_spread'])[t]):.2e}  {tail}")
# --- 4. crash-grade: kill -9 a real process, auto-resume --------------
print("\n=== crash: SIGKILL a live training process, "
      "--resume auto ===")
from repro.resilience import harness  # noqa: E402

work = tempfile.mkdtemp(prefix="robustness_crash_")
ckdir = os.path.join(work, "ck")
flags = ["--arch", "diloco_60m", "--smoke", "--k", "4", "--H", "4",
         "--rounds", "6", "--batch", "4", "--seq", "32",
         "--eval-batch", "8", "--rounds-per-call", "3"]
clean_json = os.path.join(work, "clean.json")
resumed_json = os.path.join(work, "resumed.json")
try:
    print("uninterrupted reference run...")
    harness.run_train(flags + ["--state-hash-out", clean_json])
    print("crash-injected run (SIGKILL after round 3, snapshots "
          "every 2 rounds)...")
    proc = harness.run_until_crash(
        flags + ["--checkpoint-dir", ckdir, "--checkpoint-every", "2",
                 "--crash-at-round", "3"])
    print(f"  process died rc={proc.returncode} "
          f"(SIGKILL = {harness.SIGKILL_RC}); snapshots on disk: "
          f"{sorted(os.listdir(ckdir))}")
    print("relaunching with --resume auto...")
    harness.run_train(
        flags + ["--checkpoint-dir", ckdir, "--checkpoint-every", "2",
                 "--resume", "auto", "--state-hash-out", resumed_json])
    clean, resumed = (harness.read_json(clean_json),
                      harness.read_json(resumed_json))
    match = clean["state_sha256"] == resumed["state_sha256"]
    print(f"resumed from snapshot {resumed['resumed_from_step']}; "
          f"final val loss {resumed['final_val_loss']:.4f} vs clean "
          f"{clean['final_val_loss']:.4f}; state hashes "
          f"{'MATCH bit-for-bit' if match else 'DIFFER (bug!)'}")
    assert match, "resumed state diverged from the uninterrupted run"
finally:
    shutil.rmtree(work, ignore_errors=True)

print("\nno transport failed: sync islands kept training through "
      "drops,\nthe async engine survived preemption + restore, gossip "
      "converged\nwithout any collective spanning the pool, and a "
      "kill -9'd process\nresumed bit-identically from its snapshots.")
