"""Gossip transport: butterfly mixing exactness (the proven schedule),
the structured swap ≡ partner take, random-matching involutions, fault
gating, fragment scheduling, precision policies, state checkpointing,
and the full round through ``make_round``/``make_run``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import DiLoCoConfig, TrainConfig
from repro.core import diloco, gossip
from repro.kernels import ops as kops


def quad_loss(p, batch):
    t = batch["tokens"].astype(jnp.float32).mean() / 7.0
    return (jnp.sum((p["w"] - t) ** 2)
            + 0.1 * jnp.sum(jnp.square(p["b"]))), {}


def tiny_params():
    return {"w": jnp.arange(8.0) / 8.0, "b": jnp.ones((3,))}


def sample_all(k):
    def fn(key, B, S):
        return jax.random.randint(key, (k, B, S), 0, 7, jnp.int32)
    return fn


def make_cfgs(k=4, H=2, *, P=0, **dkw):
    dcfg = DiLoCoConfig(k=k, H=H, transport="gossip",
                        streaming_fragments=P, outer_lr=0.3, **dkw)
    tcfg = TrainConfig(inner_lr=0.05, warmup_steps=2, total_steps=64,
                       batch_size=2, seq_len=4)
    return dcfg, tcfg


# ---------------------------------------------------------------------------
# pairing + mixing (pure functions)
# ---------------------------------------------------------------------------

def test_butterfly_mixes_to_exact_mean_in_log2k_rounds():
    """The proven schedule: with mix=0.5 and full-tree masks, log2(k)
    butterfly stages take ANY initial disagreement to the global mean
    (averaging along hypercube dimension b equalizes every pair
    differing only in bit b; induction over dimensions)."""
    k = 8
    rng = np.random.default_rng(0)
    est = {"a": jnp.asarray(rng.normal(size=(k, 4, 3)).astype(
        np.float32)), "b": jnp.asarray(rng.normal(size=(k, 5)).astype(
            np.float32))}
    mask = jax.tree.map(lambda g: 1.0, est)
    want = jax.tree.map(lambda g: np.asarray(g).mean(0), est)
    for t in range(3):           # log2(8) stages
        partner = gossip.partner_map(k, t, "butterfly")
        est = gossip.mix_round(est, partner, mask, mix=0.5)
    for leaf, m in zip(jax.tree.leaves(est), jax.tree.leaves(want)):
        got = np.asarray(leaf)
        np.testing.assert_allclose(got, np.broadcast_to(m, got.shape),
                                   rtol=2e-6, atol=2e-6)
        # every worker agrees with every other to the last few ulps
        # (summation order differs per worker, so not bitwise)
        assert float((got.max(0) - got.min(0)).max()) < 4e-7


def test_butterfly_swap_equals_partner_take():
    for k, stage in [(2, 0), (4, 0), (4, 1), (8, 2)]:
        g = jnp.asarray(np.random.default_rng(1).normal(
            size=(k, 3, 5)).astype(np.float32))
        p = gossip.partner_map(k, stage, "butterfly")
        np.testing.assert_array_equal(
            np.asarray(jnp.take(g, p, axis=0)),
            np.asarray(gossip.butterfly_swap(stage, k)(g)))
    with pytest.raises(ValueError):
        gossip.butterfly_swap(2, 4)   # 2^3 does not divide 4


def test_partner_maps_are_involutions():
    for k in (2, 5, 8):
        for t in range(4):
            key = jax.random.PRNGKey(10 * k + t)
            for pairing in (("butterfly",) if k & (k - 1) == 0
                            else ()) + ("random",):
                p = np.asarray(gossip.partner_map(k, t, pairing,
                                                  key=key))
                np.testing.assert_array_equal(p[p], np.arange(k))
                selfs = int((p == np.arange(k)).sum())
                assert selfs == (k % 2 if pairing == "random" else 0)


def test_mix_round_gates_dropped_and_self_pairs():
    k = 4
    est = {"a": jnp.asarray(np.random.default_rng(2).normal(
        size=(k, 6)).astype(np.float32))}
    mask = {"a": 1.0}
    partner = gossip.partner_map(k, 0, "butterfly")
    # ok=0 everywhere: nothing moves
    out = gossip.mix_round(est, partner, mask, mix=0.5,
                           ok=jnp.zeros((k,), jnp.float32))
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(est["a"]))
    # self-partnered workers (k=1 map) never move either
    one = {"a": est["a"][:1]}
    out1 = gossip.mix_round(one, gossip.partner_map(1, 0, "butterfly"),
                            mask, mix=0.5)
    np.testing.assert_array_equal(np.asarray(out1["a"]),
                                  np.asarray(one["a"]))


def test_quantized_exchange_still_contracts_disagreement():
    k = 2
    est = {"a": jnp.asarray([[1.0, 2.0], [3.0, 8.0]], jnp.float32)}
    out = gossip.mix_round(est, gossip.partner_map(k, 0, "butterfly"),
                           {"a": 1.0}, mix=0.5,
                           quant_dtype="bfloat16")
    spread0 = float(np.abs(np.diff(np.asarray(est["a"]), axis=0)).sum())
    spread1 = float(np.abs(np.diff(np.asarray(out["a"]), axis=0)).sum())
    assert spread1 < 0.1 * spread0


# ---------------------------------------------------------------------------
# the round through the shared builders
# ---------------------------------------------------------------------------

def test_gossip_round_body_runs_and_reports():
    k = 4
    dcfg, tcfg = make_cfgs(k, P=2)
    body = gossip.make_gossip_round_body(quad_loss, sample_all(k),
                                         dcfg, tcfg)
    state = gossip.init_state(tiny_params(), dcfg)
    key = jax.random.PRNGKey(0)
    state, m = body(state, key)
    assert float(m["exchange_frac"]) == 1.0
    assert float(m["gossip_frag"]) == 0.0
    state, m = body(state, jax.random.fold_in(key, 1))
    assert float(m["gossip_frag"]) == 1.0     # P=2 schedule advanced
    assert np.isfinite(float(m["gossip_spread"]))
    assert np.isfinite(float(m["inner_loss"]))


def test_gossip_inactive_worker_is_fully_frozen():
    k = 4
    dcfg, tcfg = make_cfgs(k)
    body = gossip.make_gossip_round_body(quad_loss, sample_all(k),
                                         dcfg, tcfg)
    state = gossip.init_state(tiny_params(), dcfg)
    # introduce disagreement first so freezing is observable
    state, _ = body(state, jax.random.PRNGKey(0))
    act = jnp.asarray([1.0, 1.0, 1.0, 0.0])
    before = jax.tree.map(lambda g: np.asarray(g[3]).copy(),
                          state.global_est)
    state2, m = body(state, jax.random.PRNGKey(1),
                     jnp.ones((k,)), act)
    after = jax.tree.map(lambda g: np.asarray(g[3]),
                         state2.global_est)
    for b, a in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        np.testing.assert_array_equal(b, a)
    # its butterfly partner (worker 1 at stage 1) lost its exchange
    # too, so only the (0,2) pair traded this round
    assert float(m["exchange_frac"]) == 0.5


def test_gossip_all_drops_blocks_every_exchange():
    k = 4
    dcfg, tcfg = make_cfgs(k)
    body = gossip.make_gossip_round_body(quad_loss, sample_all(k),
                                         dcfg, tcfg)
    state = gossip.init_state(tiny_params(), dcfg)
    _, m = body(state, jax.random.PRNGKey(0),
                jnp.zeros((k,)), jnp.ones((k,)))
    assert float(m["exchange_frac"]) == 0.0
    assert float(m["drop_frac"]) == 1.0


def test_gossip_through_scanned_make_run_learns():
    k = 4
    dcfg, tcfg = make_cfgs(k, P=2)
    val = jax.random.randint(jax.random.PRNGKey(9), (4, 4), 0, 7,
                             jnp.int32)
    run = diloco.make_run(quad_loss, sample_all(k), dcfg, tcfg,
                          rounds_per_call=6, total_steps=64,
                          batch_size=2, seq_len=4, eval_tokens=val)
    state = gossip.init_state(tiny_params(), dcfg)
    state, ms = run(state, jax.random.PRNGKey(0), None, None, None)
    vl = np.asarray(ms["val_loss"])
    assert np.isfinite(vl).all()
    assert vl[-1] < vl[0]
    # consensus view exists and is finite
    for leaf in jax.tree.leaves(state.global_params):
        assert np.isfinite(np.asarray(leaf)).all()


def test_gossip_mixed_precision_policy():
    k = 2
    dcfg, tcfg = make_cfgs(k, param_dtype="bfloat16",
                           master_dtype="float32")
    tcfg = dataclasses.replace(tcfg, param_dtype="bfloat16",
                               master_dtype="float32")
    body = gossip.make_gossip_round_body(quad_loss, sample_all(k),
                                         dcfg, tcfg)
    state = gossip.init_state(tiny_params(), dcfg)
    assert jax.tree.leaves(state.replica_params)[0].dtype == \
        jnp.bfloat16
    assert state.inner_state.master is not None
    state, m = body(state, jax.random.PRNGKey(0))
    assert jax.tree.leaves(state.global_est)[0].dtype == jnp.float32
    assert np.isfinite(float(m["inner_loss"]))


# ---------------------------------------------------------------------------
# validation + routing
# ---------------------------------------------------------------------------

def test_gossip_validation_errors():
    dcfg, tcfg = make_cfgs(4)
    gossip.validate(dcfg)   # baseline OK
    for bad in (dict(k=3), dict(gossip_pairing="ring"),
                dict(gossip_mix=1.5), dict(outer_grad_dtype="int4"),
                dict(error_feedback=True), dict(prune_frac=0.5)):
        with pytest.raises(ValueError):
            gossip.validate(dataclasses.replace(dcfg, **bad))
    # random pairing lifts the power-of-2 requirement
    gossip.validate(dataclasses.replace(dcfg, k=3,
                                        gossip_pairing="random"))
    with pytest.raises(ValueError, match="mesh"):
        gossip.make_gossip_round_body(quad_loss, sample_all(4), dcfg,
                                      tcfg, mesh=object())


def test_round_builder_routes_gossip_without_fragments():
    # gossip must route BEFORE the streaming check: it reuses
    # streaming_fragments as P but needs no StreamState
    k = 2
    dcfg, tcfg = make_cfgs(k, P=0)
    rnd = diloco.make_round(quad_loss, sample_all(k), dcfg, tcfg)
    state = gossip.init_state(tiny_params(), dcfg)
    state, m = rnd(state, jax.random.PRNGKey(0))
    assert "gossip_spread" in m


def test_frag_bytes_accounting():
    params = tiny_params()      # 11 elements
    dcfg, _ = make_cfgs(2, P=2, outer_grad_dtype="bfloat16")
    sizes = gossip.frag_bytes(params, dcfg)
    assert len(sizes) == 2
    assert sum(sizes) == kops.transport_bytes(11, "bfloat16")


# ---------------------------------------------------------------------------
# checkpoint round-trip (satellite b: the gossip slice)
# ---------------------------------------------------------------------------

def test_gossip_state_checkpoint_roundtrip(tmp_path):
    k = 2
    dcfg, tcfg = make_cfgs(k)
    body = gossip.make_gossip_round_body(quad_loss, sample_all(k),
                                         dcfg, tcfg)
    state = gossip.init_state(tiny_params(), dcfg)
    state, _ = body(state, jax.random.PRNGKey(0))
    path = str(tmp_path / "gossip.npz")
    ckpt.save(path, state)
    back = ckpt.restore(path, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # structure-free view re-shapes onto the NamedTuple as well
    again = ckpt.reshape_like(ckpt.restore_tree(path), state)
    assert isinstance(again, gossip.GossipState)
    np.testing.assert_array_equal(np.asarray(again.outer_t),
                                  np.asarray(state.outer_t))
