from .base import (ModelConfig, ShapeConfig, SHAPES, DiLoCoConfig,
                   TrainConfig, LONG_CONTEXT_WINDOW)
