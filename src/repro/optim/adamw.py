"""AdamW inner optimizer (paper: the standard LM optimizer), from scratch.

Decoupled weight decay per Loshchilov & Hutter 2019; bias-corrected
moments. The functional API mirrors optax: ``init`` then ``update``.

Mixed precision (see ``optim/precision.py``): under a mixed policy the
state additionally carries a high-precision ``master`` copy of the
params, the working params and the m/v moments ride at the (narrower)
replica dtype, and ``update`` routes through the mixed fused kernel —
one pass that updates f32 m/v/master and emits the bf16 working copy.
Under the default all-f32 policy ``master`` is None and both the state
layout and the numerics are bit-identical to the historical
implementation.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from . import precision


class AdamWState(NamedTuple):
    m: dict
    v: dict
    count: jnp.ndarray
    # High-precision master params under a mixed policy; None (an empty
    # pytree node — zero leaves, zero bytes) otherwise.
    master: Any = None


def init(params, *, policy: precision.Policy | None = None) -> AdamWState:
    """``params`` arrive at master precision (the caller's tree). With
    a ``policy`` the m/v moments are allocated at the replica
    ``param_dtype`` whatever dtype the incoming params have; a mixed
    policy additionally keeps a ``master_dtype`` master copy. Without a
    policy the moments simply mirror the params' dtypes (the legacy
    behavior)."""
    if policy is None or not policy.mixed:
        if policy is None:
            zeros = lambda p: jnp.zeros_like(p)
        else:
            zeros = lambda p: jnp.zeros(p.shape, policy.param_dtype)
        return AdamWState(m=jax.tree.map(zeros, params),
                          v=jax.tree.map(zeros, params),
                          count=jnp.zeros((), jnp.int32))
    zeros = lambda p: jnp.zeros(p.shape, policy.param_dtype)
    # jnp.array (not astype): the master must be a fresh buffer, never
    # an alias of the caller's params — downstream steps donate the
    # state, and donating an aliased master would delete the caller's
    # tree (astype is the identity when the dtypes already match)
    return AdamWState(
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        count=jnp.zeros((), jnp.int32),
        master=jax.tree.map(
            lambda p: jnp.array(p, dtype=policy.master_dtype), params))


def master_params(params, state: AdamWState):
    """The authoritative (master-precision) params: the state's master
    copy under a mixed policy, the working params otherwise."""
    return params if state.master is None else state.master


def update(grads, state: AdamWState, params, *, lr, b1=0.9, b2=0.95,
           eps=1e-8, weight_decay=0.1, mode: str = "ref",
           policy: precision.Policy | None = None):
    """One AdamW step. ``lr`` may be a scalar traced value (schedule).

    ``mode`` selects the backend: ``ref`` is the legacy pure-jnp tree
    map below; ``auto``/``pallas``/``interpret`` route through the fused
    single-VMEM-pass kernel in ``repro.kernels`` (one read of each of
    p/g/m/v, one write of p/m/v per step instead of XLA's split
    fusions).

    Under a mixed ``policy`` the update reads the state's master copy
    (``params`` is the derived working copy and carries no extra
    information), runs in f32, and returns the new working params at
    ``param_dtype`` — ``mode="ref"`` uses the jnp oracle, kernel modes
    the mixed Pallas kernel.
    """
    count = state.count + 1
    if state.master is not None and (policy is None or not policy.mixed):
        # silently proceeding would drop (or desync) the f32 master and
        # keep training from the rounded working copy
        raise ValueError(
            "state carries a master copy but no mixed policy was "
            "passed: thread the same precision policy through init "
            "and update")
    if policy is not None and policy.mixed:
        if state.master is None:
            raise ValueError(
                "mixed-policy update needs a master copy in the state: "
                "build it with adamw.init(params, policy=policy)")
        from repro.kernels import ops as kops
        new_p, new_m, new_v, new_w = kops.adamw_update_tree_mixed(
            grads, state.m, state.v, state.master, lr=lr, count=count,
            param_dtype=policy.param_dtype, b1=b1, b2=b2, eps=eps,
            weight_decay=weight_decay, mode=mode)
        return new_p, AdamWState(new_m, new_v, count, new_w)
    if mode != "ref":
        from repro.kernels import ops as kops
        new_p, new_m, new_v = kops.adamw_update_tree(
            params, grads, state.m, state.v, lr=lr, count=count, b1=b1,
            b2=b2, eps=eps, weight_decay=weight_decay, mode=mode)
        return new_p, AdamWState(new_m, new_v, count)
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        # accumulate in f32 whatever the storage dtype (identity for
        # f32 state, same math as ref.fused_adamw / the kernels for
        # low-precision state), then round each output back to storage
        pf, gf = p.astype(jnp.float32), g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1.0 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1.0 - b2) * jnp.square(gf)
        mhat = m_new / c1
        vhat = v_new / c2
        step = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * pf
        return ((pf - lr * step).astype(p.dtype), m_new.astype(m.dtype),
                v_new.astype(v.dtype))

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(new_m, new_v, count)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-12))
    # the cast keeps low-precision grads at their storage dtype (f32
    # grads are untouched — scale is f32, so this is the identity)
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gn
