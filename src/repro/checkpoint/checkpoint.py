"""Tree checkpointing: flat-key npz arrays + json metadata.

Supports saving/restoring arbitrary pytrees of arrays (params, optimizer
states, DiLoCo state) with structure recovered from a like-structured
example tree. Writes are atomic (tmp + rename).
"""
from __future__ import annotations

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "//"

# npz cannot represent the ml_dtypes extension types (bfloat16 leaves
# of a mixed-precision state serialize as raw void bytes that nothing
# can cast back) — such leaves ride the wire as a uint16 bit-view, with
# their true dtype names recorded under this sentinel key.
_DTYPES_KEY = "__leaf_dtypes__"
_VIEW_OF = {"bfloat16": np.uint16}


def _flatten(tree) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(_path_str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _encode_extension_dtypes(flat: dict) -> dict:
    """Bit-view extension-typed arrays to a native dtype and append the
    ``_DTYPES_KEY`` manifest (absent when every leaf is native)."""
    names = []
    for key, arr in list(flat.items()):
        dt = str(arr.dtype)
        if dt in _VIEW_OF:
            flat[key] = arr.view(_VIEW_OF[dt])
            names.append(f"{key}={dt}")
    if names:
        flat[_DTYPES_KEY] = np.asarray(names)
    return flat


def _decode_leaf(data, key: str, views: dict) -> np.ndarray:
    arr = data[key]
    if key in views:
        arr = arr.view(jnp.dtype(views[key]))
    return arr


def _views_of(data) -> dict:
    if _DTYPES_KEY not in getattr(data, "files", ()):
        return {}
    return dict(s.rsplit("=", 1) for s in data[_DTYPES_KEY].tolist())


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def _fsync_dir(dirname: str) -> None:
    """fsync the directory entry so the rename itself is durable (a
    crash after os.replace but before the metadata hits disk could
    otherwise resurrect the old file — or neither)."""
    try:
        fd = os.open(dirname, os.O_RDONLY)
    except OSError:
        return                      # platform without dir-open; best effort
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _atomic_savez(path: str, flat: dict) -> None:
    """Durable atomic write: temp file in the TARGET directory (same
    filesystem, so the rename is atomic), flush + fsync before the
    rename, fsync the directory after. A SIGKILL at any instant leaves
    either the complete old file or the complete new one — never a
    truncated npz."""
    dirname = os.path.dirname(os.path.abspath(path))
    os.makedirs(dirname, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=dirname, suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **flat)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        _fsync_dir(dirname)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def atomic_write_json(path: str, payload, **dump_kw) -> None:
    """Durable atomic json sidecar write (same tmp+fsync+rename
    discipline as the npz payload)."""
    dirname = os.path.dirname(os.path.abspath(path))
    os.makedirs(dirname, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=dirname, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f, **dump_kw)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        _fsync_dir(dirname)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def save(path: str, tree, metadata: dict | None = None) -> None:
    flat = _encode_extension_dtypes(_flatten(tree))
    _atomic_savez(path, flat)
    if metadata is not None:
        atomic_write_json(path + ".meta.json", metadata, indent=2,
                          default=str)


def restore(path: str, example_tree):
    """Restore into the structure of ``example_tree``."""
    with np.load(path) as data:
        views = _views_of(data)
        flat_example, treedef = jax.tree_util.tree_flatten_with_path(
            example_tree)
        leaves = []
        for p, ex in flat_example:
            key = _SEP.join(_path_str(q) for q in p)
            if key not in data:
                raise KeyError(f"checkpoint missing key {key!r}")
            arr = _decode_leaf(data, key, views)
            if tuple(arr.shape) != tuple(np.shape(ex)):
                raise ValueError(
                    f"shape mismatch for {key}: ckpt {arr.shape} vs "
                    f"example {np.shape(ex)}")
            leaves.append(jnp.asarray(arr, dtype=ex.dtype
                                      if hasattr(ex, "dtype") else None))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore_tree(path: str) -> dict:
    """Structure-free restore: rebuild a nested dict straight from the
    flat checkpoint keys, no example tree needed.

    Every path segment becomes a dict key — including list/tuple
    indices, which come back as ``"[i]"`` string keys — so the result
    is a dicts-only *view* of whatever tree was saved. Use it when the
    saved structure is dynamic (e.g. the async engine's live-snapshot
    table, whose version keys differ run to run); re-shape any subtree
    whose true structure you know with ``reshape_like``.
    """
    out: dict = {}
    with np.load(path) as data:
        views = _views_of(data)
        for key in data.files:
            if key == _DTYPES_KEY:
                continue
            node = out
            parts = key.split(_SEP)
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = jnp.asarray(_decode_leaf(data, key, views))
    return out


def reshape_like(tree, example):
    """Re-shape a dicts-only view (from ``restore_tree``) onto the real
    structure of ``example`` — NamedTuples, lists, custom nodes and
    all. Works because ``_path_str`` renders a dict key ``"[0]"`` and a
    list index 0 identically: the two trees flatten to the same flat
    keys, so leaves transfer by key and re-assemble under the example's
    treedef. Leaf dtypes follow the checkpoint (the example only
    supplies structure); shapes must match."""
    by_key = _flatten(tree)
    flat_ex, treedef = jax.tree_util.tree_flatten_with_path(example)
    leaves = []
    for p, ex in flat_ex:
        key = _SEP.join(_path_str(q) for q in p)
        if key not in by_key:
            raise KeyError(f"restored tree missing key {key!r}")
        arr = by_key[key]
        if tuple(np.shape(arr)) != tuple(np.shape(ex)):
            raise ValueError(
                f"shape mismatch for {key}: restored {np.shape(arr)} "
                f"vs example {np.shape(ex)}")
        leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_metadata(path: str) -> dict:
    with open(path + ".meta.json") as f:
        return json.load(f)


# ---------------------------------------------------------------------------
# packed int4 weights format — the checkpoint IS the wire format
# ---------------------------------------------------------------------------
# The serving-side counterpart of the streaming transport: the param
# tree is split into the SAME contiguous fragments the outer sync
# ships (core/fragments.py) and every region is encoded with the SAME
# fused int4 wire codec (kernels/ops.wire_encode: nibble-packed codes
# + per-128-block f32 scales in one uint8 buffer). ~0.53 B/elem vs 4,
# so packed weights are ~7.5x smaller than f32 — and a server can keep
# them packed in memory, dequantizing inside its jitted step
# (``unpack_params`` is traceable).

_MANIFEST_KEY = "__packed_manifest__"
PACKED_FORMAT = "diloco_packed_weights_v1"


def _region_key(p: int, j: int) -> str:
    return f"frag{p}{_SEP}reg{j}"


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = [_SEP.join(_path_str(q) for q in p) for p, _ in flat]
    return paths, [l for _, l in flat], treedef


def save_packed(path: str, params, *, n_fragments: int = 4,
                dtype: str = "int4", mode: str = "auto",
                metadata: dict | None = None) -> dict:
    """Save ``params`` as packed wire buffers, one per fragment region.

    Layout: for each of the ``n_fragments`` contiguous fragments (the
    partition the streaming outer sync uses), each contiguous region is
    flattened and ``wire_encode``d; the npz stores one uint8 buffer per
    region plus a json manifest (leaf paths/shapes/dtypes + the region
    table) under ``_MANIFEST_KEY``. Returns the manifest."""
    from repro.core import fragments
    from repro.kernels import ops
    paths, leaves, _ = _leaf_paths(params)
    part = fragments.partition_params(params, n_fragments)
    regions = fragments.fragment_regions(part, params)
    arrays: dict[str, np.ndarray] = {}
    man_frags = []
    for p, regs in enumerate(regions):
        rr = []
        for j, r in enumerate(regs):
            flat = fragments.region_take(
                jnp.asarray(leaves[r.leaf], jnp.float32), r)
            wire, _ = ops.wire_encode(flat, dtype, mode=mode)
            arrays[_region_key(p, j)] = np.asarray(wire)
            rr.append([r.leaf, r.start, r.stop, r.elems])
        man_frags.append(rr)
    manifest = {
        "format": PACKED_FORMAT,
        "dtype": dtype,
        "n_fragments": part.n,
        "leaf_paths": paths,
        "leaf_shapes": [list(np.shape(l)) for l in leaves],
        "leaf_dtypes": [str(np.asarray(l).dtype) for l in leaves],
        "fragments": man_frags,
        "packed_bytes": int(sum(a.nbytes for a in arrays.values())),
        "f32_bytes": int(sum(int(np.prod(np.shape(l)) or 1) * 4
                             for l in leaves)),
    }
    arrays[_MANIFEST_KEY] = np.asarray(json.dumps(manifest))
    _atomic_savez(path, arrays)
    if metadata is not None:
        atomic_write_json(path + ".meta.json", metadata, indent=2,
                          default=str)
    return manifest


def _check_structure(manifest, example_tree):
    paths, leaves, treedef = _leaf_paths(example_tree)
    if paths != list(manifest["leaf_paths"]):
        raise KeyError(
            "packed checkpoint structure mismatch: "
            f"ckpt leaves {manifest['leaf_paths'][:3]}... vs example "
            f"{paths[:3]}...")
    for p, l, s in zip(paths, leaves, manifest["leaf_shapes"]):
        if tuple(np.shape(l)) != tuple(s):
            raise ValueError(
                f"shape mismatch for {p}: ckpt {tuple(s)} vs example "
                f"{tuple(np.shape(l))}")
    return leaves, treedef


def load_packed(path: str) -> dict:
    """Load the raw packed checkpoint: ``{"manifest": ..., "buffers":
    {region_key: uint8 array}}``. The buffers stay packed — hand them
    to a server that dequantizes in-graph (``unpack_params``)."""
    with np.load(path) as data:
        if _MANIFEST_KEY not in data.files:
            raise KeyError(f"{path} is not a packed checkpoint "
                           f"(missing {_MANIFEST_KEY})")
        manifest = json.loads(str(data[_MANIFEST_KEY]))
        buffers = {k: data[k] for k in data.files if k != _MANIFEST_KEY}
    return {"manifest": manifest, "buffers": buffers}


def unpack_params(buffers, manifest, example_tree, *,
                  mode: str = "auto"):
    """Rebuild the (dequantized f32) param tree from packed buffers.

    Traceable: call it inside a jitted serving step with the buffers as
    arguments and the weights stay packed at rest — XLA sees uint8
    weight inputs ~7.5x smaller than the f32 tree. ``example_tree``
    supplies structure/shapes only (ShapeDtypeStructs work)."""
    from repro.core import fragments
    from repro.kernels import ops
    leaves, treedef = _check_structure(manifest, example_tree)
    out = [jnp.zeros(tuple(np.shape(l)),
                     jnp.dtype(getattr(l, "dtype", jnp.float32)))
           for l in leaves]
    for p, regs in enumerate(manifest["fragments"]):
        for j, (leaf_i, start, stop, elems) in enumerate(regs):
            r = fragments.Region(leaf_i, start, stop, elems)
            vals = ops.wire_decode(jnp.asarray(buffers[_region_key(p, j)]),
                                   elems, manifest["dtype"], mode=mode)
            out[leaf_i] = fragments.region_put(out[leaf_i], r, vals)
    return jax.tree_util.tree_unflatten(treedef, out)


def restore_packed(path: str, example_tree, *, mode: str = "auto"):
    """Restore a packed checkpoint to a dequantized f32 param tree,
    streaming fragment by fragment (npz loads lazily per key — peak
    extra memory is one region's wire buffer, never the packed whole)."""
    from repro.core import fragments
    from repro.kernels import ops
    with np.load(path) as data:
        if _MANIFEST_KEY not in data.files:
            raise KeyError(f"{path} is not a packed checkpoint "
                           f"(missing {_MANIFEST_KEY})")
        manifest = json.loads(str(data[_MANIFEST_KEY]))
        leaves, treedef = _check_structure(manifest, example_tree)
        out = [jnp.zeros(tuple(np.shape(l)),
                         jnp.dtype(getattr(l, "dtype", jnp.float32)))
               for l in leaves]
        for p, regs in enumerate(manifest["fragments"]):
            for j, (leaf_i, start, stop, elems) in enumerate(regs):
                r = fragments.Region(leaf_i, start, stop, elems)
                wire = jnp.asarray(data[_region_key(p, j)])
                vals = ops.wire_decode(wire, elems, manifest["dtype"],
                                       mode=mode)
                out[leaf_i] = fragments.region_put(out[leaf_i], r, vals)
    return jax.tree_util.tree_unflatten(treedef, out)
