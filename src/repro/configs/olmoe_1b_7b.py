"""olmoe-1b-7b [moe, arXiv:2409.02060]: 16L, d_model=2048, 16 heads
(kv=16), 64 experts top-8 (no shared), expert d_ff=1024, vocab=50304,
qk-norm."""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b", family="moe",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1024, vocab_size=50_304,
        n_experts=64, top_k=8, moe_d_ff=1024,
        qk_norm=True, norm="rmsnorm", act="silu",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="olmoe-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=4, d_ff=128, moe_d_ff=128, n_experts=4, top_k=2,
        vocab_size=256, attn_chunk=64, capacity_factor=4.0)
