"""Architecture registry: uniform API over every assigned architecture.

``get_arch(name)`` -> Arch with init / loss / prefill / decode entry
points, plus ``input_specs`` / ``cache_specs`` producing
ShapeDtypeStruct stand-ins for the dry-run (no allocation).
"""
from __future__ import annotations

import functools
import importlib
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, \
    LONG_CONTEXT_WINDOW
from repro.sharding.spec import unbox
from . import model as M

ARCH_NAMES = [
    "whisper_large_v3", "deepseek_v2_lite_16b", "starcoder2_7b",
    "llama_3_2_vision_90b", "stablelm_1_6b", "olmoe_1b_7b", "qwen3_32b",
    "zamba2_2_7b", "command_r_35b", "xlstm_350m",
    # the paper's own Chinchilla-style models
    "diloco_60m", "diloco_150m", "diloco_400m",
]

# families with full self-attention that need a sliding window at 500k ctx
_ATTN_FAMILIES = ("dense", "moe", "vlm", "encdec", "hybrid")


@dataclass
class Arch:
    cfg: ModelConfig

    # ---- shape adaptation ----
    def shape_cfg(self, shape: ShapeConfig) -> ModelConfig:
        """Per-shape config: long-context decode on attention archs flips
        on sliding-window attention (sub-quadratic carve-out)."""
        cfg = self.cfg
        if (shape.kind == "decode" and shape.seq_len > 65_536
                and cfg.family in _ATTN_FAMILIES and not cfg.window):
            cfg = cfg.replace(window=LONG_CONTEXT_WINDOW)
        return cfg

    # ---- params ----
    def init(self, key, cfg=None):
        params_boxed = M.init_params(key, cfg or self.cfg)
        return unbox(params_boxed)

    # ---- entry points ----
    def loss(self, params, batch, *, cfg=None, groups: int = 1):
        return M.loss_fn(params, cfg or self.cfg, batch, groups=groups)

    def prefill(self, params, batch, *, cfg=None, groups: int = 1,
                cache_len: int = 0):
        cfg = cfg or self.cfg
        extra = {k: v for k, v in batch.items() if k != "tokens"}
        return M.prefill(params, cfg, batch["tokens"],
                         extra=extra or None, window=cfg.window,
                         groups=groups, cache_len=cache_len)

    def decode(self, params, cache, tokens, pos, *, cfg=None,
               groups: int = 1, page_table=None):
        cfg = cfg or self.cfg
        return M.decode_step(params, cfg, cache, tokens, pos,
                             window=cfg.window, groups=groups,
                             page_table=page_table)

    # ---- specs for the dry-run ----
    def input_specs(self, shape: ShapeConfig, *, batch_override: int = 0,
                    dtype=jnp.float32) -> dict:
        cfg = self.shape_cfg(shape)
        B = batch_override or shape.global_batch
        S = shape.seq_len
        sd = jax.ShapeDtypeStruct
        if shape.kind == "train":
            out = {"tokens": sd((B, S), jnp.int32)}
        elif shape.kind == "prefill":
            out = {"tokens": sd((B, S), jnp.int32)}
        else:  # decode
            out = {"tokens": sd((B, 1), jnp.int32)}
        if cfg.family == "vlm" and shape.kind != "decode":
            out["patches"] = sd((B, cfg.n_patches, cfg.d_model), dtype)
        if cfg.family == "encdec" and shape.kind != "decode":
            out["frames"] = sd((B, cfg.n_frames, cfg.d_model), dtype)
        return out

    def cache_specs(self, shape: ShapeConfig, *, batch_override: int = 0,
                    dtype=jnp.float32):
        cfg = self.shape_cfg(shape)
        B = batch_override or shape.global_batch
        fn = lambda: M.init_cache(cfg, B, shape.seq_len, dtype,
                                  window=cfg.window)
        return jax.eval_shape(fn)

    def abstract_params(self, cfg=None):
        """(ShapeDtypeStruct tree, logical-axes tree) without allocation.

        The logical-axes tree is captured as a side effect of tracing the
        init under eval_shape (init is structurally deterministic)."""
        cfg = cfg or self.cfg
        axes_holder = {}

        def go(key):
            p, ax = unbox(M.init_params(key, cfg))
            axes_holder["axes"] = ax
            return p

        shapes = jax.eval_shape(go, jax.random.PRNGKey(0))
        return shapes, axes_holder["axes"]


@functools.lru_cache(maxsize=None)
def get_arch(name: str) -> Arch:
    name = name.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{name}")
    return Arch(cfg=mod.config())


@functools.lru_cache(maxsize=None)
def get_smoke_arch(name: str) -> Arch:
    name = name.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{name}")
    return Arch(cfg=mod.smoke_config())
