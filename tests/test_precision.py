"""Tests for the mixed-precision replica-state policy
(optim/precision.py, the mixed fused-AdamW kernel, and its threading
through the DiLoCo/streaming drivers) and the PR's satellites
(error-feedback transport, exact int4 transport-bytes accounting).

Pins the policy's contracts:
  * (float32, float32) — the default — is bit-identical to a
    policy-less config through the scanned driver;
  * the mixed state layout is what the memory accounting claims:
    bf16 working params + bf16 moments + f32 master, global/outer f32;
  * the mixed Pallas kernel (interpret mode) matches its jnp oracle
    elementwise, and a full mixed round matches ref numerics;
  * the bf16 policy tracks the f32 policy's loss on the toy config;
  * donation still holds under the new state layout;
  * error feedback drives the mean transport quantization bias to ~0;
  * ``transport_bytes`` charges int4's f32 scale per *started* block.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import DiLoCoConfig, TrainConfig, ModelConfig
from repro.core import diloco, streaming
from repro.data.sharding import make_regime
from repro.kernels import fused_adamw as kadamw
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.models.registry import Arch
from repro.optim import adamw, precision

K, H, B, S, VOCAB = 2, 4, 2, 16, 64


@pytest.fixture(scope="module")
def setup():
    cfg = ModelConfig(name="tiny", family="dense", n_layers=2,
                      d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                      vocab_size=VOCAB, remat=False, attn_chunk=32)
    arch = Arch(cfg=cfg)
    loss_fn = lambda p, b: arch.loss(p, b)
    sampler = make_regime("non_iid", k=K, vocab_size=VOCAB, seed=0)
    params, _ = arch.init(jax.random.PRNGKey(0), cfg)
    return arch, loss_fn, sampler, params


def _cfgs(rounds, pd="float32", md="float32", kernel_mode="ref", **kw):
    dcfg = DiLoCoConfig(k=K, H=H, param_dtype=pd, master_dtype=md,
                        kernel_mode=kernel_mode, **kw)
    tcfg = TrainConfig(inner_lr=3e-3, warmup_steps=2,
                       total_steps=rounds * H, batch_size=B, seq_len=S,
                       param_dtype=pd, master_dtype=md,
                       kernel_mode=kernel_mode)
    return dcfg, tcfg


def _run(loss_fn, sampler, params, dcfg, tcfg, rounds, *, donate=False,
         key=5):
    run = diloco.make_run(loss_fn, sampler.sample_all_shards, dcfg,
                          tcfg, rounds_per_call=rounds,
                          total_steps=rounds * H, batch_size=B,
                          seq_len=S, donate=donate)
    state = (streaming.init_state(params, dcfg)
             if dcfg.streaming_fragments
             else diloco.init_state(params, dcfg))
    return run(state, jax.random.PRNGKey(key))


# ---------------------------------------------------------------------------
# policy plumbing
# ---------------------------------------------------------------------------

def test_policy_validation():
    pol = precision.make_policy("bfloat16", "float32")
    assert pol.mixed
    assert not precision.make_policy().mixed
    assert not precision.make_policy("bfloat16", "bfloat16").mixed
    with pytest.raises(ValueError):
        precision.make_policy("float32", "bfloat16")   # master narrower
    with pytest.raises(ValueError):
        precision.make_policy("float16", "float32")    # unknown dtype


def test_round_builder_rejects_policy_mismatch(setup):
    arch, loss_fn, sampler, params = setup
    dcfg, _ = _cfgs(1, pd="bfloat16")
    _, tcfg = _cfgs(1)                 # f32 inner step vs bf16 state
    with pytest.raises(ValueError):
        diloco._make_round_body(loss_fn, sampler.sample_all_shards,
                                dcfg, tcfg)


def test_f32_policy_bit_identical_to_default(setup):
    """Explicit (float32, float32) == a policy-less config, to the bit
    (the new code path is a strict no-op at the default policy)."""
    arch, loss_fn, sampler, params = setup
    R = 3
    dcfg_d = DiLoCoConfig(k=K, H=H)
    tcfg_d = TrainConfig(inner_lr=3e-3, warmup_steps=2,
                         total_steps=R * H, batch_size=B, seq_len=S)
    st_d, ms_d = _run(loss_fn, sampler, params, dcfg_d, tcfg_d, R)
    dcfg_f, tcfg_f = _cfgs(R, pd="float32", md="float32")
    st_f, ms_f = _run(loss_fn, sampler, params, dcfg_f, tcfg_f, R)
    for a, b in zip(jax.tree.leaves(st_d), jax.tree.leaves(st_f)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(ms_d["inner_loss"]),
                                  np.asarray(ms_f["inner_loss"]))


def test_mixed_state_layout(setup):
    """The byte accounting the memory benchmark gates on: bf16 working
    params + bf16 moments + f32 per-replica master; f32 global/outer;
    no master under the uniform policies."""
    arch, loss_fn, sampler, params = setup
    dcfg, _ = _cfgs(1, pd="bfloat16", md="float32")
    st = diloco.init_state(params, dcfg)
    assert all(l.dtype == jnp.bfloat16
               for l in jax.tree.leaves(st.replica_params))
    assert all(l.dtype == jnp.bfloat16
               for l in jax.tree.leaves(st.inner_state.m))
    assert all(l.dtype == jnp.bfloat16
               for l in jax.tree.leaves(st.inner_state.v))
    assert all(l.dtype == jnp.float32
               for l in jax.tree.leaves(st.inner_state.master))
    assert all(l.dtype == jnp.float32
               for l in jax.tree.leaves(st.global_params))
    assert all(l.dtype == jnp.float32
               for l in jax.tree.leaves(st.outer_state.buf))
    # master leaves carry the replica axis and start equal to params
    g0 = jax.tree.leaves(params)[0]
    w0 = jax.tree.leaves(st.inner_state.master)[0]
    assert w0.shape == (K,) + g0.shape
    np.testing.assert_array_equal(np.asarray(w0[0]), np.asarray(g0))
    # params+moments tier halves: 2+2+2 vs 4+4+4 bytes per element
    st_f = diloco.init_state(params, DiLoCoConfig(k=K, H=H))
    tb = precision.tree_bytes
    mixed = (tb(st.replica_params) + tb(st.inner_state.m)
             + tb(st.inner_state.v))
    base = (tb(st_f.replica_params) + tb(st_f.inner_state.m)
            + tb(st_f.inner_state.v))
    assert base == 2 * mixed
    assert st_f.inner_state.master is None


# ---------------------------------------------------------------------------
# mixed fused-AdamW kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(64,), (33, 7), (4, 32, 16)])
def test_mixed_kernel_interpret_matches_oracle(shape):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    g = (jax.random.normal(ks[0], shape) * 0.1).astype(jnp.bfloat16)
    m = (jax.random.normal(ks[1], shape) * 0.05).astype(jnp.bfloat16)
    v = (jax.random.uniform(ks[2], shape) * 0.01).astype(jnp.bfloat16)
    w = jax.random.normal(ks[3], shape)
    kw = dict(lr=1e-2, c1=0.5, c2=0.3, b1=0.9, b2=0.95, eps=1e-8,
              weight_decay=0.1, param_dtype=jnp.bfloat16)
    ref_out = kref.fused_adamw_mixed(g, m, v, w, **kw)
    ker_out = kadamw.fused_adamw_mixed(g, m, v, w, interpret=True, **kw)
    assert ker_out[0].dtype == jnp.bfloat16    # working copy
    assert ker_out[3].dtype == jnp.float32     # master
    for r, k_ in zip(ref_out, ker_out):
        # f32 outputs must agree to float tolerance; bf16 outputs may
        # land one bf16 ulp apart when the f32 values straddle a
        # rounding boundary
        tol = dict(rtol=2e-6, atol=2e-6) if r.dtype == jnp.float32 \
            else dict(rtol=2.0 ** -7, atol=2.0 ** -7)
        np.testing.assert_allclose(
            np.asarray(r, np.float32), np.asarray(k_, np.float32),
            **tol)


def test_mixed_update_tree_dispatch():
    """adamw.update under a mixed policy: ref and interpret agree, the
    master is authoritative, and the working copy is its rounding."""
    pol = precision.make_policy("bfloat16", "float32")
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (37, 9))}
    grads = {"w": (jax.random.normal(jax.random.PRNGKey(1), (37, 9))
                   * 0.1).astype(jnp.bfloat16)}
    st = adamw.init(params, policy=pol)
    work = precision.cast_tree(params, pol.param_dtype)
    outs = {}
    for mode in ("ref", "interpret"):
        p2, st2 = adamw.update(grads, st, work, lr=1e-2, mode=mode,
                               policy=pol)
        assert p2["w"].dtype == jnp.bfloat16
        assert st2.master["w"].dtype == jnp.float32
        np.testing.assert_array_equal(
            np.asarray(p2["w"], np.float32),
            np.asarray(st2.master["w"].astype(jnp.bfloat16), np.float32))
        outs[mode] = (p2, st2)
    for a, b in zip(jax.tree.leaves(outs["ref"]),
                    jax.tree.leaves(outs["interpret"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-5, atol=2e-6)


def test_mixed_full_round_interpret_matches_ref(setup):
    """A full mixed-policy DiLoCo round through the mixed Pallas kernel
    (interpret) matches the jnp oracle path."""
    arch, loss_fn, sampler, params = setup
    states = {}
    for mode in ("ref", "interpret"):
        dcfg, tcfg = _cfgs(1, pd="bfloat16", md="float32",
                           kernel_mode=mode)
        rnd = diloco.make_round(loss_fn, sampler.sample_all_shards,
                                dcfg, tcfg, total_steps=H, batch_size=B,
                                seq_len=S)
        st, _ = rnd(diloco.init_state(params, dcfg),
                    jax.random.PRNGKey(3))
        states[mode] = st
    for a, b in zip(jax.tree.leaves(states["ref"]),
                    jax.tree.leaves(states["interpret"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# training behavior of the bf16 policy
# ---------------------------------------------------------------------------

def test_bf16_policy_loss_tracks_f32(setup):
    """The bf16 replica policy trains: losses stay finite and the final
    losses sit within a small gap of the f32 policy on the toy config."""
    arch, loss_fn, sampler, params = setup
    R = 3
    finals = {}
    for pd, md in (("float32", "float32"), ("bfloat16", "float32")):
        dcfg, tcfg = _cfgs(R, pd=pd, md=md)
        _, ms = _run(loss_fn, sampler, params, dcfg, tcfg, R)
        losses = np.asarray(ms["inner_loss"], np.float32)
        assert np.isfinite(losses).all()
        finals[pd] = float(losses[-1])
    assert abs(finals["bfloat16"] - finals["float32"]) < 0.05
    # training actually progressed under bf16
    dcfg, tcfg = _cfgs(R, pd="bfloat16", md="float32")
    _, ms = _run(loss_fn, sampler, params, dcfg, tcfg, R)
    losses = np.asarray(ms["inner_loss"], np.float32)
    assert losses[-1] < losses[0]


def test_mixed_outer_deltas_use_masters(setup):
    """The outer step reads the f32 masters, not the rounded bf16
    working copies: zero master drift ⇒ zero outer gradient even though
    the bf16 copies differ from the global params by rounding."""
    arch, loss_fn, sampler, params = setup
    dcfg, _ = _cfgs(1, pd="bfloat16", md="float32")
    st = diloco.init_state(params, dcfg)
    st2, m = diloco.outer_step(st, dcfg)
    # masters == global at init, so the averaged delta is exactly 0
    assert float(m["outer_gnorm"]) == 0.0
    for a, b in zip(jax.tree.leaves(st2.global_params),
                    jax.tree.leaves(st.global_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_mixed_donation_and_chunking(setup):
    """donate=True with the mixed state layout: repeated chunked calls
    reuse the donated carry, dtypes survive, caller params stay alive."""
    arch, loss_fn, sampler, params = setup
    R = 2
    dcfg, tcfg = _cfgs(2 * R, pd="bfloat16", md="float32")
    run = diloco.make_run(loss_fn, sampler.sample_all_shards, dcfg,
                          tcfg, rounds_per_call=R, total_steps=2 * R * H,
                          batch_size=B, seq_len=S, donate=True)
    state = diloco.init_state(params, dcfg)
    state, _ = run(state, jax.random.PRNGKey(1))
    state, ms = run(state, jax.random.PRNGKey(2))
    assert all(l.dtype == jnp.bfloat16
               for l in jax.tree.leaves(state.replica_params))
    assert all(l.dtype == jnp.float32
               for l in jax.tree.leaves(state.inner_state.master))
    assert np.isfinite(np.asarray(ms["inner_loss"], np.float32)).all()
    assert np.isfinite(float(jax.tree.leaves(params)[0].sum()))


def test_mixed_streaming_round_finite(setup):
    """Streaming (P=2, τ=1, α=0.5, int4) under the mixed policy: state
    stays finite, replicas stay bf16, masters stay f32."""
    arch, loss_fn, sampler, params = setup
    R = 3
    dcfg, tcfg = _cfgs(R, pd="bfloat16", md="float32",
                       streaming_fragments=2, stream_alpha=0.5,
                       stream_tau=1, outer_grad_dtype="int4")
    ss, ms = _run(loss_fn, sampler, params, dcfg, tcfg, R)
    assert np.all(np.asarray(ss.armed) == 1.0)
    for leaf in jax.tree.leaves(ss):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()
    assert all(l.dtype == jnp.bfloat16
               for l in jax.tree.leaves(ss.replica_params))
    assert all(l.dtype == jnp.float32
               for l in jax.tree.leaves(ss.inner_state.master))
    assert np.isfinite(np.asarray(ms["inner_loss"], np.float32)).all()


def test_single_worker_mixed_step(setup):
    """The pretraining/single-worker step under the mixed policy: the
    f32 master in the optimizer state is authoritative and the working
    params remain its bf16 rounding after every step."""
    arch, loss_fn, sampler, params = setup
    pol = precision.make_policy("bfloat16", "float32")
    tcfg = TrainConfig(inner_lr=3e-3, warmup_steps=2, total_steps=2 * H,
                       batch_size=B, seq_len=S, param_dtype="bfloat16",
                       master_dtype="float32")
    step = diloco.make_single_worker_step(loss_fn, tcfg,
                                          total_steps=2 * H)
    opt = adamw.init(params, policy=pol)
    work = precision.cast_tree(params, pol.param_dtype)
    batch = {"tokens": sampler.sample_validation(
        jax.random.PRNGKey(3), B, S)}
    for i in range(3):
        work, opt, m = step(work, opt, batch, jnp.asarray(i))
    assert np.isfinite(float(m["loss"]))
    for w, p in zip(jax.tree.leaves(adamw.master_params(work, opt)),
                    jax.tree.leaves(work)):
        assert w.dtype == jnp.float32 and p.dtype == jnp.bfloat16
        np.testing.assert_array_equal(
            np.asarray(w.astype(jnp.bfloat16), np.float32),
            np.asarray(p, np.float32))


# ---------------------------------------------------------------------------
# satellites: error-feedback transport, exact transport bytes
# ---------------------------------------------------------------------------

def test_error_feedback_kills_quantization_bias():
    """Sending the same delta through int4 transport over many rounds:
    without feedback the rounding bias persists forever; with the
    residual accumulator the *mean* transported value converges to the
    true delta (bias → 0, bounded by one quantization step / T)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 128)) * 0.7
    xs = np.asarray(x)
    scale = np.abs(xs).max(axis=1, keepdims=True) / 7.0    # int4 levels
    T = 64
    plain = np.asarray(kops.quant_roundtrip(x, "int4", mode="ref"))
    bias_plain = np.abs(plain - xs).max()
    res = jnp.zeros_like(x)
    acc = np.zeros_like(xs)
    for _ in range(T):
        q, res = streaming.quantize_with_feedback(x, res, "int4")
        acc += np.asarray(q)
    bias_ef = np.abs(acc / T - xs).max()
    # the residual is bounded by one quantization step, so the mean
    # bias decays like scale/T — far below the one-shot bias
    assert bias_ef <= (scale.max() + 1e-6) / T + 1e-7
    assert bias_ef < bias_plain / 10
    # float32 transport: feedback is exact pass-through
    q, res = streaming.quantize_with_feedback(x, jnp.zeros_like(x),
                                              "float32")
    np.testing.assert_array_equal(np.asarray(q), xs)
    assert float(jnp.abs(res).max()) == 0.0


def test_error_feedback_streaming_round(setup):
    """error_feedback=True threads through the streaming driver: the
    residual carry exists, is finite and non-zero after quantized
    sends, and is None when disabled or transport is f32."""
    arch, loss_fn, sampler, params = setup
    R = 2
    dcfg, tcfg = _cfgs(R, streaming_fragments=2, stream_alpha=0.5,
                       outer_grad_dtype="int4", error_feedback=True)
    ss, _ = _run(loss_fn, sampler, params, dcfg, tcfg, R)
    assert ss.residual is not None
    res_norm = sum(float(jnp.sum(jnp.abs(l)))
                   for l in jax.tree.leaves(ss.residual))
    assert np.isfinite(res_norm) and res_norm > 0.0
    leaf = jax.tree.leaves(ss.residual)[0]
    assert leaf.shape[0] == K                      # per-replica
    # off by default / meaningless for f32 transport -> no carry
    dcfg_off, _ = _cfgs(R, streaming_fragments=2,
                        outer_grad_dtype="int4")
    assert streaming.init_state(params, dcfg_off).residual is None
    dcfg_f32, _ = _cfgs(R, streaming_fragments=2, error_feedback=True)
    assert streaming.init_state(params, dcfg_f32).residual is None


def test_error_feedback_skips_dropped_replicas(setup):
    """A replica whose packet is dropped never sent anything, so its
    residual must not be consumed: it stays at its initial zeros while
    the communicating replica's residual becomes non-zero."""
    arch, loss_fn, sampler, params = setup
    R = 2
    dcfg, tcfg = _cfgs(R, streaming_fragments=2, stream_alpha=0.5,
                       outer_grad_dtype="int4", error_feedback=True)
    run = diloco.make_run(loss_fn, sampler.sample_all_shards, dcfg,
                          tcfg, rounds_per_call=R, total_steps=R * H,
                          batch_size=B, seq_len=S, donate=False)
    drops = np.ones((R, K), np.float32)
    drops[:, 1] = 0.0                      # replica 1 always dropped
    ss, _ = run(streaming.init_state(params, dcfg),
                jax.random.PRNGKey(5), jnp.asarray(drops))
    kept = sum(float(jnp.sum(jnp.abs(l[0])))
               for l in jax.tree.leaves(ss.residual))
    dropped = sum(float(jnp.sum(jnp.abs(l[1])))
                  for l in jax.tree.leaves(ss.residual))
    assert kept > 0.0
    assert dropped == 0.0


def test_partition_region_sizes_cover_fragments(setup):
    """region_sizes partitions each fragment's elements into per-leaf
    contiguous regions: regions sum to the fragment size and every
    region is positive (the wire-byte accounting unit)."""
    from repro.core import fragments
    _, _, _, params = setup
    for P in (1, 2, 4):
        part = fragments.partition_params(params, P)
        assert len(part.region_sizes) == P
        for size, regs in zip(part.sizes, part.region_sizes):
            assert sum(regs) == size
            assert all(e > 0 for e in regs)


def test_transport_bytes_counts_started_blocks():
    """int4 pays one f32 scale per *started* 128-element block — the
    ragged tail still ships a scale."""
    assert kops.transport_bytes(128, "int4") == 128 * 0.5 + 4.0
    assert kops.transport_bytes(129, "int4") == 129 * 0.5 + 2 * 4.0
    assert kops.transport_bytes(1, "int4") == 0.5 + 4.0
    assert kops.transport_bytes(256, "int4") == 256 * 0.5 + 2 * 4.0
    # non-blocked dtypes are linear
    assert kops.transport_bytes(1000, "float32") == 4000.0
    assert kops.transport_bytes(1001, "bfloat16") == 2002.0
    with pytest.raises(ValueError):
        kops.transport_bytes(10, "fp8")
