"""Resilience gates: the run must survive the PROCESS dying.

Every claim here is measured across real process boundaries — the
benchmark launches ``repro.launch.train`` subprocesses through
``repro.resilience.harness``, lets the injected Crash event SIGKILL
them mid-run, damages their snapshots on purpose, and compares the
``--state-hash-out`` JSONs bit-for-bit. Families of claims, written to
``BENCH_resilience.json``:

  resume   kill -9 at a round (tick) boundary, relaunch with
           ``--resume auto``: the final state is BITWISE identical to
           the uninterrupted run, on every transport —
           ``resume_bit_identical_{sync,streaming,sharded,gossip,
           async}``. Streaming runs int4 + error feedback (inflight
           packed buffers and residuals are the hardest carry);
           sharded runs real pod collectives on 8 forced CPU devices.

  durable  corrupting the newest snapshot (truncation — the classic
           mid-write kill artifact) makes ``--resume auto`` fall back
           to the previous verified snapshot and still reach the
           bit-identical final state (``corrupt_snapshot_falls_back``).

  guard    a scripted NaN bomb (worker 1, round 3) destroys an
           unguarded run (``nan_bomb_unguarded_poisons`` — the honesty
           control) but with the in-graph guard the final loss lands
           within ``LOSS_GAP`` of clean (``nan_bomb_guard_within_gap``)
           and with the host-side guard + snapshots the run detects
           the anomaly, rolls back, replays guarded and recovers
           (``nan_bomb_rollback_recovers``). Resilience must also be
           FREE when nothing fails: a guarded clean run and a
           checkpoint-enabled clean run are bit-identical to the plain
           one (``guard_clean_run_bit_identical``,
           ``checkpoint_hooks_bit_identical``) and the scanned driver
           still materializes metrics exactly once per chunk
           (``one_ingest_per_chunk_with_resilience``).

  elastic  a pods=2 run's snapshot resumed on a pods=4 mesh finishes
           with the same validation loss as a clean pods=4 run
           (``elastic_resume_matches_loss``) — cross-pod reduction
           order changes the bits, not the math.

Run:  PYTHONPATH=src python -m benchmarks.resilience
"""
from __future__ import annotations

import argparse
import json
import math
import os
import shutil
import sys
import tempfile
import time

from repro.resilience import harness

ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
OUT_PATH = os.path.join(ROOT, "BENCH_resilience.json")

# |final loss - clean final loss| bound for the guarded NaN-bomb runs:
# the guard turns the bombed round into a skipped contribution, so the
# run loses one replica-round of evidence, not its trajectory
LOSS_GAP = 0.05

BASE = ["--arch", "diloco_60m", "--smoke", "--k", "4", "--H", "4",
        "--batch", "4", "--seq", "32", "--eval-batch", "8"]
ROUND_BASE = BASE + ["--rounds", "6", "--rounds-per-call", "3"]
CKPT = ["--checkpoint-every", "2"]

TRANSPORTS = {
    "sync": ([], None),
    "streaming": (["--stream-fragments", "2", "--stream-tau", "2",
                   "--outer-grad-dtype", "int4", "--error-feedback"],
                  None),
    "sharded": (["--transport", "sharded", "--stream-fragments", "2",
                 "--pods", "4"], 8),
    "gossip": (["--transport", "gossip"], None),
}
ASYNC_FLAGS = BASE + ["--transport", "async", "--ticks", "12",
                      "--speeds", "1,1,2,1"]


def _hash_json(work: str, name: str) -> str:
    return os.path.join(work, name + ".json")


def kill_resume_cycle(work, name, flags, devices, *, crash, ckpt_every):
    """clean -> crash (SIGKILL) -> --resume auto, returning the two
    hash-out payloads and the checkpoint dir for further abuse."""
    ckdir = os.path.join(work, name + "_ck")
    clean = _hash_json(work, name + "_clean")
    resumed = _hash_json(work, name + "_resumed")
    harness.run_train(flags + ["--state-hash-out", clean],
                      devices=devices)
    harness.run_until_crash(
        flags + ["--checkpoint-dir", ckdir,
                 "--checkpoint-every", str(ckpt_every)] + crash,
        devices=devices)
    harness.run_train(
        flags + ["--checkpoint-dir", ckdir,
                 "--checkpoint-every", str(ckpt_every),
                 "--resume", "auto", "--state-hash-out", resumed],
        devices=devices)
    return harness.read_json(clean), harness.read_json(resumed), ckdir


def _finite(x) -> bool:
    return isinstance(x, (int, float)) and math.isfinite(x)


def run(out: str = OUT_PATH, keep_dir: str = "") -> dict:
    t_start = time.time()
    work = keep_dir or tempfile.mkdtemp(prefix="bench_res_")
    os.makedirs(work, exist_ok=True)
    report: dict = {"work_dir": work if keep_dir else "(temp)",
                    "loss_gap": LOSS_GAP, "rows": {}}
    claims: dict = {}
    try:
        # ---- kill -9 + auto-resume on every transport ----------------
        sync_clean = None
        sync_ckdir = None
        for name, (extra, devices) in TRANSPORTS.items():
            t0 = time.time()
            clean, resumed, ckdir = kill_resume_cycle(
                work, name, ROUND_BASE + extra, devices,
                crash=["--crash-at-round", "3"], ckpt_every=2)
            ok = (clean["state_sha256"] == resumed["state_sha256"]
                  and resumed["resumed_from_step"] >= 0)
            claims[f"resume_bit_identical_{name}"] = bool(ok)
            report["rows"][name] = {
                "clean_sha256": clean["state_sha256"],
                "resumed_sha256": resumed["state_sha256"],
                "resumed_from_step": resumed["resumed_from_step"],
                "final_val_loss": clean["final_val_loss"],
                "seconds": round(time.time() - t0, 1)}
            print(f"[resume] {name}: "
                  f"{'MATCH' if ok else 'MISMATCH'} from step "
                  f"{resumed['resumed_from_step']}")
            if name == "sync":
                sync_clean, sync_ckdir = clean, ckdir
            if name == "sharded":
                sharded_clean = clean

        t0 = time.time()
        clean, resumed, _ = kill_resume_cycle(
            work, "async", ASYNC_FLAGS, None,
            crash=["--crash-at-tick", "7"], ckpt_every=5)
        ok = (clean["state_sha256"] == resumed["state_sha256"]
              and resumed["resumed_from_step"] >= 0)
        claims["resume_bit_identical_async"] = bool(ok)
        report["rows"]["async"] = {
            "clean_sha256": clean["state_sha256"],
            "resumed_sha256": resumed["state_sha256"],
            "resumed_from_step": resumed["resumed_from_step"],
            "events_done": clean["events_done"],
            "seconds": round(time.time() - t0, 1)}
        print(f"[resume] async: {'MATCH' if ok else 'MISMATCH'} from "
              f"step {resumed['resumed_from_step']}")

        # ---- corrupt the newest snapshot: fall back, still exact -----
        newest_before = max(
            int(n[5:13]) for n in os.listdir(sync_ckdir)
            if n.startswith("ckpt_") and n.endswith(".npz"))
        harness.corrupt_latest(sync_ckdir, mode="truncate")
        fb = _hash_json(work, "sync_fallback")
        harness.run_train(ROUND_BASE + [
            "--checkpoint-dir", sync_ckdir] + CKPT + [
            "--resume", "auto", "--state-hash-out", fb])
        fb = harness.read_json(fb)
        claims["corrupt_snapshot_falls_back"] = bool(
            fb["resumed_from_step"] < newest_before
            and fb["resumed_from_step"] >= 0
            and fb["state_sha256"] == sync_clean["state_sha256"])
        report["rows"]["corrupt_fallback"] = {
            "corrupted_step": newest_before,
            "resumed_from_step": fb["resumed_from_step"]}
        print(f"[durable] corrupt fallback: resumed from "
              f"{fb['resumed_from_step']} (corrupted {newest_before})")

        # ---- resilience hooks are free on clean runs -----------------
        g = _hash_json(work, "sync_guard_outer")
        harness.run_train(ROUND_BASE + ["--guard-outer",
                                        "--state-hash-out", g])
        g = harness.read_json(g)
        claims["guard_clean_run_bit_identical"] = bool(
            g["state_sha256"] == sync_clean["state_sha256"])

        r = _hash_json(work, "sync_resilient_clean")
        harness.run_train(ROUND_BASE + [
            "--checkpoint-dir", os.path.join(work, "sync_free_ck"),
            "--guard"] + CKPT + ["--state-hash-out", r])
        r = harness.read_json(r)
        claims["checkpoint_hooks_bit_identical"] = bool(
            r["state_sha256"] == sync_clean["state_sha256"])
        # rounds=6 with --checkpoint-every 2 caps chunks at 2 rounds:
        # exactly ceil(6/2)=3 chunks, one metrics ingest each (the
        # plain run does ceil(6/3)=2) — the guard reads metrics the
        # boundary already materialized, adding no host syncs
        claims["one_ingest_per_chunk_with_resilience"] = bool(
            r["ingest_calls"] == 3
            and sync_clean["ingest_calls"] == 2)
        report["rows"]["free_when_clean"] = {
            "plain_ingests": sync_clean["ingest_calls"],
            "resilient_ingests": r["ingest_calls"]}
        print(f"[free] guard/ckpt clean runs bit-identical="
              f"{claims['checkpoint_hooks_bit_identical']}, ingests "
              f"{sync_clean['ingest_calls']}->{r['ingest_calls']}")

        # ---- NaN bomb: unguarded dies, guarded survives --------------
        bomb = ["--nan-bomb", "1:3"]
        nb0 = _hash_json(work, "bomb_unguarded")
        harness.run_train(ROUND_BASE + bomb + ["--state-hash-out", nb0])
        nb0 = harness.read_json(nb0)
        claims["nan_bomb_unguarded_poisons"] = bool(
            not _finite(nb0["final_val_loss"]))

        nb1 = _hash_json(work, "bomb_guarded")
        harness.run_train(ROUND_BASE + bomb + ["--guard-outer",
                                               "--state-hash-out", nb1])
        nb1 = harness.read_json(nb1)
        gap1 = (abs(nb1["final_val_loss"] - sync_clean["final_val_loss"])
                if _finite(nb1["final_val_loss"]) else float("inf"))
        claims["nan_bomb_guard_within_gap"] = bool(gap1 <= LOSS_GAP)

        nb2 = _hash_json(work, "bomb_rollback")
        harness.run_train(ROUND_BASE + bomb + [
            "--guard", "--checkpoint-dir",
            os.path.join(work, "bomb_ck")] + CKPT + [
            "--state-hash-out", nb2])
        nb2 = harness.read_json(nb2)
        gap2 = (abs(nb2["final_val_loss"] - sync_clean["final_val_loss"])
                if _finite(nb2["final_val_loss"]) else float("inf"))
        claims["nan_bomb_rollback_recovers"] = bool(
            nb2["rollbacks"] >= 1 and gap2 <= LOSS_GAP)
        report["rows"]["nan_bomb"] = {
            "clean_val_loss": sync_clean["final_val_loss"],
            "unguarded_val_loss": nb0["final_val_loss"],
            "guarded_val_loss": nb1["final_val_loss"],
            "rollback_val_loss": nb2["final_val_loss"],
            "rollbacks": nb2["rollbacks"]}
        print(f"[guard] bomb: unguarded={nb0['final_val_loss']} "
              f"guarded gap={gap1:.4f} rollback gap={gap2:.4f} "
              f"({nb2['rollbacks']} rollbacks)")

        # ---- elastic: pods=2 snapshot resumed on a pods=4 mesh -------
        p2 = ROUND_BASE + ["--transport", "sharded",
                           "--stream-fragments", "2", "--pods", "2"]
        p4 = ROUND_BASE + ["--transport", "sharded",
                           "--stream-fragments", "2", "--pods", "4"]
        eck = os.path.join(work, "elastic_ck")
        harness.run_until_crash(
            p2 + ["--checkpoint-dir", eck] + CKPT + [
                "--crash-at-round", "3"], devices=8)
        el = _hash_json(work, "elastic_resumed")
        harness.run_train(
            p4 + ["--checkpoint-dir", eck] + CKPT + [
                "--resume", "auto", "--state-hash-out", el], devices=8)
        el = harness.read_json(el)
        # cross-pod psum order changes bits, not math: gate the loss
        # (the sharded row above already gates same-pods bit identity)
        elastic_gap = abs(el["final_val_loss"]
                          - sharded_clean["final_val_loss"])
        claims["elastic_resume_matches_loss"] = bool(
            el["resumed_from_step"] >= 0 and elastic_gap <= 1e-6)
        report["rows"]["elastic"] = {
            "pods2_resumed_on_pods4_val_loss": el["final_val_loss"],
            "clean_pods4_val_loss": sharded_clean["final_val_loss"],
            "gap": elastic_gap,
            "resumed_from_step": el["resumed_from_step"]}
        print(f"[elastic] pods 2->4 loss gap = {elastic_gap:.2e}")
    finally:
        if not keep_dir:
            shutil.rmtree(work, ignore_errors=True)

    report["claims"] = claims
    report["total_s"] = round(time.time() - t_start, 1)
    with open(out, "w") as f:
        json.dump(report, f, indent=1)
    print("wrote", out)
    return report


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=OUT_PATH)
    ap.add_argument("--keep-dir", default="",
                    help="keep checkpoints/hash JSONs here instead of "
                         "a deleted temp dir")
    a = ap.parse_args(argv)
    report = run(out=a.out, keep_dir=a.keep_dir)
    bad = [k for k, v in report["claims"].items() if not v]
    if bad:
        print("FAILED claims:", ", ".join(bad))
        return 1
    print("all claims hold:", ", ".join(sorted(report["claims"])))
    return 0


if __name__ == "__main__":
    sys.exit(main())
