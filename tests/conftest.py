import os

# Tests see the single real CPU device (the dry-run, and ONLY the
# dry-run, forces 512 fake devices — in its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_enable_x64", False)
