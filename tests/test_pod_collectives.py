"""Multi-device test subsystem for the sharded streaming transport
(core/pod_collectives.py + the transport="sharded" path through
core/streaming.py), on the 8 fake CPU devices tests/conftest.py forces.

What is pinned here:
  * EQUIVALENCE — with one replica per pod (the paper's deployment:
    the "pod" mesh axis IS the replica axis) the sharded transport is
    *bit-identical* to the simulated transport for f32, P ∈ {1, 2, 4},
    across drop masks, mid-run joins and τ-overlap; the quantized
    transports (bf16/int4) gather per-pod payloads whose scale blocks
    are identical to the simulated path's, but XLA re-fuses the
    quantize math into different surroundings, so agreement is within
    quant-error bounds (a near-tie element may round to the adjacent
    code). Banded pods (k > pods) regroup the f32 psum's partial sums
    and agree to float tolerance.
  * QUANT STRUCTURE — int4 scale blocks are formed per replica on each
    pod's local shard, so a pod with tiny deltas is never flattened by
    a neighbor pod's large amax (the blocks-never-mix-pods property).
  * ROBUSTNESS (paper §"robust to resources becoming unavailable") —
    worker dropout and mid-run joins on the sharded path preserve the
    dropped pod's error-feedback residual and AdamW moments pod-locally
    and keep the loss improving.
  * HLO STRUCTURE — the compiled scanned round contains ≥ P pod-axis
    all-reduces *interleaved* with inner-step compute (not clustered at
    round end), and zero cross-pod collectives inside the inner-step
    scan bodies (launch/hlo_analysis.stream_interleaving).
  * PACKED WIRE — the default quantized sharded transport coalesces
    every fragment's leaf regions into ONE packed codes+scales buffer
    and all-gathers it once per fragment per sync; the gathered bytes
    in the lowered HLO equal k × the packed static model, the values
    match the simulated transport within the quant-error bound (bf16
    bitwise), and the pack_wire=False legacy transport stays live.
  * SCHEDULE × PARTITION properties (hypothesis) — every parameter
    element of every communicating replica is reduced exactly once per
    round for arbitrary P, non-divisible H, override patterns and pod
    bandings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import DiLoCoConfig, TrainConfig, ModelConfig
from repro.core import diloco, fragments, pod_collectives, streaming
from repro.data.sharding import make_regime
from repro.kernels import ops as kops
from repro.launch import hlo_analysis as H_hlo
from repro.launch.mesh import make_mesh, pods_of
from repro.models.registry import Arch

H, B, S, VOCAB = 4, 2, 16, 64

# Deliberately NO module-level skip on the device count: if
# tests/conftest.py regresses (jax initialized before it sets
# XLA_FLAGS), this whole suite must FAIL loudly, not silently skip and
# leave tier-1 green with the sharded-transport coverage gone.


def test_conftest_provides_fake_devices():
    """Guards the conftest XLA_FLAGS fix: if any import initializes jax
    before conftest sets the flag, every test in this module fails —
    this one first, with the diagnosis in its message."""
    assert len(jax.devices()) >= 8, (
        "tests/conftest.py no longer forces "
        "--xla_force_host_platform_device_count=8 before jax "
        "initializes — the multi-device suite cannot run")
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    assert pods_of(mesh) == 2
    assert pod_collectives.pods_of(mesh) == 2


@pytest.fixture(scope="module")
def setup():
    cfg = ModelConfig(name="tiny", family="dense", n_layers=2,
                      d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                      vocab_size=VOCAB, remat=False, attn_chunk=32)
    arch = Arch(cfg=cfg)
    loss_fn = lambda p, b: arch.loss(p, b)
    params, _ = arch.init(jax.random.PRNGKey(0), cfg)
    return arch, loss_fn, params


def _tcfg(rounds):
    return TrainConfig(inner_lr=3e-3, warmup_steps=2,
                       total_steps=rounds * H, batch_size=B, seq_len=S)


def _masks(R, k, *, seed=0, join_last=True):
    """0/1 drop masks (replica 0 always communicates) plus an
    active-mask schedule where the last replica joins after round 1."""
    rng = np.random.default_rng(seed)
    drops = (rng.random((R, k)) >= 0.4).astype(np.float32)
    drops[:, 0] = 1.0
    acts = np.ones((R, k), np.float32)
    if join_last:
        acts[0, k - 1] = 0.0
    return jnp.asarray(drops), jnp.asarray(acts)


def _pod_mesh(pods):
    return make_mesh((pods, 8 // pods), ("pod", "data"))


def _run_pair(loss_fn, params, dcfg_kw, tcfg, *, pods, R, drops, acts,
              weights=None):
    """(simulated state+metrics, sharded state+metrics) for one config."""
    sampler = make_regime("non_iid", k=dcfg_kw["k"], vocab_size=VOCAB,
                          seed=0)
    sim_cfg = DiLoCoConfig(**dcfg_kw)
    run = diloco.make_run(loss_fn, sampler.sample_all_shards, sim_cfg,
                          tcfg, rounds_per_call=R, total_steps=R * H,
                          batch_size=B, seq_len=S, donate=False)
    sim = run(streaming.init_state(params, sim_cfg),
              jax.random.PRNGKey(5), drops, acts, weights)

    sh_cfg = DiLoCoConfig(transport="sharded", **dcfg_kw)
    mesh = _pod_mesh(pods)
    run_s = diloco.make_run(loss_fn, sampler.sample_all_shards, sh_cfg,
                            tcfg, rounds_per_call=R, total_steps=R * H,
                            batch_size=B, seq_len=S, donate=False,
                            mesh=mesh)
    state0 = pod_collectives.shard_stream_state(
        streaming.init_state(params, sh_cfg), mesh)
    sh = run_s(state0, jax.random.PRNGKey(5), drops, acts, weights)
    return sim, sh


def _assert_state_bitwise(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _assert_states_quant_close(sim_st, sh_st, params, kw, *, dt,
                               rtol=5e-3, atol=5e-3):
    """Compare a simulated vs sharded StreamState within quant error.

    The deferred in-flight slot (quantized, τ>0) holds each transport's
    own RAW representation — the packed byte wire on the packed sharded
    transport, the stacked f32 payload elsewhere — so it is compared
    through its DECODED per-replica values rather than leaf-by-leaf:
    the last round's wrapped send is still in flight at the state
    boundary, and this checks the sharded wire decodes to the simulated
    payload (every earlier send is covered via pending/params once its
    apply consumed it)."""
    for la, lb in zip(jax.tree.leaves(sim_st._replace(inflight=None)),
                      jax.tree.leaves(sh_st._replace(inflight=None))):
        np.testing.assert_allclose(np.asarray(la, np.float32),
                                   np.asarray(lb, np.float32),
                                   rtol=rtol, atol=atol)
    if sim_st.inflight is None:
        assert sh_st.inflight is None
        return
    P = kw["streaming_fragments"]
    part = fragments.partition_params(params, P)
    regs = fragments.fragment_regions(part, params)
    leaves = jax.tree_util.tree_leaves
    for p, (es, eh) in enumerate(zip(sim_st.inflight,
                                     sh_st.inflight)):
        if es is None and eh is None:
            continue
        np.testing.assert_array_equal(np.asarray(es[1]),
                                      np.asarray(eh[1]))  # mask snap
        sim_payload = es[0]
        if kw.get("pack_wire", True):
            wire = np.asarray(eh[0])
            off = 0
            for r in regs[p]:
                W = kops.wire_elems(r.elems, dt)
                dec = np.stack([np.asarray(kops.wire_decode(
                    jnp.asarray(w), r.elems, dt, mode="ref"))
                    for w in wire[:, off:off + W]])
                off += W
                ref_vals = np.asarray(fragments.region_take(
                    sim_payload[r.leaf], r, lead_axes=1))
                np.testing.assert_allclose(dec, ref_vals,
                                           rtol=rtol, atol=atol)
        else:
            for ls, lh in zip(sim_payload, eh[0]):
                assert (ls is None) == (lh is None)
                if ls is not None:
                    np.testing.assert_allclose(
                        np.asarray(ls), np.asarray(lh),
                        rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# equivalence: sharded ≡ simulated
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pods", [2, 4])
@pytest.mark.parametrize("P", [1, 2, 4])
def test_sharded_f32_bit_identical(setup, P, pods):
    """One replica per pod, f32 transport: the per-fragment psum
    all-reduce is bit-identical to the simulated stacked tensordot —
    masked 0/1 products are exact, so only the (matching) accumulation
    order is in play. Covers drop masks, a mid-run join, and τ-overlap
    with α-mixing for P > 1."""
    arch, loss_fn, params = setup
    R, k = 3, pods
    drops, acts = _masks(R, k)
    tau = 0 if P == 1 else 1
    alpha = 1.0 if P == 1 else 0.5
    kw = dict(k=k, H=H, streaming_fragments=P, stream_tau=tau,
              stream_alpha=alpha)
    sim, sh = _run_pair(loss_fn, params, kw, _tcfg(R), pods=pods, R=R,
                        drops=drops, acts=acts)
    _assert_state_bitwise(sim[0], sh[0])
    for key in ("outer_gnorm", "drop_frac"):
        np.testing.assert_array_equal(np.asarray(sim[1][key]),
                                      np.asarray(sh[1][key]))
    np.testing.assert_allclose(np.asarray(sim[1]["inner_loss"]),
                               np.asarray(sh[1]["inner_loss"]),
                               rtol=1e-6)


@pytest.mark.parametrize("dt", ["bfloat16", "int4"])
def test_sharded_quantized_within_quant_error(setup, dt):
    """Quantized transports gather the per-pod payloads and reduce
    locally: the payloads are identical to the simulated path's (scale
    blocks never mix pods), but XLA re-fuses the quantize math into
    different surroundings, so a near-tie element may round to the
    adjacent code — sharded and simulated states agree within a few
    transport quantization steps (the satellite's quant-error bound),
    and both stay finite and training."""
    arch, loss_fn, params = setup
    R, k, pods, P = 3, 4, 4, 2
    drops, acts = _masks(R, k)
    kw = dict(k=k, H=H, streaming_fragments=P, stream_tau=1,
              stream_alpha=0.5, outer_grad_dtype=dt, error_feedback=True)
    sim, sh = _run_pair(loss_fn, params, kw, _tcfg(R), pods=pods, R=R,
                        drops=drops, acts=acts)
    _assert_states_quant_close(sim[0], sh[0], params, kw, dt=dt)
    assert np.isfinite(np.asarray(sh[1]["inner_loss"])).all()
    np.testing.assert_allclose(np.asarray(sim[1]["inner_loss"]),
                               np.asarray(sh[1]["inner_loss"]),
                               rtol=1e-2)


def test_sharded_banded_pods_within_tolerance(setup):
    """k=4 replicas on 2 pods (two-replica bands): the f32 psum now
    adds pre-reduced band partials, which regroups the simulated FMA
    chain — equal to float tolerance, not bitwise (documented)."""
    arch, loss_fn, params = setup
    R, k, pods = 2, 4, 2
    drops, acts = _masks(R, k)
    kw = dict(k=k, H=H, streaming_fragments=2, stream_tau=1,
              stream_alpha=0.5)
    sim, sh = _run_pair(loss_fn, params, kw, _tcfg(R), pods=pods, R=R,
                        drops=drops, acts=acts)
    for la, lb in zip(jax.tree.leaves(sim[0]), jax.tree.leaves(sh[0])):
        np.testing.assert_allclose(np.asarray(la, np.float32),
                                   np.asarray(lb, np.float32),
                                   rtol=2e-5, atol=2e-6)


def test_sharded_fractional_weights_within_tolerance(setup):
    """Shard-size weights are fractional, so the masked products round
    before the wire: psum and the simulated FMA'd tensordot agree to
    ~1 ulp per element (exactness needs 0/1 masks — documented)."""
    arch, loss_fn, params = setup
    R, k, pods = 2, 2, 2
    drops, acts = _masks(R, k, join_last=False)
    weights = jnp.asarray([0.75, 0.25])
    kw = dict(k=k, H=H, streaming_fragments=2, stream_tau=1,
              stream_alpha=0.5)
    sim, sh = _run_pair(loss_fn, params, kw, _tcfg(R), pods=pods, R=R,
                        drops=drops, acts=acts, weights=weights)
    for la, lb in zip(jax.tree.leaves(sim[0]), jax.tree.leaves(sh[0])):
        np.testing.assert_allclose(np.asarray(la, np.float32),
                                   np.asarray(lb, np.float32),
                                   rtol=2e-5, atol=2e-6)


# ---------------------------------------------------------------------------
# int4 scale blocks never mix pods
# ---------------------------------------------------------------------------

def test_int4_scale_blocks_are_pod_local():
    """A pod holding tiny deltas next to a pod holding huge deltas: if
    any scale block mixed the two pods, the tiny pod's values would
    quantize to zero. The transport quantizes per replica on the local
    shard, so the tiny pod's payload survives with its own amax."""
    mesh = _pod_mesh(2)
    big = np.full((1, 256), 1000.0, np.float32)
    tiny = np.full((1, 256), 1e-3, np.float32)
    d = jnp.asarray(np.concatenate([big, tiny]))            # (k=2, 256)

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P, NamedSharding

    def body(d_local):
        q = jax.vmap(lambda x: kops.quant_roundtrip(x, "int4"))(d_local)
        return jax.lax.all_gather(q, "pod", axis=0, tiled=True)

    fn = jax.jit(shard_map(body, mesh=mesh, in_specs=(P("pod"),),
                           out_specs=P(), check_rep=False))
    out = np.asarray(fn(jax.device_put(
        d, NamedSharding(mesh, P("pod")))))
    # per-replica blocks: every element within amax/14 of its own value
    assert np.abs(out[0] - 1000.0).max() <= 1000.0 / 13.99
    assert np.abs(out[1] - 1e-3).max() <= 1e-3 / 13.99
    assert (out[1] != 0).all()            # a mixed block would zero it
    # and the wire payload equals the simulated per-replica round trip
    sim = np.asarray(jax.vmap(
        lambda x: kops.quant_roundtrip(x, "int4"))(d))
    np.testing.assert_array_equal(out, sim)


# ---------------------------------------------------------------------------
# robustness: dropout + mid-run join on the sharded path
# ---------------------------------------------------------------------------

def test_sharded_drop_preserves_pod_local_state(setup):
    """Round 2 drops replica 1's outer packet entirely: its
    error-feedback residual must NOT be consumed (it never sent) and
    its AdamW moments must keep evolving pod-locally (it keeps
    training on its own params — Fig 8 semantics), while loss keeps
    improving through the drop."""
    arch, loss_fn, params = setup
    k = pods = 2
    sampler = make_regime("non_iid", k=k, vocab_size=VOCAB, seed=0)
    dcfg = DiLoCoConfig(k=k, H=H, streaming_fragments=2, stream_tau=1,
                        stream_alpha=0.5, outer_grad_dtype="int4",
                        error_feedback=True, transport="sharded")
    mesh = _pod_mesh(pods)
    tcfg = _tcfg(4)
    run1 = diloco.make_run(loss_fn, sampler.sample_all_shards, dcfg,
                           tcfg, rounds_per_call=1, total_steps=4 * H,
                           batch_size=B, seq_len=S, donate=False,
                           mesh=mesh)
    state = pod_collectives.shard_stream_state(
        streaming.init_state(params, dcfg), mesh)
    key = jax.random.PRNGKey(5)
    ones = jnp.ones((1, k), jnp.float32)
    drop_r2 = jnp.asarray([[1.0, 0.0]], jnp.float32)

    # round 1: everyone communicates (arms fragments, seeds residuals)
    state, m1 = run1(state, key, ones, ones)
    key = m1["next_key"]
    res_before = jax.tree.map(
        lambda r: np.asarray(r)[1].copy(), state.residual)
    mom_before = jax.tree.map(
        lambda r: np.asarray(r)[1].copy(), state.inner_state.m)

    # round 2: replica 1 dropped
    state, m2 = run1(state, key, drop_r2, ones)
    key = m2["next_key"]
    # dropped replica's residual survives every send event untouched
    # where it had pending error (it consumed nothing, sent nothing)
    changed = [not np.array_equal(np.asarray(r)[1], rb) for r, rb in zip(
        jax.tree.leaves(state.residual),
        jax.tree.leaves(res_before))]
    assert not any(changed), "dropped pod's residual was consumed"
    # but its inner moments kept training pod-locally
    assert any(not np.array_equal(np.asarray(r)[1], mb) for r, mb in zip(
        jax.tree.leaves(state.inner_state.m),
        jax.tree.leaves(mom_before)))

    # rounds 3-4: replica 1 rejoins; loss keeps improving vs round 1
    state, m3 = run1(state, m2["next_key"], ones, ones)
    state, m4 = run1(state, m3["next_key"], ones, ones)
    l1 = float(np.asarray(m1["inner_loss"])[-1])
    l4 = float(np.asarray(m4["inner_loss"])[-1])
    assert np.isfinite(l4) and l4 < l1
    for leaf in jax.tree.leaves(state):
        assert np.isfinite(np.asarray(leaf)).all()


def test_sharded_mid_run_join_parks_then_merges(setup):
    """A replica inactive in round 1 (mid-run capacity join): it parks
    on the merged fragments, joins the pool from round 2 on, and the
    run matches the simulated path bit-for-bit throughout."""
    arch, loss_fn, params = setup
    R = 3
    k = pods = 4
    drops = jnp.ones((R, k), jnp.float32)
    acts = np.ones((R, k), np.float32)
    acts[0, 3] = 0.0                       # replica 3 joins in round 2
    kw = dict(k=k, H=H, streaming_fragments=2, stream_tau=1,
              stream_alpha=0.5)
    sim, sh = _run_pair(loss_fn, params, kw, _tcfg(R), pods=pods, R=R,
                        drops=drops, acts=jnp.asarray(acts))
    _assert_state_bitwise(sim[0], sh[0])
    losses = np.asarray(sh[1]["inner_loss"])
    assert np.isfinite(losses).all() and losses[-1] < losses[0]


# ---------------------------------------------------------------------------
# HLO structure: real all-reduces, interleaved, none inside inner steps
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_hlo_pod_all_reduces_interleave(setup):
    """Compile the scanned sharded round on a (2,2,2) mesh and assert
    the paper's overlap structure on the HLO itself: ≥ P pod-crossing
    all-reduces in the round body, all but the round-final fragment's
    followed by inner-step compute (a re-serialized implementation
    would cluster them at round end with 0 compute after), and zero
    cross-pod collectives inside the inner-step scan loops."""
    arch, loss_fn, params = setup
    P_frag = 4
    k = pods = 2
    sampler = make_regime("non_iid", k=k, vocab_size=VOCAB, seed=0)
    dcfg = DiLoCoConfig(k=k, H=H, streaming_fragments=P_frag,
                        transport="sharded")
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    run = diloco.make_run(loss_fn, sampler.sample_all_shards, dcfg,
                          _tcfg(2), rounds_per_call=2, total_steps=2 * H,
                          batch_size=B, seq_len=S, donate=False,
                          mesh=mesh)
    state = pod_collectives.shard_stream_state(
        streaming.init_state(params, dcfg), mesh)
    hlo = run.lower(state, jax.random.PRNGKey(5)).compile().as_text()
    st = H_hlo.stream_interleaving(hlo, chips_per_pod=4)
    assert st["pod_all_reduces"] >= P_frag, st
    assert st["compute_events"] > 0, st
    assert st["syncs_with_compute_after"] >= P_frag - 1, st
    assert st["syncs_inside_compute"] == 0, st
    # and the generic collective accounting sees cross-pod bytes
    coll = H_hlo.collective_stats(hlo, chips_per_pod=4)
    assert coll.cross_pod_bytes > 0


# ---------------------------------------------------------------------------
# packed wire: coalesced per-fragment gathers of real codes+scales
# ---------------------------------------------------------------------------

def _toy_tree(seed=0):
    rng = np.random.default_rng(seed)
    mk = lambda *s: jnp.asarray(rng.normal(size=s).astype(np.float32))
    return {"embed": mk(7, 4), "stack_w": mk(5, 3, 2),
            "stack_b": mk(5, 2), "head": mk(4, 3)}


def _packed_mean_tree(params, d, m, P, pods, dt):
    """Pending tree from the packed transport: per fragment, encode
    every region of the local band, concatenate, ONE gather_wire,
    decode + masked mean — the exact op sequence of
    streaming.packed_send, at the wire level."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as Pspec

    part = fragments.partition_params(params, P)
    regions = fragments.fragment_regions(part, params)
    denom = jnp.maximum(m.sum(), 1e-9)
    mesh = _pod_mesh(pods)
    treedef = jax.tree_util.tree_structure(params)

    def body(d_loc):
        leaves_d = jax.tree_util.tree_leaves(d_loc)
        pend = [jnp.zeros(l.shape[1:], jnp.float32) for l in leaves_d]
        for regs in regions:
            wires = [jax.vmap(lambda v: kops.wire_encode(
                v, dt, mode="ref")[0])(
                fragments.region_take(leaves_d[r.leaf], r, lead_axes=1))
                for r in regs]
            g = pod_collectives.gather_wire(
                jnp.concatenate(wires, axis=1))
            off = 0
            for r in regs:
                W = kops.wire_elems(r.elems, dt)
                vals = jax.vmap(lambda w: kops.wire_decode(
                    w, r.elems, dt, mode="ref"))(g[:, off:off + W])
                off += W
                a = jnp.tensordot(m, vals, axes=(0, 0)) / denom
                pend[r.leaf] = fragments.region_put(pend[r.leaf], r, a)
        return jax.tree_util.tree_unflatten(treedef, pend)

    fn = jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: Pspec("pod"), d),),
        out_specs=jax.tree.map(lambda _: Pspec(), params),
        check_rep=False))
    return fn(d)


@pytest.mark.parametrize("pods", [2, 4])
@pytest.mark.parametrize("P", [1, 2, 4])
def test_packed_wire_mean_matches_simulated(P, pods):
    """Packed-wire reduction vs the simulated transport across
    P ∈ {1,2,4} × pods ∈ {2,4}: bf16 payload values are exact on the
    wire, so the reduced means agree to reassociation (XLA lowers the
    (k,)·(k,region) dot with a different accumulation blocking than
    the (k,)·(k,leaf-shape) reference — ~1 ulp); int4 agrees within
    the transport's own quant-error bound — region-wise scale blocks
    may cut a leaf's 128-block lattice differently than the simulated
    whole-leaf blocks, shifting each side at most amax/14 from the
    true delta."""
    params = _toy_tree()
    k = pods
    rng = np.random.default_rng(P * 10 + pods)
    d = jax.tree.map(lambda l: jnp.asarray(
        rng.normal(size=(k,) + l.shape).astype(np.float32)), params)
    m = jnp.asarray((rng.random(k) > 0.3).astype(np.float32))
    m = m.at[0].set(1.0)
    denom = jnp.maximum(m.sum(), 1e-9)

    def simulated(dt):
        q = jax.tree.map(lambda l: jax.vmap(
            lambda v: kops.quant_roundtrip(v, dt, mode="ref"))(l), d)
        return jax.tree.map(
            lambda l: jnp.tensordot(m, l, axes=(0, 0)) / denom, q)

    got = _packed_mean_tree(params, d, m, P, pods, "bfloat16")
    for a, b in zip(jax.tree.leaves(simulated("bfloat16")),
                    jax.tree.leaves(got)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)

    got = _packed_mean_tree(params, d, m, P, pods, "int4")
    for leaf, a, b in zip(jax.tree.leaves(d),
                          jax.tree.leaves(simulated("int4")),
                          jax.tree.leaves(got)):
        bound = float(jnp.max(jnp.abs(leaf))) / 7.0 + 1e-7
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=bound)


def test_packed_wire_is_default_and_legacy_still_works(setup):
    """pack_wire=False keeps the PR 4 fake-quant transport alive for
    comparison: the legacy int4 sharded run still matches simulated
    within quant tolerance, and the config default is packed."""
    assert DiLoCoConfig(k=2, H=4).pack_wire is True
    arch, loss_fn, params = setup
    R, k, pods, P = 2, 2, 2, 2
    drops, acts = _masks(R, k)
    kw = dict(k=k, H=H, streaming_fragments=P, stream_tau=1,
              stream_alpha=0.5, outer_grad_dtype="int4",
              error_feedback=True, pack_wire=False)
    sim, sh = _run_pair(loss_fn, params, kw, _tcfg(R), pods=pods, R=R,
                        drops=drops, acts=acts)
    _assert_states_quant_close(sim[0], sh[0], params, kw, dt="int4")


@pytest.mark.slow
def test_packed_wire_hlo_one_gather_byte_exact(setup):
    """The acceptance gate, on the lowered HLO itself: the packed int4
    round issues EXACTLY one pod-axis all-gather per fragment per sync,
    the gathered bytes equal k × the packed static model (measured,
    not modeled), and the real wire is ≥ 5× smaller than the same
    regions at f32."""
    arch, loss_fn, params = setup
    k = pods = 2
    P_frag = 2
    sampler = make_regime("non_iid", k=k, vocab_size=VOCAB, seed=0)
    dcfg = DiLoCoConfig(k=k, H=H, streaming_fragments=P_frag,
                        stream_tau=1, stream_alpha=0.5,
                        outer_grad_dtype="int4", transport="sharded")
    mesh = _pod_mesh(pods)
    run = diloco.make_run(loss_fn, sampler.sample_all_shards, dcfg,
                          _tcfg(1), rounds_per_call=1, total_steps=H,
                          batch_size=B, seq_len=S, donate=False,
                          mesh=mesh)
    state = pod_collectives.shard_stream_state(
        streaming.init_state(params, dcfg), mesh)
    hlo = run.lower(state, jax.random.PRNGKey(5)).compile().as_text()
    cpp = 8 // pods
    inter = H_hlo.stream_interleaving(hlo, chips_per_pod=cpp)
    assert inter["sync_by_op"].get("all-gather", 0) == P_frag, inter
    coll = H_hlo.collective_stats(hlo, chips_per_pod=cpp)
    part = fragments.partition_params(params, P_frag)
    model = k * sum(kops.transport_bytes(e, "int4", packed=True)
                    for regs in part.region_sizes for e in regs)
    meas = coll.cross_by_op.get("all-gather", 0)
    # two-sided: under-shipping the model is as much a regression as
    # over-shipping (the gather output is k×W bytes by construction)
    assert 0.95 * model <= meas <= 1.35 * model, (meas, model)
    f32_model = k * sum(kops.transport_bytes(e, "float32")
                        for regs in part.region_sizes for e in regs)
    assert f32_model / meas >= 5.0, (f32_model, meas)


def _lower_round(loss_fn, params, dcfg, *, pods, rounds=1):
    sampler = make_regime("non_iid", k=dcfg.k, vocab_size=VOCAB, seed=0)
    mesh = _pod_mesh(pods)
    run = diloco.make_run(loss_fn, sampler.sample_all_shards, dcfg,
                          _tcfg(rounds), rounds_per_call=rounds,
                          total_steps=rounds * H, batch_size=B,
                          seq_len=S, donate=False, mesh=mesh)
    state = pod_collectives.shard_stream_state(
        streaming.init_state(params, dcfg), mesh)
    return run.lower(state, jax.random.PRNGKey(5))


@pytest.mark.slow
def test_hlo_overlap_issue_consume_separation(setup):
    """The tentpole acceptance gate: for τ>0 on the sharded quantized
    transport, every fragment's collective issue and its opt-barrier
    consume are separated by ≥τ inner steps' worth of dot ops in the
    emitted program order (pre-optimization HLO, where instruction ids
    record emission order and the barriers still exist). The wrapped
    fragment's wire must leave through the carry and be consumed next
    round; metric all-reduces stay eager and outside the gate."""
    arch, loss_fn, params = setup
    k = pods = 2
    P_frag, tau = 2, 1
    cpp = 8 // pods

    dcfg = DiLoCoConfig(k=k, H=H, streaming_fragments=P_frag,
                        stream_tau=tau, stream_alpha=0.5,
                        outer_grad_dtype="int4", transport="sharded")
    assert streaming.deferred_consume(dcfg)
    unopt = _lower_round(loss_fn, params, dcfg, pods=pods) \
        .compiler_ir("hlo").as_hlo_text()
    ov = H_hlo.stream_overlap(unopt, chips_per_pod=cpp, tau=tau)
    assert ov["ok"], ov
    wire = [r for r in ov["rows"] if r["deferred"]]
    assert len(wire) == P_frag, ov
    assert all(r["op"] == "all-gather" for r in wire), ov
    # the round-final fragment wraps: issued at offset H, consumed at
    # offset τ of the NEXT round through the scan carry
    assert sum(r["wrapped"] for r in wire) == 1, ov
    assert all(r["steps_between"] >= tau for r in wire), ov
    assert all(r["dots_between"] > 0 for r in wire), ov

    # legacy (unpacked) quantized transport defers identically: one
    # consume barrier per fragment, per-leaf gathers behind it
    dcfg_l = DiLoCoConfig(k=k, H=H, streaming_fragments=P_frag,
                          stream_tau=tau, stream_alpha=0.5,
                          outer_grad_dtype="bfloat16",
                          transport="sharded", pack_wire=False)
    unopt_l = _lower_round(loss_fn, params, dcfg_l, pods=pods) \
        .compiler_ir("hlo").as_hlo_text()
    ov_l = H_hlo.stream_overlap(unopt_l, chips_per_pod=cpp, tau=tau)
    assert ov_l["ok"], ov_l
    assert ov_l["n_deferred"] >= P_frag, ov_l


@pytest.mark.slow
def test_hlo_overlap_tau0_stays_eager(setup):
    """τ=0 has no overlap window: the deferral predicate is off, the
    lowering carries no opt-barriers, and every collective is consumed
    where it is issued — the PR 7 eager schedule, bit-for-bit."""
    arch, loss_fn, params = setup
    k = pods = 2
    dcfg = DiLoCoConfig(k=k, H=H, streaming_fragments=2, stream_tau=0,
                        stream_alpha=0.5, outer_grad_dtype="int4",
                        transport="sharded")
    assert not streaming.deferred_consume(dcfg)
    unopt = _lower_round(loss_fn, params, dcfg, pods=pods) \
        .compiler_ir("hlo").as_hlo_text()
    ov = H_hlo.stream_overlap(unopt, chips_per_pod=8 // pods)
    assert ov["n_barriers"] == 0, ov
    assert ov["n_deferred"] == 0, ov
    assert ov["n_collectives"] >= 2, ov


# Hypothesis property tests for Partition × schedule × pod banding live
# in tests/test_pod_properties.py — a module-level importorskip there
# must not take this whole multi-device suite down with it.
