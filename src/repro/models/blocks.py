"""Composable residual blocks shared by all architecture families.

A block kind is a string; init/apply dispatch on it:
  attn_mlp        pre-norm self-attention + (MLP | MoE)     [dense & MoE LMs]
  mla_moe         MLA self-attention + MoE                  [deepseek-v2]
  cross_mlp       gated cross-attention + MLP               [VLM layers]
  self_cross_mlp  self-attn + cross-attn + MLP              [whisper decoder]
  enc_attn_mlp    bidirectional self-attention + MLP        [whisper encoder]
  mamba2          Mamba2 SSD mixer                          [zamba2, mamba]
  mlstm / slstm   xLSTM cells                               [xlstm]

Every apply returns ``(x, new_cache, aux)`` where cache is a (possibly
empty) dict pytree whose leaves scan cleanly over stacked layers, and aux
is a scalar auxiliary loss (MoE load balance; 0 elsewhere).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from . import moe as MOE
from . import mla as MLA
from . import ssm as SSM
from . import xlstm as XL
from .layers import dense_init, zeros_init


def init_block(key, cfg, kind: str):
    ks = jax.random.split(key, 8)
    n = lambda: L.init_norm(cfg.norm, cfg.d_model)
    if kind == "attn_mlp":
        p = {"ln1": n(), "attn": L.init_attention(ks[0], cfg)}
        if cfg.n_experts:
            p["ln2"] = n()
            p["moe"] = MOE.init_moe(ks[1], cfg)
        else:
            p["ln2"] = n()
            p["mlp"] = L.init_mlp(ks[1], cfg)
        return p
    if kind == "mla_moe":
        return {"ln1": n(), "mla": MLA.init_mla(ks[0], cfg),
                "ln2": n(), "moe": MOE.init_moe(ks[1], cfg)}
    if kind == "cross_mlp":
        return {"ln1": n(), "xattn": L.init_attention(ks[0], cfg),
                "ln2": n(), "mlp": L.init_mlp(ks[1], cfg),
                "gate_attn": zeros_init((1,), (None,)),
                "gate_mlp": zeros_init((1,), (None,))}
    if kind == "self_cross_mlp":
        return {"ln1": n(), "attn": L.init_attention(ks[0], cfg),
                "ln2": n(), "xattn": L.init_attention(ks[1], cfg),
                "ln3": n(), "mlp": L.init_mlp(ks[2], cfg)}
    if kind == "enc_attn_mlp":
        return {"ln1": n(), "attn": L.init_attention(ks[0], cfg),
                "ln2": n(), "mlp": L.init_mlp(ks[1], cfg)}
    if kind == "mamba2":
        return {"ln1": n(), "mixer": SSM.init_mamba2(ks[0], cfg)}
    if kind == "mlstm":
        return {"ln1": n(), "cell": XL.init_mlstm(ks[0], cfg)}
    if kind == "slstm":
        return {"ln1": n(), "cell": XL.init_slstm(ks[0], cfg)}
    raise ValueError(kind)


def apply_block(p, x, cfg, kind: str, *, positions=None, cache=None,
                cache_pos=None, kv_x=None, cross_kv=None, groups=1,
                window=None, page_table=None):
    """One residual block. ``window`` overrides cfg.window when not None."""
    win = cfg.window if window is None else window
    aux = jnp.zeros((), jnp.float32)
    new_cache = {}
    norm = lambda q, xx: L.apply_norm(p[q], xx, cfg.norm)

    if kind in ("attn_mlp", "enc_attn_mlp"):
        causal = kind == "attn_mlp"
        h = norm("ln1", x)
        a, c = L.apply_attention(p["attn"], h, cfg, positions=positions,
                                 cache=cache.get("attn") if cache else None,
                                 cache_pos=cache_pos, window=win,
                                 causal=causal, page_table=page_table)
        if c is not None:
            new_cache["attn"] = c
        if cfg.parallel_block:
            m = L.apply_mlp(p["mlp"], h, cfg)
            x = x + a + m
        else:
            x = x + a
            h2 = norm("ln2", x)
            if "moe" in p:
                m, aux = MOE.apply_moe(p["moe"], h2, cfg, groups=groups)
            else:
                m = L.apply_mlp(p["mlp"], h2, cfg)
            x = x + m
        return x, new_cache, aux

    if kind == "mla_moe":
        h = norm("ln1", x)
        a, c = MLA.apply_mla(p["mla"], h, cfg, positions=positions,
                             cache=cache.get("mla") if cache else None,
                             cache_pos=cache_pos)
        if c is not None:
            new_cache["mla"] = c
        x = x + a
        h2 = norm("ln2", x)
        m, aux = MOE.apply_moe(p["moe"], h2, cfg, groups=groups)
        return x + m, new_cache, aux

    if kind == "cross_mlp":
        # gated cross-attn (llama-3.2-vision style): tanh-gated residuals
        h = norm("ln1", x)
        xkv, new_cache = _cross_kv(p["xattn"], cfg, kv_x, cache)
        a, _ = L.apply_attention(p["xattn"], h, cfg, positions=positions,
                                 causal=False, cross_kv=xkv, window=0)
        x = x + jnp.tanh(p["gate_attn"]).astype(x.dtype) * a
        h2 = norm("ln2", x)
        m = L.apply_mlp(p["mlp"], h2, cfg)
        return (x + jnp.tanh(p["gate_mlp"]).astype(x.dtype) * m,
                new_cache, aux)

    if kind == "self_cross_mlp":
        h = norm("ln1", x)
        a, c = L.apply_attention(p["attn"], h, cfg, positions=positions,
                                 cache=cache.get("attn") if cache else None,
                                 cache_pos=cache_pos, window=win,
                                 causal=True, page_table=page_table)
        if c is not None:
            new_cache["attn"] = c
        x = x + a
        h2 = norm("ln2", x)
        xkv, xc = _cross_kv(p["xattn"], cfg, kv_x, cache)
        new_cache.update(xc)
        a2, _ = L.apply_attention(p["xattn"], h2, cfg, positions=positions,
                                  causal=False, cross_kv=xkv, window=0)
        x = x + a2
        h3 = norm("ln3", x)
        return x + L.apply_mlp(p["mlp"], h3, cfg), new_cache, aux

    if kind == "mamba2":
        h = norm("ln1", x)
        st = cache.get("ssm") if cache else None
        ct = cache.get("conv") if cache else None
        o, (ns, nt) = SSM.apply_mamba2(p["mixer"], h, cfg, state=st,
                                       conv_tail=ct)
        if cache is not None:
            new_cache = {"ssm": ns, "conv": nt}
        return x + o, new_cache, aux

    if kind in ("mlstm", "slstm"):
        h = norm("ln1", x)
        st = cache.get("state") if cache else None
        fn = XL.apply_mlstm if kind == "mlstm" else XL.apply_slstm
        o, ns = fn(p["cell"], h, cfg, state=st)
        if cache is not None:
            new_cache = {"state": ns}
        return x + o, new_cache, aux

    raise ValueError(kind)


def _cross_kv(p, cfg, kv_x, cache):
    """(cross_kv, cache_entries): project cross K/V once at prefill and
    cache them; decode reuses the cached pair (recomputing them per step
    is the dominant FLOPs waste for enc-dec/VLM serving)."""
    if kv_x is not None:
        xk, xv = L.project_cross_kv(p, cfg, kv_x)
        entries = {"xk": xk, "xv": xv} if cache is not None else {}
        return (xk, xv), entries
    if cache is not None and "xk" in cache:
        return (cache["xk"], cache["xv"]), {"xk": cache["xk"],
                                            "xv": cache["xv"]}
    raise ValueError("cross-attention needs kv_x (train/prefill) or a "
                     "prefilled cache (decode)")


def init_block_cache(cfg, kind: str, batch: int, cache_len: int, dtype):
    """Zeroed decode cache for one block of ``kind``."""
    hd = cfg.resolved_head_dim
    G = cfg.n_kv_heads
    if kind == "self_cross_mlp":
        c = {"attn": L.init_attn_cache(cfg, batch, cache_len, dtype)}
        c["xk"] = jnp.zeros((batch, cfg.n_frames, G, hd), dtype)
        c["xv"] = jnp.zeros((batch, cfg.n_frames, G, hd), dtype)
        return c
    if kind == "cross_mlp":
        return {"xk": jnp.zeros((batch, cfg.n_patches, G, hd), dtype),
                "xv": jnp.zeros((batch, cfg.n_patches, G, hd), dtype)}
    if kind in ("attn_mlp", "enc_attn_mlp"):
        return {"attn": L.init_attn_cache(cfg, batch, cache_len, dtype)}
    if kind == "mla_moe":
        return {"mla": MLA.init_mla_cache(cfg, batch, cache_len, dtype)}
    if kind == "mamba2":
        s, t = SSM.init_mamba2_state(cfg, batch, dtype)
        return {"ssm": s, "conv": t}
    if kind == "mlstm":
        return {"state": XL.init_mlstm_state(cfg, batch)}
    if kind == "slstm":
        return {"state": XL.init_slstm_state(cfg, batch)}
    return {}


def init_paged_block_cache(cfg, kind: str, batch: int, cache_len: int,
                           dtype, *, n_pages: int, page_size: int):
    """Paged variant of ``init_block_cache``: the standard attention
    K/V rings live in ONE shared page pool (engine-held page table
    maps each slot's logical ring pages to pool pages); every other
    leaf — SSM/xLSTM state, MLA latent rings, cross K/V — keeps its
    per-slot row, unchanged (those carry no per-token ring or are tiny
    per-slot states, so paging buys nothing)."""
    if kind in ("attn_mlp", "enc_attn_mlp"):
        return {"attn": L.init_paged_attn_cache(cfg, n_pages, page_size,
                                                dtype)}
    if kind == "self_cross_mlp":
        c = {"attn": L.init_paged_attn_cache(cfg, n_pages, page_size,
                                             dtype)}
        G, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        c["xk"] = jnp.zeros((batch, cfg.n_frames, G, hd), dtype)
        c["xv"] = jnp.zeros((batch, cfg.n_frames, G, hd), dtype)
        return c
    return init_block_cache(cfg, kind, batch, cache_len, dtype)


def stacked_init(key, cfg, kind: str, count: int):
    """vmap-init ``count`` layers of one kind: leaves get leading (L,) dim.

    Boxed leaves get their axes preserved (the stacked dim is None)."""
    from repro.sharding.spec import Boxed, is_boxed
    keys = jax.random.split(key, count)
    per = [init_block(k, cfg, kind) for k in keys]
    return jax.tree.map(
        lambda *ls: Boxed(jnp.stack([b.value for b in ls]),
                          (None,) + ls[0].axes),
        *per, is_leaf=is_boxed)
