"""Integration tests: end-to-end DiLoCo training behaviour.

These reproduce the paper's qualitative claims at micro scale (tiny
models, minutes of CPU): DiLoCo learns, benefits from k>1 workers,
tolerates dropped communication, and the single-worker k=1 variant
(Lookahead-style, Fig 9) trains stably.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import DiLoCoConfig, TrainConfig
from repro.core import diloco
from repro.data.sharding import make_regime
from repro.models.registry import get_smoke_arch


@pytest.fixture(scope="module")
def setup():
    arch = get_smoke_arch("diloco_60m")
    cfg = arch.cfg.replace(n_layers=2, d_model=64, n_heads=4,
                           n_kv_heads=4, d_ff=128, vocab_size=64)
    from repro.models.registry import Arch
    arch = Arch(cfg=cfg)
    loss_fn = lambda p, b: arch.loss(p, b)
    sampler = make_regime("non_iid", k=4, vocab_size=64, seed=0)
    params, _ = arch.init(jax.random.PRNGKey(0), cfg)
    val = sampler.sample_validation(jax.random.PRNGKey(99), 32, 64)
    return arch, loss_fn, sampler, params, val


def run_diloco(loss_fn, sampler, params, *, k, H, rounds, drop=0.0,
               outer_opt="nesterov", seed=0, batch=8, seq=64):
    dcfg = DiLoCoConfig(k=k, H=H, outer_opt=outer_opt, drop_prob=drop)
    tcfg = TrainConfig(inner_lr=3e-3, warmup_steps=10,
                       total_steps=rounds * H, batch_size=batch,
                       seq_len=seq)
    state = diloco.init_state(params, dcfg)
    rnd = diloco.make_round(loss_fn, sampler.sample_all_shards, dcfg,
                            tcfg, total_steps=rounds * H,
                            batch_size=batch, seq_len=seq)
    key = jax.random.PRNGKey(seed)
    rng = np.random.default_rng(seed)
    for t in range(rounds):
        key, sub = jax.random.split(key)
        mask = jnp.asarray(
            (rng.random(k) >= drop).astype(np.float32)) if drop else None
        state, m = rnd(state, sub, mask)
    return state


def test_diloco_learns(setup):
    arch, loss_fn, sampler, params, val = setup
    ev = diloco.make_eval(loss_fn)
    before = float(ev(params, val))
    state = run_diloco(loss_fn, sampler, params, k=4, H=10, rounds=6)
    after = float(ev(state.global_params, val))
    assert after < before - 0.3, (before, after)


def test_more_workers_help(setup):
    """k=4 DiLoCo reaches lower val loss than k=1 for the same number of
    rounds (more total compute — Table 3's direction)."""
    arch, loss_fn, sampler, params, val = setup
    ev = diloco.make_eval(loss_fn)
    s1 = run_diloco(loss_fn, sampler, params, k=1, H=10, rounds=5)
    sampler4 = make_regime("non_iid", k=4, vocab_size=64, seed=0)
    s4 = run_diloco(loss_fn, sampler4, params, k=4, H=10, rounds=5)
    l1 = float(ev(s1.global_params, val))
    l4 = float(ev(s4.global_params, val))
    assert l4 < l1 + 0.05, (l1, l4)


def test_robust_to_dropped_communication(setup):
    """50% drop degrades gracefully (Fig 8): still much better than
    init, within a modest margin of no-drop."""
    arch, loss_fn, sampler, params, val = setup
    ev = diloco.make_eval(loss_fn)
    before = float(ev(params, val))
    s0 = run_diloco(loss_fn, sampler, params, k=4, H=10, rounds=6)
    s5 = run_diloco(loss_fn, sampler, params, k=4, H=10, rounds=6,
                    drop=0.5)
    l0 = float(ev(s0.global_params, val))
    l5 = float(ev(s5.global_params, val))
    assert l5 < before - 0.2
    assert l5 < l0 + 0.35, (l0, l5)


def test_single_worker_acceleration_runs(setup):
    """k=1 DiLoCo (Lookahead-style outer step, Fig 9) trains stably."""
    arch, loss_fn, sampler, params, val = setup
    ev = diloco.make_eval(loss_fn)
    s = run_diloco(loss_fn, sampler, params, k=1, H=10, rounds=6)
    assert np.isfinite(float(ev(s.global_params, val)))


def test_pruned_outer_grads_still_learn(setup):
    arch, loss_fn, sampler, params, val = setup
    ev = diloco.make_eval(loss_fn)
    dcfg = DiLoCoConfig(k=4, H=10, prune_frac=0.5)
    tcfg = TrainConfig(inner_lr=3e-3, warmup_steps=10, total_steps=60,
                       batch_size=8, seq_len=64)
    state = diloco.init_state(params, dcfg)
    rnd = diloco.make_round(loss_fn, sampler.sample_all_shards, dcfg,
                            tcfg, total_steps=60, batch_size=8,
                            seq_len=64)
    key = jax.random.PRNGKey(0)
    before = float(ev(params, val))
    for t in range(6):
        key, sub = jax.random.split(key)
        state, _ = rnd(state, sub)
    after = float(ev(state.global_params, val))
    assert after < before - 0.3


def test_state_checkpoint_roundtrip(setup, tmp_path):
    """DiLoCoState survives save/restore and training continues."""
    from repro.checkpoint import checkpoint as ckpt
    arch, loss_fn, sampler, params, val = setup
    state = run_diloco(loss_fn, sampler, params, k=2, H=5, rounds=2)
    path = str(tmp_path / "diloco.npz")
    ckpt.save(path, state._asdict())
    like = jax.tree.map(jnp.zeros_like, state._asdict())
    restored = ckpt.restore(path, like)
    for a, b in zip(jax.tree.leaves(restored),
                    jax.tree.leaves(state._asdict())):
        np.testing.assert_array_equal(a, b)


def test_async_diloco_equals_sync_when_homogeneous(setup):
    """speeds all 1 and λ=1: every tick applies k outer gradients
    computed from the same dispatch point sequentially — trains stably
    and reaches a loss comparable to synchronous DiLoCo."""
    from repro.core.async_diloco import AsyncConfig, run_async
    arch, loss_fn, sampler, params, val = setup
    ev = diloco.make_eval(loss_fn)
    tcfg = TrainConfig(inner_lr=3e-3, warmup_steps=10, total_steps=400,
                       batch_size=8, seq_len=64)
    acfg = AsyncConfig(k=4, H=10, staleness_lambda=0.7,
                       speeds=(1, 1, 1, 1))
    gp, hist = run_async(
        loss_fn,
        lambda key, B, S: sampler.sample_validation(key, B, S),
        params, acfg, tcfg, ticks=6, eval_fn=ev, eval_tokens=val)
    assert np.isfinite(hist[-1]["val_loss"])
    before = float(ev(params, val))
    assert hist[-1]["val_loss"] < before - 0.2


def test_async_diloco_heterogeneous_staleness(setup):
    """Slow workers report stale gradients; staleness is tracked and
    training remains finite."""
    from repro.core.async_diloco import AsyncConfig, run_async
    arch, loss_fn, sampler, params, val = setup
    ev = diloco.make_eval(loss_fn)
    tcfg = TrainConfig(inner_lr=3e-3, warmup_steps=10, total_steps=400,
                       batch_size=8, seq_len=64)
    acfg = AsyncConfig(k=4, H=10, staleness_lambda=0.5,
                       speeds=(1, 1, 2, 4))
    gp, hist = run_async(
        loss_fn,
        lambda key, B, S: sampler.sample_validation(key, B, S),
        params, acfg, tcfg, ticks=8, eval_fn=ev, eval_tokens=val)
    stal = [r["staleness"] for r in hist]
    assert max(stal) > 0          # slow workers were genuinely stale
    assert np.isfinite(hist[-1]["val_loss"])
