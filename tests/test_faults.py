"""Fault-harness units: timeline semantics (seed-loop reduction,
stragglers, WAN latency, drop/retry/Lost, preemption presence
invariant), round-mask projections, the staleness-weight policy, and
hypothesis properties (exactly-once uids, determinism, arrival
liveness) over randomized scenarios.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import faults
from repro.core.faults import Arrival, Join, Leave, Lost, Scenario


# ---------------------------------------------------------------------------
# timeline: fault-free reduction + single-fault semantics
# ---------------------------------------------------------------------------

def test_uniform_reduces_to_seed_tick_loop():
    """Zero faults, unit speeds: every worker completes one phase per
    tick and its delta arrives instantly — the seed simulation's loop."""
    k, T = 4, 5
    ev = Scenario.uniform(k).timeline(k, T)
    assert all(isinstance(e, Arrival) for e in ev)
    assert len(ev) == k * T
    for i in range(k):
        mine = [e for e in ev if e.worker == i]
        assert [e.tick for e in mine] == list(range(1, T + 1))
        assert all(e.attempt == 0 for e in mine)
        assert all(e.finish_tick == e.tick for e in mine)
        assert all(e.dispatch_tick == e.tick - 1 for e in mine)


def test_straggler_speed_paces_arrivals():
    k, T = 4, 8
    ev = Scenario.stragglers(k, slow=(2,)).timeline(k, T)
    slow = [e.tick for e in ev if e.worker == k - 1]
    fast = [e.tick for e in ev if e.worker == 0]
    assert slow == [2, 4, 6, 8]
    assert fast == list(range(1, T + 1))


def test_wan_latency_shifts_arrivals_and_is_deterministic():
    k, T = 2, 6
    s = Scenario.wan(k, base_latency=2, jitter=0.0)
    ev = s.timeline(k, T)
    for e in ev:
        assert isinstance(e, Arrival)
        assert e.tick == e.finish_tick + 2
    sj = Scenario.wan(k, base_latency=2, jitter=0.7, seed=3)
    assert sj.timeline(k, T) == sj.timeline(k, T)  # pure function


def test_certain_drop_exhausts_retries_to_lost():
    k = 2
    s = Scenario.drop(k, prob=1.0, max_retries=2, retry_backoff=1)
    ev = s.timeline(k, 10)
    assert all(isinstance(e, Lost) for e in ev)
    # finish at 1, three attempts with backoff 1: gives up at 4
    first = [e for e in ev if e.worker == 0][0]
    assert first.tick == 4


def test_drop_with_retry_arrivals_record_attempt():
    s = Scenario.drop(4, prob=0.5, max_retries=3, retry_backoff=2,
                      seed=7)
    ev = s.timeline(4, 12)
    arr = [e for e in ev if isinstance(e, Arrival)]
    assert arr, "p=0.5 with 4 retries should deliver something"
    assert any(e.attempt > 0 for e in arr)
    assert all(0 <= e.attempt <= 3 for e in arr)
    # a retried arrival lands retry_backoff-paced after its finish
    for e in arr:
        assert e.tick >= e.finish_tick + 2 * e.attempt


def test_preemption_emits_leave_join_and_cuts_phase():
    s = Scenario.preempt(2, worker=1, leave=2, rejoin=4)
    ev = s.timeline(2, 6)
    w1 = [e for e in ev if e.worker == 1]
    kinds = [type(e) for e in w1]
    assert kinds.count(Leave) == 1 and kinds.count(Join) == 1
    lv = next(e for e in w1 if isinstance(e, Leave))
    jn = next(e for e in w1 if isinstance(e, Join))
    assert (lv.tick, jn.tick) == (2, 4)
    # no arrival lands inside the gone span
    for e in w1:
        if isinstance(e, Arrival):
            assert not (lv.tick < e.tick <= jn.tick) or e.tick <= lv.tick


def test_permanent_preemption_is_elastic_shrink():
    s = Scenario.preempt(2, worker=0, leave=3, rejoin=0)
    ev = s.timeline(2, 8)
    w0 = [e for e in ev if e.worker == 0]
    assert not any(isinstance(e, Join) for e in w0)
    assert not any(e.tick > 3 for e in w0)


def test_same_tick_ordering_join_before_arrival_before_leave():
    # worker 0 rejoining at tick 2 sorts before worker 1's arrival at
    # tick 2, which sorts before worker 1's leave at tick 2
    s = Scenario(speeds=(1, 1),
                 preemptions=((0, 1, 2), (1, 2, 3)))
    ev = s.timeline(2, 4)
    t2 = [e for e in ev if e.tick == 2]
    order = [type(e) for e in t2]
    assert order == sorted(order, key=lambda c:
                           {Join: 0, Arrival: 1, Lost: 2, Leave: 3}[c])


def _presence_ok(events, k: int) -> bool:
    """Every Arrival's worker was continuously present from dispatch
    to application (the engine's slot invariant)."""
    spans = {i: [] for i in range(k)}  # gone intervals [leave, join)
    open_ = {}
    for e in events:
        if isinstance(e, Leave):
            open_[e.worker] = e.tick
        elif isinstance(e, Join):
            spans[e.worker].append((open_.pop(e.worker), e.tick))
    for w, t in open_.items():
        spans[w].append((t, float("inf")))
    for e in events:
        if isinstance(e, Arrival):
            for lo, hi in spans[e.worker]:
                if e.dispatch_tick < hi and e.tick > lo:
                    return False
    return True


def test_inflight_payload_discarded_at_preemption():
    # latency 3 puts payloads on the wire across the leave tick; the
    # server must discard them rather than apply for a gone worker
    s = Scenario(speeds=(1, 1), latency=(3, 3),
                 preemptions=((0, 3, 6),))
    ev = s.timeline(2, 12)
    assert _presence_ok(ev, 2)


# ---------------------------------------------------------------------------
# round-mask projections (the barrier-paced consumers)
# ---------------------------------------------------------------------------

def test_round_masks_shapes_and_default():
    drops, acts = Scenario.uniform(3).round_masks(3, 5)
    assert drops.shape == acts.shape == (5, 3)
    assert drops.min() == acts.min() == 1.0


def test_round_masks_drop_survival_includes_retries():
    # p=0.6 with 1 retry: loss prob 0.36 — the masks reflect survival
    s = Scenario.drop(2, prob=0.6, max_retries=1, seed=0)
    drops, _ = s.round_masks(2, 4000)
    lost = 1.0 - drops.mean()
    assert abs(lost - 0.36) < 0.04, lost


def test_round_masks_preemption_spans_rounds():
    # T = sync_round_ticks = 2 (speed 2 straggler); worker 1 gone over
    # ticks [3, 7) touches rounds 1..3 of the tick spans [2,4),[4,6),[6,8)
    s = Scenario(speeds=(1, 2), preemptions=((1, 3, 7),))
    assert s.sync_round_ticks(2) == 2
    _, acts = s.round_masks(2, 5)
    assert acts[:, 0].tolist() == [1.0] * 5
    assert acts[:, 1].tolist() == [1.0, 0.0, 0.0, 0.0, 1.0]


def test_sync_round_ticks_bills_slowest_worker_plus_link():
    s = Scenario(speeds=(1, 3), latency=(0, 2))
    assert s.sync_round_ticks(2) == 5


# ---------------------------------------------------------------------------
# validation + staleness policy
# ---------------------------------------------------------------------------

def test_scenario_field_validation():
    with pytest.raises(ValueError):
        Scenario(speeds=(1, 2)).resolved_speeds(3)
    with pytest.raises(ValueError):
        Scenario(speeds=(0, 1)).resolved_speeds(2)
    with pytest.raises(ValueError):
        Scenario(latency=(-1,)).resolved_latency(1)
    with pytest.raises(ValueError):
        Scenario.preempt(2, worker=5, leave=1, rejoin=2)._preempt_of(2)
    with pytest.raises(ValueError):
        Scenario.preempt(2, worker=0, leave=3, rejoin=2)._preempt_of(2)
    with pytest.raises(ValueError):  # overlapping spans
        Scenario(preemptions=((0, 1, 5), (0, 3, 8)))._preempt_of(2)


def test_staleness_weight_policy():
    k = 4
    assert faults.staleness_weight(0, 1.0, k) == 1.0 / k
    # monotone non-increasing in the delay for lambda <= 1
    ws = [faults.staleness_weight(t, 0.7, k) for t in range(6)]
    assert all(a >= b for a, b in zip(ws, ws[1:]))
    assert faults.staleness_weight(3, 0.5, 2) == 0.5 ** 3 / 2
    with pytest.raises(ValueError):
        faults.staleness_weight(1, 1.5, k)
    with pytest.raises(ValueError):
        faults.staleness_weight(1, -0.1, k)


# ---------------------------------------------------------------------------
# randomized-scenario sweep (deterministic; hypothesis-shrunk variants
# of the same properties live in tests/test_async_properties.py, which
# skips cleanly when hypothesis is absent)
# ---------------------------------------------------------------------------

def random_scenario(seed: int):
    """One seeded random scenario (speeds, latency, drops, retries,
    maybe a preemption) — shared with the property-test module."""
    rng = np.random.default_rng(seed)
    k = int(rng.integers(2, 6))
    pre = ()
    if rng.random() < 0.5:
        leave = int(rng.integers(1, 7))
        rejoin = int(rng.choice([0, leave + 1, leave + 3]))
        pre = ((int(rng.integers(0, k)), leave, rejoin),)
    return k, Scenario(
        speeds=tuple(int(x) for x in rng.integers(1, 4, k)),
        latency=tuple(int(x) for x in rng.integers(0, 3, k)),
        drop_prob=float(rng.choice([0.0, 0.3, 0.7])),
        max_retries=int(rng.integers(0, 3)),
        preemptions=pre, seed=int(rng.integers(0, 100)))


@pytest.mark.parametrize("seed", range(40))
def test_timeline_exactly_once_and_live(seed):
    """The apply-loop contract, at the timeline level: every finished
    phase's uid resolves to AT MOST one terminal event (Arrival or
    Lost, never both), every Arrival lands on a continuously-present
    worker, and events are ordered."""
    k, s = random_scenario(seed)
    ticks = 2 + seed % 9
    ev = s.timeline(k, ticks)
    uids = [e.uid for e in ev if isinstance(e, (Arrival, Lost))]
    assert len(uids) == len(set(uids))
    assert _presence_ok(ev, k)
    assert [e.tick for e in ev] == sorted(e.tick for e in ev)
    for e in ev:
        if isinstance(e, Arrival):
            assert e.dispatch_tick < e.finish_tick <= e.tick <= ticks


@pytest.mark.parametrize("seed", range(20))
def test_timeline_prefix_resume_is_identical(seed):
    """Replaying a prefix and resuming mid-stream yields the identical
    suffix — the checkpoint-restore contract."""
    k, s = random_scenario(seed)
    ticks = 2 + seed % 9
    ev = s.timeline(k, ticks)
    again = s.timeline(k, ticks)
    assert ev == again
    cut = min(seed % 8, len(ev))
    assert ev[cut:] == again[cut:]
