"""HLO text analysis: collective bytes & roofline terms.

``cost_analysis()`` gives FLOPs and HBM bytes but not collective
traffic; we parse the post-SPMD HLO text and sum the *result* sizes of
every collective op (all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute), classifying each as pod-crossing or
intra-pod from its replica groups (explicit or iota-v2 format).

Roofline terms (TPU v5e):
    compute    = HLO_FLOPs / (chips × 197e12 FLOP/s bf16)
    memory     = HLO_bytes / (chips × 819e9 B/s HBM)
    collective = collective_bytes_per_chip / link_bw
with intra-pod traffic on ICI (~50 GB/s/link) and cross-pod traffic on
DCN (we model 25 GB/s per chip-pair aggregate unless overridden).
"""
from __future__ import annotations

import dataclasses
import re

import numpy as np

# hardware constants (v5e)
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link, intra-pod
DCN_BW = 25e9                # bytes/s per chip cross-pod (modeled)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter",
                "all-to-all", "collective-permute")

# `%name = TYPE all-reduce(...)` — TYPE may be a tuple
_OP_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s+"
    r"(" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([0-9,{} ]*)\}\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\]"
    r"(?:T\(([0-9,]+)\))?(?:\s*dimensions=\{([0-9,]+)\})?")
_ST_PAIRS_RE = re.compile(r"source_target_pairs=\{([0-9,{} ]*)\}")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _iota_groups(g: int, k: int, dims, perm) -> np.ndarray:
    ids = np.arange(int(np.prod(dims))).reshape(dims)
    if perm is not None:
        ids = ids.transpose(perm)
    return ids.reshape(g, k)


def _line_groups(line: str):
    """-> list of device-id groups, or None if not present."""
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        return [[int(x) for x in grp.split(",") if x.strip()]
                for grp in m.group(1).split("},{")]
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        g, k = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        perm_str = m.group(4) or m.group(5)
        perm = ([int(x) for x in perm_str.split(",")]
                if perm_str else None)
        return _iota_groups(g, k, dims, perm).tolist()
    m = _ST_PAIRS_RE.search(line)
    if m:  # collective-permute: each pair is a 2-group
        nums = [int(x) for x in re.findall(r"\d+", m.group(1))]
        return [nums[i:i + 2] for i in range(0, len(nums), 2)]
    return None


# ---------------------------------------------------------------------------
# while-loop trip multipliers
#
# Collectives inside a lax.scan body (layer loop, microbatch loop)
# execute trip-count times per step; the HLO text contains them once. We
# recover trips from each while's condition computation (lax.scan conds
# compare the counter against a literal) and propagate multipliers down
# the computation call graph (while bodies, fusions, calls,
# conditionals).
# ---------------------------------------------------------------------------

_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*", re.M)
_WHILE_RE = re.compile(
    r"while\([^)]*\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALLEE_RE = re.compile(
    r"(?:calls=|to_apply=|condition=|body=|true_computation=|"
    r"false_computation=)%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"=\s+s32\[\]\s+constant\((\d+)\)")


def _split_computations(hlo_text: str) -> dict:
    """name -> body text. Computations start at column 0 with
    `%name (...` / `ENTRY %name (...` (optimized text) or the bare
    `name {` / `ENTRY name {` of pre-optimization HLO dumps
    (``lowered.compiler_ir("hlo")``), and end at a column-0 `}`."""
    comps = {}
    name, buf = None, []
    for line in hlo_text.splitlines():
        if line and not line[0].isspace():
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*[({]", line)
            if m and line.rstrip().endswith("{"):
                name, buf = m.group(1), []
                comps[name] = buf
                if line.lstrip().startswith("ENTRY") \
                        or " ENTRY " in line:
                    comps["__entry__"] = buf
                continue
            if line.startswith("}"):
                name = None
                continue
        if name is not None:
            buf.append(line)
    return {k: "\n".join(v) for k, v in comps.items()}


def computation_multipliers(hlo_text: str) -> dict:
    """name -> execution multiplier (product of enclosing loop trips)."""
    comps = _split_computations(hlo_text)
    trips = {}
    for body in comps.values():
        for m in _WHILE_RE.finditer(body):
            cond, wbody = m.group(1), m.group(2)
            consts = [int(c) for c in
                      _CONST_RE.findall(comps.get(cond, ""))]
            trips[wbody] = max(consts) if consts else 1
            trips[cond] = trips[wbody]

    # propagate down the call graph from the entry computation
    entry = comps.get("__entry__")
    if entry is None:
        # fall back: computation with the most lines
        entry_name = max(comps, key=lambda k: len(comps[k]))
        entry = comps[entry_name]
    mult = {}

    def visit(name, m):
        if mult.get(name, 0) >= m:
            return
        mult[name] = m
        # branch computations carry trips.get(...) == 1, so one walk
        # over the shared callee map covers whiles and branches alike
        for callee in _callees(comps.get(name, "")):
            visit(callee, m * trips.get(callee, 1))

    # seed: entry text is keyed under its own name too
    for name, body in comps.items():
        if body is entry or body == entry:
            visit(name, 1)
    return mult


def _groups_cross_pods(line: str, chips_per_pod: int | None) -> bool:
    """Pod-crossing classification of one collective op line, shared by
    the byte accounting (``collective_stats``) and the schedule gate
    (``stream_interleaving``) so the two can never disagree: device ids
    [p*cpp, (p+1)*cpp) belong to pod p, a group spanning two pods is
    cross-pod traffic, and no replica_groups means all devices
    participate."""
    if not chips_per_pod:
        return False
    groups = _line_groups(line)
    if not groups:
        return True
    return any(len({d // chips_per_pod for d in grp}) > 1
               for grp in groups)


def _callees(body: str) -> set:
    """Computation names referenced by a computation body (while
    cond/body, calls/to_apply, conditional branches)."""
    out = set(_CALLEE_RE.findall(body))
    for bm in _BRANCHES_RE.finditer(body):
        out.update(re.findall(r"%?([\w.\-]+)", bm.group(1)))
    return out


@dataclasses.dataclass
class CollectiveStats:
    total_bytes: int = 0
    cross_pod_bytes: int = 0
    intra_pod_bytes: int = 0
    by_op: dict = dataclasses.field(default_factory=dict)
    count: int = 0
    # pod-crossing traffic only, split by op — what the packed-wire
    # benchmark gates against the static byte model (the all-gather
    # entry IS the quantized transport's measured wire)
    cross_by_op: dict = dataclasses.field(default_factory=dict)
    cross_count_by_op: dict = dataclasses.field(default_factory=dict)

    def as_dict(self):
        return {"total_bytes": self.total_bytes,
                "cross_pod_bytes": self.cross_pod_bytes,
                "intra_pod_bytes": self.intra_pod_bytes,
                "count": self.count, "by_op": dict(self.by_op),
                "cross_by_op": dict(self.cross_by_op),
                "cross_count_by_op": dict(self.cross_count_by_op)}


def collective_stats(hlo_text: str, *, chips_per_pod: int | None = None
                     ) -> CollectiveStats:
    """Sum collective result bytes in (post-SPMD) HLO text, each weighted
    by its enclosing while-loop trip count (lax.scan bodies execute
    trip-count times per step).

    ``chips_per_pod``: device ids [p*cpp, (p+1)*cpp) belong to pod p;
    groups spanning two pods are cross-pod traffic. None => all intra.
    """
    st = CollectiveStats()
    comps = _split_computations(hlo_text)
    mults = computation_multipliers(hlo_text)
    for cname, body in comps.items():
        if cname == "__entry__":
            continue
        mult = mults.get(cname, 1)
        for line in body.splitlines():
            m = _OP_RE.search(line)
            if not m:
                continue
            if "-done(" in line:   # async pair: count the -start only
                continue
            nbytes = _type_bytes(m.group(1)) * mult
            op = m.group(2)
            st.total_bytes += nbytes
            st.count += mult
            st.by_op[op] = st.by_op.get(op, 0) + nbytes
            if _groups_cross_pods(line, chips_per_pod):
                st.cross_pod_bytes += nbytes
                st.cross_by_op[op] = st.cross_by_op.get(op, 0) + nbytes
                st.cross_count_by_op[op] = (
                    st.cross_count_by_op.get(op, 0) + mult)
            else:
                st.intra_pod_bytes += nbytes
    return st


def roofline(flops: float, hbm_bytes: float, coll: CollectiveStats,
             *, chips: int, ici_bw: float = ICI_BW,
             dcn_bw: float = DCN_BW, peak=PEAK_FLOPS, hbm=HBM_BW) -> dict:
    """Three roofline terms (seconds) + the dominant one.

    flops / hbm_bytes are GLOBAL (whole-program) → divided over chips.
    Collective result bytes in post-SPMD HLO are PER-DEVICE shapes; a
    ring all-reduce of R result bytes moves ≈2R per device over its
    links (2(N−1)/N ≈ 2), all-gather / reduce-scatter / all-to-all /
    permute move ≈1R — so collective time needs NO further division.
    """
    compute_s = flops / (chips * peak)
    memory_s = hbm_bytes / (chips * hbm)

    def _wire(stats_bytes, by_op_share):
        ar = by_op_share.get("all-reduce", 0)
        other = stats_bytes - ar
        return 2.0 * ar + 1.0 * other

    # split by_op between intra/cross proportionally to their totals
    tot = max(coll.total_bytes, 1)
    intra_by = {k: v * coll.intra_pod_bytes / tot
                for k, v in coll.by_op.items()}
    cross_by = {k: v * coll.cross_pod_bytes / tot
                for k, v in coll.by_op.items()}
    intra_s = _wire(coll.intra_pod_bytes, intra_by) / ici_bw
    cross_s = _wire(coll.cross_pod_bytes, cross_by) / dcn_bw
    collective_s = intra_s + cross_s
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s, "collective_intra_s": intra_s,
             "collective_cross_s": cross_s}
    terms["bound"] = max(
        ("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])
    terms["total_s"] = max(compute_s, memory_s, collective_s)
    return terms


_DOT_RE = re.compile(r"\b(?:dot|convolution)\(")


def _dot_closure(comps: dict) -> dict:
    """name -> True if the computation (or anything it calls,
    transitively) contains a dot/convolution — i.e. it is "inner-step
    compute" for scheduling purposes."""
    callees = {name: _callees(body) for name, body in comps.items()}
    memo: dict = {}

    def visit(name, stack):
        if name in memo:
            return memo[name]
        if name in stack:
            return False
        body = comps.get(name, "")
        hit = bool(_DOT_RE.search(body)) or any(
            visit(c, stack | {name}) for c in callees.get(name, ()))
        memo[name] = hit
        return hit

    for name in comps:
        visit(name, set())
    return memo


_SYNC_OPS = ("all-reduce", "all-gather", "reduce-scatter")


def _crossing_collective(line: str, chips_per_pod: int | None
                         ) -> str | None:
    """The pod-crossing collective op on this line (None otherwise).
    The f32 streaming transport all-reduces; the quantized transports
    all-gather their per-pod payloads — both are fragment syncs."""
    m = _OP_RE.search(line)
    if not m or "-done(" in line or m.group(2) not in _SYNC_OPS:
        return None
    # chips_per_pod=None means "no pod structure": no collective is
    # pod-crossing — the same convention as collective_stats, via the
    # same predicate, so the two entry points cannot disagree
    return m.group(2) if _groups_cross_pods(line, chips_per_pod) \
        else None


def stream_interleaving(hlo_text: str, *, chips_per_pod: int | None
                        ) -> dict:
    """Schedule-structure check for the sharded streaming round: do the
    per-fragment pod-axis all-reduces *interleave* with inner-step
    compute, or did something re-serialize the overlap?

    Finds the computation holding the most pod-crossing collectives
    (the scanned round body), then walks its lines in program order,
    marking each as a sync event (a pod-crossing all-reduce /
    all-gather / reduce-scatter — the f32 transport all-reduces, the
    quantized transports all-gather their per-pod payloads) or a
    compute event (a dot, or an op — while/call/fusion/conditional —
    whose callee transitively contains a dot). Also counts pod-crossing
    collectives hiding *inside* compute callees: the inner-step scans
    must contain none (the paper's no-communication-during-inner-steps
    property, definitional under shard_map).

    Returns {computation, pod_collectives, pod_all_reduces,
    sync_by_op, compute_events, syncs_with_compute_after,
    syncs_inside_compute, events}. A healthy P-fragment round shows
    pod_collectives >= P (one per touched leaf per fragment),
    syncs_with_compute_after covering all but the round-final
    fragment's leaves, and syncs_inside_compute == 0.
    """
    comps = _split_computations(hlo_text)
    dotc = _dot_closure(comps)

    best = (None, [], -1, {})             # name, events, #syncs, by_op
    for name, body in comps.items():
        if name == "__entry__":
            continue
        events, by_op = [], {}
        for line in body.splitlines():
            op = _crossing_collective(line, chips_per_pod)
            if op:
                events.append("sync")
                by_op[op] = by_op.get(op, 0) + 1
                continue
            if _DOT_RE.search(line):
                events.append("compute")
                continue
            callees = _CALLEE_RE.findall(line)
            if callees and any(dotc.get(c) for c in callees):
                events.append("compute")
        n_sync = events.count("sync")
        if n_sync > best[2]:
            best = (name, events, n_sync, by_op)
    best_name, best_events, best_syncs, best_by_op = best

    # pod-crossing collectives nested inside this computation's
    # dot-containing callees (transitively): must be zero — inner-step
    # loops communicate nothing across pods
    nested = 0
    seen = set()

    def count_nested(name):
        nonlocal nested
        if name in seen:
            return
        seen.add(name)
        body = comps.get(name, "")
        for line in body.splitlines():
            if _crossing_collective(line, chips_per_pod):
                nested += 1
            for c in _CALLEE_RE.findall(line):
                count_nested(c)

    for line in comps.get(best_name, "").splitlines():
        callees = _CALLEE_RE.findall(line)
        if callees and any(dotc.get(c) for c in callees):
            for c in callees:
                count_nested(c)

    after = 0
    for i, ev in enumerate(best_events):
        if ev == "sync" and "compute" in best_events[i + 1:]:
            after += 1
    return {"computation": best_name,
            "pod_collectives": best_syncs,
            "pod_all_reduces": best_by_op.get("all-reduce", 0),
            "sync_by_op": best_by_op,
            "compute_events": best_events.count("compute"),
            "syncs_with_compute_after": after,
            "syncs_inside_compute": nested,
            "events": best_events}


# ---------------------------------------------------------------------------
# issue/consume overlap measurement (pre-optimization HLO)
#
# The deferred streaming transport ISSUES each fragment's gather at its
# send offset and CONSUMES it (decode + reduce, behind an
# opt-barrier tied to the post-window replica params) τ inner steps
# later. Pre-optimization HLO preserves that emission order in its
# instruction ids (creation order), so the separation is measurable:
# count the trip-weighted inner-step dots of the while loops whose ids
# fall between a collective's issue and the opt-barrier that consumes
# it. Backends erase the barrier late (OptimizationBarrierExpander), so
# the gate runs on `lowered.compiler_ir("hlo").as_hlo_text()` — the
# program we emit — while stream_interleaving keeps gating the
# optimized schedule (zero collectives inside inner loops).
# ---------------------------------------------------------------------------

_PLUMBING_OPS = frozenset((
    "tuple", "get-tuple-element", "convert", "bitcast", "bitcast-convert",
    "reshape", "copy", "transpose", "broadcast"))
_COMMENT_RE = re.compile(r"/\*.*?\*/")
_NAME_RE = re.compile(r"%?([A-Za-z_][\w.\-]*)")


def _instr_id(name: str) -> int:
    tail = name.rsplit(".", 1)[-1]
    return int(tail) if tail.isdigit() else -1


def _parse_instructions(body: str) -> dict:
    """name -> {id, opcode, operands, type, line, root} for one
    computation body. Operand lists in pre-optimization HLO are bare
    instruction names; attrs after the closing paren are kept on
    ``line`` for group/shape inspection."""
    out = {}
    for raw in body.splitlines():
        line = _COMMENT_RE.sub("", raw).strip()
        root = line.startswith("ROOT ")
        if root:
            line = line[5:]
        if " = " not in line:
            continue
        lhs, rhs = line.split(" = ", 1)
        name = lhs.strip().lstrip("%")
        rhs = rhs.strip()
        if rhs.startswith("("):           # tuple-typed result
            depth, i = 0, 0
            for i, ch in enumerate(rhs):
                depth += (ch == "(") - (ch == ")")
                if depth == 0:
                    break
            typ, rest = rhs[:i + 1], rhs[i + 1:]
        else:
            cut = rhs.find(" ")
            if cut < 0:
                continue
            typ, rest = rhs[:cut], rhs[cut:]
        m = re.match(r"\s*([a-z][\w\-]*)\(", rest)
        if not m:
            continue
        op = m.group(1)
        ostr = rest[m.end():rest.find(")", m.end())]
        operands = [n for n in _NAME_RE.findall(ostr)]
        out[name] = {"id": _instr_id(name), "opcode": op, "type": typ,
                     "operands": operands, "line": raw, "root": root}
    return out


def _while_trips(comps: dict) -> dict:
    """while body-computation name -> trip count (lax.scan conds
    compare the counter against a scalar literal; default 1)."""
    trips = {}
    for body in comps.values():
        for m in _WHILE_RE.finditer(body):
            cond, wbody = m.group(1), m.group(2)
            consts = [int(c) for c in _CONST_RE.findall(
                comps.get(cond, ""))]
            trips[wbody] = max(consts) if consts else 1
    return trips


def _dot_counts(comps: dict, trips: dict) -> dict:
    """name -> trip-weighted dot/convolution count of the computation,
    including everything it calls (nested scan bodies multiply by
    their trip counts)."""
    callees = {name: _callees(body) for name, body in comps.items()}
    memo: dict = {}

    def visit(name, stack):
        if name in memo:
            return memo[name]
        if name in stack:
            return 0
        body = comps.get(name, "")
        n = len(_DOT_RE.findall(body))
        for c in callees.get(name, ()):
            n += trips.get(c, 1) * visit(c, stack | {name})
        memo[name] = n
        return n

    for name in comps:
        visit(name, frozenset())
    return memo


def stream_overlap(hlo_text: str, *, chips_per_pod: int | None,
                   tau: int | None = None) -> dict:
    """Per-collective issue→consume separation of the streaming round,
    measured on PRE-optimization HLO text (``lowered.compiler_ir("hlo")
    .as_hlo_text()`` — emission order survives there as instruction
    ids; optimized text loses the opt-barriers that pin the consume).

    Picks the computation with the most pod-crossing sync collectives
    (the scanned round body) and reports one row per such collective:

    - ``issue_id``       instruction id of the gather/all-reduce
    - ``consume_id``     id of its first non-plumbing consumer (the
                         opt-barrier for deferred transports, the
                         reduce itself for eager ones); None when the
                         wire is consumed only by the ROOT carry
    - ``wrapped``        True when the wire flows out through the
                         carry and is consumed next round (matched to
                         a parameter-fed opt-barrier by wire type)
    - ``steps_between``  trip-weighted inner steps (dot-containing
                         whiles) emitted between issue and consume —
                         for wrapped rows, body-tail steps after the
                         issue plus next-round head steps before the
                         carry consume
    - ``dots_between``   same window, counted in dot ops

    Summary keys: ``min_steps_between`` / ``min_dots_between`` over
    all rows, ``n_collectives``, ``n_barriers``, and — when ``tau`` is
    given — ``ok`` (every row's steps_between >= tau).
    """
    comps = _split_computations(hlo_text)
    trips = _while_trips(comps)
    dotc = _dot_counts(comps, trips)

    # the round body: most pod-crossing sync collectives
    best_name, best_n = None, -1
    for name, body in comps.items():
        if name == "__entry__":
            continue
        n = sum(1 for ln in body.splitlines()
                if _crossing_collective(ln, chips_per_pod))
        if n > best_n:
            best_name, best_n = name, n
    instrs = _parse_instructions(comps.get(best_name, ""))

    syncs = {n: i for n, i in instrs.items()
             if _crossing_collective(i["line"], chips_per_pod)}
    barriers = {n: i for n, i in instrs.items()
                if i["opcode"] == "opt-barrier"}

    # inner-step windows: dot-containing whiles in the round body,
    # keyed by instruction id
    whiles = []
    for n, i in instrs.items():
        if i["opcode"] != "while":
            continue
        m = _WHILE_RE.search(i["line"])
        if not m:
            continue
        wbody = m.group(2)
        dots = dotc.get(wbody, 0)
        if dots > 0:
            t = trips.get(wbody, 1)
            whiles.append({"id": i["id"], "steps": t, "dots": t * dots})
    whiles.sort(key=lambda w: w["id"])

    def window(lo, hi):
        sel = [w for w in whiles if lo < w["id"] < hi]
        return (sum(w["steps"] for w in sel),
                sum(w["dots"] for w in sel))

    # barrier -> wire sources (collectives or carry parameters),
    # resolved through plumbing ops
    def sources(start_ops):
        seen, coll, params = set(), [], []
        stack = [o for o in start_ops]
        while stack:
            nm = stack.pop()
            if nm in seen or nm not in instrs:
                continue
            seen.add(nm)
            i = instrs[nm]
            if nm in syncs:
                coll.append(nm)
            elif i["opcode"] == "parameter":
                params.append(nm)
            elif i["opcode"] in _PLUMBING_OPS:
                stack.extend(i["operands"])
        return coll, params

    consumed_by = {}          # sync name -> barrier instr
    carry_barriers = []       # (barrier instr, param wire type)
    for bn, b in barriers.items():
        coll, params = sources(b["operands"])
        for cn in coll:
            consumed_by[cn] = b
        for pn in params:
            carry_barriers.append((b, instrs[pn]["type"]))

    # forward users, for eager consumes and wrapped detection
    users: dict = {}
    for n, i in instrs.items():
        for o in i["operands"]:
            users.setdefault(o, []).append(n)

    def first_consumer(nm):
        """Min-id non-plumbing user reached through plumbing (ROOT
        plumbing is terminal: the value left via the carry)."""
        seen, best = set(), None
        stack = [nm]
        while stack:
            cur = stack.pop()
            for un in users.get(cur, ()):
                if un in seen or un not in instrs:
                    continue
                seen.add(un)
                u = instrs[un]
                if u["opcode"] in _PLUMBING_OPS and un not in barriers:
                    if not u["root"]:
                        stack.append(un)
                elif best is None or u["id"] < best:
                    best = u["id"]
        return best

    rows = []
    for sn, s in sorted(syncs.items(), key=lambda kv: kv[1]["id"]):
        row = {"collective": sn, "issue_id": s["id"],
               "op": (_OP_RE.search(s["line"]) or [None, None, "?"])[2]}
        b = consumed_by.get(sn)
        cid = b["id"] if b is not None else first_consumer(sn)
        if cid is not None and cid > s["id"]:
            steps, dots = window(s["id"], cid)
            row.update(consume_id=cid, wrapped=False,
                       deferred=b is not None,
                       steps_between=steps, dots_between=dots)
        else:
            # wire leaves through the carry; pair with the
            # parameter-fed barrier of the same wire type to measure
            # the cyclic window (body tail + next-round head)
            tail_s, tail_d = window(s["id"], float("inf"))
            head_s = head_d = 0
            cb = next((b_ for b_, t in carry_barriers
                       if t == s["type"]), None)
            if cb is None and carry_barriers:
                cb = carry_barriers[0][0]
            if cb is not None:
                head_s, head_d = window(-1, cb["id"])
            row.update(consume_id=cb["id"] if cb is not None else None,
                       wrapped=True, deferred=cb is not None,
                       steps_between=tail_s + head_s,
                       dots_between=tail_d + head_d)
        rows.append(row)

    # the overlap claim covers the WIRE collectives — the ones pinned
    # behind an opt-barrier consume (or wrapped through the carry).
    # Eager metric reductions (scalar loss/telemetry psums at round
    # end) are consumed in place by design and stay out of the gate.
    wire = [r for r in rows if r["deferred"]]
    out = {"computation": best_name, "rows": rows,
           "n_collectives": len(rows), "n_barriers": len(barriers),
           "n_deferred": len(wire),
           "min_steps_between": min(
               (r["steps_between"] for r in wire), default=0),
           "min_dots_between": min(
               (r["dots_between"] for r in wire), default=0)}
    if tau is not None:
        out["tau"] = int(tau)
        out["ok"] = bool(wire) and all(
            r["steps_between"] >= tau for r in wire)
    return out


def memory_items(compiled) -> dict:
    """Compiled-memory analysis of an AOT-compiled function: argument /
    output / temp / generated-code sizes in bytes, plus the donation
    saving (``alias_size_in_bytes`` — bytes of inputs reused as
    outputs). Returns {} on backends that don't implement
    ``memory_analysis`` (e.g. some CPU versions) so callers can treat
    the numbers as best-effort."""
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    out = {}
    for field in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
        val = getattr(ma, field, None)
        if val is not None:
            out[field] = int(val)
    if out:
        # peak live estimate: arguments + outputs + temporaries, minus
        # the donated (aliased) bytes counted twice
        out["peak_bytes_est"] = (
            out.get("argument_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0))
    return out


def cost_items(compiled) -> tuple[float, float]:
    """(flops, bytes_accessed) from compiled.cost_analysis(), robust to
    the per-backend dict/list shape differences."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    nbytes = float(ca.get("bytes accessed", 0.0))
    return flops, nbytes


def wire_profile(hlo_text: str, *, chips_per_pod: int | None = None,
                 interleaving: bool = True, unopt_text: str | None = None,
                 tau: int | None = None) -> dict:
    """Manifest-ready wire profile of one lowered program: the
    collective byte totals (by op, pod-crossing split) plus the
    schedule-structure interleaving stats — the static HLO record a
    run manifest ships alongside its trace
    (``obs.metrics.RunRecorder.attach_hlo_profile``), so the trace's
    byte annotations can be audited against what the compiled program
    REALLY gathers. ``interleaving`` False skips the schedule walk
    (meaningless for programs with no pod-crossing collective).
    ``unopt_text`` (pre-optimization HLO from the same lowering) adds
    the issue/consume ``overlap`` section measured by
    ``stream_overlap``."""
    prof = {"chips_per_pod": chips_per_pod,
            "collectives": collective_stats(
                hlo_text, chips_per_pod=chips_per_pod).as_dict()}
    if interleaving:
        inter = stream_interleaving(hlo_text,
                                    chips_per_pod=chips_per_pod)
        prof["interleaving"] = {kk: inter[kk] for kk in
                                ("computation", "pod_collectives",
                                 "pod_all_reduces", "sync_by_op",
                                 "compute_events",
                                 "syncs_with_compute_after",
                                 "syncs_inside_compute")}
    if unopt_text is not None:
        prof["overlap"] = stream_overlap(
            unopt_text, chips_per_pod=chips_per_pod, tau=tau)
    return prof
