"""End-to-end driver: the paper's full protocol at reduced scale.

Phase 1 — single-worker pretraining (paper: 24k steps).
Phase 2 — DiLoCo with k=8 replicas on non-i.i.d. shards (paper: 64k
          steps, H=500), with checkpointing and final evaluation against
          a synchronous-DDP-equivalent baseline given the same
          wall-clock budget.

This is the "train a ~100M model for a few hundred steps" deliverable:
run with --full to use the paper's real 150M config (slow on CPU),
default uses the reduced variant.

  PYTHONPATH=src python examples/e2e_pretrain_diloco.py [--full]
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import DiLoCoConfig, TrainConfig
from repro.core import diloco
from repro.data.sharding import make_regime
from repro.models.registry import get_arch, get_smoke_arch
from repro.optim import adamw

ap = argparse.ArgumentParser()
ap.add_argument("--full", action="store_true",
                help="use the real 150M config (very slow on CPU)")
ap.add_argument("--k", type=int, default=8)
ap.add_argument("--H", type=int, default=20)
ap.add_argument("--rounds", type=int, default=10)
ap.add_argument("--pretrain", type=int, default=100)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--out", default="/tmp/diloco_e2e")
args = ap.parse_args()

arch = (get_arch if args.full else get_smoke_arch)("diloco_150m")
loss_fn = lambda p, b: arch.loss(p, b)
n_params = None
sampler = make_regime("non_iid", k=args.k,
                      vocab_size=arch.cfg.vocab_size)
total = args.pretrain + args.rounds * args.H
tcfg = TrainConfig(inner_lr=3e-3, warmup_steps=30, total_steps=total,
                   batch_size=args.batch, seq_len=args.seq)
evaluate = diloco.make_eval(loss_fn)
val = sampler.sample_validation(jax.random.PRNGKey(42), 64, args.seq)

# ---- phase 1: pretrain ----
t0 = time.time()
params, _ = arch.init(jax.random.PRNGKey(0), arch.cfg)
n_params = sum(l.size for l in jax.tree.leaves(params))
print(f"model: {arch.cfg.name} ({n_params / 1e6:.1f}M params)")
step = diloco.make_single_worker_step(loss_fn, tcfg)
opt = adamw.init(params)
key = jax.random.PRNGKey(1)
for i in range(args.pretrain):
    key, sub = jax.random.split(key)
    batch = {"tokens": sampler.sample_validation(sub, args.batch,
                                                 args.seq)}
    params, opt, m = step(params, opt, batch, jnp.asarray(i))
ppl0 = np.exp(float(evaluate(params, val)))
print(f"[pretrain] {args.pretrain} steps, val ppl {ppl0:.1f} "
      f"({time.time() - t0:.0f}s)")
os.makedirs(args.out, exist_ok=True)
ckpt.save(os.path.join(args.out, "pretrained.npz"), {"params": params},
          metadata={"steps": args.pretrain})

# ---- phase 2: DiLoCo ----
dcfg = DiLoCoConfig(k=args.k, H=args.H)
state = diloco.init_state(params, dcfg)
round_fn = diloco.make_round(loss_fn, sampler.sample_all_shards, dcfg,
                             tcfg, total_steps=total,
                             batch_size=args.batch, seq_len=args.seq)
state = state._replace(inner_steps_done=jnp.asarray(args.pretrain))
for t in range(args.rounds):
    key, sub = jax.random.split(key)
    state, m = round_fn(state, sub)
    ppl = np.exp(float(evaluate(state.global_params, val)))
    print(f"[diloco round {t + 1}/{args.rounds}] inner "
          f"{float(m['inner_loss']):.3f} val ppl {ppl:.1f}")
ckpt.save(os.path.join(args.out, "diloco_final.npz"),
          {"params": state.global_params},
          metadata={"rounds": args.rounds, "k": args.k, "H": args.H})

# ---- communication accounting (the paper's headline) ----
pbytes = sum(l.size * 4 for l in jax.tree.leaves(params))
sync_bytes = pbytes * args.rounds * args.H     # DDP: grads every step
diloco_bytes = pbytes * args.rounds            # DiLoCo: once per round
print(f"\ncheckpoints -> {args.out}")
print(f"communication per replica: DDP-equivalent "
      f"{sync_bytes / 1e6:.0f} MB vs DiLoCo {diloco_bytes / 1e6:.0f} MB "
      f"({args.H}x reduction)")
