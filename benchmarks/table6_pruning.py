"""Table 6: pruning outer gradients (appendix §6.2).

Per-neuron sign pruning of each replica's outer gradient before the
average. Expectation: up to 50% pruning is nearly free (paper: +0.39%
PPL at 50%, +1.66% at 75%)."""
from __future__ import annotations

from . import common as C

FRACS = [0.0, 0.25, 0.5, 0.75]


def run(scale: int = 1):
    p = dict(C.DEFAULTS)
    rounds = 20 * scale
    arch, loss_fn, sampler = C.make_setup("non_iid", k=p["k"])
    params0, pre = C.pretrain(arch, loss_fn, sampler, p["pretrain"],
                              batch=p["batch"], seq=p["seq"],
                              lr=p["inner_lr"], warmup=p["warmup"],
                              total=p["pretrain"] + rounds * p["H"])
    rows = []
    for frac in FRACS:
        h, _ = C.run_diloco(arch, loss_fn, sampler, params0, k=p["k"],
                            H=p["H"], rounds=rounds, step0=pre,
                            prune_frac=frac, batch=p["batch"],
                            seq=p["seq"], eval_every=rounds)
        rows.append(dict(prune_frac=frac, ppl=C.final_ppl(h),
                         rel_change=None))
    base = rows[0]["ppl"]
    for r in rows:
        r["rel_change"] = (r["ppl"] - base) / base
    payload = {"rows": rows,
               "claims": {
                   "prune_50_nearly_free": rows[2]["rel_change"] < 0.05,
                   "prune_75_mild": rows[3]["rel_change"] < 0.12}}
    C.save("table6_pruning", payload)
    return payload


if __name__ == "__main__":
    out = run()
    for r in out["rows"]:
        print(f"prune={r['prune_frac']:.2f} ppl={r['ppl']:.3f} "
              f"rel={r['rel_change']:+.2%}")
    print(out["claims"])
