"""qwen3-32b [dense, hf:Qwen/Qwen3-8B family]: 64L, d_model=5120,
64 heads (head_dim=128), GQA kv=8, d_ff=25600, vocab=151936, qk-norm."""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b", family="dense",
        n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8,
        head_dim=128, d_ff=25_600, vocab_size=151_936,
        pos_emb="rope", rope_theta=1e6, qk_norm=True,
        norm="rmsnorm", act="silu",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="qwen3-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, head_dim=32, d_ff=256, vocab_size=256,
        attn_chunk=64)
