"""Wrap a transport carry + RNG + round cursor into one checkpoint tree.

Every round-shaped transport (sync / streaming / sharded / gossip)
carries its whole training state in one pytree (``DiLoCoState``,
``StreamState``, ``GossipState``) and advances the host RNG by exactly
one ``jax.random.split`` per scanned chunk (``metrics["next_key"]``).
A resume therefore needs precisely three things: the state tree, the
host key as it stood at the cut, and how many rounds were already done
(the data-pipeline position is a pure function of the key chain and
the round index — sampling is fully keyed in-graph, nothing else is
stateful). ``wrap``/``unwrap`` bundle those into a dict pytree that
rides the existing ``checkpoint.py`` codecs unchanged; the async
engine keeps its own richer ``state_to_tree`` layout and only the
rng/cursor envelope here.

``tree_sha256`` is the bit-identity gate: a deterministic digest over
every leaf's dtype, shape and raw bytes, path-sorted — two runs whose
trees hash equal are bit-identical, across processes and commits.
"""
from __future__ import annotations

import hashlib

import jax
import numpy as np

_FORMAT = 1


def wrap(state, key, rounds_done: int) -> dict:
    """Bundle (state tree, host rng key, rounds-done cursor) into the
    checkpointable envelope. ``state`` may be any pytree (NamedTuple
    states included — ``checkpoint.reshape_like`` restores the exact
    structure from an example)."""
    return {
        "state": state,
        "rng": {"key": key},
        # int32: the restore path casts to the example's dtype, and
        # int64 would warn (and truncate) under jax's default x64-off
        "cursor": {"rounds_done": np.int32(rounds_done),
                   "format": np.int32(_FORMAT)},
    }


def unwrap(tree: dict):
    """Inverse of ``wrap``: returns (state, key, rounds_done)."""
    fmt = int(np.asarray(tree["cursor"]["format"]))
    if fmt != _FORMAT:
        raise ValueError(
            f"checkpoint envelope format {fmt} != supported {_FORMAT}")
    return (tree["state"], tree["rng"]["key"],
            int(np.asarray(tree["cursor"]["rounds_done"])))


def _leaf_bytes(x) -> tuple:
    a = np.asarray(x)
    # bfloat16 & friends have no portable buffer protocol — hash the
    # uint16 bit view, exactly what checkpoint.py writes to disk.
    if a.dtype.kind == "V" or a.dtype.name not in np.sctypeDict:
        a = a.view(np.uint16) if a.dtype.itemsize == 2 else a.view(np.uint8)
    return a.dtype.str, a.shape, np.ascontiguousarray(a).tobytes()


def leaf_hashes(tree) -> dict:
    """Per-leaf sha256 digests keyed by tree path — the debugging view
    of ``tree_sha256`` (which leaf made two runs' digests disagree?)."""
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        dstr, shape, raw = _leaf_bytes(leaf)
        h = hashlib.sha256()
        h.update(dstr.encode())
        h.update(repr(shape).encode())
        h.update(raw)
        out[jax.tree_util.keystr(path)] = h.hexdigest()
    return out


def tree_sha256(tree) -> str:
    """Deterministic digest of a pytree: every leaf's path, dtype,
    shape and raw bytes folded into one sha256, sorted by path so the
    digest is independent of dict insertion order."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    items = sorted(
        (jax.tree_util.keystr(path), _leaf_bytes(leaf))
        for path, leaf in leaves)
    h = hashlib.sha256()
    for path, (dstr, shape, raw) in items:
        h.update(path.encode())
        h.update(dstr.encode())
        h.update(repr(shape).encode())
        h.update(raw)
    return h.hexdigest()
