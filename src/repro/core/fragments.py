"""Fragment partitioning and sync scheduling for streaming DiLoCo.

Streaming DiLoCo (Douillard et al., 2025) never syncs the whole model
at once: the parameter tree is split into P *contiguous fragments* (by
transformer-block depth) and each fragment runs its own outer step on a
schedule staggered across the H inner steps of a round. This module
provides the two static ingredients of that subsystem:

  * ``partition_params`` — split a parameter tree into P contiguous
    fragments. Block-stacked leaves (the scanned ``stack*`` transformer
    blocks, leading axis = layers) are split along their layer axis;
    non-stacked leaves are ordered embedding-first / head-last, and the
    P cut points are chosen to balance element counts. A pattern-based
    ``overrides`` list pins whole leaves to a chosen fragment.
  * ``schedule`` — the per-round event list: fragment p *sends* (snap-
    shots its outer gradient and starts the simulated all-reduce) at
    inner offset p·H/P (offset 0 maps to the end-of-round boundary, so
    P=1 degenerates to the classic sync-after-H-steps algorithm), and
    *applies* the reduced result τ inner steps later — possibly in the
    next round, modeling a collective that runs concurrently with
    compute.

Fragments are represented as per-fragment *mask trees*: one broadcast-
ready array per leaf ((L, 1, ..., 1) for an L-layer stacked leaf, a
scalar 0/1 otherwise). Masks are tiny (O(layers) numbers, not O(params))
and make every fragment operation a ``jnp.where`` select.
"""
from __future__ import annotations

import re
from typing import Any, NamedTuple

import jax
import numpy as np

STACK_PATTERN = r"stack"
EMBED_PATTERN = r"embed"


class Partition(NamedTuple):
    """P disjoint fragments of a parameter tree.

    masks: tuple of P pytrees matching the params structure; each leaf
    is a float32 array broadcastable against the param leaf (and against
    a replica-stacked (k, ...) version of it). Summed over fragments the
    masks are exactly one everywhere.
    sizes: per-fragment element counts.
    region_sizes: per fragment, the element counts of the contiguous
    per-leaf *regions* it touches (a stacked leaf contributes its layer
    band as one region, a whole non-stacked leaf is one region). A real
    sender packs and quantizes region by region, so per-block transport
    overheads (int4's f32 scales) are charged per region via
    ``ops.transport_bytes`` — not per fragment total.
    """
    n: int
    masks: tuple
    sizes: tuple
    region_sizes: tuple = ()

    def peak_fragment_elems(self) -> int:
        return max(self.sizes) if self.sizes else 0


def _is_stacked(path: str, leaf, stack_pattern: str) -> bool:
    return (re.search(stack_pattern, path) is not None
            and leaf.ndim >= 1 and leaf.shape[0] > 1)


def partition_params(params, n_fragments: int, *, overrides=(),
                     stack_pattern: str = STACK_PATTERN) -> Partition:
    """Split ``params`` into ``n_fragments`` contiguous fragments.

    Every (leaf, layer) unit gets a depth coordinate in [0, 1]:
    embedding-like leaves 0, layer j of an L-layer stacked leaf
    (j+0.5)/L, remaining non-stacked leaves (final norm, head) 1. Units
    are sorted by depth and cut into P contiguous groups balanced by
    element count, so each fragment is a contiguous band of transformer
    blocks. ``overrides`` — ((path-regex, fragment_idx), ...), first
    match wins — pins whole leaves regardless of depth.
    """
    P = int(n_fragments)
    if P < 1:
        raise ValueError(f"n_fragments must be >= 1, got {P}")
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    paths = [jax.tree_util.keystr(kp) for kp, _ in flat]
    leaves = [leaf for _, leaf in flat]

    def forced_fragment(path: str):
        for pat, frag in overrides:
            if re.search(pat, path):
                frag = int(frag)
                if not (0 <= frag < P):
                    raise ValueError(
                        f"override {pat!r} -> fragment {frag} out of "
                        f"range for P={P}")
                return frag
        return None

    # units: (coord, size, leaf_idx, layer_idx | None, forced | None)
    units = []
    for i, (path, leaf) in enumerate(zip(paths, leaves)):
        forced = forced_fragment(path)
        if _is_stacked(path, leaf, stack_pattern):
            L = leaf.shape[0]
            per = int(leaf.size) // L
            for j in range(L):
                units.append(((j + 0.5) / L, per, i, j, forced))
        else:
            coord = 0.0 if re.search(EMBED_PATTERN, path) else 1.0
            units.append((coord, int(leaf.size), i, None, forced))
    units.sort(key=lambda u: u[0])          # stable: ties keep order

    free_total = sum(u[1] for u in units if u[4] is None) or 1
    assign = {}
    cum = 0
    for coord, size, i, j, forced in units:
        if forced is not None:
            assign[(i, j)] = forced
        else:
            assign[(i, j)] = min(P - 1,
                                 int(P * (cum + 0.5 * size) / free_total))
            cum += size

    mask_leaves: list[list] = [[] for _ in range(P)]
    sizes = [0] * P
    regions: list[list] = [[] for _ in range(P)]
    for i, (path, leaf) in enumerate(zip(paths, leaves)):
        if _is_stacked(path, leaf, stack_pattern):
            L = leaf.shape[0]
            per = int(leaf.size) // L
            vec = np.zeros((P, L), np.float32)
            for j in range(L):
                f = assign[(i, j)]
                vec[f, j] = 1.0
                sizes[f] += per
            shape = (L,) + (1,) * (leaf.ndim - 1)
            # masks stay host-side numpy: they broadcast into jnp ops
            # as constants AND remain statically inspectable (the
            # streaming round skips leaves a fragment doesn't touch)
            for p in range(P):
                mask_leaves[p].append(vec[p].reshape(shape))
                layers = int(vec[p].sum())
                if layers:
                    regions[p].append(layers * per)
        else:
            f = assign[(i, None)]
            sizes[f] += int(leaf.size)
            regions[f].append(int(leaf.size))
            for p in range(P):
                mask_leaves[p].append(
                    np.float32(1.0 if p == f else 0.0))
    masks = tuple(jax.tree_util.tree_unflatten(treedef, mask_leaves[p])
                  for p in range(P))
    return Partition(P, masks, tuple(sizes),
                     tuple(tuple(r) for r in regions))


# ---------------------------------------------------------------------------
# contiguous region index (the unit the packed wire flattens)
# ---------------------------------------------------------------------------


class Region(NamedTuple):
    """One contiguous piece of a fragment: a layer band [start, stop)
    of a stacked leaf, or a whole non-stacked leaf (start is None).
    ``elems`` counts the region's elements WITHOUT any leading replica
    axis — the per-replica payload size the wire accounting charges."""
    leaf: int
    start: int | None
    stop: int | None
    elems: int


def fragment_regions(part: Partition, params) -> tuple:
    """Per fragment, the ordered ``Region`` list its masks cover —
    derived from the (static, host-side) masks, so the packed transport
    ships exactly the elements the mask algebra selects. Region order
    and element counts match ``Partition.region_sizes`` entry for
    entry (asserted), so per-region wire accounting and the wire layout
    can never disagree."""
    leaves = jax.tree_util.tree_leaves(params)
    out = []
    for p in range(part.n):
        mask_leaves = jax.tree_util.tree_leaves(part.masks[p])
        regs = []
        for i, (mk, leaf) in enumerate(zip(mask_leaves, leaves)):
            mk = np.asarray(mk)
            if mk.ndim == 0:
                if mk:
                    regs.append(Region(i, None, None, int(leaf.size)))
                continue
            idx = np.nonzero(mk.reshape(-1))[0]
            if not idx.size:
                continue
            s, e = int(idx[0]), int(idx[-1]) + 1
            if idx.size != e - s:
                raise ValueError(
                    f"fragment {p} leaf {i}: non-contiguous layer band "
                    f"{idx.tolist()} — the packed wire flattens one "
                    "contiguous slice per region")
            per = int(leaf.size) // int(leaf.shape[0])
            regs.append(Region(i, s, e, (e - s) * per))
        if tuple(r.elems for r in regs) != tuple(part.region_sizes[p]):
            raise AssertionError(
                f"fragment {p}: region index {[r.elems for r in regs]} "
                f"disagrees with region_sizes {part.region_sizes[p]}")
        out.append(tuple(regs))
    return tuple(out)


def region_take(leaf, region: Region, lead_axes: int = 0):
    """Slice ``region`` out of ``leaf`` (which may carry ``lead_axes``
    leading replica axes) and flatten it to (*lead, elems)."""
    if region.start is not None:
        sl = (slice(None),) * lead_axes + (slice(region.start,
                                                 region.stop),)
        leaf = leaf[sl]
    return leaf.reshape(leaf.shape[:lead_axes] + (-1,))


def region_put(leaf, region: Region, flat, lead_axes: int = 0):
    """Inverse of ``region_take``: write the flat region values back
    into ``leaf`` (static slice update; whole-leaf regions reshape)."""
    if region.start is None:
        return flat.reshape(leaf.shape).astype(leaf.dtype)
    sl = (slice(None),) * lead_axes + (slice(region.start, region.stop),)
    return leaf.at[sl].set(
        flat.reshape(leaf[sl].shape).astype(leaf.dtype))


# ---------------------------------------------------------------------------
# per-round sync schedule
# ---------------------------------------------------------------------------

class StreamEvent(NamedTuple):
    kind: str          # "send" | "apply"
    fragment: int
    wrapped: bool      # apply deferred from the previous round's send


class StreamSchedule(NamedTuple):
    """Static per-round event plan. ``phases`` is a tuple of
    (inner_steps, events) pairs covering the round: run that many inner
    steps, then fire the events in order. Step counts sum to H."""
    n_fragments: int
    H: int
    tau: int
    send_offsets: tuple    # per fragment, in (0, H]
    apply_offsets: tuple   # per fragment, send + tau (may exceed H:
    #                        the apply lands in the NEXT round)
    phases: tuple


def schedule(n_fragments: int, H: int, tau: int = 0) -> StreamSchedule:
    """Build the staggered fragment schedule for one round.

    Fragment p sends at inner offset p·H/P ("after that many inner
    steps"); offset 0 maps to H — the end-of-round boundary — so P=1
    reduces to the classic DiLoCo outer step and the steady-state cycle
    is unchanged. The apply fires τ steps after the send; τ ≥ H would
    mean a collective still in flight when the fragment's next send is
    due, so τ is restricted to [0, H). At equal offsets, applies of
    earlier sends complete before new sends snapshot.
    """
    P, H, tau = int(n_fragments), int(H), int(tau)
    if P < 1 or H < 1:
        raise ValueError(f"need P >= 1 and H >= 1, got P={P} H={H}")
    if P > H:
        # more fragments than inner offsets would force >1 fragment
        # onto the same sync instant, silently breaking the peak-
        # bytes-per-sync accounting
        raise ValueError(
            f"streaming needs P <= H to stagger every fragment on its "
            f"own inner offset, got P={P} H={H}")
    if not 0 <= tau < H:
        raise ValueError(f"stream_tau must be in [0, H): tau={tau} H={H}")
    send = tuple((p * H) // P or H for p in range(P))
    apply_abs = tuple(s + tau for s in send)

    events: dict[int, tuple[list, list]] = {}

    def at(off):
        return events.setdefault(off, ([], []))

    for p in range(P):
        at(send[p])[1].append(p)
        if tau > 0:
            a = apply_abs[p]
            at(a - H if a > H else a)[0].append(p)

    phases = []
    prev = 0
    for off in sorted(events):
        applies, sends = events[off]
        acts = [StreamEvent("apply", p, apply_abs[p] > H)
                for p in sorted(applies)]
        for p in sorted(sends):
            acts.append(StreamEvent("send", p, False))
            if tau == 0:
                acts.append(StreamEvent("apply", p, False))
        phases.append((off - prev, tuple(acts)))
        prev = off
    if prev < H:                       # unreachable (fragment 0 sends
        phases.append((H - prev, ()))  # at H) — kept defensive
    return StreamSchedule(P, H, tau, send, apply_abs, tuple(phases))
