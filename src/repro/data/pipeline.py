"""Deterministic synthetic LM data: per-shard Markov-mixture streams.

The paper trains on C4 with i.i.d. (random) vs non-i.i.d. (k-Means
clustered) shards. Offline we reproduce the *statistical structure* that
matters to DiLoCo — shards with identical vs distinct distributions and a
shared, learnable generative process — with first-order Markov chains:

  - A base transition matrix T0 (seeded) shared by all shards.
  - Per-shard perturbations P_i; shard i samples from
    softmax(T0 + alpha * P_i). alpha=0 -> i.i.d.; alpha>0 -> non-i.i.d.
  - The validation stream samples from the *mixture* over shards,
    mirroring C4's global validation split.

Models can genuinely reduce perplexity toward the chain entropy floor, so
all of the paper's comparisons (DiLoCo vs baselines, i.i.d. vs non-i.i.d.,
outer optimizers, ...) are measurable end-to-end on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


class MarkovMixture:
    """Deterministic, stateless batch sampler over k shard distributions."""

    def __init__(self, vocab_size: int = 256, k: int = 8,
                 alpha: float = 2.0, seed: int = 0,
                 shard_sizes: np.ndarray | None = None):
        self.vocab_size = vocab_size
        self.k = k
        self.alpha = float(alpha)
        rng = np.random.default_rng(seed)
        base = rng.normal(size=(vocab_size, vocab_size)).astype(np.float32)
        pert = rng.normal(size=(k, vocab_size, vocab_size)).astype(np.float32)
        # logits: (k, V, V); shard i transition logits
        self._logits = jnp.asarray(base[None] + self.alpha * pert)
        # mixture (validation) logits: average of per-shard *probabilities*
        probs = jax.nn.softmax(self._logits, axis=-1)
        self._mix_logits = jnp.log(jnp.mean(probs, axis=0) + 1e-9)
        if shard_sizes is None:
            shard_sizes = np.ones((k,), np.float32)
        self.shard_sizes = np.asarray(shard_sizes, np.float32)

    # ---- sampling ----
    @functools.partial(jax.jit, static_argnums=(0, 3, 4))
    def sample_shard(self, key, shard_id, batch: int, seq_len: int):
        """tokens (batch, seq_len) int32 from shard ``shard_id``'s chain."""
        logits = self._logits[shard_id]                       # (V, V)
        return _sample_chain(key, logits, batch, seq_len)

    @functools.partial(jax.jit, static_argnums=(0, 2, 3))
    def sample_all_shards(self, key, batch: int, seq_len: int):
        """tokens (k, batch, seq_len): one batch per shard (vmapped)."""
        keys = jax.random.split(key, self.k)
        return jax.vmap(lambda kk, lg: _sample_chain(kk, lg, batch, seq_len)
                        )(keys, self._logits)

    @functools.partial(jax.jit, static_argnums=(0, 2, 3))
    def sample_validation(self, key, batch: int, seq_len: int):
        return _sample_chain(key, self._mix_logits, batch, seq_len)

    # ---- resharding ----
    def regroup(self, k_workers: int) -> "MarkovMixture":
        """Redistribute this mixture's k shards among ``k_workers``
        (round-robin), holding the DATA-GENERATING PROCESS fixed: the
        validation mixture is unchanged, each worker samples from the
        probability-mixture of its assigned shards. This is how the
        paper varies the replica count — the dataset (C4) stays the
        same, only its partitioning changes."""
        import copy
        assert 1 <= k_workers <= self.k
        probs = jax.nn.softmax(self._logits, axis=-1)         # (k,V,V)
        groups = []
        sizes = []
        for i in range(k_workers):
            idx = list(range(i, self.k, k_workers))
            groups.append(jnp.log(jnp.mean(probs[jnp.asarray(idx)], 0)
                                  + 1e-9))
            sizes.append(float(self.shard_sizes[idx].sum()))
        new = copy.copy(self)
        new.k = k_workers
        new._logits = jnp.stack(groups)
        # _mix_logits (validation) intentionally unchanged
        new.shard_sizes = np.asarray(sizes, np.float32)
        return new

    # ---- statistics ----
    def entropy_floor(self) -> float:
        """Per-token entropy (nats) of the mixture chain = best achievable
        validation loss; exp() of it is the perplexity floor."""
        p = jax.nn.softmax(self._mix_logits, axis=-1)
        # stationary distribution via power iteration
        pi = jnp.full((self.vocab_size,), 1.0 / self.vocab_size)
        for _ in range(64):
            pi = pi @ p
        ent = -jnp.sum(pi[:, None] * p * jnp.log(p + 1e-12))
        return float(ent)


def _sample_chain(key, logits, batch: int, seq_len: int):
    k0, k1 = jax.random.split(key)
    first = jax.random.randint(k0, (batch,), 0, logits.shape[0])

    def step(tok, kk):
        nxt = jax.random.categorical(kk, logits[tok], axis=-1)
        return nxt, nxt

    keys = jax.random.split(k1, seq_len - 1)
    _, rest = jax.lax.scan(step, first, keys)
    return jnp.concatenate([first[None], rest], 0).T.astype(jnp.int32)


def batch_iterator(sampler: MarkovMixture, batch: int, seq_len: int,
                   seed: int = 0, mode: str = "shards"):
    """Infinite deterministic iterator; mode: shards|validation."""
    step = 0
    key = jax.random.PRNGKey(seed)
    while True:
        sub = jax.random.fold_in(key, step)
        if mode == "shards":
            yield sampler.sample_all_shards(sub, batch, seq_len)
        else:
            yield sampler.sample_validation(sub, batch, seq_len)
        step += 1
