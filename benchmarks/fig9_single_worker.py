"""Figure 9: accelerating a single worker.

DiLoCo with k=1 (an outer step every H inner steps — a Lookahead-style
optimizer) vs plain AdamW for the same number of sequential steps, at
ZERO communication cost.

Micro-scale deviation (measured, recorded): the paper's default outer
Nesterov (lr=0.7, mu=0.9) amplifies the k=1 delta ~lr/(1-mu)=7x at
steady state and overshoots on our short, low-noise runs (+11 % PPL);
with (lr=1.0, mu=0.5) k=1 DiLoCo matches the baseline exactly. The
paper's *acceleration* needs its long-horizon noisy-SGD regime; the
claim validated here is the weaker "k=1 costs nothing"."""
from __future__ import annotations

from . import common as C


def run(scale: int = 1):
    p = dict(C.DEFAULTS)
    rounds = 30 * scale
    N = rounds * p["H"]
    arch, loss_fn, sampler = C.make_setup("iid", k=1)
    params0, pre = C.pretrain(arch, loss_fn, sampler, p["pretrain"],
                              batch=p["batch"], seq=p["seq"],
                              lr=p["inner_lr"], warmup=p["warmup"],
                              total=p["pretrain"] + N)
    base, _ = C.run_baseline(arch, loss_fn, sampler, params0, steps=N,
                             batch=p["batch"], seq=p["seq"], step0=pre,
                             total=pre + N, eval_every=p["H"])
    dil, _ = C.run_diloco(arch, loss_fn, sampler, params0, k=1,
                          H=p["H"], rounds=rounds, step0=pre,
                          outer_lr=1.0, outer_momentum=0.5,
                          batch=p["batch"], seq=p["seq"])
    payload = {"baseline_curve": base, "diloco_k1_curve": dil,
               "baseline_ppl": C.final_ppl(base),
               "diloco_k1_ppl": C.final_ppl(dil),
               "claims": {"k1_at_least_as_good":
                          C.final_ppl(dil)
                          <= C.final_ppl(base) * 1.03}}
    C.save("fig9_single_worker", payload)
    return payload


if __name__ == "__main__":
    out = run()
    print(f"baseline ppl={out['baseline_ppl']:.3f}  "
          f"DiLoCo k=1 ppl={out['diloco_k1_ppl']:.3f}")
    print(out["claims"])
