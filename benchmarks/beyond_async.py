"""Beyond-paper: asynchronous DiLoCo (the paper's §5 future work).

Superseded by ``benchmarks.async_sync``, which owns the straggler
comparison (plus equal-token, fault, and wire sections) and writes the
gated ``BENCH_async.json``. This module stays registered so existing
``run.py`` invocations and saved-result consumers keep working — it
just runs the tentpole benchmark and re-exports the straggler slice
under the old result name.
"""
from __future__ import annotations

from . import async_sync
from . import common as C


def run(scale: int = 1):
    res = async_sync.LAST_RESULT or async_sync.run(scale)
    st = res["straggler"]
    payload = {
        "superseded_by": "async_sync",
        "speeds": res["config"]["straggler_speeds"],
        "ticks": res["config"]["straggler_ticks"],
        "sync_straggler_ppl": st["sync"]["ppl"],
        "sync_outer_updates": st["sync"]["outer_updates"],
        "async": {lam: st[f"async_lam{lam}"] for lam in ("0.7", "1.0")},
        "claims": {
            name: res["claims"][name]
            for name in ("async_beats_straggler_paced_sync",
                         "async_more_updates_per_wallclock",
                         "staleness_discount_not_harmful")},
    }
    C.save("beyond_async", payload)
    return payload


if __name__ == "__main__":
    res = run()
    print("sync (straggler-paced) ppl:", round(res["sync_straggler_ppl"], 1),
          "updates:", res["sync_outer_updates"])
    for lam, v in res["async"].items():
        print(f"async λ={lam}: ppl={v['ppl']:.1f} "
              f"updates={v['outer_updates']} "
              f"staleness={v['mean_staleness']:.2f}")
    print(res["claims"])
