"""Continuous batching: the engine's outputs must be IDENTICAL to
running each request in isolation (shared-clock alignment is exact for
translation-invariant positions), slots must refill dynamically, the
paged KV-cache layout must be bit-identical to the contiguous one, and
the int4 packed-weights serving path must stay within the gated logits
tolerance of f32."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.launch.batching import ContinuousBatcher
from repro.launch.serve import greedy_decode
from repro.models.registry import get_smoke_arch, Arch


def _isolated(arch, params, prompt, gen):
    toks = greedy_decode(arch, params, jnp.asarray(prompt)[None],
                         gen=gen)
    return np.asarray(toks[0], np.int64)


@functools.lru_cache(maxsize=None)
def _arch_params(name, window=0):
    arch = get_smoke_arch(name)
    if window:
        arch = Arch(cfg=arch.cfg.replace(window=window))
    params, _ = arch.init(jax.random.PRNGKey(0), arch.cfg)
    return arch, params


@pytest.mark.parametrize("name", ["stablelm_1_6b", "zamba2_2_7b"])
def test_continuous_matches_isolated(name):
    arch, params = _arch_params(name)
    key = jax.random.PRNGKey(1)
    prompts = [
        np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                      (L,), 0, arch.cfg.vocab_size))
        for i, L in enumerate([12, 7, 19, 5])]
    gens = [6, 9, 4, 8]

    eng = ContinuousBatcher(arch, params, slots=2, cache_len=96)
    rids = [eng.submit(p, g) for p, g in zip(prompts, gens)]
    out = eng.run_until_drained()
    assert set(out) == set(rids)

    for rid, p, g in zip(rids, prompts, gens):
        want = _isolated(arch, params, p, g)
        np.testing.assert_array_equal(out[rid], want,
                                      err_msg=f"{name} rid={rid}")


# paged layout must reproduce the contiguous ring EXACTLY across the
# registry families it serves: rotary full attention, rotary sliding
# window, hybrid SSM+shared-attention, pure xLSTM
@pytest.mark.parametrize("name,window", [
    ("stablelm_1_6b", 0),
    ("stablelm_1_6b", 32),
    ("zamba2_2_7b", 0),
    ("xlstm_350m", 0),
])
def test_paged_bit_identical_to_contiguous(name, window):
    arch, params = _arch_params(name, window)
    key = jax.random.PRNGKey(2)
    prompts = [
        np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                      (L,), 0, arch.cfg.vocab_size))
        for i, L in enumerate([12, 7, 19, 5])]
    gens = [6, 1, 4, 8]          # includes the max_new=1 edge

    outs = {}
    for paged in (False, True):
        eng = ContinuousBatcher(arch, params, slots=2, cache_len=96,
                                paged=paged, page_size=16)
        rids = [eng.submit(p, g) for p, g in zip(prompts, gens)]
        outs[paged] = [eng.run_until_drained()[r] for r in rids]
    for i, (c, p) in enumerate(zip(outs[False], outs[True])):
        np.testing.assert_array_equal(c, p,
                                      err_msg=f"{name} w={window} i={i}")
    # and both match isolation
    for i, (p, g) in enumerate(zip(prompts, gens)):
        np.testing.assert_array_equal(outs[True][i],
                                      _isolated(arch, params, p, g))


@pytest.mark.parametrize("paged", [False, True])
def test_max_new_one_generates_exactly_one(paged):
    # regression: the seed appended the prefill token AND let the same
    # tick's batched decode append a second one before checking
    # ``remaining`` — max_new=1 returned 2 tokens
    arch, params = _arch_params("stablelm_1_6b")
    eng = ContinuousBatcher(arch, params, slots=2, cache_len=64,
                            paged=paged)
    prompt = np.arange(6)
    rid = eng.submit(prompt, 1)
    out = eng.run_until_drained()
    assert len(out[rid]) == 1
    np.testing.assert_array_equal(out[rid],
                                  _isolated(arch, params, prompt, 1))


@pytest.mark.parametrize("paged", [False, True])
def test_long_prompt_deferred_keeps_incumbent_exact(paged):
    # regression: admitting a prompt longer than the current clock used
    # to JUMP the shared clock mid-run, opening a position gap in every
    # incumbent's ring (wrong relative distances from then on). The
    # engine must defer the long request until the clock catches up —
    # and the overlap must leave the incumbent's tokens untouched.
    arch, params = _arch_params("stablelm_1_6b")
    eng = ContinuousBatcher(arch, params, slots=2, cache_len=96,
                            paged=paged)
    short = np.arange(6) % arch.cfg.vocab_size
    long_ = (np.arange(20) * 3) % arch.cfg.vocab_size
    r_short = eng.submit(short, 30)
    r_long = eng.submit(long_, 4)
    # drive ticks until the long request finishes: it must overlap the
    # still-active short one (that's the mid-run admission under test)
    for _ in range(100):
        eng.tick()
        if r_long in eng.finished:
            break
    assert r_long in eng.finished
    assert r_short not in eng.finished, \
        "long request should finish while the incumbent is still active"
    out = eng.run_until_drained()
    np.testing.assert_array_equal(out[r_short],
                                  _isolated(arch, params, short, 30))
    np.testing.assert_array_equal(out[r_long],
                                  _isolated(arch, params, long_, 4))


def test_drain_order_many_requests_two_slots():
    # 6 requests of differing lengths through 2 slots: all complete,
    # each exactly matches isolation regardless of admission order
    arch, params = _arch_params("stablelm_1_6b")
    key = jax.random.PRNGKey(3)
    prompts = [
        np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                      (L,), 0, arch.cfg.vocab_size))
        for i, L in enumerate([9, 4, 16, 6, 11, 5])]
    gens = [3, 7, 2, 5, 1, 4]
    eng = ContinuousBatcher(arch, params, slots=2, cache_len=96)
    rids = [eng.submit(p, g) for p, g in zip(prompts, gens)]
    out = eng.run_until_drained()
    assert set(out) == set(rids)
    for rid, p, g in zip(rids, prompts, gens):
        np.testing.assert_array_equal(out[rid],
                                      _isolated(arch, params, p, g))


def test_first_token_respects_temperature():
    # regression: greedy_decode always argmax'd the FIRST generated
    # token, ignoring temperature at position 0 — across seeds the
    # first column must actually vary when temperature > 0
    arch, params = _arch_params("stablelm_1_6b")
    prompts = jnp.asarray(np.arange(4 * 8).reshape(4, 8)
                          % arch.cfg.vocab_size, jnp.int32)
    cold = np.asarray(greedy_decode(arch, params, prompts, gen=2))
    firsts = [np.asarray(greedy_decode(arch, params, prompts, gen=2,
                                       temperature=5.0, seed=s))[:, 0]
              for s in range(6)]
    assert any(not np.array_equal(f, cold[:, 0]) for f in firsts), \
        "temperature>0 never changed the first generated token"
    assert any(not np.array_equal(firsts[0], f) for f in firsts[1:]), \
        "first token identical across seeds at temperature 5.0"
    # and temperature=0 stays deterministic
    again = np.asarray(greedy_decode(arch, params, prompts, gen=2))
    np.testing.assert_array_equal(cold, again)


def test_packed_int4_weights_serve_close_to_f32(tmp_path):
    # int4 packed-weight serving: logits within tolerance of f32, and
    # the engine's packed path completes every request
    arch, params = _arch_params("stablelm_1_6b")
    path = str(tmp_path / "w.packed.npz")
    man = ckpt.save_packed(path, params, n_fragments=4)
    assert man["f32_bytes"] / man["packed_bytes"] > 5.0
    packed = ckpt.load_packed(path)

    deq = ckpt.unpack_params(
        {k: jnp.asarray(v) for k, v in packed["buffers"].items()},
        manifest=packed["manifest"], example_tree=params)
    toks = jnp.asarray(np.arange(2 * 12).reshape(2, 12)
                       % arch.cfg.vocab_size, jnp.int32)
    lf, _ = arch.prefill(params, {"tokens": toks}, cache_len=16)
    lq, _ = arch.prefill(deq, {"tokens": toks}, cache_len=16)
    scale = float(jnp.abs(lf).max())
    assert float(jnp.abs(lf - lq).max()) <= 0.15 * scale + 0.05

    eng = ContinuousBatcher(arch, params, slots=2, cache_len=64,
                            packed_weights=packed)
    rids = [eng.submit(np.arange(5 + i) % arch.cfg.vocab_size, 4)
            for i in range(3)]
    out = eng.run_until_drained()
    assert set(out) == set(rids)
    assert all(len(out[r]) == 4 for r in rids)


def test_slots_refill():
    arch = get_smoke_arch("stablelm_1_6b")
    params, _ = arch.init(jax.random.PRNGKey(0), arch.cfg)
    eng = ContinuousBatcher(arch, params, slots=2, cache_len=64)
    for i in range(5):
        eng.submit(np.arange(4) + i, 3)
    out = eng.run_until_drained()
    assert len(out) == 5                 # 5 requests through 2 slots
    assert all(len(v) == 3 for v in out.values())


def test_learned_positions_rejected():
    arch = get_smoke_arch("whisper_large_v3")
    params, _ = arch.init(jax.random.PRNGKey(0), arch.cfg)
    with pytest.raises(ValueError):
        ContinuousBatcher(arch, params, slots=2, cache_len=64)
