"""Pallas TPU kernels for DiLoCo's compute hot-spots.

flash_attention.py  blocked online-softmax attention (inner-loop compute)
fused_adamw.py      one-VMEM-pass inner AdamW update (memory-bound)
sign_prune.py       fused sign election + magnitude pruning (Table 6)
outer_nesterov.py   fused outer Nesterov update
ops.py              backend dispatch (kernel on TPU, jnp oracle elsewhere)
ref.py              pure-jnp oracles for every kernel
"""
