"""Mixed-precision replica-state policy for the DiLoCo hot path.

DiLoCo's per-worker memory bill is the k-fold replica state: every
replica carries its params plus AdamW moments, all donated through the
scanned driver. The precision policy splits that state into two tiers:

  param_dtype   storage dtype of the *replica-side* state — the working
                params the forward/backward runs on AND the AdamW m/v
                moments. ``bfloat16`` halves the params+moments carry
                (12 B/param -> 6 B/param per replica).
  master_dtype  storage dtype of the *master-side* state. When it is
                higher precision than ``param_dtype`` the inner AdamW
                state carries a per-replica master copy of the params at
                this dtype: the fused update reads bf16 grads/moments
                plus the f32 master, runs the math in f32, writes the
                f32 master back and emits the bf16 working copy — so
                param round-off never accumulates across inner steps,
                and the outer deltas Δ_i = θ − θ_i are computed
                master-vs-master at full precision.

Policies (the only supported combinations):

  (float32, float32)   — the default; bit-identical to the historical
                         all-f32 path (no master copy is allocated).
  (bfloat16, float32)  — THE mixed policy: bf16 working params + bf16
                         moments + f32 master. Replica params+moments
                         carry halves; the f32 master adds 4 B/param,
                         still a net reduction with full-precision
                         outer gradients.
  (bfloat16, bfloat16) — pure low-precision replica state (no master;
                         the fused kernel still accumulates in f32
                         before rounding stores). Smallest carry,
                         outer deltas quantize at bf16.

``master_dtype`` below ``param_dtype`` is rejected — a master that is
*less* precise than the working copy is meaningless.

The global parameters and the outer optimizer buffers always stay at
the caller's precision (f32 everywhere in this repo): they exist once,
not k times, so shrinking them saves little and costs outer-step
accuracy.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

DTYPES = {
    "float32": jnp.float32,
    "bfloat16": jnp.bfloat16,
}

# storage width used for validation: master must not be narrower
_WIDTH = {"float32": 4, "bfloat16": 2}


class Policy(NamedTuple):
    """Resolved precision policy. Fields are jnp dtypes."""
    param_dtype: jnp.dtype
    master_dtype: jnp.dtype

    @property
    def mixed(self) -> bool:
        """True when a separate master copy is carried (param storage is
        narrower than master storage)."""
        return self.param_dtype != self.master_dtype


def make_policy(param_dtype: str = "float32",
                master_dtype: str = "float32") -> Policy:
    for name, val in (("param_dtype", param_dtype),
                      ("master_dtype", master_dtype)):
        if val not in DTYPES:
            raise ValueError(
                f"{name} must be one of {sorted(DTYPES)}, got {val!r}")
    if _WIDTH[master_dtype] < _WIDTH[param_dtype]:
        raise ValueError(
            f"master_dtype ({master_dtype}) must be at least as wide as "
            f"param_dtype ({param_dtype})")
    return Policy(jnp.dtype(DTYPES[param_dtype]),
                  jnp.dtype(DTYPES[master_dtype]))


def policy_of(cfg) -> Policy:
    """Resolve the policy of a TrainConfig / DiLoCoConfig (missing
    fields default to float32, i.e. the legacy path)."""
    return make_policy(getattr(cfg, "param_dtype", "float32"),
                       getattr(cfg, "master_dtype", "float32"))


def cast_tree(tree, dtype, *, fresh: bool = False):
    """Cast every leaf to ``dtype`` (no-op leaves stay unchanged).

    ``fresh=True`` guarantees every returned leaf is a NEW buffer even
    when the cast is the identity (``astype`` to the leaf's own dtype
    returns the same array). Use it whenever the result is handed to a
    donated jit argument: donating an aliased leaf deletes the
    caller's array with it."""
    if fresh:
        return jax.tree.map(lambda x: jnp.array(x, dtype=dtype), tree)
    return jax.tree.map(lambda x: x.astype(dtype), tree)


def tree_bytes(tree) -> int:
    """Total storage bytes of a pytree's leaves (None-safe)."""
    return int(sum(l.size * l.dtype.itemsize
                   for l in jax.tree.leaves(tree)))
