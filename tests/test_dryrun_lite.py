"""Dry-run machinery tests on a small fake-device mesh (subprocess).

The full 512-device dry-run is exercised by launch/dryrun.py runs (see
EXPERIMENTS.md); here a 8-device (2, 2, 2) mesh in a subprocess checks
the same code path end-to-end — lowering, compiling, HLO collective
parsing with pod-crossing classification — quickly enough for CI.
"""
from __future__ import annotations

import json
import subprocess
import sys
import textwrap

import pytest

from repro.launch import hlo_analysis as H


# ---------------------------------------------------------------------------
# HLO parsing units (no devices needed)
# ---------------------------------------------------------------------------

def test_type_bytes():
    assert H._type_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert H._type_bytes("(f32[4], bf16[8])") == 16 + 16
    assert H._type_bytes("pred[]") == 0 or True  # scalars ~0


def test_iota_groups_transposed():
    # [256,2]<=[2,16,16]T(2,1,0): group j = {j, j+256}
    g = H._iota_groups(256, 2, [16, 16, 2][::-1], None)  # sanity base
    line = ("%ar = f32[8]{0} all-reduce(%x), "
            "replica_groups=[256,2]<=[2,16,16]T(2,1,0), to_apply=%add")
    groups = H._line_groups(line)
    assert groups is not None
    for grp in groups:
        assert len(grp) == 2
        assert abs(grp[0] - grp[1]) == 256
    st = H.collective_stats(
        "ENTRY %main (p: f32[8]) -> f32[8] {\n  " + line + "\n}",
        chips_per_pod=256)
    assert st.cross_pod_bytes == 32
    assert st.intra_pod_bytes == 0


def test_explicit_groups_intra():
    line = ("%ag = f32[16]{0} all-gather(%x), "
            "replica_groups={{0,1},{2,3}}, dimensions={0}")
    st = H.collective_stats(
        "ENTRY %main (p: f32[8]) -> f32[16] {\n  " + line + "\n}",
        chips_per_pod=2)
    assert st.cross_pod_bytes == 0
    assert st.intra_pod_bytes == 64


def test_while_trip_multiplier():
    hlo = textwrap.dedent("""\
    %cond (p: (s32[], f32[8])) -> pred[] {
      %c = s32[] constant(12)
      %i = s32[] get-tuple-element(%p), index=0
      ROOT %lt = pred[] compare(%i, %c), direction=LT
    }
    %body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
      %x = f32[8]{0} get-tuple-element(%p), index=1
      %ar = f32[8]{0} all-reduce(%x), replica_groups={{0,1}}, to_apply=%add
      ROOT %t = (s32[], f32[8]) tuple(%i, %ar)
    }
    ENTRY %main (p0: (s32[], f32[8])) -> (s32[], f32[8]) {
      ROOT %w = (s32[], f32[8]) while(%p0), condition=%cond, body=%body
    }
    """)
    st = H.collective_stats(hlo, chips_per_pod=2)
    assert st.total_bytes == 12 * 32      # trip count applied
    mult = H.computation_multipliers(hlo)
    assert mult.get("body") == 12


def test_roofline_bound_selection():
    coll = H.CollectiveStats(total_bytes=0, cross_pod_bytes=0,
                             intra_pod_bytes=0)
    terms = H.roofline(1e18, 1e12, coll, chips=256)
    assert terms["bound"] == "compute_s"
    coll2 = H.CollectiveStats(total_bytes=10**11, cross_pod_bytes=0,
                              intra_pod_bytes=10**11,
                              by_op={"all-reduce": 10**11})
    terms2 = H.roofline(1e12, 1e9, coll2, chips=256)
    assert terms2["bound"] == "collective_s"


# ---------------------------------------------------------------------------
# jaxpr cost walker
# ---------------------------------------------------------------------------

def test_jaxpr_cost_scan_multiplier():
    import jax
    import jax.numpy as jnp
    from repro.launch.jaxpr_cost import jaxpr_cost

    def body(c, _):
        return c @ c, None

    def fn(x):
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    cost = jaxpr_cost(fn, x)
    assert cost["flops"] == 7 * 2 * 32 * 32 * 32
    assert cost["dots"] == 7


def test_jaxpr_cost_grad_counts_backward():
    import jax
    import jax.numpy as jnp
    from repro.launch.jaxpr_cost import jaxpr_cost

    def loss(w, x):
        return jnp.sum((x @ w) ** 2)

    w = jax.ShapeDtypeStruct((16, 8), jnp.float32)
    x = jax.ShapeDtypeStruct((4, 16), jnp.float32)
    fwd = jaxpr_cost(loss, w, x)
    bwd = jaxpr_cost(jax.grad(loss), w, x)
    assert bwd["flops"] >= 2 * fwd["flops"]   # fwd + transpose matmuls


# ---------------------------------------------------------------------------
# end-to-end mini dry-run in a subprocess (8 fake devices)
# ---------------------------------------------------------------------------

MINI = r"""
import os
# subprocess: tests/conftest.py does not apply here, so the fake-device
# flag is set before the first jax import (the in-process tests get the
# same flag from conftest — the old module-level-in-a-test-file footgun
# is gone)
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
sys.path.insert(0, "src")
import jax
from repro.launch import dryrun as DR
from repro.launch.mesh import make_mesh

mesh_single = make_mesh((2, 2), ("data", "model"))
mesh_multi = make_mesh((2, 2, 2), ("pod", "data", "model"))
out = []
for mesh, mp, fns in [(mesh_single, False, ("main",)),
                      (mesh_multi, True, ("main", "stream", "gossip"))]:
    recs = DR.dryrun_pair("diloco_60m", "train_4k", multi_pod=mp,
                          microbatches=2, mesh=mesh, fns=fns)
    out.extend(recs)
recs = DR.dryrun_pair("diloco_60m", "decode_32k", multi_pod=False,
                      mesh=mesh_single)
out.extend(recs)
print(json.dumps([{k: v for k, v in r.items()
                   if k in ("fn", "flops", "collectives",
                            "stream_interleaving", "error")}
                  for r in out]))
"""


@pytest.mark.slow
def test_mini_dryrun_subprocess():
    res = subprocess.run([sys.executable, "-c", MINI], cwd=".",
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stderr[-2000:]
    recs = json.loads(res.stdout.splitlines()[-1])
    fns = {r["fn"] for r in recs}
    assert {"inner_train_step", "diloco_inner_step", "diloco_outer_step",
            "ddp_train_step", "diloco_stream_round", "gossip_exchange",
            "serve_step"} <= fns
    for r in recs:
        assert "error" not in r, r
        if r["fn"] == "diloco_inner_step":
            # the paper's core structural property
            assert r["collectives"]["cross_pod_bytes"] == 0
        if r["fn"] == "diloco_outer_step":
            assert r["collectives"]["cross_pod_bytes"] > 0
        if r["fn"] == "ddp_train_step":
            assert r["collectives"]["cross_pod_bytes"] > 0
        if r["fn"] == "diloco_stream_round":
            # Streaming DiLoCo's structural property: >= P pod-axis
            # all-reduces INTERLEAVED with inner-step compute (a
            # re-serialized schedule would cluster them at round end),
            # and zero cross-pod collectives inside inner-step loops
            P_frag = 2          # dryrun.STREAM_FRAGMENTS
            st = r["stream_interleaving"]
            assert st["pod_all_reduces"] >= P_frag, st
            assert st["syncs_with_compute_after"] >= P_frag - 1, st
            assert st["compute_events"] > 0, st
            assert st["syncs_inside_compute"] == 0, st
            assert r["collectives"]["cross_pod_bytes"] > 0
        if r["fn"] == "gossip_exchange":
            # gossip's structural property: the pairwise exchange is a
            # pod PERMUTATION collective only — cross-pod bytes flow,
            # but nothing reduces or gathers across the whole fleet
            c = r["collectives"]
            assert c["cross_pod_bytes"] > 0, c
            assert set(c["by_op"]) == {"collective-permute"}, c
