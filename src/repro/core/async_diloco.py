"""Asynchronous DiLoCo — the paper's stated future work (§5, third
limitation): "extend DiLoCo to the asynchronous setting, whereby
workers update the global parameter without ever waiting for any other
worker."

Design (beyond-paper, kept deliberately close to Algorithm 1):

* Workers are heterogeneous: worker i takes ``speed_i`` rounds of
  wall-clock to finish its H inner steps (speed 1 = fastest).
* A parameter server holds the global copy θ and the outer-optimizer
  state. Whenever ANY worker finishes, its outer gradient
  Δ_i = θ^(dispatch) − θ_i is applied IMMEDIATELY — no barrier — at
  weight λ^τ / k: the 1/k is each worker's share of a round's evidence
  (synchronous DiLoCo averages k deltas; applying each at full weight
  over-steps k-fold), and λ^τ (τ = outer steps since dispatch) is the
  staleness discount for delay compensation.
* With all speeds equal and λ=1 a tick applies the same total update
  mass as one synchronous round (k deltas × 1/k), just sequentially
  through the momentum buffer (tested).

This module simulates the asynchrony on one host with a wall-clock
tick loop; the collective structure matches the sharded deployment
(each application is a single pod→global transfer of one outer
gradient — even less coupled than synchronous DiLoCo's all-reduce).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DiLoCoConfig, TrainConfig
from repro.optim import adamw
from . import diloco, outer_opt


@dataclass
class AsyncConfig:
    k: int = 8
    H: int = 10
    outer_lr: float = 0.7
    outer_momentum: float = 0.9
    staleness_lambda: float = 0.7   # discount per outer step of delay
    speeds: tuple = ()              # rounds per phase, len k (default 1s)


@dataclass
class _Worker:
    params: Any
    opt: Any
    dispatched_version: int         # outer step count at dispatch
    finish_tick: int                # wall-clock tick when phase completes


def run_async(loss_fn: Callable, sample_fn: Callable, params0,
              acfg: AsyncConfig, tcfg: TrainConfig, *, ticks: int,
              eval_fn=None, eval_tokens=None, seed: int = 0):
    """Simulate ``ticks`` wall-clock units; one tick = the fastest
    worker's phase time. Returns (global_params, history)."""
    k = acfg.k
    speeds = list(acfg.speeds) or [1] * k
    assert len(speeds) == k
    inner_step = diloco.make_inner_step(loss_fn, tcfg,
                                        total_steps=tcfg.total_steps)

    @jax.jit
    def run_phase(params, opt, key, step0):
        def body(carry, h):
            p, o = carry
            batch = {"tokens": sample_fn(jax.random.fold_in(key, h),
                                         tcfg.batch_size, tcfg.seq_len)}
            p, o, m = inner_step(p, o, batch, step0 + h)
            return (p, o), m["loss"]

        (params, opt), losses = jax.lax.scan(
            body, (params, opt), jnp.arange(acfg.H))
        return params, opt, losses.mean()

    @jax.jit
    def apply_outer(global_params, buf, worker_params, dispatch_theta,
                    weight):
        delta = jax.tree.map(lambda d0, wi: (d0 - wi) * weight,
                             dispatch_theta, worker_params)
        new_buf = jax.tree.map(
            lambda b, d: acfg.outer_momentum * b + d, buf, delta)
        new_global = jax.tree.map(
            lambda p, b, d: p - acfg.outer_lr
            * (acfg.outer_momentum * b + d),
            global_params, new_buf, delta)
        return new_global, new_buf

    global_params = params0
    buf = jax.tree.map(jnp.zeros_like, params0)
    theta_at = {0: params0}            # dispatch-version -> θ snapshot
    version = 0
    inner_done = 0
    key = jax.random.PRNGKey(seed)

    workers = []
    for i in range(k):
        workers.append(_Worker(params=params0,
                               opt=adamw.init(params0),
                               dispatched_version=0,
                               finish_tick=speeds[i]))

    history = []
    for tick in range(1, ticks + 1):
        order = [i for i in range(k) if workers[i].finish_tick == tick]
        for i in order:
            w = workers[i]
            key, sub = jax.random.split(key)
            new_p, new_opt, mloss = run_phase(
                w.params, w.opt, sub, jnp.asarray(inner_done))
            inner_done += acfg.H
            staleness = version - w.dispatched_version
            weight = (acfg.staleness_lambda ** staleness) / k
            global_params, buf = apply_outer(
                global_params, buf, new_p,
                theta_at[w.dispatched_version],
                jnp.asarray(weight, jnp.float32))
            version += 1
            theta_at[version] = global_params
            # prune old snapshots
            live = {ww.dispatched_version for ww in workers} | {version}
            theta_at = {v: t for v, t in theta_at.items() if v in live}
            # re-dispatch from the fresh global copy
            workers[i] = _Worker(params=global_params, opt=new_opt,
                                 dispatched_version=version,
                                 finish_tick=tick + speeds[i])
            rec = {"tick": tick, "worker": i, "staleness": staleness,
                   "weight": float(weight), "version": version,
                   "inner_loss": float(mloss)}
            if eval_fn is not None and eval_tokens is not None:
                rec["val_loss"] = float(eval_fn(global_params,
                                                eval_tokens))
                rec["ppl"] = float(np.exp(rec["val_loss"]))
            history.append(rec)
    return global_params, history
