"""Performance-model regression tests.

These pin the §Perf findings so they can't silently regress:
cross-KV caching keeps decode FLOPs ~O(params), the fused CE never
materializes a second (B,S,V) tensor, and the jaxpr cost walker's
invariants hold.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.jaxpr_cost import jaxpr_cost
from repro.models.registry import get_smoke_arch


def test_whisper_decode_flops_near_model_flops():
    """Decode-step FLOPs must stay within ~4x of 2·N·B — the cross-KV
    cache regression guard (recomputing encoder K/V per step was 100x)."""
    arch = get_smoke_arch("whisper_large_v3")
    cfg = arch.cfg
    params, _ = arch.init(jax.random.PRNGKey(0), cfg)
    n = sum(l.size for l in jax.tree.leaves(params))
    B, S = 2, 16
    cache = jax.eval_shape(
        lambda: __import__("repro.models.model", fromlist=["m"]).init_cache(
            cfg, B, S, jnp.float32))
    from repro.models import model as M
    cache_shapes = jax.eval_shape(
        lambda: M.init_cache(cfg, B, S, jnp.float32))
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    def step(p, c, t, i):
        return M.decode_step(p, cfg, c, t, i)

    pshapes = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
    cost = jaxpr_cost(step, pshapes, cache_shapes, tok, pos)
    model_flops = 2 * n * B
    assert cost["flops"] < 6 * model_flops, (cost["flops"], model_flops)


def test_fused_ce_cheaper_than_log_softmax():
    """next_token_loss (logsumexp−gather) must move strictly fewer
    modeled bytes than the log_softmax formulation it replaced."""
    from repro.models.layers import next_token_loss
    B, S, V = 4, 32, 1000
    logits = jax.ShapeDtypeStruct((B, S, V), jnp.float32)
    toks = jax.ShapeDtypeStruct((B, S), jnp.int32)

    def log_softmax_version(lg, tk):
        lp = jax.nn.log_softmax(lg[:, :-1].astype(jnp.float32), -1)
        tgt = tk[:, 1:]
        nll = -jnp.take_along_axis(lp, tgt[..., None], -1)[..., 0]
        return jnp.mean(nll)

    fused = jaxpr_cost(next_token_loss, logits, toks)
    old = jaxpr_cost(log_softmax_version, logits, toks)
    assert fused["bytes"] < old["bytes"], (fused["bytes"], old["bytes"])
    # and it computes the same value
    key = jax.random.PRNGKey(0)
    lg = jax.random.normal(key, (B, S, V))
    tk = jax.random.randint(jax.random.fold_in(key, 1), (B, S), 0, V)
    np.testing.assert_allclose(next_token_loss(lg, tk),
                               log_softmax_version(lg, tk), rtol=1e-5)


def test_jaxpr_cost_bytes_bracket():
    """bytes_min <= bytes for a layered scan program."""
    def body(c, w):
        return jnp.tanh(c @ w), None

    def fn(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((12, 64, 64), jnp.float32)
    c = jaxpr_cost(fn, x, ws)
    assert 0 < c["bytes_min"] <= c["bytes"]
    assert c["flops"] == 12 * 2 * 64 ** 3


def test_innermost_scan_is_fused_leaf():
    """An innermost scan's interior bytes appear in the upper bound but
    not in the fused lower bound."""
    def inner(c, k):
        s = c @ k                    # big intermediate
        return c + jnp.tanh(s), None

    def fn(x, ks):
        y, _ = jax.lax.scan(inner, x, ks)
        return y

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    ks = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)
    c = jaxpr_cost(fn, x, ks)
    # upper bound contains the 8 interior s-tensors; lower bound is
    # boundary I/O only
    assert c["bytes"] > c["bytes_min"]
    boundary = (256 * 256 + 8 * 256 * 256 + 256 * 256) * 4
    assert c["bytes_min"] <= boundary * 1.01


def test_moe_topk_matches_lax_topk_values():
    """The sort-free router selects the same expert set as lax.top_k."""
    from repro.models.moe import _topk_iterative
    key = jax.random.PRNGKey(0)
    probs = jax.nn.softmax(jax.random.normal(key, (32, 64)), -1)
    v1, i1 = _topk_iterative(probs, 8)
    v2, i2 = jax.lax.top_k(probs, 8)
    np.testing.assert_allclose(np.sort(v1, -1), np.sort(v2, -1),
                               rtol=1e-6)
    assert all(set(np.asarray(a)) == set(np.asarray(b))
               for a, b in zip(i1, i2))


def test_ring_cache_decode_path_uses_dus():
    """The 1-token write lowers to dynamic-update-slice, not scatter."""
    from repro.models import layers as L
    from repro.models.registry import get_smoke_arch
    arch = get_smoke_arch("stablelm_1_6b")
    cfg = arch.cfg

    def write(cache, k, v, pos):
        p = {"wq": jnp.zeros((cfg.d_model, cfg.n_heads,
                              cfg.resolved_head_dim))}
        # call apply_attention's cache update indirectly via decode
        return None

    # direct check at the model level: decode jaxpr has no scatter of
    # cache-sized operands
    from repro.models import model as M
    params, _ = arch.init(jax.random.PRNGKey(0), cfg)
    cache = M.init_cache(cfg, 1, 16, jnp.float32)
    pshapes = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
    cshapes = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), cache)
    closed = jax.make_jaxpr(
        lambda p, c: M.decode_step(p, cfg, c,
                                   jnp.zeros((1, 1), jnp.int32),
                                   jnp.zeros((), jnp.int32)))(
        pshapes, cshapes)

    def find_scatters(jaxpr, out):
        for e in jaxpr.eqns:
            if e.primitive.name.startswith("scatter"):
                out.append(e)
            for k2 in ("jaxpr", "call_jaxpr", "body_jaxpr", "cond_jaxpr"):
                if k2 in e.params:
                    j = e.params[k2]
                    find_scatters(j.jaxpr if hasattr(j, "jaxpr") else j,
                                  out)
        return out

    scatters = find_scatters(closed.jaxpr, [])
    big = [e for e in scatters
           if np.prod(e.outvars[0].aval.shape) > 4096]
    assert not big, [e.outvars[0].aval.shape for e in big]
