"""Barrier-free async engine: reference bit-identity, donation
equivalence (mirroring test_wire_packing's aliasing probes),
mid-run checkpoint/preempt-restore bit-identity, the seed invariants
(equal speeds + λ=1 applies one sync round's mass per tick; snapshot
pruning never drops a live version; staleness weights monotone in
delay), quantized-wire error feedback, and an exactly-once sweep over
randomized fault scenarios.

Everything runs on a tiny quadratic model (11 parameters) so the whole
module is seconds, not minutes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import DiLoCoConfig, TrainConfig
from repro.core import async_diloco, diloco, faults, outer_opt
from repro.core.async_diloco import AsyncEngine
from repro.core.faults import Scenario
from repro.optim import adamw, precision
from test_faults import random_scenario


# ---------------------------------------------------------------------------
# tiny fixture: quadratic loss over 11 parameters
# ---------------------------------------------------------------------------

def tiny_params():
    return {"w": jnp.arange(8.0) / 8.0, "b": jnp.ones((3,))}


def quad_loss(p, batch):
    t = batch["tokens"].astype(jnp.float32).mean() / 7.0
    return (jnp.sum((p["w"] - t) ** 2)
            + 0.1 * jnp.sum(jnp.square(p["b"]))), {}


def sample(key, B, S):
    return jax.random.randint(key, (B, S), 0, 7, jnp.int32)


def make_cfgs(k=2, H=2, *, lam=1.0, total=64, **dkw):
    dcfg = DiLoCoConfig(k=k, H=H, transport="async",
                        staleness_lambda=lam, **dkw)
    tcfg = TrainConfig(inner_lr=0.05, warmup_steps=2, total_steps=total,
                       batch_size=2, seq_len=4)
    return dcfg, tcfg


def make_engine(k=2, H=2, *, lam=1.0, scenario=None, donate=True,
                seed=0, **dkw):
    dcfg, tcfg = make_cfgs(k, H, lam=lam, **dkw)
    return AsyncEngine(quad_loss, sample, dcfg, tcfg,
                       scenario=scenario, seed=seed, donate=donate)


def _global_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# the core acceptance property: f32 fault-free path ≡ reference
# ---------------------------------------------------------------------------

def test_f32_fault_free_bit_identical_to_sequential_reference():
    """Equal speeds, λ=1, zero faults: the engine is bit-identical to a
    hand-written sequential reference built from the public pieces
    (make_inner_step / outer_opt.update / adamw) applying each worker's
    delta at 1/k in timeline order — no engine internals involved."""
    k, H, T = 2, 2, 3
    dcfg, tcfg = make_cfgs(k, H)
    eng = make_engine(k, H)
    state = eng.init_state(tiny_params())
    state, hist = eng.run(state, ticks=T)
    assert len(hist) == k * T

    # ---- reference: a plain sequential loop, no async_diloco
    # machinery. Its phase/apply are jitted with the same op structure
    # as the engine's (scan over H; flat-delta weight then outer
    # update) so XLA rounds identically — what the comparison then
    # pins down is the engine's EVENT SEMANTICS: per-uid RNG keys,
    # tick-major application order, dispatch-snapshot deltas, 1/k
    # weights, and re-dispatch from every fresh global.
    from jax.flatten_util import ravel_pytree
    base = jax.random.PRNGKey(0)
    inner_step = diloco.make_inner_step(quad_loss, tcfg,
                                        tcfg.total_steps)
    g = tiny_params()
    _, unravel = ravel_pytree(g)

    @jax.jit
    def ref_phase(p, o, key, step0):
        def body(carry, h):
            p, o = carry
            batch = {"tokens": sample(jax.random.fold_in(key, h),
                                      tcfg.batch_size, tcfg.seq_len)}
            p, o, m = inner_step(p, o, batch, step0 + h)
            return (p, o), m["loss"]
        (p, o), _ = jax.lax.scan(body, (p, o), jnp.arange(H))
        return p, o

    @jax.jit
    def ref_apply(g, outer, snap, p, res, weight):
        d, _ = ravel_pytree(jax.tree.map(
            lambda s, q: s - q.astype(s.dtype), snap, p))
        applied = unravel((d + res) * weight)
        return outer_opt.update(
            applied, outer, g, kind=dcfg.outer_opt, lr=dcfg.outer_lr,
            momentum=dcfg.outer_momentum, b2=dcfg.outer_adam_b2,
            eps=dcfg.outer_adam_eps)

    outer = outer_opt.init(g)
    zeros = jnp.zeros((11,), jnp.float32)
    wp = [jax.tree.map(jnp.copy, g) for _ in range(k)]
    wo = [adamw.init(g, policy=precision.policy_of(tcfg))
          for _ in range(k)]
    wver = [0] * k
    snaps = {0: jax.tree.map(jnp.copy, g)}
    ver, inner_done = 0, 0
    for tick in range(1, T + 1):
        for i in range(k):       # timeline order: tick-major, worker
            uid = i * T + (tick - 1)
            key = jax.random.fold_in(base, uid)
            p, o = ref_phase(wp[i], wo[i], key,
                             jnp.asarray(inner_done))
            inner_done += H
            g, outer = ref_apply(g, outer, snaps[wver[i]], p, zeros,
                                 jnp.float32(1.0 / k))
            ver += 1
            snaps[ver] = jax.tree.map(jnp.copy, g)
            wp[i] = jax.tree.map(jnp.copy, g)
            wo[i] = o
            wver[i] = ver

    assert _global_equal(state.global_params, g)


def test_equal_speed_lambda1_applies_one_round_mass_per_tick():
    """λ=1, equal speeds: each tick delivers k arrivals at weight 1/k —
    exactly one synchronous round's total update mass per tick."""
    k = 4
    eng = make_engine(k, 1, scenario=Scenario.uniform(k))
    state, hist = eng.run(eng.init_state(tiny_params()), ticks=3)
    by_tick = {}
    for r in hist:
        assert r["event"] == "arrival"
        by_tick.setdefault(r["tick"], []).append(r["weight"])
    for tick, ws in by_tick.items():
        assert len(ws) == k
        assert abs(sum(ws) - 1.0) < 1e-12, (tick, ws)


def test_staleness_weights_match_policy_and_stay_monotone():
    k = 3
    eng = make_engine(k, 1, lam=0.7,
                      scenario=Scenario.stragglers(k, slow=(3,)))
    state, hist = eng.run(eng.init_state(tiny_params()), ticks=6)
    arr = [r for r in hist if r["event"] == "arrival"]
    assert any(r["staleness"] > 0 for r in arr)
    for r in arr:
        assert r["staleness"] >= 0
        assert r["weight"] == pytest.approx(
            0.7 ** r["staleness"] / k, rel=1e-12)
    # monotone in the delay: sort by staleness, weights non-increasing
    by_stale = sorted(arr, key=lambda r: r["staleness"])
    ws = [r["weight"] for r in by_stale]
    assert all(a >= b for a, b in zip(ws, ws[1:]))


def test_snapshot_pruning_tracks_live_versions_exactly():
    k = 3
    eng = make_engine(k, 1,
                      scenario=Scenario.stragglers(k, slow=(2, 4)))
    state = eng.init_state(tiny_params())
    # engine asserts internally that a live version is never dropped;
    # externally: after every run the table holds exactly the live set
    for _ in range(4):
        state, _ = eng.run(state, ticks=8, max_events=3)
        assert set(state.snapshots) == state.live_versions()
    assert len(state.snapshots) <= k + 1


# ---------------------------------------------------------------------------
# donation (satellite a): equivalence + aliasing probes
# ---------------------------------------------------------------------------

def _donate_all(tree):
    f = jax.jit(lambda t: jax.tree.map(lambda x: x * 1, t),
                donate_argnums=0)
    return f(tree)


def _assert_alive(tree):
    for leaf in jax.tree_util.tree_leaves(tree):
        np.asarray(leaf)  # raises RuntimeError if deleted


def test_donated_run_bit_equals_undonated_run():
    """The regression mirror of test_wire_packing's donation probes at
    the whole-engine level: donate=True and donate=False runs are
    bit-identical under a faulty scenario (stragglers + drops), so no
    donated buffer is ever read after the jit consumed it."""
    k = 2
    scen = Scenario(speeds=(1, 2), drop_prob=0.4, max_retries=1,
                    seed=5)
    outs = {}
    for donate in (True, False):
        eng = make_engine(k, 2, lam=0.8, scenario=scen, donate=donate,
                          outer_grad_dtype="int4", error_feedback=True)
        state, hist = eng.run(eng.init_state(tiny_params()), ticks=5)
        outs[donate] = (state, hist)
    sa, ha = outs[True]
    sb, hb = outs[False]
    assert _global_equal(sa.global_params, sb.global_params)
    assert [r["event"] for r in ha] == [r["event"] for r in hb]
    for ra, rb in zip(ha, hb):
        if ra["event"] == "arrival":
            assert ra["uid"] == rb["uid"]
            assert ra["inner_loss"] == rb["inner_loss"]
            assert ra["delta_norm"] == rb["delta_norm"]
    for wa, wb in zip(sa.workers, sb.workers):
        assert np.array_equal(np.asarray(wa.residual),
                              np.asarray(wb.residual))


def test_init_state_hands_fresh_buffers():
    """init_state must never alias the caller's params, and residuals
    must be one buffer PER worker (a shared zeros array would be
    deleted for everyone at the first donated apply)."""
    params0 = tiny_params()
    eng = make_engine(2, 1)
    st = eng.init_state(params0)
    assert st.workers[0].residual is not st.workers[1].residual
    _donate_all({"g": st.global_params, "snap": st.snapshots[0],
                 "w0": st.workers[0].params,
                 "r0": st.workers[0].residual})
    _assert_alive(params0)
    _assert_alive(st.workers[1].params)
    _assert_alive(st.workers[1].residual)


def test_snapshots_survive_worker_redispatch_donation():
    """After arrivals, the live snapshot table must hold copies no
    donated carry can delete out from under later stale arrivals."""
    eng = make_engine(2, 1)
    state, _ = eng.run(eng.init_state(tiny_params()), ticks=2)
    for snap in state.snapshots.values():
        _assert_alive(snap)
    _donate_all(state.global_params)
    # worker slots and remaining snapshots must be unaffected
    for w in state.workers:
        _assert_alive(w.params)


# ---------------------------------------------------------------------------
# checkpoint (satellite b): full state round-trip + preempt-restore
# ---------------------------------------------------------------------------

def test_state_tree_roundtrip_is_exact(tmp_path):
    eng = make_engine(2, 1, outer_grad_dtype="int4",
                      error_feedback=True,
                      scenario=Scenario.drop(2, 0.5, max_retries=1,
                                             seed=3))
    state, _ = eng.run(eng.init_state(tiny_params()), ticks=3)
    path = str(tmp_path / "async.npz")
    ckpt.save(path, async_diloco.state_to_tree(state),
              metadata={"k": 2})
    back = async_diloco.state_from_tree(ckpt.restore_tree(path),
                                        tiny_params())
    assert back.version == state.version
    assert back.events_done == state.events_done
    assert back.inner_done == state.inner_done
    assert set(back.snapshots) == set(state.snapshots)
    assert _global_equal(back.global_params, state.global_params)
    for wa, wb in zip(state.workers, back.workers):
        assert (wa.version, wa.active) == (wb.version, wb.active)
        assert np.array_equal(np.asarray(wa.residual),
                              np.asarray(wb.residual))
        assert _global_equal(wa.params, wb.params)
        assert _global_equal(wa.opt.m, wb.opt.m)
    assert ckpt.load_metadata(path)["k"] == 2


def test_preempted_and_restored_run_is_bit_identical(tmp_path):
    """The PR's headline robustness property: cut a faulty run
    mid-stream, checkpoint the FULL engine state, restore into a fresh
    engine, finish — bit-identical to the uninterrupted run (stable
    per-uid RNG + event cursor make the suffix replay exact)."""
    k = 2
    scen = Scenario(speeds=(1, 2), drop_prob=0.3, max_retries=1,
                    preemptions=((1, 3, 5),), seed=11)
    kw = dict(lam=0.8, scenario=scen, outer_grad_dtype="bfloat16",
              error_feedback=True)

    eng_a = make_engine(k, 2, **kw)
    state_a, hist_a = eng_a.run(eng_a.init_state(tiny_params()),
                                ticks=8)

    eng_b = make_engine(k, 2, **kw)
    state_b, hist_b1 = eng_b.run(eng_b.init_state(tiny_params()),
                                 ticks=8, max_events=3)
    path = str(tmp_path / "cut.npz")
    ckpt.save(path, async_diloco.state_to_tree(state_b))
    del eng_b, state_b
    eng_c = make_engine(k, 2, **kw)   # fresh process stand-in
    state_c = async_diloco.state_from_tree(ckpt.restore_tree(path),
                                           tiny_params())
    state_c, hist_b2 = eng_c.run(state_c, ticks=8)

    assert _global_equal(state_a.global_params, state_c.global_params)
    hist_b = hist_b1 + hist_b2
    assert len(hist_a) == len(hist_b)
    for ra, rb in zip(hist_a, hist_b):
        assert ra["event"] == rb["event"]
        assert ra["tick"] == rb["tick"]
        if ra["event"] == "arrival":
            assert ra["uid"] == rb["uid"]
            assert ra["inner_loss"] == rb["inner_loss"]
            assert ra["delta_norm"] == rb["delta_norm"]
    for wa, wc in zip(state_a.workers, state_c.workers):
        assert np.array_equal(np.asarray(wa.residual),
                              np.asarray(wc.residual))


# ---------------------------------------------------------------------------
# quantized wire + error feedback on the async path
# ---------------------------------------------------------------------------

def test_int4_wire_bytes_and_error_feedback_residual():
    from repro.kernels import ops as kops
    eng = make_engine(2, 1, outer_grad_dtype="int4",
                      error_feedback=True)
    state, hist = eng.run(eng.init_state(tiny_params()), ticks=2)
    n = 11
    assert eng.wire_bytes() == kops.transport_bytes(n, "int4",
                                                    packed=True)
    assert all(r["wire_bytes"] == eng.wire_bytes() for r in hist)
    # int4 rounding leaves a residual that error feedback carries
    assert any(float(np.abs(np.asarray(w.residual)).max()) > 0
               for w in state.workers)
    # f32 ships raw
    eng32 = make_engine(2, 1)
    eng32.init_state(tiny_params())
    assert eng32.wire_bytes() == 4 * n


def test_error_feedback_off_keeps_zero_residual():
    eng = make_engine(2, 1, outer_grad_dtype="int4",
                      error_feedback=False)
    state, _ = eng.run(eng.init_state(tiny_params()), ticks=2)
    for w in state.workers:
        assert float(np.abs(np.asarray(w.residual)).max()) == 0.0


# ---------------------------------------------------------------------------
# exactly-once over randomized scenarios (the apply-loop contract,
# engine level — deterministic sweep; hypothesis-shrunk variant in
# tests/test_async_properties.py)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(8))
def test_every_finished_delta_applied_exactly_once(seed):
    """Whatever the completion order (stragglers, retries, preemption),
    the multiset of applied uids equals the timeline's Arrival uids —
    nothing dropped, nothing double-applied — and lost/discarded
    phases never touch the server."""
    k, scen = random_scenario(seed)
    ticks = 3 + seed % 5
    eng = make_engine(k, 1, lam=0.9, scenario=scen)
    state, hist = eng.run(eng.init_state(tiny_params()), ticks=ticks)
    events = scen.timeline(k, ticks)
    want_applied = sorted(e.uid for e in events
                          if isinstance(e, faults.Arrival))
    got_applied = sorted(r["uid"] for r in hist
                         if r["event"] == "arrival")
    assert got_applied == want_applied
    assert len(got_applied) == len(set(got_applied))
    want_lost = sorted(e.uid for e in events
                       if isinstance(e, faults.Lost))
    got_lost = sorted(r["uid"] for r in hist if r["event"] == "lost")
    assert got_lost == want_lost
    # one outer application per arrival, no more
    assert state.version == len(got_applied)


# ---------------------------------------------------------------------------
# config validation
# ---------------------------------------------------------------------------

def test_async_rejects_streaming_fragments_and_bad_lambda():
    import dataclasses
    dcfg, tcfg = make_cfgs(2, 1)
    with pytest.raises(ValueError, match="streaming_fragments"):
        AsyncEngine(quad_loss, sample,
                    dataclasses.replace(dcfg, streaming_fragments=2),
                    tcfg)
    with pytest.raises(ValueError, match="lambda"):
        AsyncEngine(quad_loss, sample,
                    dataclasses.replace(dcfg, staleness_lambda=1.5),
                    tcfg)


def test_round_builder_rejects_async_transport():
    dcfg, tcfg = make_cfgs(2, 1)
    with pytest.raises(ValueError, match="async"):
        diloco.make_round(quad_loss, lambda kk, B, S: None, dcfg, tcfg)
