"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU).

Sweeps shapes/dtypes per kernel; flash attention additionally checks
GQA grouping, causal/window masks and non-block-aligned lengths.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (flash_attention as FK, fused_adamw as FA,
                           outer_nesterov as ON, sign_prune as SP,
                           ops, ref)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

ATTN_CASES = [
    # B, H, G, S, d, causal, window
    (2, 4, 2, 128, 64, True, 0),
    (1, 4, 4, 256, 32, True, 0),
    (2, 8, 2, 96, 64, True, 0),           # not block-aligned
    (1, 2, 1, 192, 64, True, 64),          # sliding window
    (1, 4, 2, 256, 64, False, 0),          # bidirectional (encoder)
    (1, 16, 4, 128, 128, True, 0),         # MXU-aligned head dim
]


@pytest.mark.parametrize("B,H,G,S,d,causal,window", ATTN_CASES)
def test_flash_attention_matches_ref(B, H, G, S, d, causal, window):
    key = jax.random.PRNGKey(hash((B, H, G, S, d)) % (2 ** 31))
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, S, d), jnp.float32)
    k = jax.random.normal(ks[1], (B, G, S, d), jnp.float32)
    v = jax.random.normal(ks[2], (B, G, S, d), jnp.float32)
    out = FK.flash_attention(q, k, v, causal=causal, window=window,
                             block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal,
        window=window).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 4, 128, 64)).astype(dtype)
    k = jax.random.normal(ks[1], (1, 2, 128, 64)).astype(dtype)
    v = jax.random.normal(ks[2], (1, 2, 128, 64)).astype(dtype)
    out = FK.flash_attention(q, k, v, interpret=True)
    want = ref.flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(out.astype(np.float32),
                               want.astype(np.float32), rtol=tol, atol=tol)


def test_flash_attention_vs_model_attention():
    """The kernel agrees with the model's chunked online-softmax
    (layers.attention) — two independent formulations."""
    from repro.models.layers import attention
    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 3)
    B, S, H, G, d = 2, 256, 8, 4, 64
    q = jax.random.normal(ks[0], (B, S, H, d))
    k = jax.random.normal(ks[1], (B, S, G, d))
    v = jax.random.normal(ks[2], (B, S, G, d))
    want = attention(q, k, v, causal=True, chunk=64)
    out = ops.flash_attention(q, k, v, causal=True, mode="interpret")
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# fused AdamW
# ---------------------------------------------------------------------------

ADAMW_SHAPES = [(17,), (1000,), (37, 53), (4, 16, 130), (256, 128)]


@pytest.mark.parametrize("shape", ADAMW_SHAPES)
def test_fused_adamw_matches_ref(shape):
    key = jax.random.PRNGKey(sum(shape))
    ks = jax.random.split(key, 4)
    p, g, m = (jax.random.normal(kk, shape) for kk in ks[:3])
    v = jnp.abs(jax.random.normal(ks[3], shape))
    args = dict(lr=3e-4, c1=0.19, c2=0.0975, b1=0.9, b2=0.95,
                eps=1e-8, weight_decay=0.1)
    out = FA.fused_adamw(p, g, m, v, interpret=True, **args)
    want = ref.fused_adamw(p, g, m, v, **args)
    for a, b in zip(out, want):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)


def test_fused_adamw_matches_optim_adamw():
    """The kernel's semantics equal the training-loop AdamW
    (optim/adamw.py) for one step."""
    from repro.optim import adamw
    key = jax.random.PRNGKey(1)
    params = {"w": jax.random.normal(key, (32, 16))}
    grads = {"w": jax.random.normal(jax.random.fold_in(key, 1), (32, 16))}
    st = adamw.init(params)
    new_p, new_st = adamw.update(grads, st, params, lr=1e-3)
    out_p, out_m, out_v = ops.adamw_update_tree(
        params, grads, st.m, st.v, lr=1e-3, count=1, mode="interpret")
    np.testing.assert_allclose(out_p["w"], new_p["w"], rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(out_m["w"], new_st.m["w"], rtol=1e-6,
                               atol=1e-6)
    np.testing.assert_allclose(out_v["w"], new_st.v["w"], rtol=1e-6,
                               atol=1e-6)


# ---------------------------------------------------------------------------
# sign pruning
# ---------------------------------------------------------------------------

PRUNE_CASES = [((16, 256), 0.5), ((7, 100), 0.25), ((64, 300), 0.75),
               ((1, 128), 0.5), ((5, 513), 0.5)]


@pytest.mark.parametrize("shape,frac", PRUNE_CASES)
def test_sign_prune_matches_ref(shape, frac):
    x = jax.random.normal(jax.random.PRNGKey(shape[1]), shape)
    out = SP.sign_prune(x, frac, interpret=True)
    want = ref.sign_prune(x, frac)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_sign_prune_elects_majority_sign():
    # a row dominated by positive mass must keep only positive entries
    x = jnp.asarray([[5.0, 4.0, 3.0, -0.1, -0.2, 2.0, 1.0, -0.3]])
    out = np.asarray(ref.sign_prune(x, 0.25))
    assert (out <= 0).sum() == (out == 0).sum()  # no negatives survive


# ---------------------------------------------------------------------------
# outer nesterov
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(77,), (33, 129), (8, 8, 8)])
def test_outer_nesterov_matches_ref(shape):
    key = jax.random.PRNGKey(sum(shape))
    ks = jax.random.split(key, 3)
    p, d, b = (jax.random.normal(kk, shape) for kk in ks)
    out = ON.outer_nesterov(p, d, b, lr=0.7, momentum=0.9, interpret=True)
    want = ref.outer_nesterov(p, d, b, lr=0.7, momentum=0.9)
    for a, w in zip(out, want):
        np.testing.assert_allclose(a, w, rtol=1e-6, atol=1e-6)


def test_outer_nesterov_matches_outer_opt():
    """Kernel == core/outer_opt Nesterov update for one step."""
    from repro.core import outer_opt
    key = jax.random.PRNGKey(2)
    params = {"w": jax.random.normal(key, (16, 8))}
    delta = {"w": 0.01 * jax.random.normal(jax.random.fold_in(key, 1),
                                           (16, 8))}
    st = outer_opt.init(params)
    new_p, new_st = outer_opt.update(delta, st, params, kind="nesterov",
                                     lr=0.7, momentum=0.9)
    out_p, out_b = ops.nesterov_update_tree(params, delta, st.buf,
                                            lr=0.7, momentum=0.9,
                                            mode="interpret")
    np.testing.assert_allclose(out_p["w"], new_p["w"], rtol=1e-6,
                               atol=1e-6)
    np.testing.assert_allclose(out_b["w"], new_st.buf["w"], rtol=1e-6,
                               atol=1e-6)


# ---------------------------------------------------------------------------
# flash attention backward (custom_vjp, on-chip recompute)
# ---------------------------------------------------------------------------

BWD_CASES = [
    (1, 4, 2, 128, 64, True, 0),
    (2, 2, 1, 96, 32, True, 0),       # non-block-aligned
    (1, 4, 4, 128, 64, True, 48),     # sliding window
    (1, 2, 2, 128, 64, False, 0),     # bidirectional
]


@pytest.mark.parametrize("B,H,G,S,d,causal,window", BWD_CASES)
def test_flash_attention_backward(B, H, G, S, d, causal, window):
    key = jax.random.PRNGKey(S + d)
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, H, S, d))
    k = jax.random.normal(ks[1], (B, G, S, d))
    v = jax.random.normal(ks[2], (B, G, S, d))
    dout = jax.random.normal(ks[3], (B, H, S, d))
    fa = FK.make_flash_attention_vjp(causal=causal, window=window,
                                     block_q=64, block_k=64,
                                     interpret=True)
    o, vjp = jax.vjp(fa, q, k, v)
    dq, dk, dv = vjp(dout)

    def ref_fn(q, k, v):
        return ref.flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=causal,
            window=window).transpose(0, 2, 1, 3)

    o_r, vjp_r = jax.vjp(ref_fn, q, k, v)
    dq_r, dk_r, dv_r = vjp_r(dout)
    np.testing.assert_allclose(o, o_r, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(dq, dq_r, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(dk, dk_r, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(dv, dv_r, rtol=5e-4, atol=5e-4)
