"""Figure 4: varying the communication frequency H.

Fixed total inner steps; H swept (micro-scale analog of the paper's
{50,...,2000}). Expectation: more frequent communication helps, with
diminishing returns — degradation from the most to the least frequent
setting stays mild (paper: +2.9% PPL from H=50 to H=1000)."""
from __future__ import annotations

from . import common as C

H_SWEEP = [2, 5, 10, 25, 50]


def run(scale: int = 1):
    p = dict(C.DEFAULTS)
    total_inner = 200 * scale
    arch, loss_fn, sampler = C.make_setup("non_iid", k=p["k"])
    params0, pre = C.pretrain(arch, loss_fn, sampler, p["pretrain"],
                              batch=p["batch"], seq=p["seq"],
                              lr=p["inner_lr"], warmup=p["warmup"],
                              total=p["pretrain"] + total_inner)
    rows = []
    for H in H_SWEEP:
        rounds = total_inner // H
        h, _ = C.run_diloco(arch, loss_fn, sampler, params0, k=p["k"],
                            H=H, rounds=rounds, step0=pre,
                            batch=p["batch"], seq=p["seq"],
                            eval_every=max(rounds // 10, 1))
        rows.append(dict(H=H, rounds=rounds, syncs=rounds,
                         ppl=C.final_ppl(h), curve=h))
    ppls = {r["H"]: r["ppl"] for r in rows}
    payload = {"rows": rows,
               "claims": {
                   "mild_degradation_20x_less_comm":
                       ppls[H_SWEEP[-1]] / ppls[H_SWEEP[0]] < 1.10,
                   "frequent_comm_not_worse":
                       ppls[H_SWEEP[0]] <= ppls[H_SWEEP[-1]] * 1.05}}
    C.save("fig4_comm_frequency", payload)
    return payload


if __name__ == "__main__":
    out = run()
    for r in out["rows"]:
        print(f"H={r['H']:4d} syncs={r['syncs']:3d} ppl={r['ppl']:.3f}")
    print(out["claims"])
