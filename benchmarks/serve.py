"""Serving benchmark: paged continuous batching + int4 weight serving
of a DiLoCo-trained checkpoint.

The inference half of the paper's claim ("the resulting model has the
same size and speed as a model trained in fully synchronous mode"),
measured end to end:

  1. TRAIN a checkpoint with the streaming sharded driver (one
     replica band per pod over 8 forced CPU devices; falls back to the
     simulated transport — recorded, not gated — when the host cannot
     lay out the pod mesh), then write it twice: the plain f32 npz and
     the int4 packed-weights format (``checkpoint.save_packed``).
  2. SERVE it through the continuous-batching engine under a heavy
     synthetic mix — Poisson arrivals over a prompt-length menu —
     measuring tokens/s and per-request p50/p99 latency after a warmup
     pass that pre-compiles every prompt-length prefill.
  3. GATE the properties that make the path trustworthy:

  ckpt_f32_serves_bit_identical     logits of the restored f32
                  checkpoint equal the in-memory trained params bitwise;
  paged_bit_identical_to_contiguous the paged KV cache reproduces the
                  contiguous ring exactly, token for token;
  int4_weights_logits_close         packed-weight logits within a
                  gated tolerance of f32;
  packed_weight_args_ge5x_smaller   XLA's compiled-memory analysis of
                  the fused decode step: weight argument bytes shrink
                  >= 5x when the step consumes the packed buffers and
                  dequantizes in-graph (measured, not modeled; demoted
                  to informational only where the backend reports no
                  memory analysis);
  packed_wire_ge5x_smaller          the on-disk/wire bytes ratio from
                  the packed manifest (f32_bytes / packed_bytes >= 5);
  continuous_tick_speedup_ge_1p5    engine ticks to drain the mix vs
                  the serial lower bound (sum of gen lengths — what a
                  slots=1 engine must spend);
  all_requests_completed, p50_le_p99  sanity on the load run.

Writes ``BENCH_serve.json`` at the repo root (reading guide in
benchmarks/README.md).

Run:  PYTHONPATH=src python -m benchmarks.serve [--requests 24 ...]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# standalone runs get 8 fake CPU devices so the checkpoint really comes
# off the sharded streaming driver (same pattern as benchmarks/
# streaming.py); under benchmarks.run the fallback row is recorded
if "jax" not in sys.modules and \
        "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 "
        + os.environ.get("XLA_FLAGS", ""))

import jax
import jax.numpy as jnp
import numpy as np

from . import common as C
from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import DiLoCoConfig, TrainConfig
from repro.core import diloco, pod_collectives, streaming
from repro.launch import hlo_analysis
from repro.launch.batching import ContinuousBatcher
from repro.launch.mesh import make_pod_mesh

ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
OUT_PATH = os.path.join(ROOT, "BENCH_serve.json")

PROMPT_MENU = (8, 16, 24, 48)      # few distinct lengths bound the
GEN_MENU = (4, 8, 16)              # number of prefill compilations


# ---------------------------------------------------------------------------
# checkpoint production: streaming sharded driver -> f32 + packed files
# ---------------------------------------------------------------------------

def train_checkpoint(outdir, *, k, H, rounds, batch, seq, seed):
    arch, loss_fn, sampler = C.make_setup(k=k, seed=seed)
    params, _ = C.pretrain(arch, loss_fn, sampler, 30, batch=batch,
                           seq=seq, lr=3e-3, warmup=10,
                           total=30 + rounds * H, seed=seed)
    dcfg = DiLoCoConfig(k=k, H=H, streaming_fragments=2, stream_tau=1,
                        transport="sharded")
    sharded = True
    try:
        mesh = make_pod_mesh(k)
    except ValueError:
        mesh, sharded = None, False
        dcfg = DiLoCoConfig(k=k, H=H, streaming_fragments=2,
                            stream_tau=1)
    tcfg = TrainConfig(inner_lr=3e-3, warmup_steps=10,
                       total_steps=30 + rounds * H, batch_size=batch,
                       seq_len=seq)
    val = sampler.sample_validation(jax.random.PRNGKey(10_000), 16, seq)
    run = diloco.make_run(loss_fn, sampler.sample_all_shards, dcfg,
                          tcfg, rounds_per_call=rounds,
                          total_steps=30 + rounds * H, batch_size=batch,
                          seq_len=seq, eval_tokens=val, eval_every=1,
                          donate=False, mesh=mesh)
    st = streaming.init_state(params, dcfg)
    if mesh is not None:
        st = pod_collectives.shard_stream_state(st, mesh)
    st, ms = run(st, jax.random.PRNGKey(seed + 2))
    # pull the servable params off the (possibly sharded) carry
    gp = jax.tree.map(lambda a: jnp.asarray(np.asarray(a)),
                      st.global_params)
    f32_path = os.path.join(outdir, "serve_ckpt.npz")
    packed_path = os.path.join(outdir, "serve_ckpt.packed.npz")
    ckpt.save(f32_path, {"params": gp}, metadata={"driver": "streaming"})
    man = ckpt.save_packed(packed_path, gp, n_fragments=4)
    return {
        "arch": arch, "params": gp, "manifest": man,
        "f32_path": f32_path, "packed_path": packed_path,
        "sharded_driver": sharded,
        "final_val_loss": float(np.asarray(ms["val_loss"])[-1]),
    }


# ---------------------------------------------------------------------------
# load generation + engine driving
# ---------------------------------------------------------------------------

def make_mix(rng, n, vocab):
    """n requests: menu prompt lengths, Poisson arrivals (exponential
    inter-arrival, mean 1.5 ticks)."""
    reqs = [(np.asarray(rng.integers(0, vocab, int(L)), np.int64),
             int(rng.choice(GEN_MENU)))
            for L in rng.choice(PROMPT_MENU, n)]
    arrivals = np.floor(np.cumsum(rng.exponential(1.5, n))).astype(int)
    return reqs, arrivals


def run_load(eng, reqs, arrivals):
    """Drive the engine under timed load; per-request wall latency."""
    t_start = time.perf_counter()
    submit_t, finish_t, rids = {}, {}, []
    ticks0, i = eng.ticks, 0
    while i < len(reqs) or eng.queue \
            or any(r is not None for r in eng.active):
        while i < len(reqs) and arrivals[i] <= eng.ticks - ticks0:
            rid = eng.submit(reqs[i][0], reqs[i][1])
            submit_t[rid] = time.perf_counter()
            rids.append(rid)
            i += 1
        eng.tick()
        for rid in rids:
            if rid in eng.finished and rid not in finish_t:
                finish_t[rid] = time.perf_counter()
    total_s = time.perf_counter() - t_start
    lat_ms = [1e3 * (finish_t[r] - submit_t[r]) for r in rids]
    gen_tokens = sum(len(eng.finished[r]) for r in rids)
    return {
        "requests": len(rids),
        "completed": sum(r in eng.finished for r in rids),
        "gen_tokens": gen_tokens,
        "total_s": total_s,
        "tokens_per_s": gen_tokens / total_s,
        "engine_ticks": eng.ticks - ticks0,
        "serial_tick_lower_bound": sum(g for _, g in reqs),
        "p50_ms": float(np.percentile(lat_ms, 50)),
        "p99_ms": float(np.percentile(lat_ms, 99)),
        "mean_ms": float(np.mean(lat_ms)),
    }


def warmup(eng, vocab):
    """Pre-compile every menu prompt length + the fused decode step."""
    rng = np.random.default_rng(0)
    for L in PROMPT_MENU:
        eng.submit(np.asarray(rng.integers(0, vocab, L), np.int64), 2)
    eng.run_until_drained()


# ---------------------------------------------------------------------------
# compiled-memory: weight argument bytes, f32 vs packed decode step
# ---------------------------------------------------------------------------

def _tree_bytes(tree):
    return int(sum(np.asarray(l).size * np.asarray(l).dtype.itemsize
                   for l in jax.tree.leaves(tree)))


def weight_arg_bytes(eng):
    """(weight_bytes, mem_items) of the fused decode step: compiled
    argument bytes minus the non-weight operands (cache, table, token
    ids, position, key) — what remains is the weight argument."""
    toks = jnp.zeros((eng.B,), jnp.int32)
    pos = jnp.asarray(0, jnp.int32)
    table = jnp.asarray(eng.table)
    compiled = eng._jit_step.lower(eng._weights, eng.cache, table, toks,
                                   pos, eng.key).compile()
    mem = hlo_analysis.memory_items(compiled)
    if not mem or "argument_size_in_bytes" not in mem:
        return None, mem
    nonweight = (_tree_bytes(eng.cache) + _tree_bytes(table)
                 + _tree_bytes(toks) + _tree_bytes(pos)
                 + _tree_bytes(eng.key))
    return mem["argument_size_in_bytes"] - nonweight, mem


# ---------------------------------------------------------------------------
# benchmark body
# ---------------------------------------------------------------------------

def run(scale: int = 1, *, k=4, H=6, rounds=4, batch=2, seq=32,
        slots=4, cache_len=96, requests=24, seed=0, out=OUT_PATH):
    requests = requests * scale
    os.makedirs(os.path.join(ROOT, "results"), exist_ok=True)
    trained = train_checkpoint(os.path.join(ROOT, "results"), k=k, H=H,
                               rounds=rounds, batch=batch, seq=seq,
                               seed=seed)
    arch, params = trained["arch"], trained["params"]
    vocab = arch.cfg.vocab_size
    man = trained["manifest"]
    print(f"checkpoint: sharded_driver={trained['sharded_driver']} "
          f"val={trained['final_val_loss']:.4f} "
          f"packed {man['f32_bytes']}B -> {man['packed_bytes']}B "
          f"({man['f32_bytes'] / man['packed_bytes']:.2f}x)")

    # --- gate: restored f32 checkpoint serves bit-identically
    restored = ckpt.restore(trained["f32_path"],
                            {"params": params})["params"]
    probe = jnp.asarray(
        np.random.default_rng(seed).integers(0, vocab, (2, 24)),
        jnp.int32)
    lf, _ = arch.prefill(params, {"tokens": probe}, cache_len=32)
    lr_, _ = arch.prefill(restored, {"tokens": probe}, cache_len=32)
    f32_bit_identical = bool(np.array_equal(np.asarray(lf),
                                            np.asarray(lr_)))

    # --- gate: int4 packed weights stay within logits tolerance
    packed = ckpt.load_packed(trained["packed_path"])
    bufs = {kk: jnp.asarray(v) for kk, v in packed["buffers"].items()}
    deq = ckpt.unpack_params(bufs, manifest=packed["manifest"],
                             example_tree=params)
    lq, _ = arch.prefill(deq, {"tokens": probe}, cache_len=32)
    scale_l = float(jnp.abs(lf).max())
    int4_err = float(jnp.abs(lf - lq).max())
    int4_close = bool(int4_err <= 0.25 * scale_l + 0.05)

    # --- gate: paged == contiguous, token for token (trained weights)
    rng = np.random.default_rng(seed + 1)
    small_reqs, _ = make_mix(rng, 8, vocab)
    outs = {}
    for paged in (False, True):
        eng = ContinuousBatcher(arch, restored, slots=2,
                                cache_len=cache_len, paged=paged)
        rids = [eng.submit(p, g) for p, g in small_reqs]
        done = eng.run_until_drained()
        outs[paged] = [done[r] for r in rids]
    paged_identical = bool(all(
        np.array_equal(a, b)
        for a, b in zip(outs[False], outs[True])))

    # --- compiled-memory: weight argument bytes of the decode step
    eng_f32 = ContinuousBatcher(arch, restored, slots=slots,
                                cache_len=cache_len)
    eng_pk = ContinuousBatcher(arch, restored, slots=slots,
                               cache_len=cache_len,
                               packed_weights=packed)
    wb_f32, mem_f32 = weight_arg_bytes(eng_f32)
    wb_pk, mem_pk = weight_arg_bytes(eng_pk)
    backend = jax.default_backend()
    if wb_f32 is not None and wb_pk is not None and wb_pk > 0:
        mem_ratio = wb_f32 / wb_pk
        mem_claim = bool(mem_ratio >= 5.0)
    else:
        # backend reports no memory analysis: record, don't gate
        mem_ratio = None
        mem_claim = {"value": None, "informational": True,
                     "backend": backend}

    # --- timed load: Poisson mix through the paged f32 engine
    warmup(eng_f32, vocab)
    reqs, arrivals = make_mix(np.random.default_rng(seed + 2),
                              requests, vocab)
    load = run_load(eng_f32, reqs, arrivals)
    tick_speedup = (load["serial_tick_lower_bound"]
                    / max(load["engine_ticks"], 1))
    print(f"load: {load['requests']} reqs {load['gen_tokens']} tokens "
          f"{load['tokens_per_s']:.1f} tok/s p50={load['p50_ms']:.1f}ms "
          f"p99={load['p99_ms']:.1f}ms tick-speedup={tick_speedup:.2f}x")

    # packed engine under the same mix: measured, recorded as data
    warmup(eng_pk, vocab)
    load_pk = run_load(eng_pk, *make_mix(
        np.random.default_rng(seed + 2), requests, vocab))

    report = {
        "config": {"k": k, "H": H, "rounds": rounds, "slots": slots,
                   "cache_len": cache_len, "requests": requests,
                   "prompt_menu": list(PROMPT_MENU),
                   "gen_menu": list(GEN_MENU), "backend": backend,
                   "sharded_driver": trained["sharded_driver"]},
        "checkpoint": {
            "final_val_loss": trained["final_val_loss"],
            "f32_bytes": man["f32_bytes"],
            "packed_bytes": man["packed_bytes"],
            "wire_ratio": man["f32_bytes"] / man["packed_bytes"],
            "int4_logits_max_err": int4_err,
            "logits_scale": scale_l,
        },
        "compiled_memory": {
            "f32": mem_f32, "packed": mem_pk,
            "weight_arg_bytes_f32": wb_f32,
            "weight_arg_bytes_packed": wb_pk,
            "weight_arg_ratio": mem_ratio,
        },
        "load_f32": load,
        "load_packed": load_pk,
        "tick_speedup": tick_speedup,
        "claims": {
            "ckpt_f32_serves_bit_identical": f32_bit_identical,
            "paged_bit_identical_to_contiguous": paged_identical,
            "int4_weights_logits_close": int4_close,
            "packed_wire_ge5x_smaller": bool(
                man["f32_bytes"] / man["packed_bytes"] >= 5.0),
            "packed_weight_args_ge5x_smaller": mem_claim,
            "continuous_tick_speedup_ge_1p5": bool(tick_speedup >= 1.5),
            "all_requests_completed": bool(
                load["completed"] == load["requests"]
                and load_pk["completed"] == load_pk["requests"]),
            "p50_le_p99": bool(load["p50_ms"] <= load["p99_ms"]),
            # where the pod mesh could not be laid out the checkpoint
            # still trains, but the sharded-driver provenance is only
            # recorded, not claimed
            "ckpt_from_sharded_driver": (
                True if trained["sharded_driver"]
                else {"value": False, "informational": True,
                      "backend": backend}),
        },
    }
    with open(out, "w") as f:
        json.dump(report, f, indent=1)
    print("wrote", out)
    C.save("serve", report)
    return report


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--H", type=int, default=6)
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=96)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=OUT_PATH)
    a = ap.parse_args(argv)
    return run(1, k=a.k, H=a.H, rounds=a.rounds, batch=a.batch,
               seq=a.seq, slots=a.slots, cache_len=a.cache_len,
               requests=a.requests, seed=a.seed, out=a.out)


if __name__ == "__main__":
    main()
