"""Subprocess harness for crash-grade experiments.

The resilience claims are about surviving the *process* dying, so the
benchmarks cannot run in-process: this module launches real
``repro.launch.train`` subprocesses, lets the injected ``Crash`` event
SIGKILL them mid-run, corrupts their newest snapshot on purpose, and
relaunches them with ``--resume auto`` — then reads back the
``--state-hash-out`` JSON to compare final states bit-for-bit.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_SRC = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))

# SIGKILL'd processes exit -9 from the harness's point of view; the
# launcher's own crash path uses os.kill(os.getpid(), SIGKILL).
SIGKILL_RC = -9


def train_cmd(args) -> list:
    return [sys.executable, "-m", "repro.launch.train",
            *[str(a) for a in args]]


def train_env(*, devices: int | None = None) -> dict:
    """Environment for a train subprocess: src on PYTHONPATH, CPU
    platform, optionally a forced host device count (the sharded
    transport's pods)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    if devices is not None:
        env["XLA_FLAGS"] = (
            env.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={devices}").strip()
    return env


def run_train(args, *, devices: int | None = None, check: bool = True,
              timeout: float = 1200.0) -> subprocess.CompletedProcess:
    """Run one train subprocess to completion. ``check=False`` for
    runs that are EXPECTED to die (crash injection)."""
    proc = subprocess.run(
        train_cmd(args), env=train_env(devices=devices),
        capture_output=True, text=True, timeout=timeout)
    if check and proc.returncode != 0:
        raise RuntimeError(
            f"train subprocess failed rc={proc.returncode}\n"
            f"cmd: {' '.join(train_cmd(args))}\n"
            f"stdout:\n{proc.stdout[-4000:]}\n"
            f"stderr:\n{proc.stderr[-4000:]}")
    return proc


def run_until_crash(args, *, devices: int | None = None,
                    timeout: float = 1200.0) -> subprocess.CompletedProcess:
    """Run a subprocess that carries a crash injection and assert it
    really died by SIGKILL (a clean exit means the injection never
    fired — a harness bug worth failing loudly on)."""
    proc = run_train(args, devices=devices, check=False, timeout=timeout)
    if proc.returncode == 0:
        raise RuntimeError(
            "crash-injected run exited cleanly — the Crash event "
            f"never fired\nstdout:\n{proc.stdout[-4000:]}")
    return proc


def corrupt_latest(ckpt_dir: str, *, mode: str = "truncate") -> str:
    """Damage the newest snapshot in ``ckpt_dir`` so its manifest no
    longer verifies. ``truncate`` chops the npz mid-file (the classic
    mid-write kill artifact); ``bitflip`` flips one payload byte
    (bit rot — the file still opens, the hashes disagree)."""
    from .manager import CheckpointManager
    mgr = CheckpointManager(ckpt_dir)
    steps = mgr.steps()
    if not steps:
        raise FileNotFoundError(f"no snapshots in {ckpt_dir}")
    path = mgr.path_of(steps[-1])
    size = os.path.getsize(path)
    if mode == "truncate":
        with open(path, "r+b") as f:
            f.truncate(max(1, size // 2))
    elif mode == "bitflip":
        with open(path, "r+b") as f:
            f.seek(size // 2)
            b = f.read(1)
            f.seek(size // 2)
            f.write(bytes([b[0] ^ 0xFF]))
    else:
        raise ValueError(f"unknown corruption mode {mode!r}")
    return path


def read_json(path: str) -> dict:
    with open(path) as f:
        return json.load(f)
