"""Quickstart: DiLoCo in ~40 lines with the public API.

Trains a small transformer with 4 DiLoCo replicas on non-i.i.d. shards
and compares against its starting point. Runs in ~1 minute on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DiLoCoConfig, TrainConfig
from repro.core import diloco
from repro.data.sharding import make_regime
from repro.models.registry import get_smoke_arch

# 1. a model (any of the 13 registered architectures; smoke = reduced)
arch = get_smoke_arch("diloco_150m")
loss_fn = lambda p, b: arch.loss(p, b)
params, _ = arch.init(jax.random.PRNGKey(0), arch.cfg)

# 2. data: k shards with distinct distributions (the hard, non-i.i.d.
#    regime the paper defaults to)
K, H, ROUNDS = 4, 10, 8
sampler = make_regime("non_iid", k=K, vocab_size=arch.cfg.vocab_size)

# 3. DiLoCo: inner AdamW, outer Nesterov (paper defaults)
dcfg = DiLoCoConfig(k=K, H=H)           # outer: Nesterov lr=0.7 mu=0.9
tcfg = TrainConfig(inner_lr=3e-3, warmup_steps=10,
                   total_steps=ROUNDS * H, batch_size=8, seq_len=64)
state = diloco.init_state(params, dcfg)
round_fn = diloco.make_round(loss_fn, sampler.sample_all_shards, dcfg,
                             tcfg, batch_size=8, seq_len=64)

# 4. train: ONE cross-replica communication per round (every H steps)
evaluate = diloco.make_eval(loss_fn)
val = sampler.sample_validation(jax.random.PRNGKey(42), 64, 64)
print(f"start: val ppl = {np.exp(float(evaluate(params, val))):.1f} "
      f"(entropy floor {np.exp(sampler.entropy_floor()):.1f})")
key = jax.random.PRNGKey(1)
for t in range(ROUNDS):
    key, sub = jax.random.split(key)
    state, metrics = round_fn(state, sub)
    ppl = np.exp(float(evaluate(state.global_params, val)))
    print(f"round {t + 1}: inner loss {float(metrics['inner_loss']):.3f}"
          f"  val ppl {ppl:.1f}")
print("each round ran", K, "replicas x", H, "AdamW steps with a single",
      "outer all-reduce - communication reduced", H, "x vs sync DDP")
