"""deepseek-v2-lite-16b [moe, arXiv:2405.04434]: 27L, d_model=2048,
16 heads, MLA kv_lora=512 (+64 decoupled-RoPE dims), MoE with 2 shared +
64 routed experts top-6 (the assignment's structured spec "64e top-6";
its free-text "160 routed" conflicts — see DESIGN.md), expert d_ff=1408,
vocab=102400."""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b", family="moe",
        n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, vocab_size=102_400,
        mla=True, kv_lora_rank=512, rope_head_dim=64,
        head_dim=128, v_head_dim=128,
        n_experts=64, n_shared_experts=2, top_k=6, moe_d_ff=1408,
        norm="rmsnorm", act="silu",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="deepseek-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=4, head_dim=32, v_head_dim=32, kv_lora_rank=64,
        rope_head_dim=16, d_ff=128, moe_d_ff=128, n_experts=4, top_k=2,
        n_shared_experts=1, vocab_size=256, attn_chunk=64,
        capacity_factor=4.0)
