"""Scenario input validation (every __post_init__ rejection, loudly)
and the crash-grade extensions: Crash timeline splice semantics,
crash_round pacing, and the nan-bomb round masks."""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import faults
from repro.core.faults import Crash, Scenario


# ---------------------------------------------------------------------------
# __post_init__ rejections, one by one
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bad", [-0.1, 1.5])
def test_drop_prob_out_of_range(bad):
    with pytest.raises(ValueError, match="drop_prob"):
        Scenario(drop_prob=bad)


def test_negative_latency_jitter():
    with pytest.raises(ValueError, match="latency_jitter"):
        Scenario(latency_jitter=-0.5)


def test_negative_max_retries():
    with pytest.raises(ValueError, match="max_retries"):
        Scenario(max_retries=-1)


def test_zero_retry_backoff():
    # 0 would make a retry instantaneous (and the retry loop pointless)
    with pytest.raises(ValueError, match="retry_backoff"):
        Scenario(retry_backoff=0)


def test_preemption_entry_arity():
    with pytest.raises(ValueError, match="triples"):
        Scenario(preemptions=((1, 2),))


def test_preemption_negative_leave():
    with pytest.raises(ValueError, match="leave tick"):
        Scenario(preemptions=((0, -1, 5),))


def test_preemption_never_returns_sentinel_is_legal():
    # rejoin <= 0 = elastic shrink; must construct fine
    s = Scenario(preemptions=((0, 3, 0),))
    assert s._preempt_of(2) == {0: [(3, 0)]}


def test_nan_bomb_entry_arity():
    with pytest.raises(ValueError, match="pairs"):
        Scenario(nan_bombs=((1,),))


def test_nan_bomb_negative_tick():
    with pytest.raises(ValueError, match="negative tick"):
        Scenario(nan_bombs=((0, -3),))


def test_valid_scenario_constructs():
    s = Scenario(drop_prob=0.5, max_retries=2, retry_backoff=2,
                 latency_jitter=0.3, preemptions=((1, 2, 5),),
                 crash_tick=7, nan_bombs=((0, 3),))
    assert s.crash_tick == 7 and s.nan_bombs == ((0, 3),)


# k-dependent range checks stay in the per-k views
def test_bomb_worker_out_of_range():
    s = Scenario(nan_bombs=((4, 1),))
    with pytest.raises(ValueError, match="out of range"):
        s._bombs_of(4)
    assert s._bombs_of(5) == ((4, 1),)


def test_preemption_worker_out_of_range():
    s = Scenario(preemptions=((3, 1, 4),))
    with pytest.raises(ValueError, match="out of range"):
        s._preempt_of(2)


def test_preemption_overlapping_spans_rejected():
    # same worker away twice with the second leave inside the first
    # span — silent mis-simulation without the check
    s = Scenario(preemptions=((0, 2, 8), (0, 5, 10)))
    with pytest.raises(ValueError, match="overlap"):
        s._preempt_of(2)


def test_preemption_rejoin_before_leave_rejected():
    with pytest.raises(ValueError, match="after"):
        Scenario(preemptions=((0, 5, 3),))._preempt_of(2)


# ---------------------------------------------------------------------------
# crash_round / nan_masks: tick -> barrier-round projection
# ---------------------------------------------------------------------------

def test_crash_round_pacing():
    assert Scenario().crash_round(4) == -1          # no crash scripted
    assert Scenario(crash_tick=5).crash_round(4) == 5   # T = 1
    # stragglers stretch the barrier: T = max(speeds) + max(latency)
    s = Scenario(speeds=(1, 1, 1, 3), latency=(0, 0, 0, 1),
                 crash_tick=9)
    assert s.sync_round_ticks(4) == 4
    assert s.crash_round(4) == 2


def test_nan_masks_layout_and_horizon():
    s = Scenario(speeds=(2, 2, 2, 2),               # T = 2
                 nan_bombs=((1, 4), (3, 5), (0, 99)))
    m = s.nan_masks(4, rounds=3)
    assert m.shape == (3, 4) and m.dtype == np.float32
    want = np.zeros((3, 4), np.float32)
    want[2, 1] = 1.0                                # tick 4 -> round 2
    want[2, 3] = 1.0                                # tick 5 -> round 2
    np.testing.assert_array_equal(m, want)          # tick 99: beyond R


# ---------------------------------------------------------------------------
# Crash in the timeline: a pure splice
# ---------------------------------------------------------------------------

def faulty_scenario(**kw) -> Scenario:
    return Scenario(speeds=(1, 2, 1, 1), latency=(0, 1, 0, 0),
                    drop_prob=0.2, max_retries=1, seed=3,
                    preemptions=((2, 3, 6),), **kw)


def test_crash_is_spliced_not_simulated():
    """The whole resume story rests on this: adding a Crash changes
    NOTHING else about the timeline (no rng draws, no uid), so a run
    restored from a pre-crash snapshot replays the identical suffix."""
    k, ticks = 4, 10
    clean = faulty_scenario().timeline(k, ticks)
    crashed = faulty_scenario(crash_tick=5).timeline(k, ticks)
    crashes = [e for e in crashed if isinstance(e, Crash)]
    assert crashes == [Crash(5)]
    assert tuple(e for e in crashed if not isinstance(e, Crash)) == clean


def test_crash_sorts_after_its_ticks_work():
    # the crash observes (takes down) the tick's completed work: every
    # other event at the crash tick precedes it
    ev = faulty_scenario(crash_tick=4).timeline(4, 10)
    idx = next(i for i, e in enumerate(ev) if isinstance(e, Crash))
    assert all(e.tick >= 4 for e in ev[idx:])
    assert all(not (e.tick == 4 and i > idx)
               for i, e in enumerate(ev) if not isinstance(e, Crash))


def test_crash_outside_horizon_never_fires():
    for tick in (-1, 10, 11):
        ev = faulty_scenario(crash_tick=tick).timeline(4, 10)
        assert not any(isinstance(e, Crash) for e in ev)


def test_crash_round_boundary_matches_timeline_crash():
    # the round-transport kill switch and the async timeline splice
    # agree on where the crash lands
    s = Scenario.uniform(4, crash_tick=6)
    assert s.crash_round(4) == 6 // s.sync_round_ticks(4)
    assert any(isinstance(e, Crash) and e.tick == 6
               for e in s.timeline(4, 12))


def test_crash_event_has_no_worker_field():
    # sort key uses getattr(e, "worker", -1); Crash carries only the
    # tick, by construction
    assert Crash._fields == ("tick",)
    assert faults.Lost._fields[:3] == ("tick", "worker", "uid")
